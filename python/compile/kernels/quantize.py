"""Layer-1 Pallas kernel: bucketed QSGD stochastic quantization.

The paper performs quantization *on the GPU*, overlapped with backprop
(double buffering, §5 Protocol); entropy coding stays on CPU threads. We
mirror that split: this kernel is the on-accelerator half (quantize +
dequantize on the level grid), and the Rust ``coding`` module is the CPU
half (Elias coding of the levels).

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the CUDA version
assigns one thread block per bucket with a shared-memory reduction for the
bucket scale. On TPU, each **bucket is one VMEM block** (`BlockSpec` row
below); the scale is a VPU in-block reduction and the randomized rounding is
elementwise VPU work. Quantization is memory-bound — the roofline is HBM
bandwidth, so the BlockSpec *is* the optimization: stream (v, u) in, q out,
3·d·4 bytes of VMEM per grid step, no MXU involvement.

Must run with ``interpret=True`` on this testbed: real TPU lowering emits a
Mosaic custom-call the CPU PJRT plugin cannot execute.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _quantize_kernel(v_ref, u_ref, q_ref, scale_ref, *, s: int, norm: str):
    """One grid step = one bucket (a (1, d) block resident in VMEM)."""
    v = v_ref[...]
    u = u_ref[...]
    absv = jnp.abs(v)
    if norm == "l2":
        scale = jnp.sqrt(jnp.sum(v * v))
    else:  # max
        scale = jnp.max(absv)
    safe = jnp.where(scale > 0, scale, 1.0)
    r = jnp.minimum(absv * (s / safe), float(s))
    lo = jnp.floor(r)
    p = r - lo
    lev = lo + (u < p).astype(v.dtype)
    q = jnp.sign(v) * scale * (lev / float(s))
    q_ref[...] = jnp.where(scale > 0, q, 0.0)
    scale_ref[...] = jnp.full(scale_ref.shape, scale, dtype=v.dtype)


@functools.partial(jax.jit, static_argnames=("s", "norm"))
def quantize_pallas(v2d: jnp.ndarray, u2d: jnp.ndarray, *, s: int, norm: str = "l2"):
    """Quantize-dequantize each bucket row of ``v2d`` with uniforms ``u2d``.

    Returns ``(q2d, scales)`` where ``q2d`` holds the on-grid reconstructed
    values ``F(b)·sgn·ℓ/s`` and ``scales`` has shape (num_buckets, 1). The
    scales let the CPU encoder recover the integer levels exactly:
    ``ℓ_i = round(|q_i|·s/F(b))``.
    """
    nb, d = v2d.shape
    kernel = functools.partial(_quantize_kernel, s=s, norm=norm)
    return pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, d), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, d), v2d.dtype),
            jax.ShapeDtypeStruct((nb, 1), v2d.dtype),
        ],
        interpret=True,
    )(v2d, u2d)


def quantize_flat(v: jnp.ndarray, u: jnp.ndarray, *, s: int, bucket: int, norm: str = "l2"):
    """Flat-vector entry point used by the L2 fused-gradient graphs.

    Pads to a bucket multiple (paper §4: tensors are reshaped to fit bucket
    sizes), runs the kernel, and returns ``(q, scales)`` with ``q`` unpadded
    back to length n.
    """
    n = v.shape[0]
    nb = -(-n // bucket)
    pad = nb * bucket - n
    v2 = jnp.pad(v, (0, pad)).reshape(nb, bucket)
    u2 = jnp.pad(u, (0, pad)).reshape(nb, bucket)
    q2, scales = quantize_pallas(v2, u2, s=s, norm=norm)
    return q2.reshape(-1)[:n], scales
