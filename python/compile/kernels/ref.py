"""Pure-jnp oracle for the bucketed stochastic-quantization kernel.

This is the correctness contract for the Pallas kernel in ``quantize.py`` and
for the Rust quantizer in ``rust/src/quant/stochastic.rs``: all three must
agree bit-for-bit on the *level* assignment given the same uniforms.

QSGD quantization (paper §3.1, with the §4 bucketing + max-norm variants):
given a bucket ``b`` of ``d`` consecutive values and a scale
``F(b) ∈ {‖b‖₂, ‖b‖∞}``, each coordinate is mapped to

    Q_s(b_i) = F(b) · sgn(b_i) · ξ_i,   ξ_i ∈ {0, 1/s, …, 1}

where, with ``r_i = |b_i|·s/F(b)``, ``ℓ = ⌊r_i⌋`` and ``p = r_i − ℓ``:

    ξ_i = (ℓ + 1{u_i < p}) / s      (u_i ~ U[0,1), supplied by the caller)

so that E[ξ_i] = |b_i|/F(b) (Lemma 3.1(i), unbiasedness).
"""

from __future__ import annotations

import jax.numpy as jnp


def bucket_scales(v2d: jnp.ndarray, norm: str) -> jnp.ndarray:
    """Per-bucket scale F(b): ‖b‖₂ (paper §3.1) or ‖b‖∞ (paper §4 variant).

    ``v2d`` has shape (num_buckets, d); returns shape (num_buckets, 1).
    """
    if norm == "l2":
        s = jnp.sqrt(jnp.sum(v2d * v2d, axis=-1, keepdims=True))
    elif norm == "max":
        s = jnp.max(jnp.abs(v2d), axis=-1, keepdims=True)
    else:
        raise ValueError(f"unknown norm {norm!r}")
    return s


def quantize_levels_ref(
    v2d: jnp.ndarray, u2d: jnp.ndarray, s: int, norm: str = "l2"
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Reference levels: returns (levels int32 in [0, s], scales (nb,1)).

    A zero bucket (scale == 0) quantizes to all-zero levels.
    """
    scale = bucket_scales(v2d, norm)
    safe = jnp.where(scale > 0, scale, 1.0)
    r = jnp.abs(v2d) * (s / safe)
    # Guard against fp overshoot: |b_i|·s/F(b) ≤ s mathematically, but fp
    # division can exceed it by an ulp for max-norm's extremal coordinate.
    r = jnp.minimum(r, float(s))
    lo = jnp.floor(r)
    p = r - lo
    lev = lo + (u2d < p).astype(v2d.dtype)
    lev = jnp.where(scale > 0, lev, 0.0)
    return lev.astype(jnp.int32), scale


def dequantize_ref(
    levels: jnp.ndarray, signs: jnp.ndarray, scale: jnp.ndarray, s: int
) -> jnp.ndarray:
    """Q_s value from (levels, signs, per-bucket scale)."""
    return scale * signs * (levels.astype(scale.dtype) / float(s))


def quantize_dequantize_ref(
    v2d: jnp.ndarray, u2d: jnp.ndarray, s: int, norm: str = "l2"
) -> jnp.ndarray:
    """End-to-end Q_s(v): quantize and reconstruct (the oracle the Pallas
    kernel is tested against)."""
    lev, scale = quantize_levels_ref(v2d, u2d, s, norm)
    signs = jnp.sign(v2d)
    return dequantize_ref(lev, signs, scale, s)


def quantize_flat_ref(
    v: jnp.ndarray, u: jnp.ndarray, s: int, bucket: int, norm: str = "l2"
) -> jnp.ndarray:
    """Flat-vector convenience wrapper: pads v to a multiple of ``bucket``
    (paper §4 reshapes tensors to fit bucket sizes), quantizes, unpads."""
    n = v.shape[0]
    nb = -(-n // bucket)
    pad = nb * bucket - n
    v2 = jnp.pad(v, (0, pad)).reshape(nb, bucket)
    u2 = jnp.pad(u, (0, pad)).reshape(nb, bucket)
    q = quantize_dequantize_ref(v2, u2, s, norm)
    return q.reshape(-1)[:n]
