"""AOT lowering: JAX/Pallas graphs → HLO *text* artifacts + manifest.json.

Run once at build time (``make artifacts``); the Rust coordinator is fully
self-contained afterwards. HLO text (NOT ``lowered.compiler_ir("hlo")`` proto
serialization) is the interchange format: jax ≥ 0.5 emits HloModuleProto with
64-bit instruction ids which xla_extension 0.5.1 rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Artifacts built (shapes are baked into the HLO and recorded in the manifest):

* ``logreg_grad``   — convex workload (Thm 3.4 / QSVRG experiments)
* ``mlp_grad``      — the paper's MNIST-style two-layer perceptron
* ``tfm_grad``      — transformer LM (the communication-bound e2e driver)
* ``*_grad_q``      — fused variants with the Layer-1 Pallas quantization
                      kernel applied to the gradient in-graph
* ``quantize``      — the standalone Pallas kernel, used by Rust tests to
                      cross-check the Rust quantizer level-for-level
"""

from __future__ import annotations

import argparse
import functools
import json
import pathlib

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model as M
from compile.kernels.quantize import quantize_pallas

F32 = jnp.float32
I32 = jnp.int32


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def lower_artifact(name, fn, in_specs, outdir, manifest, meta=None):
    lowered = jax.jit(fn).lower(*[s for _, s in in_specs])
    text = to_hlo_text(lowered)
    fname = f"{name}.hlo.txt"
    (outdir / fname).write_text(text)
    out_shapes = [
        {"shape": [int(d) for d in o.shape], "dtype": str(o.dtype)}
        for o in jax.eval_shape(fn, *[s for _, s in in_specs])
    ]
    manifest[name] = {
        "file": fname,
        "inputs": [
            {"name": n, "shape": [int(d) for d in s.shape], "dtype": str(s.dtype)}
            for n, s in in_specs
        ],
        "outputs": out_shapes,
        **(meta or {}),
    }
    print(f"  {name}: {len(text)} chars, inputs={[n for n, _ in in_specs]}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument("--logreg-dim", type=int, default=128)
    ap.add_argument("--logreg-batch", type=int, default=64)
    ap.add_argument("--mlp-sizes", default="256,128,10")
    ap.add_argument("--mlp-batch", type=int, default=64)
    ap.add_argument("--tfm-vocab", type=int, default=512)
    ap.add_argument("--tfm-dmodel", type=int, default=128)
    ap.add_argument("--tfm-layers", type=int, default=2)
    ap.add_argument("--tfm-heads", type=int, default=4)
    ap.add_argument("--tfm-dff", type=int, default=512)
    ap.add_argument("--tfm-seq", type=int, default=64)
    ap.add_argument("--tfm-batch", type=int, default=8)
    ap.add_argument("--q-s", type=int, default=15, help="levels for fused quantize (4-bit: 2^4-1)")
    ap.add_argument("--q-bucket", type=int, default=512)
    ap.add_argument("--q-norm", default="max", choices=["l2", "max"])
    args = ap.parse_args()

    outdir = pathlib.Path(args.outdir)
    outdir.mkdir(parents=True, exist_ok=True)
    manifest: dict = {}

    def qmeta(n):
        nb = -(-n // args.q_bucket)
        return {"q_s": args.q_s, "q_bucket": args.q_bucket, "q_norm": args.q_norm, "q_buckets": nb}

    # ---- logistic regression ------------------------------------------------
    dim, lb = args.logreg_dim, args.logreg_batch
    n_lr = M.layout_size(M.logreg_layout(dim))
    lr_loss = functools.partial(M.logreg_loss, dim=dim)
    lower_artifact(
        "logreg_grad",
        M.grad_fn(lr_loss),
        [("params", spec([n_lr])), ("x", spec([lb, dim])), ("y", spec([lb]))],
        outdir,
        manifest,
        meta={"params": n_lr, "layout": M.layout_manifest(M.logreg_layout(dim)), "batch": lb},
    )

    # ---- MLP ----------------------------------------------------------------
    sizes = [int(x) for x in args.mlp_sizes.split(",")]
    n_mlp = M.layout_size(M.mlp_layout(sizes))
    mlp_loss = functools.partial(M.mlp_loss, sizes=sizes)
    mlp_inputs = [
        ("params", spec([n_mlp])),
        ("x", spec([args.mlp_batch, sizes[0]])),
        ("y", spec([args.mlp_batch], I32)),
    ]
    lower_artifact(
        "mlp_grad",
        M.grad_fn(mlp_loss),
        mlp_inputs,
        outdir,
        manifest,
        meta={"params": n_mlp, "layout": M.layout_manifest(M.mlp_layout(sizes)), "batch": args.mlp_batch, "sizes": sizes},
    )
    lower_artifact(
        "mlp_grad_q",
        M.grad_q_fn(mlp_loss, s=args.q_s, bucket=args.q_bucket, norm=args.q_norm),
        [mlp_inputs[0], ("uniforms", spec([n_mlp]))] + mlp_inputs[1:],
        outdir,
        manifest,
        meta={"params": n_mlp, "layout": M.layout_manifest(M.mlp_layout(sizes)), "batch": args.mlp_batch, "sizes": sizes, **qmeta(n_mlp)},
    )

    # ---- transformer LM -----------------------------------------------------
    cfg = M.TransformerConfig(
        vocab=args.tfm_vocab,
        d_model=args.tfm_dmodel,
        n_layer=args.tfm_layers,
        n_head=args.tfm_heads,
        d_ff=args.tfm_dff,
        seq=args.tfm_seq,
    )
    n_tfm = M.layout_size(M.transformer_layout(cfg))
    tfm_loss = functools.partial(M.transformer_loss, cfg=cfg)
    tfm_inputs = [
        ("params", spec([n_tfm])),
        ("tokens", spec([args.tfm_batch, cfg.seq + 1], I32)),
    ]
    tfm_meta = {
        "params": n_tfm,
        "layout": M.layout_manifest(M.transformer_layout(cfg)),
        "batch": args.tfm_batch,
        "config": {
            "vocab": cfg.vocab, "d_model": cfg.d_model, "n_layer": cfg.n_layer,
            "n_head": cfg.n_head, "d_ff": cfg.d_ff, "seq": cfg.seq,
        },
    }
    lower_artifact("tfm_grad", M.grad_fn(tfm_loss), tfm_inputs, outdir, manifest, meta=tfm_meta)
    lower_artifact(
        "tfm_grad_q",
        M.grad_q_fn(tfm_loss, s=args.q_s, bucket=args.q_bucket, norm=args.q_norm),
        [tfm_inputs[0], ("uniforms", spec([n_tfm])), tfm_inputs[1]],
        outdir,
        manifest,
        meta={**tfm_meta, **qmeta(n_tfm)},
    )

    # ---- standalone Pallas quantize kernel (Rust cross-validation) ----------
    qnb, qd, qs = 64, 512, 15
    lower_artifact(
        "quantize",
        functools.partial(quantize_pallas, s=qs, norm="l2"),
        [("v", spec([qnb, qd])), ("u", spec([qnb, qd]))],
        outdir,
        manifest,
        meta={"q_s": qs, "q_bucket": qd, "q_norm": "l2", "q_buckets": qnb},
    )

    (outdir / "manifest.json").write_text(json.dumps(manifest, indent=2))
    print(f"wrote {outdir}/manifest.json ({len(manifest)} artifacts)")


if __name__ == "__main__":
    main()
