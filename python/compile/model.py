"""Layer-2 JAX models: forward/backward graphs over a *flat* parameter vector.

Every model here is exposed as a pure function

    grad_fn(params: f32[n], <batch inputs>) -> (loss: f32[], grad: f32[n])

so the Rust coordinator owns a single contiguous parameter buffer per model —
exactly the representation QSGD's bucketed quantization operates on (§4: "view
each gradient as a one-dimensional vector v, reshaping tensors if necessary").

Fused variants additionally run the Layer-1 Pallas quantization kernel on the
gradient *inside the same HLO module* (paper §5: quantization happens on the
accelerator, overlapped with backprop; only entropy coding runs on CPU):

    grad_q_fn(params, uniforms, <batch>) -> (loss, qgrad, scales)

Build-time only. `aot.py` lowers these to HLO text; Rust loads and executes
them via PJRT with zero Python on the hot path.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import jax
import jax.numpy as jnp

from compile.kernels.quantize import quantize_flat


# --------------------------------------------------------------------------
# Flat-parameter plumbing
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TensorSpec:
    """One named tensor inside the flat parameter vector."""

    name: str
    shape: tuple[int, ...]

    @property
    def size(self) -> int:
        out = 1
        for d in self.shape:
            out *= d
        return out


def layout_size(specs: Sequence[TensorSpec]) -> int:
    return sum(t.size for t in specs)


def unflatten(params: jnp.ndarray, specs: Sequence[TensorSpec]) -> dict[str, jnp.ndarray]:
    """Static slicing of the flat vector into named tensors."""
    out = {}
    off = 0
    for t in specs:
        out[t.name] = params[off : off + t.size].reshape(t.shape)
        off += t.size
    assert off == params.shape[0], f"layout {off} != params {params.shape[0]}"
    return out


def layout_manifest(specs: Sequence[TensorSpec]) -> list[dict]:
    """JSON-able layout description consumed by rust/src/models/layout.rs
    (tensor boundaries drive the <10K-element skip rule and bucket reshaping)."""
    out = []
    off = 0
    for t in specs:
        out.append({"name": t.name, "shape": list(t.shape), "offset": off, "size": t.size})
        off += t.size
    return out


# --------------------------------------------------------------------------
# Logistic regression (the paper's convex setting, Thm 3.4 / QSVRG)
# --------------------------------------------------------------------------


def logreg_layout(dim: int) -> list[TensorSpec]:
    return [TensorSpec("w", (dim,)), TensorSpec("b", (1,))]


def logreg_loss(params: jnp.ndarray, x: jnp.ndarray, y: jnp.ndarray, dim: int, l2: float = 1e-4):
    """Binary logistic regression with ridge term (ℓ-strong convexity for QSVRG)."""
    p = unflatten(params, logreg_layout(dim))
    logits = x @ p["w"] + p["b"][0]
    # y in {0,1}; numerically stable BCE-with-logits.
    loss = jnp.mean(jnp.maximum(logits, 0) - logits * y + jnp.log1p(jnp.exp(-jnp.abs(logits))))
    return loss + 0.5 * l2 * jnp.sum(params * params)


# --------------------------------------------------------------------------
# MLP classifier (the paper's MNIST two-layer perceptron)
# --------------------------------------------------------------------------


def mlp_layout(sizes: Sequence[int]) -> list[TensorSpec]:
    specs = []
    for i, (a, b) in enumerate(zip(sizes[:-1], sizes[1:])):
        specs.append(TensorSpec(f"fc{i}.w", (a, b)))
        specs.append(TensorSpec(f"fc{i}.b", (b,)))
    return specs


def mlp_loss(params: jnp.ndarray, x: jnp.ndarray, y: jnp.ndarray, sizes: Sequence[int]):
    """ReLU MLP with softmax cross-entropy; y is int32 class labels."""
    p = unflatten(params, mlp_layout(sizes))
    h = x
    nl = len(sizes) - 1
    for i in range(nl):
        h = h @ p[f"fc{i}.w"] + p[f"fc{i}.b"]
        if i + 1 < nl:
            h = jax.nn.relu(h)
    logp = jax.nn.log_softmax(h, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=-1))


# --------------------------------------------------------------------------
# Transformer LM (the paper's "recurrent" communication-bound workload class;
# stands in for the LSTM/AN4 experiment and the e2e driver)
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    vocab: int = 512
    d_model: int = 128
    n_layer: int = 2
    n_head: int = 4
    d_ff: int = 512
    seq: int = 64

    @property
    def d_head(self) -> int:
        return self.d_model // self.n_head


def transformer_layout(cfg: TransformerConfig) -> list[TensorSpec]:
    d, f = cfg.d_model, cfg.d_ff
    specs = [
        TensorSpec("embed", (cfg.vocab, d)),
        TensorSpec("pos", (cfg.seq, d)),
    ]
    for i in range(cfg.n_layer):
        specs += [
            TensorSpec(f"l{i}.ln1.g", (d,)),
            TensorSpec(f"l{i}.ln1.b", (d,)),
            TensorSpec(f"l{i}.attn.wqkv", (d, 3 * d)),
            TensorSpec(f"l{i}.attn.wo", (d, d)),
            TensorSpec(f"l{i}.ln2.g", (d,)),
            TensorSpec(f"l{i}.ln2.b", (d,)),
            TensorSpec(f"l{i}.mlp.w1", (d, f)),
            TensorSpec(f"l{i}.mlp.b1", (f,)),
            TensorSpec(f"l{i}.mlp.w2", (f, d)),
            TensorSpec(f"l{i}.mlp.b2", (d,)),
        ]
    specs += [
        TensorSpec("lnf.g", (d,)),
        TensorSpec("lnf.b", (d,)),
        TensorSpec("unembed", (d, cfg.vocab)),
    ]
    return specs


def _layer_norm(x, g, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * g + b


def transformer_loss(params: jnp.ndarray, tokens: jnp.ndarray, cfg: TransformerConfig):
    """Causal next-token LM loss. ``tokens`` is int32[B, seq+1]."""
    p = unflatten(params, transformer_layout(cfg))
    x_tok = tokens[:, :-1]
    y_tok = tokens[:, 1:]
    B, T = x_tok.shape
    h = p["embed"][x_tok] + p["pos"][None, :T, :]
    mask = jnp.tril(jnp.ones((T, T), dtype=bool))
    for i in range(cfg.n_layer):
        hn = _layer_norm(h, p[f"l{i}.ln1.g"], p[f"l{i}.ln1.b"])
        qkv = hn @ p[f"l{i}.attn.wqkv"]
        q, k, v = jnp.split(qkv, 3, axis=-1)

        def heads(t):
            return t.reshape(B, T, cfg.n_head, cfg.d_head).transpose(0, 2, 1, 3)

        q, k, v = heads(q), heads(k), heads(v)
        att = (q @ k.transpose(0, 1, 3, 2)) / jnp.sqrt(float(cfg.d_head))
        att = jnp.where(mask[None, None], att, -1e30)
        att = jax.nn.softmax(att, axis=-1)
        o = (att @ v).transpose(0, 2, 1, 3).reshape(B, T, cfg.d_model)
        h = h + o @ p[f"l{i}.attn.wo"]
        hn = _layer_norm(h, p[f"l{i}.ln2.g"], p[f"l{i}.ln2.b"])
        h = h + jax.nn.gelu(hn @ p[f"l{i}.mlp.w1"] + p[f"l{i}.mlp.b1"]) @ p[f"l{i}.mlp.w2"] + p[
            f"l{i}.mlp.b2"
        ]
    h = _layer_norm(h, p["lnf.g"], p["lnf.b"])
    logits = h @ p["unembed"]
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, y_tok[..., None], axis=-1))


# --------------------------------------------------------------------------
# (loss, grad) graphs and fused quantized-gradient graphs
# --------------------------------------------------------------------------


def grad_fn(loss_fn: Callable) -> Callable:
    """(params, *batch) -> (loss, grad) — the artifact Rust executes per step."""

    def f(params, *batch):
        loss, g = jax.value_and_grad(loss_fn)(params, *batch)
        return loss, g

    return f


def grad_q_fn(loss_fn: Callable, *, s: int, bucket: int, norm: str = "l2") -> Callable:
    """(params, uniforms, *batch) -> (loss, qgrad, scales).

    The Layer-1 Pallas kernel runs on the raw gradient inside the same HLO
    module — the on-device half of QSGD. ``scales`` (one per bucket) lets the
    Rust encoder recover exact integer levels for Elias coding.
    """

    def f(params, uniforms, *batch):
        loss, g = jax.value_and_grad(loss_fn)(params, *batch)
        q, scales = quantize_flat(g, uniforms, s=s, bucket=bucket, norm=norm)
        return loss, q, scales

    return f
