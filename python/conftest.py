"""Make the build-time `compile` package importable when pytest runs from
the repository root (`pytest python/tests/`)."""

import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent))
