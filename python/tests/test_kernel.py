"""Layer-1 correctness: Pallas kernel vs the pure-jnp oracle.

The kernel/oracle agreement is the CORE correctness signal for the quantizer
(the Rust implementation is cross-checked against the same oracle through the
``quantize`` artifact in rust/tests/). Hypothesis sweeps shapes, level counts,
norms and seeds.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed; property sweep skipped"
)
from hypothesis import given, settings, strategies as st  # noqa: E402

from compile.kernels import ref
from compile.kernels.quantize import quantize_flat, quantize_pallas


def _rand(nb, d, seed, scale=1.0):
    kv, ku = jax.random.split(jax.random.PRNGKey(seed))
    v = jax.random.normal(kv, (nb, d), dtype=jnp.float32) * scale
    u = jax.random.uniform(ku, (nb, d), dtype=jnp.float32)
    return v, u


@settings(max_examples=25, deadline=None)
@given(
    nb=st.integers(1, 8),
    d=st.sampled_from([1, 2, 7, 32, 64, 129]),
    s=st.sampled_from([1, 2, 3, 15, 255]),
    norm=st.sampled_from(["l2", "max"]),
    seed=st.integers(0, 2**16),
)
def test_kernel_matches_ref(nb, d, s, norm, seed):
    v, u = _rand(nb, d, seed)
    q, scales = quantize_pallas(v, u, s=s, norm=norm)
    qr = ref.quantize_dequantize_ref(v, u, s, norm)
    np.testing.assert_allclose(np.asarray(q), np.asarray(qr), rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(
        np.asarray(scales)[:, 0], np.asarray(ref.bucket_scales(v, norm))[:, 0], rtol=1e-6
    )


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(1, 2000),
    bucket=st.sampled_from([32, 64, 512]),
    s=st.sampled_from([1, 3, 15]),
    seed=st.integers(0, 2**16),
)
def test_flat_padding_matches_ref(n, bucket, s, seed):
    kv, ku = jax.random.split(jax.random.PRNGKey(seed))
    v = jax.random.normal(kv, (n,), dtype=jnp.float32)
    u = jax.random.uniform(ku, (n,), dtype=jnp.float32)
    q, _ = quantize_flat(v, u, s=s, bucket=bucket, norm="l2")
    qr = ref.quantize_flat_ref(v, u, s, bucket, "l2")
    np.testing.assert_allclose(np.asarray(q), np.asarray(qr), rtol=1e-6, atol=1e-7)


def test_levels_are_on_grid():
    """Every output value must equal scale·sgn·ℓ/s for integer ℓ ∈ [0, s]."""
    v, u = _rand(16, 128, 7)
    s = 15
    q, scales = quantize_pallas(v, u, s=s, norm="l2")
    lev = np.abs(np.asarray(q)) * s / np.asarray(scales)
    assert np.allclose(lev, np.round(lev), atol=1e-4)
    assert lev.max() <= s + 1e-4


def test_zero_bucket():
    v = jnp.zeros((3, 64), dtype=jnp.float32)
    u = jnp.full((3, 64), 0.5, dtype=jnp.float32)
    q, scales = quantize_pallas(v, u, s=4, norm="l2")
    assert np.all(np.asarray(q) == 0)
    assert np.all(np.asarray(scales) == 0)


def test_unbiasedness_monte_carlo():
    """Lemma 3.1(i): E[Q_s(v)] = v. Average over many uniform draws."""
    kv = jax.random.PRNGKey(3)
    v = jax.random.normal(kv, (4, 64), dtype=jnp.float32)
    s = 4
    trials = 600
    acc = np.zeros_like(np.asarray(v))
    for t in range(trials):
        u = jax.random.uniform(jax.random.PRNGKey(1000 + t), (4, 64), dtype=jnp.float32)
        acc += np.asarray(ref.quantize_dequantize_ref(v, u, s, "l2"))
    mean = acc / trials
    scale = np.asarray(ref.bucket_scales(v, "l2"))
    # per-coordinate stderr ≈ scale/(s·sqrt(trials)); allow 5 sigma
    tol = 5 * scale / (s * np.sqrt(trials))
    assert np.all(np.abs(mean - np.asarray(v)) < tol + 1e-6)


@pytest.mark.parametrize("s,norm", [(1, "l2"), (4, "l2"), (16, "l2")])
def test_variance_bound(s, norm):
    """Lemma 3.1(ii): E‖Q_s(v)−v‖² ≤ min(n/s², √n/s)·‖v‖² (per bucket, d=n)."""
    d = 256
    kv = jax.random.PRNGKey(11)
    v = jax.random.normal(kv, (1, d), dtype=jnp.float32)
    bound = min(d / s**2, np.sqrt(d) / s) * float(jnp.sum(v * v))
    trials = 400
    errs = []
    for t in range(trials):
        u = jax.random.uniform(jax.random.PRNGKey(t), (1, d), dtype=jnp.float32)
        q = ref.quantize_dequantize_ref(v, u, s, norm)
        errs.append(float(jnp.sum((q - v) ** 2)))
    assert np.mean(errs) <= bound * 1.05


def test_sparsity_bound():
    """Lemma 3.1(iii): E‖Q_s(v)‖₀ ≤ s(s+√n)."""
    d, s = 1024, 2
    v = jax.random.normal(jax.random.PRNGKey(5), (1, d), dtype=jnp.float32)
    trials = 200
    nnz = []
    for t in range(trials):
        u = jax.random.uniform(jax.random.PRNGKey(t), (1, d), dtype=jnp.float32)
        q = ref.quantize_dequantize_ref(v, u, s, "l2")
        nnz.append(int(jnp.sum(q != 0)))
    assert np.mean(nnz) <= s * (s + np.sqrt(d)) * 1.05
