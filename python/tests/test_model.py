"""Layer-2 correctness: model graphs over flat parameter vectors."""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M


def test_layout_roundtrip():
    specs = M.mlp_layout([8, 4, 2])
    n = M.layout_size(specs)
    p = jnp.arange(n, dtype=jnp.float32)
    t = M.unflatten(p, specs)
    assert t["fc0.w"].shape == (8, 4)
    assert t["fc1.b"].shape == (2,)
    # concatenating back reproduces the flat vector
    flat = jnp.concatenate([t[s.name].reshape(-1) for s in specs])
    np.testing.assert_array_equal(np.asarray(flat), np.asarray(p))


def test_layout_manifest_offsets():
    specs = M.logreg_layout(16)
    man = M.layout_manifest(specs)
    assert man[0] == {"name": "w", "shape": [16], "offset": 0, "size": 16}
    assert man[1]["offset"] == 16


def test_logreg_grad_matches_fd():
    """Analytic gradient vs central finite differences."""
    dim = 6
    loss = functools.partial(M.logreg_loss, dim=dim)
    n = M.layout_size(M.logreg_layout(dim))
    key = jax.random.PRNGKey(0)
    p = jax.random.normal(key, (n,)) * 0.3
    x = jax.random.normal(jax.random.PRNGKey(1), (32, dim))
    y = (jax.random.uniform(jax.random.PRNGKey(2), (32,)) > 0.5).astype(jnp.float32)
    _, g = M.grad_fn(loss)(p, x, y)
    eps = 1e-3
    for i in range(n):
        e = jnp.zeros(n).at[i].set(eps)
        fd = (loss(p + e, x, y) - loss(p - e, x, y)) / (2 * eps)
        assert abs(float(fd) - float(g[i])) < 1e-3


def test_mlp_loss_sane():
    sizes = [16, 8, 4]
    n = M.layout_size(M.mlp_layout(sizes))
    p = jax.random.normal(jax.random.PRNGKey(0), (n,)) * 0.1
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 16))
    y = jnp.zeros(8, dtype=jnp.int32)
    loss, g = M.grad_fn(functools.partial(M.mlp_loss, sizes=sizes))(p, x, y)
    # near-uniform predictions ⇒ loss ≈ log(num_classes)
    assert abs(float(loss) - np.log(4)) < 0.5
    assert g.shape == (n,)
    assert np.isfinite(np.asarray(g)).all()


@pytest.fixture(scope="module")
def tiny_tfm():
    cfg = M.TransformerConfig(vocab=32, d_model=16, n_layer=1, n_head=2, d_ff=32, seq=8)
    n = M.layout_size(M.transformer_layout(cfg))
    return cfg, n


def test_transformer_shapes(tiny_tfm):
    cfg, n = tiny_tfm
    p = jax.random.normal(jax.random.PRNGKey(0), (n,)) * 0.05
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, cfg.seq + 1), 0, cfg.vocab)
    loss, g = M.grad_fn(functools.partial(M.transformer_loss, cfg=cfg))(p, toks)
    assert np.isfinite(float(loss))
    assert abs(float(loss) - np.log(cfg.vocab)) < 1.0  # untrained ≈ uniform
    assert g.shape == (n,)


def test_transformer_learns(tiny_tfm):
    """A few SGD steps on a constant-token batch must reduce loss sharply."""
    cfg, n = tiny_tfm
    lossf = functools.partial(M.transformer_loss, cfg=cfg)
    gf = jax.jit(M.grad_fn(lossf))
    p = jax.random.normal(jax.random.PRNGKey(0), (n,)) * 0.05
    toks = jnp.tile(jnp.arange(cfg.seq + 1, dtype=jnp.int32) % cfg.vocab, (4, 1))
    l0, _ = gf(p, toks)
    for _ in range(30):
        _, g = gf(p, toks)
        p = p - 0.5 * g
    l1, _ = gf(p, toks)
    assert float(l1) < float(l0) * 0.5


def test_causality(tiny_tfm):
    """Changing a future token must not change earlier next-token losses."""
    cfg, n = tiny_tfm
    p = jax.random.normal(jax.random.PRNGKey(0), (n,)) * 0.05
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, cfg.seq + 1), 0, cfg.vocab)
    # Flip the last input token: the next-token logits for every earlier
    # position must be unchanged (the causal mask's contract).
    t2 = toks.at[0, cfg.seq - 1].set((int(toks[0, cfg.seq - 1]) + 1) % cfg.vocab)
    cfg_small = cfg
    spec = M.transformer_layout(cfg_small)

    def fwd_logits(tokens):
        pr = M.unflatten(p, spec)
        x = tokens[:, :-1]
        B, T = x.shape
        h = pr["embed"][x] + pr["pos"][None, :T, :]
        mask = jnp.tril(jnp.ones((T, T), dtype=bool))
        for i in range(cfg_small.n_layer):
            hn = M._layer_norm(h, pr[f"l{i}.ln1.g"], pr[f"l{i}.ln1.b"])
            qkv = hn @ pr[f"l{i}.attn.wqkv"]
            q, k, v = jnp.split(qkv, 3, axis=-1)
            def heads(t):
                return t.reshape(B, T, cfg_small.n_head, cfg_small.d_head).transpose(0, 2, 1, 3)
            q, k, v = heads(q), heads(k), heads(v)
            att = (q @ k.transpose(0, 1, 3, 2)) / jnp.sqrt(float(cfg_small.d_head))
            att = jnp.where(mask[None, None], att, -1e30)
            att = jax.nn.softmax(att, axis=-1)
            o = (att @ v).transpose(0, 2, 1, 3).reshape(B, T, cfg_small.d_model)
            h = h + o @ pr[f"l{i}.attn.wo"]
            hn = M._layer_norm(h, pr[f"l{i}.ln2.g"], pr[f"l{i}.ln2.b"])
            h = h + jax.nn.gelu(hn @ pr[f"l{i}.mlp.w1"] + pr[f"l{i}.mlp.b1"]) @ pr[f"l{i}.mlp.w2"] + pr[f"l{i}.mlp.b2"]
        h = M._layer_norm(h, pr["lnf.g"], pr["lnf.b"])
        return h @ pr["unembed"]

    la, lb = fwd_logits(toks), fwd_logits(t2)
    np.testing.assert_allclose(
        np.asarray(la)[0, : cfg.seq - 1], np.asarray(lb)[0, : cfg.seq - 1], atol=1e-5
    )


def test_grad_q_fused(tiny_tfm):
    """Fused graph: loss matches raw graph; qgrad is on-grid wrt scales."""
    cfg, n = tiny_tfm
    lossf = functools.partial(M.transformer_loss, cfg=cfg)
    p = jax.random.normal(jax.random.PRNGKey(0), (n,)) * 0.05
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, cfg.seq + 1), 0, cfg.vocab)
    u = jax.random.uniform(jax.random.PRNGKey(2), (n,))
    s, bucket = 15, 64
    loss_raw, g_raw = M.grad_fn(lossf)(p, toks)
    loss_q, qg, scales = M.grad_q_fn(lossf, s=s, bucket=bucket, norm="max")(p, u, toks)
    assert abs(float(loss_raw) - float(loss_q)) < 1e-6
    # q is on the level grid and within one step of the raw gradient
    nb = -(-n // bucket)
    sc = np.repeat(np.asarray(scales)[:, 0], bucket)[:n]
    err = np.abs(np.asarray(qg) - np.asarray(g_raw))
    assert np.all(err <= sc / s + 1e-7)
