"""Unit tests for scripts/check_bench_regression.py (stdlib-only — no JAX).

The perf lane's gatekeeper has to be trustworthy in exactly the failure
modes that would otherwise go unnoticed: a bench that silently produced
garbage JSON, or produced nothing at all, must exit 2 — never read as "no
regressions". These tests drive the script in-process via a subprocess-free
import so the advisory python lane covers it without extra dependencies.
"""

import importlib.util
import json
import pathlib
import sys

import pytest

SCRIPT = (pathlib.Path(__file__).resolve().parents[2]
          / "scripts" / "check_bench_regression.py")


def load_module():
    spec = importlib.util.spec_from_file_location("check_bench_regression", SCRIPT)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


MOD = load_module()


def report(rows):
    """A minimal schema-1 bench report."""
    return {"bench": "t", "schema": 1, "results": rows, "metrics": []}


def row(section, name, ns_per_coord):
    return {"section": section, "name": name, "median_ns": ns_per_coord * 100,
            "p10_ns": 1.0, "p90_ns": 1.0, "samples": 7,
            "coords": 100.0, "ns_per_coord": ns_per_coord}


def run_main(argv):
    old = sys.argv
    sys.argv = ["check_bench_regression.py"] + argv
    try:
        return MOD.main()
    finally:
        sys.argv = old


def write(path, obj):
    path.write_text(json.dumps(obj) if not isinstance(obj, str) else obj)


def test_pair_mode_ok_and_regression(tmp_path):
    base = tmp_path / "base.json"
    new = tmp_path / "new.json"
    write(base, report([row("enc", "hot", 100.0)]))

    write(new, report([row("enc", "hot", 110.0)]))  # 1.10x < 1.25x
    assert run_main([str(new), str(base)]) == 0

    write(new, report([row("enc", "hot", 200.0)]))  # 2.00x
    assert run_main([str(new), str(base)]) == 1


def test_missing_baseline_is_soft_skip(tmp_path):
    new = tmp_path / "new.json"
    write(new, report([row("enc", "hot", 1.0)]))
    assert run_main([str(new), str(tmp_path / "absent.json")]) == 0


def test_missing_results_file_is_hard_failure(tmp_path):
    base = tmp_path / "base.json"
    write(base, report([row("enc", "hot", 1.0)]))
    assert run_main([str(tmp_path / "absent.json"), str(base)]) == 2


@pytest.mark.parametrize("garbage", [
    "not json at all {",
    json.dumps([1, 2, 3]),                        # top level not an object
    json.dumps({"results": "nope"}),              # results not a list
    json.dumps({"results": [42]}),                # non-object row
    json.dumps({"results": [{"section": "s", "name": "n",
                             "ns_per_coord": "fast"}]}),  # non-numeric
])
def test_malformed_results_exit_2(tmp_path, garbage):
    base = tmp_path / "base.json"
    new = tmp_path / "new.json"
    write(base, report([row("enc", "hot", 1.0)]))
    write(new, garbage)
    assert run_main([str(new), str(base)]) == 2


def test_one_sided_rows_never_fail(tmp_path):
    base = tmp_path / "base.json"
    new = tmp_path / "new.json"
    write(base, report([row("enc", "hot", 100.0), row("gone", "row", 1.0)]))
    write(new, report([row("enc", "hot", 90.0), row("brand", "new", 9e9)]))
    assert run_main([str(new), str(base)]) == 0


def test_discovery_compares_every_bench(tmp_path):
    results = tmp_path / "run"
    baselines = tmp_path / "baselines"
    results.mkdir()
    baselines.mkdir()
    write(results / "BENCH_alpha.json", report([row("s", "a", 100.0)]))
    write(baselines / "alpha.json", report([row("s", "a", 100.0)]))
    write(results / "BENCH_beta.json", report([row("s", "b", 100.0)]))
    write(baselines / "beta.json", report([row("s", "b", 100.0)]))
    args = ["--results-dir", str(results), "--baseline-dir", str(baselines)]
    assert run_main(args) == 0

    # a regression in ANY discovered bench fails the whole check
    write(results / "BENCH_beta.json", report([row("s", "b", 500.0)]))
    assert run_main(args) == 1

    # malformed output from any bench dominates a clean comparison elsewhere
    write(results / "BENCH_beta.json", "garbage{")
    assert run_main(args) == 2

    # a bench without a committed baseline is a soft skip, not a failure
    write(results / "BENCH_beta.json", report([row("s", "b", 1.0)]))
    (baselines / "beta.json").unlink()
    assert run_main(args) == 0


def test_discovery_with_no_results_is_hard_failure(tmp_path):
    assert run_main(["--results-dir", str(tmp_path)]) == 2


def rate_row(section, name, per_sec):
    return {"section": section, "name": name,
            "per_sec": per_sec, "direction": "higher"}


def test_throughput_rows_invert_the_ratio(tmp_path):
    """direction:higher rows regress when throughput DROPS, not rises."""
    base = tmp_path / "base.json"
    new = tmp_path / "new.json"
    write(base, report([rate_row("traffic", "msgs/sec", 500.0)]))

    # Far above the floor: obviously fine (a latency-style new/base ratio
    # of 100x would wrongly flag this).
    write(new, report([rate_row("traffic", "msgs/sec", 50000.0)]))
    assert run_main([str(new), str(base)]) == 0

    # Just inside the floor: 500/450 = 1.11x < 1.25x.
    write(new, report([rate_row("traffic", "msgs/sec", 450.0)]))
    assert run_main([str(new), str(base)]) == 0

    # Collapsed throughput regresses: 500/100 = 5x.
    write(new, report([rate_row("traffic", "msgs/sec", 100.0)]))
    assert run_main([str(new), str(base)]) == 1

    # Zero throughput must regress, not divide-by-zero crash.
    write(new, report([rate_row("traffic", "msgs/sec", 0.0)]))
    assert run_main([str(new), str(base)]) == 1


def test_mixed_direction_report_checks_each_row_its_own_way(tmp_path):
    """One report can mix latency ceilings and throughput floors."""
    base = tmp_path / "base.json"
    new = tmp_path / "new.json"
    write(base, report([row("push", "decode", 100.0),
                        rate_row("traffic", "msgs/sec", 500.0)]))

    # Both healthy: latency under ceiling, throughput over floor.
    write(new, report([row("push", "decode", 80.0),
                       rate_row("traffic", "msgs/sec", 9000.0)]))
    assert run_main([str(new), str(base)]) == 0

    # Latency fine but throughput collapsed — the rate row alone fails it.
    write(new, report([row("push", "decode", 80.0),
                       rate_row("traffic", "msgs/sec", 50.0)]))
    assert run_main([str(new), str(base)]) == 1


def test_unknown_direction_is_malformed(tmp_path):
    base = tmp_path / "base.json"
    new = tmp_path / "new.json"
    write(base, report([row("enc", "hot", 1.0)]))
    write(new, report([{"section": "s", "name": "n",
                        "per_sec": 5.0, "direction": "sideways"}]))
    assert run_main([str(new), str(base)]) == 2
