"""AOT lowering contract: HLO-text interchange + manifest integrity.

The interchange format requirements come from /opt/xla-example/README.md:
HLO *text* (not serialized proto) so xla_extension 0.5.1 can re-parse with
reassigned instruction ids.
"""

import functools
import json
import pathlib

import jax
import jax.numpy as jnp

from compile import aot, model as M


def test_to_hlo_text_produces_parseable_module():
    lowered = jax.jit(lambda x, y: (x @ y + 1.0,)).lower(
        jax.ShapeDtypeStruct((2, 3), jnp.float32), jax.ShapeDtypeStruct((3, 2), jnp.float32)
    )
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule")
    assert "parameter(0)" in text
    # return_tuple=True ⇒ root is a tuple
    assert "tuple" in text


def test_lower_artifact_writes_file_and_manifest(tmp_path: pathlib.Path):
    manifest = {}
    dim = 8
    loss = functools.partial(M.logreg_loss, dim=dim)
    n = M.layout_size(M.logreg_layout(dim))
    aot.lower_artifact(
        "toy",
        M.grad_fn(loss),
        [
            ("params", aot.spec([n])),
            ("x", aot.spec([4, dim])),
            ("y", aot.spec([4])),
        ],
        tmp_path,
        manifest,
        meta={"params": n, "layout": M.layout_manifest(M.logreg_layout(dim)), "batch": 4},
    )
    assert (tmp_path / "toy.hlo.txt").exists()
    e = manifest["toy"]
    assert e["params"] == n
    assert [i["name"] for i in e["inputs"]] == ["params", "x", "y"]
    assert e["outputs"][0]["shape"] == []  # scalar loss
    assert e["outputs"][1]["shape"] == [n]  # gradient
    # manifest must be JSON-serialisable (the Rust parser consumes it)
    json.dumps(manifest)


def test_built_manifest_consistent_with_layouts():
    """If artifacts/ exists, every grad artifact's layout must cover exactly
    its parameter count and the fused variants must carry quant metadata."""
    art_dir = pathlib.Path(__file__).resolve().parents[2] / "artifacts"
    mpath = art_dir / "manifest.json"
    if not mpath.exists():
        import pytest

        pytest.skip("artifacts not built")
    manifest = json.loads(mpath.read_text())
    for name, e in manifest.items():
        assert (art_dir / e["file"]).exists(), name
        if "layout" in e:
            total = sum(t["size"] for t in e["layout"])
            assert total == e["params"], name
            offs = [t["offset"] for t in e["layout"]]
            assert offs == sorted(offs)
        if name.endswith("_q") or name == "quantize":
            assert e["q_s"] >= 1 and e["q_bucket"] >= 1, name
