"""Unit tests for scripts/check_trace.py (stdlib-only — no JAX).

The transport-e2e lane trusts this validator to certify the Chrome trace
and JSONL span logs that the Rust side exports under `--trace-out`. The
failure modes that matter are the quiet ones: an empty directory, a rank
that never exported, or a trace whose begin/end events silently stopped
balancing — none of those may read as "traces are fine".
"""

import importlib.util
import json
import pathlib
import sys

import pytest

SCRIPT = (pathlib.Path(__file__).resolve().parents[2]
          / "scripts" / "check_trace.py")


def load_module():
    spec = importlib.util.spec_from_file_location("check_trace", SCRIPT)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


MOD = load_module()


def ev(name, ph, ts, pid=0, tid=0, rank=0, step=0):
    return {"name": name, "ph": ph, "ts": ts, "pid": pid, "tid": tid,
            "args": {"rank": rank, "step": step}}


GOOD_CHROME = [
    ev("step", "B", 0.0),
    ev("exchange", "B", 1.5),
    ev("ring.hop", "B", 1.5),       # zero-duration child, shared timestamp
    ev("ring.hop", "E", 1.5),
    ev("exchange", "E", 5.0),
    ev("step", "E", 9.0),
    ev("step", "B", 0.0, tid=1),    # second thread restarts its own clock
    ev("step", "E", 2.0, tid=1),
]


def span(t_ns, dur_ns=10, name="step", rank=0, tid=0, step=0):
    return {"t_ns": t_ns, "dur_ns": dur_ns, "name": name,
            "rank": rank, "tid": tid, "step": step}


def jsonl(spans):
    return "".join(json.dumps(s) + "\n" for s in spans)


def run_main(argv):
    old = sys.argv
    sys.argv = ["check_trace.py"] + argv
    try:
        return MOD.main()
    finally:
        sys.argv = old


def write_dir(tmp_path, chrome=None, spans=None, rank=0):
    if chrome is not None:
        (tmp_path / f"trace_rank{rank}.json").write_text(json.dumps(chrome))
    if spans is not None:
        (tmp_path / f"events_rank{rank}.jsonl").write_text(jsonl(spans))


def test_valid_directory_passes(tmp_path):
    write_dir(tmp_path, GOOD_CHROME, [span(0), span(20, tid=1), span(40)])
    assert run_main([str(tmp_path)]) == 0


def test_explicit_files_pass(tmp_path):
    write_dir(tmp_path, GOOD_CHROME, [span(0)])
    assert run_main([str(tmp_path / "trace_rank0.json"),
                     str(tmp_path / "events_rank0.jsonl")]) == 0


def test_empty_directory_is_exit_2(tmp_path):
    assert run_main([str(tmp_path)]) == 2


def test_missing_path_is_exit_2(tmp_path):
    assert run_main([str(tmp_path / "nope")]) == 2


def test_expect_ranks_catches_missing_rank(tmp_path):
    write_dir(tmp_path, GOOD_CHROME, rank=0)
    assert run_main([str(tmp_path), "--expect-ranks", "1"]) == 0
    assert run_main([str(tmp_path), "--expect-ranks", "2"]) == 2


@pytest.mark.parametrize("chrome", [
    "not json {",
    json.dumps({"traceEvents": []}),                       # not an array
    json.dumps([42]),                                      # non-object event
    json.dumps([ev("s", "X", 0.0)]),                       # bad phase
    json.dumps([ev("", "B", 0.0), ev("", "E", 1.0)]),      # empty name
    json.dumps([ev("s", "B", 5.0), ev("s", "E", 1.0)]),    # ts goes backwards
    json.dumps([ev("s", "B", 0.0)]),                       # unclosed span
    json.dumps([ev("s", "E", 0.0)]),                       # end without begin
    json.dumps([ev("a", "B", 0.0), ev("b", "E", 1.0)]),    # mismatched close
    json.dumps([{"name": "s", "ph": "B", "ts": 0.0,
                 "pid": 0, "tid": 0, "args": {}}]),        # args missing rank
])
def test_malformed_chrome_is_exit_1(tmp_path, chrome):
    (tmp_path / "trace_rank0.json").write_text(chrome)
    assert run_main([str(tmp_path)]) == 1


def test_interleaved_tids_only_need_per_tid_order(tmp_path):
    # tid 0 at t=100 after tid 1 at t=50 is fine; regression within one
    # tid is not.
    ok = [span(0, tid=0), span(50, tid=1), span(100, tid=0)]
    write_dir(tmp_path, spans=ok)
    assert run_main([str(tmp_path)]) == 0
    bad = [span(100, tid=0), span(50, tid=0)]
    write_dir(tmp_path, spans=bad)
    assert run_main([str(tmp_path)]) == 1


@pytest.mark.parametrize("lines", [
    "not json\n",
    json.dumps([1, 2]) + "\n",                             # not an object
    jsonl([{"t_ns": 0, "dur_ns": 1, "name": "",            # empty name
            "rank": 0, "tid": 0, "step": 0}]),
    jsonl([{"t_ns": -5, "dur_ns": 1, "name": "s",          # negative time
            "rank": 0, "tid": 0, "step": 0}]),
    jsonl([{"t_ns": 0, "name": "s",                        # missing dur_ns
            "rank": 0, "tid": 0, "step": 0}]),
])
def test_malformed_jsonl_is_exit_1(tmp_path, lines):
    (tmp_path / "events_rank0.jsonl").write_text(lines)
    assert run_main([str(tmp_path)]) == 1


def test_one_bad_file_fails_the_whole_directory(tmp_path):
    write_dir(tmp_path, GOOD_CHROME, [span(0)])
    write_dir(tmp_path, [ev("s", "B", 0.0)], rank=1)       # rank 1 unclosed
    assert run_main([str(tmp_path)]) == 1
