//! Cluster simulation: reproduce the *shape* of Figure 2/4 — per-network
//! epoch-time breakdown (communication vs computation) across 2–16 GPUs and
//! all compression arms — on the calibrated K80/PCIe interconnect model.
//!
//! ```sh
//! cargo run --release --example cluster_sim                   # all networks
//! cargo run --release --example cluster_sim -- --network vgg19 --preset 10gbe
//! ```

use qsgd::config::Args;
use qsgd::coordinator::epoch_sim::{simulate_epoch, EpochArm};
use qsgd::models::{zoo, CostModel};
use qsgd::simnet::{Preset, SimNet};
use qsgd::util::stats;

fn bar(frac: f64, width: usize) -> String {
    let filled = (frac * width as f64).round() as usize;
    format!("{}{}", "#".repeat(filled.min(width)), ".".repeat(width - filled.min(width)))
}

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let preset: Preset =
        args.string("preset", "k80").parse().map_err(|e: String| anyhow::anyhow!(e))?;
    let cost = CostModel::k80();

    let nets = match args.get("network") {
        Some(n) => vec![zoo::by_name(n).ok_or_else(|| anyhow::anyhow!("unknown network '{n}'"))?],
        None => zoo::table1_networks(),
    };
    let arms = [
        ("32bit", EpochArm::fp32()),
        ("1BitSGD", EpochArm::onebit()),
        ("QSGD 2bit", EpochArm::qsgd(2, 64)),
        ("QSGD 4bit", EpochArm::qsgd(4, 512)),
        ("NUQ 4bit", EpochArm::nuqsgd(4, 512)),
    ];

    for net in &nets {
        println!(
            "\n=== {} ({} params, {} samples/epoch) — bars: comm '#' / compute '.' ===",
            net.name,
            stats::fmt_bytes(net.params() as f64 * 4.0),
            net.epoch_samples
        );
        for gpus in [2usize, 4, 8, 16] {
            let simnet = SimNet::preset(gpus, preset);
            // normalise bars to the slowest arm at this GPU count
            let sims: Vec<_> = arms
                .iter()
                .map(|(label, arm)| (label, simulate_epoch(net, gpus, arm, &simnet, &cost, 1, 0)))
                .collect();
            let tmax = sims.iter().map(|(_, s)| s.epoch_time()).fold(0.0, f64::max);
            println!("  {gpus:>2} GPUs:");
            for (label, s) in &sims {
                let total = s.epoch_time();
                let comm_frac = s.breakdown.comm_fraction();
                let width = ((total / tmax) * 46.0).round() as usize;
                let comm_w = (comm_frac * width as f64).round() as usize;
                println!(
                    "    {label:<10} [{}{}] {:<9} comm {:>3.0}%",
                    "#".repeat(comm_w.min(width)),
                    ".".repeat(width - comm_w.min(width)),
                    stats::fmt_duration(total),
                    comm_frac * 100.0,
                );
            }
        }
        // the headline ratios for this network at 8 GPUs
        let simnet = SimNet::preset(8, preset);
        let fp = simulate_epoch(net, 8, &EpochArm::fp32(), &simnet, &cost, 1, 0);
        let q4 = simulate_epoch(net, 8, &EpochArm::qsgd(4, 512), &simnet, &cost, 1, 0);
        println!(
            "  → 8-GPU 4-bit speedup {:.2}x; comm time cut {:.1}x; {} on the wire per step (was {})",
            fp.epoch_time() / q4.epoch_time(),
            fp.breakdown.communication().secs() / q4.breakdown.communication().secs(),
            stats::fmt_bytes(q4.message_bytes as f64),
            stats::fmt_bytes(fp.message_bytes as f64),
        );
        let _ = bar(0.5, 10); // keep helper linked
    }
    Ok(())
}
