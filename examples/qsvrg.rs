//! QSVRG (§3.3, Theorem 3.6): linear convergence with quantized
//! variance-reduced updates, vs exact parallel SVRG and plain QSGD.
//!
//! ```sh
//! cargo run --release --example qsvrg -- --epochs 10 --processors 4
//! ```

use qsgd::config::Args;
use qsgd::coordinator::svrg::{self, SvrgConfig};
use qsgd::data::{LogisticProblem, Objective};
use qsgd::metrics::Table;
use qsgd::util::stats;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let epochs = args.usize("epochs", 10);
    let processors = args.usize("processors", 4);
    let seed = args.u64("seed", 0);

    let obj = LogisticProblem::generate(512, 128, 0.02, seed);
    println!(
        "ridge logistic: m=512 components, n=128, κ = L/ℓ ≈ {:.1}, {processors} processors",
        obj.smoothness() / obj.strong_convexity()
    );
    let f_star = svrg::solve_f_star(&obj, 8000);
    println!("f* ≈ {f_star:.6} (GD to high precision)\n");

    let run = |quantize: bool| {
        let cfg = SvrgConfig { processors, epochs, iters: None, eta: None, seed, quantize };
        svrg::run(&cfg, &obj, f_star)
    };
    let rq = run(true)?;
    let re = run(false)?;

    let mut table = Table::new(&["epoch", "QSVRG gap", "exact SVRG gap", "0.9^p ref"]);
    for e in 0..=epochs {
        let gq = rq.gap.points.get(e).map(|p| p.1).unwrap_or(f64::NAN);
        let ge = re.gap.points.get(e).map(|p| p.1).unwrap_or(f64::NAN);
        let reference = rq.gap.points[0].1 * 0.9f64.powi(e as i32);
        table.row(&[
            e.to_string(),
            format!("{gq:.3e}"),
            format!("{ge:.3e}"),
            format!("{reference:.3e}"),
        ]);
    }
    table.print();

    println!(
        "\nTheorem 3.6 bits bound: ≤ {:.0} bits/processor/epoch ({}).",
        rq.bits_bound_per_epoch,
        stats::fmt_bytes(rq.bits_bound_per_epoch / 8.0)
    );
    let measured =
        rq.wire.payload_bytes as f64 * 8.0 / (processors as f64 * epochs as f64);
    println!(
        "Measured:              {:.0} bits/processor/epoch ({}), {:.2} bits/coordinate.",
        measured,
        stats::fmt_bytes(measured / 8.0),
        rq.wire.bits_per_coordinate()
    );
    println!(
        "\nQSVRG contracts linearly at the same rate as exact SVRG while sending\n\
         ~{:.0}x fewer gradient bits — Theorem 3.6's claim.",
        rq.wire.compression_ratio()
    );
    Ok(())
}
