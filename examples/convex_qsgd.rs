//! Convex QSGD (Theorem 3.4) and quantized gradient descent (Appendix F):
//! convergence vs. quantization level on strongly convex objectives.
//!
//! ```sh
//! cargo run --release --example convex_qsgd
//! ```

use qsgd::coordinator::sources::ConvexSource;
use qsgd::coordinator::sync::{SyncConfig, SyncTrainer};
use qsgd::coordinator::CompressorSpec;
use qsgd::data::{LogisticProblem, Objective};
use qsgd::metrics::Table;
use qsgd::quant::{deterministic, Norm};
use qsgd::util::stats;

fn main() -> anyhow::Result<()> {
    // ---------------------------------------------------------------
    // Part 1: Theorem 3.4 — parallel QSGD on ridge logistic regression.
    // Variance blowup min(n/s², √n/s) shows up as the gap between arms
    // at equal step counts; all arms converge.
    // ---------------------------------------------------------------
    println!("== Convex QSGD (Theorem 3.4): ridge logistic regression, K=8 ==\n");
    let dim = 256;
    let mut table = Table::new(&["arm", "loss@0", "loss@300", "bits/coord", "wire"]);
    for (name, spec) in [
        ("32bit", CompressorSpec::Fp32),
        ("QSGD s=√n (2x var)", CompressorSpec::Qsgd { bits: 5, bucket: usize::MAX, norm: Norm::L2, regime: None }),
        ("QSGD 4bit/512", CompressorSpec::qsgd_4bit()),
        ("QSGD 2bit/64", CompressorSpec::qsgd_2bit()),
        ("QSGD s=1 (√n var)", CompressorSpec::Qsgd { bits: 2, bucket: usize::MAX, norm: Norm::L2, regime: None }),
    ] {
        let p = LogisticProblem::generate(1024, dim, 1e-3, 11);
        let mut src = ConvexSource::new(p, 16, 5);
        let mut cfg = SyncConfig::quick(8, 300, spec, 0.5);
        cfg.log_every = 50;
        let res = SyncTrainer::new(cfg).run(&mut src)?;
        table.row(&[
            name.to_string(),
            format!("{:.4}", res.loss.points[0].1),
            format!("{:.4}", res.loss.tail_mean(3)),
            format!("{:.2}", res.wire.bits_per_coordinate()),
            stats::fmt_bytes(res.wire.payload_bytes as f64),
        ]);
    }
    table.print();

    // ---------------------------------------------------------------
    // Part 2: Appendix F — deterministic quantized GD, linear rate.
    // ---------------------------------------------------------------
    println!("\n== Quantized gradient descent (Appendix F): top-|I(v)| quantizer ==\n");
    // Well-conditioned instance so the exp(−Ω(T/(κ²√n))) rate is visible in
    // a few thousand steps (Theorem F.2's constant is conservative; ~10× its
    // η still descends monotonically here).
    let obj = LogisticProblem::generate(256, 64, 0.5, 3);
    let n = obj.dim();
    let eta =
        (obj.strong_convexity() / (obj.smoothness().powi(2) * (n as f64).sqrt())) as f32 * 10.0;
    let mut w = vec![0.0f32; n];
    let mut g = vec![0.0f32; n];
    let f0 = obj.loss(&w);
    let mut bits_total = 0u64;
    println!("  step size η = {eta:.2e} (Theorem F.2: η ≤ O(ℓ/(L²√n)))");
    for t in 0..=4000usize {
        obj.full_grad(&w, &mut g);
        let q = deterministic::quantize(&g);
        bits_total += q.encode().len() as u64 * 8;
        let qd = q.dequantize();
        for (wi, &qi) in w.iter_mut().zip(&qd) {
            *wi -= eta * qi;
        }
        if t % 800 == 0 {
            println!(
                "  t={t:<5} f−f* ≈ {:.6}   |I(v)|={:<3} (≤ √n = {:.1})",
                obj.loss(&w) - 0.0,
                q.indices.len(),
                (n as f64).sqrt()
            );
        }
    }
    let f_end = obj.loss(&w);
    println!(
        "\n  f: {f0:.4} → {f_end:.4} with {} per step on the wire \
         (fp32 would be {})",
        stats::fmt_bytes(bits_total as f64 / 8.0 / 4001.0),
        stats::fmt_bytes(n as f64 * 4.0)
    );
    assert!(f_end < f0, "GD must descend");
    Ok(())
}
