//! Quickstart: the QSGD pipeline on a single gradient, then a tiny
//! data-parallel training run.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use qsgd::coding::gradient::{self, Regime};
use qsgd::coordinator::sources::ConvexSource;
use qsgd::coordinator::sync::{SyncConfig, SyncTrainer};
use qsgd::coordinator::CompressorSpec;
use qsgd::data::QuadraticProblem;
use qsgd::quant::{stochastic, Norm};
use qsgd::util::rng::{self, Xoshiro256};
use qsgd::util::stats;

fn main() -> anyhow::Result<()> {
    println!("== 1. Quantize one gradient (paper §3.1) ==");
    let mut rng = Xoshiro256::from_u64(42);
    let grad = rng::normal_vec(&mut rng, 10_000);

    for s in [1u32, 7, 100] {
        let q = stochastic::quantize_paper(&grad, s, &mut rng);
        let bytes = gradient::encode_auto(&q);
        let back = gradient::decode(&bytes)?.dequantize();
        let err: f64 = grad
            .iter()
            .zip(&back)
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            / grad.iter().map(|&x| (x as f64).powi(2)).sum::<f64>();
        println!(
            "  s={s:<4} nnz={:<6} wire={:<9} ({:.2} bits/coord, fp32 would be 32)  rel err {err:.4}",
            q.nnz(),
            stats::fmt_bytes(bytes.len() as f64),
            bytes.len() as f64 * 8.0 / grad.len() as f64,
        );
    }

    println!("\n== 2. The experiments' bucketed max-norm variant (§4) ==");
    let q = stochastic::quantize(&grad, 7, 512, Norm::Max, &mut rng);
    let sparse = gradient::encode(&q, Regime::Sparse).len();
    let dense = gradient::encode(&q, Regime::Dense).len();
    println!(
        "  4-bit/512-bucket: sparse coding {} vs dense coding {} (auto picks {})",
        stats::fmt_bytes(sparse as f64),
        stats::fmt_bytes(dense as f64),
        if sparse < dense { "sparse" } else { "dense" },
    );

    println!("\n== 3. Data-parallel SGD: fp32 vs QSGD (Algorithm 1) ==");
    for spec in [CompressorSpec::Fp32, CompressorSpec::qsgd_4bit(), CompressorSpec::qsgd_2bit()] {
        let p = QuadraticProblem::generate(512, 256, 1e-3, 0.05, 7);
        let mut src = ConvexSource::new(p, 8, 3);
        let mut cfg = SyncConfig::quick(8, 120, spec, 0.05);
        cfg.log_every = 20;
        let res = SyncTrainer::new(cfg).run(&mut src)?;
        println!(
            "  {:<14} final loss {:.4}  virtual time {:<8} wire {:>9}  ({:.1}x vs fp32)",
            res.label,
            res.loss.tail_mean(2),
            stats::fmt_duration(res.virtual_time(true).secs()),
            stats::fmt_bytes(res.wire.payload_bytes as f64),
            res.wire.compression_ratio(),
        );
    }
    println!("\nSame convergence, ~8x fewer bits — that is the paper's claim.");
    Ok(())
}
