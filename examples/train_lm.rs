//! End-to-end driver: data-parallel training of the transformer LM through
//! the full three-layer stack (Rust coordinator → PJRT → AOT JAX graph with
//! the Pallas-validated quantization path), under fp32 and QSGD arms.
//!
//! Requires `make artifacts`. Flags:
//!   --steps N (default 300)   --workers K (default 4)
//!   --arms fp32,qsgd4,qsgd2,qsgd8 (default fp32,qsgd4)
//!   --seed S
//!
//! ```sh
//! cargo run --release --example train_lm -- --steps 300
//! ```
//!
//! The recorded run lives in EXPERIMENTS.md §E2E.

use qsgd::config::Args;
use qsgd::coordinator::sources::{RuntimeSource, Workload};
use qsgd::coordinator::sync::{SyncConfig, SyncTrainer};
use qsgd::coordinator::CompressorSpec;
use qsgd::data::TokenCorpus;
use qsgd::metrics::Table;
use qsgd::models::layout::QuantPlan;
use qsgd::runtime::Runtime;
use qsgd::util::stats;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let steps = args.usize("steps", 300);
    let workers = args.usize("workers", 4);
    let seed = args.u64("seed", 0);
    let arm_names = args.string("arms", "fp32,qsgd4");

    let rt = Runtime::from_default_dir()?;
    let art = rt.manifest().get("tfm_grad")?.clone();
    let n = art.params.unwrap();
    let batch = art.batch.unwrap();
    let seq_plus_1 = art.inputs[1].shape[1];
    let corpus_entropy = TokenCorpus::new(512, seed).entropy_bits();
    println!(
        "transformer LM: {} params, batch {batch}, seq {}, {} workers, {} steps",
        n,
        seq_plus_1 - 1,
        workers,
        steps
    );
    println!(
        "corpus: Markov-Zipf, per-token entropy ≈ {corpus_entropy:.2} bits \
         (uniform = 9.00) → loss floor ≈ {:.2} nats\n",
        corpus_entropy * std::f64::consts::LN_2
    );

    let mut table = Table::new(&[
        "arm", "loss@0", "loss@end", "eval@end", "bits/coord", "wire total", "vtime(db)", "comm%",
    ]);
    let mut fp32_vtime = None;

    for name in arm_names.split(',') {
        let spec = CompressorSpec::parse(name)?;
        let mut src = RuntimeSource::new(
            &rt,
            "tfm_grad",
            Workload::Lm { corpus: TokenCorpus::new(512, seed), batch, seq_plus_1 },
        )?;
        let mut cfg = SyncConfig::quick(workers, steps, spec, 0.25);
        cfg.seed = seed;
        cfg.log_every = (steps / 20).max(1);
        cfg.eval_every = (steps / 5).max(1);
        cfg.plan = art.layout.as_ref().map(QuantPlan::quantize_all);
        let res = SyncTrainer::new(cfg).run(&mut src)?;

        let vt = res.virtual_time(true).secs();
        if matches!(CompressorSpec::parse(name)?, CompressorSpec::Fp32) {
            fp32_vtime = Some(vt);
        }
        println!("[{}] loss curve: {}", res.label, res.loss.sparkline(10));
        table.row(&[
            res.label.clone(),
            format!("{:.3}", res.loss.points.first().map(|p| p.1).unwrap_or(f64::NAN)),
            format!("{:.3}", res.loss.tail_mean(3)),
            format!("{:.3}", res.eval.last().unwrap_or(f64::NAN)),
            format!("{:.2}", res.wire.bits_per_coordinate()),
            stats::fmt_bytes(res.wire.payload_bytes as f64),
            stats::fmt_duration(vt),
            format!("{:.0}%", res.breakdown.comm_fraction() * 100.0),
        ]);
    }
    println!();
    table.print();
    if let Some(fp) = fp32_vtime {
        println!("\n(virtual-time speedups are relative to fp32 = {}; the loss\n columns demonstrate accuracy parity — the paper's Fig. 3 claim)", stats::fmt_duration(fp));
    }
    Ok(())
}
