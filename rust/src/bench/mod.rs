//! Micro-benchmark harness (the offline environment has no criterion).
//!
//! `cargo bench` targets declare `harness = false` and drive this module:
//! warmup, calibrated iteration counts, multiple samples, median/p10/p90
//! reporting, and optional throughput lines. Output is plain text tables so
//! bench logs read like the paper's.

use std::time::Instant;

use crate::util::stats;

/// One benchmark result.
#[derive(Debug, Clone)]
pub struct Sampled {
    pub name: String,
    /// Per-iteration wall time samples, seconds.
    pub samples: Vec<f64>,
}

impl Sampled {
    pub fn median(&self) -> f64 {
        stats::median(&self.samples)
    }

    pub fn report(&self) {
        let med = self.median();
        println!(
            "{:<44} {:>10}  (p10 {:>10}, p90 {:>10}, n={})",
            self.name,
            stats::fmt_duration(med),
            stats::fmt_duration(stats::percentile(&self.samples, 10.0)),
            stats::fmt_duration(stats::percentile(&self.samples, 90.0)),
            self.samples.len()
        );
    }

    pub fn report_throughput(&self, bytes_per_iter: f64) {
        let med = self.median();
        println!(
            "{:<44} {:>10}  {:>12}/s",
            self.name,
            stats::fmt_duration(med),
            stats::fmt_bytes(bytes_per_iter / med)
        );
    }
}

/// Benchmark runner.
pub struct Bench {
    /// Target per-sample duration; iterations auto-calibrate to this.
    pub sample_target_s: f64,
    pub samples: usize,
    pub warmup_s: f64,
}

impl Default for Bench {
    fn default() -> Self {
        Self { sample_target_s: 0.08, samples: 12, warmup_s: 0.15 }
    }
}

impl Bench {
    pub fn quick() -> Self {
        Self { sample_target_s: 0.03, samples: 7, warmup_s: 0.05 }
    }

    /// Benchmark `f`, which performs ONE unit of work per call. Returns
    /// per-iteration timings. A `black_box`-style sink prevents the optimizer
    /// from eliding the closure's result: return something observable.
    pub fn run<T, F: FnMut() -> T>(&self, name: &str, mut f: F) -> Sampled {
        // Warmup + calibration.
        let t0 = Instant::now();
        let mut iters_done = 0u64;
        while t0.elapsed().as_secs_f64() < self.warmup_s || iters_done < 3 {
            sink(f());
            iters_done += 1;
        }
        let per_iter = t0.elapsed().as_secs_f64() / iters_done as f64;
        let iters = ((self.sample_target_s / per_iter).ceil() as u64).max(1);

        let mut samples = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t = Instant::now();
            for _ in 0..iters {
                sink(f());
            }
            samples.push(t.elapsed().as_secs_f64() / iters as f64);
        }
        Sampled { name: name.to_string(), samples }
    }
}

/// Opaque sink — prevents dead-code elimination of benchmark bodies.
#[inline]
pub fn sink<T>(x: T) -> T {
    // std::hint::black_box is stable since 1.66.
    std::hint::black_box(x)
}

/// Print a section header in bench output.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// Print a markdown-style table row with fixed column widths.
pub fn row(cols: &[String], widths: &[usize]) {
    let mut line = String::new();
    for (c, w) in cols.iter().zip(widths) {
        line.push_str(&format!("{:<width$}  ", c, width = w));
    }
    println!("{}", line.trim_end());
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_samples() {
        let b = Bench { sample_target_s: 0.001, samples: 3, warmup_s: 0.001 };
        let s = b.run("noop-ish", || {
            let mut acc = 0u64;
            for i in 0..100u64 {
                acc = acc.wrapping_add(i * i);
            }
            acc
        });
        assert_eq!(s.samples.len(), 3);
        assert!(s.median() > 0.0);
    }
}
