//! Micro-benchmark harness (the offline environment has no criterion).
//!
//! `cargo bench` targets declare `harness = false` and drive this module:
//! warmup, calibrated iteration counts, multiple samples, median/p10/p90
//! reporting (quantiles via the log-bucketed [`crate::obs::Histogram`] —
//! the tree's single quantile implementation), and optional throughput
//! lines. Output is plain text tables so
//! bench logs read like the paper's. [`Report`] additionally collects every
//! section into a machine-readable JSON file (e.g.
//! `BENCH_coding_hotpath.json`) so the perf trajectory is diffable across
//! PRs and checkable in CI.

use std::time::Instant;

use crate::obs::Histogram;
use crate::util::stats;

/// One benchmark result.
#[derive(Debug, Clone)]
pub struct Sampled {
    pub name: String,
    /// Per-iteration wall time samples, seconds.
    pub samples: Vec<f64>,
}

impl Sampled {
    /// Log-bucketed histogram over this run's samples — quantiles route
    /// through the tree's single implementation ([`crate::obs::Histogram`],
    /// ~0.8% relative error; see the bench baselines README).
    pub fn hist(&self) -> Histogram {
        Histogram::from_samples(&self.samples)
    }

    pub fn median(&self) -> f64 {
        self.hist().median()
    }

    pub fn report(&self) {
        let h = self.hist();
        println!(
            "{:<44} {:>10}  (p10 {:>10}, p90 {:>10}, n={})",
            self.name,
            stats::fmt_duration(h.median()),
            stats::fmt_duration(h.percentile(10.0)),
            stats::fmt_duration(h.percentile(90.0)),
            self.samples.len()
        );
    }

    pub fn report_throughput(&self, bytes_per_iter: f64) {
        let med = self.median();
        println!(
            "{:<44} {:>10}  {:>12}/s",
            self.name,
            stats::fmt_duration(med),
            stats::fmt_bytes(bytes_per_iter / med)
        );
    }
}

/// Benchmark runner.
pub struct Bench {
    /// Target per-sample duration; iterations auto-calibrate to this.
    pub sample_target_s: f64,
    pub samples: usize,
    pub warmup_s: f64,
}

impl Default for Bench {
    fn default() -> Self {
        Self { sample_target_s: 0.08, samples: 12, warmup_s: 0.15 }
    }
}

impl Bench {
    pub fn quick() -> Self {
        Self { sample_target_s: 0.03, samples: 7, warmup_s: 0.05 }
    }

    /// Benchmark `f`, which performs ONE unit of work per call. Returns
    /// per-iteration timings. A `black_box`-style sink prevents the optimizer
    /// from eliding the closure's result: return something observable.
    ///
    /// Every section takes the same shape: timed warmup + calibration, one
    /// discarded full-length warmup sample (cold caches and frequency ramps
    /// on shared CI runners otherwise pollute the first measurement), then
    /// `samples` measured samples reported as median/p10/p90 — never a
    /// single timed pass.
    pub fn run<T, F: FnMut() -> T>(&self, name: &str, mut f: F) -> Sampled {
        // Warmup + calibration.
        let t0 = Instant::now();
        let mut iters_done = 0u64;
        while t0.elapsed().as_secs_f64() < self.warmup_s || iters_done < 3 {
            sink(f());
            iters_done += 1;
        }
        let per_iter = t0.elapsed().as_secs_f64() / iters_done as f64;
        let iters = ((self.sample_target_s / per_iter).ceil() as u64).max(1);

        // Discarded warmup sample at the measured length.
        for _ in 0..iters {
            sink(f());
        }
        let mut samples = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t = Instant::now();
            for _ in 0..iters {
                sink(f());
            }
            samples.push(t.elapsed().as_secs_f64() / iters as f64);
        }
        Sampled { name: name.to_string(), samples }
    }
}

/// Machine-readable bench results: every timed section plus scalar metrics
/// (alloc counts, speedups, wire sizes), serialized as JSON so the perf
/// trajectory is trackable across PRs. The advisory CI perf lane compares
/// the emitted file against a committed baseline.
///
/// Schema (`"schema": 1`):
/// ```json
/// {"bench": "...", "schema": 1,
///  "results": [{"section": "...", "name": "...", "median_ns": 1.0,
///               "p10_ns": 1.0, "p90_ns": 1.0, "samples": 12,
///               "coords": 1048576, "ns_per_coord": 1.0}],
///  "metrics": [{"section": "...", "name": "...", "value": 1.0}]}
/// ```
pub struct Report {
    bench: String,
    results: Vec<String>,
    metrics: Vec<String>,
}

impl Report {
    pub fn new(bench: &str) -> Self {
        Self { bench: bench.to_string(), results: Vec::new(), metrics: Vec::new() }
    }

    /// Record a timed section. `coords` (work items per iteration) adds the
    /// normalized `ns_per_coord` field the regression check keys on.
    pub fn add(&mut self, section: &str, s: &Sampled, coords: Option<f64>) {
        let h = s.hist();
        let med_ns = h.median() * 1e9;
        let mut row = format!(
            "{{\"section\": {}, \"name\": {}, \"median_ns\": {}, \"p10_ns\": {}, \
             \"p90_ns\": {}, \"samples\": {}",
            json_str(section),
            json_str(&s.name),
            json_num(med_ns),
            json_num(h.percentile(10.0) * 1e9),
            json_num(h.percentile(90.0) * 1e9),
            s.samples.len()
        );
        if let Some(c) = coords {
            row.push_str(&format!(
                ", \"coords\": {}, \"ns_per_coord\": {}",
                json_num(c),
                json_num(med_ns / c)
            ));
        }
        row.push('}');
        self.results.push(row);
    }

    /// Record a sustained-rate result (ops/sec, msgs/sec). Rate rows carry
    /// `"direction": "higher"` so the regression check knows bigger is
    /// better and inverts its ratio (a drop in throughput regresses, a rise
    /// never does). Timed rows keep the implicit lower-is-better default.
    pub fn add_rate(&mut self, section: &str, name: &str, per_sec: f64) {
        self.results.push(format!(
            "{{\"section\": {}, \"name\": {}, \"per_sec\": {}, \"direction\": \"higher\"}}",
            json_str(section),
            json_str(name),
            json_num(per_sec)
        ));
    }

    /// Record a scalar metric (alloc count, speedup, message bytes, …).
    pub fn add_metric(&mut self, section: &str, name: &str, value: f64) {
        self.metrics.push(format!(
            "{{\"section\": {}, \"name\": {}, \"value\": {}}}",
            json_str(section),
            json_str(name),
            json_num(value)
        ));
    }

    /// Serialize to the JSON document described above.
    pub fn to_json(&self) -> String {
        format!(
            "{{\n  \"bench\": {},\n  \"schema\": 1,\n  \"results\": [\n    {}\n  ],\n  \
             \"metrics\": [\n    {}\n  ]\n}}\n",
            json_str(&self.bench),
            self.results.join(",\n    "),
            self.metrics.join(",\n    ")
        )
    }

    /// Write the JSON next to the bench's working directory (cargo runs
    /// benches from the workspace root) and echo the path.
    pub fn write(&self, path: &str) -> std::io::Result<()> {
        std::fs::write(path, self.to_json())?;
        println!("\nwrote {path}");
        Ok(())
    }
}

/// Minimal JSON string escaping (quotes, backslash, control chars).
fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// JSON number: finite floats only (NaN/inf are not valid JSON → null).
fn json_num(x: f64) -> String {
    if x.is_finite() {
        format!("{x}")
    } else {
        "null".to_string()
    }
}

/// Opaque sink — prevents dead-code elimination of benchmark bodies.
#[inline]
pub fn sink<T>(x: T) -> T {
    // std::hint::black_box is stable since 1.66.
    std::hint::black_box(x)
}

/// Print a section header in bench output.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// Print a markdown-style table row with fixed column widths.
pub fn row(cols: &[String], widths: &[usize]) {
    let mut line = String::new();
    for (c, w) in cols.iter().zip(widths) {
        line.push_str(&format!("{:<width$}  ", c, width = w));
    }
    println!("{}", line.trim_end());
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_emits_valid_json() {
        let mut rep = Report::new("unit");
        let s = Sampled { name: "q \"x\"\n".into(), samples: vec![1e-6, 2e-6, 3e-6] };
        rep.add("sec", &s, Some(1024.0));
        rep.add("sec2", &s, None);
        rep.add_metric("sec", "allocs", 0.0);
        rep.add_metric("sec", "nan-guard", f64::NAN);
        let doc = crate::util::json::parse(&rep.to_json()).expect("report must parse");
        assert_eq!(doc.get("bench").unwrap().as_str().unwrap(), "unit");
        assert_eq!(doc.get("schema").unwrap().as_usize().unwrap(), 1);
        let results = doc.get("results").unwrap().as_arr().unwrap();
        assert_eq!(results.len(), 2);
        assert_eq!(results[0].get("coords").unwrap().as_f64(), Some(1024.0));
        let npc = results[0].get("ns_per_coord").unwrap().as_f64().unwrap();
        // Quantiles are log-bucketed (~0.8% relative error), so compare with
        // the histogram's error bound rather than bit-exactly.
        let expect = 2e3 / 1024.0;
        assert!((npc - expect).abs() / expect < 1.0 / 64.0, "ns/coord {npc}");
        assert!(results[1].get("coords").is_none());
        let metrics = doc.get("metrics").unwrap().as_arr().unwrap();
        assert_eq!(metrics.len(), 2);
        assert_eq!(metrics[1].get("value").unwrap(), &crate::util::json::Json::Null);
    }

    #[test]
    fn rate_rows_carry_higher_direction() {
        let mut rep = Report::new("unit");
        rep.add_rate("ps", "sustained msgs/sec", 12345.5);
        let doc = crate::util::json::parse(&rep.to_json()).expect("report must parse");
        let results = doc.get("results").unwrap().as_arr().unwrap();
        assert_eq!(results.len(), 1);
        let row = &results[0];
        assert_eq!(row.get("per_sec").unwrap().as_f64(), Some(12345.5));
        assert_eq!(row.get("direction").unwrap().as_str().unwrap(), "higher");
        assert!(row.get("median_ns").is_none(), "rate rows carry no latency fields");
    }

    #[test]
    fn bench_produces_samples() {
        let b = Bench { sample_target_s: 0.001, samples: 3, warmup_s: 0.001 };
        let s = b.run("noop-ish", || {
            let mut acc = 0u64;
            for i in 0..100u64 {
                acc = acc.wrapping_add(i * i);
            }
            acc
        });
        assert_eq!(s.samples.len(), 3);
        assert!(s.median() > 0.0);
    }
}
