//! Synthetic token corpus for the LM workload (AN4/LSTM stand-in).
//!
//! A first-order Markov chain over the vocabulary with Zipf-distributed
//! stationary mass and sticky local transitions. Next-token entropy is well
//! below log|V|, so a trained LM has real signal to find — the loss curve in
//! the e2e driver must drop visibly below the uniform baseline.

use rand_core::RngCore;

use crate::util::rng::{self, Xoshiro256};

#[derive(Debug, Clone)]
pub struct TokenCorpus {
    pub vocab: usize,
    /// Transition CDF rows, `vocab × vocab` (f32 cumulative).
    cdf: Vec<f32>,
    seed: u64,
}

impl TokenCorpus {
    pub fn new(vocab: usize, seed: u64) -> Self {
        let mut rng = Xoshiro256::stream(seed, 0xC0B9);
        // Zipf base distribution.
        let base: Vec<f64> = (0..vocab).map(|i| 1.0 / (i as f64 + 2.0)).collect();
        let mut cdf = vec![0.0f32; vocab * vocab];
        for r in 0..vocab {
            // row = mixture of (zipf base) and (a few sticky successors)
            let mut row: Vec<f64> = base.clone();
            for _ in 0..4 {
                let succ = rng::uniform_usize(&mut rng, vocab);
                row[succ] += 0.6 * (1.0 + rng::uniform_f64(&mut rng));
            }
            row[(r + 1) % vocab] += 0.8; // mild sequential structure
            let total: f64 = row.iter().sum();
            let mut acc = 0.0f64;
            for (c, &p) in row.iter().enumerate() {
                acc += p / total;
                cdf[r * vocab + c] = acc as f32;
            }
            cdf[r * vocab + vocab - 1] = 1.0;
        }
        Self { vocab, cdf, seed }
    }

    fn next_token(&self, prev: usize, rng: &mut dyn RngCore) -> usize {
        let u = rng::uniform_f32(rng);
        let row = &self.cdf[prev * self.vocab..(prev + 1) * self.vocab];
        // binary search the CDF row
        match row.binary_search_by(|p| p.partial_cmp(&u).unwrap()) {
            Ok(i) => (i + 1).min(self.vocab - 1),
            Err(i) => i.min(self.vocab - 1),
        }
    }

    /// Deterministic batch of token windows: `batch` rows of `seq_plus_1`
    /// int32 tokens for (worker, index).
    pub fn batch(&self, worker: usize, index: u64, batch: usize, seq_plus_1: usize) -> Vec<i32> {
        let mut rng = Xoshiro256::stream(self.seed ^ 0x70CE2, (worker as u64) << 40 | index);
        let mut out = Vec::with_capacity(batch * seq_plus_1);
        for _ in 0..batch {
            let mut tok = rng::uniform_usize(&mut rng, self.vocab);
            out.push(tok as i32);
            for _ in 1..seq_plus_1 {
                tok = self.next_token(tok, &mut rng);
                out.push(tok as i32);
            }
        }
        out
    }

    /// Empirical per-token entropy of the chain (nats→bits), a floor for LM
    /// cross-entropy loss.
    pub fn entropy_bits(&self) -> f64 {
        let v = self.vocab;
        let mut h = 0.0f64;
        for r in 0..v {
            let row = &self.cdf[r * v..(r + 1) * v];
            let mut prev = 0.0f32;
            let mut hr = 0.0f64;
            for &c in row {
                let p = (c - prev) as f64;
                if p > 1e-12 {
                    hr -= p * p.log2();
                }
                prev = c;
            }
            h += hr / v as f64; // uniform-ish average over rows
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batches_deterministic_tokens_in_range() {
        let c = TokenCorpus::new(64, 9);
        let b1 = c.batch(0, 0, 4, 17);
        let b2 = c.batch(0, 0, 4, 17);
        assert_eq!(b1, b2);
        assert_eq!(b1.len(), 4 * 17);
        assert!(b1.iter().all(|&t| (0..64).contains(&t)));
        assert_ne!(b1, c.batch(0, 1, 4, 17));
    }

    #[test]
    fn chain_has_structure() {
        // entropy must be clearly below log2(vocab)
        let c = TokenCorpus::new(128, 2);
        let h = c.entropy_bits();
        assert!(h < 6.0, "h = {h} vs uniform 7.0");
        assert!(h > 1.0, "degenerate chain");
    }

    #[test]
    fn bigram_predictability() {
        // the same prev token leads to a repeated successor reasonably often
        let c = TokenCorpus::new(32, 3);
        let toks = c.batch(0, 0, 1, 4000);
        let mut follows = std::collections::HashMap::new();
        for w in toks.windows(2) {
            *follows.entry((w[0], w[1])).or_insert(0usize) += 1;
        }
        let max_pair = follows.values().copied().max().unwrap();
        assert!(max_pair > 10, "no repeated bigrams: {max_pair}");
    }
}
