//! Rust-native convex finite-sum objectives for the theory experiments
//! (Theorem 3.4 convex QSGD, Theorem 3.6 QSVRG, Appendix F quantized GD).
//!
//! These run thousands of iterations per bench, so they are implemented
//! natively rather than through PJRT; the full three-layer path is exercised
//! by the MLP/transformer workloads instead.

use rand_core::RngCore;

use crate::util::rng::{self, Xoshiro256};

/// A differentiable finite-sum objective f = (1/m) Σ f_i, ℓ-strongly convex.
pub trait Objective: Send + Sync {
    fn dim(&self) -> usize;
    fn num_components(&self) -> usize;
    /// Full-objective value.
    fn loss(&self, w: &[f32]) -> f64;
    /// ∇f_i(w) accumulated into `out` (overwrites).
    fn component_grad(&self, i: usize, w: &[f32], out: &mut [f32]);
    /// Full gradient ∇f(w) into `out`.
    fn full_grad(&self, w: &[f32], out: &mut [f32]) {
        let mut tmp = vec![0.0f32; self.dim()];
        out.iter_mut().for_each(|o| *o = 0.0);
        let m = self.num_components();
        for i in 0..m {
            self.component_grad(i, w, &mut tmp);
            for (o, t) in out.iter_mut().zip(&tmp) {
                *o += t / m as f32;
            }
        }
    }
    /// A stochastic gradient: uniformly random component.
    fn stochastic_grad(&self, w: &[f32], rng: &mut dyn RngCore, out: &mut [f32]) {
        let i = rng::uniform_usize(rng, self.num_components());
        self.component_grad(i, w, out);
    }
    /// Strong-convexity modulus ℓ (0 if merely convex).
    fn strong_convexity(&self) -> f64;
    /// Smoothness constant L (estimate).
    fn smoothness(&self) -> f64;
}

// --------------------------------------------------------------------------
// Ridge-regularised logistic regression
// --------------------------------------------------------------------------

/// f_i(w) = log(1 + exp(−y_i·xᵢᵀw)) + (λ/2)‖w‖², y ∈ {−1, +1}.
pub struct LogisticProblem {
    pub dim: usize,
    pub lambda: f32,
    xs: Vec<f32>,
    ys: Vec<f32>,
    m: usize,
    /// max_i ‖x_i‖² (for L = max‖x‖²/4 + λ)
    max_x2: f64,
}

impl LogisticProblem {
    /// Generate a separable-with-noise dataset from a planted weight vector.
    pub fn generate(m: usize, dim: usize, lambda: f32, seed: u64) -> Self {
        let mut rng = Xoshiro256::stream(seed, 0x10615);
        let planted: Vec<f32> = rng::normal_vec(&mut rng, dim);
        let mut xs = Vec::with_capacity(m * dim);
        let mut ys = Vec::with_capacity(m);
        let mut max_x2 = 0.0f64;
        for _ in 0..m {
            let x: Vec<f32> = rng::normal_vec(&mut rng, dim);
            let margin: f32 = x.iter().zip(&planted).map(|(a, b)| a * b).sum();
            // 10% label noise keeps the optimum interior
            let flip = rng::uniform_f32(&mut rng) < 0.1;
            let y = if (margin >= 0.0) ^ flip { 1.0 } else { -1.0 };
            max_x2 = max_x2.max(x.iter().map(|v| (*v as f64).powi(2)).sum());
            xs.extend_from_slice(&x);
            ys.push(y);
        }
        Self { dim, lambda, xs, ys, m, max_x2 }
    }
}

impl Objective for LogisticProblem {
    fn dim(&self) -> usize {
        self.dim
    }

    fn num_components(&self) -> usize {
        self.m
    }

    fn loss(&self, w: &[f32]) -> f64 {
        let mut total = 0.0f64;
        for i in 0..self.m {
            let x = &self.xs[i * self.dim..(i + 1) * self.dim];
            let z: f32 = x.iter().zip(w).map(|(a, b)| a * b).sum::<f32>() * self.ys[i];
            // log(1+exp(-z)), stable
            total += if z > 0.0 {
                ((-z as f64).exp()).ln_1p()
            } else {
                -z as f64 + ((z as f64).exp()).ln_1p()
            };
        }
        let reg: f64 = 0.5 * self.lambda as f64 * w.iter().map(|v| (*v as f64).powi(2)).sum::<f64>();
        total / self.m as f64 + reg
    }

    fn component_grad(&self, i: usize, w: &[f32], out: &mut [f32]) {
        let x = &self.xs[i * self.dim..(i + 1) * self.dim];
        let y = self.ys[i];
        let z: f32 = x.iter().zip(w).map(|(a, b)| a * b).sum::<f32>() * y;
        // σ(−z) = 1/(1+e^z)
        let coef = -y / (1.0 + z.exp());
        for ((o, &xi), &wi) in out.iter_mut().zip(x).zip(w.iter()) {
            *o = coef * xi + self.lambda * wi;
        }
    }

    fn strong_convexity(&self) -> f64 {
        self.lambda as f64
    }

    fn smoothness(&self) -> f64 {
        self.max_x2 / 4.0 + self.lambda as f64
    }
}

// --------------------------------------------------------------------------
// Quadratic f(w) = (1/2m) Σ (xᵢᵀw − b_i)² + (λ/2)‖w‖²  (least squares)
// --------------------------------------------------------------------------

pub struct QuadraticProblem {
    pub dim: usize,
    pub lambda: f32,
    xs: Vec<f32>,
    bs: Vec<f32>,
    m: usize,
    max_x2: f64,
}

impl QuadraticProblem {
    pub fn generate(m: usize, dim: usize, lambda: f32, noise: f32, seed: u64) -> Self {
        let mut rng = Xoshiro256::stream(seed, 0x40AD);
        let planted: Vec<f32> = rng::normal_vec(&mut rng, dim);
        let mut xs = Vec::with_capacity(m * dim);
        let mut bs = Vec::with_capacity(m);
        let mut max_x2 = 0.0f64;
        for _ in 0..m {
            let x: Vec<f32> = rng::normal_vec(&mut rng, dim);
            let b: f32 = x.iter().zip(&planted).map(|(a, c)| a * c).sum::<f32>()
                + rng::normal_f32(&mut rng) * noise;
            max_x2 = max_x2.max(x.iter().map(|v| (*v as f64).powi(2)).sum());
            xs.extend_from_slice(&x);
            bs.push(b);
        }
        Self { dim, lambda, xs, bs, m, max_x2 }
    }
}

impl Objective for QuadraticProblem {
    fn dim(&self) -> usize {
        self.dim
    }

    fn num_components(&self) -> usize {
        self.m
    }

    fn loss(&self, w: &[f32]) -> f64 {
        let mut total = 0.0f64;
        for i in 0..self.m {
            let x = &self.xs[i * self.dim..(i + 1) * self.dim];
            let r: f32 = x.iter().zip(w).map(|(a, b)| a * b).sum::<f32>() - self.bs[i];
            total += 0.5 * (r as f64).powi(2);
        }
        let reg: f64 = 0.5 * self.lambda as f64 * w.iter().map(|v| (*v as f64).powi(2)).sum::<f64>();
        total / self.m as f64 + reg
    }

    fn component_grad(&self, i: usize, w: &[f32], out: &mut [f32]) {
        let x = &self.xs[i * self.dim..(i + 1) * self.dim];
        let r: f32 = x.iter().zip(w).map(|(a, b)| a * b).sum::<f32>() - self.bs[i];
        for ((o, &xi), &wi) in out.iter_mut().zip(x).zip(w.iter()) {
            *o = r * xi + self.lambda * wi;
        }
    }

    fn strong_convexity(&self) -> f64 {
        self.lambda as f64
    }

    fn smoothness(&self) -> f64 {
        self.max_x2 + self.lambda as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fd_check<O: Objective>(p: &O, seed: u64) {
        let mut rng = Xoshiro256::from_u64(seed);
        let w: Vec<f32> = rng::normal_vec(&mut rng, p.dim());
        let mut g = vec![0.0f32; p.dim()];
        p.full_grad(&w, &mut g);
        let eps = 1e-3f32;
        for j in 0..p.dim().min(5) {
            let mut wp = w.clone();
            let mut wm = w.clone();
            wp[j] += eps;
            wm[j] -= eps;
            let fd = (p.loss(&wp) - p.loss(&wm)) / (2.0 * eps as f64);
            assert!(
                (fd - g[j] as f64).abs() < 2e-3,
                "dim {j}: fd {fd} vs analytic {}",
                g[j]
            );
        }
    }

    #[test]
    fn logistic_gradient_matches_fd() {
        fd_check(&LogisticProblem::generate(64, 10, 1e-2, 0), 1);
    }

    #[test]
    fn quadratic_gradient_matches_fd() {
        fd_check(&QuadraticProblem::generate(64, 10, 1e-2, 0.1, 0), 2);
    }

    #[test]
    fn stochastic_grad_unbiased() {
        let p = LogisticProblem::generate(32, 8, 1e-2, 3);
        let mut rng = Xoshiro256::from_u64(4);
        let w: Vec<f32> = rng::normal_vec(&mut rng, 8);
        let mut full = vec![0.0f32; 8];
        p.full_grad(&w, &mut full);
        let mut acc = vec![0.0f64; 8];
        let trials = 20_000;
        let mut g = vec![0.0f32; 8];
        for _ in 0..trials {
            p.stochastic_grad(&w, &mut rng, &mut g);
            for (a, &x) in acc.iter_mut().zip(&g) {
                *a += x as f64;
            }
        }
        for j in 0..8 {
            assert!(
                (acc[j] / trials as f64 - full[j] as f64).abs() < 0.05,
                "dim {j}"
            );
        }
    }

    #[test]
    fn gd_converges_on_quadratic() {
        let p = QuadraticProblem::generate(128, 16, 1e-3, 0.01, 5);
        let mut w = vec![0.0f32; 16];
        let mut g = vec![0.0f32; 16];
        let lr = (1.0 / p.smoothness()) as f32;
        let l0 = p.loss(&w);
        for _ in 0..200 {
            p.full_grad(&w, &mut g);
            for (wi, gi) in w.iter_mut().zip(&g) {
                *wi -= lr * gi;
            }
        }
        assert!(p.loss(&w) < l0 * 0.05, "no convergence: {} -> {}", l0, p.loss(&w));
    }

    #[test]
    fn constants_sane() {
        let p = LogisticProblem::generate(64, 10, 1e-2, 6);
        assert!(p.strong_convexity() > 0.0);
        assert!(p.smoothness() > p.strong_convexity());
    }
}
