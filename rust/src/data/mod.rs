//! Synthetic datasets and Rust-native objectives.
//!
//! Substitutions for the paper's datasets (DESIGN.md §Substitutions):
//! Gaussian-cluster classification stands in for MNIST/CIFAR, a Zipf–Markov
//! token corpus for the LM workloads, and finite-sum logistic/quadratic
//! problems for the convex theory experiments (Thm 3.4, QSVRG, App. F).
//! Everything is deterministic given a seed.

pub mod classify;
pub mod convex;
pub mod corpus;

pub use classify::ClassifyData;
pub use convex::{LogisticProblem, Objective, QuadraticProblem};
pub use corpus::TokenCorpus;
