//! Gaussian-cluster classification data (the MNIST/CIFAR stand-in).
//!
//! `classes` well-separated Gaussian clusters in `dim` dimensions with some
//! within-class anisotropy — learnable but not trivial, so QSGD-vs-fp32
//! accuracy-parity curves (Fig. 3/5) are meaningful.


use crate::util::rng::{self, Xoshiro256};

#[derive(Debug, Clone)]
pub struct ClassifyData {
    pub dim: usize,
    pub classes: usize,
    /// Cluster centres, `classes × dim`.
    centers: Vec<f32>,
    /// Per-class noise scale.
    noise: f32,
    seed: u64,
}

impl ClassifyData {
    pub fn new(dim: usize, classes: usize, separation: f32, noise: f32, seed: u64) -> Self {
        let mut rng = Xoshiro256::stream(seed, 0xC1A55);
        let mut centers = vec![0.0f32; classes * dim];
        for c in centers.iter_mut() {
            *c = rng::normal_f32(&mut rng) * separation;
        }
        Self { dim, classes, centers, noise, seed }
    }

    /// Paper-protocol default: MNIST-like difficulty.
    pub fn mnist_like(dim: usize, classes: usize, seed: u64) -> Self {
        Self::new(dim, classes, 1.0, 1.2, seed)
    }

    /// Sample batch `index` for `worker`: (x flat [batch×dim], labels).
    /// Batches are deterministic in (seed, worker, index) so every run — and
    /// every compressor under test — sees identical data order.
    pub fn batch(&self, worker: usize, index: u64, batch: usize) -> (Vec<f32>, Vec<i32>) {
        let mut rng = Xoshiro256::stream(self.seed ^ 0xBA7C4, (worker as u64) << 40 | index);
        let mut x = Vec::with_capacity(batch * self.dim);
        let mut y = Vec::with_capacity(batch);
        for _ in 0..batch {
            let cls = rng::uniform_usize(&mut rng, self.classes);
            y.push(cls as i32);
            let ctr = &self.centers[cls * self.dim..(cls + 1) * self.dim];
            for d in 0..self.dim {
                // anisotropic noise: later dims noisier
                let aniso = 0.5 + (d as f32 / self.dim as f32);
                x.push(ctr[d] + rng::normal_f32(&mut rng) * self.noise * aniso);
            }
        }
        (x, y)
    }

    /// A held-out evaluation set.
    pub fn eval_set(&self, samples: usize) -> (Vec<f32>, Vec<i32>) {
        self.batch(usize::MAX - 1, u64::MAX - 1, samples)
    }

    /// 0-1 accuracy of `predict` (argmax scores per row) on an eval set.
    pub fn accuracy<F>(&self, samples: usize, mut predict: F) -> f64
    where
        F: FnMut(&[f32]) -> usize,
    {
        let (x, y) = self.eval_set(samples);
        let mut correct = 0usize;
        for (row, &label) in x.chunks(self.dim).zip(&y) {
            if predict(row) == label as usize {
                correct += 1;
            }
        }
        correct as f64 / samples as f64
    }
}

/// Bayes-ish reference: nearest-centre classification accuracy (upper bound
/// ballpark for linear models on this data).
pub fn nearest_center_accuracy(data: &ClassifyData, samples: usize) -> f64 {
    let centers = data.centers.clone();
    let dim = data.dim;
    data.accuracy(samples, |row| {
        let mut best = (f32::INFINITY, 0usize);
        for (c, ctr) in centers.chunks(dim).enumerate() {
            let d: f32 = row.iter().zip(ctr).map(|(a, b)| (a - b) * (a - b)).sum();
            if d < best.0 {
                best = (d, c);
            }
        }
        best.1
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batches_are_deterministic_and_distinct() {
        let d = ClassifyData::mnist_like(16, 4, 7);
        let (x1, y1) = d.batch(0, 0, 32);
        let (x2, y2) = d.batch(0, 0, 32);
        assert_eq!(x1, x2);
        assert_eq!(y1, y2);
        let (x3, _) = d.batch(0, 1, 32);
        assert_ne!(x1, x3);
        let (x4, _) = d.batch(1, 0, 32);
        assert_ne!(x1, x4);
        assert_eq!(x1.len(), 32 * 16);
    }

    #[test]
    fn labels_in_range_and_balanced_ish() {
        let d = ClassifyData::mnist_like(8, 10, 3);
        let (_, y) = d.batch(0, 0, 2000);
        let mut counts = [0usize; 10];
        for &l in &y {
            assert!((0..10).contains(&(l as usize)));
            counts[l as usize] += 1;
        }
        for &c in &counts {
            assert!(c > 100, "unbalanced: {counts:?}");
        }
    }

    #[test]
    fn task_is_learnable() {
        // nearest-centre accuracy must beat chance by a wide margin
        let d = ClassifyData::mnist_like(32, 10, 11);
        let acc = nearest_center_accuracy(&d, 1000);
        assert!(acc > 0.5, "acc {acc}");
    }
}
