//! Synthetic heavy-traffic client harness: N simulated clients multiplexed
//! over M OS threads, hammering a [`Service`] either in-process or through
//! the socket front end.
//!
//! The load shape is the classic parameter-server stress profile:
//!
//! * **Zipf shard popularity** — shard s is picked with probability
//!   ∝ 1/(s+1)^θ, so θ > 0 concentrates traffic on a few hot shards
//!   (exactly the case admission control exists for) while θ = 0 is
//!   uniform.
//! * **Configurable push/pull mix** — each op is a push (encode a gradient
//!   slice client-side, server decodes-and-applies) with probability
//!   `push_fraction`, else a pull (server re-encodes its snapshot, client
//!   decodes).
//! * **Bursty open-loop arrivals** — ops are issued in back-to-back bursts
//!   of `burst` without waiting for admission feedback, so a burst larger
//!   than a shard's queue depth *will* draw shed responses; the harness
//!   counts them instead of retrying, which is what keeps overload visible.
//!
//! Everything is seeded: thread t draws from `stream(seed ^ 0x7247, t)`, a
//! client's encode sessions from the shared `(seed, client, shard)`
//! derivation. With `threads = 1` the op sequence is fully deterministic,
//! which the integration suite uses to prove the in-process and `uds:`
//! socket paths land bit-identical final parameters.

use std::time::{Duration, Instant};

use anyhow::{ensure, Context, Result};

use super::service::{
    encode_request, parse_response, Reply, Service, OP_PULL, OP_PUSH, ST_OK, ST_SHED, ST_STALE,
};
use super::shard::SessionPool;
use crate::metrics::Latency;
use crate::transport::frame::{write_frame, FrameReader};
use crate::transport::net::{connect_retry, Endpoint};
use crate::util::rng::{self, Xoshiro256};

/// Load-shape knobs for one traffic run.
#[derive(Debug, Clone)]
pub struct TrafficConfig {
    /// Simulated clients (distinct client ids / session streams).
    pub clients: usize,
    /// OS threads the clients are multiplexed over.
    pub threads: usize,
    /// Total ops across all clients.
    pub ops: usize,
    /// Probability an op is a push (the rest are pulls).
    pub push_fraction: f64,
    /// Zipf skew θ over shards (0 = uniform).
    pub zipf: f64,
    /// Ops issued back-to-back per arrival.
    pub burst: usize,
    pub seed: u64,
}

impl Default for TrafficConfig {
    fn default() -> Self {
        Self {
            clients: 8,
            threads: 2,
            ops: 1000,
            push_fraction: 0.8,
            zipf: 1.0,
            burst: 8,
            seed: 1,
        }
    }
}

/// Where the ops go: straight into the service, or through its socket
/// front end (the service reference still supplies the shard map, codec
/// and seed the clients encode against).
#[derive(Clone, Copy)]
pub enum Target<'a> {
    InProcess,
    Socket(&'a Endpoint),
}

/// What a traffic run observed, aggregated across threads.
#[derive(Debug, Clone, Default)]
pub struct TrafficReport {
    pub ops: u64,
    pub pushes: u64,
    pub pulls: u64,
    /// Pushes accepted and applied.
    pub pushed_ok: u64,
    /// Pulls that returned parameters.
    pub pulls_ok: u64,
    /// Ops rejected by the staleness bound.
    pub stale: u64,
    /// Ops shed by admission control.
    pub shed: u64,
    pub elapsed_s: f64,
    pub push_rtt: Latency,
    pub pull_rtt: Latency,
}

impl TrafficReport {
    fn add(&mut self, other: &TrafficReport) {
        self.ops += other.ops;
        self.pushes += other.pushes;
        self.pulls += other.pulls;
        self.pushed_ok += other.pushed_ok;
        self.pulls_ok += other.pulls_ok;
        self.stale += other.stale;
        self.shed += other.shed;
        self.push_rtt.add(&other.push_rtt);
        self.pull_rtt.add(&other.pull_rtt);
    }

    /// Sustained throughput over the whole run.
    pub fn msgs_per_sec(&self) -> f64 {
        if self.elapsed_s > 0.0 {
            self.ops as f64 / self.elapsed_s
        } else {
            0.0
        }
    }

    pub fn summary(&self) -> String {
        format!(
            "{} ops in {:.3}s ({:.0} msgs/s) · push ok {} / stale {} / shed {} · pull ok {} | push rtt {} | pull rtt {}",
            self.ops,
            self.elapsed_s,
            self.msgs_per_sec(),
            self.pushed_ok,
            self.stale,
            self.shed,
            self.pulls_ok,
            self.push_rtt.summary(),
            self.pull_rtt.summary(),
        )
    }
}

/// Cumulative Zipf distribution over the non-empty shards: returns the
/// eligible shard indices and their cumulative probabilities (last = 1).
fn zipf_cdf(service: &Service, skew: f64) -> (Vec<usize>, Vec<f64>) {
    let eligible: Vec<usize> = (0..service.num_shards())
        .filter(|&s| service.map().shard(s).len > 0)
        .collect();
    let mut cdf = Vec::with_capacity(eligible.len());
    let mut total = 0.0f64;
    for (rank, _) in eligible.iter().enumerate() {
        total += 1.0 / ((rank + 1) as f64).powf(skew);
        cdf.push(total);
    }
    for c in &mut cdf {
        *c /= total;
    }
    (eligible, cdf)
}

fn sample_cdf(cdf: &[f64], u: f64) -> usize {
    cdf.partition_point(|&c| c < u).min(cdf.len() - 1)
}

/// One simulated client's state: its encode sessions (for pushes), its
/// in-process stand-in for the server-side pull sessions, and the last
/// version it pulled per shard (what its pushes claim).
struct ClientSim {
    id: u32,
    push_pool: SessionPool,
    /// In-process runs have no connection handler to own the server-side
    /// pull session, so the client holds it — same `(seed, client, shard)`
    /// derivation, hence the same bytes the socket path produces.
    pull_pool: SessionPool,
    last_pulled: Vec<u64>,
}

/// Drive `cfg.ops` synthetic ops at `service` through `target`. Returns the
/// aggregated [`TrafficReport`]; shed and stale responses are counted, not
/// retried.
pub fn run_traffic(
    service: &Service,
    target: Target<'_>,
    cfg: &TrafficConfig,
) -> Result<TrafficReport> {
    ensure!(cfg.clients >= 1, "traffic needs at least one client");
    ensure!(cfg.ops >= 1, "traffic needs at least one op");
    let threads = cfg.threads.clamp(1, cfg.clients.max(1));
    let (eligible, cdf) = zipf_cdf(service, cfg.zipf);
    ensure!(!eligible.is_empty(), "service has no non-empty shards to target");
    let max_len = eligible.iter().map(|&s| service.map().shard(s).len).max().unwrap_or(0);

    let started = Instant::now();
    let mut merged = TrafficReport::default();
    let reports: Vec<Result<TrafficReport>> = std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(threads);
        for t in 0..threads {
            let eligible = &eligible;
            let cdf = &cdf;
            handles.push(scope.spawn(move || {
                run_thread(service, target, cfg, t, threads, eligible, cdf, max_len)
            }));
        }
        handles.into_iter().map(|h| h.join().expect("traffic thread panicked")).collect()
    });
    for r in reports {
        merged.add(&r?);
    }
    merged.elapsed_s = started.elapsed().as_secs_f64();
    Ok(merged)
}

#[allow(clippy::too_many_arguments)]
fn run_thread(
    service: &Service,
    target: Target<'_>,
    cfg: &TrafficConfig,
    t: usize,
    threads: usize,
    eligible: &[usize],
    cdf: &[f64],
    max_len: usize,
) -> Result<TrafficReport> {
    let shards = service.num_shards();
    let codec = service.codec().clone();
    // Thread t owns client ids t, t+threads, … — per-client session streams
    // are derived from the global ids, so the identity→bytes mapping is the
    // same no matter how many threads the clients are multiplexed over.
    let mut clients: Vec<ClientSim> = (t..cfg.clients)
        .step_by(threads)
        .map(|c| ClientSim {
            id: c as u32,
            push_pool: SessionPool::new(codec.clone(), cfg.seed ^ 0xC11E, c as u64, shards),
            pull_pool: SessionPool::new(codec.clone(), service.seed(), c as u64, shards),
            last_pulled: vec![0; shards],
        })
        .collect();
    ensure!(!clients.is_empty(), "thread {t} owns no clients (clients < threads?)");

    // This thread's op budget and its deterministic randomness.
    let my_ops = cfg.ops / threads + usize::from(t < cfg.ops % threads);
    let mut rng_t = Xoshiro256::stream(cfg.seed ^ 0x7247, t as u64);
    // One synthetic gradient per thread, sliced per push — the encode cost
    // is what matters, not fresh values per op.
    let grad = rng::normal_vec(&mut rng_t, max_len);

    // Socket mode: one connection per thread, clients multiplexed over it.
    let mut sock = match target {
        Target::InProcess => None,
        Target::Socket(ep) => {
            let conn = connect_retry(ep, Duration::from_secs(5))
                .with_context(|| format!("traffic thread {t} dialing {}", ep.describe()))?;
            conn.set_timeouts(Some(Duration::from_secs(10)))?;
            Some((conn, FrameReader::new()))
        }
    };

    let mut rep = TrafficReport::default();
    let mut frame = Vec::new();
    let mut req = Vec::new();
    let mut done = 0usize;
    let mut next_client = 0usize;
    while done < my_ops {
        let burst = cfg.burst.max(1).min(my_ops - done);
        for _ in 0..burst {
            let c = &mut clients[next_client];
            next_client = (next_client + 1) % clients.len();
            let s = eligible[sample_cdf(cdf, rng::uniform_f64(&mut rng_t))];
            let range = service.map().shard(s);
            let is_push = rng::uniform_f64(&mut rng_t) < cfg.push_fraction;
            rep.ops += 1;
            if is_push {
                rep.pushes += 1;
                c.push_pool.session(s).encode_into(&grad[..range.len], &mut frame);
                let op_t = Instant::now();
                let reply = match &mut sock {
                    None => service.push(s, c.last_pulled[s], &frame)?,
                    Some((conn, reader)) => {
                        encode_request(&mut req, OP_PUSH, s as u16, c.id, c.last_pulled[s], &frame);
                        write_frame(conn, &req)?;
                        let resp = reader
                            .read_frame(conn)?
                            .context("server closed mid push")
                            .and_then(parse_response)?;
                        match resp.status {
                            ST_OK => Reply::Pushed { version: resp.version },
                            ST_STALE => Reply::Stale { version: resp.version },
                            ST_SHED => Reply::Shed,
                            other => anyhow::bail!("unknown push status {other}"),
                        }
                    }
                };
                rep.push_rtt.record(op_t.elapsed());
                match reply {
                    Reply::Pushed { version } => {
                        rep.pushed_ok += 1;
                        c.last_pulled[s] = version;
                    }
                    Reply::Stale { version } => {
                        rep.stale += 1;
                        // Adopt the server's version: the client would
                        // re-pull before its next push.
                        c.last_pulled[s] = version;
                    }
                    Reply::Shed => rep.shed += 1,
                }
            } else {
                rep.pulls += 1;
                let op_t = Instant::now();
                let pulled = match &mut sock {
                    None => service
                        .pull_encoded(s, c.pull_pool.session(s), &mut frame)
                        .map(|v| (v, frame.as_slice())),
                    Some((conn, reader)) => {
                        encode_request(&mut req, OP_PULL, s as u16, c.id, 0, &[]);
                        write_frame(conn, &req)?;
                        let resp = reader
                            .read_frame(conn)?
                            .context("server closed mid pull")
                            .and_then(parse_response)?;
                        match resp.status {
                            ST_OK => Some((resp.version, resp.body)),
                            ST_SHED => None,
                            other => anyhow::bail!("unknown pull status {other}"),
                        }
                    }
                };
                match pulled {
                    Some((v, bytes)) => {
                        // Client-side decode is part of the pull round trip.
                        let dense = codec.decode(bytes, range.len)?;
                        ensure!(dense.len() == range.len, "pull decoded to wrong length");
                        rep.pulls_ok += 1;
                        c.last_pulled[s] = v;
                    }
                    None => rep.shed += 1,
                }
                rep.pull_rtt.record(op_t.elapsed());
            }
        }
        done += burst;
    }
    Ok(rep)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::CompressorSpec;
    use crate::ps::router::ShardMap;
    use crate::ps::service::ServiceConfig;

    fn service(n: usize, shards: usize, depth: usize) -> Service {
        let cfg = ServiceConfig {
            compressor: CompressorSpec::qsgd_4bit(),
            lr: 0.05,
            seed: 3,
            staleness: None,
            queue_depth: depth,
        };
        Service::new(ShardMap::uniform(n, shards).unwrap(), &cfg)
    }

    #[test]
    fn zipf_cdf_skews_toward_low_shards() {
        let svc = service(1000, 4, 8);
        let (eligible, cdf) = zipf_cdf(&svc, 1.0);
        assert_eq!(eligible, vec![0, 1, 2, 3]);
        assert!((cdf.last().unwrap() - 1.0).abs() < 1e-12);
        // First shard takes the biggest slice under skew.
        assert!(cdf[0] > 0.25);
        let (_, flat) = zipf_cdf(&svc, 0.0);
        assert!((flat[0] - 0.25).abs() < 1e-12, "θ=0 is uniform");
        // Empty tail shards are excluded.
        let tiny = service(3, 7, 8);
        let (el, _) = zipf_cdf(&tiny, 1.0);
        assert_eq!(el, vec![0, 1, 2]);
    }

    #[test]
    fn in_process_traffic_conserves_ops() {
        let svc = service(2048, 4, 64);
        let cfg = TrafficConfig {
            clients: 6,
            threads: 2,
            ops: 600,
            push_fraction: 0.7,
            zipf: 1.0,
            burst: 4,
            seed: 9,
        };
        let rep = run_traffic(&svc, Target::InProcess, &cfg).unwrap();
        assert_eq!(rep.ops, 600);
        assert_eq!(rep.pushes + rep.pulls, rep.ops);
        assert_eq!(rep.pushed_ok + rep.stale + rep.pulls_ok + rep.shed, rep.ops);
        assert!(rep.pushes > 0 && rep.pulls > 0, "mix produced both ops");
        assert!(rep.msgs_per_sec() > 0.0);
        // Deep queues + no staleness bound: nothing rejected.
        assert_eq!((rep.shed, rep.stale), (0, 0));
        let m = svc.metrics();
        assert_eq!(m.pushes, rep.pushed_ok);
        assert_eq!(m.pulls, rep.pulls_ok);
        assert_eq!(rep.push_rtt.count() as u64, rep.pushes);
        assert_eq!(rep.pull_rtt.count() as u64, rep.pulls);
    }

    #[test]
    fn single_thread_traffic_is_deterministic_in_outcome() {
        // Same seed, same service state ⇒ identical final params and
        // identical op accounting across two fresh runs.
        let cfg = TrafficConfig {
            clients: 4,
            threads: 1,
            ops: 300,
            push_fraction: 0.9,
            zipf: 0.8,
            burst: 8,
            seed: 42,
        };
        let run = || {
            let svc = service(1024, 3, 64);
            let rep = run_traffic(&svc, Target::InProcess, &cfg).unwrap();
            (svc.dense_params(), rep.pushed_ok, rep.pulls_ok)
        };
        let (p1, ok1, pl1) = run();
        let (p2, ok2, pl2) = run();
        let b1: Vec<u32> = p1.iter().map(|x| x.to_bits()).collect();
        let b2: Vec<u32> = p2.iter().map(|x| x.to_bits()).collect();
        assert_eq!(b1, b2, "deterministic traffic must land identical params");
        assert_eq!((ok1, pl1), (ok2, pl2));
    }
}
