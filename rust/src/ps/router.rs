//! The shard map: a [`QuantPlan`]-derived partition of the flat parameter
//! vector across S shard instances.
//!
//! The map reuses the segment machinery from [`crate::models::layout`]: each
//! shard owns a contiguous coordinate range `[offset, offset + len)` of the
//! flat vector, described by its own [`QuantPlan`] whose segments are the
//! (possibly split) pieces of the model plan that fall inside the range —
//! so a shard knows exactly which of its coordinates ride quantized and
//! which ride fp32, with the same `Segment` vocabulary every other layer
//! speaks. Ranges are balanced to within one coordinate (the first
//! `total % S` shards get the extra one) and cover the vector exactly:
//! total, non-overlapping, and ragged-dim-safe — properties pinned by the
//! router suite in `rust/tests/ps_service.rs`.

use anyhow::Result;

use crate::models::layout::{QuantPlan, Segment};

/// One shard's slice of the flat parameter vector.
#[derive(Debug, Clone)]
pub struct ShardRange {
    pub index: usize,
    /// First coordinate owned by this shard (global index).
    pub offset: usize,
    pub len: usize,
    /// The model plan restricted to this shard: segments carry *global*
    /// offsets inside `[offset, offset + len)`, preserving each piece's
    /// quantized/fp32 treatment.
    pub plan: QuantPlan,
}

impl ShardRange {
    /// This shard's slice of a full-length vector.
    pub fn slice<'a>(&self, full: &'a [f32]) -> &'a [f32] {
        &full[self.offset..self.offset + self.len]
    }

    pub fn slice_mut<'a>(&self, full: &'a mut [f32]) -> &'a mut [f32] {
        &mut full[self.offset..self.offset + self.len]
    }
}

/// A total, non-overlapping partition of `[0, total_len)` into S shard
/// ranges, derived from a model's [`QuantPlan`].
#[derive(Debug, Clone)]
pub struct ShardMap {
    shards: Vec<ShardRange>,
    total: usize,
}

impl ShardMap {
    /// Partition `plan`'s coordinate space into `shards` near-equal
    /// contiguous ranges. Plan segments are split at shard boundaries, so a
    /// shard count that does not divide the segment structure still yields
    /// an exact partition (more shards than coordinates leaves the tail
    /// shards empty rather than failing).
    pub fn build(plan: &QuantPlan, shards: usize) -> Result<Self> {
        anyhow::ensure!(shards >= 1, "shard map needs at least 1 shard, got {shards}");
        let total = plan.total_len();
        // The plan must be contiguous from 0 — QuantPlan::build produces
        // exactly that, but hand-rolled plans could lie.
        let mut expect = 0usize;
        for s in &plan.segments {
            anyhow::ensure!(
                s.offset == expect,
                "quant plan is not contiguous at offset {} (expected {expect})",
                s.offset
            );
            expect = s.offset + s.len;
        }

        let base = total / shards;
        let extra = total % shards;
        let mut out = Vec::with_capacity(shards);
        let mut lo = 0usize;
        let mut seg_iter = plan.segments.iter().peekable();
        for index in 0..shards {
            let len = base + usize::from(index < extra);
            let hi = lo + len;
            let mut segs: Vec<Segment> = Vec::new();
            // Collect the plan pieces overlapping [lo, hi): a plan segment
            // ending inside the shard is consumed; one straddling `hi` is
            // split, its remainder left for the next shard.
            while let Some(seg) = seg_iter.peek() {
                let s_lo = seg.offset.max(lo);
                let s_hi = (seg.offset + seg.len).min(hi);
                if s_lo < s_hi {
                    segs.push(Segment { offset: s_lo, len: s_hi - s_lo, quantized: seg.quantized });
                }
                if seg.offset + seg.len <= hi {
                    seg_iter.next();
                } else {
                    break;
                }
            }
            out.push(ShardRange { index, offset: lo, len, plan: QuantPlan { segments: segs } });
            lo = hi;
        }
        Ok(Self { shards: out, total })
    }

    /// Shard map over a bare `n`-coordinate vector (no model layout): one
    /// all-quantized segment, split S ways. This is what the async driver
    /// and the synthetic traffic harness use.
    pub fn uniform(n: usize, shards: usize) -> Result<Self> {
        let plan = QuantPlan {
            segments: if n == 0 {
                vec![]
            } else {
                vec![Segment { offset: 0, len: n, quantized: true }]
            },
        };
        Self::build(&plan, shards)
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn total_len(&self) -> usize {
        self.total
    }

    pub fn shard(&self, s: usize) -> &ShardRange {
        &self.shards[s]
    }

    pub fn shards(&self) -> &[ShardRange] {
        &self.shards
    }

    /// Which shard owns global coordinate `coord` (binary search on the
    /// range offsets). Empty tail shards never win: the owning shard is the
    /// one whose `[offset, offset + len)` contains the coordinate.
    pub fn shard_of(&self, coord: usize) -> Option<usize> {
        if coord >= self.total {
            return None;
        }
        let mut lo = 0usize;
        let mut hi = self.shards.len();
        while lo + 1 < hi {
            let mid = (lo + hi) / 2;
            if self.shards[mid].offset <= coord {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        // With empty shards adjacent to `lo`, walk forward to the one that
        // actually contains the coordinate (empty ranges share an offset).
        let mut s = lo;
        while self.shards[s].len == 0 || coord >= self.shards[s].offset + self.shards[s].len {
            s += 1;
        }
        Some(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::layout::ParamLayout;

    #[test]
    fn uniform_split_is_balanced_partition() {
        let m = ShardMap::uniform(10, 3).unwrap();
        assert_eq!(m.num_shards(), 3);
        assert_eq!(m.total_len(), 10);
        let lens: Vec<usize> = m.shards().iter().map(|s| s.len).collect();
        assert_eq!(lens, vec![4, 3, 3]);
        let mut cursor = 0;
        for s in m.shards() {
            assert_eq!(s.offset, cursor);
            cursor += s.len;
            assert_eq!(s.plan.total_len(), s.len);
        }
        assert_eq!(cursor, 10);
    }

    #[test]
    fn shard_of_matches_ranges() {
        let m = ShardMap::uniform(10, 3).unwrap();
        for c in 0..10 {
            let s = m.shard_of(c).unwrap();
            let r = m.shard(s);
            assert!(c >= r.offset && c < r.offset + r.len, "coord {c} in shard {s}");
        }
        assert_eq!(m.shard_of(10), None);
    }

    #[test]
    fn more_shards_than_coords_leaves_empty_tails() {
        let m = ShardMap::uniform(3, 7).unwrap();
        let lens: Vec<usize> = m.shards().iter().map(|s| s.len).collect();
        assert_eq!(lens, vec![1, 1, 1, 0, 0, 0, 0]);
        assert_eq!(m.shard_of(2), Some(2));
    }

    #[test]
    fn plan_segments_split_at_shard_boundaries() {
        // Mixed plan: a small fp32 tensor then a large quantized one.
        let l = ParamLayout::synthetic(&[("small", vec![6]), ("big", vec![14])]);
        let plan = QuantPlan::build(&l, 10); // small -> fp32, big -> quantized
        let m = ShardMap::build(&plan, 2).unwrap();
        // 20 coords split 10/10: shard 0 = fp32[0..6) + quant[6..10),
        // shard 1 = quant[10..20).
        let s0 = &m.shard(0).plan.segments;
        assert_eq!(s0.len(), 2);
        assert_eq!((s0[0].offset, s0[0].len, s0[0].quantized), (0, 6, false));
        assert_eq!((s0[1].offset, s0[1].len, s0[1].quantized), (6, 4, true));
        let s1 = &m.shard(1).plan.segments;
        assert_eq!(s1.len(), 1);
        assert_eq!((s1[0].offset, s1[0].len, s1[0].quantized), (10, 10, true));
    }

    #[test]
    fn rejects_zero_shards_and_gappy_plans() {
        assert!(ShardMap::uniform(8, 0).is_err());
        let gappy =
            QuantPlan { segments: vec![Segment { offset: 4, len: 4, quantized: true }] };
        assert!(ShardMap::build(&gappy, 2).is_err());
    }

    #[test]
    fn empty_vector_is_fine() {
        let m = ShardMap::uniform(0, 2).unwrap();
        assert_eq!(m.total_len(), 0);
        assert_eq!(m.shard_of(0), None);
    }
}
