//! Admission control: a bounded inflight gate with explicit shedding.
//!
//! Each shard fronts its work with an [`Admission`] gate of depth
//! `queue_depth`: a request first tries to take a permit, and when all
//! permits are held — `queue_depth` requests already admitted (being
//! processed or waiting on the shard lock) — the request is **shed**: it
//! gets an immediate, counted backpressure response instead of joining an
//! unbounded queue. That is the difference between overload the client can
//! see and react to, and silent buffering that turns a traffic burst into a
//! memory bill and a latency cliff. The gate is lock-free (two atomics), so
//! shedding under overload costs one failed CAS, not a contended mutex.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

/// Bounded inflight gate: at most `depth` admitted requests at a time,
/// everything beyond that shed (counted, never blocked).
#[derive(Debug)]
pub struct Admission {
    depth: usize,
    inflight: AtomicUsize,
    admitted: AtomicU64,
    shed: AtomicU64,
}

/// RAII permit: holding one means the request was admitted; dropping it
/// frees the slot.
#[derive(Debug)]
pub struct Permit<'a> {
    gate: &'a Admission,
}

impl Admission {
    /// A gate with `depth` slots (clamped to at least 1 — a zero-depth gate
    /// would shed everything forever).
    pub fn new(depth: usize) -> Self {
        Self {
            depth: depth.max(1),
            inflight: AtomicUsize::new(0),
            admitted: AtomicU64::new(0),
            shed: AtomicU64::new(0),
        }
    }

    /// Try to admit one request. `None` means the gate is full and the
    /// request was shed (the shed counter is already incremented); `Some`
    /// holds the slot until dropped.
    pub fn try_enter(&self) -> Option<Permit<'_>> {
        let mut cur = self.inflight.load(Ordering::Relaxed);
        loop {
            if cur >= self.depth {
                self.shed.fetch_add(1, Ordering::Relaxed);
                return None;
            }
            match self.inflight.compare_exchange_weak(
                cur,
                cur + 1,
                Ordering::Acquire,
                Ordering::Relaxed,
            ) {
                Ok(_) => {
                    self.admitted.fetch_add(1, Ordering::Relaxed);
                    return Some(Permit { gate: self });
                }
                Err(now) => cur = now,
            }
        }
    }

    pub fn depth(&self) -> usize {
        self.depth
    }

    /// Currently admitted (inflight) requests.
    pub fn inflight(&self) -> usize {
        self.inflight.load(Ordering::Relaxed)
    }

    /// Total requests ever admitted.
    pub fn admitted(&self) -> u64 {
        self.admitted.load(Ordering::Relaxed)
    }

    /// Total requests shed because the gate was full.
    pub fn shed(&self) -> u64 {
        self.shed.load(Ordering::Relaxed)
    }
}

impl Drop for Permit<'_> {
    fn drop(&mut self) {
        self.gate.inflight.fetch_sub(1, Ordering::Release);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn depth_permits_then_counted_shed() {
        let gate = Admission::new(3);
        let held: Vec<Permit> = (0..3).map(|_| gate.try_enter().expect("slot free")).collect();
        assert_eq!(gate.inflight(), 3);
        // Deterministic: every attempt past the depth is shed and counted.
        for i in 1..=5u64 {
            assert!(gate.try_enter().is_none());
            assert_eq!(gate.shed(), i);
        }
        assert_eq!(gate.admitted(), 3);
        drop(held);
        assert_eq!(gate.inflight(), 0);
        assert!(gate.try_enter().is_some(), "slots free again after release");
    }

    #[test]
    fn zero_depth_clamps_to_one() {
        let gate = Admission::new(0);
        assert_eq!(gate.depth(), 1);
        let p = gate.try_enter().expect("one slot");
        assert!(gate.try_enter().is_none());
        drop(p);
    }

    #[test]
    fn concurrent_attempts_never_exceed_depth() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let gate = Admission::new(4);
        let peak = AtomicUsize::new(0);
        std::thread::scope(|scope| {
            for _ in 0..8 {
                scope.spawn(|| {
                    for _ in 0..2000 {
                        if let Some(_permit) = gate.try_enter() {
                            let now = gate.inflight();
                            peak.fetch_max(now, Ordering::Relaxed);
                            assert!(now <= 4, "inflight {now} above depth");
                        }
                    }
                });
            }
        });
        assert_eq!(gate.inflight(), 0);
        assert!(peak.load(Ordering::Relaxed) >= 1);
        // Conservation: every attempt either entered or was shed.
        assert_eq!(gate.admitted() + gate.shed(), 8 * 2000);
    }
}
