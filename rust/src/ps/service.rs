//! The sharded parameter-server service: S shard cells behind one facade,
//! a tiny request protocol over the transport stack, and the deterministic
//! event-driven driver that makes the legacy single-loop
//! [`crate::coordinator::async_ps`] the S=1 degenerate case.
//!
//! Three layers, same state:
//!
//! * [`Service`] — in-process API. Each shard cell pairs an [`Admission`]
//!   gate with a mutex-guarded [`Shard`]; shards lock independently, so
//!   pushes to different shards proceed in parallel and a hot shard sheds
//!   without slowing the others.
//! * [`serve`] — the same service over `tcp:`/`uds:` sockets: a 15-byte
//!   request header (op, shard, client id, version) rides in front of the
//!   self-describing encoded frames, reusing `transport::frame` for
//!   boundaries and `transport::net` for endpoints. One handler thread per
//!   connection owns a [`FrameReader`] and per-client [`SessionPool`]s.
//! * [`run_async`] — the event-driven virtual-time driver from
//!   `async_ps::run`, re-routed through a [`Service`]. With S=1 and the
//!   session streams below it is **bit-identical** to the legacy loop
//!   (pinned in `rust/tests/ps_service.rs`); with S>1 each worker encodes
//!   one frame per shard and the server applies them shard-by-shard.
//!
//! Determinism contract: parameter init is `stream(seed, 0xA54C)` (the
//! legacy formula over the *full* vector, then sliced), and a worker's
//! encode session for shard s is `stream(seed ^ 0xAB5, w | s << 32)` — for
//! s = 0 exactly the legacy per-worker stream, which is what makes the S=1
//! parity hold down to the wire bytes.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::{self, JoinHandle};
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Result};

use super::admission::Admission;
use super::router::ShardMap;
use super::shard::{PushOutcome, SessionPool, Shard};
use crate::coordinator::async_ps::{AsyncConfig, AsyncResult};
use crate::coordinator::sources::GradSource;
use crate::coordinator::CompressorSpec;
use crate::metrics::{Curve, Latency, WireStats};
use crate::obs::flight;
use crate::obs::trace::Site;
use crate::obs::MetricSet;
use crate::quant::{Codec, EncodeSession};
use crate::transport::frame::{write_frame, FrameReader};
use crate::transport::net::{Conn, Endpoint, Listener};
use crate::util::par;
use crate::util::rng::Xoshiro256;

/// Service-level knobs (the shard map itself travels separately).
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    pub compressor: CompressorSpec,
    pub lr: f32,
    pub seed: u64,
    /// Staleness bound τ: reject pushes whose pulled version lags the shard
    /// by more than τ updates. `None` = unbounded (legacy behaviour).
    pub staleness: Option<u64>,
    /// Admission depth per shard (bounded inflight; extra requests shed).
    pub queue_depth: usize,
}

/// What the service tells a client about its request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Reply {
    /// Push decoded and applied; shard now at `version`.
    Pushed { version: u64 },
    /// Push rejected by the staleness bound; re-pull at `version`.
    Stale { version: u64 },
    /// Shed by admission control — retry later.
    Shed,
}

/// Aggregated service counters and latency percentiles across all shards.
#[derive(Debug, Clone, Default)]
pub struct ServiceMetrics {
    pub pushes: u64,
    pub pulls: u64,
    pub stale_rejected: u64,
    pub admitted: u64,
    pub shed: u64,
    pub push_decode: Latency,
    pub pull_encode: Latency,
}

impl ServiceMetrics {
    pub fn summary(&self) -> String {
        format!(
            "pushes {} · pulls {} · stale {} · shed {} | push-decode {} | pull-encode {}",
            self.pushes,
            self.pulls,
            self.stale_rejected,
            self.shed,
            self.push_decode.summary(),
            self.pull_encode.summary(),
        )
    }

    /// Export into the unified metrics registry under the `ps.*` namespace.
    pub fn export(&self, m: &mut MetricSet) {
        m.counter("ps.pushes", self.pushes);
        m.counter("ps.pulls", self.pulls);
        m.counter("ps.stale_rejected", self.stale_rejected);
        m.counter("ps.admitted", self.admitted);
        m.counter("ps.shed", self.shed);
        m.hist("ps.push_decode_ns", self.push_decode.hist());
        m.hist("ps.pull_encode_ns", self.pull_encode.hist());
    }
}

// Flight-recorder breadcrumb sites. `a` = shard, `b` = client version.
static CRUMB_SHED: Site = Site::new("ps.shed");
static CRUMB_STALE: Site = Site::new("ps.stale");

struct Cell {
    admission: Admission,
    shard: Mutex<Shard>,
}

/// S independent shard cells behind one facade. Shared across threads as
/// `Arc<Service>`; all methods take `&self`.
pub struct Service {
    map: ShardMap,
    codec: Arc<dyn Codec>,
    seed: u64,
    cells: Vec<Cell>,
}

impl Service {
    /// A service over `map` with parameters initialised by the legacy
    /// async-PS formula: `stream(seed, 0xA54C)` normal draws × 0.1 over the
    /// full vector, then sliced per shard — so the S=1 service starts
    /// bit-identical to `async_ps::run`.
    pub fn new(map: ShardMap, cfg: &ServiceConfig) -> Self {
        let n = map.total_len();
        let init: Vec<f32> = {
            let mut r = Xoshiro256::stream(cfg.seed, 0xA54C);
            crate::util::rng::normal_vec(&mut r, n).into_iter().map(|x| x * 0.1).collect()
        };
        Self::with_init(map, cfg, &init).expect("init length matches map by construction")
    }

    /// A service with explicitly supplied initial parameters.
    pub fn with_init(map: ShardMap, cfg: &ServiceConfig, init: &[f32]) -> Result<Self> {
        ensure!(
            init.len() == map.total_len(),
            "init vector has {} coords, shard map covers {}",
            init.len(),
            map.total_len()
        );
        let codec = cfg.compressor.codec();
        let cells = map
            .shards()
            .iter()
            .map(|r| Cell {
                admission: Admission::new(cfg.queue_depth),
                shard: Mutex::new(Shard::new(
                    r.clone(),
                    codec.clone(),
                    cfg.lr,
                    cfg.staleness,
                    init,
                )),
            })
            .collect();
        Ok(Self { map, codec, seed: cfg.seed, cells })
    }

    pub fn map(&self) -> &ShardMap {
        &self.map
    }

    pub fn codec(&self) -> &Arc<dyn Codec> {
        &self.codec
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }

    pub fn num_shards(&self) -> usize {
        self.cells.len()
    }

    /// The admission gate for shard `s` — exposed so tests can hold permits
    /// and provoke deterministic shedding.
    pub fn admission(&self, s: usize) -> &Admission {
        &self.cells[s].admission
    }

    fn lock(&self, s: usize) -> std::sync::MutexGuard<'_, Shard> {
        self.cells[s].shard.lock().expect("shard mutex poisoned")
    }

    pub fn shard_version(&self, s: usize) -> u64 {
        self.lock(s).version()
    }

    /// Push one encoded gradient frame (covering shard `s`'s coordinates)
    /// from a client that last pulled `pulled_version`.
    pub fn push(&self, s: usize, pulled_version: u64, frame: &[u8]) -> Result<Reply> {
        let _sp = crate::obs_span!("ps.push");
        let cell = &self.cells[s];
        let Some(_permit) = cell.admission.try_enter() else {
            flight::crumb(&CRUMB_SHED, s as u64, pulled_version, 0);
            return Ok(Reply::Shed);
        };
        let mut sh = cell.shard.lock().expect("shard mutex poisoned");
        Ok(match sh.push(pulled_version, frame)? {
            PushOutcome::Applied { version } => Reply::Pushed { version },
            PushOutcome::Stale { version } => {
                flight::crumb(&CRUMB_STALE, s as u64, pulled_version, version);
                Reply::Stale { version }
            }
        })
    }

    /// Dense pull of shard `s` into `out`. `Some(version)` on success,
    /// `None` if shed by admission.
    pub fn pull_dense(&self, s: usize, out: &mut Vec<f32>) -> Option<u64> {
        let _sp = crate::obs_span!("ps.pull_dense");
        let cell = &self.cells[s];
        let _permit = cell.admission.try_enter()?;
        let mut sh = cell.shard.lock().expect("shard mutex poisoned");
        Some(sh.pull_dense_into(out))
    }

    /// Quantized pull: re-encode shard `s`'s versioned snapshot with the
    /// caller's (per-connection) session. `None` if shed.
    pub fn pull_encoded(
        &self,
        s: usize,
        session: &mut dyn EncodeSession,
        out: &mut Vec<u8>,
    ) -> Option<u64> {
        let _sp = crate::obs_span!("ps.pull");
        let cell = &self.cells[s];
        let _permit = cell.admission.try_enter()?;
        let mut sh = cell.shard.lock().expect("shard mutex poisoned");
        Some(sh.pull_encode_into(session, out))
    }

    /// Assemble the full parameter vector from the live shard slices
    /// (maintenance read: no admission, no pull metrics).
    pub fn dense_params(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.map.total_len()];
        for (s, cell) in self.cells.iter().enumerate() {
            let sh = cell.shard.lock().expect("shard mutex poisoned");
            let r = self.map.shard(s);
            out[r.offset..r.offset + r.len].copy_from_slice(sh.params());
        }
        out
    }

    /// Aggregate counters and latency samples across all shards.
    pub fn metrics(&self) -> ServiceMetrics {
        let mut m = ServiceMetrics::default();
        for cell in &self.cells {
            let sh = cell.shard.lock().expect("shard mutex poisoned");
            m.pushes += sh.metrics.pushes;
            m.pulls += sh.metrics.pulls;
            m.stale_rejected += sh.metrics.stale_rejected;
            m.push_decode.add(&sh.metrics.push_decode);
            m.pull_encode.add(&sh.metrics.pull_encode);
            m.admitted += cell.admission.admitted();
            m.shed += cell.admission.shed();
        }
        m
    }

    /// The aggregated metrics rendered as deterministic text — the body of
    /// a `Stats` wire response and of `metrics_rank<R>.txt`.
    pub fn metrics_text(&self) -> String {
        let mut m = MetricSet::new();
        self.metrics().export(&mut m);
        m.render_text()
    }
}

// ---------------------------------------------------------------------------
// Wire protocol: a small fixed header in front of the self-describing frames.
// ---------------------------------------------------------------------------

/// Push an encoded gradient; body = encoded frame, `version` = last pulled.
pub const OP_PUSH: u8 = 0;
/// Pull the shard re-encoded through the client's server-side session.
pub const OP_PULL: u8 = 1;
/// Pull the shard as dense little-endian f32s (the legacy pull shape).
pub const OP_PULL_DENSE: u8 = 2;
/// Fetch the service's aggregated metrics as text (shard field ignored —
/// send 0). Response body is [`Service::metrics_text`] bytes.
pub const OP_STATS: u8 = 3;

pub const ST_OK: u8 = 0;
pub const ST_SHED: u8 = 1;
pub const ST_STALE: u8 = 2;

/// Request header: `op(1) | shard u16 LE | client u32 LE | version u64 LE`.
pub const REQ_HEADER: usize = 1 + 2 + 4 + 8;
/// Response header: `status(1) | shard u16 LE | version u64 LE`.
pub const RESP_HEADER: usize = 1 + 2 + 8;

/// A parsed request, body borrowed from the transport frame.
#[derive(Debug, PartialEq, Eq)]
pub struct Request<'a> {
    pub op: u8,
    pub shard: u16,
    pub client: u32,
    pub version: u64,
    pub body: &'a [u8],
}

/// A parsed response, body borrowed from the transport frame.
#[derive(Debug, PartialEq, Eq)]
pub struct Response<'a> {
    pub status: u8,
    pub shard: u16,
    pub version: u64,
    pub body: &'a [u8],
}

/// Serialise a request into `buf` (cleared first).
pub fn encode_request(
    buf: &mut Vec<u8>,
    op: u8,
    shard: u16,
    client: u32,
    version: u64,
    body: &[u8],
) {
    buf.clear();
    buf.reserve(REQ_HEADER + body.len());
    buf.push(op);
    buf.extend_from_slice(&shard.to_le_bytes());
    buf.extend_from_slice(&client.to_le_bytes());
    buf.extend_from_slice(&version.to_le_bytes());
    buf.extend_from_slice(body);
}

pub fn parse_request(frame: &[u8]) -> Result<Request<'_>> {
    ensure!(frame.len() >= REQ_HEADER, "request frame of {} bytes is truncated", frame.len());
    Ok(Request {
        op: frame[0],
        shard: u16::from_le_bytes(frame[1..3].try_into().expect("2 bytes")),
        client: u32::from_le_bytes(frame[3..7].try_into().expect("4 bytes")),
        version: u64::from_le_bytes(frame[7..15].try_into().expect("8 bytes")),
        body: &frame[REQ_HEADER..],
    })
}

/// Serialise a response into `buf` (cleared first).
pub fn encode_response(buf: &mut Vec<u8>, status: u8, shard: u16, version: u64, body: &[u8]) {
    buf.clear();
    buf.reserve(RESP_HEADER + body.len());
    buf.push(status);
    buf.extend_from_slice(&shard.to_le_bytes());
    buf.extend_from_slice(&version.to_le_bytes());
    buf.extend_from_slice(body);
}

pub fn parse_response(frame: &[u8]) -> Result<Response<'_>> {
    ensure!(frame.len() >= RESP_HEADER, "response frame of {} bytes is truncated", frame.len());
    Ok(Response {
        status: frame[0],
        shard: u16::from_le_bytes(frame[1..3].try_into().expect("2 bytes")),
        version: u64::from_le_bytes(frame[3..11].try_into().expect("8 bytes")),
        body: &frame[RESP_HEADER..],
    })
}

// ---------------------------------------------------------------------------
// Socket server.
// ---------------------------------------------------------------------------

/// How long the accept loop sleeps per poll while checking the stop flag.
const ACCEPT_POLL: Duration = Duration::from_millis(50);
/// Per-connection socket timeout: a peer silent this long is treated as
/// dead and its handler exits with an error.
const CONN_TIMEOUT: Duration = Duration::from_secs(10);

/// A running socket server. Dropping (or calling
/// [`shutdown`](Self::shutdown)) stops the accept loop and joins every
/// handler; clients should close their connections first so handlers see a
/// clean EOF rather than riding out the [`CONN_TIMEOUT`].
pub struct ServerHandle {
    stop: Arc<AtomicBool>,
    join: Option<JoinHandle<()>>,
    endpoint: Endpoint,
}

impl ServerHandle {
    /// The bound endpoint (with the real port for `tcp:host:0` binds).
    pub fn endpoint(&self) -> &Endpoint {
        &self.endpoint
    }

    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    fn stop_and_join(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for ServerHandle {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

/// Serve `service` on `ep`. The accept loop runs on its own thread and
/// spawns one handler thread per connection; every blocking operation is
/// deadline-bounded, so shutdown never hangs.
pub fn serve(ep: &Endpoint, service: Arc<Service>) -> Result<ServerHandle> {
    let listener = Listener::bind(ep)?;
    let endpoint = listener.local_endpoint()?;
    let stop = Arc::new(AtomicBool::new(false));
    let stop_accept = stop.clone();
    let join = thread::spawn(move || {
        let mut handlers: Vec<JoinHandle<()>> = Vec::new();
        while !stop_accept.load(Ordering::Relaxed) {
            match listener.accept_deadline(Instant::now() + ACCEPT_POLL) {
                Ok(conn) => {
                    let svc = service.clone();
                    let stop = stop_accept.clone();
                    handlers.push(thread::spawn(move || {
                        // Errors here are per-connection (peer died, bad
                        // frame): the connection ends, the server lives on.
                        let _ = handle_conn(conn, svc, stop);
                    }));
                }
                // Deadline poll elapsed (or transient accept error): retry.
                Err(_) => continue,
            }
        }
        for h in handlers {
            let _ = h.join();
        }
    });
    Ok(ServerHandle { stop, join: Some(join), endpoint })
}

/// One connection's serve loop: read request frames, dispatch to the
/// service, write response frames. Owns the connection's [`FrameReader`]
/// and a [`SessionPool`] per client id seen on this connection (so pull
/// re-encode state is per (client, shard) and deterministic in the ids).
fn handle_conn(mut conn: Conn, svc: Arc<Service>, stop: Arc<AtomicBool>) -> Result<()> {
    conn.set_timeouts(Some(CONN_TIMEOUT))?;
    let mut reader = FrameReader::new();
    let mut pools: HashMap<u32, SessionPool> = HashMap::new();
    let mut resp = Vec::new();
    let mut body = Vec::new();
    let mut dense = Vec::new();
    loop {
        let frame = match reader.read_frame(&mut conn) {
            Ok(Some(f)) => f,
            Ok(None) => return Ok(()), // clean EOF: client closed
            Err(e) => {
                if stop.load(Ordering::Relaxed) {
                    return Ok(());
                }
                return Err(e.context("reading ps request"));
            }
        };
        let req = parse_request(frame)?;
        let s = req.shard as usize;
        ensure!(s < svc.num_shards(), "request for shard {s} of {}", svc.num_shards());
        match req.op {
            OP_PUSH => match svc.push(s, req.version, req.body)? {
                Reply::Pushed { version } => {
                    encode_response(&mut resp, ST_OK, req.shard, version, &[])
                }
                Reply::Stale { version } => {
                    encode_response(&mut resp, ST_STALE, req.shard, version, &[])
                }
                Reply::Shed => encode_response(&mut resp, ST_SHED, req.shard, 0, &[]),
            },
            OP_PULL => {
                let pool = pools.entry(req.client).or_insert_with(|| {
                    SessionPool::new(
                        svc.codec().clone(),
                        svc.seed(),
                        u64::from(req.client),
                        svc.num_shards(),
                    )
                });
                match svc.pull_encoded(s, pool.session(s), &mut body) {
                    Some(v) => encode_response(&mut resp, ST_OK, req.shard, v, &body),
                    None => encode_response(&mut resp, ST_SHED, req.shard, 0, &[]),
                }
            }
            OP_STATS => {
                let text = svc.metrics_text();
                encode_response(&mut resp, ST_OK, 0, 0, text.as_bytes());
            }
            OP_PULL_DENSE => match svc.pull_dense(s, &mut dense) {
                Some(v) => {
                    body.clear();
                    body.reserve(dense.len() * 4);
                    for x in &dense {
                        body.extend_from_slice(&x.to_le_bytes());
                    }
                    encode_response(&mut resp, ST_OK, req.shard, v, &body)
                }
                None => encode_response(&mut resp, ST_SHED, req.shard, 0, &[]),
            },
            other => bail!("unknown ps op {other}"),
        }
        write_frame(&mut conn, &resp)?;
    }
}

// ---------------------------------------------------------------------------
// Deterministic async driver: async_ps re-routed through the service.
// ---------------------------------------------------------------------------

#[derive(PartialEq)]
struct Event {
    at: f64,
    worker: usize,
    pulled_version: usize,
    step: u64,
}

impl Eq for Event {}

impl Ord for Event {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // min-heap on time, same tie-breaking as the legacy loop
        other.at.partial_cmp(&self.at).unwrap_or(std::cmp::Ordering::Equal)
    }
}

impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

struct DriverWorker {
    /// One encode session per shard, stream `seed ^ 0xAB5, w | s << 32`.
    sessions: Vec<Box<dyn EncodeSession>>,
    grad: Vec<f32>,
    loss: f32,
    /// One reusable wire buffer per shard.
    msgs: Vec<Vec<u8>>,
    ready: bool,
}

/// The event-driven async-PS simulation of [`crate::coordinator::async_ps`],
/// with the server state held by a `shards`-way [`Service`]. Identical event
/// schedule, identical staleness accounting; each worker pushes one encoded
/// frame per (non-empty) shard and `wire`/`push_t` charge the summed frame
/// bytes. For `shards == 1` the result is bit-identical to the legacy loop.
pub fn run_async(
    cfg: &AsyncConfig,
    source: &mut dyn GradSource,
    shards: usize,
) -> Result<AsyncResult> {
    let n = source.dim();
    let map = ShardMap::uniform(n, shards)?;
    let scfg = ServiceConfig {
        compressor: cfg.compressor.clone(),
        lr: cfg.lr,
        seed: cfg.seed,
        staleness: None,
        queue_depth: cfg.workers.max(1),
    };
    let service = Service::new(map, &scfg);
    let codec = service.codec().clone();
    let mut states: Vec<DriverWorker> = (0..cfg.workers)
        .map(|w| DriverWorker {
            sessions: (0..shards)
                .map(|s| {
                    codec.session(Xoshiro256::stream(
                        cfg.seed ^ 0xAB5,
                        w as u64 | ((s as u64) << 32),
                    ))
                })
                .collect(),
            grad: Vec::new(),
            loss: 0.0,
            msgs: (0..shards)
                .map(|s| Vec::with_capacity(codec.encoded_size_hint(service.map().shard(s).len)))
                .collect(),
            ready: false,
        })
        .collect();

    let speed = |w: usize| -> f64 { cfg.speed.get(w).copied().unwrap_or(1.0).max(1e-6) };
    let pull_bytes = n * 4; // dense param pull
    let compute_s = cfg.cost.step_compute_s(source.flops_fwd_per_step(), 1);

    let mut params = service.dense_params();
    let mut heap = std::collections::BinaryHeap::new();
    for w in 0..cfg.workers {
        let (loss, grad) = source.loss_and_grad(w, 0, &params)?;
        states[w].loss = loss;
        states[w].grad = grad;
        let t = cfg.net.p2p_time(pull_bytes).secs() + compute_s / speed(w);
        heap.push(Event { at: t, worker: w, pulled_version: 0, step: 0 });
    }

    let mut version = 0usize;
    let mut wire = WireStats::default();
    let mut loss_curve = Curve::default();
    let mut max_stale = 0usize;
    let mut stale_sum = 0usize;
    let mut now = 0.0f64;
    let ranges = service.map().shards().to_vec();

    while version < cfg.updates {
        let ev = heap.pop().expect("workers alive");
        now = ev.at;
        let w = ev.worker;

        // Lazy batched encode, as in the legacy loop, but one frame per
        // shard: each worker encodes every shard's slice of its gradient
        // with that shard's session. Empty tail shards get no frame.
        if !states[w].ready {
            par::par_map_mut(&mut states, |_, st| {
                if !st.ready {
                    for (s, r) in ranges.iter().enumerate() {
                        if r.len > 0 {
                            st.sessions[s].encode_into(r.slice(&st.grad), &mut st.msgs[s]);
                        }
                    }
                    st.ready = true;
                }
            });
        }
        let push_len: usize = states[w].msgs.iter().map(Vec::len).sum();
        wire.record(push_len, n);
        let push_t = cfg.net.p2p_time(push_len).secs();

        // Server applies the worker's per-shard frames in shard order. With
        // staleness unbounded and the driver strictly sequential, every
        // reply must be Pushed.
        for (s, r) in ranges.iter().enumerate() {
            if r.len == 0 {
                continue;
            }
            match service.push(s, ev.pulled_version as u64, &states[w].msgs[s])? {
                Reply::Pushed { .. } => {}
                other => bail!("driver push unexpectedly rejected: {other:?}"),
            }
        }
        states[w].ready = false;
        let staleness = version - ev.pulled_version;
        max_stale = max_stale.max(staleness);
        stale_sum += staleness;
        version += 1;

        if version % cfg.log_every.max(1) == 0 || version == cfg.updates {
            loss_curve.push(version, states[w].loss as f64);
        }

        if version < cfg.updates {
            params = service.dense_params();
            let (loss, grad) = source.loss_and_grad(w, ev.step + 1, &params)?;
            states[w].loss = loss;
            states[w].grad = grad;
            let next = now + push_t + cfg.net.p2p_time(pull_bytes).secs() + compute_s / speed(w);
            heap.push(Event { at: next, worker: w, pulled_version: version, step: ev.step + 1 });
        }
    }

    Ok(AsyncResult {
        loss: loss_curve,
        wire,
        params: service.dense_params(),
        max_staleness: max_stale,
        mean_staleness: stale_sum as f64 / cfg.updates as f64,
        vtime: now,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng;

    fn svc(n: usize, shards: usize, staleness: Option<u64>, depth: usize) -> Service {
        let cfg = ServiceConfig {
            compressor: CompressorSpec::qsgd_4bit(),
            lr: 0.1,
            seed: 5,
            staleness,
            queue_depth: depth,
        };
        Service::new(ShardMap::uniform(n, shards).unwrap(), &cfg)
    }

    fn push_frames(svc: &Service, grad: &[f32], session_seed: u64) -> Vec<Vec<u8>> {
        let codec = svc.codec();
        (0..svc.num_shards())
            .map(|s| {
                let r = svc.map().shard(s);
                codec
                    .session(Xoshiro256::stream(session_seed, s as u64))
                    .compress(r.slice(grad))
            })
            .collect()
    }

    #[test]
    fn wire_header_roundtrip() {
        let mut buf = Vec::new();
        encode_request(&mut buf, OP_PUSH, 7, 42, 913, b"payload");
        let req = parse_request(&buf).unwrap();
        assert_eq!(
            req,
            Request { op: OP_PUSH, shard: 7, client: 42, version: 913, body: b"payload" }
        );
        encode_response(&mut buf, ST_STALE, 7, 914, b"");
        let resp = parse_response(&buf).unwrap();
        assert_eq!(resp, Response { status: ST_STALE, shard: 7, version: 914, body: b"" });
        assert!(parse_request(&[0u8; REQ_HEADER - 1]).is_err());
        assert!(parse_response(&[0u8; RESP_HEADER - 1]).is_err());
    }

    #[test]
    fn push_then_pull_roundtrip_across_shards() {
        let n = 700;
        let svc = svc(n, 3, None, 4);
        let before = svc.dense_params();
        let grad = rng::normal_vec(&mut Xoshiro256::from_u64(2), n);
        for (s, frame) in push_frames(&svc, &grad, 77).iter().enumerate() {
            assert_eq!(svc.push(s, 0, frame).unwrap(), Reply::Pushed { version: 1 });
        }
        let after = svc.dense_params();
        assert_ne!(before, after);
        // Dense pulls reassemble the full updated vector.
        let mut out = Vec::new();
        let mut assembled = vec![0.0f32; n];
        for s in 0..svc.num_shards() {
            assert_eq!(svc.pull_dense(s, &mut out), Some(1));
            let r = svc.map().shard(s);
            assembled[r.offset..r.offset + r.len].copy_from_slice(&out);
        }
        assert_eq!(assembled, after);
        let m = svc.metrics();
        assert_eq!((m.pushes, m.pulls, m.shed), (3, 3, 0));
        assert_eq!(m.push_decode.count(), 3);
    }

    #[test]
    fn held_permits_shed_deterministically() {
        let svc = svc(256, 2, None, 2);
        let grad = rng::normal_vec(&mut Xoshiro256::from_u64(2), 256);
        let frames = push_frames(&svc, &grad, 9);
        // Fill shard 0's admission gate; shard 1 stays open.
        let _p0 = svc.admission(0).try_enter().unwrap();
        let _p1 = svc.admission(0).try_enter().unwrap();
        assert_eq!(svc.push(0, 0, &frames[0]).unwrap(), Reply::Shed);
        assert_eq!(svc.push(1, 0, &frames[1]).unwrap(), Reply::Pushed { version: 1 });
        drop((_p0, _p1));
        assert_eq!(svc.push(0, 0, &frames[0]).unwrap(), Reply::Pushed { version: 1 });
        let m = svc.metrics();
        assert_eq!(m.shed, 1);
        assert_eq!(m.pushes, 2);
    }

    #[test]
    fn stale_pushes_rejected_and_counted() {
        let svc = svc(128, 1, Some(1), 4);
        let grad = rng::normal_vec(&mut Xoshiro256::from_u64(2), 128);
        let mut sess = svc.codec().session(Xoshiro256::from_u64(3));
        for expect in 1..=3u64 {
            let f = sess.compress(&grad);
            assert_eq!(
                svc.push(0, expect - 1, &f).unwrap(),
                Reply::Pushed { version: expect }
            );
        }
        // Pulled at 0, shard at 3: lag 3 > τ=1.
        let f = sess.compress(&grad);
        assert_eq!(svc.push(0, 0, &f).unwrap(), Reply::Stale { version: 3 });
        assert_eq!(svc.metrics().stale_rejected, 1);
        assert_eq!(svc.shard_version(0), 3);
    }

    #[test]
    fn socket_serve_push_and_dense_pull() {
        let svc = Arc::new(svc(300, 2, None, 4));
        let path = std::env::temp_dir()
            .join(format!("qsgd-ps-unit-{}.sock", std::process::id()));
        let _ = std::fs::remove_file(&path);
        let server = serve(&Endpoint::Uds(path.clone()), svc.clone()).unwrap();
        {
            let mut conn =
                crate::transport::net::connect_retry(server.endpoint(), Duration::from_secs(5))
                    .unwrap();
            conn.set_timeouts(Some(Duration::from_secs(5))).unwrap();
            let mut reader = FrameReader::new();
            let grad = rng::normal_vec(&mut Xoshiro256::from_u64(4), 300);
            let frames = push_frames(&svc, &grad, 21);
            let mut req = Vec::new();
            for (s, f) in frames.iter().enumerate() {
                encode_request(&mut req, OP_PUSH, s as u16, 1, 0, f);
                write_frame(&mut conn, &req).unwrap();
                let frame = reader.read_frame(&mut conn).unwrap().unwrap();
                let resp = parse_response(frame).unwrap();
                assert_eq!((resp.status, resp.version), (ST_OK, 1));
            }
            // Dense pull of shard 0 matches the in-process view bitwise.
            encode_request(&mut req, OP_PULL_DENSE, 0, 1, 0, &[]);
            write_frame(&mut conn, &req).unwrap();
            let frame = reader.read_frame(&mut conn).unwrap().unwrap();
            let resp = parse_response(frame).unwrap();
            assert_eq!(resp.status, ST_OK);
            let r0 = svc.map().shard(0);
            let expect = &svc.dense_params()[r0.offset..r0.offset + r0.len];
            let got: Vec<f32> = resp
                .body
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                .collect();
            assert_eq!(got, expect);
            // Stats op: aggregated metrics come back as deterministic text.
            encode_request(&mut req, OP_STATS, 0, 1, 0, &[]);
            write_frame(&mut conn, &req).unwrap();
            let frame = reader.read_frame(&mut conn).unwrap().unwrap();
            let resp = parse_response(frame).unwrap();
            assert_eq!(resp.status, ST_OK);
            let text = std::str::from_utf8(resp.body).unwrap();
            assert!(text.contains("ps.pushes counter 2"), "stats body:\n{text}");
            assert!(text.contains("ps.pull_encode_ns hist"), "stats body:\n{text}");
        }
        server.shutdown();
        let _ = std::fs::remove_file(&path);
    }
}
