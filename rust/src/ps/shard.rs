//! One parameter-server shard: a slice of the flat parameter vector, the
//! fused push/pull paths over it, and the per-connection encode-session
//! pool.
//!
//! * **Push** decodes an encoded gradient frame *straight into* the shard's
//!   parameter slice with `α = −lr` ([`Codec::decode_add_threads`]) — no
//!   intermediate gradient vector, exactly the fused path the single-server
//!   async loop uses. A push carries the version the client last pulled;
//!   when a staleness bound τ is set and the shard has advanced more than τ
//!   updates past that version, the push is **rejected** (counted, not
//!   applied) — the bounded-staleness condition of Theorem D.1, enforced at
//!   the server instead of assumed of the scheduler.
//! * **Pull** re-encodes from a *versioned snapshot*: the first pull after
//!   an update copies the live slice once, then every pull at that version
//!   encodes from the stable copy — concurrent pulls at one version see
//!   identical parameters regardless of interleaved pushes, and repeat
//!   pulls don't pay the copy.
//! * **Sessions** ([`SessionPool`]) are pooled per connection, one lazily
//!   created [`EncodeSession`] per shard the connection actually touches.
//!   Sessions own RNG streams and encode scratch, so pooling them per
//!   connection is what makes per-client server-side state (ECQ-style error
//!   compensation, stateful residuals) cheap: the pool *is* that state's
//!   home.

use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use super::router::ShardRange;
use crate::metrics::Latency;
use crate::quant::{Codec, EncodeSession};
use crate::util::rng::Xoshiro256;

/// Per-shard service counters and service-time percentiles, updated under
/// the shard lock.
#[derive(Debug, Clone, Default)]
pub struct ShardMetrics {
    pub pushes: u64,
    pub pulls: u64,
    /// Pushes rejected by the staleness bound.
    pub stale_rejected: u64,
    /// Server-side decode-and-apply time per accepted push.
    pub push_decode: Latency,
    /// Server-side (snapshot +) encode time per pull.
    pub pull_encode: Latency,
}

impl ShardMetrics {
    pub fn add(&mut self, other: &ShardMetrics) {
        self.pushes += other.pushes;
        self.pulls += other.pulls;
        self.stale_rejected += other.stale_rejected;
        self.push_decode.add(&other.push_decode);
        self.pull_encode.add(&other.pull_encode);
    }
}

/// What happened to a push that made it past admission.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PushOutcome {
    /// Decoded and applied; the shard is now at `version`.
    Applied { version: u64 },
    /// Older than the staleness bound τ — rejected, nothing applied. The
    /// client should re-pull (`version` is the shard's current version).
    Stale { version: u64 },
}

/// One shard instance: owns its parameter slice and version counter.
/// Callers (the [`super::Service`]) wrap it in a mutex; everything here is
/// plain single-threaded state.
pub struct Shard {
    range: ShardRange,
    codec: Arc<dyn Codec>,
    lr: f32,
    /// Reject pushes whose pulled version lags the shard by more than τ;
    /// `None` = unbounded (the legacy async loop's behaviour).
    staleness_bound: Option<u64>,
    params: Vec<f32>,
    version: u64,
    snapshot: Vec<f32>,
    snapshot_version: Option<u64>,
    pub metrics: ShardMetrics,
}

impl Shard {
    /// A shard over `range`, its slice initialised from the full-length
    /// `init` vector.
    pub fn new(
        range: ShardRange,
        codec: Arc<dyn Codec>,
        lr: f32,
        staleness_bound: Option<u64>,
        init: &[f32],
    ) -> Self {
        let params = range.slice(init).to_vec();
        Self {
            range,
            codec,
            lr,
            staleness_bound,
            params,
            version: 0,
            snapshot: Vec::new(),
            snapshot_version: None,
            metrics: ShardMetrics::default(),
        }
    }

    pub fn range(&self) -> &ShardRange {
        &self.range
    }

    pub fn len(&self) -> usize {
        self.range.len
    }

    pub fn is_empty(&self) -> bool {
        self.range.len == 0
    }

    pub fn version(&self) -> u64 {
        self.version
    }

    pub fn params(&self) -> &[f32] {
        &self.params
    }

    /// Apply one encoded gradient frame (covering exactly this shard's
    /// coordinates) pushed by a client that last pulled `pulled_version`.
    pub fn push(&mut self, pulled_version: u64, frame: &[u8]) -> Result<PushOutcome> {
        if let Some(tau) = self.staleness_bound {
            if self.version.saturating_sub(pulled_version) > tau {
                self.metrics.stale_rejected += 1;
                return Ok(PushOutcome::Stale { version: self.version });
            }
        }
        let t = Instant::now();
        {
            let _sp = crate::obs_span!("ps.shard.decode");
            self.codec.decode_add_threads(
                frame,
                -self.lr,
                &mut self.params,
                self.codec.decode_threads(),
            )?;
        }
        self.metrics.push_decode.record(t.elapsed());
        self.metrics.pushes += 1;
        self.version += 1;
        Ok(PushOutcome::Applied { version: self.version })
    }

    /// Refresh the versioned snapshot if the live slice has advanced past
    /// it. Returns the snapshot's version.
    fn refresh_snapshot(&mut self) -> u64 {
        if self.snapshot_version != Some(self.version) {
            self.snapshot.clear();
            self.snapshot.extend_from_slice(&self.params);
            self.snapshot_version = Some(self.version);
        }
        self.version
    }

    /// Dense pull: copy the versioned snapshot into `out` (cleared first).
    /// Returns the snapshot version the copy reflects.
    pub fn pull_dense_into(&mut self, out: &mut Vec<f32>) -> u64 {
        let v = self.refresh_snapshot();
        out.clear();
        out.extend_from_slice(&self.snapshot);
        self.metrics.pulls += 1;
        v
    }

    /// Quantized pull: re-encode the versioned snapshot with the caller's
    /// (per-connection) session into `out`. Returns the snapshot version.
    pub fn pull_encode_into(
        &mut self,
        session: &mut dyn EncodeSession,
        out: &mut Vec<u8>,
    ) -> u64 {
        let v = self.refresh_snapshot();
        let t = Instant::now();
        {
            let _sp = crate::obs_span!("ps.shard.encode");
            session.encode_into(&self.snapshot, out);
        }
        self.metrics.pull_encode.record(t.elapsed());
        self.metrics.pulls += 1;
        v
    }
}

/// Deterministic RNG for a (connection, shard) encode session: pure in
/// `(seed, client, shard)`, so two runs that derive sessions for the same
/// identities encode bit-identical frames. `0x5053` is ASCII "PS".
pub fn session_rng(seed: u64, client: u64, shard: usize) -> Xoshiro256 {
    Xoshiro256::stream(seed ^ 0x5053, client ^ ((shard as u64) << 32))
}

/// Per-connection pool of [`EncodeSession`]s, one per shard, created lazily
/// on first touch — a connection that only ever talks to 2 of 64 shards
/// holds 2 sessions' worth of scratch, not 64. Both ends use it: the server
/// pools pull-re-encode sessions per accepted connection, and the traffic
/// harness pools push-encode sessions per simulated client.
pub struct SessionPool {
    codec: Arc<dyn Codec>,
    seed: u64,
    client: u64,
    slots: Vec<Option<Box<dyn EncodeSession>>>,
}

impl SessionPool {
    pub fn new(codec: Arc<dyn Codec>, seed: u64, client: u64, shards: usize) -> Self {
        Self { codec, seed, client, slots: (0..shards).map(|_| None).collect() }
    }

    /// The session for `shard`, created on first use.
    pub fn session(&mut self, shard: usize) -> &mut dyn EncodeSession {
        let slot = &mut self.slots[shard];
        if slot.is_none() {
            *slot = Some(self.codec.session(session_rng(self.seed, self.client, shard)));
        }
        slot.as_mut().expect("just filled").as_mut()
    }

    /// How many sessions have actually been materialised.
    pub fn live_sessions(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::CompressorSpec;
    use crate::ps::router::ShardMap;
    use crate::util::rng;

    fn shard(n: usize, staleness: Option<u64>) -> (Shard, Arc<dyn Codec>) {
        let map = ShardMap::uniform(n, 1).unwrap();
        let codec = CompressorSpec::qsgd_4bit().codec();
        let init = rng::normal_vec(&mut Xoshiro256::from_u64(3), n);
        let s = Shard::new(map.shard(0).clone(), codec.clone(), 0.1, staleness, &init);
        (s, codec)
    }

    #[test]
    fn push_applies_and_versions_advance() {
        let (mut s, codec) = shard(512, None);
        let before = s.params().to_vec();
        let grad = rng::normal_vec(&mut Xoshiro256::from_u64(9), 512);
        let frame = codec.session(Xoshiro256::from_u64(1)).compress(&grad);
        assert_eq!(s.push(0, &frame).unwrap(), PushOutcome::Applied { version: 1 });
        assert_eq!(s.version(), 1);
        assert_ne!(s.params(), before.as_slice());
        assert_eq!(s.metrics.pushes, 1);
        assert_eq!(s.metrics.push_decode.count(), 1);
    }

    #[test]
    fn stale_push_rejected_under_bound() {
        let (mut s, codec) = shard(256, Some(2));
        let grad = rng::normal_vec(&mut Xoshiro256::from_u64(9), 256);
        let mut sess = codec.session(Xoshiro256::from_u64(1));
        for _ in 0..4 {
            let frame = sess.compress(&grad);
            s.push(s.version(), &frame).unwrap();
        }
        assert_eq!(s.version(), 4);
        let before = s.params().to_vec();
        // Pulled at version 1, shard at 4: lag 3 > τ=2 — rejected.
        let frame = sess.compress(&grad);
        assert_eq!(s.push(1, &frame).unwrap(), PushOutcome::Stale { version: 4 });
        assert_eq!(s.params(), before.as_slice(), "rejected push must not touch params");
        assert_eq!(s.metrics.stale_rejected, 1);
        // Lag exactly τ is still admitted.
        let frame = sess.compress(&grad);
        assert_eq!(s.push(2, &frame).unwrap(), PushOutcome::Applied { version: 5 });
    }

    #[test]
    fn pull_snapshot_is_versioned_and_stable() {
        let (mut s, codec) = shard(256, None);
        let mut a = Vec::new();
        let mut b = Vec::new();
        assert_eq!(s.pull_dense_into(&mut a), 0);
        assert_eq!(s.pull_dense_into(&mut b), 0);
        assert_eq!(a, b, "same version ⇒ identical snapshot");
        let grad = rng::normal_vec(&mut Xoshiro256::from_u64(9), 256);
        let frame = codec.session(Xoshiro256::from_u64(1)).compress(&grad);
        s.push(0, &frame).unwrap();
        assert_eq!(s.pull_dense_into(&mut b), 1);
        assert_ne!(a, b, "new version ⇒ refreshed snapshot");
        // Encoded pull decodes back to the snapshot's length.
        let mut sess = codec.session(Xoshiro256::from_u64(2));
        let mut wire = Vec::new();
        assert_eq!(s.pull_encode_into(sess.as_mut(), &mut wire), 1);
        assert_eq!(codec.decode(&wire, 256).unwrap().len(), 256);
        assert_eq!(s.metrics.pulls, 4);
        assert_eq!(s.metrics.pull_encode.count(), 1);
    }

    #[test]
    fn session_pool_is_lazy_and_deterministic() {
        let codec = CompressorSpec::qsgd_4bit().codec();
        let mut pool = SessionPool::new(codec.clone(), 7, 42, 8);
        assert_eq!(pool.live_sessions(), 0);
        let grad = rng::normal_vec(&mut Xoshiro256::from_u64(5), 128);
        let f3 = pool.session(3).compress(&grad);
        assert_eq!(pool.live_sessions(), 1);
        // Same (seed, client, shard) in a fresh pool ⇒ same bytes.
        let mut pool2 = SessionPool::new(codec.clone(), 7, 42, 8);
        assert_eq!(pool2.session(3).compress(&grad), f3);
        // Different shard slot ⇒ an independent RNG stream.
        let f4 = pool.session(4).compress(&grad);
        assert_eq!(pool.live_sessions(), 2);
        assert_ne!(f3, f4);
    }
}
