//! Sharded quantized parameter-server service — the service-shaped
//! successor to the single-loop [`crate::coordinator::async_ps`].
//!
//! The paper's asynchronous story (Appendix D) is one logical server and K
//! cooperating workers; the ROADMAP north-star is a *service*: parameters
//! partitioned across S shard instances, hit by many lightweight clients
//! whose gradients arrive quantized and leave re-quantized. This module is
//! that shape, grown from the pieces the repo already trusts:
//!
//! * [`router`] — the shard map: a [`crate::models::layout::QuantPlan`]-
//!   derived total, non-overlapping partition of the flat parameter vector,
//!   each shard carrying its own plan slice (which coordinates ride
//!   quantized vs fp32).
//! * [`admission`] — bounded-inflight admission per shard: overload draws
//!   explicit, counted shed responses instead of silent buffering.
//! * [`shard`] — one shard instance: fused push decode-add straight into
//!   its parameter slice, pull re-encode from a versioned snapshot, a
//!   stale-gradient bound τ, and the per-connection
//!   [`shard::SessionPool`] of encode sessions.
//! * [`service`] — S shard cells behind one facade, the request protocol
//!   (op / shard / client / version header in front of the self-describing
//!   frames) over the `transport` socket stack, and
//!   [`service::run_async`] — the event-driven virtual-time driver whose
//!   S=1 case is bit-identical to the legacy `async_ps::run`.
//! * [`client`] — the heavy-traffic harness: N Zipf-skewed simulated
//!   clients over M threads, configurable push/pull mix, bursty open-loop
//!   arrivals, in-process or over sockets.
//!
//! Determinism is the through-line: parameter init, every encode session
//! (worker-, client- and server-side), and the single-threaded traffic
//! schedule are all pure functions of seeds and identities, which is what
//! lets the test suite pin S=1 against the legacy loop and the socket path
//! against the in-process path bit-for-bit.

pub mod admission;
pub mod client;
pub mod router;
pub mod service;
pub mod shard;

pub use admission::Admission;
pub use client::{run_traffic, Target, TrafficConfig, TrafficReport};
pub use router::{ShardMap, ShardRange};
pub use service::{run_async, serve, Reply, ServerHandle, Service, ServiceConfig, ServiceMetrics};
pub use shard::{PushOutcome, SessionPool, Shard, ShardMetrics};
