//! Run configuration: the tiny CLI argument parser (the offline environment
//! has no clap) and [`CodecOptions`], the knobs a
//! [`Codec`](crate::quant::Codec) constructor carries so callers stop
//! reaching for env vars and module constants.

use std::collections::BTreeMap;

/// Tuning knobs carried by a codec instead of read from globals: the v3
/// bucket-offset-directory size rule and the decode-side thread budget.
///
/// The defaults reproduce the wire format and behaviour of the pre-options
/// code exactly (directory at/above
/// [`DIRECTORY_MIN_COORDS`](crate::coding::gradient::DIRECTORY_MIN_COORDS)
/// coordinates, thread budget from the process-wide
/// [`max_threads`](crate::util::par::max_threads), which honours
/// `QSGD_THREADS`) — so `CodecOptions::default()` codecs emit bit-identical
/// bytes to the committed golden frames.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CodecOptions {
    /// Emit the v3 bucket-offset directory for gradients with at least this
    /// many coordinates (and ≥ 2 buckets). Changing it changes the wire
    /// bytes for sizes between the old and new thresholds — encoder and
    /// oracle must agree, which is why it rides the codec rather than a
    /// module constant.
    pub directory_min_coords: usize,
    /// Force the directory on/off regardless of size; `None` ⇒ the size
    /// rule above.
    pub directory: Option<bool>,
    /// Decode-side thread budget for
    /// [`decode_add_threads`](crate::quant::Codec::decode_add_threads);
    /// `None` ⇒ the process default (machine parallelism, capped by
    /// `QSGD_THREADS` when set).
    pub threads: Option<usize>,
}

impl Default for CodecOptions {
    fn default() -> Self {
        Self {
            directory_min_coords: crate::coding::gradient::DIRECTORY_MIN_COORDS,
            directory: None,
            threads: None,
        }
    }
}

impl CodecOptions {
    /// Single-threaded decode, default wire format — for oracles and tests
    /// that must be deterministic in wall-clock-independent ways.
    pub fn serial() -> Self {
        Self { threads: Some(1), ..Self::default() }
    }

    /// Should an encoder emit the v3 bucket-offset directory for an
    /// `n`-coordinate gradient at this bucket size? (The explicit override
    /// wins; otherwise the size rule: past the threshold with ≥ 2 buckets.)
    pub fn use_directory(&self, n: usize, bucket_size: usize) -> bool {
        self.directory.unwrap_or_else(|| {
            n >= self.directory_min_coords && n.div_ceil(bucket_size.max(1)) >= 2
        })
    }

    /// The effective decode-side thread budget.
    pub fn decode_threads(&self) -> usize {
        self.threads.unwrap_or_else(crate::util::par::max_threads).max(1)
    }
}

/// Which collective exchange algorithm moves the encoded gradients —
/// parsed from the CLI like
/// [`CompressorSpec`](crate::coordinator::CompressorSpec), built into a
/// [`CollectiveAlgo`](crate::collectives::CollectiveAlgo) by
/// [`crate::collectives::build`]. The topology × codec matrix (which specs
/// pair sensibly with which algorithms) is documented in the README's
/// "Collective algorithms" section.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub enum CollectiveSpec {
    /// Algorithm 1's all-to-all broadcast: every worker ships its full
    /// encoded gradient to all K−1 peers (CNTK MPI path). Traffic grows as
    /// (K−1)·|msg| per worker.
    #[default]
    AllToAll,
    /// Ring allreduce over bucket-aligned gradient segments. With
    /// `recompress`, each reduce-scatter hop decodes the incoming segment,
    /// adds the local contribution and re-encodes the partial sum
    /// (2·(K−1)/K·|msg| per worker); `error_feedback` carries an ECQ-style
    /// residual across hops *and steps* to compensate recompression error.
    /// Without `recompress`, the ring is pure transport: the original
    /// encodings circulate unchanged and the reduction happens locally in
    /// worker order — bit-identical to the all-to-all mean, at all-to-all
    /// traffic.
    Ring { recompress: bool, error_feedback: bool },
    /// Hierarchical two-level reduce matching the paper's
    /// multi-GPU-per-node testbed: intra-group fan-in to a leader (which
    /// re-encodes the group sum), a recompressing ring across leaders, then
    /// an intra-group fan-out of the final frames (forwarded verbatim, so
    /// every worker decodes identical bytes).
    Hierarchical { group: usize },
}

impl CollectiveSpec {
    pub fn ring() -> Self {
        CollectiveSpec::Ring { recompress: true, error_feedback: false }
    }

    pub fn ring_ef() -> Self {
        CollectiveSpec::Ring { recompress: true, error_feedback: true }
    }

    pub fn hierarchical(group: usize) -> Self {
        CollectiveSpec::Hierarchical { group }
    }

    /// `a2a` / `ring` / `ring:ef` / `ring:raw` / `hier[:G]`.
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        let s = s.to_lowercase();
        match s.as_str() {
            "a2a" | "alltoall" | "all-to-all" | "broadcast" => {
                return Ok(CollectiveSpec::AllToAll)
            }
            "ring" => return Ok(Self::ring()),
            "ring:ef" => return Ok(Self::ring_ef()),
            "ring:raw" => {
                return Ok(CollectiveSpec::Ring { recompress: false, error_feedback: false })
            }
            "hier" | "hierarchical" => return Ok(Self::hierarchical(4)),
            _ => {}
        }
        if let Some(g) = s.strip_prefix("hier:") {
            let group: usize =
                g.parse().map_err(|_| anyhow::anyhow!("bad hier group '{g}'"))?;
            anyhow::ensure!(group >= 2, "hier group must be ≥ 2, got {group}");
            return Ok(Self::hierarchical(group));
        }
        anyhow::bail!("unknown collective '{s}' (a2a|ring|ring:ef|ring:raw|hier[:G])")
    }

    pub fn label(&self) -> String {
        match *self {
            CollectiveSpec::AllToAll => "a2a".into(),
            CollectiveSpec::Ring { recompress: false, .. } => "ring:raw".into(),
            CollectiveSpec::Ring { error_feedback: true, .. } => "ring:ef".into(),
            CollectiveSpec::Ring { .. } => "ring".into(),
            CollectiveSpec::Hierarchical { group } => format!("hier:{group}"),
        }
    }
}

/// Which transport moves the encoded gradients between workers — the
/// simulated interconnect (default, single process, virtual time) or the
/// real socket transport ([`crate::transport`]: K OS processes, measured
/// wall-clock). Parsed from `--transport sim|tcp:HOST:PORT|uds:PATH`, where
/// the address names the *rendezvous point* rank 0 serves — per-rank data
/// connections use ephemeral ports / derived socket paths.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub enum TransportSpec {
    /// In-process simulated interconnect (virtual α–β time).
    #[default]
    Sim,
    /// TCP rendezvous at `HOST:PORT` (e.g. `127.0.0.1:29500`).
    Tcp { addr: String },
    /// Unix-domain-socket rendezvous at this filesystem path (per-rank
    /// listeners bind `PATH.r<rank>`). Unix only.
    Uds { path: String },
}

impl TransportSpec {
    /// `sim` / `tcp:HOST:PORT` / `uds:PATH`.
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        if s.eq_ignore_ascii_case("sim") {
            return Ok(TransportSpec::Sim);
        }
        if let Some(addr) = s.strip_prefix("tcp:") {
            anyhow::ensure!(
                addr.rsplit_once(':').is_some_and(|(h, p)| {
                    !h.is_empty() && p.parse::<u16>().is_ok()
                }),
                "tcp transport needs HOST:PORT, got '{addr}'"
            );
            return Ok(TransportSpec::Tcp { addr: addr.to_string() });
        }
        if let Some(path) = s.strip_prefix("uds:") {
            anyhow::ensure!(!path.is_empty(), "uds transport needs a socket path");
            anyhow::ensure!(cfg!(unix), "uds transport is only available on unix");
            return Ok(TransportSpec::Uds { path: path.to_string() });
        }
        anyhow::bail!("unknown transport '{s}' (sim|tcp:HOST:PORT|uds:PATH)")
    }

    pub fn label(&self) -> String {
        match self {
            TransportSpec::Sim => "sim".into(),
            TransportSpec::Tcp { addr } => format!("tcp:{addr}"),
            TransportSpec::Uds { path } => format!("uds:{path}"),
        }
    }

    pub fn is_sim(&self) -> bool {
        matches!(self, TransportSpec::Sim)
    }
}

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw args (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(iter: I) -> Self {
        let raw: Vec<String> = iter.into_iter().collect();
        let mut out = Args::default();
        let mut i = 0;
        while i < raw.len() {
            let a = &raw[i];
            if let Some(key) = a.strip_prefix("--") {
                if let Some((k, v)) = key.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if i + 1 < raw.len() && !raw[i + 1].starts_with("--") {
                    out.options.insert(key.to_string(), raw[i + 1].clone());
                    i += 1;
                } else {
                    out.flags.push(key.to_string());
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        out
    }

    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(String::as_str)
    }

    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }

    pub fn usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn f32(&self, key: &str, default: f32) -> f32 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn string(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn options_flags_positionals() {
        // NB: `--flag value`-style ambiguity is resolved greedily (the next
        // non-`--` token is consumed as the value), so boolean flags should
        // come last or use `--flag=true`; positionals go first.
        let a = parse("train tfm --workers 8 --lr=0.1 --double-buffer");
        assert_eq!(a.positional, vec!["train", "tfm"]);
        assert_eq!(a.usize("workers", 1), 8);
        assert!((a.f32("lr", 0.0) - 0.1).abs() < 1e-9);
        assert!(a.flag("double-buffer"));
        assert!(!a.flag("verbose"));
        assert_eq!(a.usize("missing", 7), 7);
    }

    #[test]
    fn trailing_flag() {
        let a = parse("--quiet");
        assert!(a.flag("quiet"));
        assert!(a.positional.is_empty());
    }

    #[test]
    fn collective_spec_parse_and_label() {
        assert_eq!(CollectiveSpec::parse("a2a").unwrap(), CollectiveSpec::AllToAll);
        assert_eq!(CollectiveSpec::parse("broadcast").unwrap(), CollectiveSpec::AllToAll);
        assert_eq!(CollectiveSpec::parse("ring").unwrap(), CollectiveSpec::ring());
        assert_eq!(CollectiveSpec::parse("RING:EF").unwrap(), CollectiveSpec::ring_ef());
        assert_eq!(
            CollectiveSpec::parse("ring:raw").unwrap(),
            CollectiveSpec::Ring { recompress: false, error_feedback: false }
        );
        assert_eq!(
            CollectiveSpec::parse("hier").unwrap(),
            CollectiveSpec::Hierarchical { group: 4 }
        );
        assert_eq!(CollectiveSpec::parse("hier:8").unwrap(), CollectiveSpec::hierarchical(8));
        assert!(CollectiveSpec::parse("hier:1").is_err());
        assert!(CollectiveSpec::parse("hier:x").is_err());
        assert!(CollectiveSpec::parse("mesh").is_err());
        assert_eq!(CollectiveSpec::default(), CollectiveSpec::AllToAll);
        for s in ["a2a", "ring", "ring:ef", "ring:raw", "hier:4"] {
            assert_eq!(CollectiveSpec::parse(s).unwrap().label(), s, "label round-trip");
        }
    }

    #[test]
    fn transport_spec_parse_and_label() {
        assert_eq!(TransportSpec::parse("sim").unwrap(), TransportSpec::Sim);
        assert_eq!(TransportSpec::parse("SIM").unwrap(), TransportSpec::Sim);
        assert!(TransportSpec::default().is_sim());
        assert_eq!(
            TransportSpec::parse("tcp:127.0.0.1:29500").unwrap(),
            TransportSpec::Tcp { addr: "127.0.0.1:29500".into() }
        );
        // bad TCP shapes: no port, non-numeric port, empty host
        assert!(TransportSpec::parse("tcp:localhost").is_err());
        assert!(TransportSpec::parse("tcp:host:port").is_err());
        assert!(TransportSpec::parse("tcp::123").is_err());
        assert!(TransportSpec::parse("uds:").is_err());
        assert!(TransportSpec::parse("mpi:whatever").is_err());
        #[cfg(unix)]
        {
            let t = TransportSpec::parse("uds:/tmp/qsgd.sock").unwrap();
            assert_eq!(t, TransportSpec::Uds { path: "/tmp/qsgd.sock".into() });
            assert!(!t.is_sim());
        }
        for s in ["sim", "tcp:127.0.0.1:29500"] {
            assert_eq!(TransportSpec::parse(s).unwrap().label(), s, "label round-trip");
        }
    }

    #[test]
    fn codec_options_directory_rule() {
        let d = CodecOptions::default();
        let min = crate::coding::gradient::DIRECTORY_MIN_COORDS;
        assert!(!d.use_directory(min - 1, 512));
        assert!(d.use_directory(min, 512));
        // a single bucket has nothing to parallelize
        assert!(!d.use_directory(min, usize::MAX));
        // explicit override wins in both directions
        let on = CodecOptions { directory: Some(true), ..CodecOptions::default() };
        assert!(on.use_directory(16, 4));
        let off = CodecOptions { directory: Some(false), ..CodecOptions::default() };
        assert!(!off.use_directory(min * 2, 512));
        // a custom threshold moves the boundary
        let low = CodecOptions { directory_min_coords: 100, ..CodecOptions::default() };
        assert!(low.use_directory(100, 10));
        assert!(!low.use_directory(99, 10));
        assert_eq!(CodecOptions::serial().decode_threads(), 1);
        assert!(d.decode_threads() >= 1);
    }
}
