//! Tiny CLI argument parser (the offline environment has no clap): supports
//! `--key value`, `--flag`, and positional arguments, with typed getters.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw args (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(iter: I) -> Self {
        let raw: Vec<String> = iter.into_iter().collect();
        let mut out = Args::default();
        let mut i = 0;
        while i < raw.len() {
            let a = &raw[i];
            if let Some(key) = a.strip_prefix("--") {
                if let Some((k, v)) = key.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if i + 1 < raw.len() && !raw[i + 1].starts_with("--") {
                    out.options.insert(key.to_string(), raw[i + 1].clone());
                    i += 1;
                } else {
                    out.flags.push(key.to_string());
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        out
    }

    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(String::as_str)
    }

    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }

    pub fn usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn f32(&self, key: &str, default: f32) -> f32 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn string(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn options_flags_positionals() {
        // NB: `--flag value`-style ambiguity is resolved greedily (the next
        // non-`--` token is consumed as the value), so boolean flags should
        // come last or use `--flag=true`; positionals go first.
        let a = parse("train tfm --workers 8 --lr=0.1 --double-buffer");
        assert_eq!(a.positional, vec!["train", "tfm"]);
        assert_eq!(a.usize("workers", 1), 8);
        assert!((a.f32("lr", 0.0) - 0.1).abs() < 1e-9);
        assert!(a.flag("double-buffer"));
        assert!(!a.flag("verbose"));
        assert_eq!(a.usize("missing", 7), 7);
    }

    #[test]
    fn trailing_flag() {
        let a = parse("--quiet");
        assert!(a.flag("quiet"));
        assert!(a.positional.is_empty());
    }
}
