//! Run configuration: the tiny CLI argument parser (the offline environment
//! has no clap) and [`CodecOptions`], the knobs a
//! [`Codec`](crate::quant::Codec) constructor carries so callers stop
//! reaching for env vars and module constants.

use std::collections::BTreeMap;

/// Tuning knobs carried by a codec instead of read from globals: the v3
/// bucket-offset-directory size rule and the decode-side thread budget.
///
/// The defaults reproduce the wire format and behaviour of the pre-options
/// code exactly (directory at/above
/// [`DIRECTORY_MIN_COORDS`](crate::coding::gradient::DIRECTORY_MIN_COORDS)
/// coordinates, thread budget from the process-wide
/// [`max_threads`](crate::util::par::max_threads), which honours
/// `QSGD_THREADS`) — so `CodecOptions::default()` codecs emit bit-identical
/// bytes to the committed golden frames.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CodecOptions {
    /// Emit the v3 bucket-offset directory for gradients with at least this
    /// many coordinates (and ≥ 2 buckets). Changing it changes the wire
    /// bytes for sizes between the old and new thresholds — encoder and
    /// oracle must agree, which is why it rides the codec rather than a
    /// module constant.
    pub directory_min_coords: usize,
    /// Force the directory on/off regardless of size; `None` ⇒ the size
    /// rule above.
    pub directory: Option<bool>,
    /// Decode-side thread budget for
    /// [`decode_add_threads`](crate::quant::Codec::decode_add_threads);
    /// `None` ⇒ the process default (machine parallelism, capped by
    /// `QSGD_THREADS` when set).
    pub threads: Option<usize>,
}

impl Default for CodecOptions {
    fn default() -> Self {
        Self {
            directory_min_coords: crate::coding::gradient::DIRECTORY_MIN_COORDS,
            directory: None,
            threads: None,
        }
    }
}

impl CodecOptions {
    /// Single-threaded decode, default wire format — for oracles and tests
    /// that must be deterministic in wall-clock-independent ways.
    pub fn serial() -> Self {
        Self { threads: Some(1), ..Self::default() }
    }

    /// Should an encoder emit the v3 bucket-offset directory for an
    /// `n`-coordinate gradient at this bucket size? (The explicit override
    /// wins; otherwise the size rule: past the threshold with ≥ 2 buckets.)
    pub fn use_directory(&self, n: usize, bucket_size: usize) -> bool {
        self.directory.unwrap_or_else(|| {
            n >= self.directory_min_coords && n.div_ceil(bucket_size.max(1)) >= 2
        })
    }

    /// The effective decode-side thread budget.
    pub fn decode_threads(&self) -> usize {
        self.threads.unwrap_or_else(crate::util::par::max_threads).max(1)
    }
}

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw args (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(iter: I) -> Self {
        let raw: Vec<String> = iter.into_iter().collect();
        let mut out = Args::default();
        let mut i = 0;
        while i < raw.len() {
            let a = &raw[i];
            if let Some(key) = a.strip_prefix("--") {
                if let Some((k, v)) = key.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if i + 1 < raw.len() && !raw[i + 1].starts_with("--") {
                    out.options.insert(key.to_string(), raw[i + 1].clone());
                    i += 1;
                } else {
                    out.flags.push(key.to_string());
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        out
    }

    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(String::as_str)
    }

    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }

    pub fn usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn f32(&self, key: &str, default: f32) -> f32 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn string(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn options_flags_positionals() {
        // NB: `--flag value`-style ambiguity is resolved greedily (the next
        // non-`--` token is consumed as the value), so boolean flags should
        // come last or use `--flag=true`; positionals go first.
        let a = parse("train tfm --workers 8 --lr=0.1 --double-buffer");
        assert_eq!(a.positional, vec!["train", "tfm"]);
        assert_eq!(a.usize("workers", 1), 8);
        assert!((a.f32("lr", 0.0) - 0.1).abs() < 1e-9);
        assert!(a.flag("double-buffer"));
        assert!(!a.flag("verbose"));
        assert_eq!(a.usize("missing", 7), 7);
    }

    #[test]
    fn trailing_flag() {
        let a = parse("--quiet");
        assert!(a.flag("quiet"));
        assert!(a.positional.is_empty());
    }

    #[test]
    fn codec_options_directory_rule() {
        let d = CodecOptions::default();
        let min = crate::coding::gradient::DIRECTORY_MIN_COORDS;
        assert!(!d.use_directory(min - 1, 512));
        assert!(d.use_directory(min, 512));
        // a single bucket has nothing to parallelize
        assert!(!d.use_directory(min, usize::MAX));
        // explicit override wins in both directions
        let on = CodecOptions { directory: Some(true), ..CodecOptions::default() };
        assert!(on.use_directory(16, 4));
        let off = CodecOptions { directory: Some(false), ..CodecOptions::default() };
        assert!(!off.use_directory(min * 2, 512));
        // a custom threshold moves the boundary
        let low = CodecOptions { directory_min_coords: 100, ..CodecOptions::default() };
        assert!(low.use_directory(100, 10));
        assert!(!low.use_directory(99, 10));
        assert_eq!(CodecOptions::serial().decode_threads(), 1);
        assert!(d.decode_threads() >= 1);
    }
}
