//! Run configuration: the tiny CLI argument parser (the offline environment
//! has no clap) and [`CodecOptions`], the knobs a
//! [`Codec`](crate::quant::Codec) constructor carries so callers stop
//! reaching for env vars and module constants.

use std::collections::BTreeMap;

/// Tuning knobs carried by a codec instead of read from globals: the v3
/// bucket-offset-directory size rule and the decode-side thread budget.
///
/// The defaults reproduce the wire format and behaviour of the pre-options
/// code exactly (directory at/above
/// [`DIRECTORY_MIN_COORDS`](crate::coding::gradient::DIRECTORY_MIN_COORDS)
/// coordinates, thread budget from the process-wide
/// [`max_threads`](crate::util::par::max_threads), which honours
/// `QSGD_THREADS`) — so `CodecOptions::default()` codecs emit bit-identical
/// bytes to the committed golden frames.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CodecOptions {
    /// Emit the v3 bucket-offset directory for gradients with at least this
    /// many coordinates (and ≥ 2 buckets). Changing it changes the wire
    /// bytes for sizes between the old and new thresholds — encoder and
    /// oracle must agree, which is why it rides the codec rather than a
    /// module constant.
    pub directory_min_coords: usize,
    /// Force the directory on/off regardless of size; `None` ⇒ the size
    /// rule above.
    pub directory: Option<bool>,
    /// Decode-side thread budget for
    /// [`decode_add_threads`](crate::quant::Codec::decode_add_threads);
    /// `None` ⇒ the process default (machine parallelism, capped by
    /// `QSGD_THREADS` when set).
    pub threads: Option<usize>,
}

impl Default for CodecOptions {
    fn default() -> Self {
        Self {
            directory_min_coords: crate::coding::gradient::DIRECTORY_MIN_COORDS,
            directory: None,
            threads: None,
        }
    }
}

impl CodecOptions {
    /// Single-threaded decode, default wire format — for oracles and tests
    /// that must be deterministic in wall-clock-independent ways.
    pub fn serial() -> Self {
        Self { threads: Some(1), ..Self::default() }
    }

    /// Should an encoder emit the v3 bucket-offset directory for an
    /// `n`-coordinate gradient at this bucket size? (The explicit override
    /// wins; otherwise the size rule: past the threshold with ≥ 2 buckets.)
    pub fn use_directory(&self, n: usize, bucket_size: usize) -> bool {
        self.directory.unwrap_or_else(|| {
            n >= self.directory_min_coords && n.div_ceil(bucket_size.max(1)) >= 2
        })
    }

    /// The effective decode-side thread budget.
    pub fn decode_threads(&self) -> usize {
        self.threads.unwrap_or_else(crate::util::par::max_threads).max(1)
    }
}

/// Which collective exchange algorithm moves the encoded gradients —
/// parsed from the CLI like
/// [`CompressorSpec`](crate::coordinator::CompressorSpec), built into a
/// [`CollectiveAlgo`](crate::collectives::CollectiveAlgo) by
/// [`crate::collectives::build`]. The topology × codec matrix (which specs
/// pair sensibly with which algorithms) is documented in the README's
/// "Collective algorithms" section.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub enum CollectiveSpec {
    /// Algorithm 1's all-to-all broadcast: every worker ships its full
    /// encoded gradient to all K−1 peers (CNTK MPI path). Traffic grows as
    /// (K−1)·|msg| per worker.
    #[default]
    AllToAll,
    /// Ring allreduce over bucket-aligned gradient segments. With
    /// `recompress`, each reduce-scatter hop decodes the incoming segment,
    /// adds the local contribution and re-encodes the partial sum
    /// (2·(K−1)/K·|msg| per worker); `error_feedback` carries an ECQ-style
    /// residual across hops *and steps* to compensate recompression error.
    /// Without `recompress`, the ring is pure transport: the original
    /// encodings circulate unchanged and the reduction happens locally in
    /// worker order — bit-identical to the all-to-all mean, at all-to-all
    /// traffic.
    Ring { recompress: bool, error_feedback: bool },
    /// Hierarchical two-level reduce matching the paper's
    /// multi-GPU-per-node testbed: intra-group fan-in to a leader (which
    /// re-encodes the group sum), a recompressing ring across leaders, then
    /// an intra-group fan-out of the final frames (forwarded verbatim, so
    /// every worker decodes identical bytes). The group structure is a
    /// declarative [`GroupSpec`], not a flat size knob: `hier:G` still
    /// parses (contiguous groups of G), and `hier:0,1/2,3` names explicit
    /// member lists.
    Hierarchical { groups: GroupSpec },
}

/// Declarative group structure for [`CollectiveSpec::Hierarchical`] — the
/// topology-style description the hierarchical collective reads its shape
/// from. Each group's first member is its leader.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum GroupSpec {
    /// Contiguous groups of this size over ranks `0..world` (the leader is
    /// the lowest rank of each group). Wire form `hier:G`.
    Contiguous(usize),
    /// Explicit member lists, e.g. `hier:0,1/2,3`: groups separated by
    /// `/`, members by `,`; the first member of each group leads it.
    Explicit(Vec<Vec<usize>>),
}

impl GroupSpec {
    /// Resolve into concrete member lists for a `world`-rank run: every
    /// rank must appear in exactly one group. Contiguous sizes are clamped
    /// to `[1, world]` the way the flat knob always was.
    pub fn resolve(&self, world: usize) -> anyhow::Result<Vec<Vec<usize>>> {
        anyhow::ensure!(world >= 1, "world size must be at least 1");
        let groups: Vec<Vec<usize>> = match self {
            GroupSpec::Contiguous(g) => {
                let g = (*g).clamp(1, world);
                (0..world)
                    .step_by(g)
                    .map(|lo| (lo..(lo + g).min(world)).collect())
                    .collect()
            }
            GroupSpec::Explicit(gs) => gs.clone(),
        };
        let mut seen = vec![false; world];
        let mut count = 0usize;
        for grp in &groups {
            anyhow::ensure!(!grp.is_empty(), "empty group in hierarchical spec");
            for &m in grp {
                anyhow::ensure!(
                    m < world,
                    "group member {m} out of range for {world} workers"
                );
                anyhow::ensure!(!seen[m], "rank {m} appears in two groups");
                seen[m] = true;
                count += 1;
            }
        }
        anyhow::ensure!(
            count == world,
            "hierarchical groups cover {count} of {world} ranks"
        );
        Ok(groups)
    }

    /// The part of the label after `hier:`.
    pub(crate) fn label_body(&self) -> String {
        match self {
            GroupSpec::Contiguous(g) => g.to_string(),
            GroupSpec::Explicit(gs) => gs
                .iter()
                .map(|grp| {
                    grp.iter()
                        .map(|m| m.to_string())
                        .collect::<Vec<_>>()
                        .join(",")
                })
                .collect::<Vec<_>>()
                .join("/"),
        }
    }
}

impl CollectiveSpec {
    pub fn ring() -> Self {
        CollectiveSpec::Ring { recompress: true, error_feedback: false }
    }

    pub fn ring_ef() -> Self {
        CollectiveSpec::Ring { recompress: true, error_feedback: true }
    }

    pub fn hierarchical(group: usize) -> Self {
        CollectiveSpec::Hierarchical { groups: GroupSpec::Contiguous(group) }
    }

    /// `a2a` / `ring` / `ring:ef` / `ring:raw` / `hier[:G]` /
    /// `hier:0,1/2,3` (explicit groups: `/` between groups, `,` between
    /// members, first member leads).
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        let s = s.to_lowercase();
        match s.as_str() {
            "a2a" | "alltoall" | "all-to-all" | "broadcast" => {
                return Ok(CollectiveSpec::AllToAll)
            }
            "ring" => return Ok(Self::ring()),
            "ring:ef" => return Ok(Self::ring_ef()),
            "ring:raw" => {
                return Ok(CollectiveSpec::Ring { recompress: false, error_feedback: false })
            }
            "hier" | "hierarchical" => return Ok(Self::hierarchical(4)),
            _ => {}
        }
        if let Some(g) = s.strip_prefix("hier:") {
            if g.contains(',') || g.contains('/') {
                let groups: Vec<Vec<usize>> = g
                    .split('/')
                    .map(|grp| {
                        grp.split(',')
                            .filter(|t| !t.is_empty())
                            .map(|t| {
                                t.parse::<usize>().map_err(|_| {
                                    anyhow::anyhow!("bad group member '{t}' in '{g}'")
                                })
                            })
                            .collect::<anyhow::Result<Vec<usize>>>()
                    })
                    .collect::<anyhow::Result<Vec<Vec<usize>>>>()?;
                anyhow::ensure!(
                    groups.iter().all(|grp| !grp.is_empty()),
                    "empty group in '{g}'"
                );
                return Ok(CollectiveSpec::Hierarchical {
                    groups: GroupSpec::Explicit(groups),
                });
            }
            let group: usize =
                g.parse().map_err(|_| anyhow::anyhow!("bad hier group '{g}'"))?;
            anyhow::ensure!(group >= 2, "hier group must be ≥ 2, got {group}");
            return Ok(Self::hierarchical(group));
        }
        anyhow::bail!(
            "unknown collective '{s}' (a2a|ring|ring:ef|ring:raw|hier[:G]|hier:0,1/2,3)"
        )
    }

    pub fn label(&self) -> String {
        match self {
            CollectiveSpec::AllToAll => "a2a".into(),
            CollectiveSpec::Ring { recompress: false, .. } => "ring:raw".into(),
            CollectiveSpec::Ring { error_feedback: true, .. } => "ring:ef".into(),
            CollectiveSpec::Ring { .. } => "ring".into(),
            CollectiveSpec::Hierarchical { groups } => {
                format!("hier:{}", groups.label_body())
            }
        }
    }
}

/// Which transport moves the encoded gradients between workers — the
/// simulated interconnect (default, single process, virtual time) or the
/// real socket transport ([`crate::transport`]: K OS processes, measured
/// wall-clock). Parsed from `--transport sim|tcp:HOST:PORT|uds:PATH`, where
/// the address names the *rendezvous point* rank 0 serves — per-rank data
/// connections use ephemeral ports / derived socket paths.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub enum TransportSpec {
    /// In-process simulated interconnect (virtual α–β time).
    #[default]
    Sim,
    /// TCP rendezvous at `HOST:PORT` (e.g. `127.0.0.1:29500`).
    Tcp { addr: String },
    /// Unix-domain-socket rendezvous at this filesystem path (per-rank
    /// listeners bind `PATH.r<rank>`). Unix only.
    Uds { path: String },
}

impl TransportSpec {
    /// `sim` / `tcp:HOST:PORT` / `uds:PATH`.
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        if s.eq_ignore_ascii_case("sim") {
            return Ok(TransportSpec::Sim);
        }
        if let Some(addr) = s.strip_prefix("tcp:") {
            anyhow::ensure!(
                addr.rsplit_once(':').is_some_and(|(h, p)| {
                    !h.is_empty() && p.parse::<u16>().is_ok()
                }),
                "tcp transport needs HOST:PORT, got '{addr}'"
            );
            return Ok(TransportSpec::Tcp { addr: addr.to_string() });
        }
        if let Some(path) = s.strip_prefix("uds:") {
            anyhow::ensure!(!path.is_empty(), "uds transport needs a socket path");
            anyhow::ensure!(cfg!(unix), "uds transport is only available on unix");
            return Ok(TransportSpec::Uds { path: path.to_string() });
        }
        anyhow::bail!("unknown transport '{s}' (sim|tcp:HOST:PORT|uds:PATH)")
    }

    pub fn label(&self) -> String {
        match self {
            TransportSpec::Sim => "sim".into(),
            TransportSpec::Tcp { addr } => format!("tcp:{addr}"),
            TransportSpec::Uds { path } => format!("uds:{path}"),
        }
    }

    pub fn is_sim(&self) -> bool {
        matches!(self, TransportSpec::Sim)
    }
}

/// Fault-injection scenario for a run, parsed from `--scenario`. One arm
/// drives both execution paths: on the simulated interconnect it configures
/// [`SimNet`](crate::simnet::SimNet) link overrides and
/// [`Faults`](crate::simnet::Faults); on the socket transport it configures
/// the [`FaultInjector`](crate::transport::FaultInjector) and the trainer's
/// recovery protocol. Every arm is seeded, so a `(scenario, seed)` pair is
/// a determinism golden.
#[derive(Debug, Clone, PartialEq, Default)]
pub enum ScenarioSpec {
    /// No faults (the default).
    #[default]
    None,
    /// Heterogeneous links: worker 0 runs at `1/factor` of the base
    /// bandwidth. `hetero[:FACTOR]`, default factor 4.
    Hetero { factor: f64 },
    /// Seeded straggler: each charged network op slows `factor`× with
    /// probability `prob`. `straggler[:PROB:FACTOR]`, default `0.1:5`.
    Straggler { prob: f64, factor: f64 },
    /// Seeded frame corruption with probability `prob` per data frame; the
    /// socket trainer re-requests corrupt frames (bounded) from live
    /// peers. `corrupt[:PROB]`, default 0.05.
    Corrupt { prob: f64 },
    /// Rank `rank` dies at step `step` (0-based); survivors skip it and
    /// renormalize the mean. `drop:RANK@STEP`.
    Drop { rank: usize, step: usize },
    /// Partial participation: a seeded shared schedule samples `k` of the
    /// N contributors each round, and the mean renormalizes over the
    /// sample. `partial:K`.
    Partial { k: usize },
}

impl ScenarioSpec {
    /// `none` / `hetero[:FACTOR]` / `straggler[:PROB:FACTOR]` /
    /// `corrupt[:PROB]` / `drop:RANK@STEP` / `partial:K`.
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        let s = s.to_lowercase();
        match s.as_str() {
            "none" => return Ok(ScenarioSpec::None),
            "hetero" => return Ok(ScenarioSpec::Hetero { factor: 4.0 }),
            "straggler" => {
                return Ok(ScenarioSpec::Straggler { prob: 0.1, factor: 5.0 })
            }
            "corrupt" => return Ok(ScenarioSpec::Corrupt { prob: 0.05 }),
            _ => {}
        }
        if let Some(f) = s.strip_prefix("hetero:") {
            let factor: f64 =
                f.parse().map_err(|_| anyhow::anyhow!("bad hetero factor '{f}'"))?;
            anyhow::ensure!(factor >= 1.0, "hetero factor must be ≥ 1, got {factor}");
            return Ok(ScenarioSpec::Hetero { factor });
        }
        if let Some(pf) = s.strip_prefix("straggler:") {
            let (p, f) = pf
                .split_once(':')
                .ok_or_else(|| anyhow::anyhow!("straggler needs PROB:FACTOR, got '{pf}'"))?;
            let prob: f64 =
                p.parse().map_err(|_| anyhow::anyhow!("bad straggler prob '{p}'"))?;
            let factor: f64 =
                f.parse().map_err(|_| anyhow::anyhow!("bad straggler factor '{f}'"))?;
            anyhow::ensure!((0.0..=1.0).contains(&prob), "straggler prob must be in [0,1]");
            anyhow::ensure!(factor >= 1.0, "straggler factor must be ≥ 1");
            return Ok(ScenarioSpec::Straggler { prob, factor });
        }
        if let Some(p) = s.strip_prefix("corrupt:") {
            let prob: f64 =
                p.parse().map_err(|_| anyhow::anyhow!("bad corrupt prob '{p}'"))?;
            anyhow::ensure!((0.0..=1.0).contains(&prob), "corrupt prob must be in [0,1]");
            return Ok(ScenarioSpec::Corrupt { prob });
        }
        if let Some(rs) = s.strip_prefix("drop:") {
            let (r, st) = rs
                .split_once('@')
                .ok_or_else(|| anyhow::anyhow!("drop needs RANK@STEP, got '{rs}'"))?;
            let rank: usize =
                r.parse().map_err(|_| anyhow::anyhow!("bad drop rank '{r}'"))?;
            let step: usize =
                st.parse().map_err(|_| anyhow::anyhow!("bad drop step '{st}'"))?;
            return Ok(ScenarioSpec::Drop { rank, step });
        }
        if let Some(k) = s.strip_prefix("partial:") {
            let k: usize =
                k.parse().map_err(|_| anyhow::anyhow!("bad partial count '{k}'"))?;
            anyhow::ensure!(k >= 1, "partial participation needs k ≥ 1");
            return Ok(ScenarioSpec::Partial { k });
        }
        anyhow::bail!(
            "unknown scenario '{s}' \
             (none|hetero[:F]|straggler[:P:F]|corrupt[:P]|drop:R@S|partial:K)"
        )
    }

    pub fn label(&self) -> String {
        match *self {
            ScenarioSpec::None => "none".into(),
            ScenarioSpec::Hetero { factor } => format!("hetero:{factor}"),
            ScenarioSpec::Straggler { prob, factor } => {
                format!("straggler:{prob}:{factor}")
            }
            ScenarioSpec::Corrupt { prob } => format!("corrupt:{prob}"),
            ScenarioSpec::Drop { rank, step } => format!("drop:{rank}@{step}"),
            ScenarioSpec::Partial { k } => format!("partial:{k}"),
        }
    }

    pub fn is_none(&self) -> bool {
        matches!(self, ScenarioSpec::None)
    }

    /// Configure a simulated interconnect for this scenario. `seed` feeds
    /// the fault schedule, so `(scenario, seed)` pins the virtual-time
    /// trace exactly.
    pub fn apply_simnet(&self, net: crate::simnet::SimNet, seed: u64) -> crate::simnet::SimNet {
        use crate::simnet::{Faults, Link};
        match *self {
            ScenarioSpec::Hetero { factor } => {
                let slow =
                    Link::new(net.link.bandwidth_bps / factor, net.link.latency_s);
                net.with_link_override(0, slow)
            }
            ScenarioSpec::Straggler { prob, factor } => {
                net.with_faults(Faults::new(seed).with_straggler(prob, factor))
            }
            ScenarioSpec::Corrupt { prob } => {
                net.with_faults(Faults::new(seed).with_corruption(prob))
            }
            // Drop/partial change who contributes, not the link model.
            ScenarioSpec::None
            | ScenarioSpec::Drop { .. }
            | ScenarioSpec::Partial { .. } => net,
        }
    }

    /// The seeded shared participation schedule: which ranks contribute to
    /// the mean at `step`. Every rank computes the same set from
    /// `(seed, step)` alone — no agreement round needed.
    pub fn participants(&self, world: usize, seed: u64, step: u64) -> Vec<usize> {
        match *self {
            ScenarioSpec::Partial { k } if world > 1 => {
                let mut idx: Vec<usize> = (0..world).collect();
                let mut s = seed ^ step.wrapping_mul(0x9E37_79B9_7F4A_7C15);
                for i in (1..world).rev() {
                    let j =
                        (crate::util::rng::splitmix64(&mut s) % (i as u64 + 1)) as usize;
                    idx.swap(i, j);
                }
                idx.truncate(k.clamp(1, world));
                idx.sort_unstable();
                idx
            }
            ScenarioSpec::Drop { rank, step: at } if world > 1 && step >= at as u64 => {
                (0..world).filter(|&r| r != rank).collect()
            }
            _ => (0..world).collect(),
        }
    }
}

/// Observability knobs shared by every subcommand (`--trace-out DIR`,
/// `--trace-sample N`), parsed here so train / dist-train / ps-serve /
/// ps-bench / exchange-worker all spell them the same way. Parsing does not
/// touch the global tracer; call [`install`](Self::install) once the process
/// knows its rank.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ObsSpec {
    /// Trace/metrics/flight output directory; `None` leaves the
    /// observability layer at its zero-overhead disabled default.
    pub trace_out: Option<String>,
    /// Keep every Nth span per thread (1 = all).
    pub sample_every: u32,
}

impl Default for ObsSpec {
    fn default() -> Self {
        Self { trace_out: None, sample_every: 1 }
    }
}

impl ObsSpec {
    /// Read `--trace-out` / `--trace-sample` from a parsed [`Args`].
    pub fn from_args(args: &Args) -> anyhow::Result<Self> {
        let sample = args.u64("trace-sample", 1);
        anyhow::ensure!(
            sample >= 1 && sample <= u64::from(u32::MAX),
            "--trace-sample must be at least 1, got {sample}"
        );
        let trace_out = args.get("trace-out").map(String::from);
        Ok(Self { trace_out, sample_every: sample as u32 })
    }

    /// Initialise the global observability layer for this process/rank.
    pub fn install(&self, rank: u32) {
        let dir = self.trace_out.as_deref().map(std::path::Path::new);
        crate::obs::init(dir, rank, self.sample_every);
    }
}

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw args (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(iter: I) -> Self {
        let raw: Vec<String> = iter.into_iter().collect();
        let mut out = Args::default();
        let mut i = 0;
        while i < raw.len() {
            let a = &raw[i];
            if let Some(key) = a.strip_prefix("--") {
                if let Some((k, v)) = key.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if i + 1 < raw.len() && !raw[i + 1].starts_with("--") {
                    out.options.insert(key.to_string(), raw[i + 1].clone());
                    i += 1;
                } else {
                    out.flags.push(key.to_string());
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        out
    }

    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(String::as_str)
    }

    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }

    pub fn usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn f32(&self, key: &str, default: f32) -> f32 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn string(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn options_flags_positionals() {
        // NB: `--flag value`-style ambiguity is resolved greedily (the next
        // non-`--` token is consumed as the value), so boolean flags should
        // come last or use `--flag=true`; positionals go first.
        let a = parse("train tfm --workers 8 --lr=0.1 --double-buffer");
        assert_eq!(a.positional, vec!["train", "tfm"]);
        assert_eq!(a.usize("workers", 1), 8);
        assert!((a.f32("lr", 0.0) - 0.1).abs() < 1e-9);
        assert!(a.flag("double-buffer"));
        assert!(!a.flag("verbose"));
        assert_eq!(a.usize("missing", 7), 7);
    }

    #[test]
    fn obs_spec_parses_trace_knobs() {
        assert_eq!(ObsSpec::from_args(&parse("train")).unwrap(), ObsSpec::default());
        let o = ObsSpec::from_args(&parse("train --trace-out /tmp/t --trace-sample 8")).unwrap();
        assert_eq!(o.trace_out.as_deref(), Some("/tmp/t"));
        assert_eq!(o.sample_every, 8);
        assert!(ObsSpec::from_args(&parse("train --trace-sample 0")).is_err());
    }

    #[test]
    fn trailing_flag() {
        let a = parse("--quiet");
        assert!(a.flag("quiet"));
        assert!(a.positional.is_empty());
    }

    #[test]
    fn collective_spec_parse_and_label() {
        assert_eq!(CollectiveSpec::parse("a2a").unwrap(), CollectiveSpec::AllToAll);
        assert_eq!(CollectiveSpec::parse("broadcast").unwrap(), CollectiveSpec::AllToAll);
        assert_eq!(CollectiveSpec::parse("ring").unwrap(), CollectiveSpec::ring());
        assert_eq!(CollectiveSpec::parse("RING:EF").unwrap(), CollectiveSpec::ring_ef());
        assert_eq!(
            CollectiveSpec::parse("ring:raw").unwrap(),
            CollectiveSpec::Ring { recompress: false, error_feedback: false }
        );
        assert_eq!(
            CollectiveSpec::parse("hier").unwrap(),
            CollectiveSpec::Hierarchical { groups: GroupSpec::Contiguous(4) }
        );
        assert_eq!(CollectiveSpec::parse("hier:8").unwrap(), CollectiveSpec::hierarchical(8));
        assert_eq!(
            CollectiveSpec::parse("hier:0,1/2,3").unwrap(),
            CollectiveSpec::Hierarchical {
                groups: GroupSpec::Explicit(vec![vec![0, 1], vec![2, 3]])
            }
        );
        assert!(CollectiveSpec::parse("hier:1").is_err());
        assert!(CollectiveSpec::parse("hier:x").is_err());
        assert!(CollectiveSpec::parse("hier:0,a/2").is_err());
        assert!(CollectiveSpec::parse("mesh").is_err());
        assert_eq!(CollectiveSpec::default(), CollectiveSpec::AllToAll);
        for s in ["a2a", "ring", "ring:ef", "ring:raw", "hier:4", "hier:0,1/2,3"] {
            assert_eq!(CollectiveSpec::parse(s).unwrap().label(), s, "label round-trip");
        }
    }

    #[test]
    fn group_spec_resolution() {
        // Contiguous: the flat knob's semantics, including the final ragged
        // group and the clamp to [1, world].
        assert_eq!(
            GroupSpec::Contiguous(4).resolve(8).unwrap(),
            vec![vec![0, 1, 2, 3], vec![4, 5, 6, 7]]
        );
        assert_eq!(
            GroupSpec::Contiguous(3).resolve(8).unwrap(),
            vec![vec![0, 1, 2], vec![3, 4, 5], vec![6, 7]]
        );
        assert_eq!(GroupSpec::Contiguous(16).resolve(4).unwrap(), vec![vec![0, 1, 2, 3]]);
        // Explicit groups: arbitrary membership, first member leads.
        let gs = GroupSpec::Explicit(vec![vec![2, 0], vec![1, 3]]);
        assert_eq!(gs.resolve(4).unwrap(), vec![vec![2, 0], vec![1, 3]]);
        // Validation: coverage must be exact.
        assert!(GroupSpec::Explicit(vec![vec![0, 1]]).resolve(4).is_err(), "missing ranks");
        assert!(
            GroupSpec::Explicit(vec![vec![0, 1], vec![1, 2, 3]]).resolve(4).is_err(),
            "duplicate rank"
        );
        assert!(
            GroupSpec::Explicit(vec![vec![0, 4]]).resolve(2).is_err(),
            "member out of range"
        );
        assert!(GroupSpec::Explicit(vec![vec![0], vec![]]).resolve(1).is_err(), "empty group");
    }

    #[test]
    fn scenario_spec_parse_label_roundtrip() {
        assert_eq!(ScenarioSpec::parse("none").unwrap(), ScenarioSpec::None);
        assert!(ScenarioSpec::default().is_none());
        assert_eq!(
            ScenarioSpec::parse("hetero").unwrap(),
            ScenarioSpec::Hetero { factor: 4.0 }
        );
        assert_eq!(
            ScenarioSpec::parse("straggler").unwrap(),
            ScenarioSpec::Straggler { prob: 0.1, factor: 5.0 }
        );
        assert_eq!(
            ScenarioSpec::parse("drop:2@1").unwrap(),
            ScenarioSpec::Drop { rank: 2, step: 1 }
        );
        assert_eq!(ScenarioSpec::parse("partial:3").unwrap(), ScenarioSpec::Partial { k: 3 });
        assert!(ScenarioSpec::parse("hetero:0.5").is_err());
        assert!(ScenarioSpec::parse("straggler:2:5").is_err());
        assert!(ScenarioSpec::parse("corrupt:1.5").is_err());
        assert!(ScenarioSpec::parse("drop:1").is_err());
        assert!(ScenarioSpec::parse("partial:0").is_err());
        assert!(ScenarioSpec::parse("meteor").is_err());
        for s in
            ["none", "hetero:4", "straggler:0.1:5", "corrupt:0.05", "drop:1@2", "partial:2"]
        {
            assert_eq!(ScenarioSpec::parse(s).unwrap().label(), s, "label round-trip");
        }
    }

    #[test]
    fn scenario_participation_schedule() {
        let part = ScenarioSpec::Partial { k: 2 };
        let a = part.participants(4, 7, 0);
        assert_eq!(a.len(), 2);
        assert_eq!(a, part.participants(4, 7, 0), "schedule is a pure function");
        // Across many steps every rank participates at least once and the
        // schedule actually varies.
        let mut seen = [false; 4];
        let mut varied = false;
        for step in 0..64 {
            let p = part.participants(4, 7, step);
            assert_eq!(p.len(), 2);
            assert!(p.windows(2).all(|w| w[0] < w[1]), "sorted, deduped");
            varied |= p != a;
            for &r in &p {
                seen[r] = true;
            }
        }
        assert!(seen.iter().all(|&s| s), "every rank gets sampled eventually");
        assert!(varied, "the sample changes across steps");
        let drop = ScenarioSpec::Drop { rank: 1, step: 2 };
        assert_eq!(drop.participants(4, 0, 1), vec![0, 1, 2, 3]);
        assert_eq!(drop.participants(4, 0, 2), vec![0, 2, 3]);
        assert_eq!(ScenarioSpec::None.participants(3, 0, 9), vec![0, 1, 2]);
    }

    #[test]
    fn transport_spec_parse_and_label() {
        assert_eq!(TransportSpec::parse("sim").unwrap(), TransportSpec::Sim);
        assert_eq!(TransportSpec::parse("SIM").unwrap(), TransportSpec::Sim);
        assert!(TransportSpec::default().is_sim());
        assert_eq!(
            TransportSpec::parse("tcp:127.0.0.1:29500").unwrap(),
            TransportSpec::Tcp { addr: "127.0.0.1:29500".into() }
        );
        // bad TCP shapes: no port, non-numeric port, empty host
        assert!(TransportSpec::parse("tcp:localhost").is_err());
        assert!(TransportSpec::parse("tcp:host:port").is_err());
        assert!(TransportSpec::parse("tcp::123").is_err());
        assert!(TransportSpec::parse("uds:").is_err());
        assert!(TransportSpec::parse("mpi:whatever").is_err());
        #[cfg(unix)]
        {
            let t = TransportSpec::parse("uds:/tmp/qsgd.sock").unwrap();
            assert_eq!(t, TransportSpec::Uds { path: "/tmp/qsgd.sock".into() });
            assert!(!t.is_sim());
        }
        for s in ["sim", "tcp:127.0.0.1:29500"] {
            assert_eq!(TransportSpec::parse(s).unwrap().label(), s, "label round-trip");
        }
    }

    #[test]
    fn codec_options_directory_rule() {
        let d = CodecOptions::default();
        let min = crate::coding::gradient::DIRECTORY_MIN_COORDS;
        assert!(!d.use_directory(min - 1, 512));
        assert!(d.use_directory(min, 512));
        // a single bucket has nothing to parallelize
        assert!(!d.use_directory(min, usize::MAX));
        // explicit override wins in both directions
        let on = CodecOptions { directory: Some(true), ..CodecOptions::default() };
        assert!(on.use_directory(16, 4));
        let off = CodecOptions { directory: Some(false), ..CodecOptions::default() };
        assert!(!off.use_directory(min * 2, 512));
        // a custom threshold moves the boundary
        let low = CodecOptions { directory_min_coords: 100, ..CodecOptions::default() };
        assert!(low.use_directory(100, 10));
        assert!(!low.use_directory(99, 10));
        assert_eq!(CodecOptions::serial().decode_threads(), 1);
        assert!(d.decode_threads() >= 1);
    }
}
