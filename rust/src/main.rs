//! `qsgd` — CLI for the QSGD reproduction.
//!
//! Subcommands:
//!   info                       — artifacts + runtime smoke info
//!   train                      — synchronous data-parallel training
//!   simulate                   — epoch-time breakdown for a paper network
//!   svrg                       — QSVRG linear-convergence run
//!   async                      — asynchronous parameter-server run
//!   ps-serve                   — sharded parameter-server service over sockets
//!   ps-bench                   — heavy-traffic client harness against the service
//!   validate                   — quick Lemma 3.1 / Thm 3.2 empirical checks

use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use qsgd::config::{Args, CollectiveSpec, ObsSpec, ScenarioSpec, TransportSpec};
use qsgd::coordinator::epoch_sim::{simulate_epoch, EpochArm};
use qsgd::coordinator::sources::{ConvexSource, GradSource, RuntimeSource, Workload};
use qsgd::coordinator::sync::{SyncConfig, SyncTrainer};
use qsgd::coordinator::{async_ps, svrg, CompressorSpec};
use qsgd::data::{ClassifyData, LogisticProblem, QuadraticProblem, TokenCorpus};
use qsgd::metrics::Table;
use qsgd::models::layout::QuantPlan;
use qsgd::models::{zoo, CostModel};
use qsgd::runtime::Runtime;
use qsgd::simnet::{Preset, SimNet};
use qsgd::transport::{
    train_rank, DistTrainConfig, Endpoint, FaultInjector, Mesh, MeshConfig, RecoveryOptions,
    SocketExchange,
};
use qsgd::util::stats;

fn main() {
    let args = Args::from_env();
    let cmd = args.positional.first().map(String::as_str).unwrap_or("help");
    let r = match cmd {
        "info" => cmd_info(&args),
        "train" => cmd_train(&args),
        "simulate" => cmd_simulate(&args),
        "svrg" => cmd_svrg(&args),
        "async" => cmd_async(&args),
        "ps-serve" => cmd_ps_serve(&args),
        "ps-bench" => cmd_ps_bench(&args),
        "validate" => cmd_validate(&args),
        // Internal: one rank of a raw collective exchange over sockets —
        // spawned by the transport_e2e determinism goldens.
        "exchange-worker" => cmd_exchange_worker(&args),
        _ => {
            print_help();
            Ok(())
        }
    };
    if let Err(e) = r {
        eprintln!("error: {e:#}");
        if qsgd::obs::enabled() {
            // Last-gasp diagnostics: the flight recorder's recent-event
            // window plus whatever spans the rings still hold.
            qsgd::obs::flight::dump("fatal: command errored");
            let _ = qsgd::obs::export_traces();
        }
        std::process::exit(1);
    }
    if let Err(e) = qsgd::obs::export_traces() {
        eprintln!("warning: exporting traces failed: {e:#}");
    }
}

fn print_help() {
    println!(
        "qsgd — QSGD (NIPS'17) reproduction\n\n\
         USAGE: qsgd <info|train|simulate|svrg|async|validate> [--flags]\n\n\
         train    --model <logreg|mlp|tfm|quadratic|logreg-native> \\\n\
                  --compressor <fp32|qsgdN[:bucket]|nuqsgdN[:bucket]|1bit|terngrad> \\\n\
                  --collective <a2a|ring|ring:ef|ring:raw|hier[:G]|hier:0,1/2,3> \\\n\
                  --workers K --steps N --lr F --seed S [--eval-every N] \\\n\
                  [--scenario none|hetero[:F]|straggler[:P:F]|corrupt[:P]|drop:R@S|partial:K] \\\n\
                  [--transport sim|tcp:HOST:PORT|uds:PATH]   # sockets: K real\n\
                  #  processes (spawned automatically; --rank R joins as one\n\
                  #  rank instead). Native models only; see README.\n\
                  # socket fault injection: [--recover] [--die-at-step S]\n\
                  #  [--corrupt-prob P] [--drop-prob P] [--fault-delay-ms MS]\n\
                  #  [--fault-seed S] [--max-faults N]\n\
                  # pipelined exchange (same bits, overlapped wall clock):\n\
                  #  [--overlap on|off]\n\
                  # observability (all subcommands): [--trace-out DIR]\n\
                  #  [--trace-sample N] — per-rank Chrome traces, JSONL\n\
                  #  span logs, metrics dumps, flight-recorder dumps\n\
         simulate --network <alexnet|vgg19|resnet50|resnet152|resnet110|bn-inception|lstm>\n\
                  --gpus K [--preset k80|10gbe|nvlink] [--collective <...>]\n\
                  [--scenario <...>] [--overlap-fraction F]\n\
         svrg     --processors K --epochs P [--exact]\n\
         async    --workers K --updates N --compressor <...> [--shards S]\n\
         ps-serve --transport <tcp:HOST:PORT|uds:PATH> --shards S --dim N \\\n\
                  [--compressor <...>] [--lr F] [--seed S] [--staleness T]\n\
                  [--queue-depth D] [--duration-s F]\n\
         ps-bench --shards S --dim N --clients N --threads M --ops N \\\n\
                  [--push-pull F] [--zipf T] [--burst B] [--staleness T]\n\
                  [--queue-depth D] [--transport sim|tcp:...|uds:PATH]\n\
         validate [--n N] [--trials T]"
    );
}

fn cmd_info(_args: &Args) -> Result<()> {
    let rt = Runtime::from_default_dir()?;
    println!("platform: {}", rt.platform());
    println!("artifacts ({}):", rt.manifest().artifacts.len());
    for (name, a) in &rt.manifest().artifacts {
        println!(
            "  {name:<14} params={:<9} inputs={} outputs={} {}",
            a.params.map(|p| p.to_string()).unwrap_or_else(|| "-".into()),
            a.inputs.len(),
            a.outputs.len(),
            a.quant
                .map(|q| format!("fused-quant s={} bucket={}", q.s, q.bucket))
                .unwrap_or_default()
        );
    }
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let transport = TransportSpec::parse(&args.string("transport", "sim"))?;
    if !transport.is_sim() {
        return cmd_train_dist(args, &transport);
    }
    ObsSpec::from_args(args)?.install(0);
    let model = args.string("model", "mlp");
    let spec = CompressorSpec::parse(&args.string("compressor", "qsgd4"))?;
    let collective = CollectiveSpec::parse(&args.string("collective", "a2a"))?;
    let workers = args.usize("workers", 4);
    let steps = args.usize("steps", 200);
    let lr = args.f32("lr", 0.1);
    let seed = args.u64("seed", 0);

    let mut cfg = SyncConfig::quick(workers, steps, spec, lr);
    cfg.collective = collective;
    cfg.seed = seed;
    cfg.eval_every = args.usize("eval-every", 25);
    cfg.log_every = args.usize("log-every", 10);
    cfg.scenario = ScenarioSpec::parse(&args.string("scenario", "none"))?;

    let run = |cfg: SyncConfig, src: &mut dyn GradSource| -> Result<()> {
        let label = cfg.compressor.label();
        let col = cfg.collective.label();
        let db = cfg.double_buffer;
        let mut trainer = SyncTrainer::new(cfg);
        let res = trainer.run(src)?;
        println!("== {} via {} on {} ==", label, col, src.name());
        println!("loss: {}", res.loss.sparkline(12));
        if !res.eval.points.is_empty() {
            println!("eval: {}", res.eval.sparkline(12));
        }
        println!(
            "virtual time: {} (comm {:.0}%), wire: {} msgs, {} payload, {:.2}x vs fp32, {:.2} bits/coord",
            stats::fmt_duration(res.virtual_time(db).secs()),
            res.breakdown.comm_fraction() * 100.0,
            res.wire.messages,
            stats::fmt_bytes(res.wire.payload_bytes as f64),
            res.wire.compression_ratio(),
            res.wire.bits_per_coordinate(),
        );
        if res.recompressions > 0 {
            println!(
                "hops: {}, recompressions: {}, cumulative recompression err²: {:.3e}",
                res.hops, res.recompressions, res.recompress_err_sq
            );
        }
        if res.faults.any() {
            let f = &res.faults;
            println!(
                "faults: {} straggled hops, {} corrupt frames, {} dead workers, \
                 {} renormalized steps",
                f.straggler_hops, f.corrupt_frames, f.dead_workers, f.renormalized_steps
            );
        }
        let mut m = qsgd::obs::MetricSet::new();
        res.wire.export(&mut m);
        res.faults.export(&mut m);
        res.wall.export(&mut m);
        m.counter("train.steps", res.breakdown.steps as u64);
        qsgd::obs::export_metrics(&m)?;
        Ok(())
    };

    match model.as_str() {
        "quadratic" => {
            let p = QuadraticProblem::generate(512, 256, 1e-3, 0.05, seed);
            run(cfg, &mut ConvexSource::new(p, 8, seed))
        }
        "logreg-native" => {
            let p = LogisticProblem::generate(512, 256, 1e-3, seed);
            run(cfg, &mut ConvexSource::new(p, 8, seed))
        }
        "logreg" | "mlp" | "tfm" => {
            let rt = Runtime::from_default_dir()?;
            let (artifact, workload) = runtime_workload(&rt, &model, seed)?;
            let art = rt.manifest().get(&artifact)?;
            if let Some(layout) = &art.layout {
                cfg.plan = Some(QuantPlan::quantize_all(layout));
            }
            let mut src = RuntimeSource::new(&rt, &artifact, workload)?;
            run(cfg, &mut src)
        }
        other => anyhow::bail!("unknown model '{other}'"),
    }
}

fn transport_endpoint(t: &TransportSpec) -> Result<Endpoint> {
    match t {
        TransportSpec::Sim => anyhow::bail!("sim transport has no socket endpoint"),
        TransportSpec::Tcp { addr } => Ok(Endpoint::Tcp(addr.clone())),
        TransportSpec::Uds { path } => {
            #[cfg(unix)]
            return Ok(Endpoint::Uds(path.into()));
            #[cfg(not(unix))]
            {
                let _ = path;
                anyhow::bail!("uds transport is only available on unix")
            }
        }
    }
}

/// `train --transport tcp:…|uds:…`: real multi-process training. Without
/// `--rank` this process is the launcher — it spawns `--workers` copies of
/// itself (same argv plus `--rank R`) and waits for all of them; with
/// `--rank` it joins the mesh as that rank and runs its share.
fn cmd_train_dist(args: &Args, transport: &TransportSpec) -> Result<()> {
    let world = args.usize("workers", 4);
    anyhow::ensure!(world >= 1, "--workers must be at least 1");
    if let Some(r) = args.get("rank") {
        let rank: usize = r.parse().map_err(|_| anyhow::anyhow!("bad --rank '{r}'"))?;
        return train_dist_rank(args, transport, rank, world);
    }

    let exe = std::env::current_exe().context("locating own executable")?;
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let mut children = Vec::with_capacity(world);
    for r in 0..world {
        let mut cmd = std::process::Command::new(&exe);
        cmd.args(&argv).arg("--rank").arg(r.to_string());
        if r != 0 {
            // Keep the console readable: replica output is identical by
            // construction, so rank 0 speaks for the run.
            cmd.stdout(std::process::Stdio::null());
        }
        children.push(cmd.spawn().with_context(|| format!("spawning rank {r}"))?);
    }

    let budget = Duration::from_secs(args.u64("spawn-timeout-s", 600));
    let deadline = Instant::now() + budget;
    let mut statuses: Vec<Option<std::process::ExitStatus>> = vec![None; world];
    loop {
        let mut pending = false;
        for (i, ch) in children.iter_mut().enumerate() {
            if statuses[i].is_none() {
                match ch.try_wait().with_context(|| format!("waiting for rank {i}"))? {
                    Some(st) => statuses[i] = Some(st),
                    None => pending = true,
                }
            }
        }
        if !pending {
            break;
        }
        if Instant::now() >= deadline {
            for ch in children.iter_mut() {
                let _ = ch.kill();
            }
            anyhow::bail!(
                "multi-process train timed out after {}s (raise --spawn-timeout-s?)",
                budget.as_secs()
            );
        }
        std::thread::sleep(Duration::from_millis(20));
    }
    #[cfg(unix)]
    if let TransportSpec::Uds { path } = transport {
        qsgd::transport::net::cleanup_uds(std::path::Path::new(path), world);
    }
    for (r, st) in statuses.iter().enumerate() {
        let st = st.expect("loop exits only when all statuses are filled");
        anyhow::ensure!(st.success(), "rank {r} exited with {st}");
    }
    Ok(())
}

/// Seeded outbound fault injector from the CLI knobs (`--corrupt-prob`,
/// `--drop-prob`, `--fault-delay-ms`, `--fault-seed`, `--max-faults`).
/// Per-rank salting keeps schedules independent across ranks while staying
/// pinned by `--fault-seed`.
fn fault_injector_from(args: &Args, rank: usize) -> Result<Option<FaultInjector>> {
    let corrupt = args.f64("corrupt-prob", 0.0);
    let drop = args.f64("drop-prob", 0.0);
    let delay = args.u64("fault-delay-ms", 0);
    if corrupt <= 0.0 && drop <= 0.0 && delay == 0 {
        return Ok(None);
    }
    let seed =
        args.u64("fault-seed", 0xFA17) ^ (rank as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
    let mut inj = FaultInjector::new(seed).with_corruption(corrupt).with_drops(drop);
    if delay > 0 {
        inj = inj.with_delay(Duration::from_millis(delay));
    }
    if let Some(m) = args.get("max-faults") {
        let m: u64 = m.parse().map_err(|_| anyhow::anyhow!("bad --max-faults '{m}'"))?;
        inj = inj.with_max_faults(m);
    }
    Ok(Some(inj))
}

/// One rank's share of a socket-transport training run.
fn train_dist_rank(
    args: &Args,
    transport: &TransportSpec,
    rank: usize,
    world: usize,
) -> Result<()> {
    ObsSpec::from_args(args)?.install(rank as u32);
    let model = args.string("model", "quadratic");
    let spec = CompressorSpec::parse(&args.string("compressor", "qsgd4"))?;
    let collective = CollectiveSpec::parse(&args.string("collective", "a2a"))?;
    let steps = args.usize("steps", 200);
    let lr = args.f32("lr", 0.1);
    let seed = args.u64("seed", 0);

    let mut cfg = DistTrainConfig::quick(world, steps, spec, lr);
    cfg.collective = collective;
    cfg.seed = seed;
    cfg.eval_every = args.usize("eval-every", 25);
    cfg.log_every = args.usize("log-every", 10);
    cfg.recovery = RecoveryOptions { enabled: args.flag("recover") };
    cfg.pipeline = match args.string("overlap", "off").as_str() {
        "on" => true,
        "off" => false,
        other => anyhow::bail!("bad --overlap '{other}' (expected on|off)"),
    };
    cfg.die_at_step = match args.get("die-at-step") {
        Some(s) => {
            Some(s.parse().map_err(|_| anyhow::anyhow!("bad --die-at-step '{s}'"))?)
        }
        None => None,
    };

    // Every rank needs its own gradient source; the runtime-artifact models
    // would mean one PJRT instance per process, which this path does not
    // attempt yet — the native convex models cover the transport's job
    // (checking modeled α–β time against measured wall-clock).
    let mut src: Box<dyn GradSource> = match model.as_str() {
        "quadratic" => {
            let p = QuadraticProblem::generate(512, 256, 1e-3, 0.05, seed);
            Box::new(ConvexSource::new(p, 8, seed))
        }
        "logreg-native" => {
            let p = LogisticProblem::generate(512, 256, 1e-3, seed);
            Box::new(ConvexSource::new(p, 8, seed))
        }
        other => anyhow::bail!(
            "--transport {} supports the native models (quadratic|logreg-native), got '{other}'",
            transport.label()
        ),
    };

    let ep = transport_endpoint(transport)?;
    let mesh_cfg = MeshConfig {
        rank,
        world,
        io_timeout: Duration::from_millis(args.u64("io-timeout-ms", 30_000)),
        connect_timeout: Duration::from_millis(args.u64("connect-timeout-ms", 60_000)),
    };
    let mut mesh = Mesh::connect(&ep, &mesh_cfg)
        .with_context(|| format!("rank {rank}: connecting the {} mesh", transport.label()))?;
    if let Some(inj) = fault_injector_from(args, rank)? {
        mesh.set_fault_injector(inj);
    }
    let res = train_rank(&cfg, mesh, src.as_mut())?;

    println!(
        "== rank {rank}/{world}: {} via {} over {} on {} ==",
        res.label,
        res.collective,
        transport.label(),
        src.name()
    );
    println!("loss: {}", res.loss.sparkline(12));
    if !res.eval.points.is_empty() {
        println!("eval: {}", res.eval.sparkline(12));
    }
    println!(
        "wall: {:.3}s total (encode {:.3}s, transfer {:.3}s, decode {:.3}s) vs modeled comm {}",
        res.wall.total_s(),
        res.wall.encode_s,
        res.wall.transfer_s,
        res.wall.decode_s,
        stats::fmt_duration(res.breakdown.communication().secs()),
    );
    println!(
        "wire (this rank): {} msgs, {} payload, {:.2}x vs fp32, {:.2} bits/coord",
        res.wire.messages,
        stats::fmt_bytes(res.wire.payload_bytes as f64),
        res.wire.compression_ratio(),
        res.wire.bits_per_coordinate(),
    );
    if res.recompressions > 0 {
        println!(
            "hops: {}, recompressions: {}, cumulative recompression err²: {:.3e}",
            res.hops, res.recompressions, res.recompress_err_sq
        );
    }
    if res.faults.any() {
        let f = &res.faults;
        println!(
            "faults: {} corrupt frames, {} re-requested, {} resends served, \
             {} dead workers, {} renormalized steps",
            f.corrupt_frames, f.rerequests, f.resends_served, f.dead_workers,
            f.renormalized_steps
        );
    }
    let mut m = qsgd::obs::MetricSet::new();
    res.wire.export(&mut m);
    res.faults.export(&mut m);
    res.wall.export(&mut m);
    m.counter("train.steps", res.breakdown.steps as u64);
    m.counter("exchange.hops", res.hops as u64);
    qsgd::obs::export_metrics(&m)?;
    Ok(())
}

/// Internal subcommand behind the `transport_e2e` goldens: join a K-process
/// mesh, run `--steps` collective exchanges of a fixed seeded gradient, and
/// write the decoded mean (raw little-endian f32s) to `--out`. The test
/// compares those bytes against the in-process simnet golden bit for bit.
fn cmd_exchange_worker(args: &Args) -> Result<()> {
    use qsgd::util::rng::{self, Xoshiro256};

    let transport = TransportSpec::parse(&args.string("transport", "sim"))?;
    let rank = args.usize("rank", 0);
    let world = args.usize("world", 1);
    ObsSpec::from_args(args)?.install(rank as u32);
    let collective = CollectiveSpec::parse(&args.string("collective", "a2a"))?;
    let spec = CompressorSpec::parse(&args.string("compressor", "qsgd4"))?;
    let n = args.usize("n", 8192);
    let steps = args.usize("steps", 1);
    let seed = args.u64("seed", 7);
    let gseed = args.u64("gseed", 99);

    let ep = transport_endpoint(&transport)?;
    let mesh_cfg = MeshConfig {
        rank,
        world,
        io_timeout: Duration::from_millis(args.u64("io-timeout-ms", 20_000)),
        connect_timeout: Duration::from_millis(args.u64("connect-timeout-ms", 30_000)),
    };
    let die_at_step = match args.get("die-at-step") {
        Some(s) => {
            Some(s.parse().map_err(|_| anyhow::anyhow!("bad --die-at-step '{s}'"))?)
        }
        None => None,
    };

    let mut mesh = Mesh::connect(&ep, &mesh_cfg)
        .with_context(|| format!("rank {rank}: connecting the exchange mesh"))?;
    if let Some(inj) = fault_injector_from(args, rank)? {
        mesh.set_fault_injector(inj);
    }
    let mut ex = SocketExchange::new(&collective, spec.codec(), mesh, seed)?;
    if args.flag("recover") {
        ex = ex.with_recovery(RecoveryOptions::on())?;
    }
    match args.string("overlap", "off").as_str() {
        "on" => ex = ex.with_pipelining(true)?,
        "off" => {}
        other => anyhow::bail!("bad --overlap '{other}' (expected on|off)"),
    }

    // Same gradient every step (the per-step variation under test is the
    // sessions' RNG streams advancing), deterministic in (gseed, rank).
    let grad = rng::normal_vec(&mut Xoshiro256::stream(gseed, rank as u64), n);
    let mut mean: Vec<f32> = Vec::new();
    let mut total = qsgd::transport::DistStats::default();
    for step in 0..steps {
        if die_at_step == Some(step) {
            anyhow::bail!("rank {rank}: dying at step {step} (--die-at-step churn injection)");
        }
        let s = ex.exchange(&grad, &mut mean)?;
        total.add(&s);
    }

    if let Some(path) = args.get("out") {
        let mut bytes = Vec::with_capacity(mean.len() * 4);
        for &x in &mean {
            bytes.extend_from_slice(&x.to_le_bytes());
        }
        std::fs::write(path, &bytes)
            .with_context(|| format!("writing decoded mean to {path}"))?;
    }
    println!(
        "rank {rank}/{world}: {} exchanges of n={n} via {}; {} hops, \
         wall {:.3}s (encode {:.3}s, transfer {:.3}s, decode {:.3}s), {} payload out",
        steps,
        ex.name(),
        total.hops,
        total.wall.total_s(),
        total.wall.encode_s,
        total.wall.transfer_s,
        total.wall.decode_s,
        stats::fmt_bytes(total.wire.payload_bytes as f64),
    );
    let occ = &total.occupancy;
    if occ.total_s() > 0.0 {
        println!(
            "rank {rank} occupancy: io-blocked {:.3}s, codec {:.3}s, idle {:.3}s \
             (of {:.3}s in exchanges)",
            occ.io_blocked_s,
            occ.codec_s,
            occ.idle_s,
            occ.total_s(),
        );
    }
    if total.faults.any() {
        let f = &total.faults;
        println!(
            "rank {rank} faults: {} corrupt, {} re-requested, {} resends served, \
             {} dead, {} renormalized steps",
            f.corrupt_frames, f.rerequests, f.resends_served, f.dead_workers,
            f.renormalized_steps
        );
    }
    let mut m = qsgd::obs::MetricSet::new();
    total.export(&mut m);
    qsgd::obs::export_metrics(&m)?;
    Ok(())
}

/// Map a model name to (artifact, workload) built from the manifest shapes.
fn runtime_workload(rt: &Runtime, model: &str, seed: u64) -> Result<(String, Workload)> {
    match model {
        "mlp" => {
            let art = rt.manifest().get("mlp_grad")?;
            let dim = art.inputs[1].shape[1];
            let batch = art.batch.unwrap_or(64);
            Ok((
                "mlp_grad".into(),
                Workload::Classify { data: ClassifyData::mnist_like(dim, 10, seed), batch },
            ))
        }
        "tfm" => {
            let art = rt.manifest().get("tfm_grad")?;
            let batch = art.batch.unwrap_or(8);
            let seq_plus_1 = art.inputs[1].shape[1];
            Ok((
                "tfm_grad".into(),
                Workload::Lm { corpus: TokenCorpus::new(512, seed), batch, seq_plus_1 },
            ))
        }
        _ => anyhow::bail!("no runtime workload for model '{model}' (use mlp|tfm)"),
    }
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let name = args.string("network", "alexnet");
    let net = zoo::by_name(&name).ok_or_else(|| anyhow::anyhow!("unknown network '{name}'"))?;
    let gpus = args.usize("gpus", 8);
    let preset: Preset =
        args.string("preset", "k80").parse().map_err(|e: String| anyhow::anyhow!(e))?;
    let scenario = ScenarioSpec::parse(&args.string("scenario", "none"))?;
    // Scenario shapes the interconnect for *every* arm (fp32 baseline
    // included), so speedups stay apples-to-apples under faults.
    let simnet = scenario.apply_simnet(SimNet::preset(gpus, preset), args.u64("seed", 0));
    let cost = CostModel::k80();
    let collective = CollectiveSpec::parse(&args.string("collective", "a2a"))?;

    // Schedule-derived overlapped epoch time (per-layer bucket readiness
    // from the network layout) at the requested overlap fraction φ.
    let overlap: Option<f64> = match args.get("overlap-fraction") {
        Some(s) => Some(
            s.parse::<f64>()
                .map_err(|_| anyhow::anyhow!("bad --overlap-fraction '{s}'"))?,
        ),
        None => None,
    };
    let mut headers = vec!["arm", "via", "epoch", "comm%", "msg", "B/wkr", "speedup"];
    if overlap.is_some() {
        headers.insert(3, "overlap");
    }
    let mut table = Table::new(&headers);
    let fp = simulate_epoch(&net, gpus, &EpochArm::fp32(), &simnet, &cost, 2, 0);
    let arms = [
        EpochArm::fp32(),
        EpochArm::qsgd(2, 64).with_collective(collective.clone()),
        EpochArm::qsgd(4, 512).with_collective(collective.clone()),
        EpochArm::qsgd(8, 512).with_collective(collective.clone()),
        EpochArm::onebit().with_collective(collective.clone()),
        EpochArm::fp32_allreduce(),
    ];
    for arm in arms {
        let r = simulate_epoch(&net, gpus, &arm, &simnet, &cost, 2, 0);
        let label =
            if arm.dense_transport { format!("{} (ring)", r.arm) } else { r.arm.clone() };
        let mut row = vec![
            label,
            r.collective.clone(),
            stats::fmt_duration(r.epoch_time()),
            format!("{:.0}%", r.breakdown.comm_fraction() * 100.0),
            stats::fmt_bytes(r.message_bytes as f64),
            stats::fmt_bytes(r.bytes_per_worker),
            format!("{:.2}x", fp.epoch_time() / r.epoch_time()),
        ];
        if let Some(phi) = overlap {
            row.insert(3, stats::fmt_duration(r.epoch_time_overlapped(phi)));
        }
        table.row(&row);
    }
    println!(
        "{} on {gpus} GPUs ({} params, {:.1}% quantized, {} steps/epoch):",
        net.name,
        net.params(),
        fp.quantized_fraction * 100.0,
        fp.steps
    );
    if !scenario.is_none() {
        let (straggled, corrupted) = simnet.fault_counts();
        println!(
            "scenario {}: {} straggled ops, {} corrupted ops across all arms",
            scenario.label(),
            straggled,
            corrupted
        );
    }
    table.print();
    if let Some(phi) = overlap {
        println!(
            "overlap: schedule-derived epoch time at fraction {phi:.2} \
             (per-layer bucket readiness from the {} layout)",
            net.name
        );
    }
    Ok(())
}

fn cmd_svrg(args: &Args) -> Result<()> {
    let processors = args.usize("processors", 4);
    let epochs = args.usize("epochs", 8);
    let obj = LogisticProblem::generate(256, 64, 0.05, args.u64("seed", 0));
    let f_star = svrg::solve_f_star(&obj, 4000);
    let cfg = svrg::SvrgConfig {
        processors,
        epochs,
        iters: None,
        eta: None,
        seed: args.u64("seed", 0),
        quantize: !args.flag("exact"),
    };
    let r = svrg::run(&cfg, &obj, f_star)?;
    println!("QSVRG (quantize={}) gap per epoch:", cfg.quantize);
    for (e, g) in &r.gap.points {
        println!("  epoch {e:>2}: {g:.3e}");
    }
    println!(
        "bits/processor/epoch bound: {:.0}; measured total payload {}",
        r.bits_bound_per_epoch,
        stats::fmt_bytes(r.wire.payload_bytes as f64)
    );
    Ok(())
}

fn cmd_async(args: &Args) -> Result<()> {
    let workers = args.usize("workers", 4);
    let updates = args.usize("updates", 500);
    let spec = CompressorSpec::parse(&args.string("compressor", "qsgd4"))?;
    let cfg = async_ps::AsyncConfig {
        workers,
        updates,
        compressor: spec,
        lr: args.f32("lr", 0.02),
        seed: args.u64("seed", 0),
        net: SimNet::new(
            workers,
            qsgd::simnet::Link::new(6e9, 50e-6),
            qsgd::simnet::Topology::Star,
        ),
        cost: CostModel::k80(),
        speed: vec![],
        log_every: args.usize("log-every", 25),
    };
    let p = QuadraticProblem::generate(512, 256, 1e-3, 0.05, cfg.seed);
    let mut src = ConvexSource::new(p, 8, cfg.seed);
    // S=1 runs the legacy single-loop server; S>1 routes the same event
    // schedule through the sharded service (bit-identical at S=1, pinned by
    // rust/tests/ps_service.rs).
    let shards = args.usize("shards", 1);
    let r = if shards <= 1 {
        async_ps::run(&cfg, &mut src)?
    } else {
        qsgd::ps::run_async(&cfg, &mut src, shards)?
    };
    let plural = if shards == 1 { "" } else { "s" };
    println!("async QSGD ({shards} shard{plural}): loss {}", r.loss.sparkline(12));
    println!(
        "staleness max={} mean={:.2}, vtime {}, payload {}",
        r.max_staleness,
        r.mean_staleness,
        stats::fmt_duration(r.vtime),
        stats::fmt_bytes(r.wire.payload_bytes as f64)
    );
    Ok(())
}

/// Shared `ps-serve` / `ps-bench` service construction: a uniform shard map
/// over `--dim` coordinates with the service knobs from the flag set.
fn ps_service_from_args(args: &Args) -> Result<qsgd::ps::Service> {
    let dim = args.usize("dim", 1 << 16);
    let shards = args.usize("shards", 4);
    let spec = CompressorSpec::parse(&args.string("compressor", "qsgd4"))?;
    let staleness = match args.get("staleness") {
        Some(s) => Some(s.parse::<u64>().context("parsing --staleness")?),
        None => None,
    };
    let cfg = qsgd::ps::ServiceConfig {
        compressor: spec,
        lr: args.f32("lr", 0.05),
        seed: args.u64("seed", 0),
        staleness,
        queue_depth: args.usize("queue-depth", 64),
    };
    let map = qsgd::ps::ShardMap::uniform(dim, shards)?;
    Ok(qsgd::ps::Service::new(map, &cfg))
}

fn cmd_ps_serve(args: &Args) -> Result<()> {
    ObsSpec::from_args(args)?.install(0);
    let transport = TransportSpec::parse(&args.string("transport", "uds:/tmp/qsgd-ps.sock"))?;
    let ep = transport_endpoint(&transport)?;
    let service = std::sync::Arc::new(ps_service_from_args(args)?);
    let handle = qsgd::ps::serve(&ep, service.clone())?;
    let dur = args.f64("duration-s", 10.0);
    println!(
        "ps-serve: {} shards × {} coords on {} for {dur:.1}s",
        service.num_shards(),
        service.map().total_len(),
        handle.endpoint().describe()
    );
    std::thread::sleep(Duration::from_secs_f64(dur.max(0.0)));
    handle.shutdown();
    println!("service: {}", service.metrics().summary());
    let mut m = qsgd::obs::MetricSet::new();
    service.metrics().export(&mut m);
    qsgd::obs::export_metrics(&m)?;
    Ok(())
}

fn cmd_ps_bench(args: &Args) -> Result<()> {
    ObsSpec::from_args(args)?.install(0);
    let service = std::sync::Arc::new(ps_service_from_args(args)?);
    let tcfg = qsgd::ps::TrafficConfig {
        clients: args.usize("clients", 16),
        threads: args.usize("threads", 4),
        ops: args.usize("ops", 20_000),
        push_fraction: args.f64("push-pull", 0.8),
        zipf: args.f64("zipf", 1.0),
        burst: args.usize("burst", 8),
        seed: args.u64("seed", 1),
    };
    let transport = TransportSpec::parse(&args.string("transport", "sim"))?;
    let rep = if transport.is_sim() {
        qsgd::ps::run_traffic(&service, qsgd::ps::Target::InProcess, &tcfg)?
    } else {
        let handle = qsgd::ps::serve(&transport_endpoint(&transport)?, service.clone())?;
        let bound = handle.endpoint().clone();
        let rep = qsgd::ps::run_traffic(&service, qsgd::ps::Target::Socket(&bound), &tcfg)?;
        handle.shutdown();
        rep
    };
    println!("ps-bench [{}]: {}", transport.label(), rep.summary());
    println!("service: {}", service.metrics().summary());
    let mut m = qsgd::obs::MetricSet::new();
    service.metrics().export(&mut m);
    qsgd::obs::export_metrics(&m)?;
    Ok(())
}

fn cmd_validate(args: &Args) -> Result<()> {
    use qsgd::coding::gradient as gcode;
    use qsgd::quant::stochastic;
    use qsgd::util::rng::{self, Xoshiro256};

    let n = args.usize("n", 4096);
    let trials = args.usize("trials", 50);
    let mut rng = Xoshiro256::from_u64(args.u64("seed", 0));
    let v = rng::normal_vec(&mut rng, n);
    let vnorm2: f64 = v.iter().map(|&x| (x as f64).powi(2)).sum();

    let mut table =
        Table::new(&["s", "var blowup", "bound", "E nnz", "s(s+√n)", "bits", "Thm3.2/C3.3"]);
    for s in [1u32, 2, 4, 16, (n as f64).sqrt() as u32] {
        let (mut var, mut nnz, mut bits) = (0.0f64, 0.0f64, 0.0f64);
        for _ in 0..trials {
            let q = stochastic::quantize_paper(&v, s, &mut rng);
            let d = q.dequantize();
            var += v.iter().zip(&d).map(|(&a, &b)| ((a - b) as f64).powi(2)).sum::<f64>();
            nnz += q.nnz() as f64;
            bits += gcode::encode_auto(&q).len() as f64 * 8.0;
        }
        let bound = ((n as f64) / (s as f64).powi(2)).min((n as f64).sqrt() / s as f64);
        let code_bound = if (s as f64) >= (n as f64).sqrt() {
            2.8 * n as f64 + 32.0
        } else {
            gcode::sparse_bits_bound(n, s)
        };
        table.row(&[
            s.to_string(),
            format!("{:.3}", var / trials as f64 / vnorm2),
            format!("{bound:.3}"),
            format!("{:.0}", nnz / trials as f64),
            format!("{:.0}", s as f64 * (s as f64 + (n as f64).sqrt())),
            format!("{:.0}", bits / trials as f64),
            format!("{code_bound:.0}"),
        ]);
    }
    println!("Lemma 3.1 / Theorem 3.2 empirical checks (n={n}, {trials} trials):");
    table.print();
    Ok(())
}
