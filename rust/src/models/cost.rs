//! Computation-time cost model for the epoch simulator.
//!
//! Converts a network's per-sample FLOPs into per-iteration GPU time:
//! `t = (1 + bwd_mult) · flops_fwd · local_batch / effective_flops`.
//! The K80 preset is calibrated so the fp32 communication/computation ratios
//! land where Figure 2 reports them (e.g. >80% comm for 16-GPU AlexNet,
//! ~71% for 2-GPU LSTM); see EXPERIMENTS.md §F2 for the calibration check.
//!
//! Also models the CPU-side quantize+encode cost the paper includes in
//! communication time ("communication time includes time spent compressing
//! and uncompressing gradients") — parameterised as coordinate throughput
//! and refreshed from the `coding_hotpath` bench measurement.

#[derive(Debug, Clone, Copy)]
pub struct CostModel {
    /// Effective sustained FLOPs of one device (not peak). K80 peak is
    /// 4.37 TFLOPs single-precision (one GK210); CNTK-era utilisation on
    /// conv nets is ~30–40%.
    pub device_flops: f64,
    /// Backward pass cost multiple of forward (standard: 2×).
    pub bwd_mult: f64,
    /// Encode throughput of the quantize+code pipeline, coordinates/second
    /// (per device; overlapped across devices). Measured by coding_hotpath;
    /// ~1e9 coords/s on this CPU, K80-era GPU quantize kernels were similar.
    pub encode_coords_per_s: f64,
    /// Decode throughput, coordinates/second per peer message.
    pub decode_coords_per_s: f64,
}

impl Default for CostModel {
    fn default() -> Self {
        Self::k80()
    }
}

impl CostModel {
    pub fn k80() -> Self {
        Self {
            device_flops: 1.5e12,
            bwd_mult: 2.0,
            // The paper quantizes/dequantizes on the GPU (only the entropy
            // code is CPU-side, overlapped); these are K80-kernel-class
            // rates. Our own single-core CPU pipeline throughput is measured
            // by the coding_hotpath bench and reported in EXPERIMENTS.md.
            encode_coords_per_s: 5.0e9,
            decode_coords_per_s: 20.0e9,
        }
    }

    /// One fwd+bwd iteration on a local minibatch.
    pub fn step_compute_s(&self, flops_fwd_per_sample: f64, local_batch: usize) -> f64 {
        (1.0 + self.bwd_mult) * flops_fwd_per_sample * local_batch as f64 / self.device_flops
    }

    /// Quantize+encode one gradient of `n` coordinates.
    pub fn encode_s(&self, n: usize) -> f64 {
        n as f64 / self.encode_coords_per_s
    }

    /// Decode `peers` messages of `n` coordinates each.
    pub fn decode_s(&self, n: usize, peers: usize) -> f64 {
        peers as f64 * n as f64 / self.decode_coords_per_s
    }

    /// Iterations in one epoch at global batch `global_batch`.
    pub fn steps_per_epoch(&self, epoch_samples: usize, global_batch: usize) -> usize {
        epoch_samples.div_ceil(global_batch.max(1))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn step_time_scales_linearly() {
        let c = CostModel::k80();
        let t1 = c.step_compute_s(1e9, 32);
        let t2 = c.step_compute_s(1e9, 64);
        assert!((t2 / t1 - 2.0).abs() < 1e-9);
        // 3·1e9·32 / 1.5e12 = 64 ms
        assert!((t1 - 0.064).abs() < 1e-9);
    }

    #[test]
    fn encode_decode_costs() {
        let c = CostModel::k80();
        assert!((c.encode_s(5_000_000_000) - 1.0).abs() < 1e-9);
        assert!((c.decode_s(1_000_000, 20) - 0.001).abs() < 1e-9);
    }

    #[test]
    fn epoch_steps() {
        let c = CostModel::k80();
        assert_eq!(c.steps_per_epoch(1000, 128), 8);
        assert_eq!(c.steps_per_epoch(1000, 0), 1000);
    }
}
