//! Model metadata: flat-parameter layouts (the contract with the L2 JAX
//! graphs), quantization plans (the paper's <10K-element skip rule and
//! bucket reshaping), shape replicas of the paper's evaluation networks, and
//! the FLOPs cost model that drives the epoch-time simulator.

pub mod cost;
pub mod layout;
pub mod zoo;

pub use cost::CostModel;
pub use layout::{ParamLayout, QuantPlan, TensorInfo};
pub use zoo::NetworkShape;
