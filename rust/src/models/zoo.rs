//! Shape replicas of the paper's evaluation networks (Tables 1–2).
//!
//! We cannot train ImageNet-scale networks on this testbed; what Figure 2 /
//! Table 1 actually depend on is (a) the gradient tensor shapes (which set
//! the bytes-on-wire after quantization+coding) and (b) per-sample FLOPs
//! (which set computation time). Both are replicated here from the
//! architectures' published definitions. Parameter counts land within a few
//! percent of the paper's Table 1 column (62M / 143M / 25M / 60M / 11M / 1M
//! / 13M); FLOPs are the standard published per-image forward costs.

use super::layout::ParamLayout;

/// A network we simulate (not train): layout + cost + workload metadata.
#[derive(Debug, Clone)]
pub struct NetworkShape {
    pub name: &'static str,
    pub layout: ParamLayout,
    /// Forward-pass FLOPs per sample (backward is modelled as 2×).
    pub flops_fwd_per_sample: f64,
    /// Samples per epoch (dataset size).
    pub epoch_samples: usize,
    /// Per-GPU-count minibatch sizes used in the paper (Table 2), indexed by
    /// log2(gpus)−1 for gpus ∈ {2,4,8,16}.
    pub batch_sizes: [usize; 4],
}

impl NetworkShape {
    pub fn params(&self) -> usize {
        self.layout.total_params()
    }

    pub fn batch_for_gpus(&self, gpus: usize) -> usize {
        let idx = match gpus {
            0..=2 => 0,
            3..=4 => 1,
            5..=8 => 2,
            _ => 3,
        };
        self.batch_sizes[idx]
    }
}

const IMAGENET: usize = 1_281_167;
const CIFAR10: usize = 50_000;

fn conv(name: &'static str, cout: usize, cin: usize, k: usize) -> (&'static str, Vec<usize>) {
    (name, vec![cout, cin, k, k])
}

fn fc(name: &'static str, a: usize, b: usize) -> (&'static str, Vec<usize>) {
    (name, vec![a, b])
}

/// AlexNet (Krizhevsky 2012): 62M params, ~0.72 GFLOPs/image forward.
pub fn alexnet() -> NetworkShape {
    let t = vec![
        conv("conv1", 96, 3, 11),
        conv("conv2", 256, 48, 5),
        conv("conv3", 384, 256, 3),
        conv("conv4", 384, 192, 3),
        conv("conv5", 256, 192, 3),
        fc("fc6", 9216, 4096),
        fc("fc7", 4096, 4096),
        fc("fc8", 4096, 1000),
    ];
    NetworkShape {
        name: "AlexNet",
        layout: ParamLayout::synthetic(&t),
        flops_fwd_per_sample: 0.72e9,
        epoch_samples: IMAGENET,
        batch_sizes: [256, 512, 1024, 1024],
    }
}

/// VGG19 (Simonyan & Zisserman): 143M params, ~19.6 GFLOPs/image.
pub fn vgg19() -> NetworkShape {
    let cfg: &[(usize, usize)] = &[
        (64, 3), (64, 64),
        (128, 64), (128, 128),
        (256, 128), (256, 256), (256, 256), (256, 256),
        (512, 256), (512, 512), (512, 512), (512, 512),
        (512, 512), (512, 512), (512, 512), (512, 512),
    ];
    static NAMES: [&str; 16] = [
        "conv1_1", "conv1_2", "conv2_1", "conv2_2", "conv3_1", "conv3_2", "conv3_3", "conv3_4",
        "conv4_1", "conv4_2", "conv4_3", "conv4_4", "conv5_1", "conv5_2", "conv5_3", "conv5_4",
    ];
    let mut t: Vec<(&'static str, Vec<usize>)> = cfg
        .iter()
        .zip(NAMES.iter())
        .map(|(&(o, i), &n)| conv(n, o, i, 3))
        .collect();
    t.push(fc("fc6", 25088, 4096));
    t.push(fc("fc7", 4096, 4096));
    t.push(fc("fc8", 4096, 1000));
    NetworkShape {
        name: "VGG19",
        layout: ParamLayout::synthetic(&t),
        flops_fwd_per_sample: 19.6e9,
        epoch_samples: IMAGENET,
        batch_sizes: [64, 128, 256, 256],
    }
}

/// ResNet bottleneck-stack replica. `blocks` per stage, ImageNet stem/head.
fn resnet_imagenet(
    name: &'static str,
    blocks: [usize; 4],
    flops: f64,
    batch: [usize; 4],
) -> NetworkShape {
    let mut t: Vec<(&'static str, Vec<usize>)> = vec![conv("stem", 64, 3, 7)];
    let widths = [(64usize, 256usize), (128, 512), (256, 1024), (512, 2048)];
    for (stage, &nb) in blocks.iter().enumerate() {
        let (w, wout) = widths[stage];
        let win = if stage == 0 { 64 } else { widths[stage - 1].1 };
        for b in 0..nb {
            let cin = if b == 0 { win } else { wout };
            // bottleneck: 1x1 reduce, 3x3, 1x1 expand (+ a projection on b==0)
            t.push(("b.reduce", vec![w, cin, 1, 1]));
            t.push(("b.conv3", vec![w, w, 3, 3]));
            t.push(("b.expand", vec![wout, w, 1, 1]));
            if b == 0 {
                t.push(("b.proj", vec![wout, cin, 1, 1]));
            }
        }
    }
    t.push(fc("fc", 2048, 1000));
    NetworkShape {
        name,
        layout: ParamLayout::synthetic(&t),
        flops_fwd_per_sample: flops,
        epoch_samples: IMAGENET,
        batch_sizes: batch,
    }
}

/// ResNet-50: 25.6M params, ~3.8 GFLOPs/image.
pub fn resnet50() -> NetworkShape {
    resnet_imagenet("ResNet50", [3, 4, 6, 3], 3.8e9, [64, 128, 256, 256])
}

/// ResNet-152: 60.2M params, ~11.3 GFLOPs/image.
pub fn resnet152() -> NetworkShape {
    resnet_imagenet("ResNet152", [3, 8, 36, 3], 11.3e9, [32, 64, 128, 256])
}

/// ResNet-110 for CIFAR-10 (basic blocks, 3 stages × 18): 1.7M params,
/// ~0.25 GFLOPs/image.
pub fn resnet110_cifar() -> NetworkShape {
    let mut t: Vec<(&'static str, Vec<usize>)> = vec![conv("stem", 16, 3, 3)];
    let widths = [16usize, 32, 64];
    for (stage, &w) in widths.iter().enumerate() {
        let win = if stage == 0 { 16 } else { widths[stage - 1] };
        for b in 0..18 {
            let cin = if b == 0 { win } else { w };
            t.push(("b.conv1", vec![w, cin, 3, 3]));
            t.push(("b.conv2", vec![w, w, 3, 3]));
        }
    }
    t.push(fc("fc", 64, 10));
    NetworkShape {
        name: "ResNet110",
        layout: ParamLayout::synthetic(&t),
        flops_fwd_per_sample: 0.25e9,
        epoch_samples: CIFAR10,
        batch_sizes: [128, 128, 128, 128],
    }
}

/// BN-Inception (Ioffe & Szegedy 2015): ~11M params, ~2 GFLOPs/image.
/// Inception modules are many small convolutions; we replicate the published
/// per-module branch widths coarsely (what matters is many <10K and mid-size
/// tensors, which stress the skip rule).
pub fn bn_inception() -> NetworkShape {
    let mut t: Vec<(&'static str, Vec<usize>)> = vec![
        conv("conv1", 64, 3, 7),
        conv("conv2r", 64, 64, 1),
        conv("conv2", 192, 64, 3),
    ];
    // 10 inception modules with growing widths
    let widths: [usize; 10] = [256, 320, 320, 576, 576, 576, 608, 608, 1056, 1024];
    let mut cin = 192;
    for &w in widths.iter() {
        let b1 = w / 4;
        t.push(("i.1x1", vec![b1, cin, 1, 1]));
        t.push(("i.3x3r", vec![b1 / 2, cin, 1, 1]));
        t.push(("i.3x3", vec![b1, b1 / 2, 3, 3]));
        t.push(("i.d3x3r", vec![b1 / 2, cin, 1, 1]));
        t.push(("i.d3x3a", vec![b1, b1 / 2, 3, 3]));
        t.push(("i.d3x3b", vec![b1, b1, 3, 3]));
        t.push(("i.pool", vec![w - 3 * b1, cin, 1, 1]));
        cin = w;
    }
    t.push(fc("fc", 1024, 1000));
    NetworkShape {
        name: "BN-Inception",
        layout: ParamLayout::synthetic(&t),
        flops_fwd_per_sample: 2.0e9,
        epoch_samples: IMAGENET,
        batch_sizes: [256, 256, 256, 1024],
    }
}

/// AN4 speech LSTM (paper: 13M params). 3-layer LSTM, hidden 750,
/// 363-dim features.
pub fn lstm_an4() -> NetworkShape {
    let h = 750;
    let feat = 363;
    let classes = 132;
    let t = vec![
        ("l0.wx", vec![4 * h, feat]),
        ("l0.wh", vec![4 * h, h]),
        ("l0.b", vec![4 * h]),
        ("l1.wx", vec![4 * h, h]),
        ("l1.wh", vec![4 * h, h]),
        ("l1.b", vec![4 * h]),
        ("l2.wx", vec![4 * h, h]),
        ("l2.wh", vec![4 * h, h]),
        ("l2.b", vec![4 * h]),
        ("out.w", vec![h, classes * 16]),
        ("out.b", vec![classes * 16]),
    ];
    let layout = ParamLayout::synthetic(&t);
    let params = layout.total_params() as f64;
    NetworkShape {
        name: "LSTM",
        layout,
        // CNTK counts speech minibatches in *frames*; cost ≈ 2·params/frame.
        flops_fwd_per_sample: 2.0 * params,
        epoch_samples: 76_000, // AN4: ~950 utterances × ~80 frames
        batch_sizes: [256, 256, 256, 256],
    }
}

/// The paper's MNIST two-layer perceptron.
pub fn mlp_mnist() -> NetworkShape {
    let t = vec![fc("fc1", 784, 1024), ("fc1.b", vec![1024]), fc("fc2", 1024, 10), ("fc2.b", vec![10])];
    NetworkShape {
        name: "MLP",
        layout: ParamLayout::synthetic(&t),
        flops_fwd_per_sample: 2.0 * 810_000.0,
        epoch_samples: 60_000,
        batch_sizes: [128, 128, 128, 128],
    }
}

/// All Table-1 networks in paper order.
pub fn table1_networks() -> Vec<NetworkShape> {
    vec![
        alexnet(),
        resnet152(),
        resnet50(),
        resnet110_cifar(),
        bn_inception(),
        vgg19(),
        lstm_an4(),
    ]
}

pub fn by_name(name: &str) -> Option<NetworkShape> {
    let lower = name.to_lowercase();
    let all = {
        let mut v = table1_networks();
        v.push(mlp_mnist());
        v
    };
    all.into_iter().find(|n| n.name.to_lowercase() == lower)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parameter_counts_match_paper_table1() {
        // (network, paper params, tolerance)
        let expect = [
            (alexnet(), 62.0e6, 0.05),
            (vgg19(), 143.0e6, 0.05),
            (resnet50(), 25.0e6, 0.10),
            (resnet152(), 60.0e6, 0.10),
            (bn_inception(), 11.0e6, 0.25),
            (resnet110_cifar(), 1.7e6, 0.75), // paper rounds to "1M"
            (lstm_an4(), 13.0e6, 0.15),
        ];
        for (net, want, tol) in expect {
            let got = net.params() as f64;
            assert!(
                (got - want).abs() / want <= tol,
                "{}: {got:.2e} vs paper {want:.2e}",
                net.name
            );
        }
    }

    #[test]
    fn batch_size_lookup() {
        let a = alexnet();
        assert_eq!(a.batch_for_gpus(2), 256);
        assert_eq!(a.batch_for_gpus(16), 1024);
        assert_eq!(a.batch_for_gpus(3), 512);
    }

    #[test]
    fn zoo_lookup() {
        assert!(by_name("alexnet").is_some());
        assert!(by_name("VGG19").is_some());
        assert!(by_name("mlp").is_some());
        assert!(by_name("gpt4").is_none());
    }

    #[test]
    fn conv_nets_have_small_tensors_for_skip_rule() {
        // ResNet110's many small conv tensors are what made 1BitSGD slow in
        // the paper's App. E discussion; the skip rule must kick in.
        use crate::models::layout::QuantPlan;
        let n = resnet110_cifar();
        let p = QuantPlan::paper_default(&n.layout);
        let f = p.quantized_fraction();
        assert!(f < 1.0 && f > 0.5, "{f}");
        // while AlexNet (big FC layers) is >99% quantized, matching §5
        let a = alexnet();
        let pa = QuantPlan::paper_default(&a.layout);
        assert!(pa.quantized_fraction() > 0.99);
    }
}
