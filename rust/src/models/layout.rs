//! Flat-parameter layouts and quantization plans.
//!
//! The L2 graphs operate on a single flat `f32[n]` parameter vector; the
//! AOT manifest records where each named tensor lives. The coordinator uses
//! this to apply the paper's §5 protocol rules: tensors with fewer than 10K
//! elements are *not* quantized ("the computational cost of quantizing them
//! significantly exceeds the reduction in communication"), and buckets never
//! straddle tensor boundaries ("we reshape matrices to fit bucket sizes, so
//! that no receptive field is split across two buckets").

use anyhow::{Context, Result};

use crate::util::json::Json;

/// Paper §5: tensors smaller than this many elements ride along in fp32.
pub const SKIP_QUANT_BELOW: usize = 10_000;

/// One named tensor inside the flat parameter vector.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorInfo {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub size: usize,
}

/// The full layout of a model's flat parameter vector.
#[derive(Debug, Clone, Default)]
pub struct ParamLayout {
    pub tensors: Vec<TensorInfo>,
}

impl ParamLayout {
    /// Parse the `layout` array of a manifest entry.
    pub fn from_json(layout: &Json) -> Result<Self> {
        let arr = layout.as_arr().context("layout is not an array")?;
        let mut tensors = Vec::with_capacity(arr.len());
        let mut expect_off = 0usize;
        for t in arr {
            let name = t.get("name").and_then(Json::as_str).context("tensor name")?.to_string();
            let shape: Vec<usize> = t
                .get("shape")
                .and_then(Json::as_arr)
                .context("tensor shape")?
                .iter()
                .map(|d| d.as_usize().unwrap_or(0))
                .collect();
            let offset = t.get("offset").and_then(Json::as_usize).context("tensor offset")?;
            let size = t.get("size").and_then(Json::as_usize).context("tensor size")?;
            anyhow::ensure!(offset == expect_off, "layout not contiguous at {name}");
            anyhow::ensure!(shape.iter().product::<usize>() == size, "shape/size mismatch at {name}");
            expect_off = offset + size;
            tensors.push(TensorInfo { name, shape, offset, size });
        }
        Ok(Self { tensors })
    }

    /// Synthetic layout (for networks we only simulate): one tensor per
    /// (name, shape) pair, packed contiguously.
    pub fn synthetic(tensors: &[(&str, Vec<usize>)]) -> Self {
        let mut out = Vec::with_capacity(tensors.len());
        let mut off = 0;
        for (name, shape) in tensors {
            let size: usize = shape.iter().product();
            out.push(TensorInfo { name: name.to_string(), shape: shape.clone(), offset: off, size });
            off += size;
        }
        Self { tensors: out }
    }

    pub fn total_params(&self) -> usize {
        self.tensors.last().map(|t| t.offset + t.size).unwrap_or(0)
    }

    pub fn tensor(&self, name: &str) -> Option<&TensorInfo> {
        self.tensors.iter().find(|t| t.name == name)
    }

    /// Per-tensor `(readiness, share)` schedule for the §5 overlap model
    /// ([`Breakdown::total_overlapped`](crate::metrics::Breakdown::total_overlapped)).
    ///
    /// Backprop walks the network output → input, so the *last* tensor's
    /// gradient is ready first and may start its exchange while earlier
    /// layers are still differentiating. Backprop time per tensor is
    /// approximated as proportional to its parameter count, giving tensor
    /// `i` (layout order) a readiness fraction of `Σ_{j≥i} size_j / total` —
    /// the suffix-cumulative size. Entries come out in transmission order
    /// (reverse layout order), readiness non-decreasing, the final entry
    /// (the input layer, ready only when backprop completes) at exactly 1.0;
    /// `share` is the tensor's size fraction. Empty layout ⇒ empty schedule
    /// (the overlap model then treats the step as one whole-gradient unit).
    pub fn overlap_schedule(&self) -> Vec<(f64, f64)> {
        let total = self.total_params();
        if total == 0 {
            return Vec::new();
        }
        let mut sched = Vec::with_capacity(self.tensors.len());
        let mut done = 0usize;
        for t in self.tensors.iter().rev() {
            done += t.size;
            sched.push((done as f64 / total as f64, t.size as f64 / total as f64));
        }
        sched
    }
}

/// A contiguous segment of the flat gradient with a single treatment.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Segment {
    pub offset: usize,
    pub len: usize,
    /// false ⇒ transmit raw fp32 (the <10K rule).
    pub quantized: bool,
}

/// How a model's gradient is carved into quantize/skip segments.
///
/// Adjacent quantized tensors are merged into one segment (buckets then run
/// across the merged range but the coordinator resets buckets at segment
/// boundaries, honouring the no-straddle rule at the tensor-group level the
/// way CNTK's reshaping does).
#[derive(Debug, Clone, Default)]
pub struct QuantPlan {
    pub segments: Vec<Segment>,
}

impl QuantPlan {
    pub fn build(layout: &ParamLayout, min_quant_size: usize) -> Self {
        let mut segments: Vec<Segment> = Vec::new();
        for t in &layout.tensors {
            let quantized = t.size >= min_quant_size;
            match segments.last_mut() {
                Some(s) if s.quantized == quantized && s.offset + s.len == t.offset => {
                    s.len += t.size;
                }
                _ => segments.push(Segment { offset: t.offset, len: t.size, quantized }),
            }
        }
        Self { segments }
    }

    /// Paper default: the §5 skip rule.
    pub fn paper_default(layout: &ParamLayout) -> Self {
        Self::build(layout, SKIP_QUANT_BELOW)
    }

    /// Quantize everything (for small test models whose tensors are all
    /// below the paper threshold).
    pub fn quantize_all(layout: &ParamLayout) -> Self {
        Self::build(layout, 0)
    }

    pub fn total_len(&self) -> usize {
        self.segments.iter().map(|s| s.len).sum()
    }

    /// Fraction of parameters transmitted in quantized form (the paper
    /// reports >99% for its networks).
    pub fn quantized_fraction(&self) -> f64 {
        let q: usize = self.segments.iter().filter(|s| s.quantized).map(|s| s.len).sum();
        let t = self.total_len();
        if t == 0 {
            0.0
        } else {
            q as f64 / t as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    #[test]
    fn parse_manifest_layout() {
        let j = json::parse(
            r#"[
              {"name": "w", "shape": [4, 8], "offset": 0, "size": 32},
              {"name": "b", "shape": [8], "offset": 32, "size": 8}
            ]"#,
        )
        .unwrap();
        let l = ParamLayout::from_json(&j).unwrap();
        assert_eq!(l.total_params(), 40);
        assert_eq!(l.tensor("w").unwrap().shape, vec![4, 8]);
        assert!(l.tensor("missing").is_none());
    }

    #[test]
    fn parse_rejects_gaps() {
        let j = json::parse(
            r#"[{"name": "w", "shape": [4], "offset": 1, "size": 4}]"#,
        )
        .unwrap();
        assert!(ParamLayout::from_json(&j).is_err());
    }

    #[test]
    fn skip_rule_and_merging() {
        let l = ParamLayout::synthetic(&[
            ("conv1", vec![64, 3, 7, 7]),    // 9408 < 10K  -> fp32
            ("fc1", vec![512, 512]),         // 262144      -> quantized
            ("fc1.b", vec![512]),            // 512         -> fp32
            ("fc2", vec![512, 512]),         // quantized
            ("fc3", vec![512, 512]),         // quantized (merges with fc2? no — fc1.b between)
        ]);
        let p = QuantPlan::paper_default(&l);
        assert_eq!(p.segments.len(), 4);
        assert!(!p.segments[0].quantized);
        assert!(p.segments[1].quantized);
        assert!(!p.segments[2].quantized);
        assert!(p.segments[3].quantized);
        assert_eq!(p.segments[3].len, 2 * 512 * 512); // fc2+fc3 merged
        assert_eq!(p.total_len(), l.total_params());
        let f = p.quantized_fraction();
        assert!(f > 0.97 && f < 1.0, "{f}");
    }

    #[test]
    fn overlap_schedule_is_reverse_order_and_normalized() {
        let l = ParamLayout::synthetic(&[
            ("in", vec![10]),  // computed last in backprop
            ("mid", vec![30]),
            ("out", vec![60]), // ready first
        ]);
        let s = l.overlap_schedule();
        assert_eq!(s.len(), 3);
        // transmission order = reverse layout order: out, mid, in
        assert!((s[0].0 - 0.6).abs() < 1e-12 && (s[0].1 - 0.6).abs() < 1e-12);
        assert!((s[1].0 - 0.9).abs() < 1e-12 && (s[1].1 - 0.3).abs() < 1e-12);
        assert!((s[2].0 - 1.0).abs() < 1e-12 && (s[2].1 - 0.1).abs() < 1e-12);
        // readiness is non-decreasing and ends at exactly 1.0
        assert!(s.windows(2).all(|w| w[0].0 <= w[1].0));
        assert_eq!(s.last().unwrap().0, 1.0);
        let share_sum: f64 = s.iter().map(|&(_, sh)| sh).sum();
        assert!((share_sum - 1.0).abs() < 1e-12);
        assert!(ParamLayout::default().overlap_schedule().is_empty());
    }

    #[test]
    fn quantize_all_is_one_segment() {
        let l = ParamLayout::synthetic(&[("a", vec![10]), ("b", vec![20])]);
        let p = QuantPlan::quantize_all(&l);
        assert_eq!(p.segments.len(), 1);
        assert_eq!(p.segments[0].len, 30);
        assert_eq!(p.quantized_fraction(), 1.0);
    }

    #[test]
    fn empty_layout() {
        let l = ParamLayout::default();
        assert_eq!(l.total_params(), 0);
        let p = QuantPlan::paper_default(&l);
        assert!(p.segments.is_empty());
        assert_eq!(p.quantized_fraction(), 0.0);
    }
}
