//! Optimizers and learning-rate schedules for the coordinator.

/// Learning-rate schedule.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum LrSchedule {
    Const(f32),
    /// lr · decay^(step/every)
    StepDecay { lr: f32, decay: f32, every: usize },
    /// 1/(L + √K/γ) style theory rate is just Const computed by the caller.
    InvSqrt { lr: f32, warmup: usize },
}

impl LrSchedule {
    pub fn at(&self, step: usize) -> f32 {
        match *self {
            LrSchedule::Const(lr) => lr,
            LrSchedule::StepDecay { lr, decay, every } => {
                lr * decay.powi((step / every.max(1)) as i32)
            }
            LrSchedule::InvSqrt { lr, warmup } => {
                if step < warmup {
                    lr * (step + 1) as f32 / warmup as f32
                } else {
                    lr * ((warmup.max(1) as f32) / (step + 1) as f32).sqrt()
                }
            }
        }
    }
}

/// SGD with optional momentum and weight decay — the update rule of
/// Algorithm 1 line 9 (`x ← x − (η/K) Σ ĝ`); the coordinator passes the
/// already-averaged decoded gradient.
#[derive(Debug, Clone)]
pub struct Sgd {
    pub schedule: LrSchedule,
    pub momentum: f32,
    pub weight_decay: f32,
    velocity: Vec<f32>,
    step: usize,
}

impl Sgd {
    pub fn new(schedule: LrSchedule, momentum: f32, weight_decay: f32, dim: usize) -> Self {
        Self {
            schedule,
            momentum,
            weight_decay,
            velocity: if momentum > 0.0 { vec![0.0; dim] } else { Vec::new() },
            step: 0,
        }
    }

    pub fn plain(lr: f32, dim: usize) -> Self {
        Self::new(LrSchedule::Const(lr), 0.0, 0.0, dim)
    }

    pub fn step_count(&self) -> usize {
        self.step
    }

    pub fn lr(&self) -> f32 {
        self.schedule.at(self.step)
    }

    /// Apply one update in place.
    pub fn apply(&mut self, params: &mut [f32], grad: &[f32]) {
        assert_eq!(params.len(), grad.len());
        let lr = self.lr();
        if self.momentum > 0.0 {
            assert_eq!(self.velocity.len(), params.len());
            for i in 0..params.len() {
                let g = grad[i] + self.weight_decay * params[i];
                self.velocity[i] = self.momentum * self.velocity[i] + g;
                params[i] -= lr * self.velocity[i];
            }
        } else if self.weight_decay > 0.0 {
            for i in 0..params.len() {
                params[i] -= lr * (grad[i] + self.weight_decay * params[i]);
            }
        } else {
            for (p, &g) in params.iter_mut().zip(grad) {
                *p -= lr * g;
            }
        }
        self.step += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedules() {
        assert_eq!(LrSchedule::Const(0.1).at(1000), 0.1);
        let s = LrSchedule::StepDecay { lr: 1.0, decay: 0.5, every: 10 };
        assert_eq!(s.at(0), 1.0);
        assert_eq!(s.at(10), 0.5);
        assert_eq!(s.at(25), 0.25);
        let w = LrSchedule::InvSqrt { lr: 1.0, warmup: 10 };
        assert!(w.at(0) < w.at(9));
        assert!(w.at(100) < w.at(10));
    }

    #[test]
    fn plain_sgd_descends_quadratic() {
        // f(x) = 0.5‖x‖² ⇒ grad = x; converges from any start
        let mut p = vec![1.0f32, -2.0, 3.0];
        let mut opt = Sgd::plain(0.1, 3);
        for _ in 0..100 {
            let g = p.clone();
            opt.apply(&mut p, &g);
        }
        assert!(p.iter().all(|&x| x.abs() < 1e-3));
        assert_eq!(opt.step_count(), 100);
    }

    #[test]
    fn momentum_accelerates() {
        let run = |mom: f32| {
            let mut p = vec![1.0f32; 4];
            let mut opt = Sgd::new(LrSchedule::Const(0.02), mom, 0.0, 4);
            for _ in 0..60 {
                let g = p.clone();
                opt.apply(&mut p, &g);
            }
            p.iter().map(|x| (x * x) as f64).sum::<f64>()
        };
        assert!(run(0.9) < run(0.0));
    }

    #[test]
    fn weight_decay_shrinks_params() {
        let mut p = vec![1.0f32];
        let mut opt = Sgd::new(LrSchedule::Const(0.1), 0.0, 0.5, 1);
        opt.apply(&mut p, &[0.0]);
        assert!((p[0] - 0.95).abs() < 1e-6);
    }
}
