//! α–β link model: per-endpoint latency + bandwidth.

/// A single (full-duplex) link attached to each endpoint.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Link {
    /// Sustainable bandwidth, bytes/second.
    pub bandwidth_bps: f64,
    /// Per-message latency (α), seconds.
    pub latency_s: f64,
}

impl Link {
    pub fn new(bandwidth_bps: f64, latency_s: f64) -> Self {
        assert!(bandwidth_bps > 0.0 && latency_s >= 0.0);
        Self { bandwidth_bps, latency_s }
    }

    /// Time to serialise `bytes` onto this link (excluding latency).
    pub fn serialize_time(&self, bytes: usize) -> f64 {
        bytes as f64 / self.bandwidth_bps
    }

    /// Full point-to-point message time.
    pub fn message_time(&self, bytes: usize) -> f64 {
        self.latency_s + self.serialize_time(bytes)
    }

    /// Fit α (latency) and β (1/bandwidth) by least squares from measured
    /// `(bytes, seconds)` transfer samples: `t = α + β·bytes`. This is how
    /// the `table1_speedup` bench turns committed loopback-bench medians
    /// into a calibrated link instead of a preset constant.
    ///
    /// Degenerate inputs fall back gracefully rather than panicking: with
    /// all samples at one size (or a non-positive fitted slope — noise can
    /// produce one), the fit collapses to a zero-latency pure-bandwidth
    /// line through the means; a fitted α below zero clamps to zero. An
    /// empty sample set yields a 1 B/s zero-latency link, which downstream
    /// code treats as "unmeasured".
    pub fn fit(samples: &[(usize, f64)]) -> Link {
        if samples.is_empty() {
            return Link::new(1.0, 0.0);
        }
        let n = samples.len() as f64;
        let mean_b = samples.iter().map(|&(b, _)| b as f64).sum::<f64>() / n;
        let mean_t = samples.iter().map(|&(_, t)| t).sum::<f64>() / n;
        let var_b: f64 = samples.iter().map(|&(b, _)| (b as f64 - mean_b).powi(2)).sum();
        let cov: f64 =
            samples.iter().map(|&(b, t)| (b as f64 - mean_b) * (t - mean_t)).sum();
        let slope = if var_b > 0.0 { cov / var_b } else { 0.0 };
        let beta = if slope > 0.0 {
            slope
        } else if mean_b > 0.0 && mean_t > 0.0 {
            // pure-bandwidth fallback through the means
            mean_t / mean_b
        } else {
            1.0
        };
        let alpha = (mean_t - beta * mean_b).max(0.0);
        Link::new(1.0 / beta, alpha)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn message_time_decomposes() {
        let l = Link::new(1e9, 5e-6);
        assert!((l.message_time(1_000_000) - (5e-6 + 1e-3)).abs() < 1e-12);
        assert_eq!(l.serialize_time(0), 0.0);
    }

    #[test]
    fn fit_recovers_alpha_beta() {
        // Exact samples from a known link: α = 10µs, β = 1/(100 MB/s).
        let truth = Link::new(1e8, 1e-5);
        let samples: Vec<(usize, f64)> = [1 << 10, 1 << 16, 1 << 20, 1 << 22]
            .iter()
            .map(|&b| (b, truth.message_time(b)))
            .collect();
        let fit = Link::fit(&samples);
        assert!((fit.latency_s - truth.latency_s).abs() / truth.latency_s < 1e-9);
        assert!((fit.bandwidth_bps - truth.bandwidth_bps).abs() / truth.bandwidth_bps < 1e-9);
    }

    #[test]
    fn fit_degenerate_inputs_fall_back() {
        // One sample (zero variance): pure-bandwidth line through the point.
        let one = Link::fit(&[(1 << 20, 0.01)]);
        assert_eq!(one.latency_s, 0.0);
        assert!((one.bandwidth_bps - (1 << 20) as f64 / 0.01).abs() < 1e-3);
        // Negative slope (noise): same fallback, never a panic.
        let noisy = Link::fit(&[(1000, 0.02), (1_000_000, 0.01)]);
        assert!(noisy.bandwidth_bps > 0.0 && noisy.latency_s >= 0.0);
        // Empty: the "unmeasured" sentinel link.
        let empty = Link::fit(&[]);
        assert_eq!((empty.bandwidth_bps, empty.latency_s), (1.0, 0.0));
    }
}
