//! α–β link model: per-endpoint latency + bandwidth.

/// A single (full-duplex) link attached to each endpoint.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Link {
    /// Sustainable bandwidth, bytes/second.
    pub bandwidth_bps: f64,
    /// Per-message latency (α), seconds.
    pub latency_s: f64,
}

impl Link {
    pub fn new(bandwidth_bps: f64, latency_s: f64) -> Self {
        assert!(bandwidth_bps > 0.0 && latency_s >= 0.0);
        Self { bandwidth_bps, latency_s }
    }

    /// Time to serialise `bytes` onto this link (excluding latency).
    pub fn serialize_time(&self, bytes: usize) -> f64 {
        bytes as f64 / self.bandwidth_bps
    }

    /// Full point-to-point message time.
    pub fn message_time(&self, bytes: usize) -> f64 {
        self.latency_s + self.serialize_time(bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn message_time_decomposes() {
        let l = Link::new(1e9, 5e-6);
        assert!((l.message_time(1_000_000) - (5e-6 + 1e-3)).abs() < 1e-12);
        assert_eq!(l.serialize_time(0), 0.0);
    }
}
