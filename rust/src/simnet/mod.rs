//! Virtual-clock interconnect simulator.
//!
//! The paper's testbed is 16× NVIDIA K80 on one EC2 p2.16xlarge with
//! GPUDirect peer-to-peer MPI (no NCCL). We cannot attach 16 GPUs here, so
//! Figure 2 / Table 1 epoch-time *shapes* are reproduced on a calibrated
//! simulator: the bytes-on-wire are exact (produced by the real Rust
//! encoder), transfer times follow an α–β (latency–bandwidth) model, and
//! computation times come from a per-network FLOPs cost model
//! (`models::cost`). See DESIGN.md §Substitutions.

pub mod fault;
pub mod link;
pub mod presets;
pub mod topology;

pub use fault::Faults;
pub use link::Link;
pub use presets::Preset;
pub use topology::Topology;

/// Virtual time, seconds. All simulated costs accumulate here; wall-clock
/// time is tracked separately by `metrics`.
#[derive(Debug, Clone, Copy, PartialEq, PartialOrd, Default)]
pub struct VTime(pub f64);

impl VTime {
    pub const ZERO: VTime = VTime(0.0);

    pub fn secs(self) -> f64 {
        self.0
    }

    pub fn max(self, other: VTime) -> VTime {
        VTime(self.0.max(other.0))
    }
}

impl std::ops::Add for VTime {
    type Output = VTime;
    fn add(self, rhs: VTime) -> VTime {
        VTime(self.0 + rhs.0)
    }
}

impl std::ops::AddAssign for VTime {
    fn add_assign(&mut self, rhs: VTime) {
        self.0 += rhs.0;
    }
}

impl std::ops::Sub for VTime {
    type Output = VTime;
    fn sub(self, rhs: VTime) -> VTime {
        VTime(self.0 - rhs.0)
    }
}

/// Cluster-level network model: K endpoints, a per-endpoint link (α–β), a
/// topology describing how collective exchanges are scheduled, and an
/// optional fault-injection scenario (heterogeneous per-endpoint links,
/// seeded stragglers, in-flight frame corruption).
#[derive(Debug, Clone)]
pub struct SimNet {
    pub workers: usize,
    pub link: Link,
    pub topology: Topology,
    /// Per-endpoint link overrides `(worker, link)` for heterogeneous
    /// clusters; endpoints without an entry use `link`. Empty by default,
    /// in which case every cost below is bit-identical to the uniform
    /// model.
    pub overrides: Vec<(usize, Link)>,
    /// Optional seeded straggler/corruption schedule charged into every
    /// transfer cost.
    pub faults: Option<Faults>,
}

impl SimNet {
    pub fn new(workers: usize, link: Link, topology: Topology) -> Self {
        assert!(workers >= 1);
        Self { workers, link, topology, overrides: Vec::new(), faults: None }
    }

    pub fn preset(workers: usize, preset: Preset) -> Self {
        let (link, topology) = preset.build();
        Self::new(workers, link, topology)
    }

    /// Override the link of one endpoint (heterogeneous cluster).
    pub fn with_link_override(mut self, worker: usize, link: Link) -> Self {
        assert!(worker < self.workers, "override for worker {worker} out of range");
        self.overrides.push((worker, link));
        self
    }

    /// Attach a seeded fault schedule to every charged transfer.
    pub fn with_faults(mut self, faults: Faults) -> Self {
        self.faults = Some(faults);
        self
    }

    /// Effective link of one endpoint (the last override wins).
    pub fn link_of(&self, worker: usize) -> Link {
        self.overrides
            .iter()
            .rev()
            .find(|(w, _)| *w == worker)
            .map(|(_, l)| *l)
            .unwrap_or(self.link)
    }

    /// The bottleneck link across all endpoints: worst latency, worst
    /// bandwidth. Synchronous collectives complete when the slowest
    /// endpoint does, so aggregate costs are charged at this link.
    fn bottleneck(&self) -> Link {
        if self.overrides.is_empty() {
            return self.link;
        }
        let mut l = self.link;
        for w in 0..self.workers {
            let lw = self.link_of(w);
            l.latency_s = l.latency_s.max(lw.latency_s);
            l.bandwidth_bps = l.bandwidth_bps.min(lw.bandwidth_bps);
        }
        l
    }

    /// Charge one network operation: apply the fault schedule's time
    /// multiplier when a scenario is active, identity otherwise.
    fn charge(&self, t: f64) -> VTime {
        match &self.faults {
            Some(f) => VTime(t * f.multiplier()),
            None => VTime(t),
        }
    }

    /// Straggled / corrupted op counts from the fault schedule (0, 0
    /// without a scenario) — the simnet side of the recovery metrics.
    pub fn fault_counts(&self) -> (u64, u64) {
        self.faults.as_ref().map(|f| (f.straggled(), f.corrupted())).unwrap_or((0, 0))
    }

    /// Virtual time for the gradient exchange of one iteration, where worker
    /// `i` contributes a message of `msg_bytes[i]` bytes that every peer must
    /// receive (Algorithm 1's broadcast), or — for `Topology::RingAllReduce`
    /// — all messages are dense equal-size buffers reduced in-ring.
    pub fn exchange_time(&self, msg_bytes: &[usize]) -> VTime {
        assert_eq!(msg_bytes.len(), self.workers);
        if self.workers == 1 {
            return VTime::ZERO;
        }
        let k = self.workers as f64;
        let bl = self.bottleneck();
        let alpha = bl.latency_s;
        let beta = 1.0 / bl.bandwidth_bps;
        let t = match self.topology {
            // Each endpoint serialises its K−1 sends on its own egress and
            // its K−1 receives on its ingress; transfers between distinct
            // pairs overlap (GPUDirect P2P). The bottleneck endpoint is the
            // one sending its message K−1 times or receiving everyone
            // else's, whichever is larger. Under heterogeneous links each
            // endpoint serialises at its *own* β.
            Topology::P2pBroadcast if !self.overrides.is_empty() => {
                let total: usize = msg_bytes.iter().sum();
                let per_endpoint = msg_bytes.iter().enumerate().map(|(w, &b)| {
                    let bw = 1.0 / self.link_of(w).bandwidth_bps;
                    let send = (self.workers - 1) as f64 * b as f64 * bw;
                    let recv = (total - b) as f64 * bw;
                    send.max(recv)
                });
                alpha * (k - 1.0) + per_endpoint.fold(0.0, f64::max)
            }
            Topology::P2pBroadcast => {
                let total: usize = msg_bytes.iter().sum();
                let max_send = msg_bytes
                    .iter()
                    .map(|&b| (self.workers - 1) as f64 * b as f64)
                    .fold(0.0, f64::max);
                let max_recv = msg_bytes
                    .iter()
                    .map(|&b| (total - b) as f64)
                    .fold(0.0, f64::max);
                alpha * (k - 1.0) + beta * max_send.max(max_recv)
            }
            // Parameter-server star: all pushes serialise at the server's
            // ingress (the caller models the pull separately via p2p_time).
            Topology::Star => {
                let total: usize = msg_bytes.iter().sum();
                2.0 * alpha + beta * total as f64
            }
            // Dense ring allreduce (the fp32 baseline's best case):
            // 2(K−1)/K · bytes with 2(K−1) latency hops; requires equal-size
            // dense buffers, so use the max.
            Topology::RingAllReduce => {
                let b = msg_bytes.iter().copied().max().unwrap_or(0) as f64;
                2.0 * (k - 1.0) * alpha + 2.0 * (k - 1.0) / k * b * beta
            }
        };
        self.charge(t)
    }

    /// Time to move one point-to-point message (async parameter-server ops).
    pub fn p2p_time(&self, bytes: usize) -> VTime {
        let bl = self.bottleneck();
        self.charge(bl.latency_s + bytes as f64 / bl.bandwidth_bps)
    }

    /// One synchronous hop of a segmented collective (ring reduce-scatter /
    /// allgather step): every endpoint sends one message to its neighbour
    /// concurrently on its own egress, so the hop completes when the largest
    /// message lands — `α + β·max_bytes`. Multi-hop algorithms
    /// ([`crate::collectives::CollectiveAlgo`]) accumulate one of these per
    /// step.
    pub fn hop_time(&self, max_bytes: usize) -> VTime {
        if self.workers <= 1 {
            return VTime::ZERO;
        }
        let bl = self.bottleneck();
        self.charge(bl.latency_s + max_bytes as f64 / bl.bandwidth_bps)
    }

    /// Concurrent fan-in of several messages to one endpoint (hierarchical
    /// intra-group reduce): the receiver's ingress serialises all payloads,
    /// one latency term — `α + β·Σ bytes`.
    pub fn fan_in_time(&self, total_bytes: usize) -> VTime {
        if self.workers <= 1 {
            return VTime::ZERO;
        }
        let bl = self.bottleneck();
        self.charge(bl.latency_s + total_bytes as f64 / bl.bandwidth_bps)
    }

    /// Fan-out of one `bytes`-sized payload to `copies` receivers
    /// (hierarchical intra-group broadcast): the sender's egress serialises
    /// the copies — `α + β·bytes·copies`.
    pub fn fan_out_time(&self, bytes: usize, copies: usize) -> VTime {
        if self.workers <= 1 || copies == 0 {
            return VTime::ZERO;
        }
        let bl = self.bottleneck();
        self.charge(bl.latency_s + (bytes * copies) as f64 / bl.bandwidth_bps)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn net(workers: usize, topo: Topology) -> SimNet {
        SimNet::new(workers, Link { bandwidth_bps: 1e9, latency_s: 1e-5 }, topo)
    }

    #[test]
    fn single_worker_is_free() {
        let n = net(1, Topology::P2pBroadcast);
        assert_eq!(n.exchange_time(&[1 << 20]).secs(), 0.0);
    }

    #[test]
    fn broadcast_scales_with_peers() {
        let n2 = net(2, Topology::P2pBroadcast);
        let n8 = net(8, Topology::P2pBroadcast);
        let t2 = n2.exchange_time(&[1 << 20; 2]).secs();
        let t8 = n8.exchange_time(&[1 << 20; 8]).secs();
        assert!(t8 > t2 * 3.0, "t2={t2} t8={t8}");
    }

    #[test]
    fn smaller_messages_are_faster() {
        let n = net(8, Topology::P2pBroadcast);
        let dense = n.exchange_time(&[4 << 20; 8]).secs();
        let compressed = n.exchange_time(&[512 << 10; 8]).secs();
        assert!(compressed < dense / 7.0);
    }

    #[test]
    fn ring_allreduce_beats_broadcast_for_dense() {
        let b = net(8, Topology::P2pBroadcast);
        let r = net(8, Topology::RingAllReduce);
        let msgs = [16 << 20; 8];
        assert!(r.exchange_time(&msgs).secs() < b.exchange_time(&msgs).secs());
    }

    #[test]
    fn heterogeneous_message_sizes() {
        let n = net(4, Topology::P2pBroadcast);
        let mut msgs = [1000usize; 4];
        msgs[2] = 1_000_000; // straggler dominates
        let t = n.exchange_time(&msgs).secs();
        // at least the time for the big sender to push 3 copies
        assert!(t >= 3.0 * 1_000_000.0 / 1e9);
    }

    #[test]
    fn segmented_transfer_costs() {
        let n = net(8, Topology::P2pBroadcast);
        let a = n.link.latency_s;
        let beta = 1.0 / n.link.bandwidth_bps;
        assert!((n.hop_time(1000).secs() - (a + 1000.0 * beta)).abs() < 1e-15);
        assert!((n.fan_in_time(3000).secs() - (a + 3000.0 * beta)).abs() < 1e-15);
        assert!((n.fan_out_time(1000, 3).secs() - (a + 3000.0 * beta)).abs() < 1e-15);
        assert_eq!(n.fan_out_time(1000, 0).secs(), 0.0);
        // 2(K−1) ring hops at chunk size ≈ the RingAllReduce closed form
        let k = 8usize;
        let msg = 1 << 20usize;
        let chunk = msg / k;
        let mut hops = VTime::ZERO;
        for _ in 0..2 * (k - 1) {
            hops += n.hop_time(chunk);
        }
        let dense = net(8, Topology::RingAllReduce);
        let closed = dense.exchange_time(&[msg; 8]).secs();
        assert!((hops.secs() - closed).abs() / closed < 1e-9);
        // a single worker pays nothing
        let solo = net(1, Topology::P2pBroadcast);
        assert_eq!(solo.hop_time(1 << 20).secs(), 0.0);
        assert_eq!(solo.fan_in_time(1 << 20).secs(), 0.0);
    }

    #[test]
    fn heterogeneous_override_slows_the_bottleneck() {
        let base = net(4, Topology::P2pBroadcast);
        let slow = base
            .clone()
            .with_link_override(0, Link { bandwidth_bps: 0.25e9, latency_s: 1e-5 });
        let msgs = [1 << 20; 4];
        let t0 = base.exchange_time(&msgs).secs();
        let t1 = slow.exchange_time(&msgs).secs();
        assert!(t1 > t0 * 2.0, "slow worker should dominate: {t0} vs {t1}");
        // Hop costs are charged at the bottleneck link.
        assert!(slow.hop_time(1 << 20).secs() > base.hop_time(1 << 20).secs() * 2.0);
        // Overriding a non-bottleneck property leaves the default path
        // intact: a faster-than-default worker changes nothing.
        let fast = base
            .clone()
            .with_link_override(2, Link { bandwidth_bps: 4e9, latency_s: 1e-6 });
        assert_eq!(fast.exchange_time(&msgs).secs(), t0);
    }

    #[test]
    fn straggler_schedule_is_deterministic_and_charged() {
        let mk = |seed: u64| {
            net(4, Topology::P2pBroadcast)
                .with_faults(Faults::new(seed).with_straggler(0.5, 10.0))
        };
        let (a, b) = (mk(9), mk(9));
        let sa: Vec<f64> = (0..64).map(|_| a.hop_time(4096).secs()).collect();
        let sb: Vec<f64> = (0..64).map(|_| b.hop_time(4096).secs()).collect();
        assert_eq!(sa, sb, "same seed, same schedule");
        let c = mk(10);
        let sc: Vec<f64> = (0..64).map(|_| c.hop_time(4096).secs()).collect();
        assert_ne!(sa, sc, "different seed, different schedule");
        let nominal = net(4, Topology::P2pBroadcast).hop_time(4096).secs();
        assert!(sa.iter().any(|&t| t > nominal * 5.0), "some hops straggle");
        assert!(sa.iter().any(|&t| t == nominal), "some hops do not");
        let (straggled, _) = a.fault_counts();
        assert!(straggled > 0);
    }

    #[test]
    fn corruption_charges_retransmits() {
        let n = net(2, Topology::P2pBroadcast)
            .with_faults(Faults::new(5).with_corruption(1.0));
        let nominal = net(2, Topology::P2pBroadcast).hop_time(1000).secs();
        assert_eq!(n.hop_time(1000).secs(), 2.0 * nominal);
        let (_, corrupted) = n.fault_counts();
        assert_eq!(corrupted, 1);
    }

    #[test]
    fn vtime_arithmetic() {
        let mut t = VTime::ZERO;
        t += VTime(1.5);
        assert_eq!((t + VTime(0.5)).secs(), 2.0);
        assert_eq!((t - VTime(0.5)).secs(), 1.0);
        assert_eq!(VTime(1.0).max(VTime(2.0)).secs(), 2.0);
    }
}
