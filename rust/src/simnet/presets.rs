//! Interconnect presets calibrated to the paper's testbed and a couple of
//! contrast points.
//!
//! Calibration targets (paper §5): on 16-GPU AlexNet (62M params, batch
//! 1024), >80% of 32-bit epoch time is communication; 4-bit QSGD cuts
//! communication 4× and epoch time 2.5×. The K80/PCIe preset below, driven
//! by the `models::cost` FLOPs model, lands in that regime (validated by
//! `fig2_breakdown` and EXPERIMENTS.md).

use super::{Link, Topology};

/// Named interconnect presets.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Preset {
    /// EC2 p2.16xlarge: K80s on a PCIe 3.0 switch hierarchy with GPUDirect
    /// P2P but no NCCL — effective per-GPU P2P bandwidth well below the
    /// 16 GB/s link peak once the MPI stack, host staging across sockets and
    /// switch contention are counted. Calibrated to ~3.5 GB/s effective +
    /// 50 µs software latency against the paper's Fig. 2 anchors (16-GPU
    /// AlexNet >80% comm at fp32; 2-GPU LSTM ~71%); see EXPERIMENTS.md §F2.
    K80Pcie,
    /// 10 GbE cluster (multi-node contrast point; heavier compression wins).
    TenGbE,
    /// NVLink-class fabric (communication nearly free; QSGD gains shrink).
    NvLink,
}

impl Preset {
    pub fn build(self) -> (Link, Topology) {
        match self {
            Preset::K80Pcie => (Link::new(3.5e9, 50e-6), Topology::P2pBroadcast),
            Preset::TenGbE => (Link::new(1.1e9, 150e-6), Topology::P2pBroadcast),
            Preset::NvLink => (Link::new(40.0e9, 10e-6), Topology::P2pBroadcast),
        }
    }
}

impl std::str::FromStr for Preset {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "k80" | "k80-pcie" => Ok(Preset::K80Pcie),
            "10gbe" => Ok(Preset::TenGbE),
            "nvlink" => Ok(Preset::NvLink),
            _ => Err(format!("unknown preset '{s}' (k80|10gbe|nvlink)")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_ordered_by_bandwidth() {
        let (k80, _) = Preset::K80Pcie.build();
        let (gbe, _) = Preset::TenGbE.build();
        let (nvl, _) = Preset::NvLink.build();
        assert!(gbe.bandwidth_bps < k80.bandwidth_bps);
        assert!(k80.bandwidth_bps < nvl.bandwidth_bps);
    }

    #[test]
    fn parse() {
        assert_eq!("k80".parse::<Preset>().unwrap(), Preset::K80Pcie);
        assert!("tpu".parse::<Preset>().is_err());
    }
}
