//! Exchange topologies (how a collective step is scheduled on the links).

/// Topology of the per-iteration gradient exchange.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Topology {
    /// Algorithm 1's all-to-all broadcast of (possibly compressed,
    /// variable-size) gradient messages — what CNTK's MPI path does for
    /// 1BitSGD/QSGD gradients.
    #[default]
    P2pBroadcast,
    /// Parameter-server star (Appendix D, async QSGD).
    Star,
    /// Bandwidth-optimal dense ring allreduce — the fp32 baseline's best
    /// case. Requires dense equal-size buffers, i.e. it cannot carry
    /// variable-length entropy-coded messages (the paper's §6 notes MPI has
    /// no sparse/variable types; this is the same constraint).
    RingAllReduce,
}

impl std::str::FromStr for Topology {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "p2p" | "broadcast" => Ok(Topology::P2pBroadcast),
            "star" | "ps" => Ok(Topology::Star),
            "ring" | "allreduce" => Ok(Topology::RingAllReduce),
            _ => Err(format!("unknown topology '{s}' (p2p|star|ring)")),
        }
    }
}

impl std::fmt::Display for Topology {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            Topology::P2pBroadcast => "p2p",
            Topology::Star => "star",
            Topology::RingAllReduce => "ring",
        };
        write!(f, "{s}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        for t in [Topology::P2pBroadcast, Topology::Star, Topology::RingAllReduce] {
            assert_eq!(t.to_string().parse::<Topology>().unwrap(), t);
        }
        assert!("mesh".parse::<Topology>().is_err());
    }
}
