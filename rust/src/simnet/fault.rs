//! Seeded fault distribution charged into the virtual clock.
//!
//! [`Faults`] models stragglers and in-flight frame corruption for
//! [`SimNet`](crate::simnet::SimNet): every charged network operation draws
//! from a counter-indexed hash stream, so a given seed yields exactly one
//! schedule regardless of wall-clock timing — the property the scenario
//! determinism goldens pin. A straggling op costs `straggle_factor`× its
//! nominal time; a corrupted frame costs one retransmit (2×) and bumps the
//! corruption counter that feeds the per-scenario recovery metrics.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::util::rng::splitmix64;

/// Seeded straggler + corruption schedule (see module docs).
#[derive(Debug)]
pub struct Faults {
    /// Probability a charged op straggles.
    pub straggle_prob: f64,
    /// Multiplier on a straggling op's time.
    pub straggle_factor: f64,
    /// Probability a frame is corrupted/dropped in flight, charged as one
    /// retransmit of the op.
    pub corrupt_prob: f64,
    seed: u64,
    ops: AtomicU64,
    straggled: AtomicU64,
    corrupted: AtomicU64,
}

impl Clone for Faults {
    fn clone(&self) -> Self {
        Faults {
            straggle_prob: self.straggle_prob,
            straggle_factor: self.straggle_factor,
            corrupt_prob: self.corrupt_prob,
            seed: self.seed,
            ops: AtomicU64::new(self.ops.load(Ordering::Relaxed)),
            straggled: AtomicU64::new(self.straggled.load(Ordering::Relaxed)),
            corrupted: AtomicU64::new(self.corrupted.load(Ordering::Relaxed)),
        }
    }
}

impl Faults {
    pub fn new(seed: u64) -> Self {
        Faults {
            straggle_prob: 0.0,
            straggle_factor: 1.0,
            corrupt_prob: 0.0,
            seed,
            ops: AtomicU64::new(0),
            straggled: AtomicU64::new(0),
            corrupted: AtomicU64::new(0),
        }
    }

    /// Straggle each charged op by `factor`× with probability `prob`.
    pub fn with_straggler(mut self, prob: f64, factor: f64) -> Self {
        assert!((0.0..=1.0).contains(&prob));
        assert!(factor >= 1.0, "a straggler slows an op down, factor must be >= 1");
        self.straggle_prob = prob;
        self.straggle_factor = factor;
        self
    }

    /// Corrupt each frame in flight with probability `prob` (charged as one
    /// retransmit of the op).
    pub fn with_corruption(mut self, prob: f64) -> Self {
        assert!((0.0..=1.0).contains(&prob));
        self.corrupt_prob = prob;
        self
    }

    fn unit(&self, op: u64, salt: u64) -> f64 {
        let mut s = self.seed ^ op.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ salt;
        let h = splitmix64(&mut s);
        (h >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Time multiplier for the next charged op (advances the schedule).
    pub fn multiplier(&self) -> f64 {
        let op = self.ops.fetch_add(1, Ordering::Relaxed);
        let mut m = 1.0;
        if self.straggle_prob > 0.0 && self.unit(op, 0x57) < self.straggle_prob {
            self.straggled.fetch_add(1, Ordering::Relaxed);
            m *= self.straggle_factor;
        }
        if self.corrupt_prob > 0.0 && self.unit(op, 0xC0) < self.corrupt_prob {
            self.corrupted.fetch_add(1, Ordering::Relaxed);
            m *= 2.0; // retransmit once
        }
        m
    }

    /// Charged ops that straggled so far.
    pub fn straggled(&self) -> u64 {
        self.straggled.load(Ordering::Relaxed)
    }

    /// Charged ops whose frame was corrupted in flight so far.
    pub fn corrupted(&self) -> u64 {
        self.corrupted.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn multiplier_schedule_is_seed_deterministic() {
        let a = Faults::new(11).with_straggler(0.25, 5.0).with_corruption(0.1);
        let b = Faults::new(11).with_straggler(0.25, 5.0).with_corruption(0.1);
        let sa: Vec<f64> = (0..512).map(|_| a.multiplier()).collect();
        let sb: Vec<f64> = (0..512).map(|_| b.multiplier()).collect();
        assert_eq!(sa, sb);
        assert!(a.straggled() > 0 && a.corrupted() > 0);
        let c = Faults::new(12).with_straggler(0.25, 5.0).with_corruption(0.1);
        let sc: Vec<f64> = (0..512).map(|_| c.multiplier()).collect();
        assert_ne!(sa, sc);
    }

    #[test]
    fn no_faults_means_unit_multiplier() {
        let f = Faults::new(3);
        assert!((0..64).all(|_| f.multiplier() == 1.0));
        assert_eq!((f.straggled(), f.corrupted()), (0, 0));
    }
}
