//! Deterministic quantizer for (non-stochastic) gradient descent —
//! paper Appendix F.
//!
//! `Q(v)` keeps the smallest index set `I(v)` with `Σ_{i∈I} |v_i| ≥ ‖v‖₂`
//! (greedy by magnitude), replacing each kept coordinate by `±‖v‖₂` and
//! zeroing the rest. Lemma F.1: `vᵀQ(v) ≥ ‖v‖²`, `|I(v)| ≤ √n`,
//! `‖Q(v)‖² ≤ √n·‖v‖²` — giving linear convergence for strongly-convex GD
//! (Theorem F.2) with `≤ √n(log n + O(1)) + 32` bits per step (Theorem F.4).

use crate::coding::bitstream::{BitReader, BitWriter};
use crate::coding::elias;

/// Sparse representation of the Appendix-F quantizer output.
#[derive(Debug, Clone, PartialEq)]
pub struct TopQuantized {
    pub n: usize,
    /// ‖v‖₂ of the input.
    pub norm: f32,
    /// Kept indices, strictly increasing.
    pub indices: Vec<u32>,
    /// Signs (+1/−1) aligned with `indices`.
    pub signs: Vec<i8>,
}

/// Compute `Q(v)` (Appendix F).
pub fn quantize(v: &[f32]) -> TopQuantized {
    let norm = v.iter().map(|x| x * x).sum::<f32>().sqrt();
    if norm <= 0.0 {
        return TopQuantized { n: v.len(), norm: 0.0, indices: vec![], signs: vec![] };
    }
    // Greedy smallest I(v): take coordinates in decreasing |v_i| until the
    // partial ℓ1 mass reaches ‖v‖₂.
    let mut order: Vec<u32> = (0..v.len() as u32).collect();
    order.sort_unstable_by(|&a, &b| {
        v[b as usize]
            .abs()
            .partial_cmp(&v[a as usize].abs())
            .unwrap_or(std::cmp::Ordering::Equal)
    });
    let mut acc = 0.0f32;
    let mut kept: Vec<u32> = Vec::new();
    for &i in &order {
        kept.push(i);
        acc += v[i as usize].abs();
        if acc >= norm {
            break;
        }
    }
    kept.sort_unstable();
    let signs = kept
        .iter()
        .map(|&i| if v[i as usize] < 0.0 { -1i8 } else { 1 })
        .collect();
    TopQuantized { n: v.len(), norm, indices: kept, signs }
}

impl TopQuantized {
    /// Densify: `Q(v)_i = ±‖v‖` on `I(v)`, 0 elsewhere.
    pub fn dequantize(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.n];
        for (&i, &s) in self.indices.iter().zip(&self.signs) {
            out[i as usize] = s as f32 * self.norm;
        }
        out
    }

    /// Wire encoding (Theorem F.4): 32-bit norm, Elias'(nnz), then Elias gap
    /// + sign per kept coordinate.
    pub fn encode(&self) -> Vec<u8> {
        let mut w = BitWriter::with_capacity(8 + self.indices.len() * 4);
        w.write_f32(self.norm);
        elias::encode0(&mut w, self.indices.len() as u64);
        let mut prev: i64 = -1;
        for (&i, &s) in self.indices.iter().zip(&self.signs) {
            elias::encode(&mut w, (i as i64 - prev) as u64);
            w.write_bit(s < 0);
            prev = i as i64;
        }
        w.into_bytes()
    }

    pub fn decode(bytes: &[u8], n: usize) -> anyhow::Result<Self> {
        let mut r = BitReader::new(bytes);
        let norm = r.read_f32()?;
        let nnz = elias::decode0(&mut r)? as usize;
        anyhow::ensure!(nnz <= n, "nnz {nnz} exceeds n {n}");
        // each kept coordinate costs ≥ 2 bits (gap + sign): reject
        // length-lying headers before allocating
        anyhow::ensure!((nnz as u64) * 2 <= r.bits_remaining(), "nnz exceeds stream");
        let mut indices = Vec::with_capacity(nnz);
        let mut signs = Vec::with_capacity(nnz);
        let mut prev: i64 = -1;
        for _ in 0..nnz {
            let gap = elias::decode(&mut r)?;
            // bound before the i64 cast: a hostile stream can encode any u64
            anyhow::ensure!(gap >= 1 && gap <= n as u64, "gap out of range");
            let idx = prev + gap as i64;
            anyhow::ensure!(idx >= 0 && (idx as usize) < n, "index out of range");
            indices.push(idx as u32);
            signs.push(if r.read_bit()? { -1 } else { 1 });
            prev = idx;
        }
        Ok(Self { n, norm, indices, signs })
    }

    /// Exact wire size of [`Self::encode`] in bits (for the cost model and
    /// the Theorem F.4 bound checks): 32-bit norm + Elias'(nnz) + per kept
    /// coordinate an Elias-coded gap and a sign bit.
    pub fn message_bits(&self) -> u64 {
        let mut bits = 32 + elias::len(self.indices.len() as u64 + 1);
        let mut prev: i64 = -1;
        for &i in &self.indices {
            bits += elias::len((i as i64 - prev) as u64) + 1;
            prev = i as i64;
        }
        bits
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn randn(n: usize, seed: u64) -> Vec<f32> {
        let mut r = crate::util::rng::Xoshiro256::from_u64(seed);
        (0..n).map(|_| crate::util::rng::uniform_f32(&mut r) * 2.0 - 1.0).collect()
    }

    #[test]
    fn lemma_f1_properties() {
        for seed in 0..20 {
            let n = 400;
            let v = randn(n, seed);
            let q = quantize(&v);
            let qd = q.dequantize();
            let dot: f32 = v.iter().zip(&qd).map(|(a, b)| a * b).sum();
            let vnorm2: f32 = v.iter().map(|x| x * x).sum();
            // (1) vᵀQ(v) ≥ ‖v‖²
            assert!(dot >= vnorm2 * 0.999, "seed {seed}");
            // (2) |I(v)| ≤ √n  — holds for the greedy minimal set on
            // generic vectors (Lemma F.1 proof shows D=√n always suffices)
            assert!(q.indices.len() as f64 <= (n as f64).sqrt().ceil(), "seed {seed}");
            // (3) ‖Q(v)‖² ≤ √n‖v‖²
            let qnorm2: f32 = qd.iter().map(|x| x * x).sum();
            assert!(qnorm2 <= (n as f32).sqrt() * vnorm2 * 1.001);
        }
    }

    #[test]
    fn encode_roundtrip() {
        let v = randn(1000, 3);
        let q = quantize(&v);
        let bytes = q.encode();
        let q2 = TopQuantized::decode(&bytes, 1000).unwrap();
        assert_eq!(q, q2);
        // Theorem F.4: |Code| ≤ √n(log n + 1 + log e) + 32 bits
        let bound = (1000f64).sqrt() * ((1000f64).log2() + 1.0 + std::f64::consts::E.log2()) + 32.0;
        assert!((bytes.len() as f64) * 8.0 <= bound + 64.0);
    }

    #[test]
    fn zero_and_single() {
        let q = quantize(&[0.0; 8]);
        assert!(q.indices.is_empty());
        assert_eq!(q.dequantize(), vec![0.0; 8]);
        let q = quantize(&[0.0, -3.0, 0.0]);
        assert_eq!(q.indices, vec![1]);
        assert_eq!(q.dequantize()[1], -3.0);
        let bytes = q.encode();
        assert_eq!(TopQuantized::decode(&bytes, 3).unwrap(), q);
    }

    #[test]
    fn decode_rejects_garbage() {
        assert!(TopQuantized::decode(&[0xff; 2], 10).is_err());
    }
}
