//! QSGD stochastic quantization `Q_s` — paper §3.1 with the §4 bucketing and
//! max-norm variants.
//!
//! Level assignment must agree with the Layer-1 Pallas kernel and its jnp
//! oracle (``python/compile/kernels/ref.py``): with `r = |v_i|·s/F(b)`,
//! `ℓ = ⌊r⌋`, `p = r − ℓ`, the quantized level is `ℓ + 1{u < p}` — unbiased
//! randomized rounding onto `{0, 1/s, …, 1}` (Lemma 3.1(i)).

use rand_core::RngCore;

use super::grid::{exponential_level, nonuniform_level, LevelGrid};
use super::{Norm, QuantBucket, QuantizedGradient};

/// Lane width of the vectorized level-assignment loops: 8 × f32 fills one
/// AVX2 register (the width `Norm::scale` already reduces at); narrower
/// ISAs split the lane loop without changing results.
const LANES: usize = 8;

/// Quantize one bucket given externally supplied uniforms (deterministic;
/// this is the function cross-checked level-for-level against Pallas).
pub fn quantize_bucket_with_uniforms(v: &[f32], u: &[f32], s: u32, norm: Norm) -> QuantBucket {
    debug_assert_eq!(v.len(), u.len());
    let scale = norm.scale(v);
    if scale <= 0.0 || !scale.is_finite() {
        return QuantBucket { scale: 0.0, levels: vec![0; v.len()] };
    }
    // Match the jnp oracle's operation order: k = s/scale, r = |v|·k.
    // Known quirk, frozen for kernel/wire bit-compatibility: when s/scale
    // overflows to +inf (scale tiny but normal, e.g. 2e-38 at s=255), zero
    // coordinates hit 0·inf = NaN and round to level ±s, i.e. to ±scale on
    // reconstruction — an error bounded by the (tiny) scale itself. The
    // grid-generic path (`quantize_bucket_into_grid`) instead treats such
    // buckets as degenerate; changing this one would break bit-identity
    // with the Pallas artifact and PR 1 frames.
    let k = s as f32 / scale;
    let levels = v
        .iter()
        .zip(u)
        .map(|(&x, &ui)| {
            let r = (x.abs() * k).min(s as f32);
            let lo = r.floor();
            let p = r - lo;
            let lev = lo as i32 + (ui < p) as i32;
            if x.is_sign_negative() {
                -lev
            } else {
                lev
            }
        })
        .collect();
    QuantBucket { scale, levels }
}

/// Grid-aware variant of [`quantize_bucket_with_uniforms`]: levels are picked
/// by stochastic rounding between *adjacent grid points*. The uniform grid
/// takes the original arithmetic path, so its buckets are bit-identical to
/// the pre-grid quantizer; non-uniform grids bracket `|v|/F(b)` in the
/// grid's point table.
pub fn quantize_bucket_with_uniforms_grid(
    v: &[f32],
    u: &[f32],
    grid: &LevelGrid,
    norm: Norm,
) -> QuantBucket {
    let pts = match grid.nonzero_points() {
        None => return quantize_bucket_with_uniforms(v, u, grid.s(), norm),
        Some(pts) => pts,
    };
    debug_assert_eq!(v.len(), u.len());
    let scale = norm.scale(v);
    // a subnormal scale would overflow `inv` to +inf (0·inf = NaN sends
    // zeros to the top level), so such buckets are degenerate too
    if !scale.is_normal() {
        return QuantBucket { scale: 0.0, levels: vec![0; v.len()] };
    }
    let inv = 1.0 / scale;
    let levels = v
        .iter()
        .zip(u)
        .map(|(&x, &ui)| {
            let a = (x.abs() * inv).min(1.0);
            let lev = nonuniform_level(pts, a, ui) as i32;
            if x.is_sign_negative() {
                -lev
            } else {
                lev
            }
        })
        .collect();
    QuantBucket { scale, levels }
}

/// Draw a uniform in [0, 1) from 24 random mantissa bits (exactly matching
/// the distribution of `jax.random.uniform` granularity for f32).
#[inline]
fn next_uniform(rng: &mut dyn RngCore) -> f32 {
    (rng.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
}

/// Quantize one bucket, drawing uniforms from `rng`.
pub fn quantize_bucket(v: &[f32], s: u32, norm: Norm, rng: &mut dyn RngCore) -> QuantBucket {
    let scale = norm.scale(v);
    if scale <= 0.0 || !scale.is_finite() {
        return QuantBucket { scale: 0.0, levels: vec![0; v.len()] };
    }
    let k = s as f32 / scale;
    let levels = v
        .iter()
        .map(|&x| {
            let r = (x.abs() * k).min(s as f32);
            let lo = r.floor();
            let p = r - lo;
            let lev = lo as i32 + ((next_uniform(rng) < p) as i32);
            if x.is_sign_negative() {
                -lev
            } else {
                lev
            }
        })
        .collect();
    QuantBucket { scale, levels }
}

/// Uniform in [0, 1) from one pre-drawn RNG word — the batched twin of
/// [`next_uniform`], consuming the same 24 mantissa bits.
#[inline(always)]
fn word_uniform(word: u32) -> f32 {
    (word >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
}

/// One coordinate of the uniform-grid level assignment, written branch-free
/// so the 8-lane loop below vectorizes: the sign select negates `lev` via
/// the IEEE sign bit instead of a branch (matching
/// `if x.is_sign_negative() { -lev } else { lev }` for every input, NaN
/// included), and every float op is the exact op of the scalar oracle, so
/// lane-wise evaluation is bit-identical.
#[inline(always)]
fn uniform_level_lane(x: f32, word: u32, k: f32, smax: f32) -> i32 {
    let u = word_uniform(word);
    let r = (x.abs() * k).min(smax);
    // r ≥ 0 ⇒ truncation == floor, and r ≤ s keeps it in i32 range
    let lo = r as i32;
    let p = r - lo as f32;
    let lev = lo + ((u < p) as i32);
    let neg = (x.to_bits() >> 31) as i32;
    (lev ^ -neg).wrapping_add(neg)
}

/// Allocation-free hot-path bucket quantizer over pre-drawn random words:
/// one `fill_bytes` virtual call per bucket instead of one `next_u32` per
/// coordinate (the per-coordinate dyn dispatch was ~40% of quantize time —
/// EXPERIMENTS §Perf). Writes signed levels into `levels` and returns the
/// transmitted scale (0.0 for degenerate buckets). This is the level
/// assignment the fused encode pipeline ([`crate::coding::pipeline`])
/// streams from, so it must stay bit-identical to [`quantize_bucket`].
///
/// The abs/scale/floor/compare chain runs in 8-lane chunks (fixed-size
/// array views so LLVM vectorizes the lane loop); the wire contract —
/// coordinate `i` consumes `words[4i..4i+4]`, same arithmetic per lane —
/// is that of [`quantize_bucket_into_scalar`], which
/// `tests/simd_levels.rs` holds as the bit-identity oracle.
#[inline]
pub fn quantize_bucket_into(v: &[f32], words: &[u8], s: u32, norm: Norm, levels: &mut [i32]) -> f32 {
    debug_assert_eq!(words.len(), v.len() * 4);
    debug_assert_eq!(levels.len(), v.len());
    let scale = norm.scale(v);
    if scale <= 0.0 || !scale.is_finite() {
        levels.fill(0);
        return 0.0;
    }
    let k = s as f32 / scale;
    let smax = s as f32;
    let n8 = v.len() - v.len() % LANES;
    for ((lc, vc), wc) in levels[..n8]
        .chunks_exact_mut(LANES)
        .zip(v[..n8].chunks_exact(LANES))
        .zip(words[..n8 * 4].chunks_exact(LANES * 4))
    {
        let lc: &mut [i32; LANES] = lc.try_into().unwrap();
        let vc: &[f32; LANES] = vc.try_into().unwrap();
        for ((l, &x), c) in lc.iter_mut().zip(vc).zip(wc.chunks_exact(4)) {
            let word = u32::from_le_bytes([c[0], c[1], c[2], c[3]]);
            *l = uniform_level_lane(x, word, k, smax);
        }
    }
    for ((l, &x), c) in levels[n8..]
        .iter_mut()
        .zip(&v[n8..])
        .zip(words[n8 * 4..].chunks_exact(4))
    {
        let word = u32::from_le_bytes([c[0], c[1], c[2], c[3]]);
        *l = uniform_level_lane(x, word, k, smax);
    }
    scale
}

/// Scalar reference for [`quantize_bucket_into`] — the pre-SIMD loop, kept
/// verbatim as the bit-identity oracle for the property tests and the
/// SIMD-vs-scalar section of the `coding_hotpath` bench.
pub fn quantize_bucket_into_scalar(
    v: &[f32],
    words: &[u8],
    s: u32,
    norm: Norm,
    levels: &mut [i32],
) -> f32 {
    debug_assert_eq!(words.len(), v.len() * 4);
    debug_assert_eq!(levels.len(), v.len());
    let scale = norm.scale(v);
    if scale <= 0.0 || !scale.is_finite() {
        levels.fill(0);
        return 0.0;
    }
    let k = s as f32 / scale;
    let smax = s as f32;
    for ((l, &x), c) in levels.iter_mut().zip(v).zip(words.chunks_exact(4)) {
        let word = u32::from_le_bytes([c[0], c[1], c[2], c[3]]);
        let u = (word >> 8) as f32 * (1.0 / (1u32 << 24) as f32);
        let r = (x.abs() * k).min(smax);
        let lo = r as i32;
        let p = r - lo as f32;
        let lev = lo + ((u < p) as i32);
        *l = if x.is_sign_negative() { -lev } else { lev };
    }
    scale
}

/// One coordinate of a non-uniform level assignment (shared chunked driver
/// below): normalize, bracket via `level_of`, branch-free sign select.
#[inline(always)]
fn grid_level_lane<F: Fn(f32, f32) -> u32>(x: f32, word: u32, inv: f32, level_of: &F) -> i32 {
    let u = word_uniform(word);
    let a = (x.abs() * inv).min(1.0);
    let lev = level_of(a, u) as i32;
    let neg = (x.to_bits() >> 31) as i32;
    (lev ^ -neg).wrapping_add(neg)
}

/// 8-lane chunked driver over a per-coordinate bracket function. The
/// exponential grid's `level_of` is pure arithmetic (exponent extraction),
/// so its lane loop vectorizes; custom grids keep the binary search per
/// lane but still gain the unrolled normalize/select pipeline.
#[inline(always)]
fn assign_grid_levels<F: Fn(f32, f32) -> u32>(
    v: &[f32],
    words: &[u8],
    inv: f32,
    levels: &mut [i32],
    level_of: F,
) {
    let n8 = v.len() - v.len() % LANES;
    for ((lc, vc), wc) in levels[..n8]
        .chunks_exact_mut(LANES)
        .zip(v[..n8].chunks_exact(LANES))
        .zip(words[..n8 * 4].chunks_exact(LANES * 4))
    {
        let lc: &mut [i32; LANES] = lc.try_into().unwrap();
        let vc: &[f32; LANES] = vc.try_into().unwrap();
        for ((l, &x), c) in lc.iter_mut().zip(vc).zip(wc.chunks_exact(4)) {
            let word = u32::from_le_bytes([c[0], c[1], c[2], c[3]]);
            *l = grid_level_lane(x, word, inv, &level_of);
        }
    }
    for ((l, &x), c) in levels[n8..]
        .iter_mut()
        .zip(&v[n8..])
        .zip(words[n8 * 4..].chunks_exact(4))
    {
        let word = u32::from_le_bytes([c[0], c[1], c[2], c[3]]);
        *l = grid_level_lane(x, word, inv, &level_of);
    }
}

/// Grid-aware hot-path bucket quantizer — the single level-assignment
/// routine both the two-phase and fused pipelines stream from, for *every*
/// grid (which is what makes fused-vs-two-phase bit-identity hold per grid).
/// Uniform grids dispatch to [`quantize_bucket_into`] unchanged; non-uniform
/// grids stochastically round `|v|/F(b)` between adjacent grid points —
/// the exponential grid through the exponent-extraction bracket
/// ([`exponential_level`]), bit-identical to the binary search it replaces.
/// Allocation-free on every path; oracle: [`quantize_bucket_into_grid_scalar`].
#[inline]
pub fn quantize_bucket_into_grid(
    v: &[f32],
    words: &[u8],
    grid: &LevelGrid,
    norm: Norm,
    levels: &mut [i32],
) -> f32 {
    let pts = match grid.nonzero_points() {
        None => return quantize_bucket_into(v, words, grid.s(), norm, levels),
        Some(pts) => pts,
    };
    debug_assert_eq!(words.len(), v.len() * 4);
    debug_assert_eq!(levels.len(), v.len());
    let scale = norm.scale(v);
    // subnormal scales are degenerate (see quantize_bucket_with_uniforms_grid)
    if !scale.is_normal() {
        levels.fill(0);
        return 0.0;
    }
    let inv = 1.0 / scale;
    if matches!(grid, LevelGrid::Exponential { .. }) {
        assign_grid_levels(v, words, inv, levels, |a, u| exponential_level(pts, a, u));
    } else {
        assign_grid_levels(v, words, inv, levels, |a, u| nonuniform_level(pts, a, u));
    }
    scale
}

/// Scalar reference for [`quantize_bucket_into_grid`] — the pre-SIMD loop
/// (binary-search bracket for every non-uniform grid), kept verbatim as
/// the bit-identity oracle.
pub fn quantize_bucket_into_grid_scalar(
    v: &[f32],
    words: &[u8],
    grid: &LevelGrid,
    norm: Norm,
    levels: &mut [i32],
) -> f32 {
    let pts = match grid.nonzero_points() {
        None => return quantize_bucket_into_scalar(v, words, grid.s(), norm, levels),
        Some(pts) => pts,
    };
    debug_assert_eq!(words.len(), v.len() * 4);
    debug_assert_eq!(levels.len(), v.len());
    let scale = norm.scale(v);
    if !scale.is_normal() {
        levels.fill(0);
        return 0.0;
    }
    let inv = 1.0 / scale;
    for ((l, &x), c) in levels.iter_mut().zip(v).zip(words.chunks_exact(4)) {
        let word = u32::from_le_bytes([c[0], c[1], c[2], c[3]]);
        let u = (word >> 8) as f32 * (1.0 / (1u32 << 24) as f32);
        let a = (x.abs() * inv).min(1.0);
        let lev = nonuniform_level(pts, a, u) as i32;
        *l = if x.is_sign_negative() { -lev } else { lev };
    }
    scale
}

#[inline]
fn quantize_bucket_from_words(v: &[f32], words: &[u8], grid: &LevelGrid, norm: Norm) -> QuantBucket {
    let mut levels = vec![0i32; v.len()];
    let scale = quantize_bucket_into_grid(v, words, grid, norm, &mut levels);
    QuantBucket { scale, levels }
}

/// Full-gradient quantization with §4 bucketing: the vector is viewed as
/// consecutive buckets of `bucket_size` (last one may be shorter — the paper
/// reshapes tensors so "no receptive field is split across two buckets"; the
/// tensor-aware reshaping lives in `models::layout`).
pub fn quantize(
    v: &[f32],
    s: u32,
    bucket_size: usize,
    norm: Norm,
    rng: &mut dyn RngCore,
) -> QuantizedGradient {
    quantize_grid(v, &LevelGrid::uniform(s), bucket_size, norm, rng)
}

/// Grid-aware full-gradient quantization — [`quantize`] generalized over
/// [`LevelGrid`]. Consumes the RNG stream exactly as [`quantize`] does (one
/// `fill_bytes` per bucket), which the fused pipeline relies on for wire
/// bit-identity.
pub fn quantize_grid(
    v: &[f32],
    grid: &LevelGrid,
    bucket_size: usize,
    norm: Norm,
    rng: &mut dyn RngCore,
) -> QuantizedGradient {
    assert!(bucket_size >= 1);
    let chunk = bucket_size.min(v.len()).max(1);
    let mut words = vec![0u8; chunk * 4];
    let buckets = v
        .chunks(bucket_size)
        .map(|c| {
            let w = &mut words[..c.len() * 4];
            rng.fill_bytes(w);
            quantize_bucket_from_words(c, w, grid, norm)
        })
        .collect();
    QuantizedGradient {
        s: grid.s(),
        grid: grid.clone(),
        bucket_size,
        norm,
        n: v.len(),
        buckets,
    }
}

/// Deterministic variant of [`quantize`] with caller-supplied uniforms
/// (used by tests to cross-validate against the Pallas artifact).
pub fn quantize_with_uniforms(
    v: &[f32],
    u: &[f32],
    s: u32,
    bucket_size: usize,
    norm: Norm,
) -> QuantizedGradient {
    quantize_grid_with_uniforms(v, u, &LevelGrid::uniform(s), bucket_size, norm)
}

/// Deterministic grid-aware variant with caller-supplied uniforms.
pub fn quantize_grid_with_uniforms(
    v: &[f32],
    u: &[f32],
    grid: &LevelGrid,
    bucket_size: usize,
    norm: Norm,
) -> QuantizedGradient {
    assert_eq!(v.len(), u.len());
    let buckets = v
        .chunks(bucket_size)
        .zip(u.chunks(bucket_size))
        .map(|(c, uc)| quantize_bucket_with_uniforms_grid(c, uc, grid, norm))
        .collect();
    QuantizedGradient {
        s: grid.s(),
        grid: grid.clone(),
        bucket_size,
        norm,
        n: v.len(),
        buckets,
    }
}

/// The paper's full-vector `Q_s` (no bucketing: d = n, 2-norm) — the object
/// Lemma 3.1 / Theorem 3.2 are stated about.
pub fn quantize_paper(v: &[f32], s: u32, rng: &mut dyn RngCore) -> QuantizedGradient {
    quantize(v, s, v.len().max(1), Norm::L2, rng)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;
    

    fn rng(seed: u64) -> Xoshiro256 {
        Xoshiro256::from_u64(seed)
    }

    fn randn(n: usize, seed: u64) -> Vec<f32> {
        
        let mut r = rng(seed);
        (0..n).map(|_| crate::util::rng::uniform_f32(&mut r) * 2.0 - 1.0).collect()
    }

    #[test]
    fn zero_vector_quantizes_to_zero() {
        let q = quantize_paper(&[0.0; 16], 4, &mut rng(0));
        assert_eq!(q.dequantize(), vec![0.0; 16]);
        assert_eq!(q.nnz(), 0);
    }

    #[test]
    fn subnormal_scale_bucket_is_degenerate_on_nonuniform_grids() {
        // scale = 1e-45 (subnormal) would overflow 1/scale to +inf, sending
        // the zero coordinate to the top level; such buckets must transmit
        // all-zero instead.
        let grid = LevelGrid::exponential(4);
        let v = [1e-45f32, 0.0, -1e-45];
        let q = quantize_grid(&v, &grid, 3, Norm::Max, &mut rng(1));
        assert_eq!(q.buckets[0].scale, 0.0);
        assert_eq!(q.buckets[0].levels, vec![0, 0, 0]);
        let b = quantize_bucket_with_uniforms_grid(&v, &[0.5; 3], &grid, Norm::Max);
        assert_eq!(b.levels, vec![0, 0, 0]);
    }

    #[test]
    fn levels_bounded_by_s() {
        let v = randn(1000, 1);
        for s in [1u32, 2, 7, 255] {
            let q = quantize_paper(&v, s, &mut rng(2));
            for b in &q.buckets {
                assert!(b.levels.iter().all(|&l| l.unsigned_abs() <= s));
            }
        }
    }

    #[test]
    fn max_norm_extremal_coordinate_hits_top_level() {
        // With max-norm, the largest |v_i| has r = s exactly ⇒ level s.
        let v = [0.1f32, -2.0, 0.5];
        let q = quantize(&v, 4, 3, Norm::Max, &mut rng(3));
        assert_eq!(q.buckets[0].levels[1], -4);
        assert!((q.buckets[0].scale - 2.0).abs() < 1e-6);
    }

    #[test]
    fn error_within_one_level() {
        // |Q_s(v)_i − v_i| ≤ F(b)/s always (randomized rounding moves at most
        // one level).
        let v = randn(512, 4);
        for norm in [Norm::L2, Norm::Max] {
            let q = quantize(&v, 7, 64, norm, &mut rng(5));
            let d = q.dequantize();
            let mut off = 0;
            for b in &q.buckets {
                for i in 0..b.levels.len() {
                    assert!((d[off + i] - v[off + i]).abs() <= b.scale / 7.0 + 1e-6);
                }
                off += b.levels.len();
            }
        }
    }

    #[test]
    fn unbiasedness_monte_carlo() {
        // Lemma 3.1(i): E[Q_s(v)] = v.
        let v = randn(64, 6);
        let s = 2;
        let trials = 3000;
        let mut acc = vec![0.0f64; 64];
        let mut r = rng(7);
        for _ in 0..trials {
            let q = quantize_paper(&v, s, &mut r);
            for (a, x) in acc.iter_mut().zip(q.dequantize()) {
                *a += x as f64;
            }
        }
        let norm = Norm::L2.scale(&v) as f64;
        let tol = 5.0 * norm / (s as f64 * (trials as f64).sqrt());
        for i in 0..64 {
            assert!(
                (acc[i] / trials as f64 - v[i] as f64).abs() < tol,
                "coordinate {i} biased"
            );
        }
    }

    #[test]
    fn variance_bound_lemma_3_1() {
        // Lemma 3.1(ii): E‖Q_s(v) − v‖² ≤ min(n/s², √n/s)·‖v‖².
        let n = 256;
        let v = randn(n, 8);
        let vnorm2: f64 = v.iter().map(|&x| (x as f64).powi(2)).sum();
        for s in [1u32, 4, 16] {
            let bound = ((n as f64) / (s as f64).powi(2)).min((n as f64).sqrt() / s as f64) * vnorm2;
            let trials = 800;
            let mut tot = 0.0f64;
            let mut r = rng(s as u64);
            for _ in 0..trials {
                let q = quantize_paper(&v, s, &mut r);
                let d = q.dequantize();
                tot += v
                    .iter()
                    .zip(&d)
                    .map(|(&a, &b)| ((a - b) as f64).powi(2))
                    .sum::<f64>();
            }
            assert!(tot / trials as f64 <= bound * 1.05, "s={s}");
        }
    }

    #[test]
    fn sparsity_bound_lemma_3_1() {
        // Lemma 3.1(iii): E‖Q_s(v)‖₀ ≤ s(s + √n).
        let n = 4096;
        let v = randn(n, 9);
        let s = 2u32;
        let trials = 200;
        let mut r = rng(11);
        let tot: usize = (0..trials).map(|_| quantize_paper(&v, s, &mut r).nnz()).sum();
        let bound = s as f64 * (s as f64 + (n as f64).sqrt());
        assert!(tot as f64 / trials as f64 <= bound * 1.05);
    }

    #[test]
    fn bucketing_is_independent_per_bucket() {
        // Quantizing [a | b] with bucket d must equal quantizing a and b
        // separately (same uniforms).
        let v = randn(128, 12);
        let u: Vec<f32> = randn(128, 13).iter().map(|x| (x + 1.0) / 2.0).collect();
        let q = quantize_with_uniforms(&v, &u, 7, 64, Norm::L2);
        let qa = quantize_bucket_with_uniforms(&v[..64], &u[..64], 7, Norm::L2);
        let qb = quantize_bucket_with_uniforms(&v[64..], &u[64..], 7, Norm::L2);
        assert_eq!(q.buckets, vec![qa, qb]);
    }

    #[test]
    fn simd_paths_match_scalar_oracles_on_awkward_tails() {
        // Full adversarial coverage lives in tests/simd_levels.rs; this
        // pins the lane/tail split itself for every length around the
        // 8-lane boundary, per grid family.
        let mut r = rng(21);
        for n in 0..=33usize {
            let v = randn(n, 100 + n as u64);
            let mut words = vec![0u8; n * 4];
            r.fill_bytes(&mut words);
            for grid in [
                LevelGrid::uniform(7),
                LevelGrid::exponential(4),
                LevelGrid::custom(vec![0.1, 0.45, 1.0]).unwrap(),
            ] {
                for norm in [Norm::L2, Norm::Max] {
                    let mut a = vec![0i32; n];
                    let mut b = vec![0i32; n];
                    let sa = quantize_bucket_into_grid(&v, &words, &grid, norm, &mut a);
                    let sb = quantize_bucket_into_grid_scalar(&v, &words, &grid, norm, &mut b);
                    assert_eq!(sa.to_bits(), sb.to_bits(), "scale n={n} {}", grid.label());
                    assert_eq!(a, b, "levels n={n} {norm:?} {}", grid.label());
                }
            }
        }
    }

    #[test]
    fn ragged_tail_bucket() {
        let v = randn(100, 14);
        let q = quantize(&v, 4, 64, Norm::Max, &mut rng(15));
        assert_eq!(q.buckets.len(), 2);
        assert_eq!(q.buckets[1].levels.len(), 36);
        assert_eq!(q.dequantize().len(), 100);
    }
}
