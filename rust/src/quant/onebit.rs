//! 1BitSGD baseline (Seide et al. 2014), as compared against in Appendix E.
//!
//! Each coordinate is reduced to its sign; per column (here: per bucket, the
//! CNTK implementation quantizes per matrix column — see the Appendix-E
//! discussion of that artefact) two reconstruction values are transmitted:
//! the mean of the positive entries and the mean of the negative entries.
//! The quantization *error is fed back*: the residual is added to the next
//! step's gradient, which is what makes the heuristic converge in practice
//! (delta-sigma modulation) but is also why it needs an extra model-sized
//! state buffer — the paper notes QSGD avoids this ("quantization on the
//! fly, without error accumulation").
//!
//! Wire cost: 1 bit per coordinate + 2 floats per column (paper §1: "a cost
//! of n bits and two floats per iteration" for column = n).

use crate::coding::bitstream::{BitReader, BitWriter};
use crate::quant::{Codec, EncodeSession, WireFormat};
use crate::util::rng::Xoshiro256;

/// Stateful 1BitSGD quantizer (holds the error-feedback residual and the
/// reusable output bitstream — one instance per worker session).
pub struct OneBitSgd {
    /// Column length used for the two reconstruction means.
    pub column: usize,
    residual: Vec<f32>,
    writer: BitWriter,
}

impl OneBitSgd {
    pub fn new(n: usize, column: usize) -> Self {
        assert!(column >= 1);
        Self { column, residual: vec![0.0; n], writer: BitWriter::new() }
    }

    /// Quantize `grad + residual`, update the residual, write the message
    /// into `out` (cleared first). All scratch — the residual and the
    /// bitstream buffer — is owned and reused, so steady-state encodes
    /// perform zero heap allocations. The residual sizes itself to the
    /// *first* gradient encoded (sessions are created before the layout is
    /// known); any later length change is a caller bug — error feedback is
    /// only meaningful against a fixed layout — and panics rather than
    /// silently discarding the carried error.
    pub fn encode_into(&mut self, grad: &[f32], out: &mut Vec<u8>) {
        let n = grad.len();
        if self.residual.len() != n {
            assert!(
                self.residual.is_empty(),
                "1BitSGD session fed a different gradient length: {} then {n}",
                self.residual.len()
            );
            self.residual.resize(n, 0.0);
        }
        let column = self.column;
        let Self { residual, writer, .. } = self;
        writer.reset();
        writer.reserve(n / 8 + (n / column + 1) * 8 + 16);
        // Header: none needed (n, column are out-of-band via config).
        for (ci, chunk) in grad.chunks(column).enumerate() {
            let off = ci * column;
            // effective gradient = grad + carried error (computed on the
            // fly — no materialised `eff` buffer)
            let res = &mut residual[off..off + chunk.len()];
            let (mut psum, mut pcnt, mut nsum, mut ncnt) = (0.0f64, 0usize, 0.0f64, 0usize);
            for (&g, &r) in chunk.iter().zip(res.iter()) {
                let x = g + r;
                if x >= 0.0 {
                    psum += x as f64;
                    pcnt += 1;
                } else {
                    nsum += x as f64;
                    ncnt += 1;
                }
            }
            let pmean = if pcnt > 0 { (psum / pcnt as f64) as f32 } else { 0.0 };
            let nmean = if ncnt > 0 { (nsum / ncnt as f64) as f32 } else { 0.0 };
            writer.write_f32(pmean);
            writer.write_f32(nmean);
            for (&g, r) in chunk.iter().zip(res.iter_mut()) {
                let x = g + *r;
                let neg = x < 0.0;
                writer.write_bit(neg);
                let recon = if neg { nmean } else { pmean };
                *r = x - recon;
            }
        }
        out.clear();
        out.extend_from_slice(writer.finish());
    }

    /// [`Self::encode_into`] allocating the returned message.
    pub fn compress(&mut self, grad: &[f32]) -> Vec<u8> {
        let mut out = Vec::new();
        self.encode_into(grad, &mut out);
        out
    }

    /// Decode a peer's message into a dense gradient.
    pub fn decompress(msg: &[u8], n: usize, column: usize) -> anyhow::Result<Vec<f32>> {
        let mut r = BitReader::new(msg);
        let mut out = Vec::with_capacity(n);
        let mut remaining = n;
        while remaining > 0 {
            let len = remaining.min(column);
            let pmean = r.read_f32()?;
            let nmean = r.read_f32()?;
            for _ in 0..len {
                out.push(if r.read_bit()? { nmean } else { pmean });
            }
            remaining -= len;
        }
        Ok(out)
    }

    /// Message size in bits for a gradient of length `n` (exact, for the
    /// cost model): 64 bits per column + 1 bit per coordinate.
    pub fn message_bits(n: usize, column: usize) -> u64 {
        let cols = n.div_ceil(column) as u64;
        cols * 64 + n as u64
    }

    pub fn residual(&self) -> &[f32] {
        &self.residual
    }

    pub fn reset(&mut self) {
        self.residual.iter_mut().for_each(|r| *r = 0.0);
    }
}

/// Shared 1BitSGD codec. The decode side is stateless (`&self`); the error
/// feedback — 1BitSGD's defining per-worker state — lives in the session,
/// which is exactly the split the session API exists for.
pub struct OneBitCodec {
    pub column: usize,
}

impl OneBitCodec {
    pub fn new(column: usize) -> Self {
        assert!(column >= 1);
        Self { column }
    }
}

impl Codec for OneBitCodec {
    fn session(&self, _rng: Xoshiro256) -> Box<dyn EncodeSession> {
        // Deterministic scheme — the RNG is unused; the residual sizes
        // itself to the first gradient encoded.
        Box::new(OneBitSession { q: OneBitSgd::new(0, self.column) })
    }

    fn decode(&self, msg: &[u8], n: usize) -> anyhow::Result<Vec<f32>> {
        OneBitSgd::decompress(msg, n, self.column)
    }

    fn decode_add_threads(
        &self,
        msg: &[u8],
        alpha: f32,
        acc: &mut [f32],
        _threads: usize,
    ) -> anyhow::Result<()> {
        let mut r = BitReader::new(msg);
        let mut off = 0usize;
        let n = acc.len();
        while off < n {
            let len = (n - off).min(self.column);
            let pmean = r.read_f32()?;
            let nmean = r.read_f32()?;
            for a in &mut acc[off..off + len] {
                *a += alpha * if r.read_bit()? { nmean } else { pmean };
            }
            off += len;
        }
        Ok(())
    }

    fn encoded_size_hint(&self, n: usize) -> usize {
        OneBitSgd::message_bits(n, self.column).div_ceil(8) as usize
    }

    fn wire_format(&self) -> WireFormat {
        WireFormat::SignColumns { column: self.column }
    }

    fn chunk_align(&self) -> usize {
        self.column
    }

    fn supports_chunked_encode(&self) -> bool {
        // the session's error-feedback residual pins one gradient layout at
        // first use — it cannot re-encode arbitrary partial-sum chunks
        false
    }

    fn name(&self) -> String {
        format!("1bit(col={})", self.column)
    }
}

/// Per-worker 1BitSGD session: owns the residual and the bitstream scratch.
struct OneBitSession {
    q: OneBitSgd,
}

impl EncodeSession for OneBitSession {
    fn encode_into(&mut self, grad: &[f32], out: &mut Vec<u8>) {
        self.q.encode_into(grad, out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_and_size() {
        let g: Vec<f32> = (0..100).map(|i| (i as f32 - 50.0) / 10.0).collect();
        let mut q = OneBitSgd::new(100, 32);
        let msg = q.compress(&g);
        assert_eq!(msg.len() as u64, OneBitSgd::message_bits(100, 32).div_ceil(8));
        let d = OneBitSgd::decompress(&msg, 100, 32).unwrap();
        assert_eq!(d.len(), 100);
        // signs must match (first step: residual = 0)
        for (x, y) in g.iter().zip(&d) {
            if *x > 0.0 {
                assert!(*y >= 0.0);
            }
            if *x < 0.0 {
                assert!(*y <= 0.0);
            }
        }
    }

    #[test]
    fn error_feedback_preserves_mass() {
        // Σ decoded + residual == Σ effective gradient per column (the
        // delta-sigma property: no gradient mass is ever lost).
        let g: Vec<f32> = (0..64).map(|i| ((i * 37) % 13) as f32 - 6.0).collect();
        let mut q = OneBitSgd::new(64, 64);
        let msg = q.compress(&g);
        let d = OneBitSgd::decompress(&msg, 64, 64).unwrap();
        for i in 0..64 {
            assert!((d[i] + q.residual()[i] - g[i]).abs() < 1e-5);
        }
    }

    #[test]
    fn residual_drives_later_steps() {
        // A coordinate too small to flip the sign on step 1 must eventually
        // be transmitted thanks to error feedback.
        let mut q = OneBitSgd::new(2, 2);
        let g = [1.0f32, -0.1];
        let mut acc = [0.0f32; 2];
        for _ in 0..50 {
            let msg = q.compress(&g);
            let d = OneBitSgd::decompress(&msg, 2, 2).unwrap();
            acc[0] += d[0];
            acc[1] += d[1];
        }
        // over 50 steps the *average* transmitted value approaches g
        assert!((acc[0] / 50.0 - 1.0).abs() < 0.1);
        assert!((acc[1] / 50.0 + 0.1).abs() < 0.05);
    }

    #[test]
    fn zero_gradient() {
        let mut q = OneBitSgd::new(8, 4);
        let msg = q.compress(&[0.0; 8]);
        let d = OneBitSgd::decompress(&msg, 8, 4).unwrap();
        assert_eq!(d, vec![0.0; 8]);
    }

    #[test]
    fn codec_decode_add_matches_decode_then_add() {
        let g: Vec<f32> = (0..100).map(|i| (i as f32 - 50.0) / 10.0).collect();
        let codec = OneBitCodec::new(32);
        let mut sess = codec.session(crate::util::rng::Xoshiro256::from_u64(0));
        let msg = sess.compress(&g);
        assert_eq!(msg.len(), codec.encoded_size_hint(100), "hint is exact for 1bit");
        let dec = codec.decode(&msg, 100).unwrap();
        let mut acc = vec![0.25f32; 100];
        codec.decode_add(&msg, 0.5, &mut acc).unwrap();
        for (a, &x) in acc.iter().zip(&dec) {
            assert_eq!(*a, 0.25 + 0.5 * x);
        }
        // truncation is rejected
        assert!(codec.decode(&msg[..msg.len() - 1], 100).is_err());
        let mut acc = vec![0.0f32; 100];
        assert!(codec.decode_add(&msg[..msg.len() - 1], 1.0, &mut acc).is_err());
    }
}
