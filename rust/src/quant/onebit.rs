//! 1BitSGD baseline (Seide et al. 2014), as compared against in Appendix E.
//!
//! Each coordinate is reduced to its sign; per column (here: per bucket, the
//! CNTK implementation quantizes per matrix column — see the Appendix-E
//! discussion of that artefact) two reconstruction values are transmitted:
//! the mean of the positive entries and the mean of the negative entries.
//! The quantization *error is fed back*: the residual is added to the next
//! step's gradient, which is what makes the heuristic converge in practice
//! (delta-sigma modulation) but is also why it needs an extra model-sized
//! state buffer — the paper notes QSGD avoids this ("quantization on the
//! fly, without error accumulation").
//!
//! Wire cost: 1 bit per coordinate + 2 floats per column (paper §1: "a cost
//! of n bits and two floats per iteration" for column = n).

use crate::coding::bitstream::{BitReader, BitWriter};

/// Stateful 1BitSGD quantizer (holds the error-feedback residual).
pub struct OneBitSgd {
    /// Column length used for the two reconstruction means.
    pub column: usize,
    residual: Vec<f32>,
}

impl OneBitSgd {
    pub fn new(n: usize, column: usize) -> Self {
        assert!(column >= 1);
        Self { column, residual: vec![0.0; n] }
    }

    /// Quantize `grad + residual`, update the residual, return the message.
    pub fn compress(&mut self, grad: &[f32]) -> Vec<u8> {
        assert_eq!(grad.len(), self.residual.len());
        let n = grad.len();
        let mut w = BitWriter::with_capacity(n / 8 + (n / self.column + 1) * 8 + 16);
        // Header: none needed (n, column are out-of-band via config).
        for (ci, chunk) in grad.chunks(self.column).enumerate() {
            let off = ci * self.column;
            // effective gradient = grad + carried error
            let eff: Vec<f32> = chunk
                .iter()
                .zip(&self.residual[off..off + chunk.len()])
                .map(|(&g, &r)| g + r)
                .collect();
            let (mut psum, mut pcnt, mut nsum, mut ncnt) = (0.0f64, 0usize, 0.0f64, 0usize);
            for &x in &eff {
                if x >= 0.0 {
                    psum += x as f64;
                    pcnt += 1;
                } else {
                    nsum += x as f64;
                    ncnt += 1;
                }
            }
            let pmean = if pcnt > 0 { (psum / pcnt as f64) as f32 } else { 0.0 };
            let nmean = if ncnt > 0 { (nsum / ncnt as f64) as f32 } else { 0.0 };
            w.write_f32(pmean);
            w.write_f32(nmean);
            for (j, &x) in eff.iter().enumerate() {
                let neg = x < 0.0;
                w.write_bit(neg);
                let recon = if neg { nmean } else { pmean };
                self.residual[off + j] = x - recon;
            }
        }
        w.into_bytes()
    }

    /// Decode a peer's message into a dense gradient.
    pub fn decompress(msg: &[u8], n: usize, column: usize) -> anyhow::Result<Vec<f32>> {
        let mut r = BitReader::new(msg);
        let mut out = Vec::with_capacity(n);
        let mut remaining = n;
        while remaining > 0 {
            let len = remaining.min(column);
            let pmean = r.read_f32()?;
            let nmean = r.read_f32()?;
            for _ in 0..len {
                out.push(if r.read_bit()? { nmean } else { pmean });
            }
            remaining -= len;
        }
        Ok(out)
    }

    /// Message size in bits for a gradient of length `n` (exact, for the
    /// cost model): 64 bits per column + 1 bit per coordinate.
    pub fn message_bits(n: usize, column: usize) -> u64 {
        let cols = n.div_ceil(column) as u64;
        cols * 64 + n as u64
    }

    pub fn residual(&self) -> &[f32] {
        &self.residual
    }

    pub fn reset(&mut self) {
        self.residual.iter_mut().for_each(|r| *r = 0.0);
    }
}

impl super::Compressor for OneBitSgd {
    fn compress(&mut self, grad: &[f32], _rng: &mut dyn rand_core::RngCore) -> Vec<u8> {
        OneBitSgd::compress(self, grad)
    }

    fn decompress(&self, msg: &[u8], n: usize) -> anyhow::Result<Vec<f32>> {
        OneBitSgd::decompress(msg, n, self.column)
    }

    fn name(&self) -> String {
        format!("1bit(col={})", self.column)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_and_size() {
        let g: Vec<f32> = (0..100).map(|i| (i as f32 - 50.0) / 10.0).collect();
        let mut q = OneBitSgd::new(100, 32);
        let msg = q.compress(&g);
        assert_eq!(msg.len() as u64, OneBitSgd::message_bits(100, 32).div_ceil(8));
        let d = OneBitSgd::decompress(&msg, 100, 32).unwrap();
        assert_eq!(d.len(), 100);
        // signs must match (first step: residual = 0)
        for (x, y) in g.iter().zip(&d) {
            if *x > 0.0 {
                assert!(*y >= 0.0);
            }
            if *x < 0.0 {
                assert!(*y <= 0.0);
            }
        }
    }

    #[test]
    fn error_feedback_preserves_mass() {
        // Σ decoded + residual == Σ effective gradient per column (the
        // delta-sigma property: no gradient mass is ever lost).
        let g: Vec<f32> = (0..64).map(|i| ((i * 37) % 13) as f32 - 6.0).collect();
        let mut q = OneBitSgd::new(64, 64);
        let msg = q.compress(&g);
        let d = OneBitSgd::decompress(&msg, 64, 64).unwrap();
        for i in 0..64 {
            assert!((d[i] + q.residual()[i] - g[i]).abs() < 1e-5);
        }
    }

    #[test]
    fn residual_drives_later_steps() {
        // A coordinate too small to flip the sign on step 1 must eventually
        // be transmitted thanks to error feedback.
        let mut q = OneBitSgd::new(2, 2);
        let g = [1.0f32, -0.1];
        let mut acc = [0.0f32; 2];
        for _ in 0..50 {
            let msg = q.compress(&g);
            let d = OneBitSgd::decompress(&msg, 2, 2).unwrap();
            acc[0] += d[0];
            acc[1] += d[1];
        }
        // over 50 steps the *average* transmitted value approaches g
        assert!((acc[0] / 50.0 - 1.0).abs() < 0.1);
        assert!((acc[1] / 50.0 + 0.1).abs() < 0.05);
    }

    #[test]
    fn zero_gradient() {
        let mut q = OneBitSgd::new(8, 4);
        let msg = q.compress(&[0.0; 8]);
        let d = OneBitSgd::decompress(&msg, 8, 4).unwrap();
        assert_eq!(d, vec![0.0; 8]);
    }
}
