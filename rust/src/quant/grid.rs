//! Quantization level grids — the generalization that turns the fused
//! pipeline into a compressor *family*.
//!
//! QSGD (§3.1) places its `s + 1` levels uniformly on `[0, 1]`; NUQSGD
//! (Ramezani-Kebrya et al., PAPERS.md) shows that for normalized gradients an
//! *exponentially spaced* grid `{0, 2^-p, …, 1/2, 1}` strictly improves the
//! variance bound at the same bit budget, because stochastic-rounding noise
//! on a coordinate is proportional to the local grid gap and most normalized
//! coordinates are small. [`LevelGrid`] captures all three shapes the stack
//! supports:
//!
//! * [`LevelGrid::Uniform`] — the paper's `{0, 1/s, …, 1}`. Quantization and
//!   dequantization ride the *original* QSGD arithmetic (`r = |v|·s/F(b)`),
//!   so uniform frames and levels are bit-identical to the pre-grid code.
//! * [`LevelGrid::Exponential`] — NUQSGD's `{0, 2^-(s-1), …, 1/2, 1}` with
//!   `s` nonzero levels (all exact powers of two, exactly representable).
//! * [`LevelGrid::Custom`] — any strictly increasing set of nonzero
//!   normalized levels ending at 1 (validated; transmitted in-band on the
//!   wire, see `coding::gradient`).
//!
//! A grid only changes *which* level a coordinate rounds to and *what value*
//! a level dequantizes to. Level indices stay signed integers in `[-s, s]`,
//! so the shared Elias codecs (`coding::gradient::encode_levels_*`) are
//! untouched — that is the extension point every later scheme reuses.

use std::sync::Arc;

/// The set of normalized magnitude levels `0 = ℓ_0 < ℓ_1 < … < ℓ_s = 1` a
/// quantizer rounds onto. Cheap to clone (non-uniform point sets are
/// `Arc`-shared), so per-worker compressors can carry their own copy.
#[derive(Debug, Clone, PartialEq)]
pub enum LevelGrid {
    /// Uniform QSGD grid `{0, 1/s, …, 1}`.
    Uniform { s: u32 },
    /// NUQSGD exponential grid: nonzero levels `{2^-(s-1), …, 1/2, 1}`.
    Exponential { points: Arc<[f32]> },
    /// User-supplied monotone grid: nonzero levels, strictly increasing,
    /// last exactly 1.0.
    Custom { points: Arc<[f32]> },
}

/// Largest custom-grid size accepted (bounds what a frame header may ask the
/// decoder to allocate; also keeps levels well inside the Elias LUT range).
pub const MAX_CUSTOM_LEVELS: usize = 4096;

/// Largest exponential-grid size: `2^-(s-1)` must stay a *normal* f32.
pub const MAX_EXPONENTIAL_LEVELS: u32 = 127;

impl LevelGrid {
    /// The paper's uniform grid with `s ≥ 1` levels.
    pub fn uniform(s: u32) -> Self {
        assert!(s >= 1, "need at least one nonzero level");
        LevelGrid::Uniform { s }
    }

    /// Exponential grid with `s` nonzero levels `{2^-(s-1), …, 1/2, 1}`.
    pub fn exponential(s: u32) -> Self {
        assert!(
            (1..=MAX_EXPONENTIAL_LEVELS).contains(&s),
            "exponential grid needs 1..={MAX_EXPONENTIAL_LEVELS} levels, got {s}"
        );
        let points: Vec<f32> = (0..s).map(|i| 2.0f32.powi(i as i32 + 1 - s as i32)).collect();
        LevelGrid::Exponential { points: points.into() }
    }

    /// NUQSGD's grid as written in the paper: `{0, 1/2^p, …, 1/2, 1}`
    /// (`p + 1` nonzero levels).
    pub fn nuqsgd(p: u32) -> Self {
        Self::exponential(p + 1)
    }

    /// Arbitrary monotone grid from its nonzero levels. Validates the shape
    /// the codecs and the stochastic rounding rely on; also used to vet
    /// grids arriving *from the wire*, so it must reject rather than panic.
    pub fn custom(points: Vec<f32>) -> anyhow::Result<Self> {
        anyhow::ensure!(!points.is_empty(), "custom grid needs at least one level");
        anyhow::ensure!(
            points.len() <= MAX_CUSTOM_LEVELS,
            "custom grid too large: {} > {MAX_CUSTOM_LEVELS}",
            points.len()
        );
        anyhow::ensure!(
            points.iter().all(|p| p.is_finite()),
            "custom grid levels must be finite"
        );
        anyhow::ensure!(points[0] > 0.0, "custom grid levels must be positive");
        anyhow::ensure!(
            points.windows(2).all(|w| w[0] < w[1]),
            "custom grid levels must be strictly increasing"
        );
        anyhow::ensure!(
            *points.last().unwrap() == 1.0,
            "custom grid must end at 1.0 (levels are normalized)"
        );
        Ok(LevelGrid::Custom { points: points.into() })
    }

    /// Number of nonzero levels `s` (level indices span `[-s, s]`).
    pub fn s(&self) -> u32 {
        match self {
            LevelGrid::Uniform { s } => *s,
            LevelGrid::Exponential { points } | LevelGrid::Custom { points } => {
                points.len() as u32
            }
        }
    }

    pub fn is_uniform(&self) -> bool {
        matches!(self, LevelGrid::Uniform { .. })
    }

    /// The nonzero level values, or `None` for the uniform grid (whose
    /// levels are computed arithmetically on the hot paths).
    pub fn nonzero_points(&self) -> Option<&[f32]> {
        match self {
            LevelGrid::Uniform { .. } => None,
            LevelGrid::Exponential { points } | LevelGrid::Custom { points } => Some(points),
        }
    }

    /// Normalized value of level `j ∈ [0, s]`.
    pub fn value(&self, j: u32) -> f32 {
        debug_assert!(j <= self.s());
        match self {
            LevelGrid::Uniform { s } => j as f32 / *s as f32,
            LevelGrid::Exponential { points } | LevelGrid::Custom { points } => {
                if j == 0 {
                    0.0
                } else {
                    points[j as usize - 1]
                }
            }
        }
    }

    /// Reference level assignment: stochastically round normalized magnitude
    /// `a ∈ [0, 1]` onto the grid with uniform draw `u ∈ [0, 1)`. Unbiased:
    /// `E[value(level)] = a`.
    ///
    /// NOTE: the bucket quantizers ([`crate::quant::stochastic`]) short-circuit
    /// `Uniform` through the original `r = a·s` arithmetic so existing frames
    /// stay bit-identical; this method is the grid-agnostic semantics used by
    /// the non-uniform hot path and by tests.
    pub fn level_of(&self, a: f32, u: f32) -> u32 {
        match self {
            LevelGrid::Uniform { s } => {
                let r = (a * *s as f32).min(*s as f32);
                let lo = r as u32;
                lo + (u < r - lo as f32) as u32
            }
            LevelGrid::Exponential { points } | LevelGrid::Custom { points } => {
                nonuniform_level(points, a, u)
            }
        }
    }

    /// Exact conditional variance of the *normalized* rounded value at
    /// magnitude `a ∈ [0, 1]`: `(a − ℓ_j)(ℓ_{j+1} − a)` for the bracketing
    /// levels (0 when `a` sits on a grid point). Multiply by `F(b)²` for the
    /// per-coordinate quantization variance.
    pub fn rounding_variance(&self, a: f32) -> f64 {
        let a = f64::from(a.clamp(0.0, 1.0));
        let s = self.s();
        // bracketing levels via the deterministic assignment (u = 1 never
        // rounds up, so level_of(a, 1.0) is the lower bracket)
        let j = self.level_of(a as f32, 1.0);
        if j >= s {
            return 0.0;
        }
        let lo = f64::from(self.value(j));
        let hi = f64::from(self.value(j + 1));
        (a - lo).max(0.0) * (hi - a).max(0.0)
    }

    /// Rigorous envelope on the relative quantization variance
    /// `E‖Q(v) − v‖² / ‖v‖²` for a 2-norm bucket of dimension `d`.
    ///
    /// * Uniform: the paper's Lemma 3.1(ii), `min(d/s², √d/s)`.
    /// * Non-uniform: per-coordinate stochastic rounding gives variance
    ///   `(ℓ_{j+1} − ℓ_j)²/4` above the smallest level (each gap is at most
    ///   `ε·ℓ_j` with `ε = max gap ratio`, so the sum telescopes against
    ///   `Σ a_i² = 1`), plus `ℓ_1·Σ a_i ≤ ℓ_1·√d` below it:
    ///   `ε²/4 + ℓ_1·√d`. For the exponential grid `ε = 1`, recovering the
    ///   NUQSGD-style `1/4 + 2^-(s-1)·√d` shape.
    pub fn variance_bound(&self, d: usize) -> f64 {
        match self {
            LevelGrid::Uniform { s } => super::variance_bound(d, *s),
            LevelGrid::Exponential { points } | LevelGrid::Custom { points } => {
                let mut eps: f64 = 1.0; // gap below the first level, relative to it
                for w in points.windows(2) {
                    eps = eps.max(f64::from(w[1] - w[0]) / f64::from(w[0]));
                }
                eps * eps / 4.0 + f64::from(points[0]) * (d as f64).sqrt()
            }
        }
    }

    /// Human-readable tag used in compressor names.
    pub fn label(&self) -> String {
        match self {
            LevelGrid::Uniform { s } => format!("uniform(s={s})"),
            LevelGrid::Exponential { points } => format!("nuqsgd(s={})", points.len()),
            LevelGrid::Custom { points } => format!("custom(s={})", points.len()),
        }
    }
}

/// Stochastic rounding onto a non-uniform point set: find the bracketing
/// levels by binary search, round up with probability proportional to the
/// position inside the gap. `pts` is strictly increasing with last == 1.0;
/// `a ∈ [0, 1]` (callers clamp). Allocation-free — safe for the fused
/// zero-alloc pipeline.
#[inline]
pub(crate) fn nonuniform_level(pts: &[f32], a: f32, u: f32) -> u32 {
    let j = lower_bracket(pts, a);
    round_in_bracket(pts, a, u, j)
}

/// Lower bracketing level of `a` by binary search: the number of nonzero
/// grid points ≤ `a`.
#[inline]
fn lower_bracket(pts: &[f32], a: f32) -> usize {
    pts.partition_point(|&g| g <= a)
}

/// Exponent-extraction variant of [`nonuniform_level`] for the
/// *exponential* grid `pts[i] = 2^(i+1-s)`: the lower bracket of `a` is
/// just `clamp(e + s, 0, s)` with `e` = `a`'s biased-corrected IEEE
/// exponent, replacing the per-coordinate binary search with two integer
/// ops. Bit-identical to [`nonuniform_level`] on exponential points for
/// every `a ∈ [0, 1]`: exact powers of two carry a zero mantissa so the
/// `≤` boundary lands on the same side, and ±0/subnormal `a` fall in
/// bracket 0 because the smallest grid point `2^(1-s)` (`s ≤ 127`) is
/// normal. The rounding arithmetic is shared, so `p` is the same float.
#[inline(always)]
pub(crate) fn exponential_level(pts: &[f32], a: f32, u: f32) -> u32 {
    let s = pts.len() as i32;
    let e = ((a.to_bits() >> 23) & 0xff) as i32 - 127;
    let j = (e + s).clamp(0, s) as usize;
    round_in_bracket(pts, a, u, j)
}

/// Shared stochastic-rounding tail: given the lower bracket `j`, round up
/// with probability equal to `a`'s position inside the gap.
#[inline(always)]
fn round_in_bracket(pts: &[f32], a: f32, u: f32, j: usize) -> u32 {
    if j == pts.len() {
        return j as u32; // a == 1.0 (top level; NaN inputs clamp here too)
    }
    let lo = if j == 0 { 0.0 } else { pts[j - 1] };
    let hi = pts[j];
    let p = (a - lo) / (hi - lo);
    j as u32 + (u < p) as u32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exponential_points_are_powers_of_two() {
        let g = LevelGrid::exponential(4);
        assert_eq!(g.s(), 4);
        assert_eq!(g.nonzero_points().unwrap(), [0.125, 0.25, 0.5, 1.0]);
        assert_eq!(g.value(0), 0.0);
        assert_eq!(g.value(4), 1.0);
        // the ISSUE's notation: {0, 1/2^p, …, 1/2, 1}
        assert_eq!(LevelGrid::nuqsgd(3), LevelGrid::exponential(4));
    }

    #[test]
    fn uniform_matches_arithmetic_grid() {
        let g = LevelGrid::uniform(4);
        for j in 0..=4 {
            assert!((g.value(j) - j as f32 / 4.0).abs() < 1e-9);
        }
        assert_eq!(g.level_of(0.5, 0.99), 2);
        assert_eq!(g.level_of(0.6, 0.39), 3); // r = 2.4, p = 0.4 > u
        assert_eq!(g.level_of(0.6, 0.41), 2);
        assert_eq!(g.level_of(1.0, 0.0), 4);
    }

    #[test]
    fn custom_validation() {
        assert!(LevelGrid::custom(vec![]).is_err());
        assert!(LevelGrid::custom(vec![0.5]).is_err()); // doesn't end at 1
        assert!(LevelGrid::custom(vec![0.5, 0.5, 1.0]).is_err()); // not strict
        assert!(LevelGrid::custom(vec![-0.5, 1.0]).is_err());
        assert!(LevelGrid::custom(vec![0.0, 1.0]).is_err()); // zero is implicit
        assert!(LevelGrid::custom(vec![f32::NAN, 1.0]).is_err());
        assert!(LevelGrid::custom(vec![0.1, 0.7, 1.0]).is_ok());
        assert!(LevelGrid::custom(vec![1.0]).is_ok());
    }

    #[test]
    fn nonuniform_rounding_brackets_and_is_exact_on_points() {
        let g = LevelGrid::exponential(3); // {0, 0.25, 0.5, 1}
        // exact grid points map to themselves regardless of u
        for (a, want) in [(0.0, 0), (0.25, 1), (0.5, 2), (1.0, 3)] {
            assert_eq!(g.level_of(a, 0.0), want, "a={a}");
            assert_eq!(g.level_of(a, 0.999), want, "a={a}");
        }
        // 0.375 is halfway between levels 1 and 2
        assert_eq!(g.level_of(0.375, 0.49), 2);
        assert_eq!(g.level_of(0.375, 0.51), 1);
        // below the smallest nonzero level
        assert_eq!(g.level_of(0.1, 0.39), 1); // p = 0.4
        assert_eq!(g.level_of(0.1, 0.41), 0);
    }

    #[test]
    fn exponential_level_matches_binary_search_everywhere() {
        // The SIMD fast path must agree with partition_point on every
        // bracket boundary: exact grid points, values straddling them,
        // subnormals, ±0 and 1.0, for shallow and maximal grids.
        for s in [1u32, 2, 3, 4, 7, 8, 64, 127] {
            let g = LevelGrid::exponential(s);
            let pts = g.nonzero_points().unwrap();
            let mut probes: Vec<f32> = vec![0.0, 1.0, f32::MIN_POSITIVE, 1e-45, 1e-40, 0.3, 0.7];
            for &p in pts {
                probes.push(p);
                probes.push(f32::from_bits(p.to_bits() - 1)); // just below
                probes.push((f32::from_bits(p.to_bits() + 1)).min(1.0)); // just above
            }
            for &a in &probes {
                for u in [0.0f32, 0.25, 0.5, 0.9999] {
                    assert_eq!(
                        exponential_level(pts, a, u),
                        nonuniform_level(pts, a, u),
                        "s={s} a={a:e} u={u}"
                    );
                }
            }
        }
    }

    #[test]
    fn rounding_variance_matches_closed_form() {
        let g = LevelGrid::exponential(2); // {0, 0.5, 1}
        assert_eq!(g.rounding_variance(0.5), 0.0);
        assert!((g.rounding_variance(0.75) - 0.0625).abs() < 1e-9);
        assert!((g.rounding_variance(0.25) - 0.0625).abs() < 1e-9);
        assert_eq!(g.rounding_variance(1.0), 0.0);
    }

    #[test]
    fn variance_bound_shapes() {
        // uniform delegates to Lemma 3.1(ii)
        assert_eq!(
            LevelGrid::uniform(4).variance_bound(256),
            crate::quant::variance_bound(256, 4)
        );
        // exponential: ε = 1 ⇒ 1/4 + 2^-(s-1)·√d
        let b = LevelGrid::exponential(8).variance_bound(256);
        assert!((b - (0.25 + (1.0 / 128.0) * 16.0)).abs() < 1e-9);
    }
}
