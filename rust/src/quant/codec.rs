//! The session-based compression API: [`Codec`] + [`EncodeSession`].
//!
//! QSGD's value proposition is that the coding step is cheap relative to
//! communication, so the API is split along the axis that matters for a
//! production coordinator:
//!
//! * A **[`Codec`]** is shared and immutable (`&self` only): frame parsing,
//!   the fused decode-and-accumulate paths, size estimation, wire-format
//!   metadata. One instance serves every parallel decode path with no
//!   locking — coordinators hold it in an `Arc` and clone the handle.
//! * An **[`EncodeSession`]** is per-worker and mutable: it owns the RNG
//!   stream, all encode scratch (bitstream buffers, batched RNG words,
//!   level staging) and any stateful residuals (1BitSGD error feedback).
//!   [`EncodeSession::encode_into`] reuses the caller's output buffer, so
//!   *every* compressor family reaches the zero-allocation steady state the
//!   fused pipeline pioneered — not just QSGD.
//!
//! Migration from the pre-session `Compressor` trait:
//!
//! | old (`Compressor`) | new |
//! |---|---|
//! | `compress(&mut self, grad, &mut rng) -> Vec<u8>` | [`EncodeSession::encode_into`] (or the [`EncodeSession::compress`] shim); the session owns the RNG, seeded at [`Codec::session`] |
//! | `decompress(&self, msg, n) -> Vec<f32>` | [`Codec::decode`] |
//! | `decompress_add(&self, msg, α, acc)` | [`Codec::decode_add`] (QSGD frames: [`crate::coding::gradient::FrameView`]) |
//! | `decompress_add_threads(…, threads)` | [`Codec::decode_add_threads`] |
//! | `name(&self)` | [`Codec::name`] |

use anyhow::Result;

use super::LevelGrid;
use crate::util::rng::Xoshiro256;

/// Wire-format metadata: which byte layout a codec's sessions emit. Lets
/// plan assembly, telemetry and heterogeneous receivers reason about
/// messages without decoding them.
#[derive(Debug, Clone, PartialEq)]
pub enum WireFormat {
    /// Raw little-endian f32s (the 32-bit baseline).
    RawF32,
    /// The self-describing Elias frame family (v1 uniform / v2 grid-tagged /
    /// v3 directory-bearing), carrying this level grid. The grid tag on the
    /// wire follows the grid family (`coding::gradient` owns the tag space).
    EliasFrame { grid: LevelGrid },
    /// 1 sign bit per coordinate plus two reconstruction means per column
    /// (1BitSGD).
    SignColumns { column: usize },
    /// 2-bit ternary codes with a 32-bit scale per bucket (TernGrad).
    Ternary { bucket: usize },
    /// Segment container: `u32 count`, then per segment
    /// `u32 len | u8 kind | payload` over inner formats (the plan codec).
    Segments,
}

/// A shared, immutable gradient codec — the decode half plus a factory for
/// per-worker encode sessions. All methods take `&self`, so one instance
/// behind an `Arc` serves K workers' concurrent decodes lock-free.
pub trait Codec: Send + Sync {
    /// Create a per-worker [`EncodeSession`] owning `rng` and all encode
    /// scratch. Per-worker RNG streams are what keep parallel encode
    /// bit-identical to a sequential worker loop.
    fn session(&self, rng: Xoshiro256) -> Box<dyn EncodeSession>;

    /// Decode a message back into a dense gradient of length `n`. The
    /// expected length bounds hostile headers *before* any
    /// size-proportional allocation.
    fn decode(&self, msg: &[u8], n: usize) -> Result<Vec<f32>>;

    /// Fused decode-and-accumulate: `acc += alpha · decode(msg)`, without
    /// materialising an intermediate vector. QSGD implementations exploit
    /// wire-level sparsity (O(nnz) per sparse message — the paper's §6
    /// future-work optimisation).
    fn decode_add(&self, msg: &[u8], alpha: f32, acc: &mut [f32]) -> Result<()> {
        self.decode_add_threads(msg, alpha, acc, 1)
    }

    /// [`Self::decode_add`] with a thread budget the implementation may
    /// spend on intra-message parallelism (QSGD v3 frames fan their
    /// bucket-offset directory out on the scoped pool). Contract: the
    /// accumulator is **bit-identical** at every budget — `threads` only
    /// buys wall-clock. The default decodes then adds, ignoring the budget.
    fn decode_add_threads(
        &self,
        msg: &[u8],
        alpha: f32,
        acc: &mut [f32],
        threads: usize,
    ) -> Result<()> {
        let _ = threads;
        let g = self.decode(msg, acc.len())?;
        for (a, &x) in acc.iter_mut().zip(&g) {
            *a += alpha * x;
        }
        Ok(())
    }

    /// The decode-side thread budget this codec is configured for —
    /// [`crate::config::CodecOptions::threads`] when set, else the
    /// process-wide default ([`crate::util::par::max_threads`]). Callers
    /// pass it to [`Self::decode_add_threads`] instead of reaching for env
    /// vars themselves.
    fn decode_threads(&self) -> usize {
        crate::util::par::max_threads()
    }

    /// Estimated encoded size in bytes for an `n`-coordinate gradient,
    /// without encoding anything. Exact for fixed-rate formats (fp32,
    /// 1BitSGD, TernGrad); an expectation-level bound for the entropy-coded
    /// QSGD frames (Theorem 3.2 / Lemma A.6). Used for byte accounting and
    /// buffer pre-sizing.
    fn encoded_size_hint(&self, n: usize) -> usize;

    /// Which wire format this codec's sessions emit.
    fn wire_format(&self) -> WireFormat;

    /// Preferred alignment (in coordinates) for splitting a gradient into
    /// independently-encoded chunks: segmenting on multiples of this keeps
    /// the chunked quantization identical to one whole-gradient pass
    /// (bucket/column boundaries line up, and a single session encoding the
    /// chunks in order consumes the same RNG stream). The segmented
    /// collectives ([`crate::collectives`]) align ring segments to it.
    fn chunk_align(&self) -> usize {
        1
    }

    /// Whether one of this codec's sessions may encode a *sequence of
    /// different-length chunks* (the segmented collectives' hop re-encode
    /// pattern). True for codecs whose sessions are stateless across calls
    /// (QSGD/NUQSGD, TernGrad, fp32); false for 1BitSGD, whose session pins
    /// the gradient layout at first use (its error-feedback residual is
    /// per-coordinate), so it only rides whole-gradient exchanges. The
    /// segmented collectives check this and refuse with a clear error
    /// instead of tripping a deep layout assert.
    fn supports_chunked_encode(&self) -> bool {
        true
    }

    fn name(&self) -> String;
}

/// Per-worker encode state: RNG stream, scratch buffers, stateful residuals.
/// Created by [`Codec::session`]; `Send` so K sessions fan out on the
/// scoped pool.
pub trait EncodeSession: Send {
    /// Encode `grad` into `out` (cleared first, capacity reused). In steady
    /// state — once the session scratch and `out` have grown to the largest
    /// gradient seen — this performs **zero** heap allocations for every
    /// in-tree codec (enforced by the counting allocator in
    /// `tests/codec_conformance.rs` and the `coding_hotpath` bench).
    fn encode_into(&mut self, grad: &[f32], out: &mut Vec<u8>);

    /// Convenience shim allocating one exact-size message.
    fn compress(&mut self, grad: &[f32]) -> Vec<u8> {
        let mut out = Vec::new();
        self.encode_into(grad, &mut out);
        out
    }
}

/// Identity codec: raw little-endian f32s (the 32-bit baseline).
pub struct Fp32;

impl Codec for Fp32 {
    fn session(&self, _rng: Xoshiro256) -> Box<dyn EncodeSession> {
        Box::new(Fp32Session)
    }

    fn decode(&self, msg: &[u8], n: usize) -> Result<Vec<f32>> {
        anyhow::ensure!(msg.len() == n * 4, "fp32 message length mismatch");
        Ok(msg
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    fn decode_add_threads(
        &self,
        msg: &[u8],
        alpha: f32,
        acc: &mut [f32],
        _threads: usize,
    ) -> Result<()> {
        anyhow::ensure!(msg.len() == acc.len() * 4, "fp32 message length mismatch");
        for (a, c) in acc.iter_mut().zip(msg.chunks_exact(4)) {
            *a += alpha * f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
        }
        Ok(())
    }

    fn encoded_size_hint(&self, n: usize) -> usize {
        n * 4
    }

    fn wire_format(&self) -> WireFormat {
        WireFormat::RawF32
    }

    fn name(&self) -> String {
        "fp32".into()
    }
}

/// Stateless fp32 session (no RNG, no scratch beyond the caller's buffer).
struct Fp32Session;

impl EncodeSession for Fp32Session {
    fn encode_into(&mut self, grad: &[f32], out: &mut Vec<u8>) {
        out.clear();
        out.reserve(grad.len() * 4);
        for &g in grad {
            out.extend_from_slice(&g.to_le_bytes());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fp32_roundtrip_and_reuse() {
        let g = vec![1.0f32, -2.5, 0.0, f32::MIN_POSITIVE];
        let codec = Fp32;
        let mut sess = codec.session(Xoshiro256::from_u64(0));
        let msg = sess.compress(&g);
        assert_eq!(msg.len(), 16);
        assert_eq!(codec.decode(&msg, 4).unwrap(), g);
        assert!(codec.decode(&msg, 5).is_err());
        // decode_add matches decode-then-add exactly
        let mut acc = vec![1.0f32; 4];
        codec.decode_add(&msg, 0.5, &mut acc).unwrap();
        for (a, &x) in acc.iter().zip(&g) {
            assert_eq!(*a, 1.0 + 0.5 * x);
        }
        assert!(codec.decode_add(&msg, 1.0, &mut vec![0.0f32; 3]).is_err());
        // output buffer is reused across encodes
        let mut out = Vec::new();
        sess.encode_into(&g, &mut out);
        let cap = out.capacity();
        sess.encode_into(&g, &mut out);
        assert_eq!(out, msg);
        assert_eq!(out.capacity(), cap);
    }

    #[test]
    fn size_hint_is_exact_for_fp32() {
        assert_eq!(Fp32.encoded_size_hint(100), 400);
        assert_eq!(Fp32.wire_format(), WireFormat::RawF32);
        // raw floats chunk anywhere
        assert_eq!(Fp32.chunk_align(), 1);
    }
}
