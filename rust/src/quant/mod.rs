//! Gradient quantizers: QSGD (stochastic, §3.1/§4), the deterministic GD
//! quantizer (Appendix F), and the 1BitSGD / TernGrad baselines.

pub mod codec;
pub mod deterministic;
pub mod grid;
pub mod onebit;
pub mod stochastic;
pub mod terngrad;

pub use codec::{Codec, EncodeSession, Fp32, WireFormat};
pub use grid::LevelGrid;



/// Which per-bucket scale `F(b)` to use (paper §4: max-norm "preserves more
/// values" but loses the sparsity guarantee; §3.1 theory uses the 2-norm).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]

pub enum Norm {
    L2,
    #[default]
    Max,
}

impl Norm {
    /// Per-bucket scale. 8-lane unrolled reductions — the scalar fold does
    /// not auto-vectorise and the scale pass was ~25% of quantize time
    /// (EXPERIMENTS.md §Perf). NOTE: the L2 summation order differs from a
    /// strict sequential sum by f32 rounding, same as XLA's vectorised
    /// reduction — the Pallas cross-check budgets for this.
    pub fn scale(self, v: &[f32]) -> f32 {
        match self {
            Norm::L2 => {
                let mut acc = [0.0f32; 8];
                let chunks = v.chunks_exact(8);
                let rem = chunks.remainder();
                for ch in chunks {
                    for i in 0..8 {
                        acc[i] += ch[i] * ch[i];
                    }
                }
                let mut s: f32 = acc.iter().sum();
                for &x in rem {
                    s += x * x;
                }
                s.sqrt()
            }
            Norm::Max => {
                let mut acc = [0.0f32; 8];
                let chunks = v.chunks_exact(8);
                let rem = chunks.remainder();
                for ch in chunks {
                    for i in 0..8 {
                        acc[i] = acc[i].max(ch[i].abs());
                    }
                }
                let mut m = acc.iter().fold(0.0f32, |a, &b| a.max(b));
                for &x in rem {
                    m = m.max(x.abs());
                }
                m
            }
        }
    }
}

/// One quantized bucket: the transmitted scale plus signed levels
/// `ℓ_i ∈ [−s, s]` (sign folded in; `|ℓ_i|/s = ξ_i` of the paper).
#[derive(Debug, Clone, PartialEq)]
pub struct QuantBucket {
    pub scale: f32,
    pub levels: Vec<i32>,
}

impl QuantBucket {
    /// Reconstruct `Q_s(b)_i = F(b)·sgn·ℓ_i/s` into `out`.
    pub fn dequantize_into(&self, s: u32, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.levels.len());
        let k = self.scale / s as f32;
        for (o, &l) in out.iter_mut().zip(&self.levels) {
            *o = l as f32 * k;
        }
    }

    /// Grid-aware reconstruction: `Q(b)_i = F(b)·sgn·ℓ(|level|)`. The uniform
    /// grid takes the original arithmetic path (bit-identical to
    /// [`Self::dequantize_into`]); non-uniform grids look level values up in
    /// the grid's point table.
    pub fn dequantize_grid_into(&self, grid: &LevelGrid, out: &mut [f32]) {
        match grid.nonzero_points() {
            None => self.dequantize_into(grid.s(), out),
            Some(pts) => {
                debug_assert_eq!(out.len(), self.levels.len());
                for (o, &l) in out.iter_mut().zip(&self.levels) {
                    *o = if l == 0 {
                        0.0
                    } else {
                        let v = self.scale * pts[(l.unsigned_abs() - 1) as usize];
                        if l < 0 {
                            -v
                        } else {
                            v
                        }
                    };
                }
            }
        }
    }

    pub fn nnz(&self) -> usize {
        self.levels.iter().filter(|&&l| l != 0).count()
    }
}

/// A fully quantized gradient: the exact object `Encode`/`Decode` of
/// Algorithm 1 moves between processors.
#[derive(Debug, Clone, PartialEq)]
pub struct QuantizedGradient {
    /// Quantization levels `s ≥ 1` (bit width b ⇒ `s = 2^(b−1) − 1` signed
    /// levels plus sign, see [`levels_for_bits`]). Invariant:
    /// `s == grid.s()` — kept as a plain field because the wire codecs and
    /// cost models key on it constantly.
    pub s: u32,
    /// Which level grid the levels index into (uniform ⇒ classic QSGD).
    pub grid: LevelGrid,
    /// Bucket size `d` (§4); the final bucket may be shorter.
    pub bucket_size: usize,
    pub norm: Norm,
    /// Original vector length.
    pub n: usize,
    pub buckets: Vec<QuantBucket>,
}

impl QuantizedGradient {
    pub fn dequantize(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.n];
        let mut off = 0;
        for b in &self.buckets {
            let end = off + b.levels.len();
            b.dequantize_grid_into(&self.grid, &mut out[off..end]);
            off = end;
        }
        debug_assert_eq!(off, self.n);
        out
    }

    /// Accumulate `alpha · Q_s(v)` into `acc` without materialising a Vec —
    /// the decode-side hot path when averaging K peers' gradients.
    pub fn dequantize_add(&self, alpha: f32, acc: &mut [f32]) {
        assert_eq!(acc.len(), self.n);
        let pts = self.grid.nonzero_points();
        let mut off = 0;
        for b in &self.buckets {
            match pts {
                None => {
                    let k = alpha * b.scale / self.s as f32;
                    for (a, &l) in acc[off..off + b.levels.len()].iter_mut().zip(&b.levels) {
                        *a += l as f32 * k;
                    }
                }
                Some(pts) => {
                    let k = alpha * b.scale;
                    for (a, &l) in acc[off..off + b.levels.len()].iter_mut().zip(&b.levels) {
                        if l != 0 {
                            let v = k * pts[(l.unsigned_abs() - 1) as usize];
                            *a += if l < 0 { -v } else { v };
                        }
                    }
                }
            }
            off += b.levels.len();
        }
    }

    pub fn nnz(&self) -> usize {
        self.buckets.iter().map(|b| b.nnz()).sum()
    }
}

/// `b`-bit QSGD in the paper's experimental framing uses `2^(b−1) − 1`
/// magnitude levels plus a sign bit per coordinate (e.g. 4-bit ⇒ s = 7
/// levels {0, 1/7, …, 1}; 2-bit ⇒ s = 1, i.e. ternary).
pub fn levels_for_bits(bits: u32) -> u32 {
    assert!((2..=16).contains(&bits), "bit width out of range");
    (1u32 << (bits - 1)) - 1
}

/// §4 variance knob: quantizing buckets of size `d` with `s` levels bounds
/// the variance blowup by `min(d/s², √d/s)` (paper example: bucket 512 at
/// 4 bits ⇒ √512/2⁴ ≈ 1.41).
pub fn variance_bound(d: usize, s: u32) -> f64 {
    let d = d as f64;
    let s = s as f64;
    (d / (s * s)).min(d.sqrt() / s)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_for_bits_matches_paper() {
        assert_eq!(levels_for_bits(2), 1); // ternary
        assert_eq!(levels_for_bits(4), 7);
        assert_eq!(levels_for_bits(8), 127);
    }

    #[test]
    fn variance_knob_example() {
        // Paper §4 example, stated with s = 2^bits: √512/2⁴ ≈ 1.41.
        assert!((variance_bound(512, 16) - 1.414).abs() < 0.01);
    }

    #[test]
    fn norm_scales() {
        let v = [3.0f32, -4.0];
        assert!((Norm::L2.scale(&v) - 5.0).abs() < 1e-6);
        assert!((Norm::Max.scale(&v) - 4.0).abs() < 1e-6);
        assert_eq!(Norm::L2.scale(&[]), 0.0);
    }

    #[test]
    fn dequantize_add_matches_dequantize() {
        let qg = QuantizedGradient {
            s: 4,
            grid: LevelGrid::uniform(4),
            bucket_size: 3,
            norm: Norm::Max,
            n: 5,
            buckets: vec![
                QuantBucket { scale: 2.0, levels: vec![4, -2, 0] },
                QuantBucket { scale: 1.0, levels: vec![1, -4] },
            ],
        };
        let d = qg.dequantize();
        assert_eq!(d, vec![2.0, -1.0, 0.0, 0.25, -1.0]);
        let mut acc = vec![1.0f32; 5];
        qg.dequantize_add(0.5, &mut acc);
        for i in 0..5 {
            assert!((acc[i] - (1.0 + 0.5 * d[i])).abs() < 1e-6);
        }
        assert_eq!(qg.nnz(), 4);
    }

    #[test]
    fn dequantize_nonuniform_grid_uses_point_table() {
        // grid {0, 1/4, 1/2, 1}: level ±3 ⇒ ±scale, level ±1 ⇒ ±scale/4
        let qg = QuantizedGradient {
            s: 3,
            grid: LevelGrid::exponential(3),
            bucket_size: 4,
            norm: Norm::Max,
            n: 4,
            buckets: vec![QuantBucket { scale: 2.0, levels: vec![3, -1, 0, 2] }],
        };
        let d = qg.dequantize();
        assert_eq!(d, vec![2.0, -0.5, 0.0, 1.0]);
        let mut acc = vec![0.0f32; 4];
        qg.dequantize_add(2.0, &mut acc);
        for i in 0..4 {
            assert!((acc[i] - 2.0 * d[i]).abs() < 1e-6);
        }
    }
}
