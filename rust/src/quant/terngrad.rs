//! TernGrad baseline (Wen et al. 2017) — the concurrent three-level scheme
//! discussed in the paper's Related Work.
//!
//! Each bucket is scaled by `s_t = max|v_i|`; coordinate i is sent as
//! `s_t · sgn(v_i) · b_i` with `b_i ~ Bernoulli(|v_i|/s_t)`. This is exactly
//! QSGD with s = 1 and max-norm scaling; we implement it standalone (with
//! TernGrad's optional gradient clipping) so the benchmark comparison is
//! explicit. Wire format: 32-bit scale + 2 bits per coordinate ({−1,0,+1}).

use rand_core::RngCore;

use crate::coding::bitstream::{BitReader, BitWriter};

/// TernGrad quantizer configuration.
pub struct TernGrad {
    pub bucket: usize,
    /// Optional gradient clipping at `c·σ` (Wen et al. §4.1); `None` = off.
    pub clip_sigmas: Option<f32>,
}

impl TernGrad {
    pub fn new(bucket: usize) -> Self {
        Self { bucket, clip_sigmas: None }
    }

    pub fn compress(&self, grad: &[f32], rng: &mut dyn RngCore) -> Vec<u8> {
        let mut w = BitWriter::with_capacity(grad.len() / 4 + 8);
        for chunk in grad.chunks(self.bucket) {
            let mut buf_storage;
            let chunk = if let Some(c) = self.clip_sigmas {
                let mean = chunk.iter().sum::<f32>() / chunk.len() as f32;
                let var =
                    chunk.iter().map(|x| (x - mean).powi(2)).sum::<f32>() / chunk.len() as f32;
                let lim = c * var.sqrt();
                buf_storage = chunk.to_vec();
                for x in &mut buf_storage {
                    *x = x.clamp(-lim, lim);
                }
                &buf_storage[..]
            } else {
                chunk
            };
            let scale = chunk.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
            w.write_f32(scale);
            if scale <= 0.0 {
                for _ in chunk {
                    w.write_bits(0, 2);
                }
                continue;
            }
            for &x in chunk {
                let p = x.abs() / scale;
                let u = (rng.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32);
                let code: u64 = if u < p {
                    if x < 0.0 {
                        2 // −1
                    } else {
                        1 // +1
                    }
                } else {
                    0
                };
                w.write_bits(code, 2);
            }
        }
        w.into_bytes()
    }

    pub fn decompress(&self, msg: &[u8], n: usize) -> anyhow::Result<Vec<f32>> {
        let mut r = BitReader::new(msg);
        let mut out = Vec::with_capacity(n);
        let mut remaining = n;
        while remaining > 0 {
            let len = remaining.min(self.bucket);
            let scale = r.read_f32()?;
            for _ in 0..len {
                let v = match r.read_bits(2)? {
                    0 => 0.0,
                    1 => scale,
                    2 => -scale,
                    _ => anyhow::bail!("invalid ternary code"),
                };
                out.push(v);
            }
            remaining -= len;
        }
        Ok(out)
    }

    /// Exact message size in bits.
    pub fn message_bits(&self, n: usize) -> u64 {
        let cols = n.div_ceil(self.bucket) as u64;
        cols * 32 + 2 * n as u64
    }
}

impl super::Compressor for TernGrad {
    fn compress(&mut self, grad: &[f32], rng: &mut dyn RngCore) -> Vec<u8> {
        TernGrad::compress(self, grad, rng)
    }

    fn decompress(&self, msg: &[u8], n: usize) -> anyhow::Result<Vec<f32>> {
        TernGrad::decompress(self, msg, n)
    }

    fn name(&self) -> String {
        format!("terngrad(bucket={})", self.bucket)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_values_are_ternary() {
        let g: Vec<f32> = (0..200).map(|i| ((i as f32) / 40.0).sin()).collect();
        let t = TernGrad::new(64);
        let mut rng = crate::util::rng::Xoshiro256::from_u64(0);
        let msg = t.compress(&g, &mut rng);
        assert_eq!(msg.len() as u64, t.message_bits(200).div_ceil(8));
        let d = t.decompress(&msg, 200).unwrap();
        for chunk in d.chunks(64).zip(g.chunks(64)) {
            let scale = chunk.1.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
            for &x in chunk.0 {
                assert!(x == 0.0 || (x.abs() - scale).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn unbiased_monte_carlo() {
        let g = [0.5f32, -0.25, 1.0, 0.0];
        let t = TernGrad::new(4);
        let mut rng = crate::util::rng::Xoshiro256::from_u64(1);
        let trials = 4000;
        let mut acc = [0.0f64; 4];
        for _ in 0..trials {
            let msg = t.compress(&g, &mut rng);
            for (a, x) in acc.iter_mut().zip(t.decompress(&msg, 4).unwrap()) {
                *a += x as f64;
            }
        }
        for i in 0..4 {
            assert!((acc[i] / trials as f64 - g[i] as f64).abs() < 0.05, "i={i}");
        }
    }

    #[test]
    fn clipping_reduces_scale() {
        let mut g = vec![0.01f32; 256];
        g[0] = 10.0; // outlier
        let unclipped = TernGrad::new(256);
        let clipped = TernGrad { bucket: 256, clip_sigmas: Some(2.5) };
        let mut rng = crate::util::rng::Xoshiro256::from_u64(2);
        let m1 = unclipped.compress(&g, &mut rng);
        let m2 = clipped.compress(&g, &mut rng);
        let s1 = f32::from_bits(u32::from_be_bytes([m1[0], m1[1], m1[2], m1[3]]));
        let s2 = f32::from_bits(u32::from_be_bytes([m2[0], m2[1], m2[2], m2[3]]));
        assert!(s2 < s1);
    }
}
