//! TernGrad baseline (Wen et al. 2017) — the concurrent three-level scheme
//! discussed in the paper's Related Work.
//!
//! Each bucket is scaled by `s_t = max|v_i|`; coordinate i is sent as
//! `s_t · sgn(v_i) · b_i` with `b_i ~ Bernoulli(|v_i|/s_t)`. This is exactly
//! QSGD with s = 1 and max-norm scaling; we implement it standalone (with
//! TernGrad's optional gradient clipping) so the benchmark comparison is
//! explicit. Wire format: 32-bit scale + 2 bits per coordinate ({−1,0,+1}).

use rand_core::RngCore;

use crate::coding::bitstream::{BitReader, BitWriter};
use crate::quant::{Codec, EncodeSession, WireFormat};
use crate::util::rng::Xoshiro256;

/// TernGrad quantizer configuration. Implements [`Codec`] directly — the
/// scheme is stateless on the decode side, and encode scratch (bitstream,
/// clip buffer) plus the RNG live in the per-worker session.
#[derive(Debug, Clone)]
pub struct TernGrad {
    pub bucket: usize,
    /// Optional gradient clipping at `c·σ` (Wen et al. §4.1); `None` = off.
    pub clip_sigmas: Option<f32>,
}

impl TernGrad {
    pub fn new(bucket: usize) -> Self {
        Self { bucket, clip_sigmas: None }
    }

    /// Encode into a caller-managed writer, reusing `clip_buf` as the
    /// clipping scratch — the allocation-free core both [`Self::compress`]
    /// and the encode session build on.
    fn encode_to(
        &self,
        grad: &[f32],
        rng: &mut dyn RngCore,
        w: &mut BitWriter,
        clip_buf: &mut Vec<f32>,
    ) {
        for chunk in grad.chunks(self.bucket) {
            let chunk = if let Some(c) = self.clip_sigmas {
                let mean = chunk.iter().sum::<f32>() / chunk.len() as f32;
                let var =
                    chunk.iter().map(|x| (x - mean).powi(2)).sum::<f32>() / chunk.len() as f32;
                let lim = c * var.sqrt();
                clip_buf.clear();
                clip_buf.extend(chunk.iter().map(|x| x.clamp(-lim, lim)));
                &clip_buf[..]
            } else {
                chunk
            };
            let scale = chunk.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
            w.write_f32(scale);
            if scale <= 0.0 {
                for _ in chunk {
                    w.write_bits(0, 2);
                }
                continue;
            }
            for &x in chunk {
                let p = x.abs() / scale;
                let u = (rng.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32);
                let code: u64 = if u < p {
                    if x < 0.0 {
                        2 // −1
                    } else {
                        1 // +1
                    }
                } else {
                    0
                };
                w.write_bits(code, 2);
            }
        }
    }

    pub fn compress(&self, grad: &[f32], rng: &mut dyn RngCore) -> Vec<u8> {
        let mut w = BitWriter::with_capacity(grad.len() / 4 + 8);
        let mut clip_buf = Vec::new();
        self.encode_to(grad, rng, &mut w, &mut clip_buf);
        w.into_bytes()
    }

    pub fn decompress(&self, msg: &[u8], n: usize) -> anyhow::Result<Vec<f32>> {
        let mut r = BitReader::new(msg);
        let mut out = Vec::with_capacity(n);
        let mut remaining = n;
        while remaining > 0 {
            let len = remaining.min(self.bucket);
            let scale = r.read_f32()?;
            for _ in 0..len {
                let v = match r.read_bits(2)? {
                    0 => 0.0,
                    1 => scale,
                    2 => -scale,
                    _ => anyhow::bail!("invalid ternary code"),
                };
                out.push(v);
            }
            remaining -= len;
        }
        Ok(out)
    }

    /// Exact message size in bits.
    pub fn message_bits(&self, n: usize) -> u64 {
        let cols = n.div_ceil(self.bucket) as u64;
        cols * 32 + 2 * n as u64
    }
}

impl Codec for TernGrad {
    fn session(&self, rng: Xoshiro256) -> Box<dyn EncodeSession> {
        Box::new(TernGradSession {
            t: self.clone(),
            rng,
            writer: BitWriter::new(),
            clip_buf: Vec::new(),
        })
    }

    fn decode(&self, msg: &[u8], n: usize) -> anyhow::Result<Vec<f32>> {
        TernGrad::decompress(self, msg, n)
    }

    fn decode_add_threads(
        &self,
        msg: &[u8],
        alpha: f32,
        acc: &mut [f32],
        _threads: usize,
    ) -> anyhow::Result<()> {
        let mut r = BitReader::new(msg);
        let mut off = 0usize;
        let n = acc.len();
        while off < n {
            let len = (n - off).min(self.bucket);
            let scale = r.read_f32()?;
            for a in &mut acc[off..off + len] {
                match r.read_bits(2)? {
                    0 => {}
                    1 => *a += alpha * scale,
                    2 => *a -= alpha * scale,
                    _ => anyhow::bail!("invalid ternary code"),
                }
            }
            off += len;
        }
        Ok(())
    }

    fn encoded_size_hint(&self, n: usize) -> usize {
        self.message_bits(n).div_ceil(8) as usize
    }

    fn wire_format(&self) -> WireFormat {
        WireFormat::Ternary { bucket: self.bucket }
    }

    fn chunk_align(&self) -> usize {
        self.bucket
    }

    fn name(&self) -> String {
        format!("terngrad(bucket={})", self.bucket)
    }
}

/// Per-worker TernGrad session: owns the RNG stream and the bitstream/clip
/// scratch, so steady-state encodes stay off the heap.
struct TernGradSession {
    t: TernGrad,
    rng: Xoshiro256,
    writer: BitWriter,
    clip_buf: Vec<f32>,
}

impl EncodeSession for TernGradSession {
    fn encode_into(&mut self, grad: &[f32], out: &mut Vec<u8>) {
        self.writer.reset();
        self.writer.reserve(grad.len() / 4 + 8);
        self.t.encode_to(grad, &mut self.rng, &mut self.writer, &mut self.clip_buf);
        out.clear();
        out.extend_from_slice(self.writer.finish());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_values_are_ternary() {
        let g: Vec<f32> = (0..200).map(|i| ((i as f32) / 40.0).sin()).collect();
        let t = TernGrad::new(64);
        let mut rng = crate::util::rng::Xoshiro256::from_u64(0);
        let msg = t.compress(&g, &mut rng);
        assert_eq!(msg.len() as u64, t.message_bits(200).div_ceil(8));
        let d = t.decompress(&msg, 200).unwrap();
        for chunk in d.chunks(64).zip(g.chunks(64)) {
            let scale = chunk.1.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
            for &x in chunk.0 {
                assert!(x == 0.0 || (x.abs() - scale).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn unbiased_monte_carlo() {
        let g = [0.5f32, -0.25, 1.0, 0.0];
        let t = TernGrad::new(4);
        let mut rng = crate::util::rng::Xoshiro256::from_u64(1);
        let trials = 4000;
        let mut acc = [0.0f64; 4];
        for _ in 0..trials {
            let msg = t.compress(&g, &mut rng);
            for (a, x) in acc.iter_mut().zip(t.decompress(&msg, 4).unwrap()) {
                *a += x as f64;
            }
        }
        for i in 0..4 {
            assert!((acc[i] / trials as f64 - g[i] as f64).abs() < 0.05, "i={i}");
        }
    }

    #[test]
    fn clipping_reduces_scale() {
        let mut g = vec![0.01f32; 256];
        g[0] = 10.0; // outlier
        let unclipped = TernGrad::new(256);
        let clipped = TernGrad { bucket: 256, clip_sigmas: Some(2.5) };
        let mut rng = crate::util::rng::Xoshiro256::from_u64(2);
        let m1 = unclipped.compress(&g, &mut rng);
        let m2 = clipped.compress(&g, &mut rng);
        let s1 = f32::from_bits(u32::from_be_bytes([m1[0], m1[1], m1[2], m1[3]]));
        let s2 = f32::from_bits(u32::from_be_bytes([m2[0], m2[1], m2[2], m2[3]]));
        assert!(s2 < s1);
    }
}
