//! Dependency-free scoped thread pool: parallel indexed maps over slices.
//!
//! The coordinator runs K simulated workers whose encode/decode jobs are
//! fully independent (per-worker compressor state and RNG streams), so a
//! plain fork/join over `std::thread::scope` is all the parallelism the hot
//! path needs. The offline build vendors neither rayon nor crossbeam; this
//! module is the substrate `collectives` and the coordinator loops build on.
//! Work is split into contiguous chunks in index order, so results (and any
//! floating-point reduction built on them) are deterministic and independent
//! of thread scheduling.

/// Interpret a `QSGD_THREADS` value: `Ok(Some(n))` for a positive integer,
/// `Ok(None)` when unset, `Err` (with the offending value) for anything
/// else — empty, zero, negative, or garbage. Split out of [`max_threads`]
/// so the rejection paths are unit-testable without mutating process env.
fn parse_threads_env(value: Option<&str>) -> Result<Option<usize>, String> {
    let Some(v) = value else { return Ok(None) };
    match v.trim().parse::<usize>() {
        Ok(n) if n >= 1 => Ok(Some(n)),
        _ => Err(v.to_string()),
    }
}

/// Upper bound on useful worker threads for this process: the
/// `QSGD_THREADS` environment variable when set to a positive integer
/// (pinning it makes bench and CI numbers reproducible across hosts —
/// results are bit-identical at any thread count by construction, but
/// timings are not), else the machine's available parallelism. Read once
/// and cached for the life of the process.
///
/// An *unparsable* `QSGD_THREADS` (empty, `0`, garbage) falls back to the
/// machine default with a loud one-time warning on stderr — a typo'd
/// pinning must not silently unpin a benchmark run.
pub fn max_threads() -> usize {
    use std::sync::OnceLock;
    static N: OnceLock<usize> = OnceLock::new();
    *N.get_or_init(|| {
        let var = std::env::var("QSGD_THREADS").ok();
        match parse_threads_env(var.as_deref()) {
            Ok(Some(n)) => return n,
            Ok(None) => {}
            Err(bad) => eprintln!(
                "warning: ignoring QSGD_THREADS='{bad}' (expected a positive \
                 integer); using the machine's available parallelism"
            ),
        }
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    })
}

/// Parallel indexed map over a mutable slice: `out[i] = f(i, &mut items[i])`.
/// Results come back in item order. Falls back to a sequential loop for
/// zero/one items or single-core hosts.
pub fn par_map_mut<T, R, F>(items: &mut [T], f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(usize, &mut T) -> R + Sync,
{
    let n = items.len();
    let threads = max_threads().min(n);
    if threads <= 1 {
        return items.iter_mut().enumerate().map(|(i, t)| f(i, t)).collect();
    }
    let chunk = n.div_ceil(threads);
    let mut out: Vec<Option<R>> = Vec::with_capacity(n);
    out.resize_with(n, || None);
    std::thread::scope(|scope| {
        for (ci, (items_c, out_c)) in
            items.chunks_mut(chunk).zip(out.chunks_mut(chunk)).enumerate()
        {
            let f = &f;
            scope.spawn(move || {
                for (j, (t, o)) in items_c.iter_mut().zip(out_c.iter_mut()).enumerate() {
                    *o = Some(f(ci * chunk + j, t));
                }
            });
        }
    });
    out.into_iter().map(|o| o.expect("par_map_mut fills every slot")).collect()
}

/// Parallel indexed map over a shared slice: `out[i] = f(i, &items[i])`.
pub fn par_map<T, R, F>(items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    let n = items.len();
    let threads = max_threads().min(n);
    if threads <= 1 {
        return items.iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }
    let chunk = n.div_ceil(threads);
    let mut out: Vec<Option<R>> = Vec::with_capacity(n);
    out.resize_with(n, || None);
    std::thread::scope(|scope| {
        for (ci, (items_c, out_c)) in items.chunks(chunk).zip(out.chunks_mut(chunk)).enumerate() {
            let f = &f;
            scope.spawn(move || {
                for (j, (t, o)) in items_c.iter().zip(out_c.iter_mut()).enumerate() {
                    *o = Some(f(ci * chunk + j, t));
                }
            });
        }
    });
    out.into_iter().map(|o| o.expect("par_map fills every slot")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_mut_preserves_order_and_mutates() {
        let mut v: Vec<u64> = (0..257).collect();
        let out = par_map_mut(&mut v, |i, x| {
            *x += 1;
            (i as u64) * 2
        });
        assert_eq!(out, (0..257).map(|i| i * 2).collect::<Vec<_>>());
        assert_eq!(v[0], 1);
        assert_eq!(v[256], 257);
    }

    #[test]
    fn map_matches_sequential() {
        let v: Vec<i64> = (0..100).map(|i| i * 7 - 50).collect();
        let par = par_map(&v, |i, x| x * x + i as i64);
        let seq: Vec<i64> = v.iter().enumerate().map(|(i, x)| x * x + i as i64).collect();
        assert_eq!(par, seq);
    }

    #[test]
    fn threads_env_parse_paths() {
        // unset ⇒ no override
        assert_eq!(parse_threads_env(None), Ok(None));
        // valid pins, whitespace tolerated
        assert_eq!(parse_threads_env(Some("1")), Ok(Some(1)));
        assert_eq!(parse_threads_env(Some(" 16 ")), Ok(Some(16)));
        // rejects (loud warning in max_threads, not a silent fallback):
        // empty, zero, negative, garbage, fractional
        for bad in ["", "  ", "0", "-2", "lots", "3.5"] {
            assert_eq!(parse_threads_env(Some(bad)), Err(bad.to_string()), "{bad:?}");
        }
        // the cached process-wide value is always usable
        assert!(max_threads() >= 1);
    }

    #[test]
    fn empty_and_single() {
        let mut e: Vec<u8> = vec![];
        assert!(par_map_mut(&mut e, |_, _| 0u8).is_empty());
        let mut one = vec![5u8];
        assert_eq!(par_map_mut(&mut one, |i, x| (*x as usize) + i), vec![5]);
    }
}
