//! Offline-environment substrates: PRNG + distributions ([`rng`]), a minimal
//! JSON parser ([`json`]), summary statistics ([`stats`]), and a small
//! property-testing harness ([`check`]).

pub mod check;
pub mod json;
pub mod rng;
pub mod stats;
