//! Offline-environment substrates: PRNG + distributions ([`rng`]), a minimal
//! JSON parser ([`json`]), summary statistics ([`stats`]), a small
//! property-testing harness ([`check`]), and a dependency-free scoped
//! thread pool ([`par`]).

pub mod check;
pub mod json;
pub mod par;
pub mod rng;
pub mod stats;
