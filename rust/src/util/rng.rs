//! Deterministic PRNG + distributions.
//!
//! The offline build environment vendors only `rand_core` (traits), so the
//! generator and the distributions live here. Xoshiro256** (Blackman &
//! Vigna) seeded via SplitMix64 — the same construction `rand_xoshiro`
//! ships; statistically solid and extremely fast, which matters because the
//! coordinator draws one uniform per gradient coordinate per step.

use rand_core::{impls, Error, RngCore, SeedableRng};

/// SplitMix64 — used for seeding and as a cheap stream splitter.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Xoshiro256** generator.
#[derive(Debug, Clone)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    pub fn from_u64(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        // all-zero state is invalid; splitmix of any seed avoids it, but be safe
        if s == [0, 0, 0, 0] {
            return Self { s: [1, 2, 3, 4] };
        }
        Self { s }
    }

    /// Derive an independent stream (e.g. one per worker) — jump-free
    /// splitting via splitmix on (seed, stream).
    pub fn stream(seed: u64, stream: u64) -> Self {
        let mut sm = seed ^ stream.wrapping_mul(0xA24BAED4963EE407);
        let _ = splitmix64(&mut sm);
        Self::from_u64(splitmix64(&mut sm))
    }

    /// Fork a child generator off this one: one draw of the parent seeds an
    /// independent child via splitmix. This is how per-segment encode
    /// sessions (plan codec) and per-hop re-encode sessions (collectives)
    /// stay deterministic in the parent stream regardless of how much each
    /// child consumes.
    pub fn fork(&mut self) -> Self {
        Self::from_u64(RngCore::next_u64(self))
    }
}

impl RngCore for Xoshiro256 {
    #[inline]
    fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    #[inline]
    fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    fn fill_bytes(&mut self, dest: &mut [u8]) {
        impls::fill_bytes_via_next(self, dest)
    }

    fn try_fill_bytes(&mut self, dest: &mut [u8]) -> Result<(), Error> {
        self.fill_bytes(dest);
        Ok(())
    }
}

impl SeedableRng for Xoshiro256 {
    type Seed = [u8; 32];

    fn from_seed(seed: Self::Seed) -> Self {
        let mut s = [0u64; 4];
        for (i, c) in seed.chunks_exact(8).enumerate() {
            s[i] = u64::from_le_bytes(c.try_into().unwrap());
        }
        if s == [0, 0, 0, 0] {
            s = [1, 2, 3, 4];
        }
        Self { s }
    }

    fn seed_from_u64(seed: u64) -> Self {
        Self::from_u64(seed)
    }
}

// --------------------------------------------------------------------------
// Distributions
// --------------------------------------------------------------------------

/// Uniform in [0, 1) with 24-bit granularity (matches `jax.random.uniform`
/// f32 granularity; also what the quantizer's level test expects).
#[inline]
pub fn uniform_f32(rng: &mut dyn RngCore) -> f32 {
    (rng.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
}

/// Uniform in [0, 1) at f64 precision.
#[inline]
pub fn uniform_f64(rng: &mut dyn RngCore) -> f64 {
    (rng.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
}

/// Uniform integer in [0, n).
#[inline]
pub fn uniform_usize(rng: &mut dyn RngCore, n: usize) -> usize {
    debug_assert!(n > 0);
    // Lemire's multiply-shift rejection-free approximation is fine here
    // (n ≪ 2^64; modulo bias is negligible for our n but avoid it anyway).
    ((rng.next_u64() as u128 * n as u128) >> 64) as usize
}

/// Standard normal via Box–Muller.
#[inline]
pub fn normal_f32(rng: &mut dyn RngCore) -> f32 {
    let u1 = uniform_f64(rng).max(1e-300);
    let u2 = uniform_f64(rng);
    ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
}

/// Vector of standard normals.
pub fn normal_vec(rng: &mut dyn RngCore, n: usize) -> Vec<f32> {
    (0..n).map(|_| normal_f32(rng)).collect()
}

/// Vector of uniforms in [0,1).
pub fn uniform_vec(rng: &mut dyn RngCore, n: usize) -> Vec<f32> {
    (0..n).map(|_| uniform_f32(rng)).collect()
}

/// Fisher–Yates shuffle.
pub fn shuffle<T>(rng: &mut dyn RngCore, v: &mut [T]) {
    for i in (1..v.len()).rev() {
        let j = uniform_usize(rng, i + 1);
        v.swap(i, j);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_distinct_streams() {
        let mut a = Xoshiro256::from_u64(42);
        let mut b = Xoshiro256::from_u64(42);
        let mut c = Xoshiro256::from_u64(43);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        let vc: Vec<u64> = (0..8).map(|_| c.next_u64()).collect();
        assert_eq!(va, vb);
        assert_ne!(va, vc);
        let mut s0 = Xoshiro256::stream(7, 0);
        let mut s1 = Xoshiro256::stream(7, 1);
        assert_ne!(s0.next_u64(), s1.next_u64());
    }

    #[test]
    fn uniform_range_and_mean() {
        let mut rng = Xoshiro256::from_u64(0);
        let n = 100_000;
        let mut sum = 0.0f64;
        for _ in 0..n {
            let u = uniform_f32(&mut rng);
            assert!((0.0..1.0).contains(&u));
            sum += u as f64;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn normal_moments() {
        let mut rng = Xoshiro256::from_u64(1);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| normal_f32(&mut rng) as f64).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn uniform_usize_in_range() {
        let mut rng = Xoshiro256::from_u64(2);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let k = uniform_usize(&mut rng, 10);
            assert!(k < 10);
            seen[k] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Xoshiro256::from_u64(3);
        let mut v: Vec<u32> = (0..100).collect();
        shuffle(&mut rng, &mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }
}
