//! Minimal JSON parser — reads `artifacts/manifest.json` (the AOT layer's
//! contract with the runtime). The offline build has no serde, so this is a
//! small recursive-descent parser over the JSON grammar (objects, arrays,
//! strings with escapes, numbers, booleans, null). Parsing only; the Rust
//! side never writes JSON.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "JSON parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

pub fn parse(src: &str) -> Result<Json, JsonError> {
    let mut p = Parser { b: src.as_bytes(), i: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.i != p.b.len() {
        return Err(p.err("trailing content"));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.i, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(self.err(&format!("unexpected byte 0x{c:02x}"))),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek().ok_or_else(|| self.err("unterminated string"))? {
                b'"' => {
                    self.i += 1;
                    return Ok(out);
                }
                b'\\' => {
                    self.i += 1;
                    match self.peek().ok_or_else(|| self.err("bad escape"))? {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("short \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // BMP only (manifest never contains surrogates)
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        c => return Err(self.err(&format!("bad escape '\\{}'", c as char))),
                    }
                    self.i += 1;
                }
                _ => {
                    // consume one UTF-8 scalar
                    let start = self.i;
                    self.i += 1;
                    while self.i < self.b.len() && (self.b[self.i] & 0xC0) == 0x80 {
                        self.i += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')) {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| JsonError { pos: start, msg: format!("bad number '{s}'") })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_document() {
        let src = r#"{
          "mlp_grad": {
            "file": "mlp_grad.hlo.txt",
            "inputs": [{"name": "params", "shape": [34186], "dtype": "float32"}],
            "outputs": [{"shape": [], "dtype": "float32"}],
            "params": 34186,
            "batch": 64,
            "nested": {"a": [1, 2.5, -3e2], "b": true, "c": null}
          }
        }"#;
        let j = parse(src).unwrap();
        let m = j.get("mlp_grad").unwrap();
        assert_eq!(m.get("file").unwrap().as_str().unwrap(), "mlp_grad.hlo.txt");
        assert_eq!(m.get("params").unwrap().as_usize().unwrap(), 34186);
        let inp = m.get("inputs").unwrap().as_arr().unwrap();
        assert_eq!(inp[0].get("shape").unwrap().as_arr().unwrap()[0].as_usize(), Some(34186));
        let nested = m.get("nested").unwrap();
        assert_eq!(nested.get("a").unwrap().as_arr().unwrap()[2].as_f64(), Some(-300.0));
        assert_eq!(nested.get("b").unwrap(), &Json::Bool(true));
        assert_eq!(nested.get("c").unwrap(), &Json::Null);
    }

    #[test]
    fn string_escapes() {
        let j = parse(r#""a\"b\\c\ndA""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "a\"b\\c\ndA");
    }

    #[test]
    fn rejects_malformed() {
        for bad in ["", "{", "[1,", "{\"a\":}", "tru", "1 2", "\"unterminated"] {
            assert!(parse(bad).is_err(), "{bad:?}");
        }
    }

    #[test]
    fn unicode_passthrough() {
        let j = parse("\"héllo ∞\"").unwrap();
        assert_eq!(j.as_str().unwrap(), "héllo ∞");
    }
}
