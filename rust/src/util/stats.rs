//! Summary statistics shared by the bench harness and the metrics module.

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / (xs.len() - 1) as f64
}

pub fn stddev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

// NOTE: there is deliberately no `percentile`/`median` here. The single
// quantile implementation in the tree is the log-bucketed
// `crate::obs::Histogram` (bounded memory, ~0.8% relative error); exact
// sorted-sample quantiles survive only as test oracles inside
// `rust/tests/obs_conformance.rs`.

/// Human-readable duration from seconds.
pub fn fmt_duration(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1}ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2}µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2}ms", secs * 1e3)
    } else if secs < 120.0 {
        format!("{:.2}s", secs)
    } else {
        format!("{:.1}min", secs / 60.0)
    }
}

/// Human-readable byte count.
pub fn fmt_bytes(bytes: f64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut b = bytes;
    let mut u = 0;
    while b >= 1024.0 && u + 1 < UNITS.len() {
        b /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{:.0}{}", b, UNITS[u])
    } else {
        format!("{:.2}{}", b, UNITS[u])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_moments() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert!((variance(&xs) - 5.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_duration(2.5e-9), "2.5ns");
        assert_eq!(fmt_duration(0.0015), "1.50ms");
        assert_eq!(fmt_bytes(10.0), "10B");
        assert_eq!(fmt_bytes(1536.0), "1.50KiB");
    }

    #[test]
    fn empty_inputs() {
        assert!(mean(&[]).is_nan());
        assert_eq!(variance(&[1.0]), 0.0);
    }
}
