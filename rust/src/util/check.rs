//! Property-testing mini-harness (the offline environment has no proptest).
//!
//! [`forall`] runs a property over many independently seeded PRNGs and, on
//! failure, re-runs a size-reduction pass ("shrinking-lite": the generator
//! receives a `size` hint the harness decays) before reporting the minimal
//! failing seed/size so the case can be replayed deterministically.

use rand_core::RngCore;

use super::rng::Xoshiro256;

/// Generation context handed to properties: a seeded PRNG plus a size hint
/// in [1, max_size] that properties should use to scale their inputs.
pub struct Gen<'a> {
    pub rng: &'a mut Xoshiro256,
    pub size: usize,
}

impl<'a> Gen<'a> {
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        lo + super::rng::uniform_usize(self.rng, hi - lo + 1)
    }

    pub fn f32_vec(&mut self, n: usize) -> Vec<f32> {
        super::rng::normal_vec(self.rng, n)
    }

    pub fn u32(&mut self) -> u32 {
        self.rng.next_u32()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u32() & 1 == 1
    }
}

/// Multiplier on every property's base case count, from the
/// `QSGD_PROPTEST_CASES` environment variable (default 1, capped at 1000).
/// CI's fast lane leaves it unset so PR runs stay cheap; the thorough lane
/// on main sets it to run the same properties at greater depth.
fn case_multiplier() -> u64 {
    use std::sync::OnceLock;
    static MULT: OnceLock<u64> = OnceLock::new();
    *MULT.get_or_init(|| {
        std::env::var("QSGD_PROPTEST_CASES")
            .ok()
            .and_then(|v| v.parse::<u64>().ok())
            .map_or(1, |m| m.clamp(1, 1000))
    })
}

/// Run `prop` on `cases` random inputs (scaled by [`case_multiplier`]). On a
/// failure at (seed, size), retry with smaller sizes to find a smaller
/// reproduction, then panic with the replay coordinates.
pub fn forall<F>(name: &str, cases: u64, max_size: usize, mut prop: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    let cases = cases.saturating_mul(case_multiplier());
    let run = |prop: &mut F, seed: u64, size: usize| -> Result<(), String> {
        let mut rng = Xoshiro256::stream(0xC0FFEE ^ seed, seed);
        let mut g = Gen { rng: &mut rng, size };
        prop(&mut g)
    };
    for seed in 0..cases {
        // cycle sizes so small inputs are exercised too
        let size = 1 + (seed as usize * 7919) % max_size;
        if let Err(msg) = run(&mut prop, seed, size) {
            // shrink: halve the size hint while the property still fails
            let mut best = (size, msg);
            let mut s = size / 2;
            while s >= 1 {
                match run(&mut prop, seed, s) {
                    Err(m) => {
                        best = (s, m);
                        if s == 1 {
                            break;
                        }
                        s /= 2;
                    }
                    Ok(()) => break,
                }
            }
            panic!(
                "property '{name}' failed: seed={seed} size={} (shrunk from {}): {}",
                best.0, size, best.1
            );
        }
    }
}

/// Assertion helper for properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !($cond) {
            return Err(format!($($fmt)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        forall("trivial", 50, 100, |g| {
            count += 1;
            let n = g.usize_in(1, g.size);
            let v = g.f32_vec(n);
            if v.len() == n {
                Ok(())
            } else {
                Err("length".into())
            }
        });
        // the thorough CI lane scales the base count via QSGD_PROPTEST_CASES
        assert!(count >= 50 && count % 50 == 0, "ran {count} cases");
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn failing_property_reports_seed() {
        forall("fails", 10, 64, |g| {
            let n = g.usize_in(1, g.size);
            prop_assert!(n < 5, "n={n} too big");
            Ok(())
        });
    }
}
