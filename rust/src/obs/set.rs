//! `MetricSet`: one labeled-row registry unifying the scattered telemetry
//! structs (`Latency`, `Occupancy`, `FaultStats`, `WireStats`,
//! `ServiceMetrics`, …) behind a single mergeable container.
//!
//! Rows are keyed by a rendered name (`subsystem.metric` plus optional
//! `{label=value}` suffixes, e.g. `ps.push.decode_ns{shard=3}`) and hold one
//! of three value kinds:
//!
//! * **Counter** — monotone `u64`, merged by addition.
//! * **Gauge** — `f64` high-watermark, merged by `max` (documented choice:
//!   cross-rank aggregation of occupancy/inflight gauges wants the peak).
//! * **Hist** — a log-bucketed [`Histogram`], merged bucket-wise.
//!
//! All three merge rules are associative and commutative, so per-thread,
//! per-shard, and per-rank sets can be folded in any order — the
//! `MetricSet::merge` property tests in `rust/tests/obs_conformance.rs`
//! pin that down.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use super::hist::Histogram;

/// One metric row.
#[derive(Debug, Clone)]
pub enum MetricValue {
    Counter(u64),
    Gauge(f64),
    Hist(Histogram),
}

/// Labeled metric rows with associative merge. Keys are ordered
/// (`BTreeMap`), so `render_text` output is deterministic.
#[derive(Debug, Clone, Default)]
pub struct MetricSet {
    rows: BTreeMap<String, MetricValue>,
}

impl MetricSet {
    pub fn new() -> Self {
        MetricSet::default()
    }

    /// Add `v` to the counter row `name` (creating it at zero).
    pub fn counter(&mut self, name: &str, v: u64) {
        match self
            .rows
            .entry(name.to_string())
            .or_insert(MetricValue::Counter(0))
        {
            MetricValue::Counter(c) => *c += v,
            other => debug_assert!(false, "metric {name} is not a counter: {other:?}"),
        }
    }

    /// Raise the gauge row `name` to at least `v` (high-watermark).
    pub fn gauge(&mut self, name: &str, v: f64) {
        match self
            .rows
            .entry(name.to_string())
            .or_insert(MetricValue::Gauge(f64::NEG_INFINITY))
        {
            MetricValue::Gauge(g) => *g = g.max(v),
            other => debug_assert!(false, "metric {name} is not a gauge: {other:?}"),
        }
    }

    /// Merge `h` into the histogram row `name`.
    pub fn hist(&mut self, name: &str, h: &Histogram) {
        match self
            .rows
            .entry(name.to_string())
            .or_insert_with(|| MetricValue::Hist(Histogram::new()))
        {
            MetricValue::Hist(mine) => mine.merge(h),
            other => debug_assert!(false, "metric {name} is not a histogram: {other:?}"),
        }
    }

    /// Record one sample into the histogram row `name`.
    pub fn observe(&mut self, name: &str, v: f64) {
        match self
            .rows
            .entry(name.to_string())
            .or_insert_with(|| MetricValue::Hist(Histogram::new()))
        {
            MetricValue::Hist(mine) => mine.record(v),
            other => debug_assert!(false, "metric {name} is not a histogram: {other:?}"),
        }
    }

    /// Fold `other` into `self`. Associative and commutative row-wise
    /// (counter: sum; gauge: max; histogram: bucket-wise sum). Rows with
    /// mismatched kinds are a programming error: `debug_assert` in dev,
    /// first-writer-wins in release.
    pub fn merge(&mut self, other: &MetricSet) {
        for (name, v) in &other.rows {
            match v {
                MetricValue::Counter(c) => self.counter(name, *c),
                MetricValue::Gauge(g) => self.gauge(name, *g),
                MetricValue::Hist(h) => self.hist(name, h),
            }
        }
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn get(&self, name: &str) -> Option<&MetricValue> {
        self.rows.get(name)
    }

    pub fn rows(&self) -> impl Iterator<Item = (&str, &MetricValue)> {
        self.rows.iter().map(|(k, v)| (k.as_str(), v))
    }

    /// Deterministic text rendering — one row per line, histogram rows as a
    /// quantile summary. This is what the PS `Stats` wire op returns and
    /// what `metrics_rank<R>.txt` contains.
    pub fn render_text(&self) -> String {
        let mut out = String::new();
        for (name, v) in &self.rows {
            match v {
                MetricValue::Counter(c) => {
                    let _ = writeln!(out, "{name} counter {c}");
                }
                MetricValue::Gauge(g) => {
                    let _ = writeln!(out, "{name} gauge {g:.6}");
                }
                MetricValue::Hist(h) => {
                    let _ = writeln!(out, "{name} hist {}", h.summary());
                }
            }
        }
        out
    }
}

/// Render a row key with one label: `name{label=value}`.
pub fn labeled(name: &str, label: &str, value: impl std::fmt::Display) -> String {
    format!("{name}{{{label}={value}}}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_sum_and_gauges_max() {
        let mut m = MetricSet::new();
        m.counter("a.ops", 3);
        m.counter("a.ops", 4);
        m.gauge("a.peak", 1.5);
        m.gauge("a.peak", 0.5);
        assert!(matches!(m.get("a.ops"), Some(MetricValue::Counter(7))));
        match m.get("a.peak") {
            Some(MetricValue::Gauge(g)) => assert_eq!(*g, 1.5),
            other => panic!("unexpected row {other:?}"),
        }
    }

    #[test]
    fn merge_folds_rows() {
        let mut a = MetricSet::new();
        a.counter("x", 1);
        a.observe("lat", 100.0);
        let mut b = MetricSet::new();
        b.counter("x", 2);
        b.counter("y", 5);
        b.observe("lat", 300.0);
        a.merge(&b);
        assert!(matches!(a.get("x"), Some(MetricValue::Counter(3))));
        assert!(matches!(a.get("y"), Some(MetricValue::Counter(5))));
        match a.get("lat") {
            Some(MetricValue::Hist(h)) => assert_eq!(h.count(), 2),
            other => panic!("unexpected row {other:?}"),
        }
    }

    #[test]
    fn text_rendering_is_deterministic() {
        let mut m = MetricSet::new();
        m.counter(&labeled("ps.push", "shard", 3), 9);
        m.gauge("occ.peak", 0.25);
        let t = m.render_text();
        assert!(t.contains("ps.push{shard=3} counter 9"));
        assert!(t.contains("occ.peak gauge 0.250000"));
        // BTreeMap ordering: occ.* sorts before ps.*.
        assert!(t.find("occ.peak").unwrap() < t.find("ps.push").unwrap());
    }
}
