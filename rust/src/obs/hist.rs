//! Log-bucketed histogram: bounded-memory quantiles with a guaranteed
//! relative error.
//!
//! This is the **single quantile implementation in the tree** — the bench
//! harness ([`crate::bench`]), the latency accounting in
//! [`crate::metrics::Latency`], and the PS shard metrics all route through
//! it. Exact sorted-sample quantiles exist only as test oracles.
//!
//! # Bucketing scheme
//!
//! Positive finite values are bucketed by their f64 bit pattern: the 11-bit
//! exponent selects an octave and the top [`SUB_BITS`] mantissa bits split
//! each octave into [`SUB`] linear sub-buckets. The covered domain is
//! `[2^-64, 2^64)` — 128 octaves × 64 sub-buckets = 8192 buckets (64 KiB,
//! allocated lazily on the first positive sample). Values outside the domain
//! clamp to the edge buckets; zero, negative, and non-finite values land in
//! a dedicated underflow bucket whose representative is 0.
//!
//! A bucket spanning `[lo, hi)` has width `lo/64 ≤ w ≤ hi/64`, and quantiles
//! report the bucket *midpoint* clamped to the observed `[min, max]`, so the
//! relative quantile error is at most `1/128 ≈ 0.8%` (worst case `1/64`
//! before the midpoint halving). That bound is what lets the
//! `pipeline_overlap` bench keep its hard `ratio <= 1.05` assert after the
//! migration off exact sample vectors.

/// Mantissa bits used for sub-bucketing: 64 linear sub-buckets per octave.
const SUB_BITS: u32 = 6;
/// Sub-buckets per octave.
const SUB: usize = 1 << SUB_BITS;
/// Smallest bucketed exponent: values below `2^MIN_EXP` clamp to bucket 0.
const MIN_EXP: i32 = -64;
/// One past the largest bucketed exponent: values at or above `2^MAX_EXP`
/// clamp to the last bucket.
const MAX_EXP: i32 = 64;
/// Total bucket count (excluding the underflow bucket).
const NBUCKETS: usize = ((MAX_EXP - MIN_EXP) as usize) * SUB;

/// Bounded-memory histogram with ~0.8% relative quantile error.
///
/// `record` is O(1) and allocation-free after the first positive sample
/// (which lazily allocates the 64 KiB bucket array). `merge` is bucket-wise
/// addition — associative and commutative, so per-thread / per-rank
/// histograms can be combined in any order.
#[derive(Debug, Clone, Default)]
pub struct Histogram {
    /// Count of samples in the underflow bucket (zero, negative, non-finite).
    under: u64,
    count: u64,
    /// Sum of all finite samples (exact mean; non-finite samples add 0).
    sum: f64,
    min: f64,
    max: f64,
    buckets: Option<Box<[u64]>>,
}

/// Bucket index for a positive finite value.
fn bucket_index(v: f64) -> usize {
    let bits = v.to_bits();
    let exp = ((bits >> 52) & 0x7ff) as i32 - 1023;
    if exp < MIN_EXP {
        return 0;
    }
    if exp >= MAX_EXP {
        return NBUCKETS - 1;
    }
    let sub = ((bits >> (52 - SUB_BITS)) & (SUB as u64 - 1)) as usize;
    ((exp - MIN_EXP) as usize) * SUB + sub
}

/// Midpoint representative of bucket `i`.
fn bucket_mid(i: usize) -> f64 {
    let exp = MIN_EXP + (i / SUB) as i32;
    let sub = (i % SUB) as f64;
    2f64.powi(exp) * (1.0 + (sub + 0.5) / SUB as f64)
}

impl Histogram {
    pub fn new() -> Self {
        Histogram {
            under: 0,
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            buckets: None,
        }
    }

    /// Build a histogram from a slice of samples.
    pub fn from_samples(xs: &[f64]) -> Self {
        let mut h = Histogram::new();
        for &x in xs {
            h.record(x);
        }
        h
    }

    /// Record one sample. Zero, negative, and non-finite values go to the
    /// underflow bucket (representative 0); the histogram is designed for
    /// non-negative measurements (durations, byte counts, rates).
    pub fn record(&mut self, v: f64) {
        self.count += 1;
        if v.is_finite() {
            self.sum += v;
            self.min = self.min.min(v);
            self.max = self.max.max(v);
        }
        if v.is_finite() && v > 0.0 {
            let buckets = self
                .buckets
                .get_or_insert_with(|| vec![0u64; NBUCKETS].into_boxed_slice());
            buckets[bucket_index(v)] += 1;
        } else {
            self.under += 1;
        }
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Exact sum of all finite samples.
    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// Exact mean of all samples (finite sum over total count).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        self.sum / self.count as f64
    }

    /// Smallest finite sample seen (NaN when empty).
    pub fn min(&self) -> f64 {
        if self.min.is_finite() {
            self.min
        } else {
            f64::NAN
        }
    }

    /// Largest finite sample seen (NaN when empty).
    pub fn max(&self) -> f64 {
        if self.max.is_finite() {
            self.max
        } else {
            f64::NAN
        }
    }

    /// Quantile `q ∈ [0, 1]` by nearest rank over the buckets, reported as
    /// the bucket midpoint clamped to the observed `[min, max]`. Relative
    /// error ≤ ~0.8% inside the bucketed domain. NaN when empty.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        let target = ((q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut cum = self.under;
        let mut rep = 0.0;
        if cum < target {
            if let Some(buckets) = &self.buckets {
                for (i, &c) in buckets.iter().enumerate() {
                    if c == 0 {
                        continue;
                    }
                    cum += c;
                    if cum >= target {
                        rep = bucket_mid(i);
                        break;
                    }
                }
            }
        }
        if self.min.is_finite() {
            rep = rep.clamp(self.min, self.max);
        }
        rep
    }

    /// Percentile `p ∈ [0, 100]` (convenience wrapper over [`quantile`]).
    ///
    /// [`quantile`]: Histogram::quantile
    pub fn percentile(&self, p: f64) -> f64 {
        self.quantile(p / 100.0)
    }

    pub fn median(&self) -> f64 {
        self.quantile(0.5)
    }

    /// Bucket-wise merge: associative and commutative, so cross-rank and
    /// cross-thread aggregation order never changes counts or quantiles
    /// (floating-point `sum` differs only by addition reordering).
    pub fn merge(&mut self, other: &Histogram) {
        self.under += other.under;
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
        if let Some(ob) = &other.buckets {
            let buckets = self
                .buckets
                .get_or_insert_with(|| vec![0u64; NBUCKETS].into_boxed_slice());
            for (b, o) in buckets.iter_mut().zip(ob.iter()) {
                *b += o;
            }
        }
    }

    /// One-line summary: `n=… mean=… p50=… p90=… p99=… max=…`.
    pub fn summary(&self) -> String {
        if self.count == 0 {
            return "n=0".to_string();
        }
        format!(
            "n={} mean={:.1} p50={:.1} p90={:.1} p99={:.1} max={:.1}",
            self.count,
            self.mean(),
            self.quantile(0.50),
            self.quantile(0.90),
            self.quantile(0.99),
            self.max()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_is_nan() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert!(h.quantile(0.5).is_nan());
        assert!(h.mean().is_nan());
        assert!(h.min().is_nan());
    }

    #[test]
    fn single_value_is_exact() {
        let mut h = Histogram::new();
        h.record(42.0);
        // One sample: every quantile clamps to [min, max] = [42, 42].
        assert_eq!(h.quantile(0.0), 42.0);
        assert_eq!(h.quantile(0.5), 42.0);
        assert_eq!(h.quantile(1.0), 42.0);
        assert_eq!(h.mean(), 42.0);
    }

    #[test]
    fn quantiles_within_bound() {
        let mut h = Histogram::new();
        for i in 1..=1000 {
            h.record(i as f64);
        }
        for (q, exact) in [(0.5, 500.0), (0.9, 900.0), (0.99, 990.0)] {
            let got = h.quantile(q);
            assert!(
                (got - exact).abs() / exact <= 1.0 / 64.0,
                "q={q}: got {got}, exact {exact}"
            );
        }
        assert_eq!(h.mean(), 500.5);
    }

    #[test]
    fn underflow_bucket_handles_junk() {
        let mut h = Histogram::new();
        h.record(0.0);
        h.record(-5.0);
        h.record(f64::NAN);
        h.record(10.0);
        assert_eq!(h.count(), 4);
        // p100 is the largest real value.
        assert_eq!(h.quantile(1.0), 10.0);
        // p25 sits in the underflow bucket (representative 0, already inside
        // the observed [min, max] range).
        assert_eq!(h.quantile(0.25), 0.0);
    }

    #[test]
    fn merge_matches_combined_recording() {
        let xs: Vec<f64> = (1..=500).map(|i| i as f64 * 0.37).collect();
        let ys: Vec<f64> = (1..=500).map(|i| i as f64 * 1.91).collect();
        let mut a = Histogram::from_samples(&xs);
        let b = Histogram::from_samples(&ys);
        a.merge(&b);
        let mut all = xs.clone();
        all.extend_from_slice(&ys);
        let c = Histogram::from_samples(&all);
        assert_eq!(a.count(), c.count());
        for q in [0.1, 0.5, 0.9, 0.99] {
            assert_eq!(a.quantile(q), c.quantile(q));
        }
    }

    #[test]
    fn domain_edges_clamp() {
        let mut h = Histogram::new();
        h.record(1e-300);
        h.record(1e300);
        assert_eq!(h.count(), 2);
        // Representatives clamp to observed min/max, so even out-of-domain
        // values produce ordered, finite quantiles.
        assert!(h.quantile(0.0) <= h.quantile(1.0));
        assert!(h.quantile(1.0).is_finite());
    }
}
