//! Structured tracing: `obs_span!`-style guards recording monotonic nanos,
//! rank, step, and an interned static name into lock-free per-thread ring
//! buffers.
//!
//! # Design constraints
//!
//! * **Off by default, and free when off.** A span site with tracing
//!   disabled costs one relaxed atomic load — no TLS touch, no allocation —
//!   so the counting-allocator zero-steady-state-alloc conformance suites
//!   keep passing with observability compiled in at defaults.
//! * **Zero steady-state allocation when on.** The first span on a thread
//!   allocates that thread's ring and registers it (first-touch, during
//!   warmup); the first use of a span site interns its `&'static str` name
//!   into a global table. After that, recording is a few relaxed atomic
//!   stores into pre-allocated slots.
//! * **Lock-free rings, safe concurrent export.** Each slot carries a
//!   seqlock word (odd while being written); the exporter snapshots rings
//!   from any thread and skips torn slots. Ring wrap discards the oldest
//!   events; the exporter re-balances begin/end pairs so emitted traces are
//!   always well-formed.
//! * **Compile-out path.** Building with `--features trace-off` turns
//!   `SpanGuard::enter` into a no-op that the optimizer deletes entirely.
//!
//! Spans are recorded as separate begin/end events (two ring slots) so
//! per-thread chronology is the natural ring order. Export pairs them up,
//! drops unmatched halves (ring wrap), and emits Chrome-trace `B`/`E`
//! events plus a JSONL span log per rank.

use std::cell::RefCell;
use std::fs;
use std::io::Write as _;
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

use anyhow::{Context, Result};

/// Events kept per thread (begin and end each take one slot).
const RING_CAP: usize = 4096;

static ENABLED: AtomicBool = AtomicBool::new(false);
static SAMPLE_EVERY: AtomicU32 = AtomicU32::new(1);
static RANK: AtomicU32 = AtomicU32::new(0);
static STEP: AtomicU64 = AtomicU64::new(0);
static NEXT_TID: AtomicU32 = AtomicU32::new(0);

/// Interned span-site names; a `Site`'s id is its index + 1 (0 = uninterned).
static NAMES: Mutex<Vec<&'static str>> = Mutex::new(Vec::new());
/// All per-thread rings ever created (threads may exit; rings outlive them).
static RINGS: Mutex<Vec<Arc<Ring>>> = Mutex::new(Vec::new());

fn clock_base() -> &'static Instant {
    static BASE: OnceLock<Instant> = OnceLock::new();
    BASE.get_or_init(Instant::now)
}

/// Monotonic nanoseconds since the first observability touch in this
/// process. Shared by the tracer and the flight recorder so their
/// timestamps correlate.
pub fn now_ns() -> u64 {
    clock_base().elapsed().as_nanos() as u64
}

/// Enable/disable span recording at runtime (default: disabled).
pub fn set_enabled(on: bool) {
    if on {
        // Pin the clock base before the first span so timestamps start near
        // zero and stay comparable across threads.
        let _ = clock_base();
    }
    ENABLED.store(on, Ordering::Relaxed);
}

pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Record every `n`-th span per thread (1 = record all; 0 is treated as 1).
pub fn set_sample_every(n: u32) {
    SAMPLE_EVERY.store(n.max(1), Ordering::Relaxed);
}

pub fn set_rank(r: u32) {
    RANK.store(r, Ordering::Relaxed);
}

pub fn rank() -> u32 {
    RANK.load(Ordering::Relaxed)
}

/// Set the current training step, attached to every span and breadcrumb
/// recorded afterwards. A single relaxed store — callable unconditionally
/// from step loops.
pub fn set_step(s: u64) {
    STEP.store(s, Ordering::Relaxed);
}

pub fn step() -> u64 {
    STEP.load(Ordering::Relaxed)
}

/// A static span call site. Declare via [`crate::obs_span!`]; the name is
/// interned into the global table on first use.
pub struct Site {
    name: &'static str,
    id: AtomicU32,
}

impl Site {
    pub const fn new(name: &'static str) -> Self {
        Site { name, id: AtomicU32::new(0) }
    }

    /// Interned id (index + 1). First touch takes the name-table lock and
    /// allocates; afterwards a relaxed load.
    pub(crate) fn id(&self) -> u32 {
        let id = self.id.load(Ordering::Relaxed);
        if id != 0 {
            return id;
        }
        let mut tab = NAMES.lock().unwrap();
        let again = self.id.load(Ordering::Relaxed);
        if again != 0 {
            return again;
        }
        tab.push(self.name);
        let id = tab.len() as u32;
        self.id.store(id, Ordering::Relaxed);
        id
    }
}

pub(crate) fn site_name(id: u32) -> &'static str {
    if id == 0 {
        return "?";
    }
    let tab = NAMES.lock().unwrap();
    tab.get(id as usize - 1).copied().unwrap_or("?")
}

/// Per-thread event ring. Written only by the owning thread; read by the
/// exporter through per-slot seqlocks.
struct Ring {
    tid: u32,
    /// Total events ever written (logical head; slot = head % RING_CAP).
    head: AtomicU64,
    seq: Box<[AtomicU64]>,
    t_ns: Box<[AtomicU64]>,
    /// `kind << 32 | site_id` (kind: 0 = begin, 1 = end).
    meta: Box<[AtomicU64]>,
    step: Box<[AtomicU64]>,
}

fn atomic_slice(n: usize) -> Box<[AtomicU64]> {
    (0..n).map(|_| AtomicU64::new(0)).collect()
}

impl Ring {
    fn new(tid: u32) -> Self {
        Ring {
            tid,
            head: AtomicU64::new(0),
            seq: atomic_slice(RING_CAP),
            t_ns: atomic_slice(RING_CAP),
            meta: atomic_slice(RING_CAP),
            step: atomic_slice(RING_CAP),
        }
    }

    fn record(&self, kind: u64, site: u32, t: u64) {
        let h = self.head.fetch_add(1, Ordering::Relaxed);
        let i = (h % RING_CAP as u64) as usize;
        let s = self.seq[i].load(Ordering::Relaxed);
        self.seq[i].store(s | 1, Ordering::Relaxed);
        self.t_ns[i].store(t, Ordering::Relaxed);
        self.meta[i].store((kind << 32) | site as u64, Ordering::Relaxed);
        self.step[i].store(STEP.load(Ordering::Relaxed), Ordering::Relaxed);
        self.seq[i].store((s | 1).wrapping_add(1), Ordering::Release);
    }
}

struct Tls {
    ring: Option<Arc<Ring>>,
    /// Per-thread span counter driving the sampling decision.
    ctr: u64,
}

thread_local! {
    static TLS: RefCell<Tls> = const { RefCell::new(Tls { ring: None, ctr: 0 }) };
}

/// RAII span guard: records a begin event on creation and an end event on
/// drop (both, or neither — so exported traces always balance).
pub struct SpanGuard {
    site: u32,
    active: bool,
}

impl SpanGuard {
    #[cfg(not(feature = "trace-off"))]
    #[inline]
    pub fn enter(site: &'static Site) -> SpanGuard {
        if !ENABLED.load(Ordering::Relaxed) {
            return SpanGuard { site: 0, active: false };
        }
        Self::enter_slow(site)
    }

    /// Compile-out path: with `--features trace-off` every span site is an
    /// inert guard the optimizer removes.
    #[cfg(feature = "trace-off")]
    #[inline(always)]
    pub fn enter(_site: &'static Site) -> SpanGuard {
        SpanGuard { site: 0, active: false }
    }

    #[cfg(not(feature = "trace-off"))]
    fn enter_slow(site: &'static Site) -> SpanGuard {
        let every = SAMPLE_EVERY.load(Ordering::Relaxed) as u64;
        TLS.with(|tls| {
            let mut tls = tls.borrow_mut();
            tls.ctr += 1;
            if tls.ctr % every != 0 {
                return SpanGuard { site: 0, active: false };
            }
            if tls.ring.is_none() {
                let tid = NEXT_TID.fetch_add(1, Ordering::Relaxed);
                let ring = Arc::new(Ring::new(tid));
                RINGS.lock().unwrap().push(Arc::clone(&ring));
                tls.ring = Some(ring);
            }
            let id = site.id();
            let ring = tls.ring.as_ref().unwrap();
            ring.record(0, id, now_ns());
            SpanGuard { site: id, active: true }
        })
    }
}

impl Drop for SpanGuard {
    #[inline]
    fn drop(&mut self) {
        if !self.active {
            return;
        }
        let t = now_ns();
        TLS.with(|tls| {
            let tls = tls.borrow();
            if let Some(ring) = tls.ring.as_ref() {
                ring.record(1, self.site, t);
            }
        });
    }
}

/// Declare a static span site and enter it:
/// `let _sp = obs_span!("ring.hop");` — the guard records begin on creation
/// and end on drop. Free when tracing is disabled or compiled out.
#[macro_export]
macro_rules! obs_span {
    ($name:literal) => {{
        static SITE: $crate::obs::trace::Site = $crate::obs::trace::Site::new($name);
        $crate::obs::trace::SpanGuard::enter(&SITE)
    }};
}

/// One exported span event.
#[derive(Clone, Copy)]
struct Event {
    t_ns: u64,
    kind: u64,
    site: u32,
    step: u64,
}

/// Snapshot a ring into chronological events, skipping torn slots.
fn snapshot(ring: &Ring) -> Vec<Event> {
    let head = ring.head.load(Ordering::Acquire);
    let start = head.saturating_sub(RING_CAP as u64);
    let mut out = Vec::with_capacity((head - start) as usize);
    for h in start..head {
        let i = (h % RING_CAP as u64) as usize;
        let s0 = ring.seq[i].load(Ordering::Acquire);
        if s0 & 1 == 1 {
            continue;
        }
        let meta = ring.meta[i].load(Ordering::Relaxed);
        let ev = Event {
            t_ns: ring.t_ns[i].load(Ordering::Relaxed),
            kind: meta >> 32,
            site: (meta & 0xffff_ffff) as u32,
            step: ring.step[i].load(Ordering::Relaxed),
        };
        if ring.seq[i].load(Ordering::Acquire) != s0 {
            continue;
        }
        out.push(ev);
    }
    out
}

/// A matched span: begin/end pair from one thread.
struct Span {
    t0: u64,
    t1: u64,
    site: u32,
    step: u64,
}

/// Pair begin/end events with a stack; drop unmatched halves (ring wrap).
fn pair_spans(events: &[Event]) -> Vec<Span> {
    let mut stack: Vec<Event> = Vec::new();
    let mut out = Vec::new();
    for &e in events {
        if e.kind == 0 {
            stack.push(e);
        } else if stack.last().is_some_and(|b| b.site == e.site) {
            let b = stack.pop().unwrap();
            out.push(Span { t0: b.t_ns, t1: e.t_ns, site: e.site, step: b.step });
        } else {
            // End without a matching begin (wrapped away): the stack below
            // it is unreliable too, so drop the lot.
            stack.clear();
        }
    }
    out
}

fn json_escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

/// Append one Chrome-trace event object (`ph` is `"B"` or `"E"`).
fn chrome_event(
    out: &mut String,
    first: &mut bool,
    s: &Span,
    ph: &str,
    t: u64,
    pid: u32,
    tid: u32,
) {
    if !*first {
        out.push_str(",\n");
    }
    *first = false;
    out.push_str(&format!(
        "{{\"name\":\"{}\",\"ph\":\"{}\",\"ts\":{:.3},\"pid\":{},\"tid\":{},\"args\":{{\"rank\":{},\"step\":{}}}}}",
        json_escape(site_name(s.site)),
        ph,
        t as f64 / 1000.0,
        pid,
        tid,
        pid,
        s.step
    ));
}

/// Export the Chrome-trace file (`trace_rank<R>.json`) and the JSONL span
/// log (`events_rank<R>.jsonl`) for this process into `dir`. Idempotent;
/// call once per run after the workload finishes.
pub fn export(dir: &Path) -> Result<()> {
    fs::create_dir_all(dir).with_context(|| format!("creating {}", dir.display()))?;
    let r = rank();

    let rings: Vec<Arc<Ring>> = RINGS.lock().unwrap().clone();
    // (tid, spans) per thread, spans sorted by begin time (ties: parents —
    // longer spans — first).
    let mut threads: Vec<(u32, Vec<Span>)> = Vec::new();
    for ring in &rings {
        let mut spans = pair_spans(&snapshot(ring));
        spans.sort_by_key(|s| (s.t0, u64::MAX - (s.t1 - s.t0)));
        threads.push((ring.tid, spans));
    }
    threads.sort_by_key(|(tid, _)| *tid);

    // Chrome trace: B/E event pairs per thread, emitted by a stack walk
    // over the (already well-nested) span list. This keeps the output
    // balanced and ts-monotone even when spans have zero duration or touch
    // at a shared timestamp — cases where a plain timestamp sort would emit
    // an `E` ahead of its `B` and fail check_trace.py's strict matcher.
    let chrome = dir.join(format!("trace_rank{r}.json"));
    let mut out = String::from("[\n");
    let mut first = true;
    for (tid, spans) in &threads {
        let mut open: Vec<&Span> = Vec::new();
        for s in spans {
            while open.last().is_some_and(|o| o.t1 <= s.t0) {
                let o = open.pop().unwrap();
                chrome_event(&mut out, &mut first, o, "E", o.t1, r, *tid);
            }
            chrome_event(&mut out, &mut first, s, "B", s.t0, r, *tid);
            open.push(s);
        }
        while let Some(o) = open.pop() {
            chrome_event(&mut out, &mut first, o, "E", o.t1, r, *tid);
        }
    }
    out.push_str("\n]\n");
    fs::write(&chrome, out).with_context(|| format!("writing {}", chrome.display()))?;

    // JSONL: one complete span per line, per-thread blocks in begin-time
    // order so t_ns is non-decreasing within each tid.
    let jsonl = dir.join(format!("events_rank{r}.jsonl"));
    let mut f = fs::File::create(&jsonl).with_context(|| format!("writing {}", jsonl.display()))?;
    for (tid, spans) in &threads {
        for s in spans {
            writeln!(
                f,
                "{{\"t_ns\":{},\"dur_ns\":{},\"name\":\"{}\",\"rank\":{},\"tid\":{},\"step\":{}}}",
                s.t0,
                s.t1 - s.t0,
                json_escape(site_name(s.site)),
                r,
                tid,
                s.step
            )?;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_spans_are_inert() {
        static SITE: Site = Site::new("test.inert");
        set_enabled(false);
        let g = SpanGuard::enter(&SITE);
        assert!(!g.active);
    }

    #[test]
    fn pairing_drops_unmatched_halves() {
        let b = |site, t| Event { t_ns: t, kind: 0, site, step: 0 };
        let e = |site, t| Event { t_ns: t, kind: 1, site, step: 0 };
        // Orphan end (site 9) then a proper nested pair-of-pairs.
        let evs = [e(9, 5), b(1, 10), b(2, 11), e(2, 12), e(1, 13)];
        let spans = pair_spans(&evs);
        assert_eq!(spans.len(), 2);
        assert!(spans.iter().all(|s| s.t1 >= s.t0));
    }

    #[test]
    fn site_interning_is_stable() {
        static A: Site = Site::new("test.site_a");
        let id1 = A.id();
        let id2 = A.id();
        assert_eq!(id1, id2);
        assert_eq!(site_name(id1), "test.site_a");
    }
}
