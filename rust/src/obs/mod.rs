//! Unified observability: structured tracing ([`trace`]), a mergeable
//! metrics registry ([`set`] / [`hist`]), and a distributed flight recorder
//! ([`flight`]).
//!
//! Everything here is hand-rolled and dependency-free (the offline registry
//! vendors only `anyhow` + `rand_core`), and everything is **free at
//! defaults**: tracing is off unless `--trace-out` is given (one relaxed
//! atomic load per span site), the flight recorder is a bounded
//! allocation-free ring, and the histogram allocates its 64 KiB bucket
//! array lazily. The codec zero-steady-state-alloc conformance suites run
//! with this module compiled in.
//!
//! # Naming scheme
//!
//! Span and metric names are dot-separated `subsystem.verb` paths with
//! optional `{label=value}` row suffixes:
//!
//! * spans — `step`, `exchange`, `ring.hop`, `a2a.encode`, `ps.push`,
//!   `net.flush`, `sim.step` …
//! * metrics — `wire.messages`, `faults.dead_workers`,
//!   `occupancy.io_blocked_s`, `ps.push.decode_ns{shard=3}` …
//!
//! # Exported artifacts (per rank, under `--trace-out DIR`)
//!
//! * `trace_rank<R>.json` — Chrome trace-event JSON (`chrome://tracing` or
//!   <https://ui.perfetto.dev>).
//! * `events_rank<R>.jsonl` — one complete span per line
//!   (`t_ns`/`dur_ns`/`name`/`rank`/`tid`/`step`).
//! * `metrics_rank<R>.txt` — deterministic [`MetricSet::render_text`] dump.
//! * `flight_rank<R>.txt` — flight-recorder dumps (appended per incident).
//!
//! `scripts/check_trace.py` validates the first two.

pub mod flight;
pub mod hist;
pub mod set;
pub mod trace;

pub use hist::Histogram;
pub use set::{labeled, MetricSet, MetricValue};
pub use trace::{
    enabled, now_ns, rank, set_enabled, set_rank, set_sample_every, set_step, step, SpanGuard,
};

use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::Result;

static TRACE_DIR: Mutex<Option<PathBuf>> = Mutex::new(None);

/// One-stop initialisation from CLI knobs: set the rank, remember the trace
/// directory (for exporters and flight dumps), enable span recording when a
/// directory is given, and apply the sampling knob.
pub fn init(trace_dir: Option<&Path>, rank: u32, sample_every: u32) {
    trace::set_rank(rank);
    trace::set_sample_every(sample_every);
    if let Some(dir) = trace_dir {
        *TRACE_DIR.lock().unwrap() = Some(dir.to_path_buf());
        flight::set_dump_dir(dir);
        trace::set_enabled(true);
    }
}

/// The configured `--trace-out` directory, if any.
pub fn trace_dir() -> Option<PathBuf> {
    TRACE_DIR.lock().unwrap().clone()
}

/// Export `trace_rank<R>.json` + `events_rank<R>.jsonl` into the configured
/// trace directory. No-op when tracing was never enabled.
pub fn export_traces() -> Result<()> {
    if let Some(dir) = trace_dir() {
        trace::export(&dir)?;
    }
    Ok(())
}

/// Write `metrics_rank<R>.txt` into the configured trace directory. No-op
/// without one.
pub fn export_metrics(set: &MetricSet) -> Result<()> {
    if let Some(dir) = trace_dir() {
        std::fs::create_dir_all(&dir)?;
        let path = dir.join(format!("metrics_rank{}.txt", trace::rank()));
        std::fs::write(path, set.render_text())?;
    }
    Ok(())
}
