//! Flight recorder: an always-on, bounded, allocation-free breadcrumb ring
//! whose last-N events are dumped when something goes wrong — a rank dies,
//! recovery triggers, or an exchange errors — so multi-process failures
//! leave a diagnosable artifact instead of a bare exit code.
//!
//! Breadcrumbs are cheap enough to leave on unconditionally at step / hop /
//! op granularity: one global `fetch_add` plus a handful of relaxed stores
//! into pre-allocated slots (the ring itself is allocated on the first
//! crumb — first-touch, never steady-state). Each crumb carries the shared
//! monotonic clock, the interned static name, the current step, and three
//! free-form `u64` arguments whose meaning is per-site (documented at the
//! call site).
//!
//! [`dump`] renders the surviving crumbs oldest-first to stderr and — when a
//! trace directory is configured — appends them to
//! `<dir>/flight_rank<R>.txt` under a reason header. Dumping is additive:
//! a recovery dump followed by a fatal dump yields a narrative, not an
//! overwrite.

use std::fs;
use std::io::Write as _;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};

use super::trace::{now_ns, rank, Site};

/// Crumbs kept (ring wraps, oldest first to go).
const FLIGHT_CAP: usize = 512;

/// Where dumps land (`flight_rank<R>.txt`); set via [`set_dump_dir`].
static DUMP_DIR: Mutex<Option<PathBuf>> = Mutex::new(None);

struct FlightRing {
    head: AtomicU64,
    seq: Box<[AtomicU64]>,
    t_ns: Box<[AtomicU64]>,
    /// `site_id << 32 | low 32 bits of step`.
    meta: Box<[AtomicU64]>,
    a: Box<[AtomicU64]>,
    b: Box<[AtomicU64]>,
    c: Box<[AtomicU64]>,
}

fn slots(n: usize) -> Box<[AtomicU64]> {
    (0..n).map(|_| AtomicU64::new(0)).collect()
}

fn ring() -> &'static FlightRing {
    static RING: OnceLock<FlightRing> = OnceLock::new();
    RING.get_or_init(|| FlightRing {
        head: AtomicU64::new(0),
        seq: slots(FLIGHT_CAP),
        t_ns: slots(FLIGHT_CAP),
        meta: slots(FLIGHT_CAP),
        a: slots(FLIGHT_CAP),
        b: slots(FLIGHT_CAP),
        c: slots(FLIGHT_CAP),
    })
}

/// Record a breadcrumb. Multi-writer safe: slots are claimed by a global
/// `fetch_add` and guarded by per-slot seqlocks; a reader racing a writer
/// skips the torn slot. (Two writers can only collide on one slot after a
/// full ring wrap mid-write — acceptable for a diagnostic ring.)
pub fn crumb(site: &'static Site, a: u64, b: u64, c: u64) {
    let r = ring();
    let h = r.head.fetch_add(1, Ordering::Relaxed);
    let i = (h % FLIGHT_CAP as u64) as usize;
    let s = r.seq[i].load(Ordering::Relaxed);
    r.seq[i].store(s | 1, Ordering::Relaxed);
    r.t_ns[i].store(now_ns(), Ordering::Relaxed);
    let step = super::trace::step();
    r.meta[i].store(((site.id() as u64) << 32) | (step & 0xffff_ffff), Ordering::Relaxed);
    r.a[i].store(a, Ordering::Relaxed);
    r.b[i].store(b, Ordering::Relaxed);
    r.c[i].store(c, Ordering::Relaxed);
    r.seq[i].store((s | 1).wrapping_add(1), Ordering::Release);
}

/// Configure where [`dump`] writes `flight_rank<R>.txt` (usually the
/// `--trace-out` directory). Without it, dumps still go to stderr.
pub fn set_dump_dir(dir: &Path) {
    *DUMP_DIR.lock().unwrap() = Some(dir.to_path_buf());
}

fn render(reason: &str) -> String {
    let r = ring();
    let head = r.head.load(Ordering::Acquire);
    let start = head.saturating_sub(FLIGHT_CAP as u64);
    let mut out = String::new();
    out.push_str(&format!(
        "=== flight recorder dump (rank {}, {} crumbs, reason: {}) ===\n",
        rank(),
        head - start,
        reason
    ));
    for h in start..head {
        let i = (h % FLIGHT_CAP as u64) as usize;
        let s0 = r.seq[i].load(Ordering::Acquire);
        if s0 & 1 == 1 {
            continue;
        }
        let t = r.t_ns[i].load(Ordering::Relaxed);
        let meta = r.meta[i].load(Ordering::Relaxed);
        let (a, b, c) = (
            r.a[i].load(Ordering::Relaxed),
            r.b[i].load(Ordering::Relaxed),
            r.c[i].load(Ordering::Relaxed),
        );
        if r.seq[i].load(Ordering::Acquire) != s0 {
            continue;
        }
        let name = super::trace::site_name((meta >> 32) as u32);
        let step = meta & 0xffff_ffff;
        out.push_str(&format!("t={t}ns step={step} {name} a={a} b={b} c={c}\n"));
    }
    out.push_str("=== end flight dump ===\n");
    out
}

/// Dump the surviving breadcrumbs to stderr and (if a dump dir is set)
/// append them to `flight_rank<R>.txt`. Called on fatal errors, recovery
/// triggers, and exchange failures; safe to call repeatedly.
pub fn dump(reason: &str) {
    let text = render(reason);
    eprint!("{text}");
    let dir = DUMP_DIR.lock().unwrap().clone();
    if let Some(dir) = dir {
        let _ = fs::create_dir_all(&dir);
        let path = dir.join(format!("flight_rank{}.txt", rank()));
        if let Ok(mut f) = fs::OpenOptions::new().create(true).append(true).open(&path) {
            let _ = f.write_all(text.as_bytes());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    static TEST_SITE: Site = Site::new("test.crumb");

    // One sequential test: the flight ring is a process-wide global, so
    // splitting these into parallel #[test]s would race on its contents.
    #[test]
    fn crumbs_render_and_ring_wraps() {
        for i in 0..10 {
            crumb(&TEST_SITE, i, i * 2, 0);
        }
        let text = render("unit test");
        assert!(text.contains("flight recorder dump"));
        assert!(text.contains("test.crumb"));
        assert!(text.contains("a=9 b=18 c=0"));

        for i in 0..(FLIGHT_CAP as u64 + 50) {
            crumb(&TEST_SITE, 1_000_000 + i, 0, 0);
        }
        let text = render("wrap test");
        // The newest crumb is present; the ring never grows past CAP lines.
        assert!(text.contains(&format!("a={}", 1_000_000 + FLIGHT_CAP as u64 + 49)));
        assert!(text.lines().count() <= FLIGHT_CAP + 2);
    }
}
