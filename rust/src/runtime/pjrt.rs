//! The real PJRT-backed runtime (requires the `xla` feature and the
//! vendored `xla` / xla_extension crate).
//!
//! Pattern follows /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`. HLO *text* is the interchange format
//! (xla_extension 0.5.1 rejects jax≥0.5 64-bit-id protos; the text parser
//! reassigns ids).

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

use anyhow::{Context, Result};

use super::artifact::{self, Manifest};

/// Output tensor type (re-exported so callers need not name the xla crate).
pub type Literal = xla::Literal;

/// A host-side input tensor.
pub enum Input<'a> {
    F32(&'a [f32], &'a [usize]),
    I32(&'a [i32], &'a [usize]),
}

impl Input<'_> {
    fn to_literal(&self) -> Result<xla::Literal> {
        let lit = match self {
            Input::F32(data, shape) => {
                anyhow::ensure!(
                    data.len() == shape.iter().product::<usize>(),
                    "f32 input length {} != shape {:?}",
                    data.len(),
                    shape
                );
                let bytes: &[u8] = unsafe {
                    std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
                };
                xla::Literal::create_from_shape_and_untyped_data(
                    xla::ElementType::F32,
                    shape,
                    bytes,
                )?
            }
            Input::I32(data, shape) => {
                anyhow::ensure!(
                    data.len() == shape.iter().product::<usize>(),
                    "i32 input length {} != shape {:?}",
                    data.len(),
                    shape
                );
                let bytes: &[u8] = unsafe {
                    std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
                };
                xla::Literal::create_from_shape_and_untyped_data(
                    xla::ElementType::S32,
                    shape,
                    bytes,
                )?
            }
        };
        Ok(lit)
    }
}

/// The runtime: one PJRT CPU client plus an executable cache.
///
/// PJRT handles are raw pointers (`!Send`); the coordinator owns one runtime
/// on its driver thread and time-multiplexes simulated workers over it —
/// parallelism across simulated devices is accounted in virtual time by
/// `simnet`, not wall time (see DESIGN.md).
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
}

impl Runtime {
    /// Create a CPU runtime over the given artifacts directory.
    pub fn new(dir: impl AsRef<std::path::Path>) -> Result<Self> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Self { client, manifest, cache: RefCell::new(HashMap::new()) })
    }

    /// Default artifacts directory (env `QSGD_ARTIFACTS` or repo-relative).
    pub fn from_default_dir() -> Result<Self> {
        Self::new(artifact::default_dir())
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch cached) executable for `name`.
    pub fn load(&self, name: &str) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.borrow().get(name) {
            return Ok(exe.clone());
        }
        let art = self.manifest.get(name)?;
        let proto = xla::HloModuleProto::from_text_file(&art.path)
            .with_context(|| format!("loading {}", art.path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(self.client.compile(&comp)?);
        self.cache.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Execute artifact `name`; returns the flattened tuple elements.
    /// (All our graphs are lowered with `return_tuple=True`.)
    pub fn execute(&self, name: &str, inputs: &[Input]) -> Result<Vec<Literal>> {
        let art = self.manifest.get(name)?;
        anyhow::ensure!(
            inputs.len() == art.inputs.len(),
            "artifact '{name}' expects {} inputs, got {}",
            art.inputs.len(),
            inputs.len()
        );
        let exe = self.load(name)?;
        let lits = inputs.iter().map(|i| i.to_literal()).collect::<Result<Vec<_>>>()?;
        let result = exe.execute::<xla::Literal>(&lits)?;
        let tuple = result[0][0].to_literal_sync()?;
        Ok(tuple.to_tuple()?)
    }

    /// Convenience: run a `(params, *batch) -> (loss, grad)` artifact.
    pub fn grad(&self, name: &str, params: &[f32], batch: &[Input]) -> Result<(f32, Vec<f32>)> {
        let mut inputs: Vec<Input> = Vec::with_capacity(batch.len() + 1);
        let pshape = [params.len()];
        inputs.push(Input::F32(params, &pshape));
        inputs.extend(batch.iter().map(reborrow));
        let out = self.execute(name, &inputs)?;
        anyhow::ensure!(out.len() == 2, "grad artifact must return (loss, grad)");
        let loss = out[0].to_vec::<f32>()?[0];
        let grad = out[1].to_vec::<f32>()?;
        Ok((loss, grad))
    }

    /// Convenience: run a fused `(params, uniforms, *batch) -> (loss, qgrad,
    /// scales)` artifact (the Layer-1 Pallas kernel runs inside the graph).
    pub fn grad_q(
        &self,
        name: &str,
        params: &[f32],
        uniforms: &[f32],
        batch: &[Input],
    ) -> Result<(f32, Vec<f32>, Vec<f32>)> {
        let mut inputs: Vec<Input> = Vec::with_capacity(batch.len() + 2);
        let pshape = [params.len()];
        let ushape = [uniforms.len()];
        inputs.push(Input::F32(params, &pshape));
        inputs.push(Input::F32(uniforms, &ushape));
        inputs.extend(batch.iter().map(reborrow));
        let out = self.execute(name, &inputs)?;
        anyhow::ensure!(out.len() == 3, "grad_q artifact must return (loss, qgrad, scales)");
        let loss = out[0].to_vec::<f32>()?[0];
        let qgrad = out[1].to_vec::<f32>()?;
        let scales = out[2].to_vec::<f32>()?;
        Ok((loss, qgrad, scales))
    }
}

fn reborrow<'a>(i: &'a Input) -> Input<'a> {
    match i {
        Input::F32(d, s) => Input::F32(d, s),
        Input::I32(d, s) => Input::I32(d, s),
    }
}

// Integration tests that execute real artifacts live in rust/tests/
// (they require `make artifacts` to have run).
