//! AOT artifact manifest: the contract between `python/compile/aot.py` and
//! the Rust runtime. Parsed from `artifacts/manifest.json`.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::models::layout::ParamLayout;
use crate::util::json::{self, Json};

/// Element type of an artifact input/output.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    fn parse(s: &str) -> Result<Self> {
        match s {
            "float32" => Ok(DType::F32),
            "int32" => Ok(DType::I32),
            _ => anyhow::bail!("unsupported dtype '{s}'"),
        }
    }

    pub fn byte_size(self) -> usize {
        4
    }
}

/// One named input or output tensor spec.
#[derive(Debug, Clone)]
pub struct IoSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl IoSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(j: &Json, default_name: &str) -> Result<Self> {
        let name = j
            .get("name")
            .and_then(Json::as_str)
            .unwrap_or(default_name)
            .to_string();
        let shape = j
            .get("shape")
            .and_then(Json::as_arr)
            .context("io spec shape")?
            .iter()
            .map(|d| d.as_usize().unwrap_or(0))
            .collect();
        let dtype = DType::parse(j.get("dtype").and_then(Json::as_str).context("io dtype")?)?;
        Ok(Self { name, shape, dtype })
    }
}

/// Quantization parameters baked into a fused `*_grad_q` artifact.
#[derive(Debug, Clone, Copy)]
pub struct FusedQuant {
    pub s: u32,
    pub bucket: usize,
    pub buckets: usize,
    /// true ⇒ max-norm scaling.
    pub max_norm: bool,
}

/// One AOT artifact (an HLO module plus its metadata).
#[derive(Debug, Clone)]
pub struct Artifact {
    pub name: String,
    pub path: PathBuf,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
    /// Flat parameter count, if this is a model-gradient artifact.
    pub params: Option<usize>,
    /// Batch size baked into the HLO, if applicable.
    pub batch: Option<usize>,
    pub layout: Option<ParamLayout>,
    pub quant: Option<FusedQuant>,
}

impl Artifact {
    fn from_json(name: &str, j: &Json, dir: &Path) -> Result<Self> {
        let file = j.get("file").and_then(Json::as_str).context("artifact file")?;
        let inputs = j
            .get("inputs")
            .and_then(Json::as_arr)
            .context("inputs")?
            .iter()
            .enumerate()
            .map(|(i, s)| IoSpec::from_json(s, &format!("in{i}")))
            .collect::<Result<Vec<_>>>()?;
        let outputs = j
            .get("outputs")
            .and_then(Json::as_arr)
            .context("outputs")?
            .iter()
            .enumerate()
            .map(|(i, s)| IoSpec::from_json(s, &format!("out{i}")))
            .collect::<Result<Vec<_>>>()?;
        let layout = match j.get("layout") {
            Some(l) => Some(ParamLayout::from_json(l)?),
            None => None,
        };
        let quant = match (j.get("q_s"), j.get("q_bucket"), j.get("q_buckets")) {
            (Some(s), Some(b), Some(nb)) => Some(FusedQuant {
                s: s.as_usize().context("q_s")? as u32,
                bucket: b.as_usize().context("q_bucket")?,
                buckets: nb.as_usize().context("q_buckets")?,
                max_norm: j.get("q_norm").and_then(Json::as_str) == Some("max"),
            }),
            _ => None,
        };
        Ok(Self {
            name: name.to_string(),
            path: dir.join(file),
            inputs,
            outputs,
            params: j.get("params").and_then(Json::as_usize),
            batch: j.get("batch").and_then(Json::as_usize),
            layout,
            quant,
        })
    }
}

/// The parsed manifest: artifact name → metadata.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    pub artifacts: BTreeMap<String, Artifact>,
    pub dir: PathBuf,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let src = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading {}/manifest.json (run `make artifacts`)", dir.display()))?;
        let j = json::parse(&src).map_err(|e| anyhow::anyhow!("{e}"))?;
        let obj = j.as_obj().context("manifest root must be an object")?;
        let mut artifacts = BTreeMap::new();
        for (name, entry) in obj {
            artifacts.insert(name.clone(), Artifact::from_json(name, entry, &dir)?);
        }
        Ok(Self { artifacts, dir })
    }

    pub fn get(&self, name: &str) -> Result<&Artifact> {
        self.artifacts
            .get(name)
            .with_context(|| format!("artifact '{name}' not in manifest"))
    }
}

/// Default artifacts directory: `$QSGD_ARTIFACTS` or `artifacts/` relative to
/// the workspace root (assumes the binary runs from the repo).
pub fn default_dir() -> PathBuf {
    std::env::var_os("QSGD_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_manifest_entry() {
        let j = json::parse(
            r#"{
              "file": "m.hlo.txt",
              "inputs": [
                {"name": "params", "shape": [40], "dtype": "float32"},
                {"name": "y", "shape": [8], "dtype": "int32"}
              ],
              "outputs": [{"shape": [], "dtype": "float32"}, {"shape": [40], "dtype": "float32"}],
              "params": 40,
              "batch": 8,
              "layout": [{"name": "w", "shape": [40], "offset": 0, "size": 40}],
              "q_s": 15, "q_bucket": 512, "q_norm": "max", "q_buckets": 1
            }"#,
        )
        .unwrap();
        let a = Artifact::from_json("m", &j, Path::new("/tmp")).unwrap();
        assert_eq!(a.inputs.len(), 2);
        assert_eq!(a.inputs[1].dtype, DType::I32);
        assert_eq!(a.inputs[1].elements(), 8);
        assert_eq!(a.outputs[1].shape, vec![40]);
        assert_eq!(a.params, Some(40));
        assert_eq!(a.layout.as_ref().unwrap().total_params(), 40);
        let q = a.quant.unwrap();
        assert_eq!((q.s, q.bucket, q.buckets, q.max_norm), (15, 512, 1, true));
        assert_eq!(a.path, PathBuf::from("/tmp/m.hlo.txt"));
    }

    #[test]
    fn missing_fields_rejected() {
        let j = json::parse(r#"{"inputs": []}"#).unwrap();
        assert!(Artifact::from_json("m", &j, Path::new("/tmp")).is_err());
    }

    #[test]
    fn real_manifest_loads_if_built() {
        // Integration-level check, skipped gracefully when artifacts are absent.
        let dir = default_dir();
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            for name in ["logreg_grad", "mlp_grad", "tfm_grad", "quantize"] {
                assert!(m.get(name).is_ok(), "{name} missing from manifest");
            }
            let mlp = m.get("mlp_grad").unwrap();
            assert!(mlp.layout.is_some());
            assert_eq!(mlp.inputs[0].elements(), mlp.params.unwrap());
        }
    }
}
