//! API-compatible stand-in for the PJRT runtime (default build, no `xla`
//! feature). Constructors fail with a descriptive error; every caller in
//! the tree goes through `Runtime::new`/`from_default_dir` and handles the
//! `Err`, and the integration tests skip when `manifest.json` is absent, so
//! the stub's execute paths are never reached.

use anyhow::{bail, Result};

use super::artifact::{self, Manifest};

/// A host-side input tensor (mirrors `pjrt::Input`).
pub enum Input<'a> {
    F32(&'a [f32], &'a [usize]),
    I32(&'a [i32], &'a [usize]),
}

/// Opaque output tensor (mirrors `xla::Literal`'s used surface).
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        bail!("PJRT runtime unavailable: built without the `xla` feature")
    }
}

/// Stub runtime: construction always fails (there is no PJRT client to
/// build), with an error that tells the user how to get the real one.
pub struct Runtime {
    manifest: Manifest,
}

impl Runtime {
    pub fn new(dir: impl AsRef<std::path::Path>) -> Result<Self> {
        let _ = dir.as_ref();
        bail!(
            "PJRT runtime unavailable: this binary was built without the `xla` \
             feature (the offline toolchain does not vendor the xla crate). \
             Rebuild with `--features xla` in an environment that provides it."
        )
    }

    pub fn from_default_dir() -> Result<Self> {
        Self::new(artifact::default_dir())
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        "unavailable".into()
    }

    pub fn execute(&self, _name: &str, _inputs: &[Input]) -> Result<Vec<Literal>> {
        bail!("PJRT runtime unavailable (stub build)")
    }

    pub fn grad(&self, _name: &str, _params: &[f32], _batch: &[Input]) -> Result<(f32, Vec<f32>)> {
        bail!("PJRT runtime unavailable (stub build)")
    }

    pub fn grad_q(
        &self,
        _name: &str,
        _params: &[f32],
        _uniforms: &[f32],
        _batch: &[Input],
    ) -> Result<(f32, Vec<f32>, Vec<f32>)> {
        bail!("PJRT runtime unavailable (stub build)")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_fails_with_guidance() {
        let err = Runtime::from_default_dir().err().expect("stub must fail");
        assert!(err.to_string().contains("xla"), "{err}");
    }
}
