//! PJRT runtime: loads AOT HLO-text artifacts and executes them from the
//! coordinator's hot path. Python never runs here — the artifacts were
//! lowered once by `make artifacts`.
//!
//! Two builds:
//!
//! * `--features xla` ([`pjrt`]) — the real PJRT CPU client via the `xla`
//!   (xla_extension) crate. Only available where that crate is vendored;
//!   the offline CI toolchain does not ship it.
//! * default ([`stub`]) — an API-compatible stub whose constructors return
//!   a descriptive error. All artifact-dependent tests check for
//!   `manifest.json` first and skip gracefully, so `cargo test` stays green
//!   without the Layer-2 toolchain.

pub mod artifact;

pub use artifact::{Artifact, DType, FusedQuant, IoSpec, Manifest};

#[cfg(feature = "xla")]
mod pjrt;
#[cfg(feature = "xla")]
pub use pjrt::{Input, Literal, Runtime};

#[cfg(not(feature = "xla"))]
mod stub;
#[cfg(not(feature = "xla"))]
pub use stub::{Input, Literal, Runtime};
