//! Sockets, rendezvous, and the full-mesh peer fabric.
//!
//! [`Endpoint`] abstracts TCP (`host:port`) and Unix-domain (`path`)
//! addresses behind one enum; [`Conn`]/[`Listener`] wrap the corresponding
//! std socket types with uniform timeout control. [`Mesh::connect`] brings K
//! processes to a fully connected peer fabric in three bounded steps:
//!
//! 1. every rank binds its own peer listener (TCP: ephemeral port on the
//!    base host; UDS: `<path>.r<rank>`) **before** rendezvous, so later
//!    dials land in the accept backlog rather than racing the listener;
//! 2. rank 0 serves an address table at the base endpoint: ranks 1..K
//!    register `(rank, listen address)` and block until the full table
//!    arrives — which doubles as the startup barrier;
//! 3. for every pair `i < j`, rank `j` dials rank `i` and announces itself
//!    with a hello frame; rank `i` accepts `K−1−i` inbound connections.
//!
//! Every blocking operation here is bounded: connects retry with capped
//! exponential backoff against a deadline, accepts poll a nonblocking
//! listener against the same deadline, and established connections carry
//! read/write timeouts. A wedged peer therefore surfaces as a clean `Err`
//! within the configured budget — the CI lane's `timeout` wrapper is a
//! backstop, never the mechanism.

use std::io::{self, Read, Write};
use std::net::{TcpListener, TcpStream, ToSocketAddrs};
#[cfg(unix)]
use std::os::unix::net::{UnixListener, UnixStream};
#[cfg(unix)]
use std::path::{Path, PathBuf};
use std::sync::mpsc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, ensure, Context, Result};

use super::fault::{FaultAction, FaultInjector};
use super::frame::{self, FrameReader};

/// Write one data frame through the optional fault injector: delivered,
/// damaged, dropped (not written at all), or delayed per the seeded
/// schedule. Control-plane writes bypass this and call
/// [`frame::write_frame`] directly.
fn inject_write(inj: Option<&FaultInjector>, w: &mut Conn, payload: &[u8]) -> Result<()> {
    let Some(inj) = inj else {
        return frame::write_frame(w, payload);
    };
    if let Some(d) = inj.delay() {
        std::thread::sleep(d);
    }
    match inj.next_action() {
        FaultAction::Deliver => frame::write_frame(w, payload),
        FaultAction::Drop => Ok(()),
        FaultAction::Corrupt => {
            let mut bad = payload.to_vec();
            FaultInjector::damage(&mut bad);
            frame::write_frame(w, &bad)
        }
    }
}

/// One instruction for a per-peer writer thread. The fault decision (and
/// any injected delay) is drawn on the *calling* thread at enqueue time, so
/// the injector's deterministic schedule is byte-identical between the
/// serial and pipelined paths; the writer thread only applies it. `buf` is
/// `None` for a drawn Drop — nothing hits the wire but the delay (if any)
/// still elapses on the writer, matching the serial path's timing shape.
enum PipeMsg {
    Write { delay: Option<Duration>, buf: Option<Vec<u8>> },
    /// Barrier: reply with the sticky first write error (or `None`) once
    /// every previously queued frame has been written.
    Flush(mpsc::Sender<Option<String>>),
}

/// A dedicated writer thread for one peer connection: owns a `try_clone` of
/// the peer's write half and drains queued frames in FIFO order, so the
/// calling thread can enqueue a hop's outbound frame and move straight on
/// to decoding/re-encoding the next hop while the bytes ship.
struct PipeWriter {
    tx: mpsc::Sender<PipeMsg>,
}

fn spawn_pipe_writer(mut conn: Conn, peer: usize) -> PipeWriter {
    let (tx, rx) = mpsc::channel::<PipeMsg>();
    std::thread::spawn(move || {
        // First write error is sticky: later frames are skipped (the
        // connection is gone anyway) and every flush reports it.
        let mut err: Option<String> = None;
        for msg in rx {
            match msg {
                PipeMsg::Write { delay, buf } => {
                    if let Some(d) = delay {
                        std::thread::sleep(d);
                    }
                    let Some(buf) = buf else { continue };
                    if err.is_none() {
                        let _sp = crate::obs_span!("net.pipe.write");
                        if let Err(e) = frame::write_frame(&mut conn, &buf) {
                            err = Some(format!("sending pipelined frame to rank {peer}: {e:#}"));
                        }
                    }
                }
                PipeMsg::Flush(ack) => {
                    let _ = ack.send(err.clone());
                }
            }
        }
        // Channel disconnected (mesh dropped or peer marked dead): exit.
    });
    PipeWriter { tx }
}

/// A dialable / bindable address for one side of the transport.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Endpoint {
    /// TCP `host:port` (port 0 binds an ephemeral port).
    Tcp(String),
    /// Unix-domain socket path.
    #[cfg(unix)]
    Uds(PathBuf),
}

impl Endpoint {
    /// Human-readable form, also the wire form used in the address table.
    pub fn describe(&self) -> String {
        match self {
            Endpoint::Tcp(a) => format!("tcp:{a}"),
            #[cfg(unix)]
            Endpoint::Uds(p) => format!("uds:{}", p.display()),
        }
    }

    /// Parse the wire form emitted by [`describe`](Self::describe).
    pub fn from_wire(s: &str) -> Result<Endpoint> {
        if let Some(addr) = s.strip_prefix("tcp:") {
            return Ok(Endpoint::Tcp(addr.to_string()));
        }
        if let Some(path) = s.strip_prefix("uds:") {
            #[cfg(unix)]
            return Ok(Endpoint::Uds(PathBuf::from(path)));
            #[cfg(not(unix))]
            bail!("unix-domain endpoint '{path}' is not supported on this platform");
        }
        bail!("unrecognized endpoint '{s}' (expected tcp:<host:port> or uds:<path>)")
    }

    /// The listener endpoint rank `rank` binds for inbound mesh dials,
    /// derived from the rendezvous base: TCP reuses the base host with an
    /// ephemeral port (the actual port travels through the address table);
    /// UDS appends a `.r<rank>` suffix.
    pub fn listener_for_rank(&self, rank: usize) -> Result<Endpoint> {
        match self {
            Endpoint::Tcp(addr) => {
                let host = addr
                    .rsplit_once(':')
                    .map(|(h, _)| h)
                    .ok_or_else(|| anyhow!("tcp address '{addr}' must be host:port"))?;
                let _ = rank;
                Ok(Endpoint::Tcp(format!("{host}:0")))
            }
            #[cfg(unix)]
            Endpoint::Uds(p) => {
                let mut os = p.as_os_str().to_os_string();
                os.push(format!(".r{rank}"));
                Ok(Endpoint::Uds(PathBuf::from(os)))
            }
        }
    }
}

/// One established stream connection, TCP or UDS.
pub enum Conn {
    Tcp(TcpStream),
    #[cfg(unix)]
    Uds(UnixStream),
}

impl Conn {
    /// Apply the same timeout to reads and writes (`None` clears both).
    pub fn set_timeouts(&self, t: Option<Duration>) -> Result<()> {
        match self {
            Conn::Tcp(s) => {
                s.set_read_timeout(t).context("setting tcp read timeout")?;
                s.set_write_timeout(t).context("setting tcp write timeout")?;
            }
            #[cfg(unix)]
            Conn::Uds(s) => {
                s.set_read_timeout(t).context("setting uds read timeout")?;
                s.set_write_timeout(t).context("setting uds write timeout")?;
            }
        }
        Ok(())
    }

    fn set_nonblocking(&self, nb: bool) -> io::Result<()> {
        match self {
            Conn::Tcp(s) => s.set_nonblocking(nb),
            #[cfg(unix)]
            Conn::Uds(s) => s.set_nonblocking(nb),
        }
    }

    /// Independent handle over the same socket, so one thread can write
    /// while another reads (the deadlock-free exchange schedule relies on
    /// this split).
    pub fn try_clone(&self) -> Result<Conn> {
        Ok(match self {
            Conn::Tcp(s) => Conn::Tcp(s.try_clone().context("cloning tcp stream")?),
            #[cfg(unix)]
            Conn::Uds(s) => Conn::Uds(s.try_clone().context("cloning uds stream")?),
        })
    }

    fn tune(&self) {
        // Latency matters more than throughput for small quantized frames;
        // Nagle would add a delayed-ack round trip per ring hop.
        if let Conn::Tcp(s) = self {
            let _ = s.set_nodelay(true);
        }
    }
}

impl Read for Conn {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        match self {
            Conn::Tcp(s) => s.read(buf),
            #[cfg(unix)]
            Conn::Uds(s) => s.read(buf),
        }
    }
}

impl Write for Conn {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        match self {
            Conn::Tcp(s) => s.write(buf),
            #[cfg(unix)]
            Conn::Uds(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> io::Result<()> {
        match self {
            Conn::Tcp(s) => s.flush(),
            #[cfg(unix)]
            Conn::Uds(s) => s.flush(),
        }
    }
}

/// A bound listening socket, TCP or UDS.
pub enum Listener {
    Tcp(TcpListener),
    #[cfg(unix)]
    Uds(UnixListener),
}

impl Listener {
    pub fn bind(ep: &Endpoint) -> Result<Listener> {
        match ep {
            Endpoint::Tcp(addr) => Ok(Listener::Tcp(
                TcpListener::bind(addr.as_str())
                    .with_context(|| format!("binding tcp listener at {addr}"))?,
            )),
            #[cfg(unix)]
            Endpoint::Uds(p) => {
                // A stale socket file from a previous crashed run would make
                // bind fail with AddrInUse even though nothing listens.
                if p.exists() {
                    let _ = std::fs::remove_file(p);
                }
                Ok(Listener::Uds(
                    UnixListener::bind(p)
                        .with_context(|| format!("binding unix listener at {}", p.display()))?,
                ))
            }
        }
    }

    /// The actual bound endpoint (resolves TCP port 0 to the real port).
    pub fn local_endpoint(&self) -> Result<Endpoint> {
        match self {
            Listener::Tcp(l) => {
                Ok(Endpoint::Tcp(l.local_addr().context("tcp local addr")?.to_string()))
            }
            #[cfg(unix)]
            Listener::Uds(l) => {
                let addr = l.local_addr().context("uds local addr")?;
                let p =
                    addr.as_pathname().ok_or_else(|| anyhow!("unnamed unix listener"))?;
                Ok(Endpoint::Uds(p.to_path_buf()))
            }
        }
    }

    fn set_nonblocking(&self, nb: bool) -> io::Result<()> {
        match self {
            Listener::Tcp(l) => l.set_nonblocking(nb),
            #[cfg(unix)]
            Listener::Uds(l) => l.set_nonblocking(nb),
        }
    }

    fn accept_raw(&self) -> io::Result<Conn> {
        match self {
            Listener::Tcp(l) => l.accept().map(|(s, _)| Conn::Tcp(s)),
            #[cfg(unix)]
            Listener::Uds(l) => l.accept().map(|(s, _)| Conn::Uds(s)),
        }
    }

    /// Accept one connection, polling a nonblocking listener so the wait is
    /// bounded by `deadline` instead of blocking forever.
    pub fn accept_deadline(&self, deadline: Instant) -> Result<Conn> {
        self.set_nonblocking(true).context("marking listener nonblocking")?;
        let conn = loop {
            match self.accept_raw() {
                Ok(c) => break c,
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                    if Instant::now() >= deadline {
                        bail!("accept timed out waiting for a peer to connect");
                    }
                    std::thread::sleep(Duration::from_millis(2));
                }
                Err(e) => {
                    return Err(anyhow::Error::new(e).context("accepting peer connection"))
                }
            }
        };
        self.set_nonblocking(false).context("restoring blocking listener")?;
        conn.set_nonblocking(false).context("marking accepted stream blocking")?;
        Ok(conn)
    }
}

fn try_connect(ep: &Endpoint, deadline: Instant) -> io::Result<Conn> {
    // Deadline first: once the budget has elapsed there is no 10ms floor to
    // hide behind — the attempt must fail fast so the caller's total bound
    // holds.
    let remaining = deadline.saturating_duration_since(Instant::now());
    if remaining.is_zero() {
        return Err(io::Error::new(io::ErrorKind::TimedOut, "connect deadline elapsed"));
    }
    match ep {
        Endpoint::Tcp(addr) => {
            let sa = addr.to_socket_addrs()?.next().ok_or_else(|| {
                io::Error::new(
                    io::ErrorKind::InvalidInput,
                    format!("no socket address resolves for '{addr}'"),
                )
            })?;
            // Per-attempt budget: short enough to retry, clamped to the time
            // actually remaining so the last attempt ends at the deadline.
            let budget = remaining.min(Duration::from_millis(500));
            Ok(Conn::Tcp(TcpStream::connect_timeout(&sa, budget)?))
        }
        #[cfg(unix)]
        Endpoint::Uds(p) => Ok(Conn::Uds(UnixStream::connect(p)?)),
    }
}

/// Dial with bounded retry: capped exponential backoff (2ms doubling to
/// 100ms) until `total` elapses. Tolerates the target rank binding its
/// listener slightly later than us — the normal case at startup.
///
/// The deadline is re-checked before every attempt (not just after a
/// failure) and each attempt's budget is clamped to the remaining time, so
/// the total dial time is bounded by `total` plus at most one short
/// attempt — even against a black-holed endpoint that never answers.
pub fn connect_retry(ep: &Endpoint, total: Duration) -> Result<Conn> {
    let deadline = Instant::now() + total;
    let mut backoff = Duration::from_millis(2);
    let mut last: Option<io::Error> = None;
    while Instant::now() < deadline {
        match try_connect(ep, deadline) {
            Ok(c) => {
                c.tune();
                return Ok(c);
            }
            Err(e) => last = Some(e),
        }
        if Instant::now() + backoff >= deadline {
            break;
        }
        std::thread::sleep(backoff);
        backoff = (backoff * 2).min(Duration::from_millis(100));
    }
    bail!(
        "connect to {} timed out after {:.1}s (last error: {})",
        ep.describe(),
        total.as_secs_f64(),
        last.map(|e| e.to_string()).unwrap_or_else(|| "none".into())
    )
}

// ---------------------------------------------------------------------------
// Rendezvous wire helpers (tiny hand-rolled frames; no serde in the build)
// ---------------------------------------------------------------------------

fn encode_hello(rank: usize, ep: &Endpoint) -> Vec<u8> {
    let addr = ep.describe();
    let mut out = Vec::with_capacity(4 + addr.len());
    out.extend_from_slice(&(rank as u32).to_le_bytes());
    out.extend_from_slice(addr.as_bytes());
    out
}

fn decode_hello(b: &[u8]) -> Result<(usize, Endpoint)> {
    ensure!(b.len() >= 4, "hello frame too short ({} bytes)", b.len());
    let rank = u32::from_le_bytes([b[0], b[1], b[2], b[3]]) as usize;
    let addr = std::str::from_utf8(&b[4..]).context("hello address is not utf-8")?;
    Ok((rank, Endpoint::from_wire(addr)?))
}

fn encode_table(eps: &[Endpoint]) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend_from_slice(&(eps.len() as u32).to_le_bytes());
    for ep in eps {
        let s = ep.describe();
        out.extend_from_slice(&(s.len() as u32).to_le_bytes());
        out.extend_from_slice(s.as_bytes());
    }
    out
}

fn decode_table(b: &[u8]) -> Result<Vec<Endpoint>> {
    ensure!(b.len() >= 4, "address table frame too short");
    let world = u32::from_le_bytes([b[0], b[1], b[2], b[3]]) as usize;
    ensure!(world <= 1 << 16, "address table claims {world} ranks");
    let mut eps = Vec::with_capacity(world);
    let mut at = 4usize;
    for _ in 0..world {
        ensure!(b.len() >= at + 4, "truncated address table");
        let len = u32::from_le_bytes([b[at], b[at + 1], b[at + 2], b[at + 3]]) as usize;
        at += 4;
        ensure!(b.len() >= at + len, "truncated address table entry");
        let s = std::str::from_utf8(&b[at..at + len]).context("table entry is not utf-8")?;
        eps.push(Endpoint::from_wire(s)?);
        at += len;
    }
    ensure!(at == b.len(), "trailing bytes after address table");
    Ok(eps)
}

// ---------------------------------------------------------------------------
// Mesh
// ---------------------------------------------------------------------------

/// Connection setup parameters for [`Mesh::connect`].
#[derive(Debug, Clone)]
pub struct MeshConfig {
    pub rank: usize,
    pub world: usize,
    /// Read/write timeout on every established connection: the bound on any
    /// single blocking exchange operation.
    pub io_timeout: Duration,
    /// Total budget for rendezvous + mesh dialing (covers the slowest rank's
    /// process startup, so it is usually much larger than `io_timeout`).
    pub connect_timeout: Duration,
}

struct Peer {
    /// Read half (the original stream).
    reader: Conn,
    /// Write half (`try_clone` of the same socket).
    writer: Conn,
    rbuf: FrameReader,
}

impl Peer {
    fn new(conn: Conn) -> Result<Peer> {
        conn.tune();
        let writer = conn.try_clone()?;
        Ok(Peer { reader: conn, writer, rbuf: FrameReader::new() })
    }
}

/// Fully connected peer fabric for one rank: a framed, timeout-bounded
/// stream to every other rank, plus the concurrent send/receive schedules
/// the collectives need (all-to-all exchange and ring hops).
///
/// Deadlock freedom: every schedule pushes writes onto a scoped helper
/// thread while the calling thread drains reads, and both sides walk peers
/// in ascending rank order. No rank ever blocks a read behind its own
/// unsent writes, so the global wait graph stays acyclic; socket timeouts
/// bound the damage if a peer dies anyway.
pub struct Mesh {
    pub rank: usize,
    pub world: usize,
    peers: Vec<Option<Peer>>,
    /// Seeded fault schedule applied to outbound data frames (tests and
    /// `--scenario` runs); `None` in production paths.
    injector: Option<FaultInjector>,
    /// Per-peer writer threads for the pipelined exchange paths; empty
    /// until [`enable_pipelining`](Self::enable_pipelining).
    pipes: Vec<Option<PipeWriter>>,
}

impl Mesh {
    /// Establish the full mesh (see module docs for the three-step dance).
    pub fn connect(base: &Endpoint, cfg: &MeshConfig) -> Result<Mesh> {
        ensure!(cfg.world >= 1, "world size must be at least 1");
        ensure!(
            cfg.rank < cfg.world,
            "rank {} out of range for world size {}",
            cfg.rank,
            cfg.world
        );
        if cfg.world == 1 {
            return Ok(Mesh {
                rank: 0,
                world: 1,
                peers: vec![None],
                injector: None,
                pipes: Vec::new(),
            });
        }

        let listener = Listener::bind(&base.listener_for_rank(cfg.rank)?)?;
        let my_ep = listener.local_endpoint()?;
        let deadline = Instant::now() + cfg.connect_timeout;

        // Step 2: rendezvous through rank 0's address table.
        let table: Vec<Endpoint> = if cfg.rank == 0 {
            let store = Listener::bind(base).context("rank 0: binding rendezvous endpoint")?;
            let mut eps: Vec<Option<Endpoint>> = vec![None; cfg.world];
            eps[0] = Some(my_ep.clone());
            let mut regs: Vec<Conn> = Vec::with_capacity(cfg.world - 1);
            let mut fr = FrameReader::new();
            while regs.len() < cfg.world - 1 {
                let mut c = store
                    .accept_deadline(deadline)
                    .context("rendezvous: waiting for workers to register")?;
                c.set_timeouts(Some(cfg.io_timeout))?;
                let hello = fr
                    .read_frame(&mut c)?
                    .ok_or_else(|| anyhow!("rendezvous: peer closed before registering"))?;
                let (r, ep) = decode_hello(hello)?;
                ensure!(
                    r > 0 && r < cfg.world,
                    "rendezvous: rank {r} out of range for world size {}",
                    cfg.world
                );
                ensure!(eps[r].is_none(), "rendezvous: duplicate registration for rank {r}");
                eps[r] = Some(ep);
                regs.push(c);
            }
            let eps: Vec<Endpoint> = eps.into_iter().map(|e| e.expect("all filled")).collect();
            let tbl = encode_table(&eps);
            for c in regs.iter_mut() {
                frame::write_frame(c, &tbl).context("rendezvous: broadcasting address table")?;
            }
            eps
        } else {
            let mut c = connect_retry(base, cfg.connect_timeout)
                .context("rendezvous: connecting to rank 0")?;
            // The table only arrives once every rank has registered, so this
            // read is bounded by the whole setup budget, not one io_timeout.
            c.set_timeouts(Some(cfg.connect_timeout))?;
            frame::write_frame(&mut c, &encode_hello(cfg.rank, &my_ep))
                .context("rendezvous: registering with rank 0")?;
            let mut fr = FrameReader::new();
            let tbl = fr.read_frame(&mut c)?.ok_or_else(|| {
                anyhow!("rendezvous: rank 0 closed before broadcasting the address table")
            })?;
            let t = decode_table(tbl)?;
            ensure!(
                t.len() == cfg.world,
                "rendezvous: table has {} entries, expected {}",
                t.len(),
                cfg.world
            );
            t
        };

        // Step 3: full mesh. For each pair i < j, j dials i with a hello.
        let mut peers: Vec<Option<Peer>> = (0..cfg.world).map(|_| None).collect();
        for (peer, ep) in table.iter().enumerate().take(cfg.rank) {
            let mut c = connect_retry(ep, cfg.connect_timeout)
                .with_context(|| format!("dialing mesh peer {peer}"))?;
            c.set_timeouts(Some(cfg.io_timeout))?;
            frame::write_frame(&mut c, &(cfg.rank as u32).to_le_bytes())
                .with_context(|| format!("announcing rank to peer {peer}"))?;
            peers[peer] = Some(Peer::new(c)?);
        }
        let mut fr = FrameReader::new();
        for _ in cfg.rank + 1..cfg.world {
            let mut c =
                listener.accept_deadline(deadline).context("accepting mesh peers")?;
            c.set_timeouts(Some(cfg.io_timeout))?;
            let hello = fr
                .read_frame(&mut c)?
                .ok_or_else(|| anyhow!("mesh peer closed before its hello frame"))?;
            ensure!(hello.len() == 4, "bad mesh hello frame ({} bytes)", hello.len());
            let r = u32::from_le_bytes([hello[0], hello[1], hello[2], hello[3]]) as usize;
            ensure!(
                r > cfg.rank && r < cfg.world,
                "mesh hello from unexpected rank {r}"
            );
            ensure!(peers[r].is_none(), "duplicate mesh connection from rank {r}");
            peers[r] = Some(Peer::new(c)?);
        }

        Ok(Mesh { rank: cfg.rank, world: cfg.world, peers, injector: None, pipes: Vec::new() })
    }

    /// Spawn one dedicated writer thread per live peer (idempotent). The
    /// pipelined send paths ([`send_enqueue`](Self::send_enqueue),
    /// [`send_recv_pipelined`](Self::send_recv_pipelined)) then queue
    /// outbound data frames to these threads instead of blocking the
    /// caller, which is what lets a ring hop's bytes ship while the caller
    /// decodes and re-encodes the next hop.
    ///
    /// Discipline: a queued frame and any *other* write to the same peer
    /// (control round, raw resend, scoped-thread exchange) would interleave
    /// at byte level on the socket, so callers must
    /// [`flush_sends`](Self::flush_sends) before mixing paths — the
    /// exchange layer flushes at the end of every pipelined collective and
    /// falls back to the serial path whenever recovery traffic is possible.
    pub fn enable_pipelining(&mut self) -> Result<()> {
        if !self.pipes.is_empty() {
            return Ok(());
        }
        let mut pipes: Vec<Option<PipeWriter>> = (0..self.world).map(|_| None).collect();
        for (r, slot) in self.peers.iter().enumerate() {
            if let Some(p) = slot {
                pipes[r] = Some(spawn_pipe_writer(p.writer.try_clone()?, r));
            }
        }
        self.pipes = pipes;
        Ok(())
    }

    /// Whether [`enable_pipelining`](Self::enable_pipelining) has run.
    pub fn pipelined(&self) -> bool {
        !self.pipes.is_empty()
    }

    /// Queue one data frame to `to`'s writer thread and return immediately.
    /// The fault decision is drawn here, on the calling thread, in exactly
    /// the order the serial path would draw it.
    pub fn send_enqueue(&mut self, to: usize, payload: &[u8]) -> Result<()> {
        let (delay, action) = match self.injector.as_ref() {
            Some(inj) => (inj.delay(), inj.next_action()),
            None => (None, FaultAction::Deliver),
        };
        let buf = match action {
            FaultAction::Deliver => Some(payload.to_vec()),
            FaultAction::Drop => None,
            FaultAction::Corrupt => {
                let mut bad = payload.to_vec();
                FaultInjector::damage(&mut bad);
                Some(bad)
            }
        };
        let pipe = self
            .pipes
            .get(to)
            .and_then(|p| p.as_ref())
            .ok_or_else(|| anyhow!("no pipelined writer for rank {to}"))?;
        pipe.tx
            .send(PipeMsg::Write { delay, buf })
            .map_err(|_| anyhow!("pipelined writer for rank {to} exited"))
    }

    /// Barrier: wait until every queued frame on every writer thread has
    /// hit its socket, surfacing the first write error. Must run before any
    /// non-pipelined write to a peer (see
    /// [`enable_pipelining`](Self::enable_pipelining)).
    pub fn flush_sends(&mut self) -> Result<()> {
        let _sp = crate::obs_span!("net.flush");
        let mut first: Option<anyhow::Error> = None;
        for (r, slot) in self.pipes.iter().enumerate() {
            let Some(pipe) = slot else { continue };
            let (ack_tx, ack_rx) = mpsc::channel();
            if pipe.tx.send(PipeMsg::Flush(ack_tx)).is_err() {
                first.get_or_insert(anyhow!("pipelined writer for rank {r} exited"));
                continue;
            }
            match ack_rx.recv() {
                Ok(None) => {}
                Ok(Some(e)) => {
                    first.get_or_insert(anyhow!(e));
                }
                Err(_) => {
                    first.get_or_insert(anyhow!("pipelined writer for rank {r} exited"));
                }
            }
        }
        match first {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }

    /// Pipelined ring hop: queue `payload` to `to`'s writer thread, then
    /// block only on the read from `from`. Falls back to the serial
    /// [`send_recv`](Self::send_recv) when pipelining is not enabled.
    /// Deadlock-free for the same reason the serial hop is: writes never
    /// wait on reads (they queue), so the global wait graph stays acyclic.
    pub fn send_recv_pipelined(
        &mut self,
        to: usize,
        from: usize,
        payload: &[u8],
    ) -> Result<&[u8]> {
        if self.pipes.is_empty() {
            return self.send_recv(to, from, payload);
        }
        ensure!(to != self.rank && from != self.rank, "send_recv cannot target self");
        self.send_enqueue(to, payload)?;
        self.recv_from(from)
    }

    /// Install a seeded fault injector on this rank's outbound data frames.
    pub fn set_fault_injector(&mut self, inj: FaultInjector) {
        self.injector = Some(inj);
    }

    /// The installed injector, if any (for reading its counters).
    pub fn injector(&self) -> Option<&FaultInjector> {
        self.injector.as_ref()
    }

    /// Ranks (excluding self) we still hold a live connection to.
    pub fn live_peers(&self) -> Vec<usize> {
        self.peers
            .iter()
            .enumerate()
            .filter_map(|(r, p)| p.as_ref().map(|_| r))
            .collect()
    }

    /// Whether `rank` is this rank or a peer we still hold a connection to.
    pub fn is_live(&self, rank: usize) -> bool {
        rank == self.rank || matches!(self.peers.get(rank), Some(Some(_)))
    }

    /// Drop the connection to `rank`: it is skipped by every later
    /// exchange. Called when a peer is declared dead by io-timeout.
    pub fn mark_dead(&mut self, rank: usize) {
        if rank != self.rank {
            if let Some(slot) = self.peers.get_mut(rank) {
                *slot = None;
            }
            // Dropping the sender disconnects the channel; the writer
            // thread drains and exits on its own.
            if let Some(slot) = self.pipes.get_mut(rank) {
                *slot = None;
            }
        }
    }

    fn peer_mut(&mut self, rank: usize) -> Result<&mut Peer> {
        self.peers
            .get_mut(rank)
            .and_then(|p| p.as_mut())
            .ok_or_else(|| anyhow!("no mesh connection to rank {rank}"))
    }

    /// Send one data frame to `peer` (blocking, bounded by the write
    /// timeout). Passes through the fault injector when one is installed.
    pub fn send_to(&mut self, peer: usize, payload: &[u8]) -> Result<()> {
        let inj = self.injector.as_ref();
        let p = self
            .peers
            .get_mut(peer)
            .and_then(|p| p.as_mut())
            .ok_or_else(|| anyhow!("no mesh connection to rank {peer}"))?;
        inject_write(inj, &mut p.writer, payload)
            .with_context(|| format!("sending frame to rank {peer}"))
    }

    /// Send one control/recovery frame to `peer`, bypassing the fault
    /// injector (the recovery path is modeled as reliable).
    pub fn send_to_raw(&mut self, peer: usize, payload: &[u8]) -> Result<()> {
        let p = self.peer_mut(peer)?;
        frame::write_frame(&mut p.writer, payload)
            .with_context(|| format!("sending frame to rank {peer}"))
    }

    /// Receive one frame from `peer` (blocking, bounded by the read
    /// timeout). The returned slice is valid until the next receive from
    /// the same peer.
    pub fn recv_from(&mut self, peer: usize) -> Result<&[u8]> {
        let rank = self.rank;
        let p = self.peer_mut(peer)?;
        match p.rbuf.read_frame(&mut p.reader) {
            Ok(Some(_)) => Ok(p.rbuf.last()),
            Ok(None) => bail!("rank {peer} closed its stream to rank {rank}"),
            Err(e) => Err(e.context(format!("receiving frame from rank {peer}"))),
        }
    }

    /// The last frame received from `peer` (empty before any exchange).
    pub fn frame(&self, peer: usize) -> &[u8] {
        self.peers[peer].as_ref().map(|p| p.rbuf.last()).unwrap_or(&[])
    }

    /// All-to-all step: send `payload` to every peer while receiving one
    /// frame from every peer. Writes run on a scoped thread in ascending
    /// rank order; reads drain on the calling thread in the same order.
    /// Afterwards each peer's frame is available via [`frame`](Self::frame).
    pub fn exchange_all(&mut self, payload: &[u8]) -> Result<()> {
        self.exchange_all_with(payload, |_, _| Ok(()))
    }

    /// [`exchange_all`](Self::exchange_all) with decode-on-arrival: as each
    /// peer's frame lands (ascending rank order on the calling thread),
    /// `on_frame(rank, bytes)` consumes it before the next read blocks —
    /// the kernel buffers later arrivals in the meantime, so codec work
    /// overlaps the remaining wire I/O without perturbing the deterministic
    /// consumption order. An `on_frame` error aborts the step after the
    /// sender thread is joined.
    pub fn exchange_all_with(
        &mut self,
        payload: &[u8],
        mut on_frame: impl FnMut(usize, &[u8]) -> Result<()>,
    ) -> Result<()> {
        if self.world == 1 {
            return Ok(());
        }
        let inj = self.injector.as_ref();
        let mut writers: Vec<(usize, &mut Conn)> = Vec::new();
        let mut readers: Vec<(usize, &mut Conn, &mut FrameReader)> = Vec::new();
        for (r, slot) in self.peers.iter_mut().enumerate() {
            if let Some(p) = slot {
                writers.push((r, &mut p.writer));
                readers.push((r, &mut p.reader, &mut p.rbuf));
            }
        }
        std::thread::scope(|s| -> Result<()> {
            let sender = s.spawn(move || -> Result<()> {
                for (r, w) in writers.iter_mut() {
                    inject_write(inj, &mut **w, payload)
                        .with_context(|| format!("sending to rank {r}"))?;
                }
                Ok(())
            });
            let mut recv_err: Option<anyhow::Error> = None;
            for (r, conn, rbuf) in readers.iter_mut() {
                match rbuf.read_frame(&mut **conn) {
                    Ok(Some(f)) => {
                        if let Err(e) = on_frame(*r, f) {
                            recv_err = Some(e.context(format!("consuming frame from rank {r}")));
                            break;
                        }
                    }
                    Ok(None) => {
                        recv_err = Some(anyhow!("rank {r} closed mid-exchange"));
                        break;
                    }
                    Err(e) => {
                        recv_err = Some(e.context(format!("receiving from rank {r}")));
                        break;
                    }
                }
            }
            // Join the sender even on receive failure: its writes are
            // bounded by the socket write timeout, so this cannot hang.
            let sent = sender.join().map_err(|_| anyhow!("mesh sender thread panicked"))?;
            if let Some(e) = recv_err {
                return Err(e);
            }
            sent
        })
    }

    /// Fault-tolerant all-to-all: like [`exchange_all`](Self::exchange_all)
    /// but a peer whose send or receive fails (closed stream, io-timeout)
    /// is marked dead and skipped instead of aborting the step. Returns
    /// the ranks that failed this round, in ascending order; their frames
    /// are not valid.
    pub fn exchange_all_tolerant(&mut self, payload: &[u8]) -> Result<Vec<usize>> {
        if self.world == 1 {
            return Ok(Vec::new());
        }
        let inj = self.injector.as_ref();
        let mut writers: Vec<(usize, &mut Conn)> = Vec::new();
        let mut readers: Vec<(usize, &mut Conn, &mut FrameReader)> = Vec::new();
        for (r, slot) in self.peers.iter_mut().enumerate() {
            if let Some(p) = slot {
                writers.push((r, &mut p.writer));
                readers.push((r, &mut p.reader, &mut p.rbuf));
            }
        }
        let mut failed: Vec<usize> = Vec::new();
        std::thread::scope(|s| -> Result<()> {
            let sender = s.spawn(move || -> Vec<usize> {
                let mut bad = Vec::new();
                for (r, w) in writers.iter_mut() {
                    if inject_write(inj, &mut **w, payload).is_err() {
                        bad.push(*r);
                    }
                }
                bad
            });
            for (r, conn, rbuf) in readers.iter_mut() {
                match rbuf.read_frame(&mut **conn) {
                    Ok(Some(_)) => {}
                    Ok(None) | Err(_) => failed.push(*r),
                }
            }
            let wbad =
                sender.join().map_err(|_| anyhow!("mesh sender thread panicked"))?;
            failed.extend(wbad);
            Ok(())
        })?;
        failed.sort_unstable();
        failed.dedup();
        for &r in &failed {
            self.mark_dead(r);
        }
        Ok(failed)
    }

    /// Recovery control round: send the one-byte code `ctrl[r]` to every
    /// live peer `r` while reading one control byte from each (injector
    /// bypassed — the recovery path is modeled as reliable). A peer that
    /// fails the round is marked dead and reported as `None`, as is the
    /// slot for self.
    ///
    /// Note the received control bytes land in each peer's frame buffer:
    /// decode (or copy out) data frames *before* running a control round.
    pub fn exchange_ctrl(&mut self, ctrl: &[u8]) -> Result<Vec<Option<u8>>> {
        ensure!(
            ctrl.len() == self.world,
            "ctrl has {} slots for world size {}",
            ctrl.len(),
            self.world
        );
        let mut out: Vec<Option<u8>> = vec![None; self.world];
        if self.world == 1 {
            return Ok(out);
        }
        let mut writers: Vec<(usize, u8, &mut Conn)> = Vec::new();
        let mut readers: Vec<(usize, &mut Conn, &mut FrameReader)> = Vec::new();
        for (r, slot) in self.peers.iter_mut().enumerate() {
            if let Some(p) = slot {
                writers.push((r, ctrl[r], &mut p.writer));
                readers.push((r, &mut p.reader, &mut p.rbuf));
            }
        }
        let mut failed: Vec<usize> = Vec::new();
        std::thread::scope(|s| -> Result<()> {
            let sender = s.spawn(move || -> Vec<usize> {
                let mut bad = Vec::new();
                for (r, c, w) in writers.iter_mut() {
                    if frame::write_frame(&mut **w, &[*c]).is_err() {
                        bad.push(*r);
                    }
                }
                bad
            });
            for (r, conn, rbuf) in readers.iter_mut() {
                match rbuf.read_frame(&mut **conn) {
                    Ok(Some(f)) if f.len() == 1 => out[*r] = Some(f[0]),
                    _ => failed.push(*r),
                }
            }
            let wbad =
                sender.join().map_err(|_| anyhow!("mesh ctrl sender thread panicked"))?;
            failed.extend(wbad);
            Ok(())
        })?;
        failed.sort_unstable();
        failed.dedup();
        for &r in &failed {
            self.mark_dead(r);
            out[r] = None;
        }
        Ok(out)
    }

    /// Recovery data round: re-send `payload` to every rank in `serve`
    /// while reading one replacement frame from every rank in `expect`
    /// (injector bypassed). The replacement frames are then available via
    /// [`frame`](Self::frame). Failed or already-dead expected ranks are
    /// marked dead and returned.
    pub fn resend_round(
        &mut self,
        serve: &[usize],
        expect: &[usize],
        payload: &[u8],
    ) -> Result<Vec<usize>> {
        let mut failed: Vec<usize> = expect
            .iter()
            .copied()
            .filter(|&r| !matches!(self.peers.get(r), Some(Some(_))))
            .collect();
        let mut writers: Vec<(usize, &mut Conn)> = Vec::new();
        let mut readers: Vec<(usize, &mut Conn, &mut FrameReader)> = Vec::new();
        for (r, slot) in self.peers.iter_mut().enumerate() {
            if let Some(p) = slot {
                if serve.contains(&r) {
                    writers.push((r, &mut p.writer));
                }
                if expect.contains(&r) {
                    readers.push((r, &mut p.reader, &mut p.rbuf));
                }
            }
        }
        std::thread::scope(|s| -> Result<()> {
            let sender = s.spawn(move || -> Vec<usize> {
                let mut bad = Vec::new();
                for (r, w) in writers.iter_mut() {
                    if frame::write_frame(&mut **w, payload).is_err() {
                        bad.push(*r);
                    }
                }
                bad
            });
            for (r, conn, rbuf) in readers.iter_mut() {
                match rbuf.read_frame(&mut **conn) {
                    Ok(Some(_)) => {}
                    _ => failed.push(*r),
                }
            }
            let wbad =
                sender.join().map_err(|_| anyhow!("mesh resend thread panicked"))?;
            failed.extend(wbad);
            Ok(())
        })?;
        failed.sort_unstable();
        failed.dedup();
        for &r in &failed {
            self.mark_dead(r);
        }
        Ok(failed)
    }

    /// Ring hop: send `payload` to rank `to` while receiving one frame from
    /// rank `from` (concurrently, write on a scoped thread). Returns the
    /// received frame, valid until the next receive from `from`.
    pub fn send_recv(&mut self, to: usize, from: usize, payload: &[u8]) -> Result<&[u8]> {
        self.send_recv_inner(to, from, payload, false)
    }

    /// [`send_recv`](Self::send_recv) bypassing the fault injector — the
    /// recovery control plane (per-hop verdicts) and resends are modeled as
    /// reliable, which is what makes one resend always enough.
    pub fn send_recv_raw(&mut self, to: usize, from: usize, payload: &[u8]) -> Result<&[u8]> {
        self.send_recv_inner(to, from, payload, true)
    }

    fn send_recv_inner(
        &mut self,
        to: usize,
        from: usize,
        payload: &[u8],
        raw: bool,
    ) -> Result<&[u8]> {
        ensure!(to != self.rank && from != self.rank, "send_recv cannot target self");
        let inj = if raw { None } else { self.injector.as_ref() };
        if to == from {
            // Two-rank ring: both halves of the same peer connection.
            let p = self
                .peers
                .get_mut(to)
                .and_then(|p| p.as_mut())
                .ok_or_else(|| anyhow!("no mesh connection to rank {to}"))?;
            let Peer { reader, writer, rbuf } = p;
            std::thread::scope(|s| -> Result<()> {
                let sender = s.spawn(move || inject_write(inj, writer, payload));
                let got = rbuf.read_frame(reader);
                let sent =
                    sender.join().map_err(|_| anyhow!("ring sender thread panicked"))?;
                sent.with_context(|| format!("sending ring frame to rank {to}"))?;
                match got {
                    Ok(Some(_)) => Ok(()),
                    Ok(None) => bail!("rank {from} closed mid ring hop"),
                    Err(e) => Err(e.context(format!("receiving ring frame from rank {from}"))),
                }
            })?;
        } else {
            let (a, b) = (to.min(from), to.max(from));
            let (lo, hi) = self.peers.split_at_mut(b);
            let pa = lo[a].as_mut().ok_or_else(|| anyhow!("no mesh connection to rank {a}"))?;
            let pb =
                hi[0].as_mut().ok_or_else(|| anyhow!("no mesh connection to rank {b}"))?;
            let (wpeer, rpeer) = if to == a { (pa, pb) } else { (pb, pa) };
            let writer = &mut wpeer.writer;
            let Peer { reader, rbuf, .. } = rpeer;
            std::thread::scope(|s| -> Result<()> {
                let sender = s.spawn(move || inject_write(inj, writer, payload));
                let got = rbuf.read_frame(reader);
                let sent =
                    sender.join().map_err(|_| anyhow!("ring sender thread panicked"))?;
                sent.with_context(|| format!("sending ring frame to rank {to}"))?;
                match got {
                    Ok(Some(_)) => Ok(()),
                    Ok(None) => bail!("rank {from} closed mid ring hop"),
                    Err(e) => Err(e.context(format!("receiving ring frame from rank {from}"))),
                }
            })?;
        }
        Ok(self.peers[from].as_ref().expect("checked above").rbuf.last())
    }
}

/// Remove the socket files a UDS rendezvous leaves behind (base + per-rank
/// listeners). Best-effort; call after a run when the sockets live outside
/// a tempdir.
#[cfg(unix)]
pub fn cleanup_uds(base: &Path, world: usize) {
    let _ = std::fs::remove_file(base);
    for r in 0..world {
        let mut os = base.as_os_str().to_os_string();
        os.push(format!(".r{r}"));
        let _ = std::fs::remove_file(PathBuf::from(os));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn endpoint_wire_roundtrip() {
        let t = Endpoint::Tcp("127.0.0.1:29500".into());
        assert_eq!(Endpoint::from_wire(&t.describe()).unwrap(), t);
        #[cfg(unix)]
        {
            let u = Endpoint::Uds(PathBuf::from("/tmp/qsgd.sock"));
            assert_eq!(Endpoint::from_wire(&u.describe()).unwrap(), u);
        }
        assert!(Endpoint::from_wire("carrier-pigeon:coop").is_err());
    }

    #[test]
    fn listener_for_rank_shapes() {
        let t = Endpoint::Tcp("127.0.0.1:29500".into());
        assert_eq!(t.listener_for_rank(3).unwrap(), Endpoint::Tcp("127.0.0.1:0".into()));
        let v6 = Endpoint::Tcp("[::1]:29500".into());
        assert_eq!(v6.listener_for_rank(0).unwrap(), Endpoint::Tcp("[::1]:0".into()));
        #[cfg(unix)]
        {
            let u = Endpoint::Uds(PathBuf::from("/tmp/qsgd.sock"));
            assert_eq!(
                u.listener_for_rank(2).unwrap(),
                Endpoint::Uds(PathBuf::from("/tmp/qsgd.sock.r2"))
            );
        }
    }

    #[test]
    fn hello_and_table_roundtrip() {
        let ep = Endpoint::Tcp("10.0.0.7:1234".into());
        let (r, got) = decode_hello(&encode_hello(5, &ep)).unwrap();
        assert_eq!((r, got), (5, ep.clone()));
        let table = vec![ep.clone(), Endpoint::Tcp("127.0.0.1:80".into())];
        assert_eq!(decode_table(&encode_table(&table)).unwrap(), table);
        assert!(decode_table(&[1, 0]).is_err());
        assert!(decode_hello(&[0, 0]).is_err());
    }

    #[test]
    fn connect_retry_respects_total_budget_against_black_hole() {
        // TEST-NET-1 (RFC 5737) is reserved: SYNs to it are typically
        // black-holed, so each attempt runs to its timeout instead of
        // failing fast. The deadline is checked before every attempt and
        // the final attempt's budget is clamped to the remaining time, so
        // the dial must return within `total` plus one short attempt of
        // scheduling slack.
        let ep = Endpoint::Tcp("192.0.2.1:9".into());
        let total = Duration::from_millis(250);
        let t0 = Instant::now();
        let err = connect_retry(&ep, total).unwrap_err();
        assert!(
            t0.elapsed() <= total + Duration::from_millis(600),
            "dial overran its budget: {:?}",
            t0.elapsed()
        );
        assert!(err.to_string().contains("192.0.2.1:9"), "{err}");
    }

    #[test]
    fn connect_retry_reports_timeout_cleanly() {
        // A port from the dynamic range with nothing listening; the retry
        // loop must give up within the budget and name the endpoint.
        let ep = Endpoint::Tcp("127.0.0.1:1".into());
        let t0 = Instant::now();
        let err = connect_retry(&ep, Duration::from_millis(120)).unwrap_err();
        assert!(t0.elapsed() < Duration::from_secs(10));
        assert!(err.to_string().contains("tcp:127.0.0.1:1"), "{err}");
    }
}
