//! One rank's synchronous training loop over the socket transport.
//!
//! The multi-process mirror of [`crate::coordinator::sync::SyncTrainer`]:
//! the same Algorithm 1 step — local gradient, encode, collective exchange,
//! decode, identical SGD update — but this process *is* one worker, and the
//! exchange moves real bytes over the [`Mesh`] instead of charging simnet
//! time. Seeding matches the in-process trainer exactly (init from
//! `stream(seed, 0x1417)`, encode sessions from `seed ^ 0xF00D`, gradients
//! deterministic in `(worker, step)` via the [`GradSource`] contract), so a
//! K-rank socket run takes the same parameter trajectory, bit for bit, as a
//! K-worker simnet run of the same config — the cross-process determinism
//! golden in `tests/transport_e2e.rs` pins this.
//!
//! Two clocks fill the returned [`RunResult`]: the usual modeled α–β
//! [`Breakdown`] (same [`CostModel`] + [`collectives::model_exchange_time`]
//! charges as the simnet path, so runs stay comparable across transports)
//! and the **measured** per-phase [`WallClock`] — real seconds this rank
//! spent encoding, blocked on sockets, and decoding.
//!
//! One deliberate difference from the in-process trainer: the all-to-all
//! arm runs the plain [`CompressorSpec`] codec, not a `QuantPlan`-aware
//! assembly — plan-aware multi-process exchange is future work, and the
//! quick-start configs here quantize everything anyway.

use anyhow::Result;

use crate::collectives;
use crate::config::CollectiveSpec;
use crate::coordinator::sources::GradSource;
use crate::coordinator::sync::RunResult;
use crate::coordinator::CompressorSpec;
use crate::metrics::{Breakdown, Curve, FaultStats, WallClock, WireStats};
use crate::models::CostModel;
use crate::optim::Sgd;
use crate::simnet::{SimNet, VTime};
use crate::util::rng::{self, Xoshiro256};

use super::exchange::{RecoveryOptions, SocketExchange};
use super::net::Mesh;

/// Configuration of one rank's distributed run. The *same values on every
/// rank* (seed included) are a correctness requirement, not a convenience —
/// replicas derive identical init and identical decoded means from them.
pub struct DistTrainConfig {
    pub steps: usize,
    pub compressor: CompressorSpec,
    pub collective: CollectiveSpec,
    pub lr: f32,
    pub momentum: f32,
    pub seed: u64,
    pub init_scale: f32,
    pub log_every: usize,
    /// Evaluate held-out metric every N steps on rank 0 (0 = never).
    pub eval_every: usize,
    /// Simnet used only for the *modeled* transfer charge, so socket runs
    /// report the same α–β breakdown a simnet run of this shape would.
    pub net: SimNet,
    pub cost: CostModel,
    /// Trainer-side fault recovery: re-request corrupt frames from live
    /// peers, skip io-timeout-dead workers with a renormalized mean.
    pub recovery: RecoveryOptions,
    /// Churn injection: exit (with an error) at the *top* of this step,
    /// before sending anything — so every survivor times this rank out in
    /// the same round and their contributor sets agree.
    pub die_at_step: Option<usize>,
    /// Pipelined exchange paths (`--overlap on`): decode-on-arrival
    /// all-to-all, writer-thread ring hops. Bit-identical results; arms
    /// without a pipelined path (and recovery-enabled runs) fall back to
    /// serial transparently.
    pub pipeline: bool,
}

impl DistTrainConfig {
    pub fn quick(world: usize, steps: usize, compressor: CompressorSpec, lr: f32) -> Self {
        Self {
            steps,
            compressor,
            collective: CollectiveSpec::AllToAll,
            lr,
            momentum: 0.0,
            seed: 0,
            init_scale: 0.1,
            log_every: 10,
            eval_every: 0,
            net: SimNet::preset(world, crate::simnet::Preset::K80Pcie),
            cost: CostModel::k80(),
            recovery: RecoveryOptions::default(),
            die_at_step: None,
            pipeline: false,
        }
    }
}

/// Run this rank's share of a K-rank synchronous training job over an
/// already-connected [`Mesh`]. Blocks until `cfg.steps` steps complete (or
/// a peer failure surfaces as an error — socket timeouts bound every hop).
pub fn train_rank(
    cfg: &DistTrainConfig,
    mesh: Mesh,
    source: &mut dyn GradSource,
) -> Result<RunResult> {
    let n = source.dim();
    let rank = mesh.rank;
    let codec = cfg.compressor.codec();
    let mut exchange =
        SocketExchange::new(&cfg.collective, codec.clone(), mesh, cfg.seed ^ 0xF00D)?
            .with_recovery(cfg.recovery)?
            .with_pipelining(cfg.pipeline)?;

    // Identical init on every rank: same seed ⇒ same stream ⇒ same bits.
    let mut init_rng = Xoshiro256::stream(cfg.seed, 0x1417);
    let mut params: Vec<f32> = rng::normal_vec(&mut init_rng, n)
        .into_iter()
        .map(|x| x * cfg.init_scale)
        .collect();
    let mut opt = Sgd::new(crate::optim::LrSchedule::Const(cfg.lr), cfg.momentum, 0.0, n);

    let mut loss_curve = Curve::default();
    let mut eval_curve = Curve::default();
    let mut breakdown = Breakdown::default();
    let mut wire = WireStats::default();
    let mut wall = WallClock::default();
    let mut mean_grad: Vec<f32> = Vec::new();
    let mut hops = 0usize;
    let mut recompressions = 0u64;
    let mut recompress_err_sq = 0.0f64;
    let mut faults = FaultStats::default();

    // One modeled transfer charge per step, the same formula the simnet
    // benches use, sized by the codec's expected message size.
    let modeled_transfer =
        collectives::model_exchange_time(&cfg.collective, &cfg.net, codec.encoded_size_hint(n));

    for step in 0..cfg.steps {
        crate::obs::set_step(step as u64);
        let _step_span = crate::obs_span!("step");
        if cfg.die_at_step == Some(step) {
            anyhow::bail!("rank {rank}: dying at step {step} (--die-at-step churn injection)");
        }
        // 1. this rank's local gradient (the source is deterministic in
        //    (worker, step), so rank-local compute is exact data parallelism)
        let (loss, grad) = {
            let _sp = crate::obs_span!("grad.compute");
            source.loss_and_grad(rank, step as u64, &params)?
        };
        breakdown.compute += VTime(cfg.cost.step_compute_s(source.flops_fwd_per_step(), 1));

        // 2.–4. encode → socket exchange → decode; every rank gets the same
        //        mean bits back.
        let stats = exchange.exchange(&grad, &mut mean_grad)?;
        wire.add(&stats.wire);
        wall.add(&stats.wall);
        faults.add(&stats.faults);
        hops += stats.hops;
        recompressions += stats.recompressions;
        recompress_err_sq += stats.recompress_err_sq;
        breakdown.encode += VTime(cfg.cost.encode_s(stats.encode_coords));
        breakdown.transfer += modeled_transfer;
        breakdown.decode += VTime(cfg.cost.decode_s(stats.decode_coords, 1));

        // 5. identical update from the identical mean
        {
            let _sp = crate::obs_span!("sgd.apply");
            opt.apply(&mut params, &mean_grad);
        }
        breakdown.steps += 1;

        anyhow::ensure!(
            params.iter().all(|p| p.is_finite()),
            "rank {rank} parameters went non-finite at step {step} \
             (learning rate above 1/L?)"
        );
        if step % cfg.log_every.max(1) == 0 || step + 1 == cfg.steps {
            loss_curve.push(step, loss as f64);
        }
        if rank == 0 && cfg.eval_every > 0 && (step % cfg.eval_every == 0 || step + 1 == cfg.steps)
        {
            if let Some(m) = source.eval(&params) {
                eval_curve.push(step, m);
            }
        }
    }

    Ok(RunResult {
        loss: loss_curve,
        eval: eval_curve,
        breakdown,
        wire,
        params,
        label: cfg.compressor.label(),
        collective: cfg.collective.label(),
        hops,
        recompressions,
        recompress_err_sq,
        wall,
        faults,
    })
}
