//! Seeded fault injection for the socket transport.
//!
//! [`FaultInjector`] is the fallible wrapper around a mesh connection's
//! write half: every *data* frame a rank sends passes through it, and the
//! injector decides — as a pure function of `(seed, frame index)` — whether
//! the frame is delivered intact, delivered corrupted, dropped entirely, or
//! delayed. Deciding on the sender side keeps the schedule independent of
//! wall-clock timing, so a given seed produces the same fault pattern on
//! every run (the determinism goldens rely on this).
//!
//! Control-plane frames (the recovery protocol's OK/RESEND bytes and the
//! resent payloads themselves) bypass the injector: the model is "the data
//! path is lossy, the recovery path is reliable", which keeps the bounded
//! re-request guarantee honest — one resend always repairs one corruption.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use crate::util::rng::splitmix64;

/// Fate of one outbound data frame.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultAction {
    /// Write the frame unchanged.
    Deliver,
    /// Write the frame with its body damaged (length-valid, undecodable).
    Corrupt,
    /// Do not write the frame at all (the receiver sees an io-timeout).
    Drop,
}

/// Seeded per-frame fault schedule (see module docs).
#[derive(Debug)]
pub struct FaultInjector {
    corrupt_prob: f64,
    drop_prob: f64,
    delay: Option<Duration>,
    /// Injection stops after this many faults so recovery tests stay
    /// bounded; `u64::MAX` means unlimited.
    max_faults: u64,
    seed: u64,
    ops: AtomicU64,
    faults: AtomicU64,
    corrupted: AtomicU64,
    dropped: AtomicU64,
}

impl FaultInjector {
    pub fn new(seed: u64) -> Self {
        FaultInjector {
            corrupt_prob: 0.0,
            drop_prob: 0.0,
            delay: None,
            max_faults: u64::MAX,
            seed,
            ops: AtomicU64::new(0),
            faults: AtomicU64::new(0),
            corrupted: AtomicU64::new(0),
            dropped: AtomicU64::new(0),
        }
    }

    /// Corrupt each data frame with probability `prob`.
    pub fn with_corruption(mut self, prob: f64) -> Self {
        assert!((0.0..=1.0).contains(&prob));
        self.corrupt_prob = prob;
        self
    }

    /// Drop each data frame with probability `prob`.
    pub fn with_drops(mut self, prob: f64) -> Self {
        assert!((0.0..=1.0).contains(&prob));
        self.drop_prob = prob;
        self
    }

    /// Sleep this long before every injected write (slow-sender straggler).
    pub fn with_delay(mut self, d: Duration) -> Self {
        self.delay = Some(d);
        self
    }

    /// Stop injecting after `n` faults (delivery continues unfaulted).
    pub fn with_max_faults(mut self, n: u64) -> Self {
        self.max_faults = n;
        self
    }

    fn unit(&self, op: u64, salt: u64) -> f64 {
        let mut s = self.seed ^ op.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ salt;
        let h = splitmix64(&mut s);
        (h >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Decide the fate of the next outbound data frame (advances the
    /// schedule by one draw even when no fault fires).
    pub fn next_action(&self) -> FaultAction {
        let op = self.ops.fetch_add(1, Ordering::Relaxed);
        if self.faults.load(Ordering::Relaxed) >= self.max_faults {
            return FaultAction::Deliver;
        }
        if self.drop_prob > 0.0 && self.unit(op, 0x0D) < self.drop_prob {
            self.faults.fetch_add(1, Ordering::Relaxed);
            self.dropped.fetch_add(1, Ordering::Relaxed);
            return FaultAction::Drop;
        }
        if self.corrupt_prob > 0.0 && self.unit(op, 0xC0) < self.corrupt_prob {
            self.faults.fetch_add(1, Ordering::Relaxed);
            self.corrupted.fetch_add(1, Ordering::Relaxed);
            return FaultAction::Corrupt;
        }
        FaultAction::Deliver
    }

    /// Per-write delay, if configured.
    pub fn delay(&self) -> Option<Duration> {
        self.delay
    }

    /// Frames corrupted so far.
    pub fn corrupted(&self) -> u64 {
        self.corrupted.load(Ordering::Relaxed)
    }

    /// Frames dropped so far.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Damage `payload` in place the way the injector's `Corrupt` action
    /// does on the wire: the frame stays length-valid but its body (first
    /// and last bytes) is flipped, so every length- or header-checked
    /// decoder rejects it.
    pub fn damage(payload: &mut [u8]) {
        if let Some(b) = payload.first_mut() {
            *b ^= 0xA5;
        }
        if let Some(b) = payload.last_mut() {
            *b ^= 0x5A;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedule_is_seed_deterministic() {
        let a = FaultInjector::new(42).with_corruption(0.3).with_drops(0.1);
        let b = FaultInjector::new(42).with_corruption(0.3).with_drops(0.1);
        let sa: Vec<FaultAction> = (0..256).map(|_| a.next_action()).collect();
        let sb: Vec<FaultAction> = (0..256).map(|_| b.next_action()).collect();
        assert_eq!(sa, sb);
        assert!(a.corrupted() > 0 && a.dropped() > 0, "probs should fire over 256 draws");
        let c = FaultInjector::new(43).with_corruption(0.3).with_drops(0.1);
        let sc: Vec<FaultAction> = (0..256).map(|_| c.next_action()).collect();
        assert_ne!(sa, sc, "different seed, different schedule");
    }

    #[test]
    fn max_faults_bounds_injection() {
        let inj = FaultInjector::new(7).with_corruption(1.0).with_max_faults(2);
        let n: usize =
            (0..64).filter(|_| inj.next_action() == FaultAction::Corrupt).count();
        assert_eq!(n, 2);
        assert_eq!(inj.corrupted(), 2);
    }

    #[test]
    fn damage_changes_bytes_but_not_length() {
        let mut p = vec![1u8, 2, 3, 4];
        FaultInjector::damage(&mut p);
        assert_eq!(p.len(), 4);
        assert_ne!(p, vec![1u8, 2, 3, 4]);
    }
}
