//! `SocketExchange`: the collective algorithms over real OS processes.
//!
//! One instance lives in each of K processes and runs *this rank's share* of
//! the same algorithms the simnet coordinators run in-process — all-to-all
//! broadcast, recompressing/raw ring allreduce, hierarchical two-level
//! reduce — moving the same encoded wire bytes over the [`Mesh`] instead of
//! charging virtual time.
//!
//! **Bit-parity is the contract** (pinned by `tests/transport_e2e.rs`): with
//! the same seeds and gradients, the decoded mean out of a K-process socket
//! run is bit-identical to the in-process simnet golden, arm by arm:
//!
//! * encode sessions are seeded exactly as the in-process algorithms seed
//!   them — `Xoshiro256::stream(seed, rank)` per worker, the leader-ring
//!   family forked at `seed ^ 0x9E3779B97F4A7C15`;
//! * the ring reuses [`collectives::ring_segments`] (same bucket-aligned
//!   layout) and the same `encode_lane` helper, so hop inputs, session RNG
//!   consumption, and recompression bytes match hop for hop;
//! * every float accumulation happens in the same order: ring lanes in lane
//!   order, hierarchical fan-in in worker order, the all-to-all merge
//!   through the same grouped [`collectives::par_decode_mean`].
//!
//! Decoding runs straight off each peer's receive buffer (the borrowed
//! `FrameView` path inside `decode_add`) — frames are not copied out of the
//! transport except where an algorithm must *hold* them across hops
//! (allgather forwarding, member fan-out frames).
//!
//! Wall-clock per-phase seconds are measured around every encode, socket
//! operation, and decode, and surface in [`DistStats`] next to the wire
//! accounting, which here covers **this rank's outbound traffic** (the
//! in-process `Exchange` sums all K workers).

use std::sync::Arc;
use std::time::Instant;

use anyhow::{ensure, Result};

use crate::collectives::{self, algo};
use crate::config::CollectiveSpec;
use crate::metrics::{FaultStats, Occupancy, WallClock, WireStats};
use crate::obs::flight;
use crate::obs::trace::Site;
use crate::quant::{Codec, EncodeSession};
use crate::util::rng::Xoshiro256;

use super::net::Mesh;

// Flight-recorder breadcrumb sites (args documented per site).
/// `a` = gradient coords, `b` = rank.
static CRUMB_EXCHANGE: Site = Site::new("exchange");
/// `a` = corrupt/re-requested frame count, `b` = peer rank.
static CRUMB_RECOVERY: Site = Site::new("recovery");
/// `a` = workers declared dead this step.
static CRUMB_DEAD: Site = Site::new("dead_worker");

/// Telemetry from one (or many accumulated) socket exchanges.
#[derive(Debug, Clone, Default)]
pub struct DistStats {
    /// Measured wall-clock seconds per phase, this rank.
    pub wall: WallClock,
    /// This rank's outbound traffic.
    pub wire: WireStats,
    /// Synchronous hops this rank participated in.
    pub hops: usize,
    pub recompressions: u64,
    pub recompress_err_sq: f64,
    pub encode_coords: usize,
    pub decode_coords: usize,
    /// Fault/recovery events this rank observed (all-zero without a
    /// [`RecoveryOptions`]-enabled exchange).
    pub faults: FaultStats,
    /// Where this rank's exchange wall time went: blocked on sockets, in
    /// codec work, or idle. On the serial paths io + codec ≈ total (idle
    /// ≈ 0 by construction); the pipelined paths shrink the io bucket —
    /// the overlap the exchange actually achieved.
    pub occupancy: Occupancy,
}

impl DistStats {
    pub fn add(&mut self, other: &DistStats) {
        self.wall.add(&other.wall);
        self.wire.add(&other.wire);
        self.hops += other.hops;
        self.recompressions += other.recompressions;
        self.recompress_err_sq += other.recompress_err_sq;
        self.encode_coords += other.encode_coords;
        self.decode_coords += other.decode_coords;
        self.faults.add(&other.faults);
        self.occupancy.add(&other.occupancy);
    }

    /// Export everything into the unified metrics registry under the
    /// `exchange.*` / `wall.*` / `wire.*` / `faults.*` / `occupancy.*`
    /// namespaces. Rows merge associatively across ranks and steps.
    pub fn export(&self, m: &mut crate::obs::MetricSet) {
        self.wall.export(m);
        self.wire.export(m);
        self.faults.export(m);
        self.occupancy.export(m);
        m.counter("exchange.hops", self.hops as u64);
        m.counter("exchange.recompressions", self.recompressions);
        m.counter("exchange.encode_coords", self.encode_coords as u64);
        m.counter("exchange.decode_coords", self.decode_coords as u64);
    }
}

/// Trainer-side fault recovery for the socket collectives.
///
/// When enabled, every received data frame is decode-validated; a frame
/// that fails validation is re-requested from the (live) sender over a
/// one-byte control round, and the resend bypasses the fault injector —
/// one resend is always enough, which is what bounds recovery. A peer
/// that stops responding (io-timeout, closed stream) is declared dead and
/// the mean is renormalized over the ranks that actually contributed
/// (skip-and-renormalize), matching the in-process partial-participation
/// path bit for bit.
///
/// Supported by the all-to-all backend (full protocol: re-request, dead
/// peers, renormalized mean) and the recompressing ring (per-hop
/// re-request only — a dead ring member still fails the step cleanly);
/// `ring:raw` and the hierarchical backend fail clean instead. The price
/// is one extra validation decode per received frame and a one-byte
/// control round per hop.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RecoveryOptions {
    pub enabled: bool,
}

impl RecoveryOptions {
    /// Recovery switched on.
    pub fn on() -> Self {
        Self { enabled: true }
    }
}

/// This rank's state for the distributed ring allreduce (also the leader
/// ring inside the hierarchical backend). Mirrors one worker's slice of
/// [`collectives::RingAllreduce`].
struct DistRing {
    session: Box<dyn EncodeSession>,
    /// Mesh ranks of the ring members, in ring order.
    members: Vec<usize>,
    /// Index of this rank within `members`.
    pos: usize,
    recompress: bool,
    error_feedback: bool,
    segs: Vec<(usize, usize)>,
    cur_n: Option<usize>,
    inflight: Vec<u8>,
    next_buf: Vec<u8>,
    /// Completed segment frames, by lane.
    finals: Vec<Vec<u8>>,
    acc: Vec<f32>,
    staging: Vec<f32>,
    dec: Vec<f32>,
    /// Error-feedback residual (gradient-sized, persists across steps).
    residual: Vec<f32>,
    /// `recompress = false`: own per-segment encodings and the circulating
    /// per-origin frame sets.
    pre: Vec<Vec<u8>>,
    sets: Vec<Vec<Vec<u8>>>,
    packed: Vec<u8>,
}

impl DistRing {
    fn new(
        codec: &dyn Codec,
        members: Vec<usize>,
        pos: usize,
        seed: u64,
        recompress: bool,
        error_feedback: bool,
    ) -> Self {
        assert!(pos < members.len());
        // Same per-member session streams as the in-process ring.
        let session = codec.session(Xoshiro256::stream(seed, pos as u64));
        Self {
            session,
            members,
            pos,
            recompress,
            error_feedback,
            segs: Vec::new(),
            cur_n: None,
            inflight: Vec::new(),
            next_buf: Vec::new(),
            finals: Vec::new(),
            acc: Vec::new(),
            staging: Vec::new(),
            dec: Vec::new(),
            residual: Vec::new(),
            pre: Vec::new(),
            sets: Vec::new(),
            packed: Vec::new(),
        }
    }

    fn ensure_layout(&mut self, codec: &dyn Codec, n: usize) {
        if self.cur_n == Some(n) {
            return;
        }
        let k = self.members.len();
        self.segs = collectives::ring_segments(n, k, codec.chunk_align().max(1));
        let max_len = self.segs.iter().map(|s| s.1).max().unwrap_or(0);
        if self.acc.len() < max_len {
            self.acc.resize(max_len, 0.0);
        }
        if self.error_feedback {
            self.residual.clear();
            self.residual.resize(n, 0.0);
        }
        if self.finals.len() != k {
            self.finals = (0..k).map(|_| Vec::new()).collect();
        }
        if !self.recompress {
            if self.pre.len() != k {
                self.pre = (0..k).map(|_| Vec::new()).collect();
            }
            if self.sets.len() != k {
                self.sets = (0..k).map(|_| (0..k).map(|_| Vec::new()).collect()).collect();
            }
        }
        self.cur_n = Some(n);
    }

    fn neighbors(&self) -> (usize, usize) {
        let k = self.members.len();
        let next = self.members[(self.pos + 1) % k];
        let prev = self.members[(self.pos + k - 1) % k];
        (next, prev)
    }

    /// Degenerate one-member ring: mirrors the in-process `k == 1` branch
    /// (one encode/decode of the whole gradient, no traffic).
    fn run_single(
        &mut self,
        codec: &dyn Codec,
        grad: &[f32],
        alpha: f32,
        mean: &mut Vec<f32>,
        stats: &mut DistStats,
    ) -> Result<()> {
        let n = grad.len();
        let t = Instant::now();
        let res = if self.error_feedback { Some(&mut self.residual[..]) } else { None };
        algo::encode_lane(
            codec,
            self.session.as_mut(),
            res,
            &mut self.staging,
            &mut self.dec,
            grad,
            &mut self.finals[0],
            None,
        )?;
        stats.wall.encode_s += t.elapsed().as_secs_f64();
        let t = Instant::now();
        mean.clear();
        mean.resize(n, 0.0);
        codec.decode_add(&self.finals[0], alpha, mean)?;
        stats.wall.decode_s += t.elapsed().as_secs_f64();
        stats.encode_coords += n;
        stats.decode_coords += n;
        Ok(())
    }

    /// Recompressing ring: K−1 reduce-scatter hops (decode incoming, add
    /// the local lane, re-encode) then K−1 allgather hops forwarding the
    /// completed frames verbatim. Leaves the frames in `self.finals` (lane
    /// order — the hierarchical fan-out sends them on) and decodes them
    /// into `mean`.
    ///
    /// With `recovery`, every hop is followed by a one-byte verdict round
    /// (to the frame's sender, i.e. against ring direction) and, when a
    /// frame failed decode validation, a bounded injector-bypassed resend:
    /// the repaired hop carries the exact bytes the fault destroyed, so a
    /// recovered exchange is bit-identical to a fault-free one (which is
    /// how `ring:ef` residuals survive a recovered step unchanged).
    ///
    /// With `pipeline`, each hop's outbound frame is queued to the peer's
    /// writer thread instead of written on a scoped thread, so its bytes
    /// ship while this thread decodes the incoming frame and re-encodes the
    /// next one; a flush barrier after the last hop surfaces any deferred
    /// write error. The hop inputs, session RNG draws, and accumulation
    /// order are unchanged, so the result stays bit-identical to the serial
    /// path. Mutually exclusive with `recovery` (the caller falls back to
    /// serial): verdict rounds and resends must not interleave with queued
    /// data frames on the same socket.
    #[allow(clippy::too_many_arguments)]
    fn run_recompress(
        &mut self,
        codec: &dyn Codec,
        mesh: &mut Mesh,
        grad: &[f32],
        alpha: f32,
        mean: &mut Vec<f32>,
        stats: &mut DistStats,
        recovery: bool,
        pipeline: bool,
    ) -> Result<()> {
        let n = grad.len();
        self.ensure_layout(codec, n);
        let k = self.members.len();
        if k == 1 {
            return self.run_single(codec, grad, alpha, mean, stats);
        }
        let r = self.pos;
        let ef = self.error_feedback;
        let (next, prev) = self.neighbors();
        debug_assert!(!(pipeline && recovery), "caller falls back to serial under recovery");
        let mut rec = algo::Recompress::default();

        // Hop-0 message: own segment (a first compression, not counted).
        let t = Instant::now();
        {
            let _sp = crate::obs_span!("ring.encode0");
            let (off, len) = self.segs[r];
            let res = if ef { Some(&mut self.residual[off..off + len]) } else { None };
            algo::encode_lane(
                codec,
                self.session.as_mut(),
                res,
                &mut self.staging,
                &mut self.dec,
                &grad[off..off + len],
                &mut self.inflight,
                None,
            )?;
        }
        stats.wall.encode_s += t.elapsed().as_secs_f64();

        // Reduce-scatter: at hop t this rank sends lane (r − t) mod K and
        // receives lane (r − 1 − t) mod K from its predecessor.
        for t in 0..k - 1 {
            let _sp = crate::obs_span!("ring.hop");
            let lane_out = (r + k - t) % k;
            stats.wire.record(self.inflight.len(), self.segs[lane_out].1);
            let lane = (r + 2 * k - 1 - t) % k;
            let (off, len) = self.segs[lane];
            let a = &mut self.acc[..len];
            a.fill(0.0);
            let decode_ok;
            {
                let tt = Instant::now();
                let incoming = if pipeline {
                    mesh.send_recv_pipelined(next, prev, &self.inflight)?
                } else {
                    mesh.send_recv(next, prev, &self.inflight)?
                };
                stats.wall.transfer_s += tt.elapsed().as_secs_f64();
                let td = Instant::now();
                decode_ok = if recovery {
                    codec.decode_add(incoming, 1.0, a).is_ok()
                } else {
                    codec.decode_add(incoming, 1.0, a)?;
                    true
                };
                stats.wall.decode_s += td.elapsed().as_secs_f64();
            }
            stats.hops += 1;
            if recovery {
                let tr = Instant::now();
                repair_hop(
                    mesh,
                    next,
                    prev,
                    decode_ok,
                    &self.inflight,
                    |inc| {
                        a.fill(0.0);
                        codec.decode_add(inc, 1.0, &mut a[..])
                    },
                    stats,
                )?;
                stats.wall.transfer_s += tr.elapsed().as_secs_f64();
            }

            let td = Instant::now();
            for (x, g) in a.iter_mut().zip(&grad[off..off + len]) {
                *x += *g;
            }
            stats.wall.decode_s += td.elapsed().as_secs_f64();

            let te = Instant::now();
            let res = if ef { Some(&mut self.residual[off..off + len]) } else { None };
            let out: &mut Vec<u8> =
                if t + 1 == k - 1 { &mut self.finals[lane] } else { &mut self.next_buf };
            algo::encode_lane(
                codec,
                self.session.as_mut(),
                res,
                &mut self.staging,
                &mut self.dec,
                a,
                out,
                Some(&mut rec),
            )?;
            stats.wall.encode_s += te.elapsed().as_secs_f64();
            if t + 1 < k - 1 {
                std::mem::swap(&mut self.inflight, &mut self.next_buf);
            }
        }

        // Allgather: K−1 hops forwarding completed frames verbatim. At hop
        // h this rank sends the final for lane (r + 1 − h) mod K (hop 0:
        // its own) and receives the final for lane (r − h) mod K.
        for h in 0..k - 1 {
            let _sp = crate::obs_span!("ring.allgather");
            let lane_out = (r + 1 + k - h) % k;
            let lane_in = (r + k - h) % k;
            stats.wire.record(self.finals[lane_out].len(), self.segs[lane_out].1);
            let tt = Instant::now();
            {
                let payload = &self.finals[lane_out];
                let incoming = if pipeline {
                    mesh.send_recv_pipelined(next, prev, payload)?
                } else {
                    mesh.send_recv(next, prev, payload)?
                };
                self.finals[lane_in].clear();
                self.finals[lane_in].extend_from_slice(incoming);
            }
            stats.wall.transfer_s += tt.elapsed().as_secs_f64();
            stats.hops += 1;
            if recovery {
                // Validate the forwarded frame; repair it in place so the
                // downstream hops (and the final decode) see clean bytes.
                let len = self.segs[lane_in].1;
                let a = &mut self.acc[..len];
                a.fill(0.0);
                let td = Instant::now();
                let ok = codec.decode_add(&self.finals[lane_in], 1.0, a).is_ok();
                stats.wall.decode_s += td.elapsed().as_secs_f64();
                let tr = Instant::now();
                // `lane_out` and `lane_in` are adjacent mod k, hence
                // distinct for k >= 2: split the lanes into disjoint
                // payload (resend source) and destination borrows.
                let hi = lane_out.max(lane_in);
                let (head, tail) = self.finals.split_at_mut(hi);
                let (payload, dst) = if lane_out < lane_in {
                    (&head[lane_out], &mut tail[0])
                } else {
                    (&tail[0], &mut head[lane_in])
                };
                repair_hop(
                    mesh,
                    next,
                    prev,
                    ok,
                    payload,
                    |inc| {
                        dst.clear();
                        dst.extend_from_slice(inc);
                        a.fill(0.0);
                        codec.decode_add(inc, 1.0, &mut a[..])
                    },
                    stats,
                )?;
                stats.wall.transfer_s += tr.elapsed().as_secs_f64();
            }
        }

        if pipeline {
            // Barrier: the last allgather frame may still be in a writer
            // queue; surface any deferred write error before declaring the
            // step done (and before any later non-pipelined traffic could
            // interleave with it).
            let tt = Instant::now();
            mesh.flush_sends()?;
            stats.wall.transfer_s += tt.elapsed().as_secs_f64();
        }

        // Same final decode as every in-process replica: lane order.
        let _sp = crate::obs_span!("ring.decode");
        let td = Instant::now();
        mean.clear();
        mean.resize(n, 0.0);
        for (j, f) in self.finals.iter().enumerate() {
            let (off, len) = self.segs[j];
            codec.decode_add(f, alpha, &mut mean[off..off + len])?;
        }
        stats.wall.decode_s += td.elapsed().as_secs_f64();
        stats.encode_coords += n;
        stats.decode_coords += 2 * n;
        stats.recompressions += rec.count;
        stats.recompress_err_sq += rec.err_sq;
        Ok(())
    }

    /// Raw (no-recompression) ring: pre-encode all K segments in segment
    /// order, circulate every origin's full frame set store-and-forward,
    /// reduce locally in worker order — bit-identical to the all-to-all
    /// mean, like the in-process variant.
    fn run_raw(
        &mut self,
        codec: &dyn Codec,
        mesh: &mut Mesh,
        grad: &[f32],
        alpha: f32,
        mean: &mut Vec<f32>,
        stats: &mut DistStats,
    ) -> Result<()> {
        let n = grad.len();
        self.ensure_layout(codec, n);
        let k = self.members.len();
        if k == 1 {
            return self.run_single(codec, grad, alpha, mean, stats);
        }
        let r = self.pos;
        let (next, prev) = self.neighbors();

        let t = Instant::now();
        for j in 0..k {
            let (off, len) = self.segs[j];
            self.session.encode_into(&grad[off..off + len], &mut self.pre[j]);
        }
        stats.wall.encode_s += t.elapsed().as_secs_f64();
        stats.encode_coords += n;
        for (j, m) in self.pre.iter().enumerate() {
            self.sets[r][j].clear();
            self.sets[r][j].extend_from_slice(m);
        }

        // K−1 store-and-forward hops: at hop h send origin (r − h) mod K's
        // set, receive origin (r − 1 − h) mod K's.
        for h in 0..k - 1 {
            let _sp = crate::obs_span!("ring.raw.hop");
            let origin_out = (r + k - h) % k;
            let origin_in = (r + 2 * k - 1 - h) % k;
            pack_set(&self.sets[origin_out], &mut self.packed);
            for (j, m) in self.sets[origin_out].iter().enumerate() {
                stats.wire.record(m.len(), self.segs[j].1);
            }
            let tt = Instant::now();
            let incoming = mesh.send_recv(next, prev, &self.packed)?;
            unpack_set(incoming, k, &mut self.sets[origin_in])?;
            stats.wall.transfer_s += tt.elapsed().as_secs_f64();
            stats.hops += 1;
        }

        // Local reduction in worker order, segments in segment order — the
        // all-to-all accumulation order.
        let td = Instant::now();
        mean.clear();
        mean.resize(n, 0.0);
        for row in self.sets.iter() {
            for (j, m) in row.iter().enumerate() {
                let (off, len) = self.segs[j];
                codec.decode_add(m, alpha, &mut mean[off..off + len])?;
            }
        }
        stats.wall.decode_s += td.elapsed().as_secs_f64();
        stats.decode_coords += k * n;
        Ok(())
    }
}

/// Concatenate a frame set into one transport frame: `u32` count, then per
/// frame `u32` length + bytes (all LE).
fn pack_set(frames: &[Vec<u8>], out: &mut Vec<u8>) {
    out.clear();
    out.extend_from_slice(&(frames.len() as u32).to_le_bytes());
    for f in frames {
        out.extend_from_slice(&(f.len() as u32).to_le_bytes());
        out.extend_from_slice(f);
    }
}

fn unpack_set(bytes: &[u8], expect: usize, out: &mut [Vec<u8>]) -> Result<()> {
    ensure!(
        out.len() == expect,
        "frame set destination has {} slots but {expect} frames are expected",
        out.len()
    );
    ensure!(bytes.len() >= 4, "frame set too short");
    let count = u32::from_le_bytes([bytes[0], bytes[1], bytes[2], bytes[3]]) as usize;
    ensure!(count == expect, "frame set carries {count} frames, expected {expect}");
    let mut at = 4usize;
    for slot in out.iter_mut() {
        ensure!(bytes.len() >= at + 4, "truncated frame set");
        let len =
            u32::from_le_bytes([bytes[at], bytes[at + 1], bytes[at + 2], bytes[at + 3]]) as usize;
        at += 4;
        ensure!(bytes.len() >= at + len, "truncated frame in set");
        slot.clear();
        slot.extend_from_slice(&bytes[at..at + len]);
        at += len;
    }
    ensure!(at == bytes.len(), "trailing bytes after frame set");
    Ok(())
}

/// One ring-hop recovery round. Verdicts travel *against* ring direction
/// (each rank judges the frame it received from `prev` and hears `next`'s
/// judgement of the frame it sent), injector-bypassed. A rank then serves a
/// resend of `payload` to `next` if asked, and/or receives a replacement
/// from `prev` if its own frame failed validation (`ok == false`),
/// consuming it through `redecode`. Sends and receives that must coexist
/// run concurrently, so a chain of repairing ranks cannot deadlock; a
/// replacement that still fails `redecode` is a hard error — recovery is
/// bounded at one resend per hop.
fn repair_hop(
    mesh: &mut Mesh,
    next: usize,
    prev: usize,
    ok: bool,
    payload: &[u8],
    mut redecode: impl FnMut(&[u8]) -> Result<()>,
    stats: &mut DistStats,
) -> Result<()> {
    let verdict = [u8::from(!ok)];
    let serve = {
        let reply = mesh.send_recv_raw(prev, next, &verdict)?;
        reply.first().copied() == Some(1)
    };
    if !ok {
        stats.faults.corrupt_frames += 1;
        stats.faults.rerequests += 1;
        flight::crumb(&CRUMB_RECOVERY, 1, prev as u64, 0);
        flight::dump("ring hop repair: re-requesting corrupt frame");
    }
    if serve {
        stats.faults.resends_served += 1;
    }
    match (serve, ok) {
        (true, true) => mesh.send_to_raw(next, payload)?,
        (true, false) => {
            let inc = mesh.send_recv_raw(next, prev, payload)?;
            redecode(inc)?;
        }
        (false, false) => {
            mesh.recv_from(prev)?;
            redecode(mesh.frame(prev))?;
        }
        (false, true) => {}
    }
    Ok(())
}

/// The all-to-all recovery protocol, one step:
///
/// 1. tolerant data exchange — unresponsive peers are declared dead;
/// 2. decode-validate every received frame, stashing valid ones (the
///    control round below clobbers the mesh receive buffers);
/// 3. one-byte control round — OK / RESEND per peer;
/// 4. bounded injector-bypassed resend round for the corrupt frames;
/// 5. renormalized mean over the contributors in ascending rank order,
///    through the same grouped merge as the in-process
///    partial-participation path — bit parity by construction.
///
/// Replica consistency requires every survivor to observe a death in the
/// same round, which holds when a worker dies at a step boundary (it sends
/// nothing, so all survivors time out in round 1). A worker dying *midway*
/// through a data round may be seen by only some survivors — full
/// agreement needs a membership protocol, out of scope here; the e2e churn
/// test kills workers at step boundaries.
#[allow(clippy::too_many_arguments)]
fn a2a_recover(
    codec: &dyn Codec,
    mesh: &mut Mesh,
    msg: &[u8],
    rx: &mut [Vec<u8>],
    scratch: &mut Vec<f32>,
    n: usize,
    mean: &mut Vec<f32>,
    stats: &mut DistStats,
) -> Result<()> {
    let k = mesh.world;
    let rank = mesh.rank;
    let live_at_entry = mesh.live_peers().len();

    // 1. tolerant data exchange
    let t = Instant::now();
    mesh.exchange_all_tolerant(msg)?;
    stats.wall.transfer_s += t.elapsed().as_secs_f64();
    stats.hops += 1;

    // 2. decode-validate and stash
    let live = mesh.live_peers();
    stats.wire.record_fanout(msg.len(), n, live.len());
    let mut valid = vec![false; k];
    let mut corrupt: Vec<usize> = Vec::new();
    let td = Instant::now();
    for &w in &live {
        scratch.clear();
        scratch.resize(n, 0.0);
        if codec.decode_add(mesh.frame(w), 1.0, scratch).is_ok() {
            rx[w].clear();
            rx[w].extend_from_slice(mesh.frame(w));
            valid[w] = true;
        } else {
            corrupt.push(w);
        }
    }
    stats.wall.decode_s += td.elapsed().as_secs_f64();
    stats.faults.corrupt_frames += corrupt.len() as u64;
    stats.faults.rerequests += corrupt.len() as u64;
    if !corrupt.is_empty() {
        flight::crumb(&CRUMB_RECOVERY, corrupt.len() as u64, corrupt[0] as u64, 0);
        flight::dump("a2a recovery: re-requesting corrupt frames");
    }

    // 3. control round: OK=0 / RESEND=1 per peer
    let tt = Instant::now();
    let mut ctrl = vec![0u8; k];
    for &w in &corrupt {
        ctrl[w] = 1;
    }
    let replies = mesh.exchange_ctrl(&ctrl)?;
    let serve: Vec<usize> = replies
        .iter()
        .enumerate()
        .filter(|&(_, c)| *c == Some(1))
        .map(|(w, _)| w)
        .collect();
    stats.faults.resends_served += serve.len() as u64;

    // 4. bounded resend round (injector bypassed)
    let expect: Vec<usize> = corrupt.iter().copied().filter(|&w| mesh.is_live(w)).collect();
    let failed = mesh.resend_round(&serve, &expect, msg)?;
    stats.wall.transfer_s += tt.elapsed().as_secs_f64();
    let td = Instant::now();
    for &w in &expect {
        if failed.contains(&w) {
            continue;
        }
        scratch.clear();
        scratch.resize(n, 0.0);
        ensure!(
            codec.decode_add(mesh.frame(w), 1.0, scratch).is_ok(),
            "frame from rank {w} still corrupt after its one resend — \
             recovery is bounded, giving up"
        );
        rx[w].clear();
        rx[w].extend_from_slice(mesh.frame(w));
        valid[w] = true;
    }
    stats.wall.decode_s += td.elapsed().as_secs_f64();

    // 5. renormalized mean over the agreed contributor set. A peer that
    // died in rounds 3–4 may have left a valid stashed frame; exclude it
    // so every survivor's contributor set agrees.
    let contributors: Vec<usize> =
        (0..k).filter(|&w| w == rank || (valid[w] && mesh.is_live(w))).collect();
    let died = (live_at_entry - mesh.live_peers().len()) as u64;
    stats.faults.dead_workers += died;
    if died > 0 {
        flight::crumb(&CRUMB_DEAD, died, contributors.len() as u64, 0);
        flight::dump("a2a recovery: worker(s) declared dead, renormalizing mean");
    }
    if contributors.len() < k {
        stats.faults.renormalized_steps += 1;
    }
    let t = Instant::now();
    let frames: Vec<&[u8]> = contributors
        .iter()
        .map(|&w| if w == rank { msg } else { rx[w].as_slice() })
        .collect();
    *mean = collectives::par_decode_mean(
        &frames,
        n,
        1.0 / contributors.len() as f32,
        codec.decode_threads(),
        |m, a, acc, th| codec.decode_add_threads(m, a, acc, th),
    )?;
    stats.wall.decode_s += t.elapsed().as_secs_f64();
    stats.decode_coords += contributors.len() * n;
    Ok(())
}

/// Pipelined all-to-all merge: decode each peer frame as it drains off the
/// socket instead of waiting for the receive-all barrier, overlapping codec
/// work with the remaining wire reads.
///
/// Bit-parity with [`collectives::par_decode_mean`] holds by replicating
/// its exact accumulation structure: messages in worker order are split
/// into [`collectives::DECODE_MERGE_GROUPS`] contiguous groups, each group
/// accumulates serially (ascending worker index, this rank's own message
/// interleaved at index `rank`), and the group partials merge in group
/// index order into a zeroed accumulator. Frames arrive in ascending peer
/// order, so the on-arrival decode visits exactly that sequence.
fn a2a_pipelined(
    codec: &dyn Codec,
    mesh: &mut Mesh,
    msg: &[u8],
    n: usize,
    stats: &mut DistStats,
) -> Result<Vec<f32>> {
    let _sp = crate::obs_span!("a2a.pipelined");
    let k = mesh.world;
    let rank = mesh.rank;
    let alpha = 1.0 / k as f32;
    let groups = collectives::DECODE_MERGE_GROUPS.min(k);
    let chunk = k.div_ceil(groups);
    let intra = (codec.decode_threads().max(1) / groups).max(1);
    // `chunks(chunk)` over k messages yields ceil(k/chunk) groups — which
    // can be fewer than `groups` — so size the partial set to the real
    // count and the merge sequence matches exactly.
    let mut partials: Vec<Vec<f32>> = (0..k.div_ceil(chunk)).map(|_| vec![0.0f32; n]).collect();
    let mut own_done = false;
    let mut codec_s = 0.0f64;

    let tx = Instant::now();
    mesh.exchange_all_with(msg, |w, frame| {
        // Keep the within-group order ascending: decode our own message at
        // its slot between the peer frames.
        if !own_done && rank < w {
            let td = Instant::now();
            codec.decode_add_threads(msg, alpha, &mut partials[rank / chunk], intra)?;
            codec_s += td.elapsed().as_secs_f64();
            own_done = true;
        }
        let td = Instant::now();
        codec.decode_add_threads(frame, alpha, &mut partials[w / chunk], intra)?;
        codec_s += td.elapsed().as_secs_f64();
        Ok(())
    })?;
    let wall = tx.elapsed().as_secs_f64();
    stats.hops += 1;
    // The exchange interleaved transfer and decode; split its wall time so
    // the WallClock phases still sum to the real elapsed total.
    stats.wall.transfer_s += (wall - codec_s).max(0.0);
    stats.wall.decode_s += codec_s.min(wall);

    let td = Instant::now();
    if !own_done {
        codec.decode_add_threads(msg, alpha, &mut partials[rank / chunk], intra)?;
    }
    let mut mean = vec![0.0f32; n];
    for p in &partials {
        for (a, &x) in mean.iter_mut().zip(p) {
            *a += x;
        }
    }
    stats.wall.decode_s += td.elapsed().as_secs_f64();
    stats.decode_coords += k * n;
    Ok(mean)
}

/// Per-collective state behind [`SocketExchange`].
enum Backend {
    AllToAll {
        session: Box<dyn EncodeSession>,
        msg: Vec<u8>,
        /// Recovery mode: per-peer stash of validated frames (control
        /// rounds clobber the mesh receive buffers) + validation scratch.
        rx: Vec<Vec<u8>>,
        scratch: Vec<f32>,
    },
    Ring {
        ring: DistRing,
    },
    Hier {
        session: Box<dyn EncodeSession>,
        msg: Vec<u8>,
        /// This rank's group, in listed order; `members[0]` is the leader.
        members: Vec<usize>,
        /// Number of groups (= leader-ring size).
        lcount: usize,
        /// Leader ranks only: the recompressing ring over group sums.
        ring: Option<DistRing>,
        group_sum: Vec<f32>,
        /// Member ranks: leader-ring segment layout + received final frames.
        lsegs: Vec<(usize, usize)>,
        lfinals: Vec<Vec<u8>>,
        lcur_n: Option<usize>,
    },
}

/// One rank's end of a multi-process collective exchange.
pub struct SocketExchange {
    codec: Arc<dyn Codec>,
    mesh: Mesh,
    backend: Backend,
    label: String,
    recovery: RecoveryOptions,
    /// Pipelined exchange paths requested (see
    /// [`with_pipelining`](Self::with_pipelining)).
    pipeline: bool,
}

impl SocketExchange {
    /// Build this rank's backend. `seed` must be the same value the
    /// in-process golden passes to [`collectives::build`] (the trainer uses
    /// `cfg.seed ^ 0xF00D`) for bit-parity.
    pub fn new(
        spec: &CollectiveSpec,
        codec: Arc<dyn Codec>,
        mesh: Mesh,
        seed: u64,
    ) -> Result<Self> {
        let rank = mesh.rank;
        let world = mesh.world;
        let label = spec.label();
        let backend = match spec {
            CollectiveSpec::AllToAll => Backend::AllToAll {
                session: codec.session(Xoshiro256::stream(seed, rank as u64)),
                msg: Vec::new(),
                rx: (0..world).map(|_| Vec::new()).collect(),
                scratch: Vec::new(),
            },
            CollectiveSpec::Ring { recompress, error_feedback } => Backend::Ring {
                ring: DistRing::new(
                    codec.as_ref(),
                    (0..world).collect(),
                    rank,
                    seed,
                    *recompress,
                    *error_feedback,
                ),
            },
            CollectiveSpec::Hierarchical { groups } => {
                let resolved = groups.resolve(world)?;
                let leaders: Vec<usize> = resolved.iter().map(|g| g[0]).collect();
                let gi = resolved
                    .iter()
                    .position(|g| g.contains(&rank))
                    .expect("resolve() covers every rank");
                let ring = if resolved[gi][0] == rank {
                    // Same forked stream family as the in-process leader ring.
                    Some(DistRing::new(
                        codec.as_ref(),
                        leaders,
                        gi,
                        seed ^ 0x9E3779B97F4A7C15,
                        true,
                        false,
                    ))
                } else {
                    None
                };
                Backend::Hier {
                    session: codec.session(Xoshiro256::stream(seed, rank as u64)),
                    msg: Vec::new(),
                    members: resolved[gi].clone(),
                    lcount: resolved.len(),
                    ring,
                    group_sum: Vec::new(),
                    lsegs: Vec::new(),
                    lfinals: Vec::new(),
                    lcur_n: None,
                }
            }
        };
        Ok(Self {
            codec,
            mesh,
            backend,
            label,
            recovery: RecoveryOptions::default(),
            pipeline: false,
        })
    }

    /// Enable the pipelined exchange paths: the all-to-all decodes each
    /// peer frame as it drains off the socket, and the recompressing ring
    /// queues each hop's outbound frame to a per-peer writer thread so its
    /// bytes ship while this thread decodes and re-encodes the next hop.
    /// Bit-parity with the serial paths is preserved — same sessions, same
    /// injector draws, same accumulation order.
    ///
    /// Arms with no pipelined path run serial transparently: `ring:raw`
    /// and the hierarchical backend (store-and-forward / fan-in shapes),
    /// and *any* arm while recovery is enabled — recovery's control rounds
    /// and raw resends must not interleave with queued data frames on the
    /// same socket.
    pub fn with_pipelining(mut self, on: bool) -> Result<Self> {
        if on {
            self.mesh.enable_pipelining()?;
        }
        self.pipeline = on;
        Ok(self)
    }

    /// Enable fault recovery (see [`RecoveryOptions`]). Errors for backends
    /// with no recovery path, which fail clean instead.
    pub fn with_recovery(mut self, opts: RecoveryOptions) -> Result<Self> {
        if opts.enabled {
            let supported = match &self.backend {
                Backend::AllToAll { .. } => true,
                Backend::Ring { ring } => ring.recompress,
                Backend::Hier { .. } => false,
            };
            ensure!(
                supported,
                "recovery is supported by the all-to-all and recompressing ring \
                 collectives only — '{}' fails clean on faults instead",
                self.label
            );
        }
        self.recovery = opts;
        Ok(self)
    }

    /// Direct access to the mesh (for installing a fault injector or
    /// reading liveness in tests and the trainer).
    pub fn mesh_mut(&mut self) -> &mut Mesh {
        &mut self.mesh
    }

    pub fn rank(&self) -> usize {
        self.mesh.rank
    }

    pub fn world(&self) -> usize {
        self.mesh.world
    }

    pub fn name(&self) -> String {
        format!("{} over {} ({} ranks)", self.label, self.codec.name(), self.mesh.world)
    }

    /// Run one synchronous exchange of this rank's gradient; `mean`
    /// receives the decoded global mean (identical bits on every rank).
    pub fn exchange(&mut self, grad: &[f32], mean: &mut Vec<f32>) -> Result<DistStats> {
        let _sp = crate::obs_span!("exchange");
        flight::crumb(&CRUMB_EXCHANGE, grad.len() as u64, self.mesh.rank as u64, 0);
        let r = self.exchange_inner(grad, mean);
        if r.is_err() {
            flight::dump("exchange errored");
        }
        r
    }

    fn exchange_inner(&mut self, grad: &[f32], mean: &mut Vec<f32>) -> Result<DistStats> {
        let n = grad.len();
        let mut stats = DistStats::default();
        let SocketExchange { codec, mesh, backend, recovery, pipeline, .. } = self;
        let codec: &dyn Codec = &**codec;
        let recovery = recovery.enabled;
        // Recovery traffic (verdict rounds, raw resends) must not interleave
        // with queued data frames: fall back to the serial paths, same bits.
        let pipeline = *pipeline && !recovery;
        let t_total = Instant::now();

        match backend {
            Backend::AllToAll { session, msg, rx, scratch } => {
                let k = mesh.world;
                let t = Instant::now();
                {
                    let _sp = crate::obs_span!("a2a.encode");
                    session.encode_into(grad, msg);
                }
                stats.wall.encode_s += t.elapsed().as_secs_f64();
                stats.encode_coords += n;

                if recovery {
                    a2a_recover(
                        codec, mesh, msg, rx, scratch, n, mean, &mut stats,
                    )?;
                } else if pipeline {
                    stats.wire.record_fanout(msg.len(), n, k.saturating_sub(1));
                    *mean = a2a_pipelined(codec, mesh, msg, n, &mut stats)?;
                } else {
                    stats.wire.record_fanout(msg.len(), n, k.saturating_sub(1));

                    let t = Instant::now();
                    {
                        let _sp = crate::obs_span!("a2a.exchange");
                        mesh.exchange_all(msg)?;
                    }
                    stats.wall.transfer_s += t.elapsed().as_secs_f64();
                    stats.hops += 1;

                    // Same grouped merge as in-process: messages in worker
                    // order, this rank's own bytes included at its own index.
                    let t = Instant::now();
                    let _sp = crate::obs_span!("a2a.decode");
                    let rank = mesh.rank;
                    let msgs: Vec<&[u8]> = (0..k)
                        .map(|w| if w == rank { msg.as_slice() } else { mesh.frame(w) })
                        .collect();
                    *mean = collectives::par_decode_mean(
                        &msgs,
                        n,
                        1.0 / k as f32,
                        codec.decode_threads(),
                        |m, a, acc, th| codec.decode_add_threads(m, a, acc, th),
                    )?;
                    drop(_sp);
                    stats.wall.decode_s += t.elapsed().as_secs_f64();
                    stats.decode_coords += k * n;
                }
            }

            Backend::Ring { ring } => {
                ensure!(
                    codec.supports_chunked_encode(),
                    "{} sessions cannot encode ring segments (stateful fixed layout) — \
                     use the all-to-all collective for this codec",
                    codec.name()
                );
                let alpha = 1.0 / mesh.world as f32;
                if ring.recompress {
                    ring.run_recompress(
                        codec, mesh, grad, alpha, mean, &mut stats, recovery, pipeline,
                    )?;
                } else {
                    ring.run_raw(codec, mesh, grad, alpha, mean, &mut stats)?;
                }
            }

            Backend::Hier {
                session,
                msg,
                members,
                lcount,
                ring,
                group_sum,
                lsegs,
                lfinals,
                lcur_n,
            } => {
                ensure!(
                    codec.supports_chunked_encode(),
                    "{} sessions cannot re-encode leader-ring segments (stateful fixed \
                     layout) — use the all-to-all collective for this codec",
                    codec.name()
                );
                let world = mesh.world;
                let leader = members[0];
                let gsize = members.len();
                let lcount = *lcount;

                // Phase 1 — every rank encodes its full gradient.
                let t = Instant::now();
                session.encode_into(grad, msg);
                stats.wall.encode_s += t.elapsed().as_secs_f64();
                stats.encode_coords += n;

                if let Some(ring) = ring.as_mut() {
                    // Leader: fan-in, decode-sum in listed member order (own
                    // message first — it passes through encode/decode even
                    // though it never crosses a link, as in Algorithm 1).
                    let td = Instant::now();
                    group_sum.clear();
                    group_sum.resize(n, 0.0);
                    codec.decode_add(msg, 1.0, group_sum)?;
                    stats.wall.decode_s += td.elapsed().as_secs_f64();
                    stats.decode_coords += n;
                    for &m in &members[1..] {
                        let tt = Instant::now();
                        mesh.recv_from(m)?;
                        stats.wall.transfer_s += tt.elapsed().as_secs_f64();
                        let td = Instant::now();
                        codec.decode_add(mesh.frame(m), 1.0, group_sum)?;
                        stats.wall.decode_s += td.elapsed().as_secs_f64();
                        stats.decode_coords += n;
                    }
                    if gsize > 1 {
                        stats.hops += 1;
                    }

                    // Phase 2 — recompressing ring across leaders; the
                    // final decode averages over the global worker count.
                    ring.run_recompress(
                        codec,
                        mesh,
                        group_sum,
                        1.0 / world as f32,
                        mean,
                        &mut stats,
                        false,
                        false,
                    )?;

                    // Phase 3 — fan the final frames out verbatim, lane
                    // order (`mean` is already materialised by the ring).
                    if gsize > 1 {
                        let tt = Instant::now();
                        for &m in &members[1..] {
                            for f in ring.finals.iter() {
                                mesh.send_to(m, f)?;
                            }
                        }
                        stats.wall.transfer_s += tt.elapsed().as_secs_f64();
                        stats.hops += 1;
                        for (j, f) in ring.finals.iter().enumerate() {
                            stats.wire.record_fanout(f.len(), ring.segs[j].1, gsize - 1);
                        }
                    }
                } else {
                    // Member: send the full-gradient frame to the leader…
                    stats.wire.record(msg.len(), n);
                    let tt = Instant::now();
                    mesh.send_to(leader, msg)?;
                    stats.wall.transfer_s += tt.elapsed().as_secs_f64();
                    stats.hops += 1;

                    // …then receive the leader ring's final frames (lane
                    // order) and decode them exactly as the leaders do.
                    if *lcur_n != Some(n) {
                        *lsegs =
                            collectives::ring_segments(n, lcount, codec.chunk_align().max(1));
                        *lfinals = (0..lcount).map(|_| Vec::new()).collect();
                        *lcur_n = Some(n);
                    }
                    let tt = Instant::now();
                    for j in 0..lcount {
                        mesh.recv_from(leader)?;
                        let f = mesh.frame(leader);
                        lfinals[j].clear();
                        lfinals[j].extend_from_slice(f);
                    }
                    stats.wall.transfer_s += tt.elapsed().as_secs_f64();
                    stats.hops += 1;

                    let td = Instant::now();
                    mean.clear();
                    mean.resize(n, 0.0);
                    for (j, f) in lfinals.iter().enumerate() {
                        let (off, len) = lsegs[j];
                        codec.decode_add(f, 1.0 / world as f32, &mut mean[off..off + len])?;
                    }
                    stats.wall.decode_s += td.elapsed().as_secs_f64();
                    stats.decode_coords += n;
                }
            }
        }
        // Attribute this exchange's wall time: sockets vs codec, remainder
        // idle. The phase timers run disjointly on this thread, so their
        // sum never exceeds the enclosing total (idle clamps at zero).
        stats.occupancy.record(
            t_total.elapsed().as_secs_f64(),
            stats.wall.transfer_s,
            stats.wall.encode_s + stats.wall.decode_s,
        );
        Ok(stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pack_unpack_roundtrip() {
        let frames = vec![vec![1u8, 2, 3], vec![], vec![9u8; 70000]];
        let mut packed = Vec::new();
        pack_set(&frames, &mut packed);
        let mut out = vec![Vec::new(); 3];
        unpack_set(&packed, 3, &mut out).unwrap();
        assert_eq!(out, frames);
        // wrong count, truncation, trailing garbage all rejected
        assert!(unpack_set(&packed, 2, &mut out[..2].to_vec()).is_err());
        assert!(unpack_set(&packed[..packed.len() - 1], 3, &mut out).is_err());
        let mut extra = packed.clone();
        extra.push(0);
        assert!(unpack_set(&extra, 3, &mut out).is_err());
    }

    #[test]
    fn unpack_set_rejects_mismatched_destination() {
        // A destination with fewer slots than `expect` used to pass the
        // count check and silently drop trailing frames (the `at ==
        // bytes.len()` check caught it only by accident, after partially
        // filling the output); more slots would panic later. Both are now
        // rejected up front with both counts named.
        let frames = vec![vec![1u8, 2], vec![3u8], vec![4u8, 5, 6]];
        let mut packed = Vec::new();
        pack_set(&frames, &mut packed);
        let mut short = vec![Vec::new(); 2];
        let err = unpack_set(&packed, 3, &mut short).unwrap_err().to_string();
        assert!(err.contains('2') && err.contains('3'), "names both counts: {err}");
        let mut long = vec![Vec::new(); 5];
        assert!(unpack_set(&packed, 3, &mut long).is_err());
        let mut exact = vec![Vec::new(); 3];
        unpack_set(&packed, 3, &mut exact).unwrap();
        assert_eq!(exact, frames);
    }
}
