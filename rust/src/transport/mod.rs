//! Real multi-process transport: the collective algorithms over OS sockets.
//!
//! Everything below `collectives` in this crate moves *real encoded bytes*
//! but charges *virtual* time on a simulated interconnect. This module is
//! the other half of that bargain: the same algorithms, the same wire
//! bytes, across K actual processes connected by TCP or Unix-domain
//! sockets — so the simnet's modeled α–β numbers can be checked against
//! measured wall-clock on a real loopback (and, eventually, a real NIC).
//!
//! Layers, bottom up:
//!
//! * [`frame`] — length-prefixed message framing over any byte stream:
//!   partial-read loops, a hard length cap *before* allocation, reusable
//!   receive buffers that grow proportionally to bytes actually delivered
//!   (a length-lying peer cannot OOM the receiver).
//! * [`net`] — endpoints (`tcp:<addr>` / `uds:<path>`), connect with
//!   bounded retry + backoff, accept with deadlines, and the [`net::Mesh`]:
//!   a fully-connected K-process group built from one rendezvous address,
//!   every blocking socket operation bounded by a configurable timeout —
//!   a dead peer is a clean error, never a hang.
//! * [`exchange`] — [`exchange::SocketExchange`], one rank's end of the
//!   all-to-all / ring / hierarchical collectives, bit-identical to the
//!   in-process implementations (same sessions, same segment layout, same
//!   accumulation order), measuring real per-phase wall-clock. With
//!   pipelining enabled (`with_pipelining`), the all-to-all decodes peer
//!   frames as they drain off the sockets and the recompressing ring ships
//!   each hop's outbound frame from a per-peer writer thread while the main
//!   thread decodes and re-encodes the next hop — same bits, overlapped
//!   wall clock, with the io/codec/idle split surfaced as
//!   [`crate::metrics::Occupancy`] in [`exchange::DistStats`].
//! * [`trainer`] — [`trainer::train_rank`], one rank's synchronous SGD
//!   loop producing the same `RunResult` the simnet trainer does, with the
//!   measured [`crate::metrics::WallClock`] filled in next to the modeled
//!   breakdown.
//!
//! * [`fault`] — [`fault::FaultInjector`], the seeded fault schedule the
//!   scenario layer installs on a mesh's outbound data frames (corrupt /
//!   drop / delay), with the recovery control plane bypassing it.
//!
//! The `transport_e2e` CI lane runs the cross-process determinism goldens
//! (spawned `qsgd exchange-worker` processes over loopback TCP and UDS)
//! under a hard timeout, including the churn case that kills a rank
//! mid-epoch and requires the survivors to finish with a renormalized
//! mean.

pub mod exchange;
pub mod fault;
pub mod frame;
pub mod net;
pub mod trainer;

pub use exchange::{DistStats, RecoveryOptions, SocketExchange};
pub use fault::{FaultAction, FaultInjector};
pub use frame::{write_frame, FrameReader, MAX_FRAME};
pub use net::{connect_retry, Conn, Endpoint, Listener, Mesh, MeshConfig};
pub use trainer::{train_rank, DistTrainConfig};
