//! Length-prefixed framing over a byte stream.
//!
//! The wire unit is `[u32 LE payload length][payload bytes]`. The payload is
//! an opaque blob — in practice an encoded gradient frame produced by an
//! [`EncodeSession`](crate::quant::EncodeSession), which carries its own
//! magic/version header and is validated by the hardened decoder after the
//! transport hands it over. This module only guarantees that frame
//! *boundaries* survive a stream that delivers bytes in arbitrary chunks.
//!
//! Two hostile-input properties are load-bearing (the streaming robustness
//! suite pins both):
//!
//! * **No hangs**: every read loop forwards the underlying stream's errors,
//!   so a socket with a read timeout surfaces `WouldBlock`/`TimedOut` as a
//!   clean `Err` instead of blocking forever. EOF mid-prefix or mid-payload
//!   is an error, not silence; EOF *between* frames is the clean
//!   end-of-stream `Ok(None)`.
//! * **No allocation blow-ups**: a length prefix is a claim, not a budget.
//!   [`FrameReader`] grows its buffer at most [`READ_CHUNK`] bytes past what
//!   the peer actually delivered, so a prefix lying about a huge payload
//!   costs memory proportional to the bytes received, never to the claim.

use std::io::{self, Read, Write};

use anyhow::{bail, ensure, Context, Result};

/// Hard cap on a single frame's payload. Far above any encoded-gradient
/// frame the repo produces (a 1B-coordinate fp32 gradient is 4 GiB, but no
/// collective ships whole fp32 gradients — QSGD frames are 4–32× smaller and
/// segmented by the ring), yet small enough that a hostile length prefix is
/// rejected before any allocation begins.
pub const MAX_FRAME: usize = 1 << 30;

/// Growth step for the receive buffer while a frame's payload streams in.
/// Also the bound on how far the buffer may extend past received bytes.
pub const READ_CHUNK: usize = 64 * 1024;

/// Write one framed payload: `u32` LE length prefix, payload bytes, flush.
///
/// Works over any [`Write`] — a `TcpStream`/`UnixStream` with a write
/// timeout turns a stalled peer into an error here rather than a hang.
pub fn write_frame<W: Write + ?Sized>(w: &mut W, payload: &[u8]) -> Result<()> {
    ensure!(
        payload.len() <= MAX_FRAME,
        "frame payload of {} bytes exceeds the {} byte cap",
        payload.len(),
        MAX_FRAME
    );
    let hdr = (payload.len() as u32).to_le_bytes();
    w.write_all(&hdr).context("writing frame length prefix")?;
    w.write_all(payload).context("writing frame payload")?;
    w.flush().context("flushing frame")?;
    Ok(())
}

/// Incremental frame reader with a reusable receive buffer.
///
/// One `FrameReader` per peer stream: each [`read_frame`](Self::read_frame)
/// call returns a borrowed view of the payload, valid until the next call —
/// decoding runs straight off this buffer (the zero-copy
/// `FrameView`/`decode_add` path), no per-frame allocation in steady state.
pub struct FrameReader {
    buf: Vec<u8>,
    max_frame: usize,
}

impl Default for FrameReader {
    fn default() -> Self {
        Self::new()
    }
}

impl FrameReader {
    pub fn new() -> Self {
        Self::with_max(MAX_FRAME)
    }

    /// Reader with a custom payload cap (tests use small caps to exercise
    /// the rejection path cheaply).
    pub fn with_max(max_frame: usize) -> Self {
        Self { buf: Vec::new(), max_frame }
    }

    /// Read the next frame. Returns:
    ///
    /// * `Ok(Some(payload))` — one complete frame, borrowed from the
    ///   internal buffer (valid until the next call);
    /// * `Ok(None)` — clean end of stream (EOF exactly on a frame boundary);
    /// * `Err(..)` — EOF mid-prefix or mid-payload, a length prefix above
    ///   the cap, or any underlying I/O error (including read timeouts).
    ///
    /// Partial reads are handled throughout: the stream may deliver one byte
    /// at a time and the frame still reassembles byte-identically.
    pub fn read_frame<R: Read + ?Sized>(&mut self, r: &mut R) -> Result<Option<&[u8]>> {
        let mut hdr = [0u8; 4];
        let mut got = 0usize;
        while got < 4 {
            match r.read(&mut hdr[got..]) {
                Ok(0) => {
                    if got == 0 {
                        return Ok(None);
                    }
                    bail!("stream closed mid length prefix ({got}/4 bytes)");
                }
                Ok(k) => got += k,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(e) => {
                    return Err(anyhow::Error::new(e).context("reading frame length prefix"))
                }
            }
        }
        let len = u32::from_le_bytes(hdr) as usize;
        ensure!(
            len <= self.max_frame,
            "frame length prefix claims {len} bytes, above the {} byte cap",
            self.max_frame
        );
        // Grow chunkwise as bytes arrive: a lying prefix cannot make us
        // allocate more than (received + READ_CHUNK) bytes.
        self.buf.clear();
        let mut filled = 0usize;
        while filled < len {
            let step = (len - filled).min(READ_CHUNK);
            if self.buf.len() < filled + step {
                self.buf.resize(filled + step, 0);
            }
            match r.read(&mut self.buf[filled..filled + step]) {
                Ok(0) => bail!("stream closed mid frame: got {filled} of {len} payload bytes"),
                Ok(k) => filled += k,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(anyhow::Error::new(e).context("reading frame payload")),
            }
        }
        self.buf.truncate(len);
        Ok(Some(&self.buf))
    }

    /// The most recently completed frame (empty before the first one).
    /// Lets callers re-borrow a frame after the `&mut self` borrow of
    /// [`read_frame`](Self::read_frame) has ended.
    pub fn last(&self) -> &[u8] {
        &self.buf
    }

    /// Current receive-buffer capacity — the robustness suite asserts this
    /// stays proportional to bytes received, not to hostile length claims.
    pub fn buf_capacity(&self) -> usize {
        self.buf.capacity()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn roundtrip_and_reuse() {
        let payloads: Vec<Vec<u8>> =
            vec![vec![], vec![7u8; 1], (0..=255u8).collect(), vec![3u8; 200_000]];
        let mut wire = Vec::new();
        for p in &payloads {
            write_frame(&mut wire, p).unwrap();
        }
        let mut rd = FrameReader::new();
        let mut cur = Cursor::new(wire);
        for p in &payloads {
            let got = rd.read_frame(&mut cur).unwrap().expect("frame present");
            assert_eq!(got, p.as_slice());
        }
        assert!(rd.read_frame(&mut cur).unwrap().is_none(), "clean EOF after last frame");
    }

    #[test]
    fn eof_on_boundary_is_none_midframe_is_error() {
        let mut wire = Vec::new();
        write_frame(&mut wire, &[9u8; 50]).unwrap();
        // Boundary EOF.
        let mut rd = FrameReader::new();
        let mut cur = Cursor::new(wire.clone());
        assert!(rd.read_frame(&mut cur).unwrap().is_some());
        assert!(rd.read_frame(&mut cur).unwrap().is_none());
        // Every strict prefix is an error (mid-prefix or mid-payload), except
        // the empty prefix which is a clean end of stream.
        for cut in 0..wire.len() {
            let mut rd = FrameReader::new();
            let mut cur = Cursor::new(wire[..cut].to_vec());
            let got = rd.read_frame(&mut cur);
            if cut == 0 {
                assert!(got.unwrap().is_none());
            } else {
                assert!(got.is_err(), "cut at {cut} must be rejected");
            }
        }
    }

    #[test]
    fn oversized_length_prefix_rejected_before_allocation() {
        let mut wire = Vec::new();
        wire.extend_from_slice(&(u32::MAX).to_le_bytes());
        wire.extend_from_slice(&[0u8; 16]);
        let mut rd = FrameReader::new();
        let err = rd.read_frame(&mut Cursor::new(wire)).unwrap_err();
        assert!(err.to_string().contains("cap"), "{err}");
        assert_eq!(rd.buf_capacity(), 0, "no allocation for a rejected prefix");
    }

    #[test]
    fn lying_length_prefix_allocates_proportional_to_delivery() {
        // Claims 512 MiB (under the cap), delivers 100 bytes, then EOF.
        let mut wire = Vec::new();
        wire.extend_from_slice(&(512u32 << 20).to_le_bytes());
        wire.extend_from_slice(&[1u8; 100]);
        let mut rd = FrameReader::new();
        let err = rd.read_frame(&mut Cursor::new(wire)).unwrap_err();
        assert!(err.to_string().contains("mid frame"), "{err}");
        assert!(
            rd.buf_capacity() <= 2 * READ_CHUNK,
            "buffer capacity {} must not track the 512MiB claim",
            rd.buf_capacity()
        );
    }

    #[test]
    fn write_rejects_over_cap_payload() {
        // Construct no actual huge buffer: check the guard arithmetic via a
        // zero-length write with a fake length is impossible, so just assert
        // the cap constant round-trips through u32.
        assert!(MAX_FRAME <= u32::MAX as usize);
    }
}
