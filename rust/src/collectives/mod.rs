//! Collective exchange primitives over the simulated interconnect.
//!
//! These move *real* encoded bytes between simulated workers (the decode
//! side consumes exactly what the encode side produced — no shortcuts) and
//! charge virtual transfer time on the [`crate::simnet::SimNet`] model.
//!
//! The K per-worker Encode/Decode jobs of Algorithm 1 are independent
//! (per-worker compressor state, per-worker `Xoshiro256` RNG streams), so
//! [`par_encode`] and [`par_decode_mean`] fan them out on the scoped pool
//! ([`crate::util::par`]); wire bytes stay bit-identical to a sequential
//! pass and the decode merge order is fixed, so results are deterministic.

use anyhow::Result;

use crate::simnet::{SimNet, VTime};
use crate::util::par;

/// Result of an all-broadcast: every worker sees all K messages, in worker
/// order (a worker's own message included, as in Algorithm 1 where the local
/// gradient also passes through Encode/Decode — quantization noise applies
/// to one's own contribution too).
pub struct BroadcastResult {
    pub time: VTime,
    pub messages: Vec<Vec<u8>>,
}

/// All-to-all broadcast of per-worker messages (Algorithm 1 lines 4–8).
pub fn all_broadcast(net: &SimNet, messages: Vec<Vec<u8>>) -> BroadcastResult {
    assert_eq!(messages.len(), net.workers);
    let sizes: Vec<usize> = messages.iter().map(Vec::len).collect();
    let time = net.exchange_time(&sizes);
    BroadcastResult { time, messages }
}

/// Encode K independent per-worker messages in parallel (Algorithm 1 line 3
/// across simulated workers). Each job owns its compressor state and RNG
/// stream, so the produced bytes are bit-identical to a sequential loop in
/// worker order.
pub fn par_encode<W, F>(workers: &mut [W], encode: F) -> Vec<Vec<u8>>
where
    W: Send,
    F: Fn(usize, &mut W) -> Vec<u8> + Sync,
{
    par::par_map_mut(workers, encode)
}

/// Message groups for the parallel decode merge. Fixed (not derived from the
/// machine's core count) so the float accumulation order — groups are summed
/// in index order — is identical on every host. With K ≤ this many peers
/// each group holds one message and the result is bit-identical to the
/// sequential decode-accumulate loop.
pub const DECODE_MERGE_GROUPS: usize = 8;

/// Decode K peer messages and average them into a fresh accumulator
/// (Algorithm 1 lines 7–8): `out = Σ_k alpha · decode(messages[k])`.
/// Groups of messages decode concurrently into private partial accumulators
/// (via the caller's fused `decode_add`), which are then merged in fixed
/// group order.
///
/// Two levels of parallelism: across message groups, and *within* one
/// message — the closure receives the per-group intra-message thread
/// budget (leftover cores once the groups are staffed) to spend on
/// directory-bearing frames via
/// [`decompress_add_threads`](crate::quant::Compressor::decompress_add_threads).
/// Small K on a many-core host ⇒ the budget goes to buckets within each
/// message; large K ⇒ the groups already saturate the pool and the budget
/// degrades to 1 (serial per message). Either way the result is
/// bit-identical to the sequential decode-accumulate of each group.
pub fn par_decode_mean<F>(
    messages: &[Vec<u8>],
    n: usize,
    alpha: f32,
    decode_add: F,
) -> Result<Vec<f32>>
where
    F: Fn(&[u8], f32, &mut [f32], usize) -> Result<()> + Sync,
{
    let mut acc = vec![0.0f32; n];
    if messages.is_empty() {
        return Ok(acc);
    }
    let groups = DECODE_MERGE_GROUPS.min(messages.len());
    let intra = (par::max_threads() / groups).max(1);
    let chunk = messages.len().div_ceil(groups);
    let grouped: Vec<&[Vec<u8>]> = messages.chunks(chunk).collect();
    let partials = par::par_map(&grouped, |_, group| -> Result<Vec<f32>> {
        let mut part = vec![0.0f32; n];
        for msg in group.iter() {
            decode_add(msg, alpha, &mut part, intra)?;
        }
        Ok(part)
    });
    for p in partials {
        let p = p?;
        for (a, &x) in acc.iter_mut().zip(&p) {
            *a += x;
        }
    }
    Ok(acc)
}

/// Dense fp32 ring allreduce (the 32-bit baseline's transport): averages the
/// workers' gradients in-network; every worker receives the same mean.
pub fn ring_allreduce_mean(net: &SimNet, grads: &[Vec<f32>]) -> (VTime, Vec<f32>) {
    assert_eq!(grads.len(), net.workers);
    let n = grads[0].len();
    assert!(grads.iter().all(|g| g.len() == n), "allreduce requires equal sizes");
    let bytes = n * 4;
    let time = net.exchange_time(&vec![bytes; net.workers]);
    let mut mean = vec![0.0f32; n];
    let k = net.workers as f32;
    for g in grads {
        for (m, &x) in mean.iter_mut().zip(g) {
            *m += x / k;
        }
    }
    (time, mean)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simnet::{Link, Topology};

    fn net(k: usize, topo: Topology) -> SimNet {
        SimNet::new(k, Link::new(1e9, 1e-6), topo)
    }

    #[test]
    fn broadcast_preserves_bytes() {
        let msgs: Vec<Vec<u8>> = (0..4).map(|i| vec![i as u8; 10 + i]).collect();
        let r = all_broadcast(&net(4, Topology::P2pBroadcast), msgs.clone());
        assert_eq!(r.messages, msgs);
        assert!(r.time.secs() > 0.0);
    }

    #[test]
    fn allreduce_mean_is_exact() {
        let grads = vec![vec![1.0f32, 2.0], vec![3.0, 6.0]];
        let (t, mean) = ring_allreduce_mean(&net(2, Topology::RingAllReduce), &grads);
        assert_eq!(mean, vec![2.0, 4.0]);
        assert!(t.secs() > 0.0);
    }

    #[test]
    #[should_panic(expected = "equal sizes")]
    fn allreduce_rejects_ragged() {
        let grads = vec![vec![1.0f32], vec![1.0, 2.0]];
        ring_allreduce_mean(&net(2, Topology::RingAllReduce), &grads);
    }

    #[test]
    fn par_encode_matches_sequential_worker_loop() {
        use crate::coordinator::CompressorSpec;
        use crate::util::rng::{self, Xoshiro256};

        struct Lane {
            c: Box<dyn crate::quant::Compressor>,
            rng: Xoshiro256,
            grad: Vec<f32>,
        }
        let n = 2000usize;
        let spec = CompressorSpec::qsgd_4bit();
        let mk = || -> Vec<Lane> {
            (0..6)
                .map(|w| {
                    let mut gr = Xoshiro256::stream(7, w as u64);
                    Lane {
                        c: spec.build(n),
                        rng: Xoshiro256::stream(11, w as u64),
                        grad: rng::normal_vec(&mut gr, n),
                    }
                })
                .collect()
        };
        let mut seq = mk();
        let expect: Vec<Vec<u8>> =
            seq.iter_mut().map(|l| l.c.compress(&l.grad, &mut l.rng)).collect();
        let mut par_lanes = mk();
        let got = par_encode(&mut par_lanes, |_, l| l.c.compress(&l.grad, &mut l.rng));
        assert_eq!(got, expect, "parallel encode must be bit-identical");
    }

    #[test]
    fn par_decode_mean_matches_sequential_accumulation() {
        use crate::coding::gradient;
        use crate::quant::{stochastic, Norm};
        use crate::util::rng::{self, Xoshiro256};

        let n = 3000usize;
        let k = 8usize;
        let mut rng = Xoshiro256::from_u64(3);
        let msgs: Vec<Vec<u8>> = (0..k)
            .map(|_| {
                let g = rng::normal_vec(&mut rng, n);
                let q = stochastic::quantize(&g, 7, 512, Norm::Max, &mut rng);
                gradient::encode_auto(&q)
            })
            .collect();
        let alpha = 1.0 / k as f32;
        let mut seq = vec![0.0f32; n];
        for m in &msgs {
            gradient::decode_add(m, alpha, &mut seq).unwrap();
        }
        let par = par_decode_mean(&msgs, n, alpha, |m, a, acc, t| {
            gradient::par_decode_add_threads(m, a, acc, t).map(|_| ())
        })
        .unwrap();
        // K ≤ DECODE_MERGE_GROUPS ⇒ one message per group ⇒ the merge order
        // equals the sequential accumulation order exactly.
        assert!(k <= DECODE_MERGE_GROUPS);
        assert_eq!(par, seq);
        // corrupt message propagates the error
        let mut bad = msgs.clone();
        bad[3][0] ^= 0xff;
        assert!(par_decode_mean(&bad, n, alpha, |m, a, acc, t| {
            gradient::par_decode_add_threads(m, a, acc, t).map(|_| ())
        })
        .is_err());
    }

    #[test]
    fn par_decode_mean_intra_message_parallelism_is_bit_identical() {
        // Directory-bearing frames: few large messages, so the intra-message
        // budget actually engages. The mean must equal the fully serial
        // accumulation bit-for-bit.
        use crate::coding::gradient::{self, Regime};
        use crate::quant::{stochastic, Norm};
        use crate::util::rng::{self, Xoshiro256};

        let n = 20_000usize;
        let mut rng = Xoshiro256::from_u64(9);
        let msgs: Vec<Vec<u8>> = (0..2)
            .map(|_| {
                let g = rng::normal_vec(&mut rng, n);
                let q = stochastic::quantize(&g, 7, 512, Norm::Max, &mut rng);
                gradient::encode_with_directory(&q, Regime::Dense, true)
            })
            .collect();
        let alpha = 0.5f32;
        let mut seq = vec![0.0f32; n];
        for m in &msgs {
            gradient::decode_add(m, alpha, &mut seq).unwrap();
        }
        let par = par_decode_mean(&msgs, n, alpha, |m, a, acc, t| {
            gradient::par_decode_add_threads(m, a, acc, t.max(4)).map(|_| ())
        })
        .unwrap();
        assert_eq!(par, seq);
    }
}
