//! Collective exchange primitives over the simulated interconnect.
//!
//! These move *real* encoded bytes between simulated workers (the decode
//! side consumes exactly what the encode side produced — no shortcuts) and
//! charge virtual transfer time on the [`crate::simnet::SimNet`] model.
//!
//! The K per-worker Encode/Decode jobs of Algorithm 1 are independent
//! (per-worker [`EncodeSession`](crate::quant::EncodeSession) state with its
//! own `Xoshiro256` RNG stream), so the coordinators fan encode jobs out
//! directly on the scoped pool ([`crate::util::par::par_map_mut`] over
//! session/buffer pairs — see `coordinator::sync`) and [`par_decode_mean`]
//! does the same for the decode merge; wire bytes stay bit-identical to a
//! sequential pass and the merge order is fixed, so results are
//! deterministic.

pub mod algo;

pub use algo::{
    build, build_with_scenario, model_bytes_per_worker, model_exchange_time, ring_segments,
    AllToAll, CollectiveAlgo, Exchange, Hierarchical, HopStat, RingAllreduce,
};

use anyhow::Result;

use crate::simnet::{SimNet, VTime};
use crate::util::par;

/// Result of an all-broadcast: every worker sees all K messages, in worker
/// order (a worker's own message included, as in Algorithm 1 where the local
/// gradient also passes through Encode/Decode — quantization noise applies
/// to one's own contribution too). Messages are *borrowed*: the broadcast
/// only charges virtual transfer time, so senders keep ownership of their
/// (reusable) encode buffers — no per-step copies of the wire bytes.
pub struct BroadcastResult<'a> {
    pub time: VTime,
    pub messages: &'a [Vec<u8>],
}

/// All-to-all broadcast of per-worker messages (Algorithm 1 lines 4–8).
pub fn all_broadcast<'a>(net: &SimNet, messages: &'a [Vec<u8>]) -> BroadcastResult<'a> {
    assert_eq!(messages.len(), net.workers);
    let sizes: Vec<usize> = messages.iter().map(Vec::len).collect();
    let time = net.exchange_time(&sizes);
    BroadcastResult { time, messages }
}

/// Message groups for the parallel decode merge. Fixed (not derived from the
/// machine's core count) so the float accumulation order — groups are summed
/// in index order — is identical on every host. With K ≤ this many peers
/// each group holds one message and the result is bit-identical to the
/// sequential decode-accumulate loop.
pub const DECODE_MERGE_GROUPS: usize = 8;

/// Decode K peer messages and average them into a fresh accumulator
/// (Algorithm 1 lines 7–8): `out = Σ_k alpha · decode(messages[k])`.
/// Groups of messages decode concurrently into private partial accumulators
/// (via the caller's fused `decode_add`), which are then merged in fixed
/// group order.
///
/// Two levels of parallelism: across message groups, and *within* one
/// message — the closure receives the per-group intra-message thread
/// budget (the caller's total `threads` budget, less what the groups
/// consume) to spend on directory-bearing frames via
/// [`decode_add_threads`](crate::quant::Codec::decode_add_threads).
/// Small K on a many-core host ⇒ the budget goes to buckets within each
/// message; large K ⇒ the groups already saturate the pool and the budget
/// degrades to 1 (serial per message). Either way the result is
/// bit-identical to the sequential decode-accumulate of each group.
///
/// `threads` is the *total* budget, normally the decoding codec's
/// [`decode_threads`](crate::quant::Codec::decode_threads) — the codec
/// carries the configured budget ([`crate::config::CodecOptions`]) so
/// call sites stop consulting env vars.
///
/// Generic over the message container (`Vec<u8>` for the simnet coordinators,
/// `&[u8]` for the socket transport's borrowed receive buffers) so the
/// zero-copy path needs no per-step copies just to share the merge.
pub fn par_decode_mean<M, F>(
    messages: &[M],
    n: usize,
    alpha: f32,
    threads: usize,
    decode_add: F,
) -> Result<Vec<f32>>
where
    M: AsRef<[u8]> + Sync,
    F: Fn(&[u8], f32, &mut [f32], usize) -> Result<()> + Sync,
{
    let mut acc = vec![0.0f32; n];
    if messages.is_empty() {
        return Ok(acc);
    }
    let groups = DECODE_MERGE_GROUPS.min(messages.len());
    let intra = (threads.max(1) / groups).max(1);
    let chunk = messages.len().div_ceil(groups);
    let grouped: Vec<&[M]> = messages.chunks(chunk).collect();
    let partials = par::par_map(&grouped, |_, group| -> Result<Vec<f32>> {
        let mut part = vec![0.0f32; n];
        for msg in group.iter() {
            decode_add(msg.as_ref(), alpha, &mut part, intra)?;
        }
        Ok(part)
    });
    for p in partials {
        let p = p?;
        for (a, &x) in acc.iter_mut().zip(&p) {
            *a += x;
        }
    }
    Ok(acc)
}

/// Dense fp32 ring allreduce (the 32-bit baseline's transport): averages the
/// workers' gradients in-network; every worker receives the same mean.
pub fn ring_allreduce_mean(net: &SimNet, grads: &[Vec<f32>]) -> (VTime, Vec<f32>) {
    assert_eq!(grads.len(), net.workers);
    let n = grads[0].len();
    assert!(grads.iter().all(|g| g.len() == n), "allreduce requires equal sizes");
    let bytes = n * 4;
    let time = net.exchange_time(&vec![bytes; net.workers]);
    let mut mean = vec![0.0f32; n];
    let k = net.workers as f32;
    for g in grads {
        for (m, &x) in mean.iter_mut().zip(g) {
            *m += x / k;
        }
    }
    (time, mean)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simnet::{Link, Topology};

    fn net(k: usize, topo: Topology) -> SimNet {
        SimNet::new(k, Link::new(1e9, 1e-6), topo)
    }

    #[test]
    fn broadcast_preserves_bytes() {
        let msgs: Vec<Vec<u8>> = (0..4).map(|i| vec![i as u8; 10 + i]).collect();
        let r = all_broadcast(&net(4, Topology::P2pBroadcast), &msgs);
        assert_eq!(r.messages, msgs.as_slice());
        assert!(r.time.secs() > 0.0);
    }

    #[test]
    fn allreduce_mean_is_exact() {
        let grads = vec![vec![1.0f32, 2.0], vec![3.0, 6.0]];
        let (t, mean) = ring_allreduce_mean(&net(2, Topology::RingAllReduce), &grads);
        assert_eq!(mean, vec![2.0, 4.0]);
        assert!(t.secs() > 0.0);
    }

    #[test]
    #[should_panic(expected = "equal sizes")]
    fn allreduce_rejects_ragged() {
        let grads = vec![vec![1.0f32], vec![1.0, 2.0]];
        ring_allreduce_mean(&net(2, Topology::RingAllReduce), &grads);
    }

    #[test]
    fn pooled_session_encode_matches_sequential_worker_loop() {
        // The coordinators' encode fan-out shape: per-worker sessions paired
        // with reusable output buffers on the scoped pool must produce the
        // bytes of a sequential worker loop, bit for bit.
        use crate::coordinator::CompressorSpec;
        use crate::quant::{Codec, EncodeSession};
        use crate::util::rng::{self, Xoshiro256};

        struct Lane {
            sess: Box<dyn EncodeSession>,
            grad: Vec<f32>,
            out: Vec<u8>,
        }
        let n = 2000usize;
        let codec = CompressorSpec::qsgd_4bit().codec();
        let mk = |codec: &dyn Codec| -> Vec<Lane> {
            (0..6)
                .map(|w| {
                    let mut gr = Xoshiro256::stream(7, w as u64);
                    Lane {
                        sess: codec.session(Xoshiro256::stream(11, w as u64)),
                        grad: rng::normal_vec(&mut gr, n),
                        out: Vec::new(),
                    }
                })
                .collect()
        };
        let mut seq = mk(codec.as_ref());
        let expect: Vec<Vec<u8>> = seq.iter_mut().map(|l| l.sess.compress(&l.grad)).collect();
        let mut par_lanes = mk(codec.as_ref());
        par::par_map_mut(&mut par_lanes, |_, l| {
            let Lane { sess, grad, out } = l;
            sess.encode_into(grad, out)
        });
        let got: Vec<Vec<u8>> = par_lanes.into_iter().map(|l| l.out).collect();
        assert_eq!(got, expect, "parallel encode must be bit-identical");
    }

    #[test]
    fn par_decode_mean_matches_sequential_accumulation() {
        use crate::coding::gradient;
        use crate::quant::{stochastic, Norm};
        use crate::util::rng::{self, Xoshiro256};

        let n = 3000usize;
        let k = 8usize;
        let mut rng = Xoshiro256::from_u64(3);
        let msgs: Vec<Vec<u8>> = (0..k)
            .map(|_| {
                let g = rng::normal_vec(&mut rng, n);
                let q = stochastic::quantize(&g, 7, 512, Norm::Max, &mut rng);
                gradient::encode_auto(&q)
            })
            .collect();
        let alpha = 1.0 / k as f32;
        let mut seq = vec![0.0f32; n];
        for m in &msgs {
            gradient::decode_add(m, alpha, &mut seq).unwrap();
        }
        let par = par_decode_mean(&msgs, n, alpha, par::max_threads(), |m, a, acc, t| {
            gradient::par_decode_add_threads(m, a, acc, t).map(|_| ())
        })
        .unwrap();
        // K ≤ DECODE_MERGE_GROUPS ⇒ one message per group ⇒ the merge order
        // equals the sequential accumulation order exactly.
        assert!(k <= DECODE_MERGE_GROUPS);
        assert_eq!(par, seq);
        // corrupt message propagates the error
        let mut bad = msgs.clone();
        bad[3][0] ^= 0xff;
        assert!(par_decode_mean(&bad, n, alpha, par::max_threads(), |m, a, acc, t| {
            gradient::par_decode_add_threads(m, a, acc, t).map(|_| ())
        })
        .is_err());
    }

    #[test]
    fn par_decode_mean_intra_message_parallelism_is_bit_identical() {
        // Directory-bearing frames: few large messages, so the intra-message
        // budget actually engages. The mean must equal the fully serial
        // accumulation bit-for-bit.
        use crate::coding::gradient::{self, Regime};
        use crate::quant::{stochastic, Norm};
        use crate::util::rng::{self, Xoshiro256};

        let n = 20_000usize;
        let mut rng = Xoshiro256::from_u64(9);
        let msgs: Vec<Vec<u8>> = (0..2)
            .map(|_| {
                let g = rng::normal_vec(&mut rng, n);
                let q = stochastic::quantize(&g, 7, 512, Norm::Max, &mut rng);
                gradient::encode_with_directory(&q, Regime::Dense, true)
            })
            .collect();
        let alpha = 0.5f32;
        let mut seq = vec![0.0f32; n];
        for m in &msgs {
            gradient::decode_add(m, alpha, &mut seq).unwrap();
        }
        let par = par_decode_mean(&msgs, n, alpha, par::max_threads(), |m, a, acc, t| {
            gradient::par_decode_add_threads(m, a, acc, t.max(4)).map(|_| ())
        })
        .unwrap();
        assert_eq!(par, seq);
    }
}
