//! Collective exchange primitives over the simulated interconnect.
//!
//! These move *real* encoded bytes between simulated workers (the decode
//! side consumes exactly what the encode side produced — no shortcuts) and
//! charge virtual transfer time on the [`crate::simnet::SimNet`] model.

use crate::simnet::{SimNet, VTime};

/// Result of an all-broadcast: every worker sees all K messages, in worker
/// order (a worker's own message included, as in Algorithm 1 where the local
/// gradient also passes through Encode/Decode — quantization noise applies
/// to one's own contribution too).
pub struct BroadcastResult {
    pub time: VTime,
    pub messages: Vec<Vec<u8>>,
}

/// All-to-all broadcast of per-worker messages (Algorithm 1 lines 4–8).
pub fn all_broadcast(net: &SimNet, messages: Vec<Vec<u8>>) -> BroadcastResult {
    assert_eq!(messages.len(), net.workers);
    let sizes: Vec<usize> = messages.iter().map(Vec::len).collect();
    let time = net.exchange_time(&sizes);
    BroadcastResult { time, messages }
}

/// Dense fp32 ring allreduce (the 32-bit baseline's transport): averages the
/// workers' gradients in-network; every worker receives the same mean.
pub fn ring_allreduce_mean(net: &SimNet, grads: &[Vec<f32>]) -> (VTime, Vec<f32>) {
    assert_eq!(grads.len(), net.workers);
    let n = grads[0].len();
    assert!(grads.iter().all(|g| g.len() == n), "allreduce requires equal sizes");
    let bytes = n * 4;
    let time = net.exchange_time(&vec![bytes; net.workers]);
    let mut mean = vec![0.0f32; n];
    let k = net.workers as f32;
    for g in grads {
        for (m, &x) in mean.iter_mut().zip(g) {
            *m += x / k;
        }
    }
    (time, mean)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simnet::{Link, Topology};

    fn net(k: usize, topo: Topology) -> SimNet {
        SimNet::new(k, Link::new(1e9, 1e-6), topo)
    }

    #[test]
    fn broadcast_preserves_bytes() {
        let msgs: Vec<Vec<u8>> = (0..4).map(|i| vec![i as u8; 10 + i]).collect();
        let r = all_broadcast(&net(4, Topology::P2pBroadcast), msgs.clone());
        assert_eq!(r.messages, msgs);
        assert!(r.time.secs() > 0.0);
    }

    #[test]
    fn allreduce_mean_is_exact() {
        let grads = vec![vec![1.0f32, 2.0], vec![3.0, 6.0]];
        let (t, mean) = ring_allreduce_mean(&net(2, Topology::RingAllReduce), &grads);
        assert_eq!(mean, vec![2.0, 4.0]);
        assert!(t.secs() > 0.0);
    }

    #[test]
    #[should_panic(expected = "equal sizes")]
    fn allreduce_rejects_ragged() {
        let grads = vec![vec![1.0f32], vec![1.0, 2.0]];
        ring_allreduce_mean(&net(2, Topology::RingAllReduce), &grads);
    }
}
