//! Pluggable collective exchange algorithms: all-to-all broadcast, ring
//! allreduce with per-hop recompression, and hierarchical two-level reduce.
//!
//! Every algorithm moves *real* wire bytes through the session codec stack
//! ([`Codec`] / [`EncodeSession`] / the frame decoders) — the decode side
//! consumes exactly the bytes the encode side produced, never a byte-count
//! shortcut — and charges per-hop α–β virtual time on the [`SimNet`] link
//! model ([`SimNet::hop_time`] and friends). All algorithms produce the
//! **mean** of the K workers' gradients, bit-identical on every (simulated)
//! worker, so the synchronous trainer's replica-consistency invariant holds
//! under any of them.
//!
//! * [`AllToAll`] — Algorithm 1's broadcast (the CNTK MPI path): every
//!   worker ships its full encoded gradient to all K−1 peers; traffic grows
//!   as (K−1)·|msg| per worker.
//! * [`RingAllreduce`] — reduce-scatter + allgather over bucket-aligned
//!   gradient segments. Each reduce-scatter hop decodes the incoming
//!   segment, adds the local contribution, and **re-encodes** the partial
//!   sum through the hop owner's [`EncodeSession`] (ECQ-style error
//!   feedback optionally carried across hops *and* steps); the completed
//!   segments then circulate verbatim, so every worker decodes identical
//!   bytes. Traffic is the bandwidth-optimal 2·(K−1)/K·|msg| per worker at
//!   the price of K−1 recompressions per segment.
//! * [`Hierarchical`] — the paper's multi-GPU-per-node testbed shape:
//!   intra-group fan-in to a leader (which re-encodes the group sum), a
//!   recompressing ring across leaders, then an intra-group fan-out of the
//!   final frames (forwarded verbatim — one global set of bytes, so the
//!   cross-group replica invariant survives).
//!
//! Determinism: the simulation walks hops and workers in fixed index order,
//! per-worker sessions own independent RNG streams (forked via
//! [`Xoshiro256::stream`] / [`Xoshiro256::fork`]), and the decode side is
//! bit-identical at every thread budget by the [`Codec`] contract — so a
//! fixed seed reproduces the final aggregate bits at any `QSGD_THREADS`.
//!
//! Steady-state allocation: the ring's hop re-encode path (decode →
//! accumulate → re-encode) runs entirely in scratch owned by the algorithm
//! (chunk accumulator, error-feedback staging, reusable wire buffers), so
//! after the first exchange it performs zero heap allocations for the
//! uniform-grid QSGD codecs — enforced by `tests/collectives_algos.rs` and
//! the `collectives_exchange` bench. (Grid-tagged v2 frames allocate their
//! in-band point table on *decode*; the uniform arms stay v1.)

use std::sync::Arc;

use anyhow::Result;

use crate::config::{CollectiveSpec, GroupSpec, ScenarioSpec};
use crate::metrics::{FaultStats, WireStats};
use crate::quant::{Codec, EncodeSession};
use crate::simnet::{SimNet, VTime};
use crate::util::par;
use crate::util::rng::Xoshiro256;

/// Outcome of one collective exchange. `wire` counts every *link traversal*
/// (an all-to-all message sent to K−1 peers is charged K−1 times), so
/// byte totals are comparable across algorithms; compression ratios are
/// unaffected (payload and fp32-equivalent scale together).
#[derive(Debug, Clone, Default)]
pub struct Exchange {
    /// Total simulated transfer time (per-hop α–β terms summed).
    pub time: VTime,
    /// Cluster-wide wire traffic, per link traversal.
    pub wire: WireStats,
    /// Number of synchronous hops charged.
    pub hops: usize,
    /// Partial-sum re-encode events (0 for all-to-all).
    pub recompressions: u64,
    /// Cumulative recompression quantization error: Σ ‖decode(e) − input‖²
    /// over every re-encode this exchange, where `input` is what was
    /// actually encoded (the partial sum, plus the carried residual under
    /// error feedback). Per-step this is the quantizer's noise either way;
    /// what `ring:ef` buys is *bias* compensation — the residual makes the
    /// errors telescope, so the time-averaged aggregate converges to the
    /// exact mean (see `tests/collectives_algos.rs`).
    pub recompress_err_sq: f64,
    /// Max over workers of coordinates quantize+encoded (cost-model
    /// charging: all workers encode in parallel in virtual time).
    pub encode_coords: usize,
    /// Max over workers of coordinates decoded.
    pub decode_coords: usize,
    /// Fault/recovery events observed during this exchange (all-zero on the
    /// classic full-participation path).
    pub faults: FaultStats,
}

/// One synchronous hop of the most recent exchange: which phase it belonged
/// to, the bytes it moved (cluster-wide), and its α–β time.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HopStat {
    pub phase: &'static str,
    pub bytes: u64,
    pub time: VTime,
}

/// A collective exchange algorithm. Implementations own all per-worker
/// mutable state (encode sessions, wire buffers, error-feedback residuals),
/// so one instance drives one training run; construct via [`build`].
pub trait CollectiveAlgo: Send {
    fn name(&self) -> String;

    /// Pre-size internal scratch for `n`-coordinate gradients so even the
    /// first [`Self::exchange`] stays off the heap where possible.
    fn prepare(&mut self, _n: usize) {}

    /// Run one exchange: aggregate the K workers' dense gradients into
    /// their mean (written into `mean`, reused across steps), moving real
    /// encoded bytes and charging simulated transfer time. Every simulated
    /// worker observes bit-identical aggregate values.
    fn exchange(
        &mut self,
        net: &SimNet,
        grads: &[Vec<f32>],
        mean: &mut Vec<f32>,
    ) -> Result<Exchange>;

    /// Per-hop wire stats of the most recent [`Self::exchange`].
    fn hop_stats(&self) -> &[HopStat];

    /// Expected wire bytes per worker for one step, given a measured
    /// full-gradient message of `msg_bytes` — the traffic model
    /// `epoch_sim` byte accounting routes through (dense-vs-QSGD crossover
    /// points are algorithm-aware).
    fn bytes_per_worker(&self, k: usize, msg_bytes: usize) -> f64;

    /// Modeled exchange time for one step at message size `msg_bytes`
    /// (epoch-scale simulation: no real bytes move).
    fn model_time(&self, net: &SimNet, msg_bytes: usize) -> VTime;
}

/// Instantiate the algorithm a [`CollectiveSpec`] names, with per-worker
/// encode sessions forked off `(seed, worker)` streams of the shared codec.
pub fn build(
    spec: &CollectiveSpec,
    codec: Arc<dyn Codec>,
    workers: usize,
    seed: u64,
) -> Box<dyn CollectiveAlgo> {
    match spec {
        CollectiveSpec::AllToAll => Box::new(AllToAll::new(codec, workers, seed)),
        CollectiveSpec::Ring { recompress, error_feedback } => {
            Box::new(RingAllreduce::new(codec, workers, seed, *recompress, *error_feedback))
        }
        CollectiveSpec::Hierarchical { groups } => Box::new(
            Hierarchical::new_with_groups(codec, workers, seed, groups)
                .unwrap_or_else(|e| panic!("invalid hierarchical group spec: {e}")),
        ),
    }
}

/// [`build`], plus a fault scenario. Participation scenarios (`drop:R@S`,
/// `partial:K`) need per-worker skip support, which only [`AllToAll`]
/// provides — ring and hierarchical reject them cleanly rather than
/// silently dropping contributions. Time-only scenarios (hetero /
/// straggler / corrupt) live in the [`SimNet`] and work under every
/// collective. Unlike [`build`], an unsatisfiable group spec is a clean
/// error here, so CLI paths should prefer this constructor.
pub fn build_with_scenario(
    spec: &CollectiveSpec,
    scenario: &ScenarioSpec,
    codec: Arc<dyn Codec>,
    workers: usize,
    seed: u64,
) -> Result<Box<dyn CollectiveAlgo>> {
    if matches!(scenario, ScenarioSpec::Drop { .. } | ScenarioSpec::Partial { .. }) {
        anyhow::ensure!(
            matches!(spec, CollectiveSpec::AllToAll),
            "scenario '{}' requires the all-to-all collective (ring and hierarchical \
             have no per-worker skip path and fail clean)",
            scenario.label()
        );
        return Ok(Box::new(
            AllToAll::new(codec, workers, seed).with_scenario(scenario.clone(), seed),
        ));
    }
    if let CollectiveSpec::Hierarchical { groups } = spec {
        return Ok(Box::new(Hierarchical::new_with_groups(codec, workers, seed, groups)?));
    }
    Ok(build(spec, codec, workers, seed))
}

/// Recompression accounting shared by the re-encode helpers (the socket
/// transport's distributed ring reuses it for parity with the simnet path).
#[derive(Debug, Clone, Copy, Default)]
pub(crate) struct Recompress {
    pub(crate) count: u64,
    pub(crate) err_sq: f64,
}

/// Bucket-aligned ring segment layout shared by the simulated ring and the
/// socket transport: `(offset, len)` per lane, boundaries on multiples of
/// `align` so per-segment quantization matches a whole-gradient pass.
/// Trailing segments may be short or empty, which the codecs handle.
///
/// Why exactly one segment per ring member (`per ≈ ⌈n/k⌉` rounded up to
/// the alignment), not finer strips: the committed hot-path medians
/// (`rust/benches/baselines/coding_hotpath.json`) put per-hop codec work
/// at ~8 ns/coord `decode_add` plus ~10–12 ns/coord fused re-encode, so a
/// K=8 hop over a 2²⁰-coord gradient already spends milliseconds in the
/// codec — orders of magnitude above per-frame latency — and sub-dividing
/// segments would multiply framing and session overhead without shortening
/// the codec critical path (the pipelined transport overlaps wire time
/// with that codec work instead). The alignment is the codec's
/// `chunk_align()` (the bucket width, 512 at the paper's setting): a cut
/// inside a bucket would renormalize it differently per segment and break
/// bit parity with the whole-gradient encode. The transport wire goldens
/// pin this layout — change it only with a frame-format version bump.
pub fn ring_segments(n: usize, k: usize, align: usize) -> Vec<(usize, usize)> {
    assert!(k >= 1, "ring needs at least one member");
    let align = align.max(1);
    // smallest multiple of the alignment covering ceil(n/k)
    let per = n.div_ceil(k).div_ceil(align).max(1).saturating_mul(align);
    (0..k)
        .map(|i| {
            let off = (i * per).min(n);
            let end = ((i + 1) * per).min(n);
            (off, end - off)
        })
        .collect()
}

/// Encode `v` through `session` into `out`, optionally compensated by an
/// error-feedback residual (ECQ-style: encode `v + r`, then set
/// `r ← (v + r) − decode(·)`), optionally accounting the quantization
/// error ‖decode(·) − encoded input‖² into `stats` (the input is `v + r`
/// under error feedback — measuring against `v` would conflate the
/// deliberately re-injected residual with recompression noise). One decode
/// of the fresh frame serves both; when neither is requested the decode is
/// skipped entirely. All scratch (`staging`, `dec`) is caller-owned and
/// reused.
#[allow(clippy::too_many_arguments)]
pub(crate) fn encode_lane(
    codec: &dyn Codec,
    session: &mut dyn EncodeSession,
    mut residual: Option<&mut [f32]>,
    staging: &mut Vec<f32>,
    dec: &mut Vec<f32>,
    v: &[f32],
    out: &mut Vec<u8>,
    stats: Option<&mut Recompress>,
) -> Result<()> {
    let ef = residual.is_some();
    if let Some(res) = residual.as_deref() {
        staging.clear();
        staging.extend_from_slice(v);
        for (s, r) in staging.iter_mut().zip(res) {
            *s += *r;
        }
        session.encode_into(staging, out);
    } else {
        session.encode_into(v, out);
    }
    if !ef && stats.is_none() {
        return Ok(());
    }
    dec.clear();
    dec.resize(v.len(), 0.0);
    codec.decode_add(out, 1.0, dec)?;
    if let Some(res) = residual.as_deref_mut() {
        for (r, (s, d)) in res.iter_mut().zip(staging.iter().zip(dec.iter())) {
            *r = *s - *d;
        }
    }
    if let Some(st) = stats {
        st.count += 1;
        let input: &[f32] = if ef { staging } else { v };
        let mut e = 0.0f64;
        for (x, d) in input.iter().zip(dec.iter()) {
            e += (*x as f64 - *d as f64).powi(2);
        }
        st.err_sq += e;
    }
    Ok(())
}

/// Fan the per-worker encode jobs out on the scoped pool: `sessions[w]`
/// encodes `grads[w]` into `msgs[w]`. Per-session RNG streams keep the
/// bytes bit-identical to a sequential worker loop.
fn par_encode_into(
    sessions: &mut [Box<dyn EncodeSession>],
    msgs: &mut [Vec<u8>],
    grads: &[Vec<f32>],
) {
    struct Job<'a> {
        session: &'a mut dyn EncodeSession,
        out: &'a mut Vec<u8>,
    }
    let mut jobs: Vec<Job> = sessions
        .iter_mut()
        .zip(msgs.iter_mut())
        .map(|(s, out)| Job { session: s.as_mut(), out })
        .collect();
    par::par_map_mut(&mut jobs, |w, job| job.session.encode_into(&grads[w], job.out));
}

/// [`par_encode_into`] restricted to the workers in `subset` — the others
/// do no work at all (a dead worker computes nothing), leaving their wire
/// buffers and RNG streams untouched.
fn par_encode_subset(
    sessions: &mut [Box<dyn EncodeSession>],
    msgs: &mut [Vec<u8>],
    grads: &[Vec<f32>],
    subset: &[usize],
) {
    struct Job<'a> {
        w: usize,
        session: &'a mut dyn EncodeSession,
        out: &'a mut Vec<u8>,
    }
    let mut jobs: Vec<Job> = sessions
        .iter_mut()
        .zip(msgs.iter_mut())
        .enumerate()
        .filter(|(w, _)| subset.contains(w))
        .map(|(w, (s, out))| Job { w, session: s.as_mut(), out })
        .collect();
    par::par_map_mut(&mut jobs, |_, job| job.session.encode_into(&grads[job.w], job.out));
}

/// Expected wire bytes per worker per step for a collective, given a
/// measured full-gradient message size — the pure traffic model behind
/// [`CollectiveAlgo::bytes_per_worker`]; `epoch_sim` calls this directly so
/// epoch-scale accounting never constructs sessions.
pub fn model_bytes_per_worker(spec: &CollectiveSpec, k: usize, msg_bytes: usize) -> f64 {
    if k <= 1 {
        return 0.0;
    }
    match spec {
        CollectiveSpec::AllToAll => ((k - 1) * msg_bytes) as f64,
        // K−1 reduce-scatter + K−1 allgather hops of ~|msg|/K segments
        CollectiveSpec::Ring { recompress: true, .. } => {
            2.0 * (k - 1) as f64 * msg_bytes as f64 / k as f64
        }
        // store-and-forward of full frame sets — all-to-all traffic
        CollectiveSpec::Ring { recompress: false, .. } => ((k - 1) * msg_bytes) as f64,
        CollectiveSpec::Hierarchical { groups } => {
            let leaders = groups
                .resolve(k)
                .map(|gs| gs.len())
                .unwrap_or_else(|e| panic!("invalid hierarchical group spec: {e}"));
            let fan = (k - leaders) as f64 * msg_bytes as f64; // in = out
            let ring = if leaders > 1 {
                // leader ring: 2(L−1) hops of ~|msg|/L segments on L links
                2.0 * (leaders - 1) as f64 * msg_bytes as f64
            } else {
                0.0
            };
            (2.0 * fan + ring) / k as f64
        }
    }
}

/// Modeled exchange time for one step at message size `msg_bytes` — the
/// pure α–β model behind [`CollectiveAlgo::model_time`]. The all-to-all
/// arm reproduces [`SimNet::exchange_time`]'s broadcast closed form
/// exactly, so legacy epoch-sim numbers are unchanged.
pub fn model_exchange_time(spec: &CollectiveSpec, net: &SimNet, msg_bytes: usize) -> VTime {
    let k = net.workers;
    if k <= 1 {
        return VTime::ZERO;
    }
    match spec {
        CollectiveSpec::AllToAll => net.exchange_time(&vec![msg_bytes; k]),
        CollectiveSpec::Ring { recompress, .. } => {
            let mut t = VTime::ZERO;
            if *recompress {
                let chunk = msg_bytes.div_ceil(k);
                for _ in 0..2 * (k - 1) {
                    t += net.hop_time(chunk);
                }
            } else {
                for _ in 0..k - 1 {
                    t += net.hop_time(msg_bytes);
                }
            }
            t
        }
        CollectiveSpec::Hierarchical { groups } => {
            let gs = groups
                .resolve(k)
                .unwrap_or_else(|e| panic!("invalid hierarchical group spec: {e}"));
            let leaders = gs.len();
            // the widest group bounds both fan phases (they run in parallel
            // across groups in virtual time)
            let widest = gs.iter().map(Vec::len).max().unwrap_or(1);
            let mut t = VTime::ZERO;
            if widest > 1 {
                t += net.fan_in_time((widest - 1) * msg_bytes);
            }
            if leaders > 1 {
                let chunk = msg_bytes.div_ceil(leaders);
                for _ in 0..2 * (leaders - 1) {
                    t += net.hop_time(chunk);
                }
            }
            if widest > 1 {
                t += net.fan_out_time(msg_bytes, widest - 1);
            }
            t
        }
    }
}

// ---------------------------------------------------------------------------
// All-to-all broadcast (Algorithm 1, refactored in)
// ---------------------------------------------------------------------------

/// Algorithm 1's all-to-all broadcast behind the [`CollectiveAlgo`] trait:
/// K parallel per-worker encodes into reusable wire buffers, one broadcast
/// charge, and the grouped parallel decode-mean — byte- and bit-identical
/// to the pre-subsystem synchronous trainer for the same seeds.
pub struct AllToAll {
    codec: Arc<dyn Codec>,
    sessions: Vec<Box<dyn EncodeSession>>,
    msgs: Vec<Vec<u8>>,
    hop_log: Vec<HopStat>,
    /// Participation scenario (`drop:R@S` / `partial:K`); [`ScenarioSpec::None`]
    /// keeps the classic full-mean path byte-identical.
    scenario: ScenarioSpec,
    scenario_seed: u64,
    step: u64,
}

impl AllToAll {
    pub fn new(codec: Arc<dyn Codec>, workers: usize, seed: u64) -> Self {
        assert!(workers >= 1);
        let sessions = (0..workers)
            .map(|w| codec.session(Xoshiro256::stream(seed, w as u64)))
            .collect();
        let msgs = (0..workers).map(|_| Vec::new()).collect();
        Self {
            codec,
            sessions,
            msgs,
            hop_log: Vec::new(),
            scenario: ScenarioSpec::None,
            scenario_seed: 0,
            step: 0,
        }
    }

    /// Install a participation scenario: each step draws its contributor set
    /// from the seeded schedule, and the mean is renormalized over the
    /// workers that actually participated (skip-and-renormalize).
    pub fn with_scenario(mut self, scenario: ScenarioSpec, seed: u64) -> Self {
        self.scenario = scenario;
        self.scenario_seed = seed;
        self
    }

    /// One exchange where only `participants` contribute: live workers
    /// encode and broadcast among themselves (the dead/unsampled ranks
    /// neither transmit nor receive), and the mean is renormalized to
    /// `1/|participants|` — the same skip-and-renormalize rule the socket
    /// trainer applies when a worker is declared dead.
    fn exchange_partial(
        &mut self,
        net: &SimNet,
        grads: &[Vec<f32>],
        mean: &mut Vec<f32>,
        participants: &[usize],
    ) -> Result<Exchange> {
        let k = self.sessions.len();
        let n = grads.first().map(Vec::len).unwrap_or(0);
        par_encode_subset(&mut self.sessions, &mut self.msgs, grads, participants);

        let mut wire = WireStats::default();
        let mut sizes = vec![0usize; k];
        for &w in participants {
            sizes[w] = self.msgs[w].len();
            // each live message traverses one link per live peer
            wire.record_fanout(self.msgs[w].len(), n, participants.len() - 1);
        }
        let time = net.exchange_time(&sizes);
        self.hop_log.clear();
        self.hop_log.push(HopStat {
            phase: "broadcast-partial",
            bytes: wire.payload_bytes,
            time,
        });

        let alpha = 1.0 / participants.len() as f32;
        let subset: Vec<&[u8]> =
            participants.iter().map(|&w| self.msgs[w].as_slice()).collect();
        let codec = &self.codec;
        *mean = super::par_decode_mean(
            &subset,
            n,
            alpha,
            codec.decode_threads(),
            |msg, a, acc, t| codec.decode_add_threads(msg, a, acc, t),
        )?;

        Ok(Exchange {
            time,
            wire,
            hops: 1,
            recompressions: 0,
            recompress_err_sq: 0.0,
            encode_coords: n,
            decode_coords: participants.len() * n,
            faults: FaultStats {
                dead_workers: (k - participants.len()) as u64,
                renormalized_steps: 1,
                ..FaultStats::default()
            },
        })
    }
}

impl CollectiveAlgo for AllToAll {
    fn name(&self) -> String {
        format!("a2a over {}", self.codec.name())
    }

    fn prepare(&mut self, n: usize) {
        let cap = self.codec.encoded_size_hint(n);
        for m in &mut self.msgs {
            if m.capacity() < cap {
                m.reserve(cap - m.len());
            }
        }
    }

    fn exchange(
        &mut self,
        net: &SimNet,
        grads: &[Vec<f32>],
        mean: &mut Vec<f32>,
    ) -> Result<Exchange> {
        let k = self.sessions.len();
        assert_eq!(grads.len(), k, "gradient count != workers");
        assert_eq!(net.workers, k, "net sized for a different worker count");
        let n = grads.first().map(Vec::len).unwrap_or(0);
        assert!(grads.iter().all(|g| g.len() == n), "equal gradient sizes required");

        if !self.scenario.is_none() {
            let step = self.step;
            self.step += 1;
            let participants = self.scenario.participants(k, self.scenario_seed, step);
            if participants.len() < k {
                return self.exchange_partial(net, grads, mean, &participants);
            }
        }

        // K independent fused encode jobs on the scoped pool.
        par_encode_into(&mut self.sessions, &mut self.msgs, grads);

        let mut wire = WireStats::default();
        for m in &self.msgs {
            // each message traverses K−1 links (one per peer)
            wire.record_fanout(m.len(), n, k - 1);
        }
        let bc = super::all_broadcast(net, &self.msgs);
        let time = bc.time;
        self.hop_log.clear();
        self.hop_log.push(HopStat { phase: "broadcast", bytes: wire.payload_bytes, time });

        let alpha = 1.0 / k as f32;
        let codec = &self.codec;
        *mean = super::par_decode_mean(
            bc.messages,
            n,
            alpha,
            codec.decode_threads(),
            |msg, a, acc, t| codec.decode_add_threads(msg, a, acc, t),
        )?;

        Ok(Exchange {
            time,
            wire,
            hops: 1,
            recompressions: 0,
            recompress_err_sq: 0.0,
            encode_coords: n,
            decode_coords: k * n,
            faults: FaultStats::default(),
        })
    }

    fn hop_stats(&self) -> &[HopStat] {
        &self.hop_log
    }

    fn bytes_per_worker(&self, k: usize, msg_bytes: usize) -> f64 {
        model_bytes_per_worker(&CollectiveSpec::AllToAll, k, msg_bytes)
    }

    fn model_time(&self, net: &SimNet, msg_bytes: usize) -> VTime {
        model_exchange_time(&CollectiveSpec::AllToAll, net, msg_bytes)
    }
}

// ---------------------------------------------------------------------------
// Ring allreduce with per-hop recompression
// ---------------------------------------------------------------------------

/// Ring allreduce over bucket-aligned gradient segments.
///
/// `recompress = true` (the real algorithm): K−1 reduce-scatter hops — each
/// worker decodes the incoming segment, adds its local contribution and
/// re-encodes the partial sum through its own session — then K−1 allgather
/// hops forwarding the completed segment frames verbatim, so every worker
/// decodes one global set of bytes. `error_feedback` carries a per-worker
/// residual (ECQ-style) across hops and steps to compensate the
/// recompression error.
///
/// `recompress = false` (pure transport): every worker pre-encodes all K
/// segments in segment order — bucket alignment plus the single per-worker
/// session make the quantized levels identical to a whole-gradient encode —
/// and the original frames circulate unchanged; the reduction happens
/// locally in worker order. This is bit-identical to the [`AllToAll`] mean
/// (property-tested), at all-to-all traffic: the variant isolates what
/// recompression buys (bytes) and costs (variance).
pub struct RingAllreduce {
    codec: Arc<dyn Codec>,
    pub recompress: bool,
    pub error_feedback: bool,
    /// Final-decode scaling; `None` ⇒ `1/K`. The hierarchical leader ring
    /// overrides this with `1/K_total` so the global mean comes out of one
    /// decode pass.
    pub alpha: Option<f32>,
    sessions: Vec<Box<dyn EncodeSession>>,
    /// (offset, len) of each ring segment; boundaries are multiples of the
    /// codec's [`Codec::chunk_align`] so segment quantization matches a
    /// whole-gradient pass.
    segs: Vec<(usize, usize)>,
    cur_n: Option<usize>,
    /// Message each worker sends this hop / staging for the next hop.
    inflight: Vec<Vec<u8>>,
    next: Vec<Vec<u8>>,
    /// Completed (fully reduced) segment frames, decoded by every worker.
    finals: Vec<Vec<u8>>,
    /// `recompress = false`: per worker, per segment original encodings.
    pre: Vec<Vec<Vec<u8>>>,
    /// Chunk accumulator for the hop partial sum.
    acc: Vec<f32>,
    /// Error-feedback staging (`v + r`) and decode scratch.
    staging: Vec<f32>,
    dec: Vec<f32>,
    /// Per-worker error-feedback residual, gradient-sized; persists across
    /// steps (that is the point).
    residual: Vec<Vec<f32>>,
    hop_log: Vec<HopStat>,
}

impl RingAllreduce {
    pub fn new(
        codec: Arc<dyn Codec>,
        workers: usize,
        seed: u64,
        recompress: bool,
        error_feedback: bool,
    ) -> Self {
        assert!(workers >= 1);
        let sessions: Vec<Box<dyn EncodeSession>> = (0..workers)
            .map(|w| codec.session(Xoshiro256::stream(seed, w as u64)))
            .collect();
        Self {
            codec,
            recompress,
            error_feedback,
            alpha: None,
            sessions,
            segs: Vec::new(),
            cur_n: None,
            inflight: (0..workers).map(|_| Vec::new()).collect(),
            next: (0..workers).map(|_| Vec::new()).collect(),
            finals: (0..workers).map(|_| Vec::new()).collect(),
            pre: Vec::new(),
            acc: Vec::new(),
            staging: Vec::new(),
            dec: Vec::new(),
            residual: Vec::new(),
            hop_log: Vec::new(),
        }
    }

    /// Completed segment frames of the most recent exchange (the bytes the
    /// hierarchical fan-out forwards verbatim).
    pub fn final_frames(&self) -> &[Vec<u8>] {
        &self.finals
    }

    /// Segment layout of the most recent exchange.
    pub fn segments(&self) -> &[(usize, usize)] {
        &self.segs
    }

    fn ensure_layout(&mut self, n: usize) {
        if self.cur_n == Some(n) {
            return;
        }
        let k = self.sessions.len();
        let align = self.codec.chunk_align().max(1);
        self.segs = ring_segments(n, k, align);
        let max_len = self.segs.iter().map(|s| s.1).max().unwrap_or(0);
        if self.acc.len() < max_len {
            self.acc.resize(max_len, 0.0);
        }
        if self.error_feedback {
            self.residual.clear();
            self.residual.resize_with(k, || vec![0.0f32; n]);
        }
        if !self.recompress && self.pre.len() != k {
            self.pre = (0..k).map(|_| (0..k).map(|_| Vec::new()).collect()).collect();
        }
        self.cur_n = Some(n);
    }

    fn run_recompress(
        &mut self,
        net: &SimNet,
        grads: &[Vec<f32>],
        mean: &mut Vec<f32>,
        alpha: f32,
    ) -> Result<Exchange> {
        let k = grads.len();
        let n = grads[0].len();
        let mut ex = Exchange::default();
        let mut stats = Recompress::default();
        let ef = self.error_feedback;
        let Self {
            codec,
            sessions,
            segs,
            inflight,
            next,
            finals,
            acc,
            staging,
            dec,
            residual,
            hop_log,
            ..
        } = self;

        // Hop-0 messages: every worker encodes its own segment (a first
        // compression, not a recompression — not counted in the stats).
        for w in 0..k {
            let (off, len) = segs[w];
            let res = if ef { Some(&mut residual[w][off..off + len]) } else { None };
            encode_lane(
                codec.as_ref(),
                sessions[w].as_mut(),
                res,
                staging,
                dec,
                &grads[w][off..off + len],
                &mut inflight[w],
                None,
            )?;
        }

        // Reduce-scatter: K−1 hops. At hop t worker i sends segment
        // (i − t) mod K to worker i+1; the receiver decodes, adds its local
        // contribution and re-encodes for the next hop (or emits the final
        // frame on the last hop).
        for t in 0..k - 1 {
            let max_b = inflight.iter().map(Vec::len).max().unwrap_or(0);
            let sum_b: u64 = inflight.iter().map(|m| m.len() as u64).sum();
            let ht = net.hop_time(max_b);
            for (i, m) in inflight.iter().enumerate() {
                let lane = (i + k - t) % k;
                ex.wire.record(m.len(), segs[lane].1);
            }
            hop_log.push(HopStat { phase: "reduce-scatter", bytes: sum_b, time: ht });
            ex.time += ht;
            ex.hops += 1;

            for r in 0..k {
                let src = (r + k - 1) % k;
                let lane = (r + 2 * k - 1 - t) % k;
                let (off, len) = segs[lane];
                let a = &mut acc[..len];
                a.fill(0.0);
                codec.decode_add(&inflight[src], 1.0, a)?;
                for (x, g) in a.iter_mut().zip(&grads[r][off..off + len]) {
                    *x += *g;
                }
                let res = if ef { Some(&mut residual[r][off..off + len]) } else { None };
                let out: &mut Vec<u8> =
                    if t + 1 == k - 1 { &mut finals[lane] } else { &mut next[r] };
                encode_lane(
                    codec.as_ref(),
                    sessions[r].as_mut(),
                    res,
                    staging,
                    dec,
                    a,
                    out,
                    Some(&mut stats),
                )?;
            }
            std::mem::swap(inflight, next);
        }

        // Allgather: K−1 hops forwarding the completed frames verbatim.
        let max_f = finals.iter().map(Vec::len).max().unwrap_or(0);
        let sum_f: u64 = finals.iter().map(|m| m.len() as u64).sum();
        for _ in 0..k - 1 {
            let ht = net.hop_time(max_f);
            hop_log.push(HopStat { phase: "allgather", bytes: sum_f, time: ht });
            ex.time += ht;
            ex.hops += 1;
        }
        for (j, f) in finals.iter().enumerate() {
            ex.wire.record_fanout(f.len(), segs[j].1, k - 1);
        }

        // Every worker decodes the same final frames ⇒ identical bits on
        // every replica; simulated once.
        mean.clear();
        mean.resize(n, 0.0);
        for (j, f) in finals.iter().enumerate() {
            let (off, len) = segs[j];
            codec.decode_add(f, alpha, &mut mean[off..off + len])?;
        }
        ex.encode_coords = n;
        ex.decode_coords = 2 * n;
        ex.recompressions = stats.count;
        ex.recompress_err_sq = stats.err_sq;
        Ok(ex)
    }

    fn run_raw(
        &mut self,
        net: &SimNet,
        grads: &[Vec<f32>],
        mean: &mut Vec<f32>,
        alpha: f32,
    ) -> Result<Exchange> {
        let k = grads.len();
        let n = grads[0].len();
        let mut ex = Exchange::default();
        let Self { codec, sessions, segs, pre, hop_log, .. } = self;

        // Pre-encode every segment in segment order: one session per worker
        // over bucket-aligned boundaries consumes the RNG stream exactly as
        // a whole-gradient encode would, so the levels match Algorithm 1.
        for w in 0..k {
            for j in 0..k {
                let (off, len) = segs[j];
                sessions[w].encode_into(&grads[w][off..off + len], &mut pre[w][j]);
            }
        }

        // Store-and-forward around the ring: K−1 hops, each worker passing
        // on one worker's full frame set.
        let mut max_set = 0usize;
        let mut total: u64 = 0;
        for row in pre.iter() {
            let b: usize = row.iter().map(Vec::len).sum();
            max_set = max_set.max(b);
            total += b as u64;
        }
        for _ in 0..k - 1 {
            let ht = net.hop_time(max_set);
            hop_log.push(HopStat { phase: "forward", bytes: total, time: ht });
            ex.time += ht;
            ex.hops += 1;
        }
        for row in pre.iter() {
            for (j, m) in row.iter().enumerate() {
                ex.wire.record_fanout(m.len(), segs[j].1, k - 1);
            }
        }

        // Local reduction in worker order — the all-to-all accumulation
        // order, hence the bit-identity property.
        mean.clear();
        mean.resize(n, 0.0);
        for row in pre.iter() {
            for (j, m) in row.iter().enumerate() {
                let (off, len) = segs[j];
                codec.decode_add(m, alpha, &mut mean[off..off + len])?;
            }
        }
        ex.encode_coords = n;
        ex.decode_coords = k * n;
        Ok(ex)
    }
}

impl CollectiveAlgo for RingAllreduce {
    fn name(&self) -> String {
        let mode = match (self.recompress, self.error_feedback) {
            (true, true) => "ring+ef",
            (true, false) => "ring",
            (false, _) => "ring:raw",
        };
        format!("{mode} over {}", self.codec.name())
    }

    fn prepare(&mut self, n: usize) {
        self.ensure_layout(n);
        let hint = self
            .segs
            .iter()
            .map(|&(_, len)| self.codec.encoded_size_hint(len))
            .max()
            .unwrap_or(0);
        for buf in self.inflight.iter_mut().chain(&mut self.next).chain(&mut self.finals) {
            if buf.capacity() < hint {
                buf.reserve(hint - buf.len());
            }
        }
    }

    fn exchange(
        &mut self,
        net: &SimNet,
        grads: &[Vec<f32>],
        mean: &mut Vec<f32>,
    ) -> Result<Exchange> {
        let k = self.sessions.len();
        assert_eq!(grads.len(), k, "gradient count != workers");
        assert_eq!(net.workers, k, "net sized for a different worker count");
        anyhow::ensure!(
            self.codec.supports_chunked_encode(),
            "{} sessions cannot encode ring segments (stateful fixed layout) — \
             use the all-to-all collective for this codec",
            self.codec.name()
        );
        let n = grads.first().map(Vec::len).unwrap_or(0);
        assert!(grads.iter().all(|g| g.len() == n), "equal gradient sizes required");
        self.ensure_layout(n);
        self.hop_log.clear();
        let alpha = self.alpha.unwrap_or(1.0 / k as f32);

        if k == 1 {
            // degenerate ring: own gradient through one encode/decode
            let Self { codec, sessions, finals, staging, dec, residual, .. } = self;
            let res = residual.first_mut().map(|r| &mut r[..]);
            encode_lane(
                codec.as_ref(),
                sessions[0].as_mut(),
                res,
                staging,
                dec,
                &grads[0],
                &mut finals[0],
                None,
            )?;
            mean.clear();
            mean.resize(n, 0.0);
            codec.decode_add(&finals[0], alpha, mean)?;
            return Ok(Exchange { encode_coords: n, decode_coords: n, ..Exchange::default() });
        }
        if self.recompress {
            self.run_recompress(net, grads, mean, alpha)
        } else {
            self.run_raw(net, grads, mean, alpha)
        }
    }

    fn hop_stats(&self) -> &[HopStat] {
        &self.hop_log
    }

    fn bytes_per_worker(&self, k: usize, msg_bytes: usize) -> f64 {
        let spec = CollectiveSpec::Ring {
            recompress: self.recompress,
            error_feedback: self.error_feedback,
        };
        model_bytes_per_worker(&spec, k, msg_bytes)
    }

    fn model_time(&self, net: &SimNet, msg_bytes: usize) -> VTime {
        let spec = CollectiveSpec::Ring {
            recompress: self.recompress,
            error_feedback: self.error_feedback,
        };
        model_exchange_time(&spec, net, msg_bytes)
    }
}

// ---------------------------------------------------------------------------
// Hierarchical two-level reduce
// ---------------------------------------------------------------------------

/// Two-level reduce over a declarative group structure (the paper's
/// multi-GPU-per-node testbed): members encode full gradients and fan in to
/// their group leader (the first rank listed in each group), leaders sum
/// and ring-allreduce the group sums (with per-hop recompression), then the
/// final frames fan out verbatim — every worker in every group decodes one
/// global set of bytes. [`GroupSpec::Contiguous`] reproduces the old flat
/// `hier:G` knob bit-for-bit; [`GroupSpec::Explicit`] describes arbitrary
/// (e.g. rack-aware) memberships.
pub struct Hierarchical {
    codec: Arc<dyn Codec>,
    spec: GroupSpec,
    /// Resolved member lists; `groups[gi][0]` is group `gi`'s leader.
    groups: Vec<Vec<usize>>,
    workers: usize,
    sessions: Vec<Box<dyn EncodeSession>>,
    ring: RingAllreduce,
    msgs: Vec<Vec<u8>>,
    sums: Vec<Vec<f32>>,
    hop_log: Vec<HopStat>,
}

impl Hierarchical {
    /// Contiguous groups of `group` workers — the legacy flat-knob shape.
    pub fn new(codec: Arc<dyn Codec>, workers: usize, seed: u64, group: usize) -> Self {
        assert!(group >= 1);
        Self::new_with_groups(codec, workers, seed, &GroupSpec::Contiguous(group))
            .expect("contiguous groups are always resolvable")
    }

    /// Build from a declarative [`GroupSpec`]; errors when the spec does not
    /// cover `workers` ranks exactly once.
    pub fn new_with_groups(
        codec: Arc<dyn Codec>,
        workers: usize,
        seed: u64,
        spec: &GroupSpec,
    ) -> Result<Self> {
        assert!(workers >= 1);
        let groups = spec.resolve(workers)?;
        let leaders = groups.len();
        let sessions: Vec<Box<dyn EncodeSession>> = (0..workers)
            .map(|w| codec.session(Xoshiro256::stream(seed, w as u64)))
            .collect();
        // leader-ring sessions fork off a distinct stream family
        let ring =
            RingAllreduce::new(codec.clone(), leaders, seed ^ 0x9E3779B97F4A7C15, true, false);
        Ok(Self {
            codec,
            spec: spec.clone(),
            groups,
            workers,
            sessions,
            ring,
            msgs: (0..workers).map(|_| Vec::new()).collect(),
            sums: Vec::new(),
            hop_log: Vec::new(),
        })
    }

    fn leaders(&self) -> usize {
        self.groups.len()
    }
}

impl CollectiveAlgo for Hierarchical {
    fn name(&self) -> String {
        format!("hier:{} over {}", self.spec.label_body(), self.codec.name())
    }

    fn prepare(&mut self, n: usize) {
        let cap = self.codec.encoded_size_hint(n);
        for m in &mut self.msgs {
            if m.capacity() < cap {
                m.reserve(cap - m.len());
            }
        }
        self.ring.prepare(n);
    }

    fn exchange(
        &mut self,
        net: &SimNet,
        grads: &[Vec<f32>],
        mean: &mut Vec<f32>,
    ) -> Result<Exchange> {
        let k = self.workers;
        assert_eq!(grads.len(), k, "gradient count != workers");
        assert_eq!(net.workers, k, "net sized for a different worker count");
        anyhow::ensure!(
            self.codec.supports_chunked_encode(),
            "{} sessions cannot re-encode leader-ring segments (stateful fixed layout) — \
             use the all-to-all collective for this codec",
            self.codec.name()
        );
        let n = grads.first().map(Vec::len).unwrap_or(0);
        assert!(grads.iter().all(|g| g.len() == n), "equal gradient sizes required");
        let leaders = self.leaders();
        self.hop_log.clear();
        let mut ex = Exchange::default();

        // Phase 1 — every worker encodes its full gradient (the leader's
        // own message never crosses a link but still passes through
        // encode/decode, as in Algorithm 1); members fan in to the leader.
        par_encode_into(&mut self.sessions, &mut self.msgs, grads);

        let mut fan_in = VTime::ZERO;
        let mut fan_in_bytes: u64 = 0;
        for members in &self.groups {
            let mut bytes = 0usize;
            for &w in &members[1..] {
                let m = &self.msgs[w];
                ex.wire.record(m.len(), n);
                bytes += m.len();
            }
            if members.len() > 1 {
                fan_in = fan_in.max(net.fan_in_time(bytes));
            }
            fan_in_bytes += bytes as u64;
        }
        if leaders < k {
            self.hop_log.push(HopStat { phase: "fan-in", bytes: fan_in_bytes, time: fan_in });
            ex.time += fan_in;
            ex.hops += 1;
        }

        // Leaders sum their group's decoded messages (listed member order —
        // ascending rank order for contiguous groups).
        if self.sums.len() != leaders {
            self.sums = (0..leaders).map(|_| Vec::new()).collect();
        }
        for gi in 0..leaders {
            let sum = &mut self.sums[gi];
            sum.clear();
            sum.resize(n, 0.0);
            for &w in &self.groups[gi] {
                self.codec.decode_add(&self.msgs[w], 1.0, sum)?;
            }
        }

        // Phase 2 — recompressing ring across the leaders; the final decode
        // already averages over the *global* worker count. Scenario state
        // carries over: the fault schedule continues on the leader ring, and
        // a leader rank's link override follows it to its ring position.
        self.ring.alpha = Some(1.0 / k as f32);
        let mut leader_net = SimNet::new(leaders, net.link, net.topology);
        leader_net.faults = net.faults.clone();
        for &(w, link) in &net.overrides {
            if let Some(gi) = self.groups.iter().position(|g| g[0] == w) {
                leader_net = leader_net.with_link_override(gi, link);
            }
        }
        let re = self.ring.exchange(&leader_net, &self.sums, mean)?;
        ex.time += re.time;
        ex.hops += re.hops;
        ex.wire.add(&re.wire);
        ex.recompressions += re.recompressions;
        ex.recompress_err_sq += re.recompress_err_sq;
        for h in self.ring.hop_stats() {
            self.hop_log.push(*h);
        }

        // Phase 3 — leaders fan the final frames out to their members,
        // verbatim: one global byte set, so every replica decodes identical
        // values (already materialised in `mean` by the ring).
        let final_bytes: usize = self.ring.final_frames().iter().map(Vec::len).sum();
        let mut fan_out = VTime::ZERO;
        let mut copies_total = 0usize;
        for members in &self.groups {
            let size = members.len();
            if size > 1 {
                fan_out = fan_out.max(net.fan_out_time(final_bytes, size - 1));
                copies_total += size - 1;
            }
        }
        if copies_total > 0 {
            for (j, f) in self.ring.final_frames().iter().enumerate() {
                let seg_len = self.ring.segments()[j].1;
                ex.wire.record_fanout(f.len(), seg_len, copies_total);
            }
            self.hop_log.push(HopStat {
                phase: "fan-out",
                bytes: (final_bytes * copies_total) as u64,
                time: fan_out,
            });
            ex.time += fan_out;
            ex.hops += 1;
        }

        // Leaders encode their own message plus the ring's shares; members
        // decode the same final frames the leaders do. The widest group's
        // leader decodes the most.
        let widest = self.groups.iter().map(Vec::len).max().unwrap_or(1);
        ex.encode_coords = n + re.encode_coords;
        ex.decode_coords = widest * n + re.decode_coords;
        ex.faults.add(&re.faults);
        Ok(ex)
    }

    fn hop_stats(&self) -> &[HopStat] {
        &self.hop_log
    }

    fn bytes_per_worker(&self, k: usize, msg_bytes: usize) -> f64 {
        let spec = CollectiveSpec::Hierarchical { groups: self.spec.clone() };
        model_bytes_per_worker(&spec, k, msg_bytes)
    }

    fn model_time(&self, net: &SimNet, msg_bytes: usize) -> VTime {
        let spec = CollectiveSpec::Hierarchical { groups: self.spec.clone() };
        model_exchange_time(&spec, net, msg_bytes)
    }
}
