//! Synchronous data-parallel SGD with gradient compression — Algorithm 1
//! with the §5 protocol.
//!
//! Per iteration, for each of K (simulated) workers: obtain a stochastic
//! gradient, Encode (quantize + entropy-code under the model's QuantPlan),
//! all-broadcast the messages over the simulated interconnect, Decode all K
//! messages, average, and step. Virtual time charges compute (FLOPs model),
//! encode/decode (coordinate-throughput model), and transfer (α–β link
//! model); with `double_buffer` the per-step total is
//! `max(compute, communication)` as in the paper's overlapped pipeline.
//!
//! Gradient *computation* is time-multiplexed on the driver thread (PJRT
//! handles are !Send); cluster parallelism is accounted in *virtual* time.
//! The whole encode → exchange → decode pipeline is delegated to a
//! pluggable [`CollectiveAlgo`](crate::collectives::CollectiveAlgo)
//! selected by [`SyncConfig::collective`]:
//!
//! * [`CollectiveSpec::AllToAll`] (the default) reproduces Algorithm 1
//!   exactly as before the subsystem existed — K parallel per-worker
//!   [`crate::quant::EncodeSession`] jobs, one broadcast, the grouped
//!   parallel decode-mean through one shared [`PlanCodec`] — byte- and
//!   bit-identical for the same seeds.
//! * `Ring` / `Hierarchical` run the segmented algorithms over the *plain*
//!   spec codec (bucket-aligned segments; the [`QuantPlan`] skip rule
//!   applies to the all-to-all path only, where whole-model messages
//!   exist), re-encoding partial sums at aggregation hops.
//!
//! Every algorithm yields the same mean bits on every replica, so the
//! per-step parameter-consistency checks hold unchanged.

use std::sync::Arc;

use anyhow::Result;

use super::exchange::PlanCodec;
use super::sources::GradSource;
use super::CompressorSpec;
use crate::collectives;
use crate::config::{CollectiveSpec, ScenarioSpec};
use crate::metrics::{Breakdown, Curve, FaultStats, WallClock, WireStats};
use crate::models::layout::QuantPlan;
use crate::models::CostModel;
use crate::optim::Sgd;
use crate::quant::Codec;
use crate::simnet::{SimNet, VTime};
use crate::util::rng::{self, Xoshiro256};

/// Configuration of one synchronous training run.
pub struct SyncConfig {
    pub workers: usize,
    pub steps: usize,
    pub compressor: CompressorSpec,
    /// Which collective algorithm moves the encoded gradients (all-to-all
    /// broadcast, recompressing ring, hierarchical two-level reduce).
    pub collective: CollectiveSpec,
    /// Quantization plan (tensor-aware skip rule); `None` ⇒ quantize all.
    /// Applies to the all-to-all path; the segmented collectives run the
    /// plain spec codec over bucket-aligned segments.
    pub plan: Option<QuantPlan>,
    pub lr: f32,
    pub momentum: f32,
    pub seed: u64,
    /// §5 double buffering: overlap communication with the next step's compute.
    pub double_buffer: bool,
    /// Record loss every `log_every` steps.
    pub log_every: usize,
    /// Evaluate held-out metric every `eval_every` steps (0 = never).
    pub eval_every: usize,
    pub net: SimNet,
    /// Fault-injection scenario (`--scenario`): shapes the interconnect
    /// (hetero links, seeded stragglers, corruption retransmits) and/or the
    /// per-step participation schedule (`drop`, `partial`). `(scenario,
    /// seed)` pins the whole faulted trace, so every scenario has a
    /// determinism golden.
    pub scenario: ScenarioSpec,
    pub cost: CostModel,
    /// Initial parameter scale (gaussian init · scale).
    pub init_scale: f32,
    /// Verify all workers hold bit-identical parameters every N steps.
    pub consistency_every: usize,
}

impl SyncConfig {
    pub fn quick(workers: usize, steps: usize, compressor: CompressorSpec, lr: f32) -> Self {
        Self {
            workers,
            steps,
            compressor,
            collective: CollectiveSpec::AllToAll,
            plan: None,
            lr,
            momentum: 0.0,
            seed: 0,
            double_buffer: true,
            log_every: 10,
            eval_every: 0,
            net: SimNet::preset(workers, crate::simnet::Preset::K80Pcie),
            scenario: ScenarioSpec::None,
            cost: CostModel::k80(),
            init_scale: 0.1,
            consistency_every: 50,
        }
    }
}

/// Outcome of a run.
pub struct RunResult {
    pub loss: Curve,
    pub eval: Curve,
    pub breakdown: Breakdown,
    pub wire: WireStats,
    pub params: Vec<f32>,
    pub label: String,
    /// Which collective moved the bytes (`a2a`, `ring`, `ring:ef`, …).
    pub collective: String,
    /// Synchronous hops charged over the whole run.
    pub hops: usize,
    /// Partial-sum re-encode events over the whole run (0 for all-to-all).
    pub recompressions: u64,
    /// Cumulative recompression quantization error over the run
    /// (Σ‖decode(e) − encoded input‖² across all partial-sum re-encodes).
    /// `ring:ef` does not shrink this per-step number — its residual makes
    /// the errors telescope so the *bias* cancels across steps.
    pub recompress_err_sq: f64,
    /// Measured wall-clock per-phase seconds, populated only by the socket
    /// transport (`--transport tcp:…|uds:…`); all-zero on simnet runs.
    pub wall: WallClock,
    /// Fault and recovery events over the whole run: scenario-injected
    /// faults on simnet runs, observed faults plus recovery activity on
    /// socket runs. All-zero under `--scenario none` without recovery.
    pub faults: FaultStats,
}

impl RunResult {
    /// Virtual epoch/run time under the configured pipeline mode.
    pub fn virtual_time(&self, double_buffer: bool) -> VTime {
        if double_buffer {
            self.breakdown.total_double_buffered()
        } else {
            self.breakdown.total()
        }
    }

    /// Schedule-derived virtual run time under §5-style per-layer overlap:
    /// `schedule` is the model layout's transmission schedule
    /// ([`crate::models::layout::ParamLayout::overlap_schedule`]) and
    /// `fraction` the overlap knob φ ∈ [0, 1]
    /// ([`Breakdown::total_overlapped`]). φ = 0 equals
    /// `virtual_time(false)` exactly; φ = 1 is at or above the
    /// whole-step `virtual_time(true)` bound (that bound ignores intra-step
    /// readiness ordering).
    pub fn virtual_time_overlapped(&self, schedule: &[(f64, f64)], fraction: f64) -> VTime {
        self.breakdown.total_overlapped(schedule, fraction)
    }
}

/// One simulated worker's state. Encode sessions (and any error-feedback
/// residuals) live inside the collective algorithm; decoding shares one
/// codec across all replicas.
struct Worker {
    params: Vec<f32>,
    opt: Sgd,
}

/// The synchronous trainer.
pub struct SyncTrainer {
    pub cfg: SyncConfig,
}

impl SyncTrainer {
    pub fn new(cfg: SyncConfig) -> Self {
        Self { cfg }
    }

    pub fn run(&mut self, source: &mut dyn GradSource) -> Result<RunResult> {
        let cfg = &self.cfg;
        let n = source.dim();
        let plan = cfg
            .plan
            .clone()
            .unwrap_or_else(|| QuantPlan::build(&one_tensor_layout(n), 0));
        anyhow::ensure!(plan.total_len() == n, "plan does not cover the gradient");
        // A plan with skip segments only has meaning on the all-to-all path
        // (whole-model messages). Refuse loudly rather than silently
        // quantizing tensors the caller asked to keep full-precision.
        if !matches!(cfg.collective, CollectiveSpec::AllToAll) {
            if let Some(p) = &cfg.plan {
                anyhow::ensure!(
                    p.quantized_fraction() >= 1.0 - 1e-9,
                    "the QuantPlan skip rule is honoured by the all-to-all collective only; \
                     '{}' would quantize the skip segments — use a2a or drop the plan",
                    cfg.collective.label()
                );
            }
        }

        // One shared codec (decode side, `&self` only) serves every worker;
        // per-worker encode sessions (seeded `(seed ^ 0xF00D, w)` streams,
        // exactly as the pre-subsystem trainer seeded them) live inside the
        // collective algorithm, so parallel encode stays bit-identical to a
        // sequential worker loop. The all-to-all arm honours the QuantPlan
        // through the [`PlanCodec`]; the segmented arms run the plain spec
        // codec over bucket-aligned segments.
        let codec: Arc<dyn Codec> = match cfg.collective {
            CollectiveSpec::AllToAll => Arc::new(PlanCodec::from_spec(plan, &cfg.compressor)),
            _ => cfg.compressor.codec(),
        };
        let mut algo = collectives::build_with_scenario(
            &cfg.collective,
            &cfg.scenario,
            codec,
            cfg.workers,
            cfg.seed ^ 0xF00D,
        )?;
        algo.prepare(n);
        // Scenario-shaped interconnect: link overrides and the seeded fault
        // schedule live on this local copy; `cfg.net` stays pristine.
        let net = cfg.scenario.apply_simnet(cfg.net.clone(), cfg.seed);

        // Identical init on every worker (same seed), per-worker RNG streams
        // for quantization randomness.
        let mut init_rng = Xoshiro256::stream(cfg.seed, 0x1417);
        let init: Vec<f32> = rng::normal_vec(&mut init_rng, n)
            .into_iter()
            .map(|x| x * cfg.init_scale)
            .collect();
        let mut workers: Vec<Worker> = (0..cfg.workers)
            .map(|_| Worker {
                params: init.clone(),
                opt: Sgd::new(
                    crate::optim::LrSchedule::Const(cfg.lr),
                    cfg.momentum,
                    0.0,
                    n,
                ),
            })
            .collect();

        let mut loss_curve = Curve::default();
        let mut eval_curve = Curve::default();
        let mut breakdown = Breakdown::default();
        let mut wire = WireStats::default();
        let mut mean_grad: Vec<f32> = Vec::new();
        let mut hops = 0usize;
        let mut recompressions = 0u64;
        let mut recompress_err_sq = 0.0f64;
        let mut faults = FaultStats::default();

        for step in 0..cfg.steps {
            crate::obs::set_step(step as u64);
            let _step_span = crate::obs_span!("sim.step");
            // 1. local gradients (virtual: all workers compute in parallel)
            let mut grads = Vec::with_capacity(cfg.workers);
            let mut mean_loss = 0.0f64;
            for w in 0..cfg.workers {
                let (loss, grad) = source.loss_and_grad(w, step as u64, &workers[w].params)?;
                mean_loss += loss as f64 / cfg.workers as f64;
                grads.push(grad);
            }
            breakdown.compute += VTime(cfg.cost.step_compute_s(source.flops_fwd_per_step(), 1));

            // 2.–4. encode → exchange → decode through the collective
            // algorithm: real wire bytes move (reused per-worker buffers,
            // per-session RNG streams), per-hop α–β time is charged, and
            // the mean comes back bit-identical on every replica at any
            // thread budget.
            let x = algo.exchange(&net, &grads, &mut mean_grad)?;
            wire.add(&x.wire);
            faults.add(&x.faults);
            hops += x.hops;
            recompressions += x.recompressions;
            recompress_err_sq += x.recompress_err_sq;
            breakdown.encode += VTime(cfg.cost.encode_s(x.encode_coords));
            breakdown.transfer += x.time;
            breakdown.decode += VTime(cfg.cost.decode_s(x.decode_coords, 1));

            // 5. apply identical update on every worker
            for w in workers.iter_mut() {
                w.opt.apply(&mut w.params, &mean_grad);
            }
            breakdown.steps += 1;

            if step % cfg.log_every.max(1) == 0 || step + 1 == cfg.steps {
                loss_curve.push(step, mean_loss);
            }
            if cfg.eval_every > 0 && (step % cfg.eval_every == 0 || step + 1 == cfg.steps) {
                if let Some(m) = source.eval(&workers[0].params) {
                    eval_curve.push(step, m);
                }
            }
            if cfg.consistency_every > 0 && step % cfg.consistency_every == 0 {
                assert_consistent(&workers);
            }
        }
        assert_consistent(&workers);
        let (straggled, corrupted) = net.fault_counts();
        faults.straggler_hops += straggled;
        faults.corrupt_frames += corrupted;

        Ok(RunResult {
            loss: loss_curve,
            eval: eval_curve,
            breakdown,
            wire,
            params: workers.swap_remove(0).params,
            label: cfg.compressor.label(),
            collective: cfg.collective.label(),
            hops,
            recompressions,
            recompress_err_sq,
            wall: WallClock::default(),
            faults,
        })
    }
}

fn one_tensor_layout(n: usize) -> crate::models::layout::ParamLayout {
    crate::models::layout::ParamLayout::synthetic(&[("flat", vec![n])])
}

/// All replicas must hold bit-identical parameters (synchronous SGD with
/// deterministic aggregation — the paper's Algorithm 1 invariant).
fn assert_consistent(workers: &[Worker]) {
    if workers.len() < 2 {
        return;
    }
    let first = &workers[0].params;
    assert!(
        first.iter().all(|p| p.is_finite()),
        "parameters went non-finite (learning rate above 1/L?)"
    );
    for (i, w) in workers.iter().enumerate().skip(1) {
        assert!(
            w.params == *first,
            "worker {i} diverged from worker 0 — synchronous invariant broken"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::sources::ConvexSource;
    use crate::data::QuadraticProblem;

    fn run_with(spec: CompressorSpec, steps: usize, lr: f32) -> RunResult {
        let p = QuadraticProblem::generate(256, 128, 1e-3, 0.05, 7);
        let mut src = ConvexSource::new(p, 8, 3);
        let mut cfg = SyncConfig::quick(4, steps, spec, lr);
        cfg.eval_every = 10;
        SyncTrainer::new(cfg).run(&mut src).unwrap()
    }

    #[test]
    fn fp32_converges() {
        let r = run_with(CompressorSpec::Fp32, 150, 0.05);
        let first = r.loss.points[0].1;
        let last = r.loss.tail_mean(3);
        assert!(last < first * 0.2, "{first} -> {last}");
        // fp32 messages carry only the small segment-framing overhead
        let ratio = r.wire.compression_ratio();
        assert!(ratio > 0.95 && ratio <= 1.0, "ratio {ratio}");
    }

    #[test]
    fn qsgd_converges_with_compression() {
        let r = run_with(CompressorSpec::qsgd_4bit(), 150, 0.05);
        let first = r.loss.points[0].1;
        let last = r.loss.tail_mean(3);
        assert!(last < first * 0.3, "{first} -> {last}");
        assert!(r.wire.compression_ratio() > 4.0, "ratio {}", r.wire.compression_ratio());
        // bytes on the wire must be far below fp32's (at this tiny model
        // size transfer *time* is latency-dominated; the time comparison at
        // real model sizes is the fig2_breakdown bench's job)
        let fp = run_with(CompressorSpec::Fp32, 20, 0.05);
        let q = run_with(CompressorSpec::qsgd_4bit(), 20, 0.05);
        assert!(q.wire.payload_bytes * 4 < fp.wire.payload_bytes);
    }

    #[test]
    fn nuqsgd_converges_and_compresses() {
        // Non-uniform grid end-to-end through Algorithm 1: converges at the
        // same bit budget as 4-bit QSGD and still compresses well below fp32.
        let r = run_with(CompressorSpec::nuqsgd_4bit(), 150, 0.05);
        let first = r.loss.points[0].1;
        let last = r.loss.tail_mean(3);
        // slightly looser floor than the uniform arm: the exponential grid's
        // coarse top segment raises worst-case per-coordinate noise
        assert!(last < first * 0.45, "{first} -> {last}");
        let fp = run_with(CompressorSpec::Fp32, 20, 0.05);
        let nu = run_with(CompressorSpec::nuqsgd_4bit(), 20, 0.05);
        assert!(nu.wire.payload_bytes * 2 < fp.wire.payload_bytes);
    }

    #[test]
    fn onebit_and_terngrad_converge() {
        for spec in [CompressorSpec::OneBit { column: 32 }, CompressorSpec::TernGrad { bucket: 32 }] {
            let r = run_with(spec.clone(), 200, 0.03);
            let first = r.loss.points[0].1;
            let last = r.loss.tail_mean(3);
            assert!(last < first * 0.5, "{}: {first} -> {last}", spec.label());
        }
    }

    #[test]
    fn segmented_collectives_converge_and_stay_consistent() {
        // Ring (with and without error feedback) and hierarchical reduce
        // through the full trainer: loss falls, the replica-consistency
        // invariant holds (checked inside run), and the recompression
        // telemetry is populated.
        for col in [
            CollectiveSpec::ring(),
            CollectiveSpec::ring_ef(),
            CollectiveSpec::hierarchical(2),
        ] {
            let p = QuadraticProblem::generate(256, 128, 1e-3, 0.05, 7);
            let mut src = ConvexSource::new(p, 8, 3);
            let mut cfg = SyncConfig::quick(4, 150, CompressorSpec::qsgd_4bit(), 0.05);
            cfg.collective = col.clone();
            let r = SyncTrainer::new(cfg).run(&mut src).unwrap();
            let first = r.loss.points[0].1;
            let last = r.loss.tail_mean(3);
            assert!(last < first * 0.5, "{}: {first} -> {last}", col.label());
            assert!(r.hops > 0, "{}", col.label());
            assert!(r.recompressions > 0, "{}", col.label());
            assert!(r.recompress_err_sq > 0.0, "{}", col.label());
            assert_eq!(r.collective, col.label());
        }
    }

    #[test]
    fn ring_moves_fewer_wire_bytes_than_all_to_all() {
        // The bandwidth argument end-to-end: same compressor, same steps,
        // ring traffic (2(K−1)·|msg| cluster-wide) far below all-to-all
        // (K(K−1)·|msg|) at K=8.
        let run = |col: CollectiveSpec| {
            let p = QuadraticProblem::generate(256, 128, 1e-3, 0.05, 7);
            let mut src = ConvexSource::new(p, 8, 3);
            let mut cfg = SyncConfig::quick(8, 10, CompressorSpec::qsgd_4bit(), 0.05);
            cfg.collective = col;
            SyncTrainer::new(cfg).run(&mut src).unwrap()
        };
        let a2a = run(CollectiveSpec::AllToAll);
        let ring = run(CollectiveSpec::ring());
        assert!(
            ring.wire.payload_bytes * 2 < a2a.wire.payload_bytes,
            "ring {} vs a2a {}",
            ring.wire.payload_bytes,
            a2a.wire.payload_bytes
        );
        // a2a reports no recompression
        assert_eq!(a2a.recompressions, 0);
        assert_eq!(a2a.recompress_err_sq, 0.0);
    }

    #[test]
    fn segmented_collectives_reject_skip_plans_and_fixed_layout_codecs() {
        use crate::models::layout::ParamLayout;
        // skip-bearing plan + ring ⇒ loud error, not silent quantization of
        // the segments the caller asked to keep full-precision
        let p = QuadraticProblem::generate(256, 128, 1e-3, 0.05, 7);
        let mut src = ConvexSource::new(p, 8, 3);
        let layout = ParamLayout::synthetic(&[("a", vec![100]), ("b", vec![156])]);
        let plan = QuantPlan::build(&layout, 128); // "a" (100 < 128) skipped
        assert!(plan.quantized_fraction() < 1.0);
        let mut cfg = SyncConfig::quick(4, 5, CompressorSpec::qsgd_4bit(), 0.05);
        cfg.plan = Some(plan);
        cfg.collective = CollectiveSpec::ring();
        assert!(SyncTrainer::new(cfg).run(&mut src).is_err());
        // 1BitSGD's session pins one gradient layout ⇒ segmented
        // collectives refuse up front instead of panicking mid-hop
        let p2 = QuadraticProblem::generate(256, 128, 1e-3, 0.05, 7);
        let mut src2 = ConvexSource::new(p2, 8, 3);
        let mut cfg2 = SyncConfig::quick(4, 5, CompressorSpec::OneBit { column: 32 }, 0.05);
        cfg2.collective = CollectiveSpec::ring();
        let err = SyncTrainer::new(cfg2).run(&mut src2).unwrap_err();
        assert!(err.to_string().contains("all-to-all"), "{err:#}");
    }

    #[test]
    fn fault_scenarios_renormalize_and_stay_deterministic() {
        use crate::config::ScenarioSpec;
        let run = |scenario: &str| {
            let p = QuadraticProblem::generate(256, 128, 1e-3, 0.05, 7);
            let mut src = ConvexSource::new(p, 8, 3);
            let mut cfg = SyncConfig::quick(4, 40, CompressorSpec::qsgd_4bit(), 0.05);
            cfg.scenario = ScenarioSpec::parse(scenario).unwrap();
            SyncTrainer::new(cfg).run(&mut src).unwrap()
        };
        let clean = run("none");
        assert_eq!(clean.faults, FaultStats::default());

        // Partial participation: every step renormalizes over 3 of 4
        // workers, the trace is seed-pinned, and the skipped contributions
        // actually change the trajectory.
        let a = run("partial:3");
        let b = run("partial:3");
        assert_eq!(a.params, b.params, "partial schedule must be deterministic");
        assert_eq!(a.faults.renormalized_steps, 40);
        assert_eq!(a.faults.dead_workers, 40);
        assert!(a.params != clean.params, "partial must alter the trajectory");
        let first = a.loss.points[0].1;
        assert!(a.loss.tail_mean(3) < first, "loss must still fall");

        // Drop: rank 1 leaves at step 10 and stays gone.
        let d = run("drop:1@10");
        assert_eq!(d.faults.renormalized_steps, 30);

        // Straggler/corrupt/hetero shape virtual time only — wire bytes and
        // the decoded means stay bit-identical to the clean run.
        let s1 = run("straggler:0.5:5.0");
        let s2 = run("straggler:0.5:5.0");
        assert_eq!(s1.params, clean.params);
        assert!(s1.faults.straggler_hops > 0);
        assert!(s1.breakdown.transfer.secs() > clean.breakdown.transfer.secs());
        assert_eq!(
            s1.breakdown.transfer.secs().to_bits(),
            s2.breakdown.transfer.secs().to_bits(),
            "straggler schedule must pin the virtual-time trace"
        );
        let c = run("corrupt:0.5");
        assert_eq!(c.params, clean.params);
        assert!(c.faults.corrupt_frames > 0);
        assert!(c.breakdown.transfer.secs() > clean.breakdown.transfer.secs());
        let h = run("hetero:4.0");
        assert_eq!(h.params, clean.params);
        assert!(h.breakdown.transfer.secs() > clean.breakdown.transfer.secs());
    }

    #[test]
    fn skip_scenarios_require_all_to_all() {
        use crate::config::ScenarioSpec;
        let p = QuadraticProblem::generate(256, 128, 1e-3, 0.05, 7);
        let mut src = ConvexSource::new(p, 8, 3);
        let mut cfg = SyncConfig::quick(4, 5, CompressorSpec::qsgd_4bit(), 0.05);
        cfg.collective = CollectiveSpec::ring();
        cfg.scenario = ScenarioSpec::parse("partial:3").unwrap();
        let err = SyncTrainer::new(cfg).run(&mut src).unwrap_err();
        assert!(err.to_string().contains("all-to-all"), "{err:#}");
    }

    #[test]
    fn deterministic_given_seed() {
        let a = run_with(CompressorSpec::qsgd_2bit(), 30, 0.05);
        let b = run_with(CompressorSpec::qsgd_2bit(), 30, 0.05);
        assert_eq!(a.params, b.params);
        assert_eq!(a.wire.payload_bytes, b.wire.payload_bytes);
    }

    #[test]
    fn more_workers_lower_variance() {
        // K-worker averaging reduces gradient noise ⇒ for the same step
        // count and lr, terminal loss should not be (much) worse.
        let p = QuadraticProblem::generate(256, 128, 1e-3, 0.5, 9);
        let mut src = ConvexSource::new(p, 2, 5);
        let r1 = SyncTrainer::new(SyncConfig::quick(1, 120, CompressorSpec::qsgd_4bit(), 0.04))
            .run(&mut src)
            .unwrap();
        let p2 = QuadraticProblem::generate(256, 128, 1e-3, 0.5, 9);
        let mut src2 = ConvexSource::new(p2, 2, 5);
        let r8 = SyncTrainer::new(SyncConfig::quick(8, 120, CompressorSpec::qsgd_4bit(), 0.04))
            .run(&mut src2)
            .unwrap();
        assert!(r8.loss.tail_mean(3) <= r1.loss.tail_mean(3) * 1.2);
    }

    #[test]
    fn breakdown_populated() {
        let r = run_with(CompressorSpec::qsgd_4bit(), 10, 0.05);
        assert!(r.breakdown.compute.secs() > 0.0);
        assert!(r.breakdown.encode.secs() > 0.0);
        assert!(r.breakdown.transfer.secs() > 0.0);
        assert!(r.breakdown.decode.secs() > 0.0);
        assert_eq!(r.breakdown.steps, 10);
        assert!(r.virtual_time(true).secs() <= r.virtual_time(false).secs());
    }
}
