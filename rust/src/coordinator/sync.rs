//! Synchronous data-parallel SGD with gradient compression — Algorithm 1
//! with the §5 protocol.
//!
//! Per iteration, for each of K (simulated) workers: obtain a stochastic
//! gradient, Encode (quantize + entropy-code under the model's QuantPlan),
//! all-broadcast the messages over the simulated interconnect, Decode all K
//! messages, average, and step. Virtual time charges compute (FLOPs model),
//! encode/decode (coordinate-throughput model), and transfer (α–β link
//! model); with `double_buffer` the per-step total is
//! `max(compute, communication)` as in the paper's overlapped pipeline.
//!
//! Gradient *computation* is time-multiplexed on the driver thread (PJRT
//! handles are !Send); cluster parallelism is accounted in *virtual* time.
//! The K Encode jobs, however, are pure Rust with per-worker
//! [`EncodeSession`] state, so they run concurrently on the scoped pool
//! ([`crate::util::par`]) into per-worker reusable wire buffers —
//! bit-identical bytes to a sequential pass, since each session owns its
//! `Xoshiro256` stream. Because decoding is deterministic, each message is
//! decoded once through the one shared [`PlanCodec`] (concurrently, merged
//! in fixed order — [`crate::collectives::par_decode_mean`]) and the
//! decoded gradient is shared — mathematically identical to every worker
//! decoding its own copy, which per-step parameter-consistency checks
//! enforce.

use std::sync::Arc;

use anyhow::Result;

use super::exchange::PlanCodec;
use super::sources::GradSource;
use super::CompressorSpec;
use crate::collectives;
use crate::metrics::{Breakdown, Curve, WireStats};
use crate::models::layout::QuantPlan;
use crate::models::CostModel;
use crate::optim::Sgd;
use crate::quant::{Codec, EncodeSession};
use crate::simnet::{SimNet, VTime};
use crate::util::par;
use crate::util::rng::{self, Xoshiro256};

/// Configuration of one synchronous training run.
pub struct SyncConfig {
    pub workers: usize,
    pub steps: usize,
    pub compressor: CompressorSpec,
    /// Quantization plan (tensor-aware skip rule); `None` ⇒ quantize all.
    pub plan: Option<QuantPlan>,
    pub lr: f32,
    pub momentum: f32,
    pub seed: u64,
    /// §5 double buffering: overlap communication with the next step's compute.
    pub double_buffer: bool,
    /// Record loss every `log_every` steps.
    pub log_every: usize,
    /// Evaluate held-out metric every `eval_every` steps (0 = never).
    pub eval_every: usize,
    pub net: SimNet,
    pub cost: CostModel,
    /// Initial parameter scale (gaussian init · scale).
    pub init_scale: f32,
    /// Verify all workers hold bit-identical parameters every N steps.
    pub consistency_every: usize,
}

impl SyncConfig {
    pub fn quick(workers: usize, steps: usize, compressor: CompressorSpec, lr: f32) -> Self {
        Self {
            workers,
            steps,
            compressor,
            plan: None,
            lr,
            momentum: 0.0,
            seed: 0,
            double_buffer: true,
            log_every: 10,
            eval_every: 0,
            net: SimNet::preset(workers, crate::simnet::Preset::K80Pcie),
            cost: CostModel::k80(),
            init_scale: 0.1,
            consistency_every: 50,
        }
    }
}

/// Outcome of a run.
pub struct RunResult {
    pub loss: Curve,
    pub eval: Curve,
    pub breakdown: Breakdown,
    pub wire: WireStats,
    pub params: Vec<f32>,
    pub label: String,
}

impl RunResult {
    /// Virtual epoch/run time under the configured pipeline mode.
    pub fn virtual_time(&self, double_buffer: bool) -> VTime {
        if double_buffer {
            self.breakdown.total_double_buffered()
        } else {
            self.breakdown.total()
        }
    }
}

/// One simulated worker's state. The encode session owns the worker's RNG
/// stream and all compression scratch (plus any error-feedback residuals).
/// Decoding needs no per-worker state at all — the trainer shares one
/// [`PlanCodec`] across all replicas.
struct Worker {
    params: Vec<f32>,
    opt: Sgd,
    session: Box<dyn EncodeSession>,
}

/// One worker's encode job for the scoped pool: its session paired with
/// its reusable wire buffer (the buffers live in the trainer so the
/// broadcast can borrow them as one contiguous slice).
struct EncodeJob<'a> {
    session: &'a mut dyn EncodeSession,
    out: &'a mut Vec<u8>,
}

/// The synchronous trainer.
pub struct SyncTrainer {
    pub cfg: SyncConfig,
}

impl SyncTrainer {
    pub fn new(cfg: SyncConfig) -> Self {
        Self { cfg }
    }

    pub fn run(&mut self, source: &mut dyn GradSource) -> Result<RunResult> {
        let cfg = &self.cfg;
        let n = source.dim();
        let plan = cfg
            .plan
            .clone()
            .unwrap_or_else(|| QuantPlan::build(&one_tensor_layout(n), 0));
        anyhow::ensure!(plan.total_len() == n, "plan does not cover the gradient");

        // One shared codec (decode side, `&self` only) serves every worker;
        // each worker gets its own encode session seeded from a per-worker
        // RNG stream, so parallel encode stays bit-identical to a
        // sequential worker loop.
        let codec = Arc::new(PlanCodec::from_spec(plan, &cfg.compressor));
        let msg_cap = codec.encoded_size_hint(n);

        // Identical init on every worker (same seed), per-worker RNG streams
        // for quantization randomness.
        let mut init_rng = Xoshiro256::stream(cfg.seed, 0x1417);
        let init: Vec<f32> = rng::normal_vec(&mut init_rng, n)
            .into_iter()
            .map(|x| x * cfg.init_scale)
            .collect();
        let mut workers: Vec<Worker> = (0..cfg.workers)
            .map(|w| Worker {
                params: init.clone(),
                opt: Sgd::new(
                    crate::optim::LrSchedule::Const(cfg.lr),
                    cfg.momentum,
                    0.0,
                    n,
                ),
                session: codec.session(Xoshiro256::stream(cfg.seed ^ 0xF00D, w as u64)),
            })
            .collect();
        // Per-worker wire buffers, reused every step (sized once from the
        // codec's estimate, so even step one stays off the heap).
        let mut msgs: Vec<Vec<u8>> =
            (0..cfg.workers).map(|_| Vec::with_capacity(msg_cap)).collect();

        let mut loss_curve = Curve::default();
        let mut eval_curve = Curve::default();
        let mut breakdown = Breakdown::default();
        let mut wire = WireStats::default();

        for step in 0..cfg.steps {
            // 1. local gradients (virtual: all workers compute in parallel)
            let mut grads = Vec::with_capacity(cfg.workers);
            let mut mean_loss = 0.0f64;
            for w in 0..cfg.workers {
                let (loss, grad) = source.loss_and_grad(w, step as u64, &workers[w].params)?;
                mean_loss += loss as f64 / cfg.workers as f64;
                grads.push(grad);
            }
            breakdown.compute += VTime(cfg.cost.step_compute_s(source.flops_fwd_per_step(), 1));

            // 2. encode — K independent fused quantize+code jobs on the
            // scoped pool (wall-clock parallelism; virtual time still
            // charges one overlapped encode pass). Per-session RNG streams
            // keep the bytes bit-identical to a sequential loop, and each
            // session encodes into its worker's reusable wire buffer —
            // zero steady-state allocations on the encode path.
            let mut jobs: Vec<EncodeJob> = workers
                .iter_mut()
                .zip(msgs.iter_mut())
                .map(|(w, out)| EncodeJob { session: w.session.as_mut(), out })
                .collect();
            par::par_map_mut(&mut jobs, |w, job| job.session.encode_into(&grads[w], job.out));
            drop(jobs);
            for msg in &msgs {
                wire.record(msg.len(), n);
            }
            breakdown.encode += VTime(cfg.cost.encode_s(n));

            // 3. exchange (messages are borrowed — the broadcast charges
            // virtual transfer time, senders keep their buffers)
            let bc = collectives::all_broadcast(&cfg.net, &msgs);
            breakdown.transfer += bc.time;

            // 4. decode + average (decode each message once; see module doc).
            // Fused decode-into-accumulator — O(nnz) per sparse message —
            // with message groups decoded concurrently, each message's
            // buckets decoded in parallel under the leftover budget of the
            // codec's thread allowance (directory frames), and partials
            // merged in fixed order, so the mean is deterministic at any
            // thread count. One shared codec decodes for all replicas.
            let alpha = 1.0 / cfg.workers as f32;
            let mean_grad = collectives::par_decode_mean(
                bc.messages,
                n,
                alpha,
                codec.decode_threads(),
                |msg, a, acc, t| codec.decode_add_threads(msg, a, acc, t),
            )?;
            breakdown.decode += VTime(cfg.cost.decode_s(n, cfg.workers));

            // 5. apply identical update on every worker
            for w in workers.iter_mut() {
                w.opt.apply(&mut w.params, &mean_grad);
            }
            breakdown.steps += 1;

            if step % cfg.log_every.max(1) == 0 || step + 1 == cfg.steps {
                loss_curve.push(step, mean_loss);
            }
            if cfg.eval_every > 0 && (step % cfg.eval_every == 0 || step + 1 == cfg.steps) {
                if let Some(m) = source.eval(&workers[0].params) {
                    eval_curve.push(step, m);
                }
            }
            if cfg.consistency_every > 0 && step % cfg.consistency_every == 0 {
                assert_consistent(&workers);
            }
        }
        assert_consistent(&workers);

        Ok(RunResult {
            loss: loss_curve,
            eval: eval_curve,
            breakdown,
            wire,
            params: workers.swap_remove(0).params,
            label: cfg.compressor.label(),
        })
    }
}

fn one_tensor_layout(n: usize) -> crate::models::layout::ParamLayout {
    crate::models::layout::ParamLayout::synthetic(&[("flat", vec![n])])
}

/// All replicas must hold bit-identical parameters (synchronous SGD with
/// deterministic aggregation — the paper's Algorithm 1 invariant).
fn assert_consistent(workers: &[Worker]) {
    if workers.len() < 2 {
        return;
    }
    let first = &workers[0].params;
    assert!(
        first.iter().all(|p| p.is_finite()),
        "parameters went non-finite (learning rate above 1/L?)"
    );
    for (i, w) in workers.iter().enumerate().skip(1) {
        assert!(
            w.params == *first,
            "worker {i} diverged from worker 0 — synchronous invariant broken"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::sources::ConvexSource;
    use crate::data::QuadraticProblem;

    fn run_with(spec: CompressorSpec, steps: usize, lr: f32) -> RunResult {
        let p = QuadraticProblem::generate(256, 128, 1e-3, 0.05, 7);
        let mut src = ConvexSource::new(p, 8, 3);
        let mut cfg = SyncConfig::quick(4, steps, spec, lr);
        cfg.eval_every = 10;
        SyncTrainer::new(cfg).run(&mut src).unwrap()
    }

    #[test]
    fn fp32_converges() {
        let r = run_with(CompressorSpec::Fp32, 150, 0.05);
        let first = r.loss.points[0].1;
        let last = r.loss.tail_mean(3);
        assert!(last < first * 0.2, "{first} -> {last}");
        // fp32 messages carry only the small segment-framing overhead
        let ratio = r.wire.compression_ratio();
        assert!(ratio > 0.95 && ratio <= 1.0, "ratio {ratio}");
    }

    #[test]
    fn qsgd_converges_with_compression() {
        let r = run_with(CompressorSpec::qsgd_4bit(), 150, 0.05);
        let first = r.loss.points[0].1;
        let last = r.loss.tail_mean(3);
        assert!(last < first * 0.3, "{first} -> {last}");
        assert!(r.wire.compression_ratio() > 4.0, "ratio {}", r.wire.compression_ratio());
        // bytes on the wire must be far below fp32's (at this tiny model
        // size transfer *time* is latency-dominated; the time comparison at
        // real model sizes is the fig2_breakdown bench's job)
        let fp = run_with(CompressorSpec::Fp32, 20, 0.05);
        let q = run_with(CompressorSpec::qsgd_4bit(), 20, 0.05);
        assert!(q.wire.payload_bytes * 4 < fp.wire.payload_bytes);
    }

    #[test]
    fn nuqsgd_converges_and_compresses() {
        // Non-uniform grid end-to-end through Algorithm 1: converges at the
        // same bit budget as 4-bit QSGD and still compresses well below fp32.
        let r = run_with(CompressorSpec::nuqsgd_4bit(), 150, 0.05);
        let first = r.loss.points[0].1;
        let last = r.loss.tail_mean(3);
        // slightly looser floor than the uniform arm: the exponential grid's
        // coarse top segment raises worst-case per-coordinate noise
        assert!(last < first * 0.45, "{first} -> {last}");
        let fp = run_with(CompressorSpec::Fp32, 20, 0.05);
        let nu = run_with(CompressorSpec::nuqsgd_4bit(), 20, 0.05);
        assert!(nu.wire.payload_bytes * 2 < fp.wire.payload_bytes);
    }

    #[test]
    fn onebit_and_terngrad_converge() {
        for spec in [CompressorSpec::OneBit { column: 32 }, CompressorSpec::TernGrad { bucket: 32 }] {
            let r = run_with(spec.clone(), 200, 0.03);
            let first = r.loss.points[0].1;
            let last = r.loss.tail_mean(3);
            assert!(last < first * 0.5, "{}: {first} -> {last}", spec.label());
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let a = run_with(CompressorSpec::qsgd_2bit(), 30, 0.05);
        let b = run_with(CompressorSpec::qsgd_2bit(), 30, 0.05);
        assert_eq!(a.params, b.params);
        assert_eq!(a.wire.payload_bytes, b.wire.payload_bytes);
    }

    #[test]
    fn more_workers_lower_variance() {
        // K-worker averaging reduces gradient noise ⇒ for the same step
        // count and lr, terminal loss should not be (much) worse.
        let p = QuadraticProblem::generate(256, 128, 1e-3, 0.5, 9);
        let mut src = ConvexSource::new(p, 2, 5);
        let r1 = SyncTrainer::new(SyncConfig::quick(1, 120, CompressorSpec::qsgd_4bit(), 0.04))
            .run(&mut src)
            .unwrap();
        let p2 = QuadraticProblem::generate(256, 128, 1e-3, 0.5, 9);
        let mut src2 = ConvexSource::new(p2, 2, 5);
        let r8 = SyncTrainer::new(SyncConfig::quick(8, 120, CompressorSpec::qsgd_4bit(), 0.04))
            .run(&mut src2)
            .unwrap();
        assert!(r8.loss.tail_mean(3) <= r1.loss.tail_mean(3) * 1.2);
    }

    #[test]
    fn breakdown_populated() {
        let r = run_with(CompressorSpec::qsgd_4bit(), 10, 0.05);
        assert!(r.breakdown.compute.secs() > 0.0);
        assert!(r.breakdown.encode.secs() > 0.0);
        assert!(r.breakdown.transfer.secs() > 0.0);
        assert!(r.breakdown.decode.secs() > 0.0);
        assert_eq!(r.breakdown.steps, 10);
        assert!(r.virtual_time(true).secs() <= r.virtual_time(false).secs());
    }
}
