//! Epoch-time simulator for the paper's evaluation networks (Figure 2 /
//! Table 1 substrate).
//!
//! For a given network shape replica, GPU count and compression arm, this
//! produces the epoch-time breakdown the paper plots: computation from the
//! FLOPs cost model, communication from *measured* encoded message sizes
//! (the real Rust quantize+code pipeline runs on synthetic gradients shaped
//! exactly like the network's tensors) pushed through the α–β interconnect
//! model. The fp32 arm rides the dense transport; compressed arms use the
//! all-to-all broadcast of variable-size messages, as in CNTK's MPI path.

use crate::collectives;
use crate::config::CollectiveSpec;
use crate::coordinator::exchange::PlanCodec;
use crate::coordinator::CompressorSpec;
use crate::metrics::Breakdown;
use crate::models::layout::QuantPlan;
use crate::models::{CostModel, NetworkShape};
use crate::quant::{Codec, EncodeSession, Norm};
use crate::simnet::{SimNet, VTime};
use crate::util::rng::{self, Xoshiro256};

/// One simulated training arm.
#[derive(Debug, Clone)]
pub struct EpochArm {
    pub compressor: CompressorSpec,
    /// Which collective algorithm carries the encoded messages — transfer
    /// time and byte accounting route through its
    /// [`collectives::CollectiveAlgo::model_time`] /
    /// [`collectives::CollectiveAlgo::bytes_per_worker`], so dense-vs-QSGD
    /// crossover points are algorithm-aware.
    pub collective: CollectiveSpec,
    /// Use the dense ring-allreduce transport (only valid for Fp32 — the
    /// entropy-coded messages are variable-length).
    pub dense_transport: bool,
}

impl EpochArm {
    /// The paper's 32-bit baseline: CNTK's MPI gradient exchange (an
    /// all-to-all broadcast of dense buffers — this, not an optimised ring
    /// allreduce, is what makes 16-GPU AlexNet >80% communication in Fig. 2).
    pub fn fp32() -> Self {
        Self {
            compressor: CompressorSpec::Fp32,
            collective: CollectiveSpec::AllToAll,
            dense_transport: false,
        }
    }

    /// Ablation: fp32 over a bandwidth-optimal ring allreduce (what a
    /// modern NCCL-style stack would give the baseline).
    pub fn fp32_allreduce() -> Self {
        Self {
            compressor: CompressorSpec::Fp32,
            collective: CollectiveSpec::AllToAll,
            dense_transport: true,
        }
    }

    pub fn qsgd(bits: u32, bucket: usize) -> Self {
        Self {
            compressor: CompressorSpec::Qsgd { bits, bucket, norm: Norm::Max, regime: None },
            collective: CollectiveSpec::AllToAll,
            dense_transport: false,
        }
    }

    /// NUQSGD arm at the same bit budget as [`Self::qsgd`] — the
    /// uniform-vs-non-uniform comparison rides the same simulator.
    pub fn nuqsgd(bits: u32, bucket: usize) -> Self {
        Self {
            compressor: CompressorSpec::Nuqsgd { bits, bucket, norm: Norm::Max, regime: None },
            collective: CollectiveSpec::AllToAll,
            dense_transport: false,
        }
    }

    pub fn onebit() -> Self {
        Self {
            compressor: CompressorSpec::OneBit { column: 512 },
            collective: CollectiveSpec::AllToAll,
            dense_transport: false,
        }
    }

    /// Same arm over a different collective (`.with_collective(ring())`
    /// etc.) — the topology × codec matrix in one builder.
    pub fn with_collective(mut self, collective: CollectiveSpec) -> Self {
        self.collective = collective;
        self
    }
}

/// Result of simulating one epoch.
#[derive(Debug, Clone)]
pub struct EpochSim {
    pub network: String,
    pub arm: String,
    /// Collective the transfer/byte models were taken from.
    pub collective: String,
    pub gpus: usize,
    pub breakdown: Breakdown,
    pub message_bytes: usize,
    /// Expected wire bytes per worker per step under the arm's collective
    /// (all-to-all: (K−1)·|msg|; recompressing ring: 2(K−1)/K·|msg|; …) —
    /// the per-algorithm traffic the old K·|msg| accounting ignored.
    pub bytes_per_worker: f64,
    pub steps: usize,
    pub quantized_fraction: f64,
    /// Per-tensor `(readiness, share)` transmission schedule of the
    /// network's layout ([`crate::models::layout::ParamLayout::overlap_schedule`])
    /// — what [`Self::epoch_time_overlapped`] feeds the §5 overlap model.
    pub schedule: Vec<(f64, f64)>,
}

impl EpochSim {
    /// Epoch time as the paper's stacked bars report it (communication and
    /// computation shown additively; Fig. 2's bar height).
    pub fn epoch_time(&self) -> f64 {
        self.breakdown.total().secs()
    }

    /// Schedule-derived epoch time under §5-style overlap at fraction
    /// `phi ∈ [0, 1]`: layer L's buckets go on the wire while layers
    /// L−1…0 are still differentiating
    /// ([`Breakdown::total_overlapped`]). `phi = 0` reproduces
    /// [`Self::epoch_time`] exactly; `phi = 1` is full per-layer bucket
    /// readiness (at or above the old whole-step double-buffering bound).
    pub fn epoch_time_overlapped(&self, phi: f64) -> f64 {
        self.breakdown.total_overlapped(&self.schedule, phi).secs()
    }
}

/// A synthetic gradient with per-tensor scale structure: each tensor gets
/// its own magnitude (layers differ by orders of magnitude in practice,
/// which is exactly why the paper buckets per-tensor).
fn synthetic_gradient(net: &NetworkShape, rng: &mut Xoshiro256) -> Vec<f32> {
    let n = net.params();
    let mut g = vec![0.0f32; n];
    for t in &net.layout.tensors {
        let scale = 10f32.powf(rng::uniform_f32(rng) * 2.0 - 2.0); // 1e-2..1e0
        for x in &mut g[t.offset..t.offset + t.size] {
            *x = rng::normal_f32(rng) * scale;
        }
    }
    g
}

/// Simulate one epoch of data-parallel training of `net` on `gpus` devices.
///
/// `measure_trials` controls how many synthetic gradients are encoded to
/// estimate the mean message size (they are full-size encodes of the real
/// pipeline — the dominant cost of this function).
pub fn simulate_epoch(
    net: &NetworkShape,
    gpus: usize,
    arm: &EpochArm,
    simnet: &SimNet,
    cost: &CostModel,
    measure_trials: usize,
    seed: u64,
) -> EpochSim {
    assert_eq!(simnet.workers, gpus);
    let n = net.params();
    let plan = QuantPlan::paper_default(&net.layout);
    let qfrac = plan.quantized_fraction();
    let mut rng = Xoshiro256::stream(seed, 0xE90C);

    // Measure the real encoded size. The fp32 arm's size is exact without
    // encoding (raw transport, no segment framing on the dense path), so
    // the codec's size hint suffices; compressed arms run the real
    // pipeline through one reused session + output buffer — measure-only,
    // no per-trial message materialised and discarded.
    let msg_bytes = if matches!(arm.compressor, CompressorSpec::Fp32) {
        arm.compressor.codec().encoded_size_hint(n)
    } else {
        let pc = PlanCodec::from_spec(plan, &arm.compressor);
        let mut sess = pc.session(Xoshiro256::stream(seed, 0xEC0D));
        let mut out = Vec::with_capacity(pc.encoded_size_hint(n));
        let mut total = 0usize;
        for _ in 0..measure_trials.max(1) {
            let g = synthetic_gradient(net, &mut rng);
            sess.encode_into(&g, &mut out);
            total += out.len();
        }
        total / measure_trials.max(1)
    };

    // Table 2 reports *global* minibatch sizes; each device computes on its
    // local shard.
    let global_batch = net.batch_for_gpus(gpus);
    let local_batch = (global_batch / gpus).max(1);
    let steps = cost.steps_per_epoch(net.epoch_samples, global_batch);

    let step_compute = cost.step_compute_s(net.flops_fwd_per_sample, local_batch);
    // fp32 skips the quantize+code stage entirely.
    let (step_encode, step_decode) = if matches!(arm.compressor, CompressorSpec::Fp32) {
        (0.0, 0.0)
    } else {
        (cost.encode_s(n), cost.decode_s(n, gpus))
    };
    // Transfer time and per-worker traffic route through the arm's
    // collective traffic model (pure functions — no sessions are built at
    // epoch scale; the all-to-all model reproduces the broadcast closed
    // form exactly, so legacy arms are unchanged).
    let (step_transfer, bytes_per_worker) = if arm.dense_transport {
        let dense = SimNet { topology: crate::simnet::Topology::RingAllReduce, ..simnet.clone() };
        let bpw = if gpus > 1 {
            2.0 * (gpus - 1) as f64 * msg_bytes as f64 / gpus as f64
        } else {
            0.0
        };
        (dense.exchange_time(&vec![msg_bytes; gpus]).secs(), bpw)
    } else {
        (
            collectives::model_exchange_time(&arm.collective, simnet, msg_bytes).secs(),
            collectives::model_bytes_per_worker(&arm.collective, gpus, msg_bytes),
        )
    };

    let breakdown = Breakdown {
        compute: VTime(step_compute * steps as f64),
        encode: VTime(step_encode * steps as f64),
        transfer: VTime(step_transfer * steps as f64),
        decode: VTime(step_decode * steps as f64),
        steps,
    };

    EpochSim {
        network: net.name.to_string(),
        arm: arm.compressor.label(),
        collective: arm.collective.label(),
        gpus,
        breakdown,
        message_bytes: msg_bytes,
        bytes_per_worker,
        steps,
        quantized_fraction: qfrac,
        schedule: net.layout.overlap_schedule(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::zoo;
    use crate::simnet::Preset;

    fn sim(net: &NetworkShape, gpus: usize, arm: &EpochArm) -> EpochSim {
        let simnet = SimNet::preset(gpus, Preset::K80Pcie);
        simulate_epoch(net, gpus, arm, &simnet, &CostModel::k80(), 1, 0)
    }

    #[test]
    fn alexnet_16gpu_is_comm_bound_at_fp32() {
        // Paper §5: >80% of 32-bit 16-GPU AlexNet epoch time is communication.
        let net = zoo::alexnet();
        let r = sim(&net, 16, &EpochArm::fp32());
        assert!(r.breakdown.comm_fraction() > 0.7, "comm frac {}", r.breakdown.comm_fraction());
    }

    #[test]
    fn qsgd_4bit_cuts_alexnet_epoch_time() {
        // Paper: 4-bit QSGD reduces 16-GPU AlexNet epoch time ~2.5×.
        let net = zoo::alexnet();
        let fp = sim(&net, 16, &EpochArm::fp32());
        let q4 = sim(&net, 16, &EpochArm::qsgd(4, 512));
        let speedup = fp.epoch_time() / q4.epoch_time();
        assert!(speedup > 1.5 && speedup < 5.0, "speedup {speedup}");
        // message must be ~7-8× smaller than fp32
        assert!(q4.message_bytes * 5 < fp.message_bytes);
    }

    #[test]
    fn resnet_benefits_less_than_alexnet() {
        // Computation-heavy nets gain less (Table 1: ResNet50 1.26× vs
        // AlexNet 2.05× on 8 GPUs).
        let a = zoo::alexnet();
        let r = zoo::resnet50();
        let sa = sim(&a, 8, &EpochArm::fp32()).epoch_time() / sim(&a, 8, &EpochArm::qsgd(4, 512)).epoch_time();
        let sr = sim(&r, 8, &EpochArm::fp32()).epoch_time() / sim(&r, 8, &EpochArm::qsgd(4, 512)).epoch_time();
        assert!(sa > sr, "alexnet {sa} vs resnet {sr}");
        assert!(sr >= 1.0, "resnet should not slow down: {sr}");
    }

    #[test]
    fn nuqsgd_arm_rides_the_same_simulator() {
        // Uniform-vs-non-uniform at the same bit budget, end to end through
        // the plan compressor + interconnect model: both compress far below
        // fp32, and the denser exponential-grid levels stay the same order
        // of magnitude as the uniform arm on the wire.
        let net = zoo::alexnet();
        let q4 = sim(&net, 8, &EpochArm::qsgd(4, 512));
        let nu4 = sim(&net, 8, &EpochArm::nuqsgd(4, 512));
        let fp_bytes = net.params() * 4;
        assert!(nu4.message_bytes * 3 < fp_bytes, "NUQSGD msg {}", nu4.message_bytes);
        assert!(
            nu4.message_bytes < q4.message_bytes * 4,
            "NUQSGD {} vs QSGD {}",
            nu4.message_bytes,
            q4.message_bytes
        );
    }

    #[test]
    fn traffic_model_is_collective_aware() {
        let net = zoo::alexnet();
        let arm = EpochArm::qsgd(4, 512);
        let a2a = sim(&net, 16, &arm);
        let ring = sim(&net, 16, &arm.clone().with_collective(CollectiveSpec::ring()));
        let hier = sim(&net, 16, &arm.clone().with_collective(CollectiveSpec::hierarchical(4)));
        // the measured message is identical — only the exchange differs
        assert_eq!(a2a.message_bytes, ring.message_bytes);
        assert_eq!(a2a.message_bytes, hier.message_bytes);
        // all-to-all: exactly (K−1)·|msg| per worker
        assert!(
            (a2a.bytes_per_worker - 15.0 * a2a.message_bytes as f64).abs() < 1e-6,
            "a2a bpw {}",
            a2a.bytes_per_worker
        );
        // recompressing ring: 2(K−1)/K·|msg| ≈ 1.875·|msg| — far below a2a
        assert!(
            ring.bytes_per_worker * 4.0 < a2a.bytes_per_worker,
            "ring {} vs a2a {}",
            ring.bytes_per_worker,
            a2a.bytes_per_worker
        );
        assert!(hier.bytes_per_worker < a2a.bytes_per_worker);
        // and the transfer-time model follows the bytes
        assert!(ring.breakdown.transfer.secs() < a2a.breakdown.transfer.secs());
        assert_eq!(ring.collective, "ring");
    }

    #[test]
    fn fault_scenarios_have_deterministic_epoch_goldens() {
        use crate::config::ScenarioSpec;
        let net = zoo::alexnet();
        let arm = EpochArm::qsgd(4, 512);
        let run = |scenario: &str, seed: u64| {
            let s = ScenarioSpec::parse(scenario).unwrap();
            let simnet = s.apply_simnet(SimNet::preset(8, Preset::K80Pcie), seed);
            simulate_epoch(&net, 8, &arm, &simnet, &CostModel::k80(), 1, 0).epoch_time()
        };
        let base = run("none", 1);
        // prob-1.0 schedules so the (few) charges in one epoch model all
        // bite; seed-sensitivity of stochastic schedules is pinned below.
        for sc in ["hetero:4.0", "straggler:1.0:5.0", "corrupt:1.0"] {
            let a = run(sc, 1);
            let b = run(sc, 1);
            assert_eq!(a.to_bits(), b.to_bits(), "{sc} must be seed-pinned");
            assert!(a > base, "{sc}: {a} not above baseline {base}");
        }
    }

    #[test]
    fn scenario_schedules_are_seed_pinned_and_seed_sensitive() {
        use crate::config::ScenarioSpec;
        let total = |seed: u64| {
            let s = ScenarioSpec::parse("straggler:0.5:5.0").unwrap();
            let net = s.apply_simnet(SimNet::preset(4, Preset::K80Pcie), seed);
            let mut t = 0.0f64;
            for _ in 0..64 {
                t += net.exchange_time(&vec![1 << 16; 4]).secs();
            }
            t
        };
        assert_eq!(total(7).to_bits(), total(7).to_bits(), "same seed, same trace");
        assert!(total(7).to_bits() != total(8).to_bits(), "different seed, different trace");
    }

    #[test]
    fn overlapped_epoch_time_interpolates_the_serial_total() {
        let net = zoo::alexnet();
        let r = sim(&net, 16, &EpochArm::fp32());
        assert!(!r.schedule.is_empty(), "alexnet layout must yield a schedule");
        // φ = 0 is exactly the stacked-bar total.
        assert_eq!(r.epoch_time_overlapped(0.0).to_bits(), r.epoch_time().to_bits());
        // φ = 1 strictly helps a comm-bound configuration and never beats
        // the max(comp, comm) floor.
        let full = r.epoch_time_overlapped(1.0);
        assert!(full < r.epoch_time(), "overlap should shrink a comm-bound epoch");
        let comp = r.breakdown.compute.secs();
        let comm = r.breakdown.communication().secs();
        assert!(full >= comp.max(comm) - 1e-9);
        // and φ = 0.5 lies between the endpoints
        let half = r.epoch_time_overlapped(0.5);
        assert!(full <= half && half <= r.epoch_time());
    }

    #[test]
    fn comm_fraction_grows_with_gpus() {
        let net = zoo::alexnet();
        let f2 = sim(&net, 2, &EpochArm::fp32()).breakdown.comm_fraction();
        let f16 = sim(&net, 16, &EpochArm::fp32()).breakdown.comm_fraction();
        assert!(f16 > f2, "{f2} -> {f16}");
    }
}
