//! QSVRG — quantized stochastic variance-reduced gradient (§3.3, App. B/G).
//!
//! K processors partition the m components of f = (1/m)Σ f_i. At each epoch
//! start, every processor broadcasts its *unquantized* local full gradient
//! `∇h_i(y)` (§3.3 main text — this is the `+Fn` term of Theorem 3.6; the
//! epoch-start broadcast must be exact because ‖∇h_i(x*)‖ does not vanish,
//! so quantizing it, as the Appendix-B restatement does, leaves a variance
//! floor). The sum H_p = ∇f(y) anchors the SVRG correction. Within the
//! epoch, processor i broadcasts `u_{t,i} = Q̃(∇f_j(x_t) − ∇f_j(y) + H_p)`
//! with Q̃ = Q(·, √n) — *this* argument shrinks as x, y → x*, so the
//! quantization noise contracts with the iterate and the linear rate
//! survives. Theorem 3.6: with η = O(1/L), T = O(L/ℓ), the epoch error
//! contracts by 0.9 per epoch with ≤ (F + 2.8n)(T+1) + Fn bits/epoch.

use anyhow::Result;

use crate::coding::gradient as gcode;
use crate::data::Objective;
use crate::metrics::{Curve, WireStats};
use crate::quant::stochastic;
use crate::quant::Norm;
use crate::util::rng::{self, Xoshiro256};

pub struct SvrgConfig {
    pub processors: usize,
    pub epochs: usize,
    /// Iterations per epoch; `None` ⇒ the Theorem 3.6 choice `8·⌈L/ℓ⌉`.
    pub iters: Option<usize>,
    /// Step size; `None` ⇒ `1/(10L)`.
    pub eta: Option<f32>,
    pub seed: u64,
    /// Quantize updates (QSVRG) or run exact parallel SVRG (baseline).
    pub quantize: bool,
}

impl SvrgConfig {
    pub fn paper(processors: usize, epochs: usize) -> Self {
        Self { processors, epochs, iters: None, eta: None, seed: 0, quantize: true }
    }
}

pub struct SvrgResult {
    /// (epoch, f(y_p) − f*) — must contract ~0.9^p (Theorem 3.6).
    pub gap: Curve,
    pub wire: WireStats,
    pub y: Vec<f32>,
    /// Bits bound per processor per epoch from Theorem 3.6.
    pub bits_bound_per_epoch: f64,
}

/// Q̃(v) = Q(v, √n) with 2-norm — the paper's QSVRG quantizer. Returns the
/// dequantized vector and the encoded size in bytes (dense regime,
/// Corollary 3.3 coding).
fn qtilde(v: &[f32], rng: &mut Xoshiro256, wire: &mut WireStats) -> Vec<f32> {
    let n = v.len();
    let s = (n as f64).sqrt().round().max(1.0) as u32;
    let q = stochastic::quantize(v, s, n, Norm::L2, rng);
    let bytes = gcode::encode(&q, gcode::Regime::Dense);
    wire.record(bytes.len(), n);
    // decode path exercised for realism
    let dec = gcode::decode(&bytes).expect("self-roundtrip");
    dec.dequantize()
}

/// Run (Q)SVRG on a finite-sum objective. `f_star` is the optimal value,
/// used only for reporting the per-epoch gap.
pub fn run(cfg: &SvrgConfig, obj: &dyn Objective, f_star: f64) -> Result<SvrgResult> {
    let n = obj.dim();
    let m = obj.num_components();
    let k = cfg.processors;
    anyhow::ensure!(m % k == 0, "components ({m}) must split evenly over {k} processors");
    let per = m / k;

    let ell = obj.strong_convexity();
    let big_l = obj.smoothness();
    anyhow::ensure!(ell > 0.0, "QSVRG needs strong convexity");
    let iters = cfg.iters.unwrap_or(((big_l / ell).ceil() as usize) * 8).max(4);
    let eta = cfg.eta.unwrap_or((1.0 / (10.0 * big_l)) as f32);

    let mut rng = Xoshiro256::stream(cfg.seed, 0x5A96);
    let mut y = vec![0.0f32; n];
    let mut gap = Curve::default();
    let mut wire = WireStats::default();
    gap.push(0, obj.loss(&y) - f_star);

    let mut tmp = vec![0.0f32; n];
    let mut tmp2 = vec![0.0f32; n];
    for epoch in 1..=cfg.epochs {
        // Epoch start: processors broadcast ∇h_i(y) *unquantized* (§3.3:
        // "the unquantized full gradient" — F·n bits each); H_p = Σ_i.
        let mut h_p = vec![0.0f32; n];
        for proc in 0..k {
            // ∇h_i(y) = (1/m) Σ_{j in partition} ∇f_j(y)
            let mut hi = vec![0.0f32; n];
            for j in proc * per..(proc + 1) * per {
                obj.component_grad(j, &y, &mut tmp);
                for (h, &t) in hi.iter_mut().zip(&tmp) {
                    *h += t / m as f32;
                }
            }
            if cfg.quantize {
                wire.record(n * 4, n); // exact fp32 broadcast on the wire
            }
            for (h, &c) in h_p.iter_mut().zip(&hi) {
                *h += c;
            }
        }

        // Epoch body.
        let mut x = y.clone();
        let mut x_sum = vec![0.0f64; n];
        for _t in 0..iters {
            let mut u_total = vec![0.0f32; n];
            for proc in 0..k {
                let j = proc * per + rng::uniform_usize(&mut rng, per);
                obj.component_grad(j, &x, &mut tmp);
                obj.component_grad(j, &y, &mut tmp2);
                let mut v: Vec<f32> = tmp
                    .iter()
                    .zip(&tmp2)
                    .zip(&h_p)
                    .map(|((&a, &b), &h)| a - b + h)
                    .collect();
                if cfg.quantize {
                    v = qtilde(&v, &mut rng, &mut wire);
                }
                for (u, &vi) in u_total.iter_mut().zip(&v) {
                    *u += vi / k as f32;
                }
            }
            for (xi, &u) in x.iter_mut().zip(&u_total) {
                *xi -= eta * u;
            }
            for (s, &xi) in x_sum.iter_mut().zip(&x) {
                *s += xi as f64;
            }
        }
        y = x_sum.iter().map(|&s| (s / iters as f64) as f32).collect();
        gap.push(epoch, (obj.loss(&y) - f_star).max(1e-300));
    }

    // Theorem 3.6: per processor per epoch ≤ (F + 2.8n)(T+1) + F·n bits.
    let bits_bound = (32.0 + 2.8 * n as f64) * (iters as f64 + 1.0) + 32.0 * n as f64;

    Ok(SvrgResult { gap, wire, y, bits_bound_per_epoch: bits_bound })
}

/// Solve to near-optimality with full-gradient descent (for f*).
pub fn solve_f_star(obj: &dyn Objective, iters: usize) -> f64 {
    let n = obj.dim();
    let mut w = vec![0.0f32; n];
    let mut g = vec![0.0f32; n];
    let lr = (1.0 / obj.smoothness()) as f32;
    for _ in 0..iters {
        obj.full_grad(&w, &mut g);
        for (wi, &gi) in w.iter_mut().zip(&g) {
            *wi -= lr * gi;
        }
    }
    obj.loss(&w)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::LogisticProblem;

    #[test]
    fn qsvrg_contracts_linearly() {
        let obj = LogisticProblem::generate(128, 16, 0.05, 1);
        let f_star = solve_f_star(&obj, 3000);
        let cfg = SvrgConfig { processors: 4, epochs: 6, iters: None, eta: None, seed: 2, quantize: true };
        let r = run(&cfg, &obj, f_star).unwrap();
        let g0 = r.gap.points[0].1;
        let gend = r.gap.last().unwrap();
        // Theorem 3.6: 0.9^p contraction; after 6 epochs expect < 0.6·gap0
        assert!(gend < g0 * 0.6, "gap {g0} -> {gend}");
        // monotone-ish decrease (allow small bumps)
        assert!(r.gap.points.windows(2).filter(|w| w[1].1 > w[0].1 * 1.5).count() <= 1);
        assert!(r.wire.messages > 0);
    }

    #[test]
    fn quantized_matches_exact_rate_roughly() {
        let obj = LogisticProblem::generate(128, 16, 0.05, 3);
        let f_star = solve_f_star(&obj, 3000);
        let mk = |quantize| SvrgConfig { processors: 4, epochs: 5, iters: None, eta: None, seed: 4, quantize };
        let rq = run(&mk(true), &obj, f_star).unwrap();
        let re = run(&mk(false), &obj, f_star).unwrap();
        // Theorem 3.6 guarantees QSVRG contracts at least 0.9 per epoch;
        // exact SVRG contracts faster in practice, so compare *rates*.
        let rate = |r: &SvrgResult| {
            let g0 = r.gap.points[0].1.max(1e-300);
            (r.gap.last().unwrap() / g0).powf(1.0 / 5.0)
        };
        assert!(rate(&rq) <= 0.9, "QSVRG rate {} > 0.9", rate(&rq));
        assert!(rate(&re) <= rate(&rq) * 1.05, "exact should be no slower");
    }

    #[test]
    fn bits_per_epoch_within_bound() {
        // dim large enough that per-message constants (frame header, scale)
        // don't dominate the F + 2.8n budget
        let obj = LogisticProblem::generate(64, 512, 0.1, 5);
        let f_star = solve_f_star(&obj, 2000);
        let cfg = SvrgConfig { processors: 2, epochs: 3, iters: Some(20), eta: None, seed: 6, quantize: true };
        let r = run(&cfg, &obj, f_star).unwrap();
        // measured bits per processor per epoch ≤ theorem bound (the bound
        // counts (T+1) Q̃ messages of ≤ F+2.8n bits each, plus Fn slack)
        let per_proc_per_epoch = r.wire.payload_bytes as f64 * 8.0 / (2.0 * 3.0);
        // Our dense coder measures ≈3.1 bits/coord vs the theorem's
        // headline 2.8 constant (see dense_bits_bound doc); allow 20%.
        assert!(
            per_proc_per_epoch <= r.bits_bound_per_epoch * 1.2,
            "measured {per_proc_per_epoch} vs bound {}",
            r.bits_bound_per_epoch
        );
    }

    #[test]
    fn uneven_partition_rejected() {
        let obj = LogisticProblem::generate(30, 8, 0.1, 7);
        let cfg = SvrgConfig::paper(4, 1);
        assert!(run(&cfg, &obj, 0.0).is_err());
    }
}
