//! Gradient sources: where the coordinator gets (loss, gradient) from.
//!
//! * [`ConvexSource`] — Rust-native finite-sum objectives (theory workloads;
//!   thousands of steps per second).
//! * [`RuntimeSource`] — the full three-layer path: PJRT executes the AOT
//!   JAX graph for (loss, grad); batches come from the synthetic datasets.

use anyhow::Result;
use rand_core::RngCore;

use crate::data::{ClassifyData, Objective, TokenCorpus};
use crate::runtime::{Input, Runtime};
use crate::util::rng::Xoshiro256;

/// Provider of per-worker stochastic gradients plus optional evaluation.
pub trait GradSource {
    fn dim(&self) -> usize;
    /// Compute (loss, grad) for `worker` at `step` on `params`. Data order
    /// is deterministic in (worker, step).
    fn loss_and_grad(&mut self, worker: usize, step: u64, params: &[f32]) -> Result<(f32, Vec<f32>)>;
    /// Optional held-out evaluation metric (higher = better unless noted).
    fn eval(&mut self, _params: &[f32]) -> Option<f64> {
        None
    }
    /// Forward FLOPs per step per worker (drives the virtual compute clock).
    fn flops_fwd_per_step(&self) -> f64;
    fn name(&self) -> String;
}

// --------------------------------------------------------------------------
// Convex (Rust-native)
// --------------------------------------------------------------------------

/// Minibatched stochastic gradients of a finite-sum convex objective.
pub struct ConvexSource<O: Objective> {
    pub objective: O,
    pub batch: usize,
    seed: u64,
}

impl<O: Objective> ConvexSource<O> {
    pub fn new(objective: O, batch: usize, seed: u64) -> Self {
        Self { objective, batch, seed }
    }
}

impl<O: Objective> GradSource for ConvexSource<O> {
    fn dim(&self) -> usize {
        self.objective.dim()
    }

    fn loss_and_grad(&mut self, worker: usize, step: u64, params: &[f32]) -> Result<(f32, Vec<f32>)> {
        let mut rng = Xoshiro256::stream(self.seed ^ 0x5EED, (worker as u64) << 40 | step);
        let n = self.dim();
        let mut grad = vec![0.0f32; n];
        let mut tmp = vec![0.0f32; n];
        for _ in 0..self.batch {
            self.objective.stochastic_grad(params, &mut rng as &mut dyn RngCore, &mut tmp);
            for (g, t) in grad.iter_mut().zip(&tmp) {
                *g += t / self.batch as f32;
            }
        }
        Ok((self.objective.loss(params) as f32, grad))
    }

    fn eval(&mut self, params: &[f32]) -> Option<f64> {
        Some(self.objective.loss(params))
    }

    fn flops_fwd_per_step(&self) -> f64 {
        (2 * self.dim() * self.batch) as f64
    }

    fn name(&self) -> String {
        format!("convex(dim={},batch={})", self.dim(), self.batch)
    }
}

// --------------------------------------------------------------------------
// PJRT-backed model sources
// --------------------------------------------------------------------------

/// Which workload the runtime artifact trains on.
pub enum Workload {
    /// Gaussian-cluster classification → `(x f32[B,D], y i32[B])` batches.
    Classify { data: ClassifyData, batch: usize },
    /// Token LM → `tokens i32[B, seq+1]` batches.
    Lm { corpus: TokenCorpus, batch: usize, seq_plus_1: usize },
}

/// Full three-layer gradient source: PJRT-executed AOT graph.
pub struct RuntimeSource<'r> {
    pub runtime: &'r Runtime,
    pub artifact: String,
    pub workload: Workload,
    dim: usize,
    flops: f64,
    /// Cached eval batch for the classify case.
    eval_cache: Option<(Vec<f32>, Vec<i32>)>,
}

impl<'r> RuntimeSource<'r> {
    pub fn new(runtime: &'r Runtime, artifact: &str, workload: Workload) -> Result<Self> {
        let art = runtime.manifest().get(artifact)?;
        let dim = art.params.ok_or_else(|| anyhow::anyhow!("artifact has no param count"))?;
        // FLOPs estimate: 2·params·batch forward (dense nets ≈ 2·P per sample).
        let batch = match &workload {
            Workload::Classify { batch, .. } => *batch,
            Workload::Lm { batch, seq_plus_1, .. } => batch * seq_plus_1,
        };
        let flops = 2.0 * dim as f64 * batch as f64;
        Ok(Self { runtime, artifact: artifact.to_string(), workload, dim, flops, eval_cache: None })
    }
}

impl GradSource for RuntimeSource<'_> {
    fn dim(&self) -> usize {
        self.dim
    }

    fn loss_and_grad(&mut self, worker: usize, step: u64, params: &[f32]) -> Result<(f32, Vec<f32>)> {
        match &self.workload {
            Workload::Classify { data, batch } => {
                let (x, y) = data.batch(worker, step, *batch);
                let xs = [*batch, data.dim];
                let ys = [*batch];
                self.runtime.grad(
                    &self.artifact,
                    params,
                    &[Input::F32(&x, &xs), Input::I32(&y, &ys)],
                )
            }
            Workload::Lm { corpus, batch, seq_plus_1 } => {
                let toks = corpus.batch(worker, step, *batch, *seq_plus_1);
                let ts = [*batch, *seq_plus_1];
                self.runtime.grad(&self.artifact, params, &[Input::I32(&toks, &ts)])
            }
        }
    }

    fn eval(&mut self, params: &[f32]) -> Option<f64> {
        match &self.workload {
            Workload::Classify { data, batch } => {
                // held-out loss via the same grad artifact (loss output only)
                if self.eval_cache.is_none() {
                    self.eval_cache = Some(data.batch(usize::MAX - 2, u64::MAX - 2, *batch));
                }
                let (x, y) = self.eval_cache.as_ref().unwrap();
                let xs = [*batch, data.dim];
                let ys = [*batch];
                self.runtime
                    .grad(&self.artifact, params, &[Input::F32(x, &xs), Input::I32(y, &ys)])
                    .ok()
                    .map(|(l, _)| l as f64)
            }
            Workload::Lm { corpus, batch, seq_plus_1 } => {
                let toks = corpus.batch(usize::MAX - 2, u64::MAX - 2, *batch, *seq_plus_1);
                let ts = [*batch, *seq_plus_1];
                self.runtime
                    .grad(&self.artifact, params, &[Input::I32(&toks, &ts)])
                    .ok()
                    .map(|(l, _)| l as f64)
            }
        }
    }

    fn flops_fwd_per_step(&self) -> f64 {
        self.flops
    }

    fn name(&self) -> String {
        format!("runtime({})", self.artifact)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::QuadraticProblem;

    #[test]
    fn convex_source_is_deterministic() {
        let p = QuadraticProblem::generate(64, 8, 1e-3, 0.1, 0);
        let mut s = ConvexSource::new(p, 4, 42);
        let w = vec![0.5f32; 8];
        let (l1, g1) = s.loss_and_grad(0, 0, &w).unwrap();
        let (l2, g2) = s.loss_and_grad(0, 0, &w).unwrap();
        assert_eq!(l1, l2);
        assert_eq!(g1, g2);
        let (_, g3) = s.loss_and_grad(1, 0, &w).unwrap();
        assert_ne!(g1, g3);
        assert!(s.eval(&w).is_some());
        assert!(s.flops_fwd_per_step() > 0.0);
    }
}
