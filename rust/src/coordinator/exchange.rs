//! Plan-aware message assembly: applies the §5 protocol to a full model
//! gradient — quantize the large tensors, ship small tensors (<10K elements)
//! in raw fp32, frame the segments so the receiver can reassemble.
//!
//! Frame layout (byte-aligned, little-endian):
//!   u32 segment_count, then per segment: u32 payload_len | u8 kind | payload
//! where kind 0 = fp32 raw, 1 = compressed.
//!
//! Split along the session API: [`PlanCodec`] is the shared, immutable
//! decode half (one `Arc` serves every worker's decode concurrently), and
//! its [`Codec::session`] creates a per-worker [`PlanSession`] holding one
//! inner [`EncodeSession`] per quantized segment — stateful compressors
//! (1BitSGD's error-feedback residual) track per-coordinate state, so their
//! sessions must be segment-local.

use std::sync::Arc;

use anyhow::{ensure, Context, Result};

use crate::config::CodecOptions;
use crate::coordinator::CompressorSpec;
use crate::models::layout::QuantPlan;
use crate::quant::{Codec, EncodeSession, WireFormat};
use crate::util::rng::Xoshiro256;

/// Codec wrapper that honours a [`QuantPlan`]: raw fp32 for skip segments,
/// the spec's codec for quantized ones. All decode paths are `&self`.
pub struct PlanCodec {
    pub plan: QuantPlan,
    /// The shared inner codec for quantized segments (stateless decode; the
    /// per-segment encode state lives in [`PlanSession`]).
    inner: Arc<dyn Codec>,
    opts: CodecOptions,
}

impl PlanCodec {
    pub fn from_spec(plan: QuantPlan, spec: &CompressorSpec) -> Self {
        Self::from_spec_with(plan, spec, CodecOptions::default())
    }

    /// [`Self::from_spec`] with explicit [`CodecOptions`] threaded into the
    /// inner codec (directory threshold, decode thread budget).
    pub fn from_spec_with(plan: QuantPlan, spec: &CompressorSpec, opts: CodecOptions) -> Self {
        let inner = spec.codec_with(opts.clone());
        Self { plan, inner, opts }
    }

    fn quantized_segments(&self) -> usize {
        self.plan.segments.iter().filter(|s| s.quantized).count()
    }
}

impl Codec for PlanCodec {
    fn session(&self, mut rng: Xoshiro256) -> Box<dyn EncodeSession> {
        // Fork one independent RNG stream per quantized segment off the
        // worker's stream ([`Xoshiro256::fork`]), so segment sessions stay
        // deterministic in (seed, segment index) regardless of how often
        // each encodes.
        let sessions: Vec<Box<dyn EncodeSession>> = (0..self.quantized_segments())
            .map(|_| self.inner.session(rng.fork()))
            .collect();
        Box::new(PlanSession { plan: self.plan.clone(), sessions, scratch: Vec::new() })
    }

    /// Decode a message produced by a [`PlanSession`] under the same plan.
    fn decode(&self, msg: &[u8], n: usize) -> Result<Vec<f32>> {
        ensure!(n == self.plan.total_len(), "expected length does not match the plan");
        let mut pos = 0usize;
        let take = |pos: &mut usize, n: usize| -> Result<&[u8]> {
            ensure!(*pos + n <= msg.len(), "truncated message");
            let s = &msg[*pos..*pos + n];
            *pos += n;
            Ok(s)
        };
        let nseg = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap()) as usize;
        ensure!(nseg == self.plan.segments.len(), "segment count mismatch");
        let mut out = vec![0.0f32; self.plan.total_len()];
        for seg in &self.plan.segments {
            let len = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap()) as usize;
            let kind = take(&mut pos, 1)?[0];
            let payload = take(&mut pos, len)?;
            let dst = &mut out[seg.offset..seg.offset + seg.len];
            match kind {
                0 => {
                    ensure!(!seg.quantized, "fp32 payload for quantized segment");
                    ensure!(payload.len() == seg.len * 4, "fp32 segment length");
                    for (d, c) in dst.iter_mut().zip(payload.chunks_exact(4)) {
                        *d = f32::from_le_bytes(c.try_into().unwrap());
                    }
                }
                1 => {
                    ensure!(seg.quantized, "compressed payload for fp32 segment");
                    let dec = self
                        .inner
                        .decode(payload, seg.len)
                        .context("segment decompress")?;
                    dst.copy_from_slice(&dec);
                }
                k => anyhow::bail!("unknown segment kind {k}"),
            }
        }
        ensure!(pos == msg.len(), "trailing bytes in message");
        Ok(out)
    }

    /// Fused decode-and-accumulate across the plan's segments:
    /// `acc += alpha · decode(msg)`, with the thread budget passed through
    /// to each quantized segment's
    /// [`Codec::decode_add_threads`] — directory-bearing segments decode
    /// their buckets in parallel; the accumulator is bit-identical at every
    /// budget.
    fn decode_add_threads(
        &self,
        msg: &[u8],
        alpha: f32,
        acc: &mut [f32],
        threads: usize,
    ) -> Result<()> {
        ensure!(acc.len() == self.plan.total_len(), "accumulator/plan mismatch");
        let mut pos = 0usize;
        let take = |pos: &mut usize, n: usize| -> Result<&[u8]> {
            ensure!(*pos + n <= msg.len(), "truncated message");
            let s = &msg[*pos..*pos + n];
            *pos += n;
            Ok(s)
        };
        let nseg = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap()) as usize;
        ensure!(nseg == self.plan.segments.len(), "segment count mismatch");
        for seg in &self.plan.segments {
            let len = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap()) as usize;
            let kind = take(&mut pos, 1)?[0];
            let payload = take(&mut pos, len)?;
            let dst = &mut acc[seg.offset..seg.offset + seg.len];
            match kind {
                0 => {
                    ensure!(!seg.quantized && payload.len() == seg.len * 4, "fp32 segment");
                    for (d, c) in dst.iter_mut().zip(payload.chunks_exact(4)) {
                        *d += alpha * f32::from_le_bytes(c.try_into().unwrap());
                    }
                }
                1 => {
                    ensure!(seg.quantized, "compressed payload for fp32 segment");
                    self.inner.decode_add_threads(payload, alpha, dst, threads)?;
                }
                k => anyhow::bail!("unknown segment kind {k}"),
            }
        }
        ensure!(pos == msg.len(), "trailing bytes in message");
        Ok(())
    }

    fn decode_threads(&self) -> usize {
        self.opts.decode_threads()
    }

    /// Byte estimate for one full-plan message: the 4-byte segment count,
    /// 5 bytes of framing per segment, exact fp32 payloads for skip
    /// segments, and the inner codec's hint for quantized ones.
    fn encoded_size_hint(&self, n: usize) -> usize {
        debug_assert_eq!(n, self.plan.total_len());
        let _ = n;
        4 + self
            .plan
            .segments
            .iter()
            .map(|seg| {
                5 + if seg.quantized {
                    self.inner.encoded_size_hint(seg.len)
                } else {
                    seg.len * 4
                }
            })
            .sum::<usize>()
    }

    fn wire_format(&self) -> WireFormat {
        WireFormat::Segments
    }

    fn name(&self) -> String {
        format!(
            "plan[{}seg]x{} over {}",
            self.plan.segments.len(),
            self.quantized_segments(),
            self.inner.name()
        )
    }
}

/// Per-worker plan encode session: one inner session per quantized segment
/// plus a reusable payload staging buffer — zero steady-state allocations
/// when the inner sessions are (fp32/QSGD/NUQSGD/1bit/TernGrad all are).
pub struct PlanSession {
    plan: QuantPlan,
    sessions: Vec<Box<dyn EncodeSession>>,
    scratch: Vec<u8>,
}

impl EncodeSession for PlanSession {
    fn encode_into(&mut self, grad: &[f32], out: &mut Vec<u8>) {
        let Self { plan, sessions, scratch } = self;
        assert_eq!(grad.len(), plan.total_len(), "gradient/plan mismatch");
        out.clear();
        out.extend_from_slice(&(plan.segments.len() as u32).to_le_bytes());
        let mut qi = 0usize;
        for seg in &plan.segments {
            let slice = &grad[seg.offset..seg.offset + seg.len];
            if seg.quantized {
                sessions[qi].encode_into(slice, scratch);
                qi += 1;
                out.extend_from_slice(&(scratch.len() as u32).to_le_bytes());
                out.push(1);
                out.extend_from_slice(scratch);
            } else {
                out.extend_from_slice(&((seg.len * 4) as u32).to_le_bytes());
                out.push(0);
                for &x in slice {
                    out.extend_from_slice(&x.to_le_bytes());
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::CompressorSpec;
    use crate::models::layout::{ParamLayout, QuantPlan};
    use crate::util::rng::{self, Xoshiro256};

    fn layout() -> ParamLayout {
        ParamLayout::synthetic(&[
            ("small", vec![100]),           // fp32
            ("big", vec![200, 100]),        // quantized
            ("bias", vec![50]),             // fp32
        ])
    }

    #[test]
    fn skip_segments_are_lossless() {
        let l = layout();
        let plan = QuantPlan::build(&l, 10_000);
        let mut rng = Xoshiro256::from_u64(0);
        let grad = rng::normal_vec(&mut rng, l.total_params());
        let pc = PlanCodec::from_spec(plan, &CompressorSpec::qsgd_4bit());
        let mut sess = pc.session(Xoshiro256::from_u64(1));
        let msg = sess.compress(&grad);
        let back = pc.decode(&msg, grad.len()).unwrap();
        // fp32 segments: exact
        assert_eq!(&back[..100], &grad[..100]);
        assert_eq!(&back[20100..], &grad[20100..]);
        // quantized middle: within one level of a 512-bucket max-norm quantizer
        for (chunk_g, chunk_b) in grad[100..20100].chunks(512).zip(back[100..20100].chunks(512)) {
            let scale = chunk_g.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
            for (g, b) in chunk_g.iter().zip(chunk_b) {
                assert!((g - b).abs() <= scale / 7.0 + 1e-6);
            }
        }
    }

    #[test]
    fn message_smaller_than_fp32_and_hint_bounds_it() {
        let l = layout();
        let plan = QuantPlan::build(&l, 10_000);
        let mut rng = Xoshiro256::from_u64(1);
        let grad = rng::normal_vec(&mut rng, l.total_params());
        let pc = PlanCodec::from_spec(plan, &CompressorSpec::qsgd_4bit());
        let msg = pc.session(Xoshiro256::from_u64(2)).compress(&grad);
        assert!(msg.len() < l.total_params() * 4 / 3, "msg {} bytes", msg.len());
        // the no-encode estimate upper-bounds the measured message
        let hint = pc.encoded_size_hint(grad.len());
        assert!(msg.len() <= hint, "measured {} > hint {hint}", msg.len());
        // ... and not absurdly: within the fp32 ceiling plus framing
        assert!(hint <= l.total_params() * 4 + 5 * pc.plan.segments.len() + 4);
    }

    #[test]
    fn corrupt_messages_rejected() {
        let l = layout();
        let plan = QuantPlan::build(&l, 10_000);
        let mut rng = Xoshiro256::from_u64(2);
        let grad = rng::normal_vec(&mut rng, l.total_params());
        let pc = PlanCodec::from_spec(plan, &CompressorSpec::qsgd_4bit());
        let n = grad.len();
        let msg = pc.session(Xoshiro256::from_u64(3)).compress(&grad);
        assert!(pc.decode(&msg[..msg.len() - 3], n).is_err());
        let mut extra = msg.clone();
        extra.extend_from_slice(&[0, 1, 2]);
        assert!(pc.decode(&extra, n).is_err());
        assert!(pc.decode(&[], n).is_err());
    }

    #[test]
    fn fp32_plan_is_identity() {
        let l = layout();
        let plan = QuantPlan::build(&l, usize::MAX); // nothing quantized
        let mut rng = Xoshiro256::from_u64(3);
        let grad = rng::normal_vec(&mut rng, l.total_params());
        let pc = PlanCodec::from_spec(plan, &CompressorSpec::Fp32);
        let msg = pc.session(Xoshiro256::from_u64(4)).compress(&grad);
        assert_eq!(pc.decode(&msg, grad.len()).unwrap(), grad);
        // nothing quantized ⇒ the hint is exact
        assert_eq!(pc.encoded_size_hint(grad.len()), msg.len());
    }

    #[test]
    fn session_reuses_buffers_and_is_deterministic() {
        let l = layout();
        let plan = QuantPlan::build(&l, 10_000);
        let mut rng = Xoshiro256::from_u64(4);
        let grad = rng::normal_vec(&mut rng, l.total_params());
        let pc = PlanCodec::from_spec(plan, &CompressorSpec::qsgd_4bit());
        let a = pc.session(Xoshiro256::from_u64(5)).compress(&grad);
        let b = pc.session(Xoshiro256::from_u64(5)).compress(&grad);
        assert_eq!(a, b, "same session seed must reproduce the same message");
        let mut sess = pc.session(Xoshiro256::from_u64(6));
        // pre-size above any plausible message so capacity equality below
        // tests reuse rather than growth policy
        let mut out = Vec::with_capacity(l.total_params() * 4 + 64);
        sess.encode_into(&grad, &mut out);
        let cap = out.capacity();
        sess.encode_into(&grad, &mut out);
        assert_eq!(out.capacity(), cap, "output buffer must be reused");
    }
}
