//! Plan-aware message assembly: applies the §5 protocol to a full model
//! gradient — quantize the large tensors, ship small tensors (<10K elements)
//! in raw fp32, frame the segments so the receiver can reassemble.
//!
//! Frame layout (byte-aligned, little-endian):
//!   u32 segment_count, then per segment: u32 payload_len | u8 kind | payload
//! where kind 0 = fp32 raw, 1 = compressed.

use anyhow::{ensure, Context, Result};
use rand_core::RngCore;

use crate::coordinator::CompressorSpec;
use crate::models::layout::QuantPlan;
use crate::quant::Compressor;

/// Compressor wrapper that honours a [`QuantPlan`]. Each quantized segment
/// gets its *own* inner compressor instance sized to the segment — stateful
/// compressors (1BitSGD's error-feedback residual) track per-coordinate
/// state, so they must be segment-local.
pub struct PlanCompressor {
    pub plan: QuantPlan,
    inner: Vec<Box<dyn Compressor>>,
}

impl PlanCompressor {
    pub fn from_spec(plan: QuantPlan, spec: &CompressorSpec) -> Self {
        let inner = plan
            .segments
            .iter()
            .filter(|s| s.quantized)
            .map(|s| spec.build(s.len))
            .collect();
        Self { plan, inner }
    }

    /// Encode a full gradient following the plan.
    pub fn compress(&mut self, grad: &[f32], rng: &mut dyn RngCore) -> Vec<u8> {
        assert_eq!(grad.len(), self.plan.total_len(), "gradient/plan mismatch");
        let mut out = Vec::with_capacity(grad.len() / 2 + 64);
        out.extend_from_slice(&(self.plan.segments.len() as u32).to_le_bytes());
        let mut qi = 0usize;
        for seg in &self.plan.segments.clone() {
            let slice = &grad[seg.offset..seg.offset + seg.len];
            let (kind, payload): (u8, Vec<u8>) = if seg.quantized {
                let c = &mut self.inner[qi];
                qi += 1;
                (1, c.compress(slice, rng))
            } else {
                let mut raw = Vec::with_capacity(slice.len() * 4);
                for &x in slice {
                    raw.extend_from_slice(&x.to_le_bytes());
                }
                (0, raw)
            };
            out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
            out.push(kind);
            out.extend_from_slice(&payload);
        }
        out
    }

    /// Decode a message produced by [`Self::compress`] under the same plan.
    pub fn decompress(&self, msg: &[u8]) -> Result<Vec<f32>> {
        let mut pos = 0usize;
        let take = |pos: &mut usize, n: usize| -> Result<&[u8]> {
            ensure!(*pos + n <= msg.len(), "truncated message");
            let s = &msg[*pos..*pos + n];
            *pos += n;
            Ok(s)
        };
        let nseg = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap()) as usize;
        ensure!(nseg == self.plan.segments.len(), "segment count mismatch");
        let mut out = vec![0.0f32; self.plan.total_len()];
        let mut qi = 0usize;
        for seg in &self.plan.segments {
            let len = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap()) as usize;
            let kind = take(&mut pos, 1)?[0];
            let payload = take(&mut pos, len)?;
            let dst = &mut out[seg.offset..seg.offset + seg.len];
            match kind {
                0 => {
                    ensure!(!seg.quantized, "fp32 payload for quantized segment");
                    ensure!(payload.len() == seg.len * 4, "fp32 segment length");
                    for (d, c) in dst.iter_mut().zip(payload.chunks_exact(4)) {
                        *d = f32::from_le_bytes(c.try_into().unwrap());
                    }
                }
                1 => {
                    ensure!(seg.quantized, "compressed payload for fp32 segment");
                    let dec = self.inner[qi]
                        .decompress(payload, seg.len)
                        .context("segment decompress")?;
                    qi += 1;
                    dst.copy_from_slice(&dec);
                }
                k => anyhow::bail!("unknown segment kind {k}"),
            }
        }
        ensure!(pos == msg.len(), "trailing bytes in message");
        Ok(out)
    }

    /// Fused decode-and-accumulate across the plan's segments:
    /// `acc += alpha · decode(msg)`. Uses each inner compressor's sparse
    /// `decompress_add` path (the §6 sparsity optimisation).
    pub fn decompress_add(&self, msg: &[u8], alpha: f32, acc: &mut [f32]) -> Result<()> {
        self.decompress_add_threads(msg, alpha, acc, 1)
    }

    /// [`Self::decompress_add`] with an intra-message thread budget, passed
    /// through to each quantized segment's
    /// [`Compressor::decompress_add_threads`] — directory-bearing segments
    /// decode their buckets in parallel; the accumulator is bit-identical
    /// at every budget.
    pub fn decompress_add_threads(
        &self,
        msg: &[u8],
        alpha: f32,
        acc: &mut [f32],
        threads: usize,
    ) -> Result<()> {
        anyhow::ensure!(acc.len() == self.plan.total_len(), "accumulator/plan mismatch");
        let mut pos = 0usize;
        let take = |pos: &mut usize, n: usize| -> Result<&[u8]> {
            ensure!(*pos + n <= msg.len(), "truncated message");
            let s = &msg[*pos..*pos + n];
            *pos += n;
            Ok(s)
        };
        let nseg = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap()) as usize;
        ensure!(nseg == self.plan.segments.len(), "segment count mismatch");
        let mut qi = 0usize;
        for seg in &self.plan.segments {
            let len = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap()) as usize;
            let kind = take(&mut pos, 1)?[0];
            let payload = take(&mut pos, len)?;
            let dst = &mut acc[seg.offset..seg.offset + seg.len];
            match kind {
                0 => {
                    ensure!(!seg.quantized && payload.len() == seg.len * 4, "fp32 segment");
                    for (d, c) in dst.iter_mut().zip(payload.chunks_exact(4)) {
                        *d += alpha * f32::from_le_bytes(c.try_into().unwrap());
                    }
                }
                1 => {
                    ensure!(seg.quantized, "compressed payload for fp32 segment");
                    self.inner[qi].decompress_add_threads(payload, alpha, dst, threads)?;
                    qi += 1;
                }
                k => anyhow::bail!("unknown segment kind {k}"),
            }
        }
        ensure!(pos == msg.len(), "trailing bytes in message");
        Ok(())
    }

    pub fn name(&self) -> String {
        format!("plan[{}seg]x{}", self.plan.segments.len(), self.inner.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::CompressorSpec;
    use crate::models::layout::{ParamLayout, QuantPlan};
    use crate::util::rng::{self, Xoshiro256};

    fn layout() -> ParamLayout {
        ParamLayout::synthetic(&[
            ("small", vec![100]),           // fp32
            ("big", vec![200, 100]),        // quantized
            ("bias", vec![50]),             // fp32
        ])
    }

    #[test]
    fn skip_segments_are_lossless() {
        let l = layout();
        let plan = QuantPlan::build(&l, 10_000);
        let mut rng = Xoshiro256::from_u64(0);
        let grad = rng::normal_vec(&mut rng, l.total_params());
        let mut pc = PlanCompressor::from_spec(plan, &CompressorSpec::qsgd_4bit());
        let msg = pc.compress(&grad, &mut rng);
        let back = pc.decompress(&msg).unwrap();
        // fp32 segments: exact
        assert_eq!(&back[..100], &grad[..100]);
        assert_eq!(&back[20100..], &grad[20100..]);
        // quantized middle: within one level of a 512-bucket max-norm quantizer
        for (chunk_g, chunk_b) in grad[100..20100].chunks(512).zip(back[100..20100].chunks(512)) {
            let scale = chunk_g.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
            for (g, b) in chunk_g.iter().zip(chunk_b) {
                assert!((g - b).abs() <= scale / 7.0 + 1e-6);
            }
        }
    }

    #[test]
    fn message_smaller_than_fp32() {
        let l = layout();
        let plan = QuantPlan::build(&l, 10_000);
        let mut rng = Xoshiro256::from_u64(1);
        let grad = rng::normal_vec(&mut rng, l.total_params());
        let mut pc = PlanCompressor::from_spec(plan, &CompressorSpec::qsgd_4bit());
        let msg = pc.compress(&grad, &mut rng);
        assert!(msg.len() < l.total_params() * 4 / 3, "msg {} bytes", msg.len());
    }

    #[test]
    fn corrupt_messages_rejected() {
        let l = layout();
        let plan = QuantPlan::build(&l, 10_000);
        let mut rng = Xoshiro256::from_u64(2);
        let grad = rng::normal_vec(&mut rng, l.total_params());
        let mut pc = PlanCompressor::from_spec(plan, &CompressorSpec::qsgd_4bit());
        let msg = pc.compress(&grad, &mut rng);
        assert!(pc.decompress(&msg[..msg.len() - 3]).is_err());
        let mut extra = msg.clone();
        extra.extend_from_slice(&[0, 1, 2]);
        assert!(pc.decompress(&extra).is_err());
        assert!(pc.decompress(&[]).is_err());
    }

    #[test]
    fn fp32_plan_is_identity() {
        let l = layout();
        let plan = QuantPlan::build(&l, usize::MAX); // nothing quantized
        let mut rng = Xoshiro256::from_u64(3);
        let grad = rng::normal_vec(&mut rng, l.total_params());
        let mut pc = PlanCompressor::from_spec(plan, &CompressorSpec::Fp32);
        let msg = pc.compress(&grad, &mut rng);
        assert_eq!(pc.decompress(&msg).unwrap(), grad);
    }
}
