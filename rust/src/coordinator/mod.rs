//! The Layer-3 coordinator: data-parallel training loops with pluggable
//! gradient compression — the paper's system contribution.
//!
//! * [`sync`] — synchronous data-parallel SGD (Algorithm 1) with the §5
//!   protocol (bucket-aware encoding, <10K skip rule, double buffering).
//! * [`svrg`] — QSVRG (§3.3 / Appendix B): quantized variance-reduced
//!   epochs with linear convergence.
//! * [`async_ps`] — asynchronous parameter-server QSGD (Appendix D).
//! * [`exchange`] — plan-aware message assembly (which segments are
//!   quantized, framing, byte accounting).
//! * [`sources`] — gradient providers: Rust-native convex objectives and
//!   PJRT-executed model artifacts (MLP, transformer LM).

pub mod async_ps;
pub mod epoch_sim;
pub mod exchange;
pub mod sources;
pub mod svrg;
pub mod sync;

use std::sync::Arc;

use crate::coding::gradient::Regime;
use crate::coding::{QsgdCodec, TwoPhaseQsgd};
use crate::config::CodecOptions;
use crate::quant::{self, Codec, LevelGrid, Norm};

/// Which gradient compression the coordinator applies — mirrors the paper's
/// experimental arms (32-bit, QSGD b-bit/bucket, 1BitSGD, TernGrad) plus the
/// NUQSGD non-uniform-grid arm for uniform-vs-non-uniform comparisons.
#[derive(Debug, Clone, PartialEq)]
pub enum CompressorSpec {
    Fp32,
    Qsgd { bits: u32, bucket: usize, norm: Norm, regime: Option<Regime> },
    /// NUQSGD: same bit budget as `Qsgd { bits, .. }` but levels on the
    /// exponential grid `{0, 2^-(s-1), …, 1/2, 1}`.
    Nuqsgd { bits: u32, bucket: usize, norm: Norm, regime: Option<Regime> },
    OneBit { column: usize },
    TernGrad { bucket: usize },
}

impl CompressorSpec {
    /// The paper's headline configuration: 4-bit, 512 bucket, max-norm.
    pub fn qsgd_4bit() -> Self {
        CompressorSpec::Qsgd { bits: 4, bucket: 512, norm: Norm::Max, regime: None }
    }

    /// 2-bit / 64-bucket arm (Appendix E uses bucket 64 for 2-bit).
    pub fn qsgd_2bit() -> Self {
        CompressorSpec::Qsgd { bits: 2, bucket: 64, norm: Norm::Max, regime: None }
    }

    /// 8-bit / 512-bucket arm.
    pub fn qsgd_8bit() -> Self {
        CompressorSpec::Qsgd { bits: 8, bucket: 512, norm: Norm::Max, regime: None }
    }

    /// NUQSGD at the headline 4-bit/512 configuration.
    pub fn nuqsgd_4bit() -> Self {
        CompressorSpec::Nuqsgd { bits: 4, bucket: 512, norm: Norm::Max, regime: None }
    }

    /// The exponential grid a `Nuqsgd { bits, .. }` arm quantizes onto.
    pub fn nuqsgd_grid(bits: u32) -> LevelGrid {
        LevelGrid::exponential(quant::levels_for_bits(bits))
    }

    /// Instantiate the shared [`Codec`] for this arm (default
    /// [`CodecOptions`]). QSGD arms ride the fused zero-allocation pipeline
    /// ([`crate::coding::pipeline`]) — bit-identical on the wire to the
    /// two-phase path, which [`Self::codec_two_phase`] keeps as the oracle.
    /// Per-worker encode state comes from [`Codec::session`].
    pub fn codec(&self) -> Arc<dyn Codec> {
        self.codec_with(CodecOptions::default())
    }

    /// [`Self::codec`] with explicit [`CodecOptions`] (directory threshold,
    /// decode thread budget) carried by the codec. Arms whose wire format
    /// has no option-sensitive knobs (fp32/1bit/TernGrad) still honour the
    /// decode thread budget via a thin adapter.
    pub fn codec_with(&self, opts: CodecOptions) -> Arc<dyn Codec> {
        match *self {
            CompressorSpec::Fp32 => Arc::new(WithOptions { inner: quant::Fp32, opts }),
            CompressorSpec::Qsgd { bits, bucket, norm, regime } => Arc::new(
                QsgdCodec::new(quant::levels_for_bits(bits), bucket, norm, regime)
                    .with_options(opts),
            ),
            CompressorSpec::Nuqsgd { bits, bucket, norm, regime } => Arc::new(
                QsgdCodec::with_grid(Self::nuqsgd_grid(bits), bucket, norm, regime)
                    .with_options(opts),
            ),
            CompressorSpec::OneBit { column } => {
                Arc::new(WithOptions { inner: quant::onebit::OneBitCodec::new(column), opts })
            }
            CompressorSpec::TernGrad { bucket } => {
                Arc::new(WithOptions { inner: quant::terngrad::TernGrad::new(bucket), opts })
            }
        }
    }

    /// The pre-fusion two-phase path (quantize, then encode as a separate
    /// pass over materialised buckets). Kept as the property-test oracle for
    /// the fused pipeline — one oracle covering both QSGD and NUQSGD arms
    /// ([`TwoPhaseQsgd`] is grid-generic); remaining arms fall through to
    /// [`Self::codec`].
    pub fn codec_two_phase(&self) -> Arc<dyn Codec> {
        self.codec_two_phase_with(CodecOptions::default())
    }

    /// [`Self::codec_two_phase`] with explicit [`CodecOptions`] — the
    /// oracle must carry the *same* options as the fused codec under
    /// comparison, or the wire bytes legitimately differ (e.g. a custom
    /// directory threshold flips the v3 frame at a different size).
    pub fn codec_two_phase_with(&self, opts: CodecOptions) -> Arc<dyn Codec> {
        match *self {
            CompressorSpec::Qsgd { bits, bucket, norm, regime } => Arc::new(
                TwoPhaseQsgd::new(quant::levels_for_bits(bits), bucket, norm, regime)
                    .with_options(opts),
            ),
            CompressorSpec::Nuqsgd { bits, bucket, norm, regime } => Arc::new(
                TwoPhaseQsgd::with_grid(Self::nuqsgd_grid(bits), bucket, norm, regime)
                    .with_options(opts),
            ),
            _ => self.codec_with(opts),
        }
    }

    pub fn label(&self) -> String {
        match *self {
            CompressorSpec::Fp32 => "32bit".into(),
            CompressorSpec::Qsgd { bits, bucket, .. } => format!("QSGD {bits}bit/{bucket}"),
            CompressorSpec::Nuqsgd { bits, bucket, .. } => format!("NUQSGD {bits}bit/{bucket}"),
            CompressorSpec::OneBit { .. } => "1BitSGD".into(),
            CompressorSpec::TernGrad { .. } => "TernGrad".into(),
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<Self> {
        // e.g. "fp32", "qsgd4", "qsgd2:64", "nuqsgd4:512", "1bit", "terngrad"
        let s = s.to_lowercase();
        if s == "fp32" || s == "32bit" {
            return Ok(CompressorSpec::Fp32);
        }
        if s == "1bit" || s == "onebit" {
            return Ok(CompressorSpec::OneBit { column: 512 });
        }
        if s == "terngrad" {
            return Ok(CompressorSpec::TernGrad { bucket: 512 });
        }
        let (prefix, nonuniform) = match s.strip_prefix("nuqsgd") {
            Some(rest) => (Some(rest), true),
            None => (s.strip_prefix("qsgd"), false),
        };
        if let Some(rest) = prefix {
            let (bits_s, bucket_s) = match rest.split_once(':') {
                Some((b, d)) => (b, Some(d)),
                None => (rest, None),
            };
            let bits: u32 = bits_s.parse().map_err(|_| anyhow::anyhow!("bad bits '{bits_s}'"))?;
            let bucket = match bucket_s {
                Some(d) => d.parse()?,
                None => if bits <= 2 { 64 } else { 512 },
            };
            return Ok(if nonuniform {
                // the exponential grid needs 2^-(s-1) to stay a normal f32,
                // which caps NUQSGD at an 8-bit budget (s = 127)
                anyhow::ensure!(
                    (2..=8).contains(&bits),
                    "nuqsgd supports 2..=8 bits, got {bits}"
                );
                CompressorSpec::Nuqsgd { bits, bucket, norm: Norm::Max, regime: None }
            } else {
                CompressorSpec::Qsgd { bits, bucket, norm: Norm::Max, regime: None }
            });
        }
        anyhow::bail!("unknown compressor '{s}' (fp32|qsgdN[:bucket]|nuqsgdN[:bucket]|1bit|terngrad)")
    }
}

/// Adapter pinning [`CodecOptions`] (today: the decode thread budget) onto
/// codecs whose wire format has no option-sensitive knobs — keeps
/// [`CompressorSpec::codec_with`] honest for every arm instead of silently
/// dropping the options on fp32/1bit/TernGrad.
struct WithOptions<C: Codec> {
    inner: C,
    opts: CodecOptions,
}

impl<C: Codec> Codec for WithOptions<C> {
    fn session(&self, rng: crate::util::rng::Xoshiro256) -> Box<dyn quant::EncodeSession> {
        self.inner.session(rng)
    }

    fn decode(&self, msg: &[u8], n: usize) -> anyhow::Result<Vec<f32>> {
        self.inner.decode(msg, n)
    }

    fn decode_add_threads(
        &self,
        msg: &[u8],
        alpha: f32,
        acc: &mut [f32],
        threads: usize,
    ) -> anyhow::Result<()> {
        self.inner.decode_add_threads(msg, alpha, acc, threads)
    }

    fn decode_threads(&self) -> usize {
        self.opts.decode_threads()
    }

    fn encoded_size_hint(&self, n: usize) -> usize {
        self.inner.encoded_size_hint(n)
    }

    fn wire_format(&self) -> quant::WireFormat {
        self.inner.wire_format()
    }

    fn chunk_align(&self) -> usize {
        self.inner.chunk_align()
    }

    fn supports_chunked_encode(&self) -> bool {
        self.inner.supports_chunked_encode()
    }

    fn name(&self) -> String {
        self.inner.name()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_specs() {
        assert_eq!(CompressorSpec::parse("fp32").unwrap(), CompressorSpec::Fp32);
        assert_eq!(
            CompressorSpec::parse("qsgd4").unwrap(),
            CompressorSpec::Qsgd { bits: 4, bucket: 512, norm: Norm::Max, regime: None }
        );
        assert_eq!(
            CompressorSpec::parse("qsgd2:128").unwrap(),
            CompressorSpec::Qsgd { bits: 2, bucket: 128, norm: Norm::Max, regime: None }
        );
        assert!(matches!(CompressorSpec::parse("1bit").unwrap(), CompressorSpec::OneBit { .. }));
        assert!(CompressorSpec::parse("nuqsgd16").is_err());
        assert_eq!(
            CompressorSpec::parse("nuqsgd4").unwrap(),
            CompressorSpec::Nuqsgd { bits: 4, bucket: 512, norm: Norm::Max, regime: None }
        );
        assert_eq!(
            CompressorSpec::parse("nuqsgd2:128").unwrap(),
            CompressorSpec::Nuqsgd { bits: 2, bucket: 128, norm: Norm::Max, regime: None }
        );
        assert!(CompressorSpec::parse("zstd").is_err());
    }

    #[test]
    fn codec_and_roundtrip_all() {
        use crate::util::rng::Xoshiro256;
        let mut rng = Xoshiro256::from_u64(0);
        let grad: Vec<f32> = crate::util::rng::normal_vec(&mut rng, 700);
        for spec in [
            CompressorSpec::Fp32,
            CompressorSpec::qsgd_2bit(),
            CompressorSpec::qsgd_4bit(),
            CompressorSpec::qsgd_8bit(),
            CompressorSpec::nuqsgd_4bit(),
            CompressorSpec::Nuqsgd { bits: 2, bucket: 64, norm: Norm::Max, regime: None },
            CompressorSpec::OneBit { column: 128 },
            CompressorSpec::TernGrad { bucket: 128 },
        ] {
            let codec = spec.codec();
            let msg = codec.session(Xoshiro256::from_u64(1)).compress(&grad);
            let back = codec.decode(&msg, grad.len()).unwrap();
            assert_eq!(back.len(), grad.len(), "{}", spec.label());
            assert!(codec.decode_threads() >= 1);
        }
        // the segmented collectives align ring chunks to this; the options
        // adapter must forward it rather than fall back to the default
        assert_eq!(CompressorSpec::qsgd_4bit().codec().chunk_align(), 512);
        assert_eq!(CompressorSpec::OneBit { column: 128 }.codec().chunk_align(), 128);
        assert_eq!(CompressorSpec::TernGrad { bucket: 96 }.codec().chunk_align(), 96);
        assert_eq!(CompressorSpec::Fp32.codec().chunk_align(), 1);
    }

    #[test]
    fn codec_options_reach_every_arm() {
        // The decode thread budget must not be silently dropped for any
        // arm, and the two-phase oracle must carry the same options as the
        // fused codec under comparison (here: a tiny directory threshold
        // flips both to v3 frames at the same size).
        use crate::config::CodecOptions;
        use crate::util::rng::Xoshiro256;
        let serial = CodecOptions::serial();
        for spec in [
            CompressorSpec::Fp32,
            CompressorSpec::qsgd_4bit(),
            CompressorSpec::nuqsgd_4bit(),
            CompressorSpec::OneBit { column: 64 },
            CompressorSpec::TernGrad { bucket: 64 },
        ] {
            assert_eq!(spec.codec_with(serial.clone()).decode_threads(), 1, "{}", spec.label());
        }
        let opts = CodecOptions { directory_min_coords: 256, ..CodecOptions::default() };
        let mut rng = Xoshiro256::from_u64(2);
        let grad = crate::util::rng::normal_vec(&mut rng, 1000);
        let spec = CompressorSpec::qsgd_4bit();
        let a = spec.codec_with(opts.clone()).session(Xoshiro256::from_u64(3)).compress(&grad);
        let b = spec
            .codec_two_phase_with(opts)
            .session(Xoshiro256::from_u64(3))
            .compress(&grad);
        assert_eq!(a, b, "oracle must track the fused codec's options");
        // 1000 ≥ 256 with ≥ 2 buckets ⇒ both emit the v3 directory frame
        assert_eq!(a[1] >> 4, crate::coding::gradient::FRAME_VERSION_DIR as u8);
        // custom grids account their in-band point table in the size hint
        let grid = crate::quant::LevelGrid::custom((1..=64).map(|i| i as f32 / 64.0).collect())
            .unwrap();
        let c = crate::coding::QsgdCodec::with_grid(grid, 64, Norm::Max, None);
        let msg = c.session(Xoshiro256::from_u64(4)).compress(&grad[..64]);
        assert!(msg.len() <= c.encoded_size_hint(64), "hint must cover the grid header");
    }
}
