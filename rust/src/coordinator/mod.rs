//! The Layer-3 coordinator: data-parallel training loops with pluggable
//! gradient compression — the paper's system contribution.
//!
//! * [`sync`] — synchronous data-parallel SGD (Algorithm 1) with the §5
//!   protocol (bucket-aware encoding, <10K skip rule, double buffering).
//! * [`svrg`] — QSVRG (§3.3 / Appendix B): quantized variance-reduced
//!   epochs with linear convergence.
//! * [`async_ps`] — asynchronous parameter-server QSGD (Appendix D).
//! * [`exchange`] — plan-aware message assembly (which segments are
//!   quantized, framing, byte accounting).
//! * [`sources`] — gradient providers: Rust-native convex objectives and
//!   PJRT-executed model artifacts (MLP, transformer LM).

pub mod async_ps;
pub mod epoch_sim;
pub mod exchange;
pub mod sources;
pub mod svrg;
pub mod sync;

use crate::coding::gradient::Regime;
use crate::coding::{FusedQsgd, NuqsgdCompressor, QsgdCompressor};
use crate::quant::{self, Compressor, LevelGrid, Norm};

/// Which gradient compression the coordinator applies — mirrors the paper's
/// experimental arms (32-bit, QSGD b-bit/bucket, 1BitSGD, TernGrad) plus the
/// NUQSGD non-uniform-grid arm for uniform-vs-non-uniform comparisons.
#[derive(Debug, Clone, PartialEq)]
pub enum CompressorSpec {
    Fp32,
    Qsgd { bits: u32, bucket: usize, norm: Norm, regime: Option<Regime> },
    /// NUQSGD: same bit budget as `Qsgd { bits, .. }` but levels on the
    /// exponential grid `{0, 2^-(s-1), …, 1/2, 1}`.
    Nuqsgd { bits: u32, bucket: usize, norm: Norm, regime: Option<Regime> },
    OneBit { column: usize },
    TernGrad { bucket: usize },
}

impl CompressorSpec {
    /// The paper's headline configuration: 4-bit, 512 bucket, max-norm.
    pub fn qsgd_4bit() -> Self {
        CompressorSpec::Qsgd { bits: 4, bucket: 512, norm: Norm::Max, regime: None }
    }

    /// 2-bit / 64-bucket arm (Appendix E uses bucket 64 for 2-bit).
    pub fn qsgd_2bit() -> Self {
        CompressorSpec::Qsgd { bits: 2, bucket: 64, norm: Norm::Max, regime: None }
    }

    /// 8-bit / 512-bucket arm.
    pub fn qsgd_8bit() -> Self {
        CompressorSpec::Qsgd { bits: 8, bucket: 512, norm: Norm::Max, regime: None }
    }

    /// NUQSGD at the headline 4-bit/512 configuration.
    pub fn nuqsgd_4bit() -> Self {
        CompressorSpec::Nuqsgd { bits: 4, bucket: 512, norm: Norm::Max, regime: None }
    }

    /// The exponential grid a `Nuqsgd { bits, .. }` arm quantizes onto.
    pub fn nuqsgd_grid(bits: u32) -> LevelGrid {
        LevelGrid::exponential(quant::levels_for_bits(bits))
    }

    /// Instantiate a (possibly stateful) compressor for one worker. QSGD
    /// arms ride the fused zero-allocation pipeline
    /// ([`crate::coding::pipeline`]) — bit-identical on the wire to the
    /// two-phase path, which [`Self::build_two_phase`] keeps as the oracle.
    pub fn build(&self, n: usize) -> Box<dyn Compressor> {
        match *self {
            CompressorSpec::Fp32 => Box::new(quant::Fp32),
            CompressorSpec::Qsgd { bits, bucket, norm, regime } => {
                Box::new(FusedQsgd::new(quant::levels_for_bits(bits), bucket, norm, regime))
            }
            CompressorSpec::Nuqsgd { bits, bucket, norm, regime } => {
                Box::new(FusedQsgd::with_grid(Self::nuqsgd_grid(bits), bucket, norm, regime))
            }
            CompressorSpec::OneBit { column } => Box::new(quant::onebit::OneBitSgd::new(n, column)),
            CompressorSpec::TernGrad { bucket } => Box::new(quant::terngrad::TernGrad::new(bucket)),
        }
    }

    /// The pre-fusion two-phase path (quantize, then encode as a separate
    /// pass over materialised buckets). Kept as the property-test oracle for
    /// the fused pipeline — one oracle per fused arm (QSGD and NUQSGD);
    /// remaining arms fall through to [`Self::build`].
    pub fn build_two_phase(&self, n: usize) -> Box<dyn Compressor> {
        match *self {
            CompressorSpec::Qsgd { bits, bucket, norm, regime } => Box::new(QsgdCompressor {
                s: quant::levels_for_bits(bits),
                bucket,
                norm,
                regime,
            }),
            CompressorSpec::Nuqsgd { bits, bucket, norm, regime } => Box::new(NuqsgdCompressor {
                grid: Self::nuqsgd_grid(bits),
                bucket,
                norm,
                regime,
            }),
            _ => self.build(n),
        }
    }

    pub fn label(&self) -> String {
        match *self {
            CompressorSpec::Fp32 => "32bit".into(),
            CompressorSpec::Qsgd { bits, bucket, .. } => format!("QSGD {bits}bit/{bucket}"),
            CompressorSpec::Nuqsgd { bits, bucket, .. } => format!("NUQSGD {bits}bit/{bucket}"),
            CompressorSpec::OneBit { .. } => "1BitSGD".into(),
            CompressorSpec::TernGrad { .. } => "TernGrad".into(),
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<Self> {
        // e.g. "fp32", "qsgd4", "qsgd2:64", "nuqsgd4:512", "1bit", "terngrad"
        let s = s.to_lowercase();
        if s == "fp32" || s == "32bit" {
            return Ok(CompressorSpec::Fp32);
        }
        if s == "1bit" || s == "onebit" {
            return Ok(CompressorSpec::OneBit { column: 512 });
        }
        if s == "terngrad" {
            return Ok(CompressorSpec::TernGrad { bucket: 512 });
        }
        let (prefix, nonuniform) = match s.strip_prefix("nuqsgd") {
            Some(rest) => (Some(rest), true),
            None => (s.strip_prefix("qsgd"), false),
        };
        if let Some(rest) = prefix {
            let (bits_s, bucket_s) = match rest.split_once(':') {
                Some((b, d)) => (b, Some(d)),
                None => (rest, None),
            };
            let bits: u32 = bits_s.parse().map_err(|_| anyhow::anyhow!("bad bits '{bits_s}'"))?;
            let bucket = match bucket_s {
                Some(d) => d.parse()?,
                None => if bits <= 2 { 64 } else { 512 },
            };
            return Ok(if nonuniform {
                // the exponential grid needs 2^-(s-1) to stay a normal f32,
                // which caps NUQSGD at an 8-bit budget (s = 127)
                anyhow::ensure!(
                    (2..=8).contains(&bits),
                    "nuqsgd supports 2..=8 bits, got {bits}"
                );
                CompressorSpec::Nuqsgd { bits, bucket, norm: Norm::Max, regime: None }
            } else {
                CompressorSpec::Qsgd { bits, bucket, norm: Norm::Max, regime: None }
            });
        }
        anyhow::bail!("unknown compressor '{s}' (fp32|qsgdN[:bucket]|nuqsgdN[:bucket]|1bit|terngrad)")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_specs() {
        assert_eq!(CompressorSpec::parse("fp32").unwrap(), CompressorSpec::Fp32);
        assert_eq!(
            CompressorSpec::parse("qsgd4").unwrap(),
            CompressorSpec::Qsgd { bits: 4, bucket: 512, norm: Norm::Max, regime: None }
        );
        assert_eq!(
            CompressorSpec::parse("qsgd2:128").unwrap(),
            CompressorSpec::Qsgd { bits: 2, bucket: 128, norm: Norm::Max, regime: None }
        );
        assert!(matches!(CompressorSpec::parse("1bit").unwrap(), CompressorSpec::OneBit { .. }));
        assert!(CompressorSpec::parse("nuqsgd16").is_err());
        assert_eq!(
            CompressorSpec::parse("nuqsgd4").unwrap(),
            CompressorSpec::Nuqsgd { bits: 4, bucket: 512, norm: Norm::Max, regime: None }
        );
        assert_eq!(
            CompressorSpec::parse("nuqsgd2:128").unwrap(),
            CompressorSpec::Nuqsgd { bits: 2, bucket: 128, norm: Norm::Max, regime: None }
        );
        assert!(CompressorSpec::parse("zstd").is_err());
    }

    #[test]
    fn build_and_roundtrip_all() {
        let mut rng = crate::util::rng::Xoshiro256::from_u64(0);
        let grad: Vec<f32> = crate::util::rng::normal_vec(&mut rng, 700);
        for spec in [
            CompressorSpec::Fp32,
            CompressorSpec::qsgd_2bit(),
            CompressorSpec::qsgd_4bit(),
            CompressorSpec::qsgd_8bit(),
            CompressorSpec::nuqsgd_4bit(),
            CompressorSpec::Nuqsgd { bits: 2, bucket: 64, norm: Norm::Max, regime: None },
            CompressorSpec::OneBit { column: 128 },
            CompressorSpec::TernGrad { bucket: 128 },
        ] {
            let mut c = spec.build(grad.len());
            let msg = c.compress(&grad, &mut rng);
            let back = c.decompress(&msg, grad.len()).unwrap();
            assert_eq!(back.len(), grad.len(), "{}", spec.label());
        }
    }
}
