//! Asynchronous parameter-server QSGD — Appendix D.
//!
//! Star topology: a central server holds the parameters; each worker loops
//! {pull params, compute stochastic gradient on its (stale) copy, push the
//! *encoded* gradient}. The server applies updates in arrival order. An
//! event-driven simulation over the virtual clock produces bounded-staleness
//! behaviour: a worker's delay is its pull + compute + push interval, so the
//! maximum staleness T of Theorem D.1 is set by the slowest round trip.
//!
//! Encoding is batched onto the scoped pool: a worker's gradient is fixed at
//! pull time (it depends only on the parameters it pulled), so its Encode
//! job is independent of every event that fires before its own push. The
//! event loop therefore encodes lazily — when the next event's message is
//! not ready, *all* pending Encode jobs run concurrently
//! ([`crate::util::par`]). Per-worker RNG streams make the wire bytes
//! bit-identical to encoding at pop time, and arrival order, staleness and
//! the applied updates are unchanged.
//!
//! This loop is also the **S=1 reference oracle** for the sharded
//! parameter-server service: [`crate::ps::run_async`] drives the same event
//! schedule through [`crate::ps::Service`] and must stay bit-identical to
//! this implementation at one shard (seeded golden + live comparison in
//! `rust/tests/ps_service.rs`). Change the RNG stream derivations or the
//! event ordering here and that parity — and the pinned golden — breaks.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use anyhow::Result;

use super::sources::GradSource;
use super::CompressorSpec;
use crate::metrics::{Curve, WireStats};
use crate::models::CostModel;
use crate::quant::{Codec, EncodeSession};
use crate::simnet::SimNet;
use crate::util::par;
use crate::util::rng::Xoshiro256;

pub struct AsyncConfig {
    pub workers: usize,
    /// Total gradient applications at the server.
    pub updates: usize,
    pub compressor: CompressorSpec,
    pub lr: f32,
    pub seed: u64,
    pub net: SimNet,
    pub cost: CostModel,
    /// Per-worker compute-speed multipliers (stragglers); empty ⇒ all 1.
    pub speed: Vec<f64>,
    pub log_every: usize,
}

pub struct AsyncResult {
    pub loss: Curve,
    pub wire: WireStats,
    pub params: Vec<f32>,
    /// Max observed staleness (server updates between a worker's pull and
    /// its push being applied).
    pub max_staleness: usize,
    pub mean_staleness: f64,
    /// Virtual makespan.
    pub vtime: f64,
}

#[derive(PartialEq)]
struct Event {
    at: f64,
    worker: usize,
    /// Server update count when this worker pulled (for staleness).
    pulled_version: usize,
    step: u64,
}

impl Eq for Event {}

impl Ord for Event {
    fn cmp(&self, other: &Self) -> Ordering {
        // min-heap on time
        other.at.partial_cmp(&self.at).unwrap_or(Ordering::Equal)
    }
}

impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// One worker's in-flight state: the gradient it computed on its last pull,
/// and the lazily (batch-)encoded push message. The encode session owns the
/// worker's RNG stream and scratch; `msg` is the worker's reusable wire
/// buffer (`ready` marks whether it holds the current gradient's encoding),
/// so the steady-state encode path performs no allocations. Decoding — the
/// server side — goes through the one shared codec.
struct WorkerState {
    session: Box<dyn EncodeSession>,
    grad: Vec<f32>,
    loss: f32,
    msg: Vec<u8>,
    ready: bool,
}

pub fn run(cfg: &AsyncConfig, source: &mut dyn GradSource) -> Result<AsyncResult> {
    let n = source.dim();
    let mut params: Vec<f32> = {
        let mut r = Xoshiro256::stream(cfg.seed, 0xA54C);
        crate::util::rng::normal_vec(&mut r, n).into_iter().map(|x| x * 0.1).collect()
    };
    let codec = cfg.compressor.codec();
    let msg_cap = codec.encoded_size_hint(n);
    let mut states: Vec<WorkerState> = (0..cfg.workers)
        .map(|w| WorkerState {
            session: codec.session(Xoshiro256::stream(cfg.seed ^ 0xAB5, w as u64)),
            grad: Vec::new(),
            loss: 0.0,
            msg: Vec::with_capacity(msg_cap),
            ready: false,
        })
        .collect();

    let speed = |w: usize| -> f64 {
        cfg.speed.get(w).copied().unwrap_or(1.0).max(1e-6)
    };
    let pull_bytes = n * 4; // dense param pull
    let compute_s = cfg.cost.step_compute_s(source.flops_fwd_per_step(), 1);

    // Initial pulls: every worker computes its first gradient on the initial
    // parameters (identical inputs to computing at pop time — the snapshot a
    // worker pulled cannot change before its own push fires).
    let mut heap = BinaryHeap::new();
    for w in 0..cfg.workers {
        let (loss, grad) = source.loss_and_grad(w, 0, &params)?;
        states[w].loss = loss;
        states[w].grad = grad;
        let t = cfg.net.p2p_time(pull_bytes).secs() + compute_s / speed(w);
        heap.push(Event { at: t, worker: w, pulled_version: 0, step: 0 });
    }

    let mut version = 0usize;
    let mut wire = WireStats::default();
    let mut loss_curve = Curve::default();
    let mut max_stale = 0usize;
    let mut stale_sum = 0usize;
    let mut now = 0.0f64;

    while version < cfg.updates {
        let ev = heap.pop().expect("workers alive");
        now = ev.at;
        let w = ev.worker;

        // Lazy batched encode: if this worker's push message is not ready,
        // every pending Encode job runs concurrently on the scoped pool. In
        // the homogeneous steady state this encodes all K messages in one
        // K-way parallel batch per K events. Each session encodes into its
        // worker's reusable buffer.
        if !states[w].ready {
            par::par_map_mut(&mut states, |_, st| {
                if !st.ready {
                    st.session.encode_into(&st.grad, &mut st.msg);
                    st.ready = true;
                }
            });
        }
        wire.record(states[w].msg.len(), n);
        let push_t = cfg.net.p2p_time(states[w].msg.len()).secs();

        // Server receives and applies (arrival order = heap order here).
        // Fused decode-straight-into-params with α = −lr — no intermediate
        // gradient vector, and a directory-bearing frame decodes its
        // buckets in parallel: the PS handles one message at a time, so
        // intra-message parallelism is the only level available to it. The
        // thread budget comes from the shared codec's options instead of a
        // global env lookup.
        codec.decode_add_threads(&states[w].msg, -cfg.lr, &mut params, codec.decode_threads())?;
        states[w].ready = false;
        let staleness = version - ev.pulled_version;
        max_stale = max_stale.max(staleness);
        stale_sum += staleness;
        version += 1;

        if version % cfg.log_every.max(1) == 0 || version == cfg.updates {
            loss_curve.push(version, states[w].loss as f64);
        }

        // Worker pulls fresh params and immediately computes its next
        // gradient (deterministic in (worker, step, params) per the
        // GradSource contract), leaving the encode for a later batch. Once
        // the update budget is spent the pending events are abandoned, so
        // skip the (possibly expensive) gradient evaluation too.
        if version < cfg.updates {
            let (loss, grad) = source.loss_and_grad(w, ev.step + 1, &params)?;
            states[w].loss = loss;
            states[w].grad = grad;
            let next = now + push_t + cfg.net.p2p_time(pull_bytes).secs() + compute_s / speed(w);
            heap.push(Event { at: next, worker: w, pulled_version: version, step: ev.step + 1 });
        }
    }

    Ok(AsyncResult {
        loss: loss_curve,
        wire,
        params,
        max_staleness: max_stale,
        mean_staleness: stale_sum as f64 / cfg.updates as f64,
        vtime: now,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::sources::ConvexSource;
    use crate::data::QuadraticProblem;
    use crate::simnet::{Link, SimNet, Topology};

    fn cfg(workers: usize, updates: usize, compressor: CompressorSpec) -> AsyncConfig {
        AsyncConfig {
            workers,
            updates,
            compressor,
            lr: 0.02,
            seed: 1,
            net: SimNet::new(workers, Link::new(1e9, 1e-5), Topology::Star),
            cost: CostModel::k80(),
            speed: vec![],
            log_every: 10,
        }
    }

    fn source() -> ConvexSource<QuadraticProblem> {
        ConvexSource::new(QuadraticProblem::generate(256, 24, 1e-3, 0.05, 11), 8, 13)
    }

    #[test]
    fn async_qsgd_converges() {
        let mut src = source();
        let r = run(&cfg(4, 400, CompressorSpec::qsgd_4bit()), &mut src).unwrap();
        let first = r.loss.points[0].1;
        let last = r.loss.tail_mean(3);
        assert!(last < first * 0.3, "{first} -> {last}");
        assert!(r.vtime > 0.0);
    }

    #[test]
    fn async_nuqsgd_converges() {
        // The self-describing v2 frames (grid tag in-band) flow through the
        // parameter server exactly like uniform frames.
        let mut src = source();
        let r = run(&cfg(4, 400, CompressorSpec::nuqsgd_4bit()), &mut src).unwrap();
        let first = r.loss.points[0].1;
        let last = r.loss.tail_mean(3);
        assert!(last < first * 0.45, "{first} -> {last}");
    }

    #[test]
    fn staleness_bounded_by_worker_count() {
        let mut src = source();
        let r = run(&cfg(4, 300, CompressorSpec::qsgd_4bit()), &mut src).unwrap();
        // homogeneous workers: staleness ≈ K−1
        assert!(r.max_staleness <= 2 * 4, "max staleness {}", r.max_staleness);
        assert!(r.mean_staleness > 0.0);
    }

    #[test]
    fn stragglers_increase_staleness() {
        // Make compute dominate the round trip so speed multipliers matter.
        let slow_cost = CostModel { device_flops: 1e6, ..CostModel::k80() };
        let mut src = source();
        let mut c = cfg(4, 300, CompressorSpec::qsgd_4bit());
        c.cost = slow_cost;
        c.speed = vec![1.0, 1.0, 1.0, 0.05]; // one very slow worker
        let r_slow = run(&c, &mut src).unwrap();
        let mut src2 = source();
        let mut cu = cfg(4, 300, CompressorSpec::qsgd_4bit());
        cu.cost = slow_cost;
        let r_uniform = run(&cu, &mut src2).unwrap();
        assert!(
            r_slow.max_staleness > r_uniform.max_staleness,
            "slow {} vs uniform {}",
            r_slow.max_staleness,
            r_uniform.max_staleness
        );
    }

    #[test]
    fn compression_reduces_push_bytes() {
        let mut src = source();
        let rq = run(&cfg(2, 100, CompressorSpec::qsgd_4bit()), &mut src).unwrap();
        let mut src2 = source();
        let rf = run(&cfg(2, 100, CompressorSpec::Fp32), &mut src2).unwrap();
        assert!(rq.wire.payload_bytes * 3 < rf.wire.payload_bytes);
    }
}
