//! Training metrics: virtual/wall time breakdowns, bytes-on-wire accounting,
//! loss curves, and the table printers the benches use to emit paper-style
//! rows.

use crate::simnet::VTime;
use crate::util::stats;

/// Per-step cost breakdown accumulated over a run (virtual seconds — the
/// simulated cluster clock; wall time is tracked by callers where relevant).
#[derive(Debug, Clone, Default)]
pub struct Breakdown {
    /// Fwd+bwd compute.
    pub compute: VTime,
    /// Quantize + entropy-code (the paper folds this into communication).
    pub encode: VTime,
    /// Wire transfer.
    pub transfer: VTime,
    /// Decode + aggregate.
    pub decode: VTime,
    pub steps: usize,
}

impl Breakdown {
    /// The paper's "communication" bucket: encode + transfer + decode.
    pub fn communication(&self) -> VTime {
        self.encode + self.transfer + self.decode
    }

    pub fn total(&self) -> VTime {
        self.compute + self.communication()
    }

    /// Total with double buffering (§5 Protocol): communication of step t
    /// overlaps with computation of step t+1, so epoch time ≈
    /// steps · max(comp, comm) + the non-overlapped tail.
    pub fn total_double_buffered(&self) -> VTime {
        let per_comp = self.compute.secs() / self.steps.max(1) as f64;
        let per_comm = self.communication().secs() / self.steps.max(1) as f64;
        VTime(self.steps as f64 * per_comp.max(per_comm) + per_comp.min(per_comm))
    }

    /// Schedule-derived overlapped total: per-layer bucket readiness instead
    /// of the single [`Self::total_double_buffered`] lower bound.
    ///
    /// `schedule` lists the transmission units of one step in the order they
    /// go on the wire (backprop reverse layout order — see
    /// [`crate::models::layout::ParamLayout::overlap_schedule`]); each entry
    /// is `(readiness, share)` where `readiness` is the fraction of the
    /// step's compute after which the unit's gradient exists, and `share` is
    /// its fraction of the step's communication (shares are normalized here,
    /// so callers may pass raw sizes). `fraction` is the §5 overlap knob
    /// φ ∈ [0, 1]: at φ = 0 every unit waits for the full backprop (the
    /// serial `compute + comm` of [`Self::total`], exactly); at φ = 1 unit
    /// `i` may start as soon as `readiness_i · compute` has elapsed.
    ///
    /// Communication is serialized on the link in schedule order:
    /// `start_i = max(ready_i, finish_{i-1})`, `finish_i = start_i +
    /// share_i · comm`. Readiness times shrink linearly in φ
    /// (`ready_i = comp · (1 − φ·(1 − readiness_i))`), so the result is
    /// monotonically non-increasing in φ and always within
    /// `[max(comp, comm), comp + comm]` per step.
    pub fn total_overlapped(&self, schedule: &[(f64, f64)], fraction: f64) -> VTime {
        let steps = self.steps.max(1) as f64;
        let comp = self.compute.secs() / steps;
        let comm = self.communication().secs() / steps;
        let phi = fraction.clamp(0.0, 1.0);
        let whole = [(1.0f64, 1.0f64)];
        let sched: &[(f64, f64)] = if schedule.is_empty() { &whole } else { schedule };
        let total_share: f64 = sched.iter().map(|&(_, s)| s.max(0.0)).sum();
        let mut finish = 0.0f64;
        for &(readiness, share) in sched {
            let r = readiness.clamp(0.0, 1.0);
            let ready = comp * (1.0 - phi * (1.0 - r));
            let start = ready.max(finish);
            let norm = if total_share > 0.0 { share.max(0.0) / total_share } else { 0.0 };
            finish = start + comm * norm;
        }
        // The step is not done before backprop is (guards schedules whose
        // last entry declares readiness < 1).
        VTime(self.steps as f64 * finish.max(comp))
    }

    pub fn comm_fraction(&self) -> f64 {
        let t = self.total().secs();
        if t <= 0.0 {
            0.0
        } else {
            self.communication().secs() / t
        }
    }

    pub fn add(&mut self, other: &Breakdown) {
        self.compute += other.compute;
        self.encode += other.encode;
        self.transfer += other.transfer;
        self.decode += other.decode;
        self.steps += other.steps;
    }
}

/// Measured wall-clock seconds per communication phase, recorded by the
/// socket transport next to the modeled α–β [`Breakdown`] so measured and
/// modeled communication time are directly comparable in one `RunResult`.
/// All-zero for simnet-only runs — nothing real was timed there.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct WallClock {
    /// Quantize + entropy-code (this rank only).
    pub encode_s: f64,
    /// Blocking socket sends/receives, including peer-skew wait time.
    pub transfer_s: f64,
    /// Decode + aggregate (this rank only).
    pub decode_s: f64,
}

impl WallClock {
    pub fn total_s(&self) -> f64 {
        self.encode_s + self.transfer_s + self.decode_s
    }

    pub fn is_zero(&self) -> bool {
        self.encode_s == 0.0 && self.transfer_s == 0.0 && self.decode_s == 0.0
    }

    pub fn add(&mut self, other: &WallClock) {
        self.encode_s += other.encode_s;
        self.transfer_s += other.transfer_s;
        self.decode_s += other.decode_s;
    }

    /// Export into the unified registry (`wall.*` histogram rows — one
    /// sample per rank, so cross-rank merge yields the distribution).
    pub fn export(&self, m: &mut crate::obs::MetricSet) {
        m.observe("wall.encode_s", self.encode_s);
        m.observe("wall.transfer_s", self.transfer_s);
        m.observe("wall.decode_s", self.decode_s);
    }
}

/// Wall-clock occupancy of the exchange loop, attributed to what the main
/// thread was doing: blocked on socket I/O, running codec work (quantize /
/// entropy-code / decode), or idle (scheduling gaps, pipeline stalls,
/// control-plane rounds). Recorded by the socket transport per exchange so
/// the pipelined path's win — io-blocked time shrinking while codec time
/// stays put — is directly measurable. All-zero for simnet-only runs.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Occupancy {
    /// Blocked in socket sends/receives (includes peer-skew wait).
    pub io_blocked_s: f64,
    /// Encode + decode work on this rank.
    pub codec_s: f64,
    /// Exchange wall time not attributed to either bucket (never negative).
    pub idle_s: f64,
}

impl Occupancy {
    pub fn total_s(&self) -> f64 {
        self.io_blocked_s + self.codec_s + self.idle_s
    }

    /// Attribute one exchange: `total_s` is the measured wall time of the
    /// whole exchange, of which `io_s` was spent blocked on sockets and
    /// `codec_s` in encode/decode. The remainder (clamped at zero — the
    /// buckets are themselves measured and can overshoot by timer noise)
    /// lands in `idle_s`.
    pub fn record(&mut self, total_s: f64, io_s: f64, codec_s: f64) {
        self.io_blocked_s += io_s;
        self.codec_s += codec_s;
        self.idle_s += (total_s - io_s - codec_s).max(0.0);
    }

    pub fn add(&mut self, other: &Occupancy) {
        self.io_blocked_s += other.io_blocked_s;
        self.codec_s += other.codec_s;
        self.idle_s += other.idle_s;
    }

    /// Export into the unified registry (`occupancy.*` histogram rows).
    pub fn export(&self, m: &mut crate::obs::MetricSet) {
        m.observe("occupancy.io_blocked_s", self.io_blocked_s);
        m.observe("occupancy.codec_s", self.codec_s);
        m.observe("occupancy.idle_s", self.idle_s);
    }
}

/// Bits-on-wire accounting for one worker's outbound traffic.
#[derive(Debug, Clone, Default)]
pub struct WireStats {
    pub messages: u64,
    pub payload_bytes: u64,
    /// What the same payloads would cost uncompressed (n·4 bytes each).
    pub fp32_equiv_bytes: u64,
}

impl WireStats {
    pub fn record(&mut self, payload: usize, n_coords: usize) {
        self.messages += 1;
        self.payload_bytes += payload as u64;
        self.fp32_equiv_bytes += n_coords as u64 * 4;
    }

    /// Record one payload traversing `copies` links (a broadcast fan-out:
    /// an all-to-all message reaches K−1 peers, a leader's frame reaches
    /// its group). Payload and fp32-equivalent scale together, so
    /// compression ratios are unaffected by the fan-out factor.
    pub fn record_fanout(&mut self, payload: usize, n_coords: usize, copies: usize) {
        self.messages += copies as u64;
        self.payload_bytes += payload as u64 * copies as u64;
        self.fp32_equiv_bytes += n_coords as u64 * 4 * copies as u64;
    }

    /// Bandwidth saving factor vs fp32 (the paper's headline ~5.7× etc).
    pub fn compression_ratio(&self) -> f64 {
        if self.payload_bytes == 0 {
            return 1.0;
        }
        self.fp32_equiv_bytes as f64 / self.payload_bytes as f64
    }

    pub fn bits_per_coordinate(&self) -> f64 {
        if self.fp32_equiv_bytes == 0 {
            return 0.0;
        }
        self.payload_bytes as f64 * 8.0 / (self.fp32_equiv_bytes as f64 / 4.0)
    }

    pub fn add(&mut self, other: &WireStats) {
        self.messages += other.messages;
        self.payload_bytes += other.payload_bytes;
        self.fp32_equiv_bytes += other.fp32_equiv_bytes;
    }

    /// Export into the unified registry (`wire.*` counter rows).
    pub fn export(&self, m: &mut crate::obs::MetricSet) {
        m.counter("wire.messages", self.messages);
        m.counter("wire.payload_bytes", self.payload_bytes);
        m.counter("wire.fp32_equiv_bytes", self.fp32_equiv_bytes);
    }
}

/// Per-run fault and recovery accounting, filled by the scenario layer:
/// the simnet charges stragglers/corruption into virtual time and the
/// socket transport counts real re-requests, resends, and renormalized
/// steps. All-zero when no scenario is configured.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultStats {
    /// Frames that arrived damaged (failed decode validation).
    pub corrupt_frames: u64,
    /// Re-requests sent to a live peer for a damaged frame.
    pub rerequests: u64,
    /// Resends served to peers that asked for one.
    pub resends_served: u64,
    /// Workers declared dead (io-timeout or closed connection).
    pub dead_workers: u64,
    /// Steps whose mean was renormalized over a partial contributor set.
    pub renormalized_steps: u64,
    /// Simnet ops that drew a straggler slowdown.
    pub straggler_hops: u64,
}

impl FaultStats {
    pub fn any(&self) -> bool {
        *self != FaultStats::default()
    }

    pub fn add(&mut self, other: &FaultStats) {
        self.corrupt_frames += other.corrupt_frames;
        self.rerequests += other.rerequests;
        self.resends_served += other.resends_served;
        self.dead_workers += other.dead_workers;
        self.renormalized_steps += other.renormalized_steps;
        self.straggler_hops += other.straggler_hops;
    }

    /// Export into the unified registry (`faults.*` counter rows).
    pub fn export(&self, m: &mut crate::obs::MetricSet) {
        m.counter("faults.corrupt_frames", self.corrupt_frames);
        m.counter("faults.rerequests", self.rerequests);
        m.counter("faults.resends_served", self.resends_served);
        m.counter("faults.dead_workers", self.dead_workers);
        m.counter("faults.renormalized_steps", self.renormalized_steps);
        m.counter("faults.straggler_hops", self.straggler_hops);
    }
}

/// Latency accumulator over the log-bucketed [`crate::obs::Histogram`] —
/// bounded memory (one 64 KiB bucket array no matter how many ops are
/// recorded), ~0.8% relative quantile error, exact mean. Used by the
/// parameter-server service for its push-decode / pull-encode service times
/// and by the traffic harness for client round trips. Recording is O(1) and
/// allocation-free after first touch; [`Latency::add`] is bucket-wise and
/// associative, so per-shard and per-client accumulators fold in any order.
#[derive(Debug, Clone, Default)]
pub struct Latency {
    hist: crate::obs::Histogram,
}

impl Latency {
    pub fn record_ns(&mut self, ns: f64) {
        self.hist.record(ns);
    }

    pub fn record(&mut self, elapsed: std::time::Duration) {
        self.record_ns(elapsed.as_secs_f64() * 1e9);
    }

    pub fn count(&self) -> usize {
        self.hist.count() as usize
    }

    pub fn is_empty(&self) -> bool {
        self.hist.is_empty()
    }

    /// p-th percentile in nanoseconds; 0.0 when nothing was recorded (keeps
    /// downstream JSON finite instead of NaN).
    pub fn percentile_ns(&self, p: f64) -> f64 {
        if self.hist.is_empty() {
            return 0.0;
        }
        self.hist.percentile(p)
    }

    pub fn p50_ns(&self) -> f64 {
        self.percentile_ns(50.0)
    }

    pub fn p99_ns(&self) -> f64 {
        self.percentile_ns(99.0)
    }

    pub fn mean_ns(&self) -> f64 {
        if self.hist.is_empty() {
            return 0.0;
        }
        self.hist.mean()
    }

    pub fn add(&mut self, other: &Latency) {
        self.hist.merge(&other.hist);
    }

    /// Histogram view, for exporting into a [`crate::obs::MetricSet`] row.
    pub fn hist(&self) -> &crate::obs::Histogram {
        &self.hist
    }

    /// `"p50 12.3µs p99 45.6µs (n=789)"` — the one-line form the CLI and
    /// bench output print.
    pub fn summary(&self) -> String {
        format!(
            "p50 {} p99 {} (n={})",
            stats::fmt_duration(self.p50_ns() / 1e9),
            stats::fmt_duration(self.p99_ns() / 1e9),
            self.count()
        )
    }
}

/// A (step → value) curve, e.g. loss or accuracy over training.
#[derive(Debug, Clone, Default)]
pub struct Curve {
    pub points: Vec<(usize, f64)>,
}

impl Curve {
    pub fn push(&mut self, step: usize, value: f64) {
        self.points.push((step, value));
    }

    pub fn last(&self) -> Option<f64> {
        self.points.last().map(|&(_, v)| v)
    }

    /// Mean of the final `k` values (smoothed terminal value).
    pub fn tail_mean(&self, k: usize) -> f64 {
        let vals: Vec<f64> =
            self.points.iter().rev().take(k).map(|&(_, v)| v).collect();
        stats::mean(&vals)
    }

    /// First step at which the curve drops to ≤ `target` (loss) — used for
    /// "time to target accuracy/loss" comparisons (Fig. 3).
    pub fn first_step_below(&self, target: f64) -> Option<usize> {
        self.points.iter().find(|&&(_, v)| v <= target).map(|&(s, _)| s)
    }

    /// Render as compact text for logs: `step:value` pairs, subsampled.
    pub fn sparkline(&self, max_points: usize) -> String {
        if self.points.is_empty() {
            return String::new();
        }
        let stride = (self.points.len() / max_points.max(1)).max(1);
        self.points
            .iter()
            .step_by(stride)
            .map(|(s, v)| format!("{s}:{v:.4}"))
            .collect::<Vec<_>>()
            .join(" ")
    }
}

/// Fixed-width table printer (paper-style rows in bench output).
pub struct Table {
    headers: Vec<String>,
    widths: Vec<usize>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Self {
            widths: headers.iter().map(|h| h.len()).collect(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cols: &[String]) {
        assert_eq!(cols.len(), self.headers.len());
        for (w, c) in self.widths.iter_mut().zip(cols) {
            *w = (*w).max(c.len());
        }
        self.rows.push(cols.to_vec());
    }

    pub fn print(&self) {
        let line = |cols: &[String]| {
            let mut s = String::from("| ");
            for (c, w) in cols.iter().zip(&self.widths) {
                s.push_str(&format!("{:<width$} | ", c, width = w));
            }
            println!("{}", s.trim_end());
        };
        line(&self.headers);
        let sep: Vec<String> = self.widths.iter().map(|w| "-".repeat(*w)).collect();
        line(&sep);
        for r in &self.rows {
            line(r);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn breakdown_math() {
        let b = Breakdown {
            compute: VTime(6.0),
            encode: VTime(1.0),
            transfer: VTime(2.0),
            decode: VTime(1.0),
            steps: 2,
        };
        assert_eq!(b.communication().secs(), 4.0);
        assert_eq!(b.total().secs(), 10.0);
        assert!((b.comm_fraction() - 0.4).abs() < 1e-12);
        // double buffered: 2 steps · max(3, 2) + min(3, 2) = 8
        assert!((b.total_double_buffered().secs() - 8.0).abs() < 1e-12);
    }

    #[test]
    fn overlapped_total_bounds_and_endpoints() {
        let b = Breakdown {
            compute: VTime(6.0),
            encode: VTime(1.0),
            transfer: VTime(2.0),
            decode: VTime(1.0),
            steps: 2,
        };
        // Two units: the late half of the net ready at 40% of backprop, the
        // early half only when backprop finishes.
        let sched = [(0.4, 1.0), (1.0, 1.0)];
        // φ = 0 reproduces the serial total exactly.
        assert_eq!(b.total_overlapped(&sched, 0.0).secs().to_bits(), b.total().secs().to_bits());
        // Empty schedule = one whole-gradient unit: serial at every φ > 0
        // still ends at comp + comm (nothing is ready before comp).
        assert_eq!(b.total_overlapped(&[], 0.5).secs().to_bits(), b.total().secs().to_bits());
        // Monotone non-increasing in φ, and within [max(comp, comm), serial].
        let per_comp = 3.0;
        let per_comm = 2.0;
        let mut prev = f64::INFINITY;
        for phi in [0.0, 0.25, 0.5, 0.75, 1.0] {
            let t = b.total_overlapped(&sched, phi).secs();
            assert!(t <= prev + 1e-12, "φ={phi}: {t} > {prev}");
            assert!(t <= b.total().secs() + 1e-12);
            assert!(t >= 2.0 * per_comp.max(per_comm) - 1e-12);
            prev = t;
        }
        // φ = 1 by hand: unit 1 ready at 0.4·3 = 1.2, finish 2.2; unit 2
        // ready at 3.0, finish 4.0 ⇒ 2 steps · 4.0 = 8.0.
        assert!((b.total_overlapped(&sched, 1.0).secs() - 8.0).abs() < 1e-12);
        // Raw sizes normalize: scaling all shares changes nothing.
        let scaled = [(0.4, 512.0), (1.0, 512.0)];
        assert_eq!(
            b.total_overlapped(&scaled, 0.7).secs().to_bits(),
            b.total_overlapped(&sched, 0.7).secs().to_bits()
        );
    }

    #[test]
    fn occupancy_attribution_clamps_idle() {
        let mut o = Occupancy::default();
        o.record(10.0, 4.0, 3.0);
        assert_eq!(o.io_blocked_s, 4.0);
        assert_eq!(o.codec_s, 3.0);
        assert_eq!(o.idle_s, 3.0);
        // measured buckets can overshoot the outer timer: idle clamps at 0
        o.record(1.0, 0.8, 0.4);
        assert_eq!(o.idle_s, 3.0);
        assert!((o.total_s() - 11.2).abs() < 1e-12);
        let mut sum = Occupancy::default();
        sum.add(&o);
        sum.add(&o);
        assert!((sum.io_blocked_s - 9.6).abs() < 1e-12);
    }

    #[test]
    fn wire_stats() {
        let mut w = WireStats::default();
        w.record(100, 1000); // 100 bytes for 1000 coords
        w.record(100, 1000);
        assert_eq!(w.messages, 2);
        assert!((w.compression_ratio() - 40.0).abs() < 1e-12);
        assert!((w.bits_per_coordinate() - 0.8).abs() < 1e-12);
        // fan-out scales payload and fp32-equivalent together: the ratio is
        // invariant, the byte totals are not
        let mut f = WireStats::default();
        f.record_fanout(100, 1000, 3);
        assert_eq!(f.messages, 3);
        assert_eq!(f.payload_bytes, 300);
        assert!((f.compression_ratio() - 40.0).abs() < 1e-12);
        f.record_fanout(100, 1000, 0);
        assert_eq!(f.messages, 3);
    }

    #[test]
    fn fault_stats_accumulate() {
        let mut a = FaultStats::default();
        assert!(!a.any());
        let b = FaultStats {
            corrupt_frames: 2,
            rerequests: 2,
            resends_served: 1,
            dead_workers: 1,
            renormalized_steps: 3,
            straggler_hops: 7,
        };
        a.add(&b);
        a.add(&b);
        assert!(a.any());
        assert_eq!(a.corrupt_frames, 4);
        assert_eq!(a.straggler_hops, 14);
    }

    #[test]
    fn latency_percentiles_and_merge() {
        let mut l = Latency::default();
        assert!(l.is_empty());
        assert_eq!(l.p50_ns(), 0.0);
        assert_eq!(l.p99_ns(), 0.0);
        assert_eq!(l.mean_ns(), 0.0);
        for ns in [100.0, 200.0, 300.0, 400.0] {
            l.record_ns(ns);
        }
        l.record(std::time::Duration::from_nanos(500));
        assert_eq!(l.count(), 5);
        // Quantiles come from the log-bucketed histogram: ~0.8% relative
        // error, so compare against its bound rather than bit-exactly.
        let p50 = l.p50_ns();
        assert!((p50 - 300.0).abs() / 300.0 <= 1.0 / 64.0, "p50 {p50}");
        assert!((l.mean_ns() - 300.0).abs() < 1e-9);
        assert!(l.p99_ns() > l.p50_ns());
        let mut sum = Latency::default();
        sum.add(&l);
        sum.add(&l);
        assert_eq!(sum.count(), 10);
        assert!(sum.summary().contains("n=10"));
    }

    #[test]
    fn curve_queries() {
        let mut c = Curve::default();
        for (s, v) in [(0, 5.0), (10, 3.0), (20, 1.5), (30, 1.0)] {
            c.push(s, v);
        }
        assert_eq!(c.first_step_below(2.0), Some(20));
        assert_eq!(c.first_step_below(0.5), None);
        assert_eq!(c.last(), Some(1.0));
        assert!((c.tail_mean(2) - 1.25).abs() < 1e-12);
        assert!(!c.sparkline(2).is_empty());
    }

    #[test]
    fn table_renders() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["1".into(), "2".into()]);
        t.print(); // smoke: no panic
    }
}
