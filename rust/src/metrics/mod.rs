//! Training metrics: virtual/wall time breakdowns, bytes-on-wire accounting,
//! loss curves, and the table printers the benches use to emit paper-style
//! rows.

use crate::simnet::VTime;
use crate::util::stats;

/// Per-step cost breakdown accumulated over a run (virtual seconds — the
/// simulated cluster clock; wall time is tracked by callers where relevant).
#[derive(Debug, Clone, Default)]
pub struct Breakdown {
    /// Fwd+bwd compute.
    pub compute: VTime,
    /// Quantize + entropy-code (the paper folds this into communication).
    pub encode: VTime,
    /// Wire transfer.
    pub transfer: VTime,
    /// Decode + aggregate.
    pub decode: VTime,
    pub steps: usize,
}

impl Breakdown {
    /// The paper's "communication" bucket: encode + transfer + decode.
    pub fn communication(&self) -> VTime {
        self.encode + self.transfer + self.decode
    }

    pub fn total(&self) -> VTime {
        self.compute + self.communication()
    }

    /// Total with double buffering (§5 Protocol): communication of step t
    /// overlaps with computation of step t+1, so epoch time ≈
    /// steps · max(comp, comm) + the non-overlapped tail.
    pub fn total_double_buffered(&self) -> VTime {
        let per_comp = self.compute.secs() / self.steps.max(1) as f64;
        let per_comm = self.communication().secs() / self.steps.max(1) as f64;
        VTime(self.steps as f64 * per_comp.max(per_comm) + per_comp.min(per_comm))
    }

    pub fn comm_fraction(&self) -> f64 {
        let t = self.total().secs();
        if t <= 0.0 {
            0.0
        } else {
            self.communication().secs() / t
        }
    }

    pub fn add(&mut self, other: &Breakdown) {
        self.compute += other.compute;
        self.encode += other.encode;
        self.transfer += other.transfer;
        self.decode += other.decode;
        self.steps += other.steps;
    }
}

/// Measured wall-clock seconds per communication phase, recorded by the
/// socket transport next to the modeled α–β [`Breakdown`] so measured and
/// modeled communication time are directly comparable in one `RunResult`.
/// All-zero for simnet-only runs — nothing real was timed there.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct WallClock {
    /// Quantize + entropy-code (this rank only).
    pub encode_s: f64,
    /// Blocking socket sends/receives, including peer-skew wait time.
    pub transfer_s: f64,
    /// Decode + aggregate (this rank only).
    pub decode_s: f64,
}

impl WallClock {
    pub fn total_s(&self) -> f64 {
        self.encode_s + self.transfer_s + self.decode_s
    }

    pub fn is_zero(&self) -> bool {
        self.encode_s == 0.0 && self.transfer_s == 0.0 && self.decode_s == 0.0
    }

    pub fn add(&mut self, other: &WallClock) {
        self.encode_s += other.encode_s;
        self.transfer_s += other.transfer_s;
        self.decode_s += other.decode_s;
    }
}

/// Bits-on-wire accounting for one worker's outbound traffic.
#[derive(Debug, Clone, Default)]
pub struct WireStats {
    pub messages: u64,
    pub payload_bytes: u64,
    /// What the same payloads would cost uncompressed (n·4 bytes each).
    pub fp32_equiv_bytes: u64,
}

impl WireStats {
    pub fn record(&mut self, payload: usize, n_coords: usize) {
        self.messages += 1;
        self.payload_bytes += payload as u64;
        self.fp32_equiv_bytes += n_coords as u64 * 4;
    }

    /// Record one payload traversing `copies` links (a broadcast fan-out:
    /// an all-to-all message reaches K−1 peers, a leader's frame reaches
    /// its group). Payload and fp32-equivalent scale together, so
    /// compression ratios are unaffected by the fan-out factor.
    pub fn record_fanout(&mut self, payload: usize, n_coords: usize, copies: usize) {
        self.messages += copies as u64;
        self.payload_bytes += payload as u64 * copies as u64;
        self.fp32_equiv_bytes += n_coords as u64 * 4 * copies as u64;
    }

    /// Bandwidth saving factor vs fp32 (the paper's headline ~5.7× etc).
    pub fn compression_ratio(&self) -> f64 {
        if self.payload_bytes == 0 {
            return 1.0;
        }
        self.fp32_equiv_bytes as f64 / self.payload_bytes as f64
    }

    pub fn bits_per_coordinate(&self) -> f64 {
        if self.fp32_equiv_bytes == 0 {
            return 0.0;
        }
        self.payload_bytes as f64 * 8.0 / (self.fp32_equiv_bytes as f64 / 4.0)
    }

    pub fn add(&mut self, other: &WireStats) {
        self.messages += other.messages;
        self.payload_bytes += other.payload_bytes;
        self.fp32_equiv_bytes += other.fp32_equiv_bytes;
    }
}

/// Per-run fault and recovery accounting, filled by the scenario layer:
/// the simnet charges stragglers/corruption into virtual time and the
/// socket transport counts real re-requests, resends, and renormalized
/// steps. All-zero when no scenario is configured.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultStats {
    /// Frames that arrived damaged (failed decode validation).
    pub corrupt_frames: u64,
    /// Re-requests sent to a live peer for a damaged frame.
    pub rerequests: u64,
    /// Resends served to peers that asked for one.
    pub resends_served: u64,
    /// Workers declared dead (io-timeout or closed connection).
    pub dead_workers: u64,
    /// Steps whose mean was renormalized over a partial contributor set.
    pub renormalized_steps: u64,
    /// Simnet ops that drew a straggler slowdown.
    pub straggler_hops: u64,
}

impl FaultStats {
    pub fn any(&self) -> bool {
        *self != FaultStats::default()
    }

    pub fn add(&mut self, other: &FaultStats) {
        self.corrupt_frames += other.corrupt_frames;
        self.rerequests += other.rerequests;
        self.resends_served += other.resends_served;
        self.dead_workers += other.dead_workers;
        self.renormalized_steps += other.renormalized_steps;
        self.straggler_hops += other.straggler_hops;
    }
}

/// A (step → value) curve, e.g. loss or accuracy over training.
#[derive(Debug, Clone, Default)]
pub struct Curve {
    pub points: Vec<(usize, f64)>,
}

impl Curve {
    pub fn push(&mut self, step: usize, value: f64) {
        self.points.push((step, value));
    }

    pub fn last(&self) -> Option<f64> {
        self.points.last().map(|&(_, v)| v)
    }

    /// Mean of the final `k` values (smoothed terminal value).
    pub fn tail_mean(&self, k: usize) -> f64 {
        let vals: Vec<f64> =
            self.points.iter().rev().take(k).map(|&(_, v)| v).collect();
        stats::mean(&vals)
    }

    /// First step at which the curve drops to ≤ `target` (loss) — used for
    /// "time to target accuracy/loss" comparisons (Fig. 3).
    pub fn first_step_below(&self, target: f64) -> Option<usize> {
        self.points.iter().find(|&&(_, v)| v <= target).map(|&(s, _)| s)
    }

    /// Render as compact text for logs: `step:value` pairs, subsampled.
    pub fn sparkline(&self, max_points: usize) -> String {
        if self.points.is_empty() {
            return String::new();
        }
        let stride = (self.points.len() / max_points.max(1)).max(1);
        self.points
            .iter()
            .step_by(stride)
            .map(|(s, v)| format!("{s}:{v:.4}"))
            .collect::<Vec<_>>()
            .join(" ")
    }
}

/// Fixed-width table printer (paper-style rows in bench output).
pub struct Table {
    headers: Vec<String>,
    widths: Vec<usize>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Self {
            widths: headers.iter().map(|h| h.len()).collect(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cols: &[String]) {
        assert_eq!(cols.len(), self.headers.len());
        for (w, c) in self.widths.iter_mut().zip(cols) {
            *w = (*w).max(c.len());
        }
        self.rows.push(cols.to_vec());
    }

    pub fn print(&self) {
        let line = |cols: &[String]| {
            let mut s = String::from("| ");
            for (c, w) in cols.iter().zip(&self.widths) {
                s.push_str(&format!("{:<width$} | ", c, width = w));
            }
            println!("{}", s.trim_end());
        };
        line(&self.headers);
        let sep: Vec<String> = self.widths.iter().map(|w| "-".repeat(*w)).collect();
        line(&sep);
        for r in &self.rows {
            line(r);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn breakdown_math() {
        let b = Breakdown {
            compute: VTime(6.0),
            encode: VTime(1.0),
            transfer: VTime(2.0),
            decode: VTime(1.0),
            steps: 2,
        };
        assert_eq!(b.communication().secs(), 4.0);
        assert_eq!(b.total().secs(), 10.0);
        assert!((b.comm_fraction() - 0.4).abs() < 1e-12);
        // double buffered: 2 steps · max(3, 2) + min(3, 2) = 8
        assert!((b.total_double_buffered().secs() - 8.0).abs() < 1e-12);
    }

    #[test]
    fn wire_stats() {
        let mut w = WireStats::default();
        w.record(100, 1000); // 100 bytes for 1000 coords
        w.record(100, 1000);
        assert_eq!(w.messages, 2);
        assert!((w.compression_ratio() - 40.0).abs() < 1e-12);
        assert!((w.bits_per_coordinate() - 0.8).abs() < 1e-12);
        // fan-out scales payload and fp32-equivalent together: the ratio is
        // invariant, the byte totals are not
        let mut f = WireStats::default();
        f.record_fanout(100, 1000, 3);
        assert_eq!(f.messages, 3);
        assert_eq!(f.payload_bytes, 300);
        assert!((f.compression_ratio() - 40.0).abs() < 1e-12);
        f.record_fanout(100, 1000, 0);
        assert_eq!(f.messages, 3);
    }

    #[test]
    fn fault_stats_accumulate() {
        let mut a = FaultStats::default();
        assert!(!a.any());
        let b = FaultStats {
            corrupt_frames: 2,
            rerequests: 2,
            resends_served: 1,
            dead_workers: 1,
            renormalized_steps: 3,
            straggler_hops: 7,
        };
        a.add(&b);
        a.add(&b);
        assert!(a.any());
        assert_eq!(a.corrupt_frames, 4);
        assert_eq!(a.straggler_hops, 14);
    }

    #[test]
    fn curve_queries() {
        let mut c = Curve::default();
        for (s, v) in [(0, 5.0), (10, 3.0), (20, 1.5), (30, 1.0)] {
            c.push(s, v);
        }
        assert_eq!(c.first_step_below(2.0), Some(20));
        assert_eq!(c.first_step_below(0.5), None);
        assert_eq!(c.last(), Some(1.0));
        assert!((c.tail_mean(2) - 1.25).abs() < 1e-12);
        assert!(!c.sparkline(2).is_empty());
    }

    #[test]
    fn table_renders() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["1".into(), "2".into()]);
        t.print(); // smoke: no panic
    }
}
