//! QSGD: Communication-Efficient SGD via Gradient Quantization and Encoding.
//!
//! Full-system reproduction of Alistarh et al., NIPS 2017. Three-layer stack:
//!
//! * **Layer 3 (this crate)** — the distributed-training coordinator: data-parallel
//!   worker orchestration, gradient quantization ([`quant`]), lossless Elias coding
//!   ([`coding`]), a simulated multi-GPU interconnect ([`simnet`]), collective
//!   communication patterns ([`collectives`]), a real multi-process socket
//!   transport running the same collectives across OS processes ([`transport`]),
//!   the synchronous / asynchronous / variance-reduced training loops
//!   ([`coordinator`]), a sharded quantized parameter-server service with
//!   admission control and a heavy-traffic client harness ([`ps`]), and a
//!   unified observability layer — structured tracing, a mergeable metrics
//!   registry, and a distributed flight recorder ([`obs`]).
//! * **Layer 2 (JAX, build-time)** — model forward/backward graphs, AOT-lowered to
//!   HLO text and executed from Rust via PJRT ([`runtime`]).
//! * **Layer 1 (Pallas, build-time)** — the stochastic-quantization kernel, fused
//!   into the L2 graph; validated against a pure-jnp oracle at build time.
//!
//! Python never runs on the training hot path: `make artifacts` lowers the graphs
//! once, and the Rust binary is self-contained afterwards.

pub mod bench;
pub mod coding;
pub mod collectives;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod metrics;
pub mod models;
pub mod obs;
pub mod optim;
pub mod ps;
pub mod quant;
pub mod runtime;
pub mod simnet;
pub mod transport;
pub mod util;
