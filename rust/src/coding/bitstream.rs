//! MSB-first bit-level I/O for the gradient wire format.
//!
//! The encoder is on the hot path (the paper overlaps quantize+encode with
//! backprop; if coding is slower than the network it becomes the bottleneck),
//! so the writer appends into a `u64` accumulator and spills whole words.

/// Append-only bit buffer (MSB-first within each byte).
#[derive(Default, Clone)]
pub struct BitWriter {
    buf: Vec<u8>,
    /// Pending bits, left-aligned in the low `fill` bits of `acc`.
    acc: u64,
    fill: u32,
}

impl BitWriter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_capacity(bytes: usize) -> Self {
        Self { buf: Vec::with_capacity(bytes), acc: 0, fill: 0 }
    }

    /// Total bits written so far.
    #[inline]
    pub fn len_bits(&self) -> u64 {
        self.buf.len() as u64 * 8 + self.fill as u64
    }

    #[inline]
    pub fn write_bit(&mut self, bit: bool) {
        self.write_bits(bit as u64, 1);
    }

    /// Write the low `count` bits of `v` (MSB of those bits first). Writes
    /// wider than 32 bits are split so the 64-bit accumulator (≤31 pending
    /// bits + ≤32 new) never overflows.
    #[inline]
    pub fn write_bits(&mut self, v: u64, count: u32) {
        debug_assert!(count <= 64);
        debug_assert!(count == 64 || v < (1u64 << count));
        if count > 32 {
            self.write_bits(v >> 32, count - 32);
            self.write_bits(v & 0xffff_ffff, 32);
            return;
        }
        self.acc = (self.acc << count) | v;
        self.fill += count;
        // Spill whole 32-bit words at once (perf: the encoder emits 2–8 bit
        // codes; byte-at-a-time spilling was ~15% of encode time).
        if self.fill >= 32 {
            self.fill -= 32;
            let word = (self.acc >> self.fill) as u32;
            self.buf.extend_from_slice(&word.to_be_bytes());
        }
    }

    /// Raw 32-bit float (the per-bucket scale; `F = 32` in the paper).
    #[inline]
    pub fn write_f32(&mut self, x: f32) {
        self.write_bits(x.to_bits() as u64, 32);
    }

    /// Flush pending bits (zero-padding the final partial byte) and expose
    /// the encoded bytes without consuming the writer — the reusable-buffer
    /// path of the fused pipeline ([`crate::coding::pipeline`]). Identical
    /// byte output to [`Self::into_bytes`].
    pub fn finish(&mut self) -> &[u8] {
        while self.fill >= 8 {
            self.fill -= 8;
            self.buf.push((self.acc >> self.fill) as u8);
        }
        if self.fill > 0 {
            let pad = 8 - self.fill;
            self.buf.push(((self.acc << pad) & 0xff) as u8);
            self.fill = 0;
        }
        &self.buf
    }

    /// Zero-pad to the next byte boundary (no-op when already aligned).
    /// Directory frames byte-align each bucket payload so decoders can
    /// jump to any bucket by byte offset.
    #[inline]
    pub fn align_to_byte(&mut self) {
        let rem = self.fill % 8;
        if rem != 0 {
            self.write_bits(0, 8 - rem);
        }
    }

    /// Append whole bytes to a byte-aligned stream (the directory frame
    /// splices the pre-encoded bucket payload after the header this way).
    pub fn extend_aligned(&mut self, bytes: &[u8]) {
        debug_assert_eq!(self.fill % 8, 0, "stream must be byte-aligned");
        while self.fill >= 8 {
            self.fill -= 8;
            self.buf.push((self.acc >> self.fill) as u8);
        }
        self.buf.extend_from_slice(bytes);
    }

    /// Reset to an empty stream, keeping the allocated capacity.
    pub fn reset(&mut self) {
        self.buf.clear();
        self.acc = 0;
        self.fill = 0;
    }

    /// Pre-size the byte buffer (zero-allocation steady state from call one).
    pub fn reserve(&mut self, bytes: usize) {
        self.buf.reserve(bytes);
    }

    /// Flush (zero-padding the final partial byte) and return the bytes.
    pub fn into_bytes(mut self) -> Vec<u8> {
        self.finish();
        self.buf
    }
}

/// Reader over a byte slice produced by [`BitWriter`].
pub struct BitReader<'a> {
    buf: &'a [u8],
    /// Next bit index.
    pos: u64,
}

#[derive(Debug, PartialEq, Eq, Clone, Copy)]
pub struct BitstreamExhausted;

impl std::fmt::Display for BitstreamExhausted {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "bitstream exhausted")
    }
}
impl std::error::Error for BitstreamExhausted {}

impl<'a> BitReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    /// Reader positioned at an arbitrary bit offset — how
    /// [`FrameView`](crate::coding::gradient::FrameView) resumes payload
    /// decoding after having parsed the header once. `pos` past the end is
    /// allowed (every read then reports exhaustion).
    pub fn at(buf: &'a [u8], pos: u64) -> Self {
        Self { buf, pos }
    }

    /// Current absolute bit offset into the stream.
    #[inline]
    pub fn bit_pos(&self) -> u64 {
        self.pos
    }

    #[inline]
    pub fn bits_remaining(&self) -> u64 {
        (self.buf.len() as u64 * 8).saturating_sub(self.pos)
    }

    #[inline]
    pub fn read_bit(&mut self) -> Result<bool, BitstreamExhausted> {
        if self.pos >= self.buf.len() as u64 * 8 {
            return Err(BitstreamExhausted);
        }
        let byte = self.buf[(self.pos / 8) as usize];
        let bit = (byte >> (7 - (self.pos % 8))) & 1;
        self.pos += 1;
        Ok(bit == 1)
    }

    #[inline]
    pub fn read_bits(&mut self, count: u32) -> Result<u64, BitstreamExhausted> {
        debug_assert!(count <= 64);
        if self.bits_remaining() < count as u64 {
            return Err(BitstreamExhausted);
        }
        let mut out = 0u64;
        let mut left = count;
        while left > 0 {
            let byte_idx = (self.pos / 8) as usize;
            let bit_off = (self.pos % 8) as u32;
            let avail = 8 - bit_off;
            let take = avail.min(left);
            let byte = self.buf[byte_idx] as u64;
            let chunk = (byte >> (avail - take)) & ((1u64 << take) - 1);
            out = (out << take) | chunk;
            self.pos += take as u64;
            left -= take;
        }
        Ok(out)
    }

    #[inline]
    pub fn read_f32(&mut self) -> Result<f32, BitstreamExhausted> {
        Ok(f32::from_bits(self.read_bits(32)? as u32))
    }

    /// Skip ahead to the next byte boundary (never past the end: the
    /// stream's total bit count is itself byte-aligned).
    #[inline]
    pub fn align_to_byte(&mut self) {
        self.pos = (self.pos + 7) & !7;
    }

    /// Current byte offset into the stream. Only meaningful on a
    /// byte-aligned reader (directory frames align before the payload).
    #[inline]
    pub fn byte_pos(&self) -> usize {
        debug_assert_eq!(self.pos % 8, 0, "reader not byte-aligned");
        (self.pos / 8) as usize
    }

    /// Peek the next `count ≤ 32` bits without consuming, zero-padded past
    /// the end of the stream (prefix-table decoding needs a fixed window).
    #[inline]
    pub fn peek_bits(&self, count: u32) -> u64 {
        debug_assert!((1..=32).contains(&count));
        let byte_idx = (self.pos / 8) as usize;
        let bit_off = (self.pos % 8) as u32;
        // Fast path: an 8-byte window always contains bit_off + 32 bits.
        if byte_idx + 8 <= self.buf.len() {
            let w = u64::from_be_bytes(self.buf[byte_idx..byte_idx + 8].try_into().unwrap());
            return (w << bit_off) >> (64 - count);
        }
        // Tail: assemble what remains, zero-padded.
        let mut out = 0u64;
        let mut pos = self.pos;
        let mut left = count;
        let total = self.buf.len() as u64 * 8;
        while left > 0 {
            if pos >= total {
                out <<= left;
                break;
            }
            let bi = (pos / 8) as usize;
            let off = (pos % 8) as u32;
            let avail = 8 - off;
            let take = avail.min(left);
            let byte = self.buf[bi] as u64;
            let chunk = (byte >> (avail - take)) & ((1u64 << take) - 1);
            out = (out << take) | chunk;
            pos += take as u64;
            left -= take;
        }
        out
    }

    /// Consume `count` bits previously peeked.
    #[inline]
    pub fn advance(&mut self, count: u32) -> Result<(), BitstreamExhausted> {
        if self.bits_remaining() < count as u64 {
            return Err(BitstreamExhausted);
        }
        self.pos += count as u64;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_bits() {
        let mut w = BitWriter::new();
        w.write_bit(true);
        w.write_bits(0b1011, 4);
        w.write_bits(0xdead_beef, 32);
        w.write_f32(-1.5);
        w.write_bits(u64::MAX, 64);
        assert_eq!(w.len_bits(), 1 + 4 + 32 + 32 + 64);
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        assert!(r.read_bit().unwrap());
        assert_eq!(r.read_bits(4).unwrap(), 0b1011);
        assert_eq!(r.read_bits(32).unwrap(), 0xdead_beef);
        assert_eq!(r.read_f32().unwrap(), -1.5);
        assert_eq!(r.read_bits(64).unwrap(), u64::MAX);
    }

    #[test]
    fn exhaustion_is_an_error() {
        let bytes = BitWriter::new().into_bytes();
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.read_bit(), Err(BitstreamExhausted));
        let mut w = BitWriter::new();
        w.write_bits(0b101, 3);
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        // padding bits are readable (zero), but not beyond the byte
        assert_eq!(r.read_bits(8).unwrap(), 0b1010_0000);
        assert_eq!(r.read_bit(), Err(BitstreamExhausted));
    }

    #[test]
    fn finish_matches_into_bytes_and_reset_reuses() {
        let mut reused = BitWriter::new();
        reused.reserve(64);
        for round in 0..3u64 {
            reused.reset();
            let mut owned = BitWriter::new();
            for i in 0..50 + round {
                owned.write_bits(i % 31, 5);
                reused.write_bits(i % 31, 5);
            }
            owned.write_bits(round, 3);
            reused.write_bits(round, 3);
            assert_eq!(reused.finish(), owned.into_bytes().as_slice());
        }
    }

    #[test]
    fn alignment_and_aligned_extend() {
        let mut w = BitWriter::new();
        w.write_bits(0b101, 3);
        w.align_to_byte();
        assert_eq!(w.len_bits(), 8);
        w.align_to_byte(); // idempotent
        assert_eq!(w.len_bits(), 8);
        w.extend_aligned(&[0xde, 0xad]);
        w.write_bits(0b1, 1);
        let bytes = w.into_bytes();
        assert_eq!(bytes, vec![0b1010_0000, 0xde, 0xad, 0b1000_0000]);

        let mut r = BitReader::new(&bytes);
        assert_eq!(r.read_bits(3).unwrap(), 0b101);
        r.align_to_byte();
        assert_eq!(r.byte_pos(), 1);
        r.align_to_byte(); // idempotent
        assert_eq!(r.byte_pos(), 1);
        assert_eq!(r.read_bits(16).unwrap(), 0xdead);
        assert!(r.read_bit().unwrap());
    }

    #[test]
    fn cross_byte_reads() {
        let mut w = BitWriter::new();
        for i in 0..100u64 {
            w.write_bits(i % 4, 2);
        }
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        for i in 0..100u64 {
            assert_eq!(r.read_bits(2).unwrap(), i % 4);
        }
    }
}
