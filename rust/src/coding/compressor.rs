//! The two-phase QSGD/NUQSGD codec: quantize onto a [`LevelGrid`] into
//! materialised buckets, then entropy-code as a separate pass — the
//! *oracle* for the fused pipeline ([`crate::coding::QsgdCodec`]), which
//! must emit bit-identical wire bytes for every grid and configuration.
//! One grid-generic type covers both classic QSGD (uniform grid — the
//! quantizer dispatches to the legacy arithmetic, bit-identical to the
//! pre-grid code) and NUQSGD/custom grids.

use rand_core::RngCore;

use super::gradient::{self, Regime};
use crate::config::CodecOptions;
use crate::quant::{self, Codec, EncodeSession, LevelGrid, Norm, WireFormat};
use crate::util::rng::Xoshiro256;

/// Two-phase quantize-then-encode codec (the property-test oracle).
/// Mirrors [`crate::coding::QsgdCodec`]'s configuration surface exactly;
/// only the encode execution differs (materialised [`crate::quant::QuantBucket`]s
/// and a second encoding pass instead of the fused streaming path).
#[derive(Debug, Clone)]
pub struct TwoPhaseQsgd {
    pub grid: LevelGrid,
    /// Bucket size `d` (`usize::MAX` ⇒ whole-vector scheme).
    pub bucket: usize,
    pub norm: Norm,
    /// `None` ⇒ the paper's regime rule per gradient.
    pub regime: Option<Regime>,
    /// Directory threshold + decode thread budget — must match the fused
    /// codec under comparison, or the wire bytes legitimately differ.
    pub opts: CodecOptions,
}

impl TwoPhaseQsgd {
    /// Uniform-grid (classic QSGD) constructor.
    pub fn new(s: u32, bucket: usize, norm: Norm, regime: Option<Regime>) -> Self {
        Self::with_grid(LevelGrid::uniform(s), bucket, norm, regime)
    }

    pub fn with_grid(grid: LevelGrid, bucket: usize, norm: Norm, regime: Option<Regime>) -> Self {
        assert!(bucket >= 1);
        Self { grid, bucket, norm, regime, opts: CodecOptions::default() }
    }

    /// Experiment-style constructor: `bits`-bit QSGD with the given bucket
    /// (paper §5 uses e.g. 4-bit/512-bucket, 2-bit/64-bucket, max-norm).
    pub fn with_bits(bits: u32, bucket: usize) -> Self {
        Self::new(quant::levels_for_bits(bits), bucket, Norm::Max, None)
    }

    /// NUQSGD arm at the same bit budget as [`Self::with_bits`]:
    /// exponential grid with `2^(b−1) − 1` nonzero levels, max-norm.
    pub fn nuqsgd_with_bits(bits: u32, bucket: usize) -> Self {
        Self::with_grid(
            LevelGrid::exponential(quant::levels_for_bits(bits)),
            bucket,
            Norm::Max,
            None,
        )
    }

    /// Theory-style constructor: the §3.1 scheme (2-norm, single bucket).
    pub fn paper(s: u32) -> Self {
        Self::new(s, usize::MAX, Norm::L2, None)
    }

    /// Builder-style [`CodecOptions`] override.
    pub fn with_options(mut self, opts: CodecOptions) -> Self {
        self.opts = opts;
        self
    }

    /// Phase one: materialise the quantized gradient.
    pub fn quantize(&self, grad: &[f32], rng: &mut dyn RngCore) -> quant::QuantizedGradient {
        let bucket = self.bucket.min(grad.len().max(1));
        quant::stochastic::quantize_grid(grad, &self.grid, bucket, self.norm, rng)
    }
}

impl Codec for TwoPhaseQsgd {
    fn session(&self, rng: Xoshiro256) -> Box<dyn EncodeSession> {
        Box::new(TwoPhaseSession { codec: self.clone(), rng })
    }

    fn decode(&self, msg: &[u8], n: usize) -> anyhow::Result<Vec<f32>> {
        gradient::decode_expecting(msg, n)
    }

    fn decode_add_threads(
        &self,
        msg: &[u8],
        alpha: f32,
        acc: &mut [f32],
        threads: usize,
    ) -> anyhow::Result<()> {
        gradient::par_decode_add_expecting(msg, alpha, acc, threads)
    }

    fn decode_threads(&self) -> usize {
        self.opts.decode_threads()
    }

    fn encoded_size_hint(&self, n: usize) -> usize {
        let bucket = self.bucket.min(n.max(1));
        gradient::encoded_size_hint(
            n,
            &self.grid,
            bucket,
            self.norm,
            self.regime,
            self.opts.use_directory(n, bucket),
        )
    }

    fn wire_format(&self) -> WireFormat {
        WireFormat::EliasFrame { grid: self.grid.clone() }
    }

    fn chunk_align(&self) -> usize {
        if self.bucket == usize::MAX {
            1
        } else {
            self.bucket
        }
    }

    fn name(&self) -> String {
        format!("{}-two-phase(bucket={},{:?})", self.grid.label(), self.bucket, self.norm)
    }
}

/// Two-phase encode session. Deliberately *not* zero-alloc (phase one
/// materialises one `Vec<i32>` per bucket) — its job is to be an
/// independently-derived reference implementation, not to be fast.
struct TwoPhaseSession {
    codec: TwoPhaseQsgd,
    rng: Xoshiro256,
}

impl EncodeSession for TwoPhaseSession {
    fn encode_into(&mut self, grad: &[f32], out: &mut Vec<u8>) {
        let q = self.codec.quantize(grad, &mut self.rng);
        let regime = self.codec.regime.unwrap_or_else(|| gradient::auto_regime(&q));
        let dir = self.codec.opts.use_directory(q.n, q.bucket_size);
        let bytes = gradient::encode_with_directory(&q, regime, dir);
        out.clear();
        out.extend_from_slice(&bytes);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    #[test]
    fn end_to_end_error_bound() {
        let mut r = Xoshiro256::from_u64(0);
        let grad: Vec<f32> =
            (0..5000).map(|_| crate::util::rng::uniform_f32(&mut r) - 0.5).collect();
        let codec = TwoPhaseQsgd::with_bits(4, 512);
        let msg = codec.session(Xoshiro256::from_u64(7)).compress(&grad);
        let back = codec.decode(&msg, grad.len()).unwrap();
        // per-coordinate error ≤ bucket-max / s
        for (chunk_g, chunk_b) in grad.chunks(512).zip(back.chunks(512)) {
            let scale = chunk_g.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
            for (g, b) in chunk_g.iter().zip(chunk_b) {
                assert!((g - b).abs() <= scale / 7.0 + 1e-6);
            }
        }
        // 4-bit QSGD must compress well below fp32
        assert!(msg.len() * 4 < grad.len() * 4);
        // and the no-encode size hint bounds the measured size
        assert!(msg.len() <= codec.encoded_size_hint(grad.len()), "hint too small");
    }

    #[test]
    fn wrong_length_rejected() {
        let codec = TwoPhaseQsgd::paper(4);
        let msg = codec.session(Xoshiro256::from_u64(1)).compress(&[1.0, 2.0, 3.0]);
        assert!(codec.decode(&msg, 4).is_err());
        assert!(codec.decode(&msg, 3).is_ok());
    }

    #[test]
    fn uniform_grid_session_matches_legacy_arithmetic() {
        // The merged grid-generic oracle must reproduce the pre-grid QSGD
        // bytes: quantize_grid dispatches uniform grids to the original
        // arithmetic, so frames stay v1 byte-identical (golden frames in
        // tests/nuqsgd.rs pin this across releases).
        let mut r = Xoshiro256::from_u64(2);
        let grad = crate::util::rng::normal_vec(&mut r, 1500);
        let via_grid = TwoPhaseQsgd::with_grid(LevelGrid::uniform(7), 512, Norm::Max, None)
            .session(Xoshiro256::from_u64(3))
            .compress(&grad);
        let q = crate::quant::stochastic::quantize(
            &grad,
            7,
            512,
            Norm::Max,
            &mut Xoshiro256::from_u64(3),
        );
        assert_eq!(via_grid, gradient::encode_auto(&q));
    }
}
