//! The end-to-end QSGD compressor: stochastic quantization + Elias coding,
//! as plugged into Algorithm 1's Encode/Decode steps.

use rand_core::RngCore;

use super::gradient::{self, Regime};
use crate::quant::{self, Compressor, LevelGrid, Norm};

/// QSGD Encode/Decode (quantize → entropy-code). Stateless (the paper:
/// "quantization on the fly, without error accumulation").
#[derive(Debug, Clone)]
pub struct QsgdCompressor {
    /// Number of quantization levels `s`.
    pub s: u32,
    /// Bucket size `d` (paper §4; `usize::MAX` ⇒ whole-vector §3.1 scheme).
    pub bucket: usize,
    pub norm: Norm,
    /// `None` ⇒ the paper's regime rule per gradient ([`gradient::preferred_regime`]).
    pub regime: Option<Regime>,
}

impl QsgdCompressor {
    /// Experiment-style constructor: `bits`-bit QSGD with the given bucket
    /// (paper §5 uses e.g. 4-bit/512-bucket, 2-bit/64-bucket, max-norm).
    pub fn with_bits(bits: u32, bucket: usize) -> Self {
        Self { s: quant::levels_for_bits(bits), bucket, norm: Norm::Max, regime: None }
    }

    /// Theory-style constructor: the §3.1 scheme (2-norm, single bucket).
    pub fn paper(s: u32) -> Self {
        Self { s, bucket: usize::MAX, norm: Norm::L2, regime: None }
    }

    pub fn quantize(&self, grad: &[f32], rng: &mut dyn RngCore) -> quant::QuantizedGradient {
        let bucket = self.bucket.min(grad.len().max(1));
        quant::stochastic::quantize(grad, self.s, bucket, self.norm, rng)
    }
}

impl Compressor for QsgdCompressor {
    fn compress(&mut self, grad: &[f32], rng: &mut dyn RngCore) -> Vec<u8> {
        let q = self.quantize(grad, rng);
        match self.regime {
            Some(r) => gradient::encode(&q, r),
            None => gradient::encode_auto(&q),
        }
    }

    fn decompress(&self, msg: &[u8], n: usize) -> anyhow::Result<Vec<f32>> {
        gradient::decode_expecting(msg, n)
    }

    fn decompress_add(&self, msg: &[u8], alpha: f32, acc: &mut [f32]) -> anyhow::Result<()> {
        gradient::decode_add_expecting(msg, alpha, acc)
    }

    fn decompress_add_threads(
        &self,
        msg: &[u8],
        alpha: f32,
        acc: &mut [f32],
        threads: usize,
    ) -> anyhow::Result<()> {
        gradient::par_decode_add_expecting(msg, alpha, acc, threads)
    }

    fn name(&self) -> String {
        let b = (self.s + 1).next_power_of_two().trailing_zeros() + 1;
        format!("qsgd(s={},~{}bit,bucket={},{:?})", self.s, b, self.bucket, self.norm)
    }
}

/// Two-phase NUQSGD / arbitrary-grid compressor: quantize onto a
/// [`LevelGrid`] into materialised buckets, then encode as a separate pass.
/// Mirrors [`QsgdCompressor`] exactly — it exists as the property-test
/// *oracle* for the fused grid pipeline ([`crate::coding::FusedQsgd`]),
/// which must emit bit-identical wire bytes for every grid.
#[derive(Debug, Clone)]
pub struct NuqsgdCompressor {
    pub grid: LevelGrid,
    /// Bucket size `d` (`usize::MAX` ⇒ whole-vector scheme).
    pub bucket: usize,
    pub norm: Norm,
    /// `None` ⇒ the paper's regime rule per gradient.
    pub regime: Option<Regime>,
}

impl NuqsgdCompressor {
    /// NUQSGD arm at the same bit budget as [`QsgdCompressor::with_bits`]:
    /// exponential grid with `2^(b−1) − 1` nonzero levels, max-norm.
    pub fn with_bits(bits: u32, bucket: usize) -> Self {
        Self {
            grid: LevelGrid::exponential(quant::levels_for_bits(bits)),
            bucket,
            norm: Norm::Max,
            regime: None,
        }
    }

    pub fn quantize(&self, grad: &[f32], rng: &mut dyn RngCore) -> quant::QuantizedGradient {
        let bucket = self.bucket.min(grad.len().max(1));
        quant::stochastic::quantize_grid(grad, &self.grid, bucket, self.norm, rng)
    }
}

impl Compressor for NuqsgdCompressor {
    fn compress(&mut self, grad: &[f32], rng: &mut dyn RngCore) -> Vec<u8> {
        let q = self.quantize(grad, rng);
        match self.regime {
            Some(r) => gradient::encode(&q, r),
            None => gradient::encode_auto(&q),
        }
    }

    fn decompress(&self, msg: &[u8], n: usize) -> anyhow::Result<Vec<f32>> {
        gradient::decode_expecting(msg, n)
    }

    fn decompress_add(&self, msg: &[u8], alpha: f32, acc: &mut [f32]) -> anyhow::Result<()> {
        gradient::decode_add_expecting(msg, alpha, acc)
    }

    fn decompress_add_threads(
        &self,
        msg: &[u8],
        alpha: f32,
        acc: &mut [f32],
        threads: usize,
    ) -> anyhow::Result<()> {
        gradient::par_decode_add_expecting(msg, alpha, acc, threads)
    }

    fn name(&self) -> String {
        format!("{}(bucket={},{:?})", self.grid.label(), self.bucket, self.norm)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;
    

    #[test]
    fn end_to_end_error_bound() {
        
        let mut r = Xoshiro256::from_u64(0);
        let grad: Vec<f32> = (0..5000).map(|_| crate::util::rng::uniform_f32(&mut r) - 0.5).collect();
        let mut c = QsgdCompressor::with_bits(4, 512);
        let msg = c.compress(&grad, &mut r);
        let back = c.decompress(&msg, grad.len()).unwrap();
        // per-coordinate error ≤ bucket-max / s
        for (chunk_g, chunk_b) in grad.chunks(512).zip(back.chunks(512)) {
            let scale = chunk_g.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
            for (g, b) in chunk_g.iter().zip(chunk_b) {
                assert!((g - b).abs() <= scale / 7.0 + 1e-6);
            }
        }
        // 4-bit QSGD must compress well below fp32
        assert!(msg.len() * 4 < grad.len() * 4);
    }

    #[test]
    fn wrong_length_rejected() {
        let mut c = QsgdCompressor::paper(4);
        let mut r = Xoshiro256::from_u64(1);
        let msg = c.compress(&[1.0, 2.0, 3.0], &mut r);
        assert!(c.decompress(&msg, 4).is_err());
    }
}
