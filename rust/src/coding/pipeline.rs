//! Fused zero-allocation quantize→encode pipeline.
//!
//! The two-phase path (`quant::stochastic::quantize` → `gradient::encode`)
//! materialises a [`crate::quant::QuantBucket`] — one `Vec<i32>` per bucket —
//! purely so the encoder can re-walk it. On the encode hot path that is
//! wasted work: the paper's §5 protocol overlaps quantize+code with backprop
//! ("communication time includes time spent compressing and uncompressing
//! gradients"), so the pipeline must stay allocation-free and cache-resident
//! as schemes get richer (NUQSGD makes the same point about the
//! quantize+code stage). [`FusedEncoder`] owns all per-worker scratch — the
//! bitstream buffer, the batched RNG words, the bucket-level scratch, and
//! the Elias codeword table — and streams levels into the bitstream
//! bucket-by-bucket.
//!
//! Wire compatibility is a hard invariant: the fused path emits bytes
//! **bit-identical** to the two-phase oracle for every `(s, bucket, norm,
//! regime)` configuration, because it consumes the per-worker RNG stream in
//! the same order (one `fill_bytes` per bucket), assigns levels with the
//! same `quantize_bucket_into` arithmetic, and emits codewords through the
//! same `encode_levels_*` routines and LUT sizing. `tests/fused_pipeline.rs`
//! property-tests this; the two-phase [`crate::coding::TwoPhaseQsgd`] is
//! retained as the oracle.
//!
//! Regime selection mirrors `gradient::encode_auto`: with an explicit regime
//! or a 2-norm (where the paper's rule is static in `(s, d)`), buckets
//! stream straight into the bitstream; the §4 max-norm variant has no
//! sparsity guarantee, so its regime comes from measured density — that path
//! quantizes into a gradient-sized level scratch first (still zero
//! steady-state allocations) and then encodes.

use rand_core::RngCore;

use super::bitstream::BitWriter;
use super::elias::EliasLut;
use super::gradient::{self, Regime};
use crate::config::CodecOptions;
use crate::quant::{self, Codec, EncodeSession, LevelGrid, Norm, WireFormat};
use crate::util::rng::Xoshiro256;

/// Reusable per-worker fused quantize+encode state, generic over the
/// quantization [`LevelGrid`] (uniform QSGD, NUQSGD exponential, custom).
pub struct FusedEncoder {
    /// Quantization levels `s ≥ 1` (`== grid.s()`, kept for display and
    /// LUT sizing).
    pub s: u32,
    /// Which level grid coordinates round onto. Carried in the scratch
    /// state; non-uniform point tables are `Arc`-shared, so the encode loop
    /// stays allocation-free.
    pub grid: LevelGrid,
    /// Bucket size `d` (`usize::MAX` ⇒ whole-vector §3.1 scheme).
    pub bucket: usize,
    pub norm: Norm,
    /// `None` ⇒ the paper's regime rule per gradient.
    pub regime: Option<Regime>,
    /// Wire-format knobs ([`CodecOptions`]): the bucket-offset-directory
    /// size rule (default: the shared [`gradient::use_directory_default`]
    /// threshold the two-phase oracle also applies, keeping the wire bytes
    /// bit-identical) and the decode thread budget.
    pub opts: CodecOptions,
    writer: BitWriter,
    /// Batched RNG words, 4 bytes per coordinate of the current bucket.
    words: Vec<u8>,
    /// Level scratch: bucket-sized on the streaming path, gradient-sized on
    /// the measured-density path.
    levels: Vec<i32>,
    /// Per-bucket scales (measured-density path only).
    scales: Vec<f32>,
    /// Directory-frame staging: bucket payloads stream here (byte-aligned)
    /// so their byte lengths can precede them in the final frame. Reused
    /// across encodes like every other piece of scratch.
    payload: BitWriter,
    /// Per-bucket payload byte lengths of the current directory frame.
    dir_lens: Vec<u64>,
    /// Codeword table shared across buckets, sized as the two-phase encoder
    /// sizes it.
    lut: EliasLut,
}

impl FusedEncoder {
    pub fn new(s: u32, bucket: usize, norm: Norm, regime: Option<Regime>) -> Self {
        Self::with_grid(LevelGrid::uniform(s), bucket, norm, regime)
    }

    /// Grid-generic constructor — the fused pipeline as a compressor family.
    pub fn with_grid(grid: LevelGrid, bucket: usize, norm: Norm, regime: Option<Regime>) -> Self {
        assert!(bucket >= 1);
        let s = grid.s();
        Self {
            s,
            grid,
            bucket,
            norm,
            regime,
            opts: CodecOptions::default(),
            writer: BitWriter::new(),
            words: Vec::new(),
            levels: Vec::new(),
            scales: Vec::new(),
            payload: BitWriter::new(),
            dir_lens: Vec::new(),
            lut: EliasLut::new(gradient::encode_lut_max(s)),
        }
    }

    /// Pre-size the internal bitstream buffer so even the first encode runs
    /// without reallocation.
    pub fn reserve(&mut self, bytes: usize) {
        self.writer.reserve(bytes);
    }

    /// Encode `grad` into `out` (cleared first), reusing every piece of
    /// internal scratch. In steady state — after the scratch has grown to
    /// the largest gradient seen — this performs zero heap allocations
    /// (verified by the counting allocator in the `coding_hotpath` bench);
    /// this holds on the directory path too, whose staging buffer and
    /// length vector are part of the owned scratch.
    pub fn encode_into(&mut self, grad: &[f32], rng: &mut dyn RngCore, out: &mut Vec<u8>) {
        let n = grad.len();
        let bucket = self.bucket.min(n.max(1));
        if self.words.len() < bucket * 4 {
            self.words.resize(bucket * 4, 0);
        }
        self.writer.reset();
        let dir = self.opts.use_directory(n, bucket);
        let static_regime = match (self.regime, self.norm) {
            (Some(r), _) => Some(r),
            (None, Norm::L2) => Some(gradient::preferred_regime(self.s, bucket)),
            (None, Norm::Max) => None,
        };
        match static_regime {
            Some(regime) => self.encode_streaming(grad, bucket, regime, rng, dir),
            None => self.encode_measured(grad, bucket, rng, dir),
        }
        let bytes = self.writer.finish();
        out.clear();
        out.extend_from_slice(bytes);
    }

    /// Convenience wrapper allocating the output message.
    pub fn encode(&mut self, grad: &[f32], rng: &mut dyn RngCore) -> Vec<u8> {
        let mut out = Vec::new();
        self.encode_into(grad, rng, &mut out);
        out
    }

    /// Assemble the final v3 frame once the bucket payloads have been staged
    /// (byte-aligned) in `self.payload` with their byte lengths in
    /// `self.dir_lens`: header, then the shared
    /// [`gradient::splice_directory_payload`] assembly — the same routine
    /// the two-phase encoder uses, which is what keeps the paths
    /// bit-identical.
    fn emit_directory_frame(&mut self, n: usize, bucket: usize, regime: Regime) {
        let Self { writer, payload, dir_lens, lut, grid, norm, .. } = self;
        gradient::write_frame_header_dir(writer, grid, n, bucket, *norm, regime);
        gradient::splice_directory_payload(writer, payload, dir_lens, lut);
    }

    /// Regime known up front: each bucket is quantized into the bucket-sized
    /// scratch and immediately streamed into the bitstream (or, on the
    /// directory path, into the byte-aligned staging buffer whose per-bucket
    /// lengths become the directory).
    fn encode_streaming(
        &mut self,
        grad: &[f32],
        bucket: usize,
        regime: Regime,
        rng: &mut dyn RngCore,
        dir: bool,
    ) {
        if self.levels.len() < bucket {
            self.levels.resize(bucket, 0);
        }
        {
            let Self { writer, payload, dir_lens, words, levels, lut, grid, norm, .. } = self;
            if dir {
                payload.reset();
                dir_lens.clear();
            } else {
                gradient::write_frame_header_grid(writer, grid, grad.len(), bucket, *norm, regime);
            }
            let out: &mut BitWriter = if dir { &mut *payload } else { &mut *writer };
            let mut prev = 0u64;
            for c in grad.chunks(bucket) {
                let wds = &mut words[..c.len() * 4];
                rng.fill_bytes(wds);
                let lv = &mut levels[..c.len()];
                let scale = quant::stochastic::quantize_bucket_into_grid(c, wds, grid, *norm, lv);
                match regime {
                    Regime::Sparse => gradient::encode_levels_sparse_with(out, scale, lv, lut),
                    Regime::Dense => gradient::encode_levels_dense_with(out, scale, lv, lut),
                }
                if dir {
                    gradient::record_bucket_len(out, dir_lens, &mut prev);
                }
            }
        }
        if dir {
            self.emit_directory_frame(grad.len(), bucket, regime);
        }
    }

    /// Max-norm auto regime (measured density, as `encode_auto` does): one
    /// quantization pass into the gradient-sized scratch, then encode.
    fn encode_measured(&mut self, grad: &[f32], bucket: usize, rng: &mut dyn RngCore, dir: bool) {
        let n = grad.len();
        if self.levels.len() < n {
            self.levels.resize(n, 0);
        }
        self.scales.clear();
        let regime;
        {
            let Self { writer, payload, dir_lens, words, levels, scales, lut, s, grid, norm, .. } =
                self;
            let mut nnz = 0usize;
            for (bi, c) in grad.chunks(bucket).enumerate() {
                let wds = &mut words[..c.len() * 4];
                rng.fill_bytes(wds);
                let lv = &mut levels[bi * bucket..bi * bucket + c.len()];
                scales.push(quant::stochastic::quantize_bucket_into_grid(c, wds, grid, *norm, lv));
                nnz += lv.iter().filter(|&&l| l != 0).count();
            }
            // encode_auto's max-norm rule: dense once ≳25% of levels are nonzero.
            regime = if nnz * 4 > n {
                Regime::Dense
            } else {
                gradient::preferred_regime(*s, bucket)
            };
            if dir {
                payload.reset();
                dir_lens.clear();
            } else {
                gradient::write_frame_header_grid(writer, grid, n, bucket, *norm, regime);
            }
            let out: &mut BitWriter = if dir { &mut *payload } else { &mut *writer };
            let mut prev = 0u64;
            for (bi, c) in grad.chunks(bucket).enumerate() {
                let lv = &levels[bi * bucket..bi * bucket + c.len()];
                match regime {
                    Regime::Sparse => gradient::encode_levels_sparse_with(out, scales[bi], lv, lut),
                    Regime::Dense => gradient::encode_levels_dense_with(out, scales[bi], lv, lut),
                }
                if dir {
                    gradient::record_bucket_len(out, dir_lens, &mut prev);
                }
            }
        }
        if dir {
            self.emit_directory_frame(n, bucket, regime);
        }
    }
}

/// The QSGD codec over the fused pipeline — what
/// [`crate::coordinator::CompressorSpec::codec`] returns for QSGD/NUQSGD
/// arms. Shared and immutable: decoding goes through
/// [`gradient::FrameView`], and [`Codec::session`] hands each worker a
/// [`QsgdSession`] owning a [`FusedEncoder`] plus its RNG stream. The
/// two-phase [`crate::coding::TwoPhaseQsgd`] stays available as the
/// bit-identity oracle (`CompressorSpec::codec_two_phase`).
#[derive(Debug, Clone)]
pub struct QsgdCodec {
    pub grid: LevelGrid,
    /// Bucket size `d` (`usize::MAX` ⇒ whole-vector §3.1 scheme).
    pub bucket: usize,
    pub norm: Norm,
    /// `None` ⇒ the paper's regime rule per gradient.
    pub regime: Option<Regime>,
    /// Directory threshold + decode thread budget, shared with every
    /// session this codec creates.
    pub opts: CodecOptions,
}

impl QsgdCodec {
    pub fn new(s: u32, bucket: usize, norm: Norm, regime: Option<Regime>) -> Self {
        Self::with_grid(LevelGrid::uniform(s), bucket, norm, regime)
    }

    /// Grid-generic constructor (NUQSGD exponential grids, custom grids).
    pub fn with_grid(grid: LevelGrid, bucket: usize, norm: Norm, regime: Option<Regime>) -> Self {
        assert!(bucket >= 1);
        Self { grid, bucket, norm, regime, opts: CodecOptions::default() }
    }

    /// Experiment-style constructor (paper §5: e.g. 4-bit/512, max-norm).
    pub fn with_bits(bits: u32, bucket: usize) -> Self {
        Self::new(quant::levels_for_bits(bits), bucket, Norm::Max, None)
    }

    /// NUQSGD arm at the same bit budget as `with_bits`: exponential grid
    /// with `2^(b−1) − 1` nonzero levels.
    pub fn nuqsgd_with_bits(bits: u32, bucket: usize) -> Self {
        Self::with_grid(
            LevelGrid::exponential(quant::levels_for_bits(bits)),
            bucket,
            Norm::Max,
            None,
        )
    }

    /// Theory-style constructor: the §3.1 scheme (2-norm, single bucket).
    pub fn paper(s: u32) -> Self {
        Self::new(s, usize::MAX, Norm::L2, None)
    }

    /// Builder-style [`CodecOptions`] override (directory threshold, decode
    /// thread budget).
    pub fn with_options(mut self, opts: CodecOptions) -> Self {
        self.opts = opts;
        self
    }
}

impl Codec for QsgdCodec {
    fn session(&self, rng: Xoshiro256) -> Box<dyn EncodeSession> {
        let mut enc =
            FusedEncoder::with_grid(self.grid.clone(), self.bucket, self.norm, self.regime);
        enc.opts = self.opts.clone();
        Box::new(QsgdSession { enc, rng })
    }

    fn decode(&self, msg: &[u8], n: usize) -> anyhow::Result<Vec<f32>> {
        gradient::decode_expecting(msg, n)
    }

    fn decode_add_threads(
        &self,
        msg: &[u8],
        alpha: f32,
        acc: &mut [f32],
        threads: usize,
    ) -> anyhow::Result<()> {
        gradient::par_decode_add_expecting(msg, alpha, acc, threads)
    }

    fn decode_threads(&self) -> usize {
        self.opts.decode_threads()
    }

    fn encoded_size_hint(&self, n: usize) -> usize {
        let bucket = self.bucket.min(n.max(1));
        gradient::encoded_size_hint(
            n,
            &self.grid,
            bucket,
            self.norm,
            self.regime,
            self.opts.use_directory(n, bucket),
        )
    }

    fn wire_format(&self) -> WireFormat {
        WireFormat::EliasFrame { grid: self.grid.clone() }
    }

    fn chunk_align(&self) -> usize {
        // usize::MAX encodes the whole-vector §3.1 scheme: no useful
        // sub-gradient alignment exists, fall back to unaligned chunks.
        if self.bucket == usize::MAX {
            1
        } else {
            self.bucket
        }
    }

    fn name(&self) -> String {
        format!("{}-fused(bucket={},{:?})", self.grid.label(), self.bucket, self.norm)
    }
}

/// Per-worker fused encode session: owns the [`FusedEncoder`] scratch and
/// the worker's RNG stream. Zero heap allocations in steady state —
/// including the v3 directory path — verified by the counting allocator in
/// the `coding_hotpath` bench and `tests/codec_conformance.rs`.
pub struct QsgdSession {
    enc: FusedEncoder,
    rng: Xoshiro256,
}

impl QsgdSession {
    /// Direct access to the underlying encoder (pre-sizing via
    /// [`FusedEncoder::reserve`], wire-format overrides in tests).
    pub fn encoder(&mut self) -> &mut FusedEncoder {
        &mut self.enc
    }
}

impl EncodeSession for QsgdSession {
    fn encode_into(&mut self, grad: &[f32], out: &mut Vec<u8>) {
        self.enc.encode_into(grad, &mut self.rng, out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coding::TwoPhaseQsgd;
    use crate::util::rng::{self, Xoshiro256};

    fn randn(n: usize, seed: u64) -> Vec<f32> {
        let mut r = Xoshiro256::from_u64(seed);
        rng::normal_vec(&mut r, n)
    }

    #[test]
    fn fused_roundtrips_through_standard_decoder() {
        let v = randn(3000, 0);
        for (s, bucket, norm) in [
            (1u32, 64usize, Norm::Max),
            (7, 512, Norm::Max),
            (127, 512, Norm::Max),
            (15, 3000, Norm::L2),
        ] {
            let codec = QsgdCodec::new(s, bucket, norm, None);
            let mut sess = codec.session(Xoshiro256::from_u64(1));
            let msg = sess.compress(&v);
            let back = codec.decode(&msg, v.len()).unwrap();
            assert_eq!(back.len(), v.len());
            // reconstruction stays within one level per bucket
            for (cg, cb) in v.chunks(bucket).zip(back.chunks(bucket)) {
                let scale = match norm {
                    Norm::Max => cg.iter().fold(0.0f32, |m, &x| m.max(x.abs())),
                    Norm::L2 => cg.iter().map(|x| x * x).sum::<f32>().sqrt(),
                };
                for (g, b) in cg.iter().zip(cb) {
                    assert!((g - b).abs() <= scale / s as f32 + 1e-6);
                }
            }
        }
    }

    #[test]
    fn matches_two_phase_on_basic_configs() {
        let v = randn(2500, 2);
        for (s, bucket, norm, regime) in [
            (7u32, 512usize, Norm::Max, None),
            (1, 64, Norm::Max, None),
            (15, 2500, Norm::L2, None),
            (4, 128, Norm::L2, Some(Regime::Sparse)),
            (4, 128, Norm::Max, Some(Regime::Dense)),
        ] {
            let mut oracle =
                TwoPhaseQsgd::new(s, bucket, norm, regime).session(Xoshiro256::from_u64(3));
            let mut fused =
                QsgdCodec::new(s, bucket, norm, regime).session(Xoshiro256::from_u64(3));
            let a = oracle.compress(&v);
            let b = fused.compress(&v);
            assert_eq!(a, b, "s={s} bucket={bucket} {norm:?} {regime:?}");
        }
    }

    #[test]
    fn empty_and_degenerate_gradients() {
        let mut fused = QsgdCodec::with_bits(4, 512).session(Xoshiro256::from_u64(4));
        let mut oracle = TwoPhaseQsgd::with_bits(4, 512).session(Xoshiro256::from_u64(4));
        for v in [vec![], vec![0.0f32; 100], vec![f32::NAN; 10]] {
            let a = oracle.compress(&v);
            let b = fused.compress(&v);
            assert_eq!(a, b, "len={}", v.len());
            let q = gradient::decode(&b).unwrap();
            assert_eq!(q.n, v.len());
        }
    }

    #[test]
    fn forced_directory_matches_two_phase_assembly() {
        // The fused single-pass staging (quantize → staged bucket payloads →
        // header + directory + splice) must emit exactly the bytes of the
        // two-phase quantize-then-encode_with_directory path.
        let v = randn(3000, 7);
        for regime in [Regime::Sparse, Regime::Dense] {
            let mut enc = FusedEncoder::new(7, 512, Norm::Max, Some(regime));
            enc.opts.directory = Some(true);
            let mut r = Xoshiro256::from_u64(8);
            let a = enc.encode(&v, &mut r);
            let q = crate::quant::stochastic::quantize(
                &v,
                7,
                512,
                Norm::Max,
                &mut Xoshiro256::from_u64(8),
            );
            let b = gradient::encode_with_directory(&q, regime, true);
            assert_eq!(a, b, "{regime:?}");
            assert_eq!(gradient::decode(&a).unwrap(), q);
        }
        // measured-density path (max-norm auto regime) with the directory
        let mut enc = FusedEncoder::new(7, 512, Norm::Max, None);
        enc.opts.directory = Some(true);
        let a = enc.encode(&v, &mut Xoshiro256::from_u64(9));
        let q = crate::quant::stochastic::quantize(
            &v,
            7,
            512,
            Norm::Max,
            &mut Xoshiro256::from_u64(9),
        );
        // encode_auto's regime rule, then force the directory on
        let regime = if q.nnz() * 4 > q.n {
            Regime::Dense
        } else {
            gradient::preferred_regime(q.s, q.bucket_size)
        };
        assert_eq!(a, gradient::encode_with_directory(&q, regime, true));
    }

    #[test]
    fn encode_into_reuses_output_buffer() {
        let v = randn(4096, 5);
        let mut enc = FusedEncoder::new(7, 512, Norm::Max, None);
        enc.reserve(4096);
        let mut out = Vec::with_capacity(8192);
        let mut r = Xoshiro256::from_u64(6);
        enc.encode_into(&v, &mut r, &mut out);
        let first = out.clone();
        let cap = out.capacity();
        let mut r = Xoshiro256::from_u64(6);
        enc.encode_into(&v, &mut r, &mut out);
        assert_eq!(out, first, "same seed must reproduce the same frame");
        assert_eq!(out.capacity(), cap, "output buffer must be reused");
    }
}
