//! Lossless coding of quantized gradients (paper §3.1 "Efficient Coding of
//! Gradients", Appendices A.2/A.3): bit-level I/O, recursive Elias integer
//! codes, and the sparse/dense gradient wire formats.

pub mod bitstream;
pub mod elias;
pub mod gradient;

mod compressor;
pub use compressor::QsgdCompressor;
