//! Lossless coding of quantized gradients (paper §3.1 "Efficient Coding of
//! Gradients", Appendices A.2/A.3): bit-level I/O, recursive Elias integer
//! codes, the sparse/dense gradient wire formats, and the fused
//! zero-allocation quantize→encode pipeline ([`pipeline`]).

pub mod bitstream;
pub mod elias;
pub mod gradient;
pub mod pipeline;

mod compressor;
pub use compressor::TwoPhaseQsgd;
pub use gradient::FrameView;
pub use pipeline::{FusedEncoder, QsgdCodec, QsgdSession};
