//! Lossless wire codecs for quantized gradients.
//!
//! Two codecs, per the paper's two regimes:
//!
//! * **Sparse `Code_s`** (Theorem 3.2 / Appendix A.2): per bucket, a 32-bit
//!   scale, then Elias-coded *gaps* between nonzeros with a sign bit and the
//!   Elias-coded magnitude level per nonzero. Optimal when `s ≪ √d` and the
//!   quantized bucket is mostly zeros (expected nnz ≤ s(s+√d), Lemma A.5).
//! * **Dense `Code'_s`** (Corollary 3.3 / Appendix A.3): per bucket, a 32-bit
//!   scale, then for *every* coordinate a sign bit + `Elias'(ℓ_i)`. At
//!   `s = √d` this costs ≤ 2.8·d + 32 bits in expectation.
//!
//! [`encode_auto`] picks the regime the paper's analysis prescribes
//! (`s² + √d ≤ d/2` ⇒ sparse) and records the choice in a 1-bit flag so the
//! decoder is self-describing.

use anyhow::{bail, ensure, Result};

use super::bitstream::{BitReader, BitWriter};
use super::elias;
use crate::quant::{LevelGrid, Norm, QuantBucket, QuantizedGradient};
use crate::util::par;

/// Which coding regime a bucket was encoded with.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Regime {
    Sparse,
    Dense,
}

/// The paper's regime rule (Lemma A.2 requires `s² + √d ≤ d/2`).
pub fn preferred_regime(s: u32, d: usize) -> Regime {
    let s = s as f64;
    if s * s + (d as f64).sqrt() <= d as f64 / 2.0 {
        Regime::Sparse
    } else {
        Regime::Dense
    }
}

// --------------------------------------------------------------------------
// Per-bucket codecs
// --------------------------------------------------------------------------

/// Size of the gap lookup table for the sparse encoder (gaps above this
/// fall back to the recursive encoder; nnz ≈ s√d keeps typical gaps small).
const GAP_LUT: u64 = 4096;

/// Prefix window of the decoder lookup table (14 bits covers every level of
/// 8-bit QSGD and typical sparse gaps).
const DECODE_LUT_W: u32 = 14;

/// Sparse `Code_s`: scale, Elias'(nnz), then (Elias gap, sign, Elias level)
/// per nonzero. Gaps are `pos₀+1, pos₁−pos₀, …` (all ≥ 1, Elias-codable).
pub fn encode_bucket_sparse(w: &mut BitWriter, b: &QuantBucket) {
    let lut = elias::EliasLut::new(GAP_LUT);
    encode_bucket_sparse_with(w, b, &lut)
}

/// LUT-accelerated sparse encoder (the whole-gradient [`encode`] builds the
/// table once and reuses it across buckets).
pub fn encode_bucket_sparse_with(w: &mut BitWriter, b: &QuantBucket, lut: &elias::EliasLut) {
    encode_levels_sparse_with(w, b.scale, &b.levels, lut)
}

/// Sparse bucket body from a raw level slice — the fused pipeline's entry
/// point ([`crate::coding::pipeline`]); shares every codeword decision with
/// the [`QuantBucket`] path, so the wire bytes are bit-identical.
pub fn encode_levels_sparse_with(
    w: &mut BitWriter,
    scale: f32,
    levels: &[i32],
    lut: &elias::EliasLut,
) {
    w.write_f32(scale);
    let nnz = levels.iter().filter(|&&l| l != 0).count() as u64;
    lut.encode(w, nnz + 1); // Elias'(nnz)
    let mut prev: i64 = -1;
    for (i, &l) in levels.iter().enumerate() {
        if l == 0 {
            continue;
        }
        lut.encode(w, (i as i64 - prev) as u64);
        // sign bit + Elias(|l|) fused into one write when tabulated
        match lut.get(l.unsigned_abs() as u64) {
            Some((pat, bits)) => {
                w.write_bits(((l < 0) as u64) << bits | pat as u64, bits + 1)
            }
            None => {
                w.write_bit(l < 0);
                elias::encode(w, l.unsigned_abs() as u64);
            }
        }
        prev = i as i64;
    }
}

pub fn decode_bucket_sparse(r: &mut BitReader, d: usize, s: u32) -> Result<QuantBucket> {
    decode_bucket_sparse_with(r, d, s, &elias::DecodeLut::new(DECODE_LUT_W))
}

/// Prefix-table-accelerated sparse decoder (the whole-gradient [`decode`]
/// builds the table once).
pub fn decode_bucket_sparse_with(
    r: &mut BitReader,
    d: usize,
    s: u32,
    lut: &elias::DecodeLut,
) -> Result<QuantBucket> {
    let scale = r.read_f32()?;
    let nnz = lut.decode0(r)? as usize;
    ensure!(nnz <= d, "nnz {nnz} exceeds bucket size {d}");
    // every nonzero costs ≥ 3 bits (gap + sign + magnitude) — reject
    // length-lying headers before touching the levels
    ensure!((nnz as u64) * 3 <= r.bits_remaining(), "nnz exceeds remaining stream");
    let mut levels = vec![0i32; d];
    let mut prev: i64 = -1;
    for _ in 0..nnz {
        let gap = lut.decode(r)?;
        // gaps are 1-based positions within the bucket; a hostile stream can
        // encode anything up to u64::MAX, so bound before the i64 cast
        ensure!(gap >= 1 && gap <= d as u64, "gap {gap} out of bucket");
        let idx = prev + gap as i64;
        ensure!(idx >= 0 && (idx as usize) < d, "nonzero index out of bucket");
        let neg = r.read_bit()?;
        let mag = lut.decode(r)?;
        // the sparse encoder only emits nonzeros, so mag = 0 is malformed
        ensure!(mag >= 1 && mag <= s as u64, "level {mag} out of range (s={s})");
        levels[idx as usize] = if neg { -(mag as i32) } else { mag as i32 };
        prev = idx;
    }
    Ok(QuantBucket { scale, levels })
}

/// Dense `Code'_s`: scale, then per coordinate `Elias'(|ℓ|)` followed by a
/// sign bit *only when ℓ ≠ 0* (Lemma A.7 charges a sign bit for every
/// coordinate; skipping it for zeros keeps unique decodability and saves
/// ≈P(ℓ=0) bits/coordinate — this is what brings the practical encoder to
/// the Corollary 3.3 ballpark of 2.8n + 32).
pub fn encode_bucket_dense(w: &mut BitWriter, b: &QuantBucket) {
    let max_lev = b.levels.iter().map(|l| l.unsigned_abs()).max().unwrap_or(0);
    let lut = elias::EliasLut::new(max_lev as u64 + 1);
    encode_bucket_dense_with(w, b, &lut)
}

/// LUT-accelerated dense encoder: per coordinate, `Elias'(|ℓ|)` and the
/// optional sign bit are fused into a single `write_bits` call.
pub fn encode_bucket_dense_with(w: &mut BitWriter, b: &QuantBucket, lut: &elias::EliasLut) {
    encode_levels_dense_with(w, b.scale, &b.levels, lut)
}

/// Dense bucket body from a raw level slice (fused-pipeline entry point).
pub fn encode_levels_dense_with(
    w: &mut BitWriter,
    scale: f32,
    levels: &[i32],
    lut: &elias::EliasLut,
) {
    w.write_f32(scale);
    for &l in levels {
        let mag = l.unsigned_abs() as u64;
        match lut.get(mag + 1) {
            Some((pat, bits)) => {
                if l == 0 {
                    w.write_bits(pat as u64, bits);
                } else {
                    w.write_bits((pat as u64) << 1 | (l < 0) as u64, bits + 1);
                }
            }
            None => {
                elias::encode(w, mag + 1);
                if l != 0 {
                    w.write_bit(l < 0);
                }
            }
        }
    }
}

pub fn decode_bucket_dense(r: &mut BitReader, d: usize, s: u32) -> Result<QuantBucket> {
    decode_bucket_dense_with(r, d, s, &elias::DecodeLut::new(DECODE_LUT_W))
}

pub fn decode_bucket_dense_with(
    r: &mut BitReader,
    d: usize,
    s: u32,
    lut: &elias::DecodeLut,
) -> Result<QuantBucket> {
    let scale = r.read_f32()?;
    // every coordinate costs ≥ 1 bit — reject length-lying headers before
    // the d-sized allocation (a hostile header cannot force an OOM)
    ensure!(d as u64 <= r.bits_remaining(), "bucket size exceeds remaining stream");
    let mut levels = Vec::with_capacity(d);
    for _ in 0..d {
        let mag = lut.decode0(r)?;
        ensure!(mag <= s as u64, "level {mag} exceeds s={s}");
        if mag == 0 {
            levels.push(0);
        } else {
            let neg = r.read_bit()?;
            levels.push(if neg { -(mag as i32) } else { mag as i32 });
        }
    }
    Ok(QuantBucket { scale, levels })
}

// --------------------------------------------------------------------------
// Whole-gradient frame
// --------------------------------------------------------------------------

/// Frame header: everything the decoder needs is in-band, so messages are
/// self-describing (important for the async parameter-server mode where a
/// server may receive messages from heterogeneously-configured workers).
///
/// Layout: magic(8) | version(4) | regime(1) | norm(1) | s via Elias |
/// n via Elias' | bucket_size via Elias | [v2/v3: grid tag via Elias,
/// then for custom grids the s grid points as raw f32s] | [v3 only: the
/// bucket-offset directory — one Elias(byte_len + 1) per bucket — then
/// zero-padding to the next byte boundary, then the bucket payloads, each
/// starting byte-aligned at the cumulative offset].
///
/// Version 1 is exactly the pre-grid (uniform QSGD) format — uniform frames
/// are emitted as v1, byte-identical to what PR 1 shipped. Non-uniform
/// grids bump the version nibble to 2 and append the grid tag, so old
/// decoders fail loudly on frames they cannot dequantize. Version 3 frames
/// additionally carry the bucket-offset directory (emitted past
/// [`use_directory_default`]'s size threshold), which lets a decoder fan
/// per-bucket work lists out across threads ([`par_decode_add`]) instead
/// of walking the entropy-coded stream serially.
pub const FRAME_MAGIC: u64 = 0xA5;
pub const FRAME_VERSION: u64 = 1;
/// Frame version carrying an in-band [`LevelGrid`] tag.
pub const FRAME_VERSION_GRID: u64 = 2;
/// Frame version carrying a grid tag *and* a bucket-offset directory.
pub const FRAME_VERSION_DIR: u64 = 3;

/// Grid tags in v2/v3 frames (`GRID_TAG_UNIFORM` appears only in v3:
/// uniform grids without a directory stay on the tagless v1 layout).
const GRID_TAG_EXPONENTIAL: u64 = 1;
const GRID_TAG_CUSTOM: u64 = 2;
const GRID_TAG_UNIFORM: u64 = 3;

/// Frames at or above this many coordinates (with ≥ 2 buckets) carry the
/// bucket-offset directory by default. Below it the ~1–2 bytes/bucket of
/// directory plus padding outweighs any decode-parallelism win; above it
/// the overhead is <1% of the payload at the paper's 4-bit/512
/// configuration.
///
/// Derivation from the committed hot-path medians
/// (`rust/benches/baselines/coding_hotpath.json`): serial `decode_add`
/// sustains ~8 ns/coord while the directory-fed parallel decode reaches
/// ~5 ns/coord at 4 threads, so the directory buys ~3 ns/coord — which
/// must first amortize the roughly-100 µs fixed cost of fanning
/// per-bucket work lists across the pool and merging partials.
/// Break-even is therefore near 100 µs / 3 ns ≈ 3·10⁴ coords; 2¹⁶ =
/// 65 536 leaves ~2× slack for slower machines. The *byte* cost is
/// size-independent at fixed bucket width (≈ 2 B per 512-coord bucket ≈
/// 0.8% of a 4-bit payload), so the threshold is set by the time
/// crossover, not the wire overhead. Do not retune this value in place:
/// it selects the frame version on the wire, and the transport goldens
/// pin frames on both sides of it — move it only with a format version
/// bump.
pub const DIRECTORY_MIN_COORDS: usize = 1 << 16;

/// The shared default rule for emitting the bucket-offset directory —
/// [`crate::config::CodecOptions::use_directory`] at the default threshold.
/// Both the two-phase [`encode`] and the fused pipeline apply exactly this
/// rule, which is what keeps their wire bytes bit-identical at every size;
/// codecs built with non-default [`CodecOptions`](crate::config::CodecOptions)
/// carry their own threshold instead.
pub fn use_directory_default(n: usize, bucket_size: usize) -> bool {
    crate::config::CodecOptions::default().use_directory(n, bucket_size)
}

/// Hard ceiling on the dimension a frame header may declare. Protects the
/// unchecked [`decode`] path from hostile headers that would otherwise drive
/// gigantic allocations; `decode_expecting`/`decode_add` additionally bound
/// by the caller's true length. 2^28 coords ≈ 1 GiB of levels, comfortably
/// above every model shape in `models::zoo`.
pub const MAX_FRAME_DIM: usize = 1 << 28;

/// Hard ceiling on the declared level count `s` (levels must fit `i32` with
/// slack; the biggest legitimate `s` is `√n` for the §3.1 scheme).
pub const MAX_FRAME_S: u64 = 1 << 24;

/// Write the self-describing frame header from its raw fields, uniform-grid
/// (v1) layout. Shared by the two-phase [`encode`] and the fused
/// [`crate::coding::pipeline`] so both emit byte-identical frames.
pub fn write_frame_header(
    w: &mut BitWriter,
    s: u32,
    n: usize,
    bucket_size: usize,
    norm: Norm,
    regime: Regime,
) {
    write_frame_header_grid(w, &LevelGrid::Uniform { s }, n, bucket_size, norm, regime)
}

/// Grid-aware frame header: uniform grids emit the v1 layout unchanged;
/// non-uniform grids emit v2 with the grid described in-band.
pub fn write_frame_header_grid(
    w: &mut BitWriter,
    grid: &LevelGrid,
    n: usize,
    bucket_size: usize,
    norm: Norm,
    regime: Regime,
) {
    w.write_bits(FRAME_MAGIC, 8);
    w.write_bits(
        if grid.is_uniform() { FRAME_VERSION } else { FRAME_VERSION_GRID },
        4,
    );
    w.write_bit(matches!(regime, Regime::Sparse));
    w.write_bit(matches!(norm, Norm::Max));
    elias::encode(w, grid.s() as u64);
    elias::encode0(w, n as u64);
    elias::encode(w, bucket_size as u64);
    match grid {
        LevelGrid::Uniform { .. } => {}
        LevelGrid::Exponential { .. } => elias::encode(w, GRID_TAG_EXPONENTIAL),
        LevelGrid::Custom { points } => {
            elias::encode(w, GRID_TAG_CUSTOM);
            for &p in points.iter() {
                w.write_f32(p);
            }
        }
    }
}

/// v3 header: the v2 fields with the version nibble bumped, plus a grid
/// tag for *every* grid family (uniform included — v3 is not tagless). The
/// directory itself follows the header; [`encode_with_directory`] and the
/// fused pipeline write it from their recorded per-bucket byte lengths.
pub fn write_frame_header_dir(
    w: &mut BitWriter,
    grid: &LevelGrid,
    n: usize,
    bucket_size: usize,
    norm: Norm,
    regime: Regime,
) {
    w.write_bits(FRAME_MAGIC, 8);
    w.write_bits(FRAME_VERSION_DIR, 4);
    w.write_bit(matches!(regime, Regime::Sparse));
    w.write_bit(matches!(norm, Norm::Max));
    elias::encode(w, grid.s() as u64);
    elias::encode0(w, n as u64);
    elias::encode(w, bucket_size as u64);
    match grid {
        LevelGrid::Uniform { .. } => elias::encode(w, GRID_TAG_UNIFORM),
        LevelGrid::Exponential { .. } => elias::encode(w, GRID_TAG_EXPONENTIAL),
        LevelGrid::Custom { points } => {
            elias::encode(w, GRID_TAG_CUSTOM);
            for &p in points.iter() {
                w.write_f32(p);
            }
        }
    }
}

fn write_header(w: &mut BitWriter, g: &QuantizedGradient, regime: Regime) {
    debug_assert_eq!(g.s, g.grid.s());
    write_frame_header_grid(w, &g.grid, g.n, g.bucket_size, g.norm, regime)
}

struct Header {
    regime: Regime,
    norm: Norm,
    s: u32,
    grid: LevelGrid,
    n: usize,
    bucket_size: usize,
    /// Version 3: a bucket-offset directory follows the header.
    dir: bool,
}

fn read_header(r: &mut BitReader) -> Result<Header> {
    ensure!(r.read_bits(8)? == FRAME_MAGIC, "bad frame magic");
    let version = r.read_bits(4)?;
    ensure!(
        version == FRAME_VERSION
            || version == FRAME_VERSION_GRID
            || version == FRAME_VERSION_DIR,
        "unsupported frame version {version}"
    );
    let regime = if r.read_bit()? { Regime::Sparse } else { Regime::Dense };
    let norm = if r.read_bit()? { Norm::Max } else { Norm::L2 };
    let s64 = elias::decode(r)?;
    ensure!((1..=MAX_FRAME_S).contains(&s64), "level count {s64} out of range");
    let s = s64 as u32;
    let n64 = elias::decode0(r)?;
    ensure!(n64 <= MAX_FRAME_DIM as u64, "frame dimension {n64} out of range");
    let n = n64 as usize;
    let bucket_size = elias::decode(r)? as usize;
    ensure!(bucket_size >= 1, "zero bucket size");
    let grid = if version == FRAME_VERSION {
        LevelGrid::Uniform { s }
    } else {
        match elias::decode(r)? {
            GRID_TAG_UNIFORM if version == FRAME_VERSION_DIR => LevelGrid::Uniform { s },
            GRID_TAG_EXPONENTIAL => {
                ensure!(
                    s <= crate::quant::grid::MAX_EXPONENTIAL_LEVELS,
                    "exponential grid too deep: s={s}"
                );
                LevelGrid::exponential(s)
            }
            GRID_TAG_CUSTOM => {
                ensure!(
                    s as usize <= crate::quant::grid::MAX_CUSTOM_LEVELS,
                    "custom grid too large: s={s}"
                );
                // 32 bits per point — bound against the stream before
                // allocating, then re-validate the grid shape end-to-end
                ensure!(s as u64 * 32 <= r.bits_remaining(), "grid points exceed stream");
                let mut pts = Vec::with_capacity(s as usize);
                for _ in 0..s {
                    pts.push(r.read_f32()?);
                }
                LevelGrid::custom(pts)?
            }
            tag => bail!("unknown grid tag {tag}"),
        }
    };
    Ok(Header { regime, norm, s, grid, n, bucket_size, dir: version == FRAME_VERSION_DIR })
}

/// Smallest byte length a legitimate bucket payload can have: the 32-bit
/// scale plus at least one bit of level data (dense `d ≥ 1` coordinates, or
/// sparse `Elias'(nnz)`), byte-aligned ⇒ 40 bits. Directory entries below
/// this are hostile; rejecting them up front bounds the directory Vec by
/// `message_len / 5` entries (without it, a 1-bit-per-entry all-zero
/// directory could claim `8 × message_len` entries and drive a ~200×
/// allocation amplification before any payload validation).
const MIN_BUCKET_PAYLOAD_BYTES: u64 = 5;

/// Read a v3 frame's bucket-offset directory and byte-align the reader at
/// the payload base. Returns absolute `(byte_offset, byte_len)` per bucket,
/// every range verified to lie inside `bytes`. Hostile headers are bounded
/// before any size-proportional work: the bucket count must fit the
/// remaining stream, and every entry must be at least
/// [`MIN_BUCKET_PAYLOAD_BYTES`], so cumulative length checks fail fast.
fn read_directory(
    r: &mut BitReader,
    bytes: &[u8],
    n: usize,
    bucket_size: usize,
) -> Result<Vec<(usize, usize)>> {
    let nb = if n == 0 { 0 } else { n.div_ceil(bucket_size) };
    ensure!(nb as u64 <= r.bits_remaining(), "directory exceeds stream");
    let mut lens = Vec::with_capacity(nb.min(1 << 16));
    let mut total = 0u64;
    for _ in 0..nb {
        let len = elias::decode0(r)?;
        ensure!(len >= MIN_BUCKET_PAYLOAD_BYTES, "bucket payload too short: {len} bytes");
        total = total
            .checked_add(len)
            .ok_or_else(|| anyhow::anyhow!("directory length overflow"))?;
        ensure!(total <= bytes.len() as u64, "directory overruns message");
        lens.push(len as usize);
    }
    r.align_to_byte();
    let base = r.byte_pos();
    ensure!(base as u64 + total <= bytes.len() as u64, "directory overruns message");
    let mut off = base;
    Ok(lens
        .into_iter()
        .map(|l| {
            let entry = (off, l);
            off += l;
            entry
        })
        .collect())
}

/// Size of the shared encoder codeword table for quantization level `s`:
/// covers levels (≤ s) and typical run-length gaps; rare larger values fall
/// back to recursion. Shared with the fused pipeline so both paths pick the
/// same tabulated-vs-recursive codeword boundary.
pub fn encode_lut_max(s: u32) -> u64 {
    (s as u64 + 2).max(GAP_LUT).min((1 << 18) - 1)
}

/// Encode a quantized gradient with an explicit regime. The bucket-offset
/// directory follows [`use_directory_default`]; [`encode_with_directory`]
/// overrides it.
pub fn encode(g: &QuantizedGradient, regime: Regime) -> Vec<u8> {
    encode_with_directory(g, regime, use_directory_default(g.n, g.bucket_size))
}

/// Record one staged bucket's byte length for the directory: align the
/// staging writer to a byte boundary and push the delta since the previous
/// bucket. Shared by the two-phase encoder below and both fused paths
/// ([`crate::coding::pipeline`]), so the staging convention — and with it
/// the fused-vs-two-phase bit-identity — cannot drift between copies.
pub(crate) fn record_bucket_len(payload: &mut BitWriter, lens: &mut Vec<u64>, prev: &mut u64) {
    payload.align_to_byte();
    let now = payload.len_bits() / 8;
    lens.push(now - *prev);
    *prev = now;
}

/// Emit the directory entries (`Elias'(byte len)` each) and splice the
/// byte-aligned staged payload after them — the assembly tail shared with
/// the fused pipeline. The caller has already written the v3 header.
pub(crate) fn splice_directory_payload(
    w: &mut BitWriter,
    payload: &mut BitWriter,
    lens: &[u64],
    lut: &elias::EliasLut,
) {
    for &l in lens {
        lut.encode(w, l + 1);
    }
    w.align_to_byte();
    w.extend_aligned(payload.finish());
}

/// [`encode`] with the bucket-offset directory forced on or off. With the
/// directory, each bucket is entropy-coded into a staging buffer
/// (byte-aligned) so its byte length can precede it in the directory; the
/// payload bits are otherwise identical to the directory-less frame.
pub fn encode_with_directory(g: &QuantizedGradient, regime: Regime, directory: bool) -> Vec<u8> {
    // Dense regime lower-bounds at ~2.8 bits/coord; sparse at ~nnz·(log d).
    let cap = g.n / 2 + g.buckets.len() * 10 + 16;
    let mut w = BitWriter::with_capacity(cap);
    // One codeword table shared across all buckets.
    let lut = elias::EliasLut::new(encode_lut_max(g.s));
    if !directory {
        write_header(&mut w, g, regime);
        for b in &g.buckets {
            match regime {
                Regime::Sparse => encode_bucket_sparse_with(&mut w, b, &lut),
                Regime::Dense => encode_bucket_dense_with(&mut w, b, &lut),
            }
        }
        return w.into_bytes();
    }
    let mut payload = BitWriter::with_capacity(cap);
    let mut lens = Vec::with_capacity(g.buckets.len());
    let mut prev = 0u64;
    for b in &g.buckets {
        match regime {
            Regime::Sparse => encode_bucket_sparse_with(&mut payload, b, &lut),
            Regime::Dense => encode_bucket_dense_with(&mut payload, b, &lut),
        }
        record_bucket_len(&mut payload, &mut lens, &mut prev);
    }
    write_frame_header_dir(&mut w, &g.grid, g.n, g.bucket_size, g.norm, regime);
    splice_directory_payload(&mut w, &mut payload, &lens, &lut);
    w.into_bytes()
}

/// The regime [`encode_auto`] picks for a quantized gradient.
///
/// For the §4 max-norm variant the sparse analysis does not apply ("max
/// normalization no longer provides any sparsity guarantees"), so the
/// regime is chosen from the *measured* density: dense coding wins both on
/// size and decode speed once ≳25% of levels are nonzero. Shared with the
/// two-phase codec so oracle and fused pipeline cannot drift.
pub fn auto_regime(g: &QuantizedGradient) -> Regime {
    match g.norm {
        Norm::L2 => preferred_regime(g.s, g.bucket_size),
        Norm::Max => {
            if g.nnz() * 4 > g.n {
                Regime::Dense
            } else {
                preferred_regime(g.s, g.bucket_size)
            }
        }
    }
}

/// Encode with the paper's regime rule ([`auto_regime`]) applied per
/// gradient.
pub fn encode_auto(g: &QuantizedGradient) -> Vec<u8> {
    encode(g, auto_regime(g))
}

// --------------------------------------------------------------------------
// FrameView — the borrowed decode type
// --------------------------------------------------------------------------

/// A parsed, borrowed view of one encoded gradient frame: the v1/v2/v3
/// header (and, for v3, the bucket-offset directory) is parsed **once**,
/// after which every decode path — materialise, dequantize, fused
/// decode-add, intra-message-parallel decode-add — walks the payload
/// without copying it.
///
/// This is the single decode entry point of the stack: the module-level
/// [`decode`]/[`decode_add`]/[`par_decode_add_threads`] functions are thin
/// wrappers, and the QSGD codecs, `collectives::par_decode_mean`, the async
/// parameter server and the plan codec's segment decode all land here.
///
/// Hostile-input bounds are unchanged from the wrapper functions: the
/// declared dimension is capped ([`parse_with_limit`](Self::parse_with_limit)),
/// and a v3 directory is bounded by the stream before any
/// size-proportional allocation.
pub struct FrameView<'a> {
    bytes: &'a [u8],
    regime: Regime,
    norm: Norm,
    s: u32,
    grid: LevelGrid,
    n: usize,
    bucket_size: usize,
    /// Absolute bit offset where the serial payload begins (v1/v2 frames;
    /// for v3 frames the directory has already been consumed and bucket
    /// payloads are addressed by byte offset instead).
    payload_bit: u64,
    /// v3 frames: absolute `(byte offset, byte length)` of each bucket
    /// payload, every range verified to lie inside `bytes`.
    directory: Option<Vec<(usize, usize)>>,
}

impl<'a> FrameView<'a> {
    /// Parse a frame header (and directory, if v3). The declared dimension
    /// is capped at [`MAX_FRAME_DIM`]; when the expected gradient length is
    /// known, prefer [`Self::parse_with_limit`], which bounds hostile
    /// headers by it.
    pub fn parse(bytes: &'a [u8]) -> Result<Self> {
        Self::parse_with_limit(bytes, MAX_FRAME_DIM)
    }

    /// [`Self::parse`] with a caller-supplied ceiling on the declared
    /// dimension — applied before any size-proportional allocation.
    pub fn parse_with_limit(bytes: &'a [u8], max_n: usize) -> Result<Self> {
        let mut r = BitReader::new(bytes);
        let h = read_header(&mut r)?;
        ensure!(h.n <= max_n, "declared dimension {} exceeds limit {max_n}", h.n);
        let directory = if h.dir {
            Some(read_directory(&mut r, bytes, h.n, h.bucket_size)?)
        } else {
            None
        };
        Ok(FrameView {
            bytes,
            regime: h.regime,
            norm: h.norm,
            s: h.s,
            grid: h.grid,
            n: h.n,
            bucket_size: h.bucket_size,
            payload_bit: r.bit_pos(),
            directory,
        })
    }

    /// Decoded gradient length declared by the header.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Number of quantization levels `s`.
    pub fn s(&self) -> u32 {
        self.s
    }

    /// The level grid the frame's levels index into (parsed from the wire
    /// for v2/v3 frames; uniform for v1).
    pub fn grid(&self) -> &LevelGrid {
        &self.grid
    }

    pub fn norm(&self) -> Norm {
        self.norm
    }

    pub fn regime(&self) -> Regime {
        self.regime
    }

    /// Bucket size `d` (the final bucket may be shorter).
    pub fn bucket_size(&self) -> usize {
        self.bucket_size
    }

    pub fn bucket_count(&self) -> usize {
        if self.n == 0 {
            0
        } else {
            self.n.div_ceil(self.bucket_size)
        }
    }

    /// Dimension of bucket `i` (the last bucket may be shorter than
    /// [`Self::bucket_size`]).
    pub fn bucket_dim(&self, i: usize) -> usize {
        debug_assert!(i < self.bucket_count());
        (self.n - i * self.bucket_size).min(self.bucket_size)
    }

    /// Whether the frame carries the v3 bucket-offset directory (and can
    /// therefore decode its buckets in parallel).
    pub fn has_directory(&self) -> bool {
        self.directory.is_some()
    }

    /// The verified `(byte offset, byte length)` ranges of a v3 frame's
    /// bucket payloads, in bucket order.
    pub fn directory(&self) -> Option<&[(usize, usize)]> {
        self.directory.as_deref()
    }

    /// Zero-copy iteration over a v3 frame's bucket payloads as borrowed
    /// byte slices (bucket `i`'s slice decodes independently of every
    /// other). `None` for v1/v2 frames, whose bucket boundaries are only
    /// discovered by decoding.
    pub fn bucket_payloads(&self) -> Option<impl Iterator<Item = &'a [u8]> + '_> {
        let bytes = self.bytes;
        self.directory
            .as_ref()
            .map(move |d| d.iter().map(move |&(off, len)| &bytes[off..off + len]))
    }

    /// Materialise the quantized gradient (levels and scales).
    pub fn decode(&self) -> Result<QuantizedGradient> {
        let lut = decode_lut();
        // capacity clamp: a hostile header must not size this by bucket count
        let mut buckets = Vec::with_capacity(self.bucket_count().min(1024));
        let mut remaining = self.n;
        match &self.directory {
            Some(dir) => {
                for &(off, len) in dir {
                    let d = remaining.min(self.bucket_size);
                    let mut br = BitReader::new(&self.bytes[off..off + len]);
                    buckets.push(self.decode_bucket(&mut br, d, lut)?);
                    remaining -= d;
                }
            }
            None => {
                let mut r = BitReader::at(self.bytes, self.payload_bit);
                while remaining > 0 {
                    let d = remaining.min(self.bucket_size);
                    buckets.push(self.decode_bucket(&mut r, d, lut)?);
                    remaining -= d;
                }
            }
        }
        Ok(QuantizedGradient {
            s: self.s,
            grid: self.grid.clone(),
            bucket_size: self.bucket_size,
            norm: self.norm,
            n: self.n,
            buckets,
        })
    }

    fn decode_bucket(
        &self,
        r: &mut BitReader,
        d: usize,
        lut: &elias::DecodeLut,
    ) -> Result<QuantBucket> {
        match self.regime {
            Regime::Sparse => decode_bucket_sparse_with(r, d, self.s, lut),
            Regime::Dense => decode_bucket_dense_with(r, d, self.s, lut),
        }
    }

    /// Fused decode-and-accumulate: `acc[..n] += alpha · Q(v)` straight from
    /// the borrowed payload, without materialising levels (the paper's §6
    /// sparsity exploitation: O(nnz) per sparse bucket).
    pub fn decode_add(&self, alpha: f32, acc: &mut [f32]) -> Result<()> {
        self.decode_add_threads(alpha, acc, 1)
    }

    /// [`Self::decode_add`] with a thread budget: a directory-bearing frame
    /// maps contiguous bucket ranges to disjoint accumulator chunks and
    /// decodes them concurrently on the scoped pool
    /// ([`crate::util::par`]) — bit-identical to the serial walk at every
    /// budget, since bucket payloads are independent and the
    /// per-coordinate float ops are unchanged. Frames without a directory
    /// always walk serially.
    pub fn decode_add_threads(&self, alpha: f32, acc: &mut [f32], threads: usize) -> Result<()> {
        ensure!(self.n <= acc.len(), "accumulator too small: {} < {}", acc.len(), self.n);
        let lut = decode_lut();
        let pts = self.grid.nonzero_points();
        let dir = match &self.directory {
            None => {
                // v1/v2: no bucket boundaries in-band — serial stream walk.
                let mut r = BitReader::at(self.bytes, self.payload_bit);
                let mut off = 0usize;
                let mut remaining = self.n;
                while remaining > 0 {
                    let d = remaining.min(self.bucket_size);
                    decode_bucket_add(
                        &mut r,
                        self.regime,
                        self.s,
                        pts,
                        alpha,
                        &mut acc[off..off + d],
                        lut,
                    )?;
                    off += d;
                    remaining -= d;
                }
                return Ok(());
            }
            Some(dir) => dir,
        };
        let nb = dir.len();
        let jobs_n = threads.max(1).min(nb.max(1));
        if jobs_n <= 1 {
            let mut off = 0usize;
            let mut remaining = self.n;
            for &(o, l) in dir {
                let d = remaining.min(self.bucket_size);
                let mut br = BitReader::new(&self.bytes[o..o + l]);
                decode_bucket_add(
                    &mut br,
                    self.regime,
                    self.s,
                    pts,
                    alpha,
                    &mut acc[off..off + d],
                    lut,
                )?;
                off += d;
                remaining -= d;
            }
            return Ok(());
        }
        // Contiguous bucket ranges paired with disjoint accumulator chunks.
        // nb ≥ 2 implies bucket_size < n ≤ MAX_FRAME_DIM, so the chunk width
        // below cannot overflow.
        let bpj = nb.div_ceil(jobs_n);
        let chunk_coords = bpj * self.bucket_size;
        struct Job<'b> {
            acc: &'b mut [f32],
            first_bucket: usize,
        }
        let mut jobs: Vec<Job> = acc[..self.n]
            .chunks_mut(chunk_coords)
            .enumerate()
            .map(|(i, c)| Job { acc: c, first_bucket: i * bpj })
            .collect();
        let bytes = self.bytes;
        let results = par::par_map_mut(&mut jobs, |_, job| -> Result<()> {
            let mut off = 0usize;
            let mut bi = job.first_bucket;
            while off < job.acc.len() {
                let d = (job.acc.len() - off).min(self.bucket_size);
                let (o, l) = dir[bi];
                let mut br = BitReader::new(&bytes[o..o + l]);
                let chunk = &mut job.acc[off..off + d];
                decode_bucket_add(&mut br, self.regime, self.s, pts, alpha, chunk, lut)?;
                off += d;
                bi += 1;
            }
            Ok(())
        });
        for res in results {
            res?;
        }
        Ok(())
    }
}

/// Decode a frame produced by [`encode`]/[`encode_auto`]. The declared
/// dimension is capped at [`MAX_FRAME_DIM`]; when the expected length is
/// known, prefer [`decode_expecting`], which bounds hostile headers by it.
pub fn decode(bytes: &[u8]) -> Result<QuantizedGradient> {
    decode_with_limit(bytes, MAX_FRAME_DIM)
}

/// [`decode`] with a caller-supplied ceiling on the declared dimension —
/// the defense `decode_expecting` applies before any size-proportional
/// allocation happens.
pub fn decode_with_limit(bytes: &[u8], max_n: usize) -> Result<QuantizedGradient> {
    FrameView::parse_with_limit(bytes, max_n)?.decode()
}

/// Process-wide decoder prefix table (immutable after first use).
fn decode_lut() -> &'static elias::DecodeLut {
    use std::sync::OnceLock;
    static LUT: OnceLock<elias::DecodeLut> = OnceLock::new();
    LUT.get_or_init(|| elias::DecodeLut::new(DECODE_LUT_W))
}

/// Decode one bucket payload and accumulate `alpha·Q(bucket)` into `acc`
/// (whose length is the bucket dimension) — the shared kernel of the
/// serial and parallel decode-add paths. Per coordinate the float ops are
/// identical, so any work split over buckets produces a bit-identical
/// accumulator.
fn decode_bucket_add(
    r: &mut BitReader,
    regime: Regime,
    s: u32,
    pts: Option<&[f32]>,
    alpha: f32,
    acc: &mut [f32],
    lut: &elias::DecodeLut,
) -> Result<()> {
    let d = acc.len();
    let scale = r.read_f32()?;
    let k = alpha * scale / s as f32;
    let ka = alpha * scale;
    // non-uniform grids dequantize via the point table; `mag ≥ 1` is
    // enforced below before indexing it
    let value = |mag: u64| -> f32 {
        match pts {
            None => mag as f32 * k,
            Some(p) => ka * p[(mag - 1) as usize],
        }
    };
    match regime {
        Regime::Sparse => {
            let nnz = lut.decode0(r)? as usize;
            ensure!(nnz <= d, "nnz {nnz} exceeds bucket size {d}");
            let mut prev: i64 = -1;
            for _ in 0..nnz {
                let gap = lut.decode(r)?;
                ensure!(gap >= 1 && gap <= d as u64, "gap {gap} out of bucket");
                let idx = prev + gap as i64;
                ensure!(idx >= 0 && (idx as usize) < d, "nonzero index out of bucket");
                let neg = r.read_bit()?;
                let mag = lut.decode(r)?;
                ensure!(mag >= 1 && mag <= s as u64, "level out of range");
                let val = value(mag);
                acc[idx as usize] += if neg { -val } else { val };
                prev = idx;
            }
        }
        Regime::Dense => {
            for a in acc.iter_mut() {
                let mag = lut.decode0(r)?;
                ensure!(mag <= s as u64, "level exceeds s");
                if mag != 0 {
                    let neg = r.read_bit()?;
                    let val = value(mag);
                    *a += if neg { -val } else { val };
                }
            }
        }
    }
    Ok(())
}

/// Fused decode-and-accumulate: `acc += alpha · Q_s(v)` straight from the
/// wire bytes, without materialising the levels — a thin wrapper over
/// [`FrameView::decode_add`].
///
/// This is the sparsity exploitation the paper's §6 names as future work
/// ("current implementations of MPI do not provide support for sparse
/// types"): in the sparse regime the cost is O(nnz) per message instead of
/// O(n) — for s=1, ~√n work per peer. Returns the decoded length.
/// Directory-bearing (v3) frames can instead fan their buckets out across
/// threads — see [`par_decode_add`]; this entry point stays serial.
pub fn decode_add(bytes: &[u8], alpha: f32, acc: &mut [f32]) -> Result<usize> {
    par_decode_add_threads(bytes, alpha, acc, 1)
}

/// [`decode_add`] with intra-message parallelism
/// ([`FrameView::decode_add_threads`] at the process-wide budget): for v3
/// frames the bucket-offset directory yields per-bucket byte ranges, which
/// map to disjoint accumulator chunks and decode concurrently on the scoped
/// pool ([`crate::util::par`]) — bit-identical to the serial walk. Frames
/// without a directory fall back to the serial walk.
pub fn par_decode_add(bytes: &[u8], alpha: f32, acc: &mut [f32]) -> Result<usize> {
    par_decode_add_threads(bytes, alpha, acc, par::max_threads())
}

/// [`par_decode_add`] with an explicit thread budget (`≤ 1` ⇒ serial) —
/// the knob `collectives::par_decode_mean` uses to split cores between
/// concurrent messages and buckets within a message.
pub fn par_decode_add_threads(
    bytes: &[u8],
    alpha: f32,
    acc: &mut [f32],
    threads: usize,
) -> Result<usize> {
    let view = FrameView::parse(bytes)?;
    view.decode_add_threads(alpha, acc, threads)?;
    Ok(view.n())
}

/// Decode a frame and dequantize, checking the decoded length against the
/// caller's expectation — the shared `decode` body of both the fused and
/// two-phase codecs.
pub fn decode_expecting(msg: &[u8], n: usize) -> Result<Vec<f32>> {
    // bound hostile headers by the *expected* length before any
    // size-proportional allocation
    let q = decode_with_limit(msg, n)?;
    ensure!(q.n == n, "decoded length {} != expected {n}", q.n);
    Ok(q.dequantize())
}

/// Fused decode-and-accumulate with the length check (shared `decode_add`
/// body of both QSGD codecs).
pub fn decode_add_expecting(msg: &[u8], alpha: f32, acc: &mut [f32]) -> Result<()> {
    let n = decode_add(msg, alpha, acc)?;
    ensure!(n == acc.len(), "decoded length {n} != expected {}", acc.len());
    Ok(())
}

/// Intra-message-parallel decode-and-accumulate with the length check
/// (shared `decode_add_threads` body of the QSGD codecs).
pub fn par_decode_add_expecting(
    msg: &[u8],
    alpha: f32,
    acc: &mut [f32],
    threads: usize,
) -> Result<()> {
    let n = par_decode_add_threads(msg, alpha, acc, threads)?;
    ensure!(n == acc.len(), "decoded length {n} != expected {}", acc.len());
    Ok(())
}

// --------------------------------------------------------------------------
// Theoretical bounds (for the theory_bounds bench / tests)
// --------------------------------------------------------------------------

/// Theorem 3.2 bound on E|Code_s(Q_s(v))| in bits for a d-dim vector:
/// `(3 + (3/2+o(1))·log(2(s²+d)/(s(s+√d))))·s(s+√d) + 32`, instantiated with
/// o(1) = 0 and the Lemma 3.1(iii) sparsity `s(s+√d)`. (Lemma A.5's tighter
/// `s²+√d` drops an `s` factor on the `Σu_i` term relative to its own
/// stated nonzero-probability; the Theorem 3.2 form is the one the real
/// encoder observably satisfies.)
pub fn sparse_bits_bound(d: usize, s: u32) -> f64 {
    let d = d as f64;
    let s = s as f64;
    let nnz = s * (s + d.sqrt());
    (3.0 + 1.5 * ((2.0 * (s * s + d)) / nnz).log2()) * nnz + 32.0
}

/// Lemma A.6 bound on E|Code'_s(Q_s(v))| with o(1) = 0:
/// `F + (1/2·(log(1 + (s²+min(d,s√d))/d) + 1) + 2)·d` ≈ 3.3·d + 32 at
/// `s=√d`. Corollary 3.3's headline "2.8n + 32" drops lower-order terms;
/// the measured-vs-2.8n comparison is reported by the theory_bounds bench.
pub fn dense_bits_bound(d: usize, s: u32) -> f64 {
    let d = d as f64;
    let s = s as f64;
    32.0 + (0.5 * ((1.0 + (s * s + d.min(s * d.sqrt())) / d).log2() + 1.0) + 2.0) * d
}

/// Estimate of the encoded size in bytes for an `n`-coordinate gradient
/// quantized onto `grid` over `bucket_size`-sized buckets (with an
/// optionally forced `regime`, as the codecs carry it), without encoding
/// anything. Backs
/// [`Codec::encoded_size_hint`](crate::quant::Codec::encoded_size_hint) for
/// byte accounting and buffer pre-sizing.
///
/// * `Norm::L2` with the auto regime: the paper's expectation bounds per
///   bucket ([`sparse_bits_bound`] / [`dense_bits_bound`] under the regime
///   rule) — an expectation, not a per-draw bound.
/// * `Norm::Max` (no sparsity guarantee) or any *forced* regime: a
///   **worst-case** per-coordinate budget covering both codecs — dense
///   costs at most `|Elias'(s)| + 1` bits/coordinate; sparse at most
///   `|Elias(s)| + 2` (a fully dense bucket has all-ones gaps) plus the
///   `Elias'(nnz)` field. This makes the hint a safe `Vec` pre-size for
///   every max-norm or pinned-regime session.
///
/// The header term is computed from the actual Elias field widths (magic,
/// version, flags, `s`, `n`, bucket size, grid tag) and includes the
/// in-band grid points a custom grid ships (32 bits per level — see
/// [`write_frame_header_grid`]); when `directory`, the v3 overhead (one
/// `Elias'(byte len)` entry and byte alignment per bucket) is added.
pub fn encoded_size_hint(
    n: usize,
    grid: &LevelGrid,
    bucket_size: usize,
    norm: Norm,
    regime: Option<Regime>,
    directory: bool,
) -> usize {
    let s = grid.s();
    let bucket = bucket_size.min(n.max(1)).max(1);
    // magic + version + regime/norm flags + Elias(s) + Elias'(n) +
    // Elias(bucket) + the largest grid tag any frame version carries
    // (uniform v1 frames are tagless — budgeting the v3 tag keeps this an
    // upper bound for them too).
    let tag_bits = match grid {
        LevelGrid::Uniform { .. } => elias::len(GRID_TAG_UNIFORM),
        LevelGrid::Exponential { .. } => elias::len(GRID_TAG_EXPONENTIAL),
        LevelGrid::Custom { points } => elias::len(GRID_TAG_CUSTOM) + points.len() as u64 * 32,
    };
    let header_bits = (8 + 4 + 1 + 1) as u64
        + elias::len(s as u64)
        + elias::len(n as u64 + 1)
        + elias::len(bucket as u64)
        + tag_bits;
    if n == 0 {
        return (header_bits as f64 / 8.0).ceil() as usize;
    }
    let nb = n.div_ceil(bucket);
    let per_bucket = if norm == Norm::L2 && regime.is_none() {
        match preferred_regime(s, bucket) {
            Regime::Sparse => sparse_bits_bound(bucket, s),
            Regime::Dense => dense_bits_bound(bucket, s),
        }
    } else {
        // worst case over whichever codec can run: per coordinate, dense is
        // Elias'(level) + sign; sparse is gap + sign + Elias(level), with
        // all-ones gaps (1 bit) at full density dominating by concavity of
        // the Elias length in the gap.
        let dense_coord = (elias::len(s as u64 + 1) + 1) as f64;
        let sparse_coord = (elias::len(s as u64) + 2) as f64;
        32.0 + elias::len(bucket as u64 + 1) as f64 + bucket as f64 * dense_coord.max(sparse_coord)
    };
    let mut bits = header_bits as f64 + per_bucket * nb as f64;
    if directory {
        bits += 32.0 * nb as f64;
    }
    (bits / 8.0).ceil() as usize
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::stochastic;
    use crate::util::rng::Xoshiro256;
    

    fn randn(n: usize, seed: u64) -> Vec<f32> {
        
        let mut r = Xoshiro256::from_u64(seed);
        (0..n).map(|_| crate::util::rng::uniform_f32(&mut r) * 2.0 - 1.0).collect()
    }

    #[test]
    fn roundtrip_both_regimes() {
        let v = randn(3000, 0);
        let mut rng = Xoshiro256::from_u64(1);
        for s in [1u32, 7, 127] {
            for bucket in [128usize, 512, 3000] {
                for norm in [Norm::L2, Norm::Max] {
                    let q = stochastic::quantize(&v, s, bucket, norm, &mut rng);
                    for regime in [Regime::Sparse, Regime::Dense] {
                        let bytes = encode(&q, regime);
                        let q2 = decode(&bytes).unwrap();
                        assert_eq!(q, q2, "s={s} bucket={bucket} {regime:?}");
                    }
                    let bytes = encode_auto(&q);
                    assert_eq!(decode(&bytes).unwrap(), q);
                }
            }
        }
    }

    #[test]
    fn sparse_beats_dense_in_sparse_regime() {
        // s=1 over a large bucket: quantized vector has ~√d nonzeros; the
        // gap coding must win by a wide margin.
        let v = randn(16384, 2);
        let mut rng = Xoshiro256::from_u64(3);
        let q = stochastic::quantize(&v, 1, v.len(), Norm::L2, &mut rng);
        let sp = encode(&q, Regime::Sparse).len();
        let de = encode(&q, Regime::Dense).len();
        assert!(sp * 3 < de, "sparse {sp} vs dense {de}");
        assert_eq!(preferred_regime(1, v.len()), Regime::Sparse);
    }

    #[test]
    fn dense_regime_meets_corollary_3_3() {
        // s = √n: expected code length ≤ 2.8n + 32 bits.
        let n = 4096;
        let s = (n as f64).sqrt() as u32;
        let v = randn(n, 4);
        let mut rng = Xoshiro256::from_u64(5);
        let mut total_bits = 0u64;
        let trials = 30;
        for _ in 0..trials {
            let q = stochastic::quantize(&v, s, n, Norm::L2, &mut rng);
            total_bits += encode(&q, Regime::Dense).len() as u64 * 8;
        }
        let avg = total_bits as f64 / trials as f64;
        // Rigorous Lemma A.6 bound always holds; the Corollary 3.3 headline
        // figure (2.8n + 32) should hold within a few percent with the
        // sign-skip optimisation (gaussian gradients measure ≈2.7–2.9 b/coord).
        assert!(avg <= dense_bits_bound(n, s), "avg {avg} vs Lemma A.6 {}", dense_bits_bound(n, s));
        assert!(avg <= 1.15 * (2.8 * n as f64 + 32.0), "avg {avg} vs 1.15·(2.8n+32)");
        assert_eq!(preferred_regime(s, n), Regime::Dense);
    }

    #[test]
    fn sparse_regime_meets_theorem_3_2() {
        let n = 16384;
        let v = randn(n, 6);
        let mut rng = Xoshiro256::from_u64(7);
        for s in [1u32, 2, 4] {
            let mut total = 0u64;
            let trials = 20;
            for _ in 0..trials {
                let q = stochastic::quantize(&v, s, n, Norm::L2, &mut rng);
                total += encode(&q, Regime::Sparse).len() as u64 * 8;
            }
            let avg = total as f64 / trials as f64;
            let bound = sparse_bits_bound(n, s);
            assert!(avg <= bound, "s={s}: avg {avg} > bound {bound}");
        }
    }

    #[test]
    fn decode_rejects_corruption() {
        let v = randn(256, 8);
        let mut rng = Xoshiro256::from_u64(9);
        let q = stochastic::quantize(&v, 7, 64, Norm::Max, &mut rng);
        let mut bytes = encode_auto(&q);
        bytes[0] ^= 0xff; // clobber magic
        assert!(decode(&bytes).is_err());
        assert!(decode(&[]).is_err());
        // truncation
        let bytes = encode_auto(&q);
        assert!(decode(&bytes[..bytes.len() / 2]).is_err());
    }

    #[test]
    fn decode_add_matches_decode_then_add() {
        let v = randn(5000, 10);
        let mut rng = Xoshiro256::from_u64(11);
        for (s, bucket, norm) in [(1u32, 5000usize, Norm::L2), (7, 512, Norm::Max)] {
            let q = stochastic::quantize(&v, s, bucket, norm, &mut rng);
            for regime in [Regime::Sparse, Regime::Dense] {
                let bytes = encode(&q, regime);
                let mut acc1 = vec![1.0f32; 5000];
                let n = decode_add(&bytes, 0.5, &mut acc1).unwrap();
                assert_eq!(n, 5000);
                let mut acc2 = vec![1.0f32; 5000];
                decode(&bytes).unwrap().dequantize_add(0.5, &mut acc2);
                for (a, b) in acc1.iter().zip(&acc2) {
                    assert!((a - b).abs() < 1e-6);
                }
            }
        }
        // accumulator too small is rejected
        let q = stochastic::quantize(&v, 7, 512, Norm::Max, &mut rng);
        let bytes = encode_auto(&q);
        assert!(decode_add(&bytes, 1.0, &mut vec![0.0; 10]).is_err());
    }

    #[test]
    fn directory_frames_roundtrip_and_parallel_decode_matches_serial() {
        let v = randn(7000, 20);
        let mut rng = Xoshiro256::from_u64(21);
        for (grid, norm) in [
            (LevelGrid::uniform(7), Norm::Max),
            (LevelGrid::exponential(4), Norm::Max),
            (LevelGrid::custom(vec![0.2, 0.6, 1.0]).unwrap(), Norm::L2),
        ] {
            let q = stochastic::quantize_grid(&v, &grid, 512, norm, &mut rng);
            for regime in [Regime::Sparse, Regime::Dense] {
                let plain = encode_with_directory(&q, regime, false);
                let dirred = encode_with_directory(&q, regime, true);
                assert_ne!(plain, dirred);
                // version nibble: high 4 bits of byte 1
                assert_eq!(dirred[1] >> 4, FRAME_VERSION_DIR as u8);
                // both decode to the same quantized gradient
                assert_eq!(decode(&dirred).unwrap(), decode(&plain).unwrap());
                assert_eq!(decode(&dirred).unwrap(), q);
                // serial and parallel decode-add agree bit-for-bit at every
                // thread budget, and with the directory-less frame
                let mut base = vec![0.125f32; 7000];
                decode_add(&plain, 0.5, &mut base).unwrap();
                for threads in [1usize, 2, 3, 8, 64] {
                    let mut acc = vec![0.125f32; 7000];
                    let n = par_decode_add_threads(&dirred, 0.5, &mut acc, threads).unwrap();
                    assert_eq!(n, 7000);
                    assert_eq!(acc, base, "threads={threads} {regime:?} {}", grid.label());
                }
            }
        }
    }

    #[test]
    fn directory_rule_is_size_thresholded() {
        assert!(!use_directory_default(0, 512));
        assert!(!use_directory_default(DIRECTORY_MIN_COORDS - 1, 512));
        assert!(use_directory_default(DIRECTORY_MIN_COORDS, 512));
        // a single bucket has nothing to parallelize
        assert!(!use_directory_default(DIRECTORY_MIN_COORDS, usize::MAX));
        // and encode() applies the rule: small frames stay v1
        let v = randn(64, 22);
        let q = stochastic::quantize(&v, 7, 64, Norm::Max, &mut Xoshiro256::from_u64(23));
        let bytes = encode(&q, Regime::Dense);
        assert_eq!(bytes[1] >> 4, FRAME_VERSION as u8);
    }

    #[test]
    fn empty_gradient() {
        let q = stochastic::quantize(&[], 4, 16, Norm::L2, &mut Xoshiro256::from_u64(0));
        let bytes = encode_auto(&q);
        let q2 = decode(&bytes).unwrap();
        assert_eq!(q2.n, 0);
        assert!(q2.dequantize().is_empty());
        let view = FrameView::parse(&bytes).unwrap();
        assert_eq!(view.n(), 0);
        assert_eq!(view.bucket_count(), 0);
    }

    #[test]
    fn frame_view_exposes_header_and_buckets_without_copying() {
        let v = randn(2000, 30);
        let mut rng = Xoshiro256::from_u64(31);
        let grid = LevelGrid::exponential(7);
        let q = stochastic::quantize_grid(&v, &grid, 512, Norm::Max, &mut rng);
        for (directory, version) in [(false, FRAME_VERSION_GRID), (true, FRAME_VERSION_DIR)] {
            let bytes = encode_with_directory(&q, Regime::Dense, directory);
            assert_eq!(bytes[1] >> 4, version as u8);
            let view = FrameView::parse(&bytes).unwrap();
            assert_eq!(view.n(), 2000);
            assert_eq!(view.s(), 7);
            assert_eq!(view.bucket_size(), 512);
            assert_eq!(view.bucket_count(), 4);
            assert_eq!(view.bucket_dim(3), 2000 - 3 * 512);
            assert_eq!(view.norm(), Norm::Max);
            assert_eq!(view.regime(), Regime::Dense);
            assert_eq!(view.grid(), &grid);
            assert_eq!(view.has_directory(), directory);
            // one parse, many decodes — all equal to the one-shot decode
            assert_eq!(view.decode().unwrap(), q);
            assert_eq!(view.decode().unwrap(), decode(&bytes).unwrap());
            let mut a = vec![0.5f32; 2000];
            let mut b = vec![0.5f32; 2000];
            view.decode_add(0.25, &mut a).unwrap();
            decode_add(&bytes, 0.25, &mut b).unwrap();
            assert_eq!(a, b);
            if directory {
                // bucket payload slices borrow the frame and tile it exactly
                let dir = view.directory().unwrap();
                assert_eq!(dir.len(), 4);
                let payloads: Vec<&[u8]> = view.bucket_payloads().unwrap().collect();
                assert_eq!(payloads.len(), 4);
                let total: usize = dir.iter().map(|&(_, l)| l).sum();
                assert_eq!(dir[0].0 + total, bytes.len());
                // each payload decodes independently to the matching bucket
                for (i, p) in payloads.iter().enumerate() {
                    let mut br = crate::coding::bitstream::BitReader::new(p);
                    let b =
                        decode_bucket_dense_with(&mut br, view.bucket_dim(i), 7, decode_lut())
                            .unwrap();
                    assert_eq!(b, q.buckets[i]);
                }
            } else {
                assert!(view.directory().is_none());
                assert!(view.bucket_payloads().is_none());
            }
        }
    }

    #[test]
    fn frame_view_limit_bounds_hostile_headers() {
        let v = randn(300, 32);
        let q = stochastic::quantize(&v, 7, 64, Norm::Max, &mut Xoshiro256::from_u64(33));
        let bytes = encode_auto(&q);
        assert!(FrameView::parse_with_limit(&bytes, 299).is_err());
        assert!(FrameView::parse_with_limit(&bytes, 300).is_ok());
        // accumulator shorter than n is rejected by decode_add
        let view = FrameView::parse(&bytes).unwrap();
        let mut small = vec![0.0f32; 299];
        assert!(view.decode_add(1.0, &mut small).is_err());
        // a longer accumulator only receives the first n coordinates
        let mut long = vec![1.0f32; 301];
        view.decode_add(1.0, &mut long).unwrap();
        assert_eq!(long[300], 1.0);
    }
}
