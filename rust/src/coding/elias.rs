//! Recursive Elias (Elias omega) integer coding — paper Definition A.1.
//!
//! `Elias(k)` for `k ≥ 1`: start with a terminating `0`; while `k > 1`,
//! prepend the binary representation of `k` and recurse on
//! `k' = (bits in that representation) − 1`. Length satisfies
//! `|Elias(k)| ≤ log k + log log k + … + 1 = (1+o(1))·log k + 1` (Lemma A.1).
//!
//! `Elias'(k) = Elias(k+1)` extends the code to `k = 0` (used by the dense
//! `Code'_s` of Corollary 3.3, Appendix A.3).

use super::bitstream::{BitReader, BitWriter, BitstreamExhausted};

/// Encode `k ≥ 1` (panics on 0 in debug; the dense codec uses [`encode0`]).
#[inline]
pub fn encode(w: &mut BitWriter, mut k: u64) {
    debug_assert!(k >= 1, "Elias omega is defined on positive integers");
    // Collect the groups (they are *prepended*, so emit in reverse).
    // At most 6 groups for u64 (64 -> 6 -> 2 -> 1).
    let mut groups: [(u64, u32); 8] = [(0, 0); 8];
    let mut ng = 0;
    while k > 1 {
        let bits = 64 - k.leading_zeros();
        groups[ng] = (k, bits);
        ng += 1;
        k = (bits - 1) as u64;
    }
    for i in (0..ng).rev() {
        let (v, bits) = groups[i];
        w.write_bits(v, bits);
    }
    w.write_bit(false);
}

/// Decode an omega-coded positive integer.
#[inline]
pub fn decode(r: &mut BitReader) -> Result<u64, BitstreamExhausted> {
    let mut n: u64 = 1;
    loop {
        if !r.read_bit()? {
            return Ok(n);
        }
        if n >= 64 {
            // Malformed stream: would overflow u64. Treat as exhaustion.
            return Err(BitstreamExhausted);
        }
        // The group starts with the `1` we just consumed, followed by n bits.
        n = (1 << n) | r.read_bits(n as u32)?;
    }
}

/// `Elias'(k) = Elias(k+1)` — zero-capable variant (Appendix A.3).
#[inline]
pub fn encode0(w: &mut BitWriter, k: u64) {
    encode(w, k + 1);
}

#[inline]
pub fn decode0(r: &mut BitReader) -> Result<u64, BitstreamExhausted> {
    Ok(decode(r)? - 1)
}

/// Code length in bits, without encoding (for bound checks / sizing).
#[inline]
pub fn len(mut k: u64) -> u64 {
    debug_assert!(k >= 1);
    let mut bits = 1; // terminating 0
    while k > 1 {
        let b = 64 - k.leading_zeros();
        bits += b as u64;
        k = (b - 1) as u64;
    }
    bits
}

/// Precomputed codeword table for small integers — the encoder hot path.
///
/// Quantized levels are bounded by `s` (≤ 255 for 8-bit QSGD) and run-length
/// gaps are short in the dense-ish regimes, so almost every emitted codeword
/// comes from this table as a single `write_bits` call instead of the
/// group-by-group recursion (≈3× encode speedup, see EXPERIMENTS.md §Perf).
pub struct EliasLut {
    /// codes[k-1] = (pattern, bits) for k in [1, len].
    codes: Vec<(u32, u32)>,
}

impl EliasLut {
    /// Build a table covering `1..=max_k` (codewords must fit 32 bits, which
    /// holds for max_k < 2^18: len(2^18) = 19+5+3+2+1 = 30).
    pub fn new(max_k: u64) -> Self {
        assert!(max_k >= 1 && max_k < (1 << 18));
        let codes = (1..=max_k)
            .map(|k| {
                let mut w = BitWriter::new();
                encode(&mut w, k);
                let bits = w.len_bits() as u32;
                debug_assert!(bits <= 32);
                let bytes = w.into_bytes();
                let mut pat: u32 = 0;
                for (i, &b) in bytes.iter().enumerate() {
                    pat |= (b as u32) << (24 - 8 * i);
                }
                (pat >> (32 - bits), bits)
            })
            .collect();
        Self { codes }
    }

    /// Codeword for `k`, if tabulated.
    #[inline]
    pub fn get(&self, k: u64) -> Option<(u32, u32)> {
        self.codes.get((k - 1) as usize).copied()
    }

    /// Encode `k`, via the table when possible.
    #[inline]
    pub fn encode(&self, w: &mut BitWriter, k: u64) {
        match self.get(k) {
            Some((pat, bits)) => w.write_bits(pat as u64, bits),
            None => encode(w, k),
        }
    }
}

/// Prefix-table decoder: a `2^W`-entry table maps the next W bits directly
/// to `(value, codeword length)` for every integer whose omega code fits in
/// W bits; longer codewords fall back to the bit-serial [`decode`]. The
/// decoder hot path (one lookup per codeword) replaces ~4 `read_bit` calls
/// per level — see EXPERIMENTS.md §Perf.
pub struct DecodeLut {
    w: u32,
    /// table[prefix] = (value, bits); bits == 0 ⇒ fall back.
    table: Vec<(u32, u8)>,
}

impl DecodeLut {
    /// `w ≤ 16` keeps the table ≤ 512 KiB; w = 14 covers all levels of
    /// 8-bit QSGD (|Elias(128)| = 14) and typical sparse gaps.
    pub fn new(w: u32) -> Self {
        assert!((1..=16).contains(&w));
        let mut table = vec![(0u32, 0u8); 1usize << w];
        // enumerate k by increasing code length; stop once len(k) > w
        let mut k = 1u64;
        loop {
            let bits = len(k) as u32;
            if bits > w {
                // omega code lengths are not monotone in k, so scan on until
                // lengths exceed w for a whole stretch; bound the scan.
                if k > (1 << w) {
                    break;
                }
                k += 1;
                continue;
            }
            let mut bw = BitWriter::new();
            encode(&mut bw, k);
            let bytes = bw.into_bytes();
            let mut pat: u32 = 0;
            for (i, &b) in bytes.iter().enumerate().take(4) {
                pat |= (b as u32) << (24 - 8 * i);
            }
            let prefix = (pat >> (32 - w)) as usize; // code left-aligned in w bits
            let free = w - bits;
            for fill in 0..(1usize << free) {
                table[(prefix & !((1usize << free) - 1)) | fill] = (k as u32, bits as u8);
            }
            k += 1;
        }
        Self { w, table }
    }

    /// Decode one integer, via the table when the codeword is short enough.
    #[inline]
    pub fn decode(&self, r: &mut BitReader) -> Result<u64, BitstreamExhausted> {
        let prefix = r.peek_bits(self.w) as usize;
        let (v, bits) = self.table[prefix];
        if bits != 0 {
            r.advance(bits as u32)?;
            Ok(v as u64)
        } else {
            decode(r)
        }
    }

    /// `Elias'` variant.
    #[inline]
    pub fn decode0(&self, r: &mut BitReader) -> Result<u64, BitstreamExhausted> {
        Ok(self.decode(r)? - 1)
    }
}

// --------------------------------------------------------------------------
// Elias gamma / delta — ablation codes (DESIGN.md: the paper picks omega for
// its (1+o(1))·log k asymptotics; gamma is 2·log k + 1 and delta is
// log k + 2·log log k + 1, so for the small integers QSGD actually emits the
// ranking can invert — the theory_bounds bench measures it).
// --------------------------------------------------------------------------

/// Elias gamma: ⌊log k⌋ zeros, then the binary representation of k.
#[inline]
pub fn encode_gamma(w: &mut BitWriter, k: u64) {
    debug_assert!(k >= 1);
    let bits = 64 - k.leading_zeros();
    w.write_bits(0, bits - 1);
    w.write_bits(k, bits);
}

#[inline]
pub fn decode_gamma(r: &mut BitReader) -> Result<u64, BitstreamExhausted> {
    let mut zeros = 0u32;
    while !r.read_bit()? {
        zeros += 1;
        if zeros >= 64 {
            return Err(BitstreamExhausted);
        }
    }
    // leading 1 already consumed
    Ok((1u64 << zeros) | r.read_bits(zeros)?)
}

pub fn len_gamma(k: u64) -> u64 {
    let bits = (64 - k.leading_zeros()) as u64;
    2 * bits - 1
}

/// Elias delta: gamma(bit length) then the remaining bits of k.
#[inline]
pub fn encode_delta(w: &mut BitWriter, k: u64) {
    debug_assert!(k >= 1);
    let bits = 64 - k.leading_zeros();
    encode_gamma(w, bits as u64);
    if bits > 1 {
        w.write_bits(k & ((1u64 << (bits - 1)) - 1), bits - 1);
    }
}

#[inline]
pub fn decode_delta(r: &mut BitReader) -> Result<u64, BitstreamExhausted> {
    let bits = decode_gamma(r)? as u32;
    if bits == 0 || bits > 64 {
        return Err(BitstreamExhausted);
    }
    if bits == 1 {
        return Ok(1);
    }
    Ok((1u64 << (bits - 1)) | r.read_bits(bits - 1)?)
}

pub fn len_delta(k: u64) -> u64 {
    let bits = (64 - k.leading_zeros()) as u64;
    len_gamma(bits) + bits - 1
}

/// The paper's analytic upper bound `(1+o(1))·log k + 1`, instantiated as
/// `log k + log log k + log log log k + … + 1` (Lemma A.1(1)).
pub fn len_bound(k: u64) -> f64 {
    let mut x = k as f64;
    let mut total = 1.0;
    while x > 1.0 {
        let l = x.log2();
        if l <= 0.0 {
            break;
        }
        total += l;
        x = l;
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(k: u64) -> u64 {
        let mut w = BitWriter::new();
        encode(&mut w, k);
        assert_eq!(w.len_bits(), len(k));
        let bytes = w.into_bytes();
        decode(&mut BitReader::new(&bytes)).unwrap()
    }

    #[test]
    fn known_codewords() {
        // Canonical omega codes: 1 -> "0", 2 -> "10 0", 3 -> "11 0",
        // 4 -> "10 100 0" ... check lengths and first values.
        assert_eq!(len(1), 1);
        assert_eq!(len(2), 3);
        assert_eq!(len(3), 3);
        assert_eq!(len(4), 6); // "10" + "100" + "0"
        assert_eq!(len(16), 11); // "10" + "100" + "10000" + "0"
        assert_eq!(len(100), 13); // "10" + "110" + "1100100" + "0"
        let mut w = BitWriter::new();
        encode(&mut w, 1);
        assert_eq!(w.into_bytes(), vec![0b0000_0000]);
        let mut w = BitWriter::new();
        encode(&mut w, 2);
        assert_eq!(w.into_bytes(), vec![0b1000_0000]);
    }

    #[test]
    fn roundtrip_small_and_large() {
        for k in 1..=2000 {
            assert_eq!(roundtrip(k), k);
        }
        for k in [u32::MAX as u64, 1 << 40, u64::MAX / 2, u64::MAX] {
            assert_eq!(roundtrip(k), k);
        }
    }

    #[test]
    fn zero_capable_variant() {
        for k in 0..500 {
            let mut w = BitWriter::new();
            encode0(&mut w, k);
            let bytes = w.into_bytes();
            assert_eq!(decode0(&mut BitReader::new(&bytes)).unwrap(), k);
        }
    }

    #[test]
    fn length_within_paper_bound() {
        // Lemma A.1: |Elias(k)| ≤ log k + log log k + ... + 1, up to the
        // +O(1) slack from ceil'd group sizes. Allow the standard +2·groups.
        for k in 1..100_000u64 {
            let l = len(k) as f64;
            assert!(l <= len_bound(k) + 2.0 * (1.0 + (k as f64).log2().max(1.0).log2().max(0.0)) + 3.0,
                "k={k} len={l} bound={}", len_bound(k));
        }
    }

    #[test]
    fn sequence_roundtrip() {
        let ks: Vec<u64> = (1..300).map(|i| (i * 2654435761u64) % 10_000 + 1).collect();
        let mut w = BitWriter::new();
        for &k in &ks {
            encode(&mut w, k);
        }
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        for &k in &ks {
            assert_eq!(decode(&mut r).unwrap(), k);
        }
    }

    #[test]
    fn decode_malformed_does_not_panic() {
        let bytes = vec![0xff; 64];
        let mut r = BitReader::new(&bytes);
        assert!(decode(&mut r).is_err());
    }

    #[test]
    fn gamma_delta_roundtrip_and_lengths() {
        for k in 1..=3000u64 {
            let mut w = BitWriter::new();
            encode_gamma(&mut w, k);
            assert_eq!(w.len_bits(), len_gamma(k), "gamma len k={k}");
            let b = w.into_bytes();
            assert_eq!(decode_gamma(&mut BitReader::new(&b)).unwrap(), k);

            let mut w = BitWriter::new();
            encode_delta(&mut w, k);
            assert_eq!(w.len_bits(), len_delta(k), "delta len k={k}");
            let b = w.into_bytes();
            assert_eq!(decode_delta(&mut BitReader::new(&b)).unwrap(), k);
        }
        // canonical values: γ(1)="1", γ(2)="010", δ(1)="1"
        assert_eq!(len_gamma(1), 1);
        assert_eq!(len_gamma(2), 3);
        assert_eq!(len_delta(1), 1);
        // asymptotics: omega and delta beat gamma for large k
        let k = 1 << 20;
        assert!(len(k) < len_gamma(k));
        assert!(len_delta(k) < len_gamma(k));
        // but for the tiny integers QSGD mostly emits, gamma is shortest
        assert!(len_gamma(2) <= len(2));
        assert!(len_gamma(3) <= len(3));
    }

    #[test]
    fn lut_matches_reference_encoder() {
        let lut = EliasLut::new(4096);
        for k in 1..=5000u64 {
            let mut wa = BitWriter::new();
            lut.encode(&mut wa, k); // table for k ≤ 4096, fallback above
            let mut wb = BitWriter::new();
            encode(&mut wb, k);
            assert_eq!(wa.len_bits(), wb.len_bits(), "k={k}");
            assert_eq!(wa.into_bytes(), wb.into_bytes(), "k={k}");
        }
        assert!(lut.get(1).is_some());
        assert!(lut.get(4096).is_some());
        assert!(lut.get(4097).is_none());
    }
}
