//! Shared case generators for the integration property tests
//! (`fused_pipeline.rs`, `properties.rs`, `nuqsgd.rs`, `robustness.rs`,
//! `baselines.rs`). Everything is driven by the seeded
//! [`qsgd::util::check::Gen`] context, so failures replay deterministically
//! from the (seed, size) coordinates `forall` reports.
//!
//! Each test binary compiles its own copy of this module and uses a
//! different slice of it, hence the file-level dead_code allowance.
#![allow(dead_code)]

use qsgd::coding::gradient::Regime;
use qsgd::quant::{LevelGrid, Norm};
use qsgd::util::check::Gen;
use qsgd::util::rng;

/// Adversarial coordinate values: signed zeros, subnormals, magnitudes near
/// both ends of the f32 range. (NaN/±inf are exercised separately where the
/// property under test is defined for them.)
pub const ADVERSARIAL_VALUES: &[f32] = &[
    0.0,
    -0.0,
    // smallest normal and smallest subnormal, both signs
    f32::MIN_POSITIVE,
    -f32::MIN_POSITIVE,
    1e-45,
    -1e-45,
    1e-38,
    -1e-38,
    1e-30,
    -1e-30,
    // near the top of the f32 range (squares overflow to inf under L2)
    3e38,
    -3e38,
    1.0,
    -1.0,
];

/// A gradient of length `n`: Gaussian base with adversarial values sprinkled
/// in, occasionally rescaled to huge/tiny magnitude, occasionally all-zero.
pub fn gen_vec(g: &mut Gen, n: usize) -> Vec<f32> {
    let mut v = g.f32_vec(n);
    match g.usize_in(0, 7) {
        // all-zero gradient (degenerate buckets end-to-end)
        0 => v.iter_mut().for_each(|x| *x = 0.0),
        // whole-vector magnitude stress (scale under/overflow in Norm::scale)
        1 => {
            let k = if g.bool() { 1e30 } else { 1e-30 };
            v.iter_mut().for_each(|x| *x *= k);
        }
        _ => {}
    }
    // sprinkle adversarial coordinates over ~1/8 of positions
    if n > 0 {
        let hits = g.usize_in(0, n.div_ceil(8));
        for _ in 0..hits {
            let i = g.usize_in(0, n - 1);
            let a = ADVERSARIAL_VALUES[g.usize_in(0, ADVERSARIAL_VALUES.len() - 1)];
            v[i] = a;
        }
    }
    v
}

/// Dimension + bucket size: small, bucket-boundary-straddling and
/// whole-vector shapes all get coverage.
pub fn gen_dims(g: &mut Gen) -> (usize, usize) {
    let n = g.usize_in(0, g.size);
    let bucket = [1usize, 3, 16, 64, 512, 4096, usize::MAX][g.usize_in(0, 6)];
    (n, bucket)
}

pub fn gen_norm(g: &mut Gen) -> Norm {
    if g.bool() {
        Norm::L2
    } else {
        Norm::Max
    }
}

pub fn gen_regime(g: &mut Gen) -> Option<Regime> {
    match g.usize_in(0, 2) {
        0 => None,
        1 => Some(Regime::Sparse),
        _ => Some(Regime::Dense),
    }
}

/// A level grid of any family: uniform (QSGD), exponential (NUQSGD), or a
/// random strictly-increasing custom grid.
pub fn gen_grid(g: &mut Gen) -> LevelGrid {
    match g.usize_in(0, 2) {
        0 => LevelGrid::uniform([1u32, 4, 15, 255][g.usize_in(0, 3)]),
        1 => LevelGrid::exponential([1u32, 2, 4, 8, 16][g.usize_in(0, 4)]),
        _ => gen_custom_grid(g),
    }
}

/// A random valid custom grid: up to 12 strictly increasing levels in
/// (0, 1), always ending at exactly 1.0.
pub fn gen_custom_grid(g: &mut Gen) -> LevelGrid {
    let k = g.usize_in(0, 11);
    let mut pts: Vec<f32> = (0..k)
        .map(|_| rng::uniform_f32(g.rng))
        .filter(|&x| x > 1e-6 && x < 0.999)
        .collect();
    pts.sort_by(|a, b| a.partial_cmp(b).unwrap());
    pts.dedup();
    pts.push(1.0);
    LevelGrid::custom(pts).expect("generated grid must be valid")
}

/// Caller-supplied uniforms in [0, 1) for the deterministic quantizers.
pub fn gen_uniforms(g: &mut Gen, n: usize) -> Vec<f32> {
    rng::uniform_vec(g.rng, n)
}

/// A fresh RNG seed derived from the generation context (so the property
/// can seed twin compressors identically).
pub fn gen_seed(g: &mut Gen) -> u64 {
    (g.u32() as u64) << 32 | g.u32() as u64
}
